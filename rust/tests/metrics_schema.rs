//! Golden schema test for the metrics JSON snapshot: the exact key sets
//! of the per-op rows and the reserved `_`-sections are load-bearing —
//! dashboards and the CI bench tooling key on them — so any drift must
//! be a deliberate, test-updating change.

use mddct::coordinator::Metrics;
use mddct::server::ServerStats;
use mddct::util::json::Json;

/// Sorted keys of a JSON object (panics on non-objects).
fn keys(v: &Json) -> Vec<&str> {
    match v {
        Json::Obj(o) => o.keys().map(String::as_str).collect(),
        other => panic!("expected object, got {other:?}"),
    }
}

#[test]
fn snapshot_schema_is_golden() {
    let m = Metrics::new();
    m.record("dct2d", 2, 0.002, 3, 4); // sharded 2D traffic
    m.record("dct3d", 3, 0.010, 1, 1);
    m.record_packed("dct2d", 8);
    m.record_error("dct2d");
    let snap = m.snapshot();

    // per-op row: the full golden key set (packed_batch_hist appears
    // only once a packed batch ran, so dct2d has it and dct3d doesn't)
    let golden_op = [
        "dropped_replies",
        "errors",
        "expired_requests",
        "max_batch",
        "max_bands",
        "max_latency_s",
        "max_packed_batch",
        "mean_batch",
        "mean_latency_s",
        "p50_latency_s",
        "p95_latency_s",
        "packed_batch_hist",
        "packed_batches",
        "packed_requests",
        "packed_zero_copy",
        "requests",
        "retried_degraded",
        "sharded_requests",
        "shed_requests",
    ];
    assert_eq!(keys(snap.get("dct2d").unwrap()), golden_op);
    let without_hist: Vec<&str> =
        golden_op.iter().copied().filter(|k| *k != "packed_batch_hist").collect();
    assert_eq!(keys(snap.get("dct3d").unwrap()), without_hist);

    // rank breakdown: one bucket per dimensionality seen, fixed fields
    let by_rank = snap.get("_sharding_by_rank").unwrap();
    assert_eq!(keys(by_rank), ["2d", "3d"]);
    for rank in ["2d", "3d"] {
        assert_eq!(
            keys(by_rank.get(rank).unwrap()),
            ["max_bands", "requests", "sharded_requests"]
        );
    }

    // scratch-pool section: always present, fixed fields
    assert_eq!(
        keys(snap.get("_scratch").unwrap()),
        [
            "max_retained_per_class",
            "pool_misses",
            "prewarm_bytes",
            "prewarm_calls",
            "retained_buffers",
            "retained_bytes",
        ]
    );

    // the snapshot round-trips through the crate's own JSON grammar
    let reparsed = Json::parse(&snap.to_string()).unwrap();
    assert_eq!(keys(&reparsed), keys(&snap));
    assert_eq!(
        reparsed.get("dct2d").unwrap().get("requests").unwrap().as_f64().unwrap(),
        1.0
    );
    assert_eq!(
        reparsed.get("dct2d").unwrap().get("errors").unwrap().as_f64().unwrap(),
        1.0
    );
}

#[test]
fn server_section_schema_is_golden() {
    // the `_server` section the TCP front-end merges into the snapshot
    // (via Service::snapshot_with): fixed key set, all numeric, present
    // even on a server that has seen no traffic
    let stats = ServerStats::new();
    let golden_server = [
        "accepted_conns",
        "active_conns",
        "bytes_in",
        "bytes_out",
        "decode_errors",
        "draining",
        "frames_in",
        "frames_out",
        "idle_timeouts",
        "inflight_requests",
        "read_timeouts",
        "rejected_conns",
        "violation_closes",
    ];
    let snap = stats.snapshot();
    assert_eq!(keys(&snap), golden_server);
    for k in golden_server {
        assert_eq!(snap.get(k).and_then(Json::as_f64), Some(0.0), "{k} starts at zero");
    }
    // the section survives the crate's own JSON grammar round trip
    let reparsed = Json::parse(&snap.to_string()).unwrap();
    assert_eq!(keys(&reparsed), golden_server);
}

#[test]
fn tenant_section_schema_is_golden() {
    // the `_tenants` section appears only once explicitly-tenanted
    // traffic was recorded; each row has a fixed key set
    let m = Metrics::new();
    m.record("dct2d", 2, 0.002, 1, 1);
    assert!(m.snapshot().get("_tenants").is_none(), "untenanted traffic adds no section");
    m.record_tenant_submitted("alice");
    m.record_tenant_done("alice", 0.004);
    m.record_tenant_shed("alice");
    m.record_tenant_expired("alice");
    let snap = m.snapshot();
    let tenants = snap.get("_tenants").expect("_tenants after tenanted traffic");
    assert_eq!(keys(tenants), ["alice"]);
    assert_eq!(
        keys(tenants.get("alice").unwrap()),
        [
            "completed",
            "expired_requests",
            "mean_latency_s",
            "p50_latency_s",
            "p95_latency_s",
            "p99_latency_s",
            "shed_requests",
            "submitted",
        ]
    );
    // and survives the crate's own JSON grammar round trip
    let reparsed = Json::parse(&snap.to_string()).unwrap();
    assert_eq!(keys(reparsed.get("_tenants").unwrap()), ["alice"]);
}

#[test]
fn empty_registry_snapshot_still_carries_scratch() {
    let snap = Metrics::new().snapshot();
    // no traffic: no op rows, no rank section — but the scratch section
    // (process-wide pool state) is unconditional
    assert!(snap.get("_scratch").is_some());
    assert!(snap.get("_sharding_by_rank").is_none());
}
