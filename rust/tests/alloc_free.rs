//! Zero-allocation contract for the fused hot paths (the batched-engine
//! PR's acceptance criterion): after plan construction (which prewarms
//! the building thread's scratch pool via the plan-owned `Workspace`)
//! and one warm-up call (which covers any class a different kernel
//! selection might add), `forward`/`inverse` on the fused 1D/2D plans
//! must perform **zero heap allocations**.
//!
//! Asserted with a counting global allocator. This file deliberately
//! contains a single `#[test]` so the whole binary runs on one thread —
//! the counter is process-global, and a concurrently-running test would
//! pollute it. Plans run `ExecPolicy::Serial` so every stage executes
//! inline on the counted thread. The thread-local pool-miss guard in
//! `util::scratch` is asserted alongside as the finer-grained signal.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use mddct::dct::{Algo1d, Dct1d, Dct2, Idct1d, Idct2, Idxst1d};
use mddct::layout::Layout as MddctLayout;
use mddct::parallel::ExecPolicy;
use mddct::util::rng::Rng;
use mddct::util::scratch;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

/// Run `f` several times after one warm-up call and assert the global
/// allocation counter and the thread-local pool-miss guard both stand
/// still across the timed calls.
fn assert_alloc_free(what: &str, mut f: impl FnMut()) {
    f(); // warm-up: populates any scratch class prewarm didn't cover
    let misses0 = scratch::pool_misses();
    let allocs0 = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..3 {
        f();
    }
    let allocs = ALLOCS.load(Ordering::Relaxed) - allocs0;
    let misses = scratch::pool_misses() - misses0;
    assert_eq!(misses, 0, "{what}: scratch pool missed {misses} times after warm-up");
    assert_eq!(allocs, 0, "{what}: {allocs} heap allocations after warm-up");
}

#[test]
fn warmed_fused_hot_paths_do_not_allocate() {
    let mut rng = Rng::new(800);

    // fused 2D forward + inverse, power-of-two (radix kernels, blocked
    // column path) and non-power-of-two (Bluestein columns + rows)
    for (n1, n2) in [(16usize, 16usize), (32, 8), (12, 12)] {
        let x = rng.normal_vec(n1 * n2);
        let mut y = vec![0.0; n1 * n2];
        let fwd = Dct2::with_policy(n1, n2, ExecPolicy::Serial);
        assert_alloc_free(&format!("dct2 {n1}x{n2}"), || fwd.forward(&x, &mut y));
        let inv = Idct2::with_policy(n1, n2, ExecPolicy::Serial);
        assert_alloc_free(&format!("idct2 {n1}x{n2}"), || inv.forward(&x, &mut y));
    }

    // zero-copy batch entry points: the coordinator's packed views path
    // (forward_batch_views) and the strided single-block path must also
    // run allocation-free once warm — the whole point of taking views
    // is that no pack buffer materializes. The views Vec and the output
    // are built outside the measured closures.
    {
        let (n1, n2, batch) = (8usize, 12usize, 4usize);
        let numel = n1 * n2;
        let xs = rng.normal_vec(numel * batch);
        let views: Vec<&[f64]> = xs.chunks(numel).collect();
        let mut out = vec![0.0; numel * batch];
        let fwd = Dct2::with_policy(n1, n2, ExecPolicy::Serial);
        assert_alloc_free("dct2 batch views", || fwd.forward_batch_views(&views, &mut out));
        let inv = Idct2::with_policy(n1, n2, ExecPolicy::Serial);
        assert_alloc_free("idct2 batch views", || inv.forward_batch_views(&views, &mut out));

        // strided view over a larger arena (row stride > n2)
        let (s2, s1) = (1usize, n2 + 5);
        let layout = MddctLayout::contiguous(&[n1, n2])
            .with_strides(&[s1, s2])
            .with_batch_stride((n1 - 1) * s1 + n2);
        let arena = rng.normal_vec((n1 - 1) * s1 + n2);
        let mut y = vec![0.0; numel];
        assert_alloc_free("dct2 strided", || fwd.forward_strided(&arena, &layout, &mut y));
        assert_alloc_free("idct2 strided", || inv.forward_strided(&arena, &layout, &mut y));
    }

    // 1D family: all four Algorithm-1 variants, the inverse, and IDXST
    let n = 32;
    let x = rng.normal_vec(n);
    let mut y = vec![0.0; n];
    for algo in Algo1d::ALL {
        let plan = Dct1d::with_exec(n, algo, ExecPolicy::Serial);
        assert_alloc_free(&format!("dct1d {}", algo.name()), || plan.forward(&x, &mut y));
    }
    let idct = Idct1d::with_exec(n, ExecPolicy::Serial);
    assert_alloc_free("idct1d", || idct.forward(&x, &mut y));
    let idxst = Idxst1d::new(n);
    assert_alloc_free("idxst1d", || idxst.forward(&x, &mut y));
}
