//! End-to-end AOT integration: the JAX/Pallas-lowered HLO artifacts,
//! executed from Rust via PJRT, must agree with (a) the native Rust
//! backend and (b) the direct O(N^2) oracle.
//!
//! Requires `make artifacts` (skipped gracefully when absent so plain
//! `cargo test` works before the first artifact build).

use mddct::dct::direct::dct2d_direct;
use mddct::dct::{Algo1d, Combo, Dct1d, Dct2, Idct2, IdxstCombo};
use mddct::runtime::PjrtRuntime;
use mddct::util::rng::Rng;

fn runtime() -> Option<PjrtRuntime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        return None;
    }
    Some(PjrtRuntime::new("artifacts").expect("runtime"))
}

/// f32 artifacts vs f64 native: tolerance driven by f32 roundoff on
/// O(N log N) accumulations.
fn assert_close(got: &[f64], want: &[f64], tol: f64, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    let scale = want.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol * scale,
            "{what} at {i}: got {g}, want {w} (scale {scale})"
        );
    }
}

#[test]
fn dct2d_artifact_matches_native_and_oracle() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("dct2d_64x64").expect("load dct2d_64x64");
    let mut rng = Rng::new(100);
    let x = rng.normal_vec(64 * 64);
    let got = exe.run_f64(&[x.clone()]).expect("run")[0].clone();
    let mut native = vec![0.0; 64 * 64];
    Dct2::new(64, 64).forward(&x, &mut native);
    assert_close(&got, &native, 2e-4, "pjrt vs native");
    assert_close(&got, &dct2d_direct(&x, 64, 64), 2e-4, "pjrt vs oracle");
}

#[test]
fn pallas_artifact_matches_jnp_artifact() {
    let Some(rt) = runtime() else { return };
    let a = rt.load("dct2d_pallas_128x128").expect("pallas artifact");
    let b = rt.load("dct2d_128x128").expect("jnp artifact");
    let mut rng = Rng::new(101);
    let x: Vec<f32> = (0..128 * 128).map(|_| rng.normal() as f32).collect();
    let ya = a.run_f32(&[x.clone()]).unwrap()[0].clone();
    let yb = b.run_f32(&[x]).unwrap()[0].clone();
    let scale = yb.iter().fold(1.0f32, |m, v| m.max(v.abs()));
    for (i, (u, v)) in ya.iter().zip(&yb).enumerate() {
        assert!((u - v).abs() <= 1e-3 * scale, "at {i}: {u} vs {v}");
    }
}

#[test]
fn idct_artifact_roundtrips_dct_artifact() {
    let Some(rt) = runtime() else { return };
    let fwd = rt.load("dct2d_128x128").unwrap();
    let inv = rt.load("idct2d_128x128").unwrap();
    let mut rng = Rng::new(102);
    let x = rng.normal_vec(128 * 128);
    let y = fwd.run_f64(&[x.clone()]).unwrap()[0].clone();
    let back = inv.run_f64(&[y]).unwrap()[0].clone();
    assert_close(&back, &x, 5e-3, "roundtrip");
}

#[test]
fn idct2_native_matches_idct_artifact() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("idct2d_64x64").unwrap();
    let mut rng = Rng::new(103);
    let x = rng.normal_vec(64 * 64);
    let got = exe.run_f64(&[x.clone()]).unwrap()[0].clone();
    let mut native = vec![0.0; 64 * 64];
    Idct2::new(64, 64).forward(&x, &mut native);
    assert_close(&got, &native, 2e-4, "idct pjrt vs native");
}

#[test]
fn dct1d_artifacts_match_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(104);
    let x = rng.normal_vec(1024);
    for (name, algo) in [
        ("dct1d_4n_1024", Algo1d::FourN),
        ("dct1d_2n_mirror_1024", Algo1d::Mirror2N),
        ("dct1d_2n_pad_1024", Algo1d::Pad2N),
        ("dct1d_n_1024", Algo1d::NPoint),
    ] {
        let exe = rt.load(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        let got = exe.run_f64(&[x.clone()]).unwrap()[0].clone();
        let mut native = vec![0.0; 1024];
        Dct1d::new(1024, algo).forward(&x, &mut native);
        assert_close(&got, &native, 5e-4, name);
    }
}

#[test]
fn idxst_combo_artifacts_match_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(105);
    let n = 256;
    let x = rng.normal_vec(n * n);
    for (name, combo) in
        [("idct_idxst_256x256", Combo::IdctIdxst), ("idxst_idct_256x256", Combo::IdxstIdct)]
    {
        let exe = rt.load(name).unwrap();
        let got = exe.run_f64(&[x.clone()]).unwrap()[0].clone();
        let mut native = vec![0.0; n * n];
        IdxstCombo::new(n, n, combo).forward(&x, &mut native);
        assert_close(&got, &native, 2e-3, name);
    }
}

#[test]
fn rfft2d_artifact_has_two_outputs() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("rfft2d_64x64").unwrap();
    let x = vec![1.0f32; 64 * 64];
    let out = exe.run_f32(&[x]).unwrap();
    assert_eq!(out.len(), 2);
    assert_eq!(out[0].len(), 64 * 33);
    // DC bin of an all-ones input = N1*N2, imaginary part 0
    assert!((out[0][0] - 4096.0).abs() < 1e-1);
    assert!(out[1][0].abs() < 1e-3);
}

#[test]
fn executable_cache_hits() {
    let Some(rt) = runtime() else { return };
    let a = rt.load("dct2d_64x64").unwrap();
    let before = rt.cached_count();
    let b = rt.load("dct2d_64x64").unwrap();
    assert_eq!(rt.cached_count(), before);
    assert!(std::sync::Arc::ptr_eq(&a, &b));
    assert!(a.stats().compile_seconds > 0.0);
}

#[test]
fn dst_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("dst2d_256x256").expect("dst artifact");
    let mut rng = Rng::new(106);
    let x = rng.normal_vec(256 * 256);
    let got = exe.run_f64(&[x.clone()]).unwrap()[0].clone();
    let mut native = vec![0.0; 256 * 256];
    mddct::dct::Dst2::new(256, 256).forward(&x, &mut native);
    assert_close(&got, &native, 2e-3, "dst2d pjrt vs native");
    // inverse artifact roundtrips
    let inv = rt.load("idst2d_256x256").unwrap();
    let back = inv.run_f64(&[got]).unwrap()[0].clone();
    assert_close(&back, &x, 5e-3, "dst roundtrip");
}
