//! Layout polymorphism properties (the layout PR's acceptance tests):
//!
//! * every f64 strided / views / batched entry point gathers exactly
//!   the values a contiguous call would, in the same arithmetic order,
//!   so its output is **bit-identical** to the contiguous-f64 oracle —
//!   across pow2 and Bluestein shapes, batch sizes, exec policies, and
//!   shard counts;
//! * the f32 generic plans track the f64 oracle to 1e-4 relative
//!   accuracy (forward) and roundtrip back to the input within 1e-3.

use mddct::dct::{Dct2, Dct2F32, Idct2, Idct2F32};
use mddct::fft::nd::Rfft2Plan;
use mddct::layout::Layout;
use mddct::parallel::{ExecPolicy, ShardPolicy};
use mddct::util::rng::Rng;

/// Embed `batch` contiguous `n1 x n2` blocks into a NaN-padded strided
/// arena; returns the arena and its layout. NaN padding makes any
/// out-of-view read poison the output, so bit-identity also proves the
/// strided gather never strays.
fn stride_blocks(
    xs: &[f64],
    n1: usize,
    n2: usize,
    batch: usize,
    r1: usize,
    r2: usize,
) -> (Vec<f64>, Layout) {
    let (s2, s1) = (r2, n2 * r2 * r1 + 1);
    let span = (n1 - 1) * s1 + (n2 - 1) * s2 + 1;
    let bstride = span + 3;
    let layout = Layout::contiguous(&[n1, n2])
        .with_strides(&[s1, s2])
        .with_batch_stride(bstride);
    assert!(layout.validate().is_ok());
    let mut arena = vec![f64::NAN; layout.required_len(batch)];
    for b in 0..batch {
        for i in 0..n1 {
            for j in 0..n2 {
                arena[b * bstride + i * s1 + j * s2] = xs[b * n1 * n2 + i * n2 + j];
            }
        }
    }
    (arena, layout)
}

const SHAPES: [(usize, usize); 4] = [(8, 8), (16, 16), (9, 15), (13, 7)];

#[test]
fn strided_dct2_is_bit_identical_to_contiguous() {
    let mut rng = Rng::new(900);
    for &(n1, n2) in &SHAPES {
        for shards in [1usize, 2, 3] {
            for (r1, r2) in [(1usize, 2usize), (2, 1), (3, 3)] {
                let fwd = Dct2::with_policy(n1, n2, ExecPolicy::Threads(shards))
                    .with_shards(ShardPolicy::MaxShards(shards));
                let x = rng.normal_vec(n1 * n2);
                let mut want = vec![0.0; n1 * n2];
                fwd.forward(&x, &mut want);
                let (arena, layout) = stride_blocks(&x, n1, n2, 1, r1, r2);
                let mut got = vec![0.0; n1 * n2];
                fwd.forward_strided(&arena, &layout, &mut got);
                assert_eq!(got, want, "dct2 {n1}x{n2} shards={shards} r=({r1},{r2})");

                let inv = Idct2::with_policy(n1, n2, ExecPolicy::Threads(shards))
                    .with_shards(ShardPolicy::MaxShards(shards));
                let mut iwant = vec![0.0; n1 * n2];
                inv.forward(&x, &mut iwant);
                let mut igot = vec![0.0; n1 * n2];
                inv.forward_strided(&arena, &layout, &mut igot);
                assert_eq!(igot, iwant, "idct2 {n1}x{n2} shards={shards} r=({r1},{r2})");
            }
        }
    }
}

#[test]
fn strided_and_views_batches_are_bit_identical_to_packed() {
    let mut rng = Rng::new(901);
    for &(n1, n2) in &SHAPES {
        let numel = n1 * n2;
        for batch in [1usize, 3, 5] {
            let xs = rng.normal_vec(numel * batch);
            for exec in [ExecPolicy::Serial, ExecPolicy::Threads(4)] {
                let fwd = Dct2::with_policy(n1, n2, exec);
                let mut want = vec![0.0; numel * batch];
                fwd.forward_batch(&xs, &mut want, batch);

                let views: Vec<&[f64]> = xs.chunks(numel).collect();
                let mut got = vec![0.0; numel * batch];
                fwd.forward_batch_views(&views, &mut got);
                assert_eq!(got, want, "dct2 views {n1}x{n2} b={batch} {exec:?}");

                let (arena, layout) = stride_blocks(&xs, n1, n2, batch, 2, 1);
                got.fill(0.0);
                fwd.forward_batch_strided(&arena, &layout, &mut got, batch);
                assert_eq!(got, want, "dct2 strided batch {n1}x{n2} b={batch} {exec:?}");

                let inv = Idct2::with_policy(n1, n2, exec);
                let mut iwant = vec![0.0; numel * batch];
                inv.forward_batch(&xs, &mut iwant, batch);
                let mut igot = vec![0.0; numel * batch];
                inv.forward_batch_views(&views, &mut igot);
                assert_eq!(igot, iwant, "idct2 views {n1}x{n2} b={batch} {exec:?}");
                igot.fill(0.0);
                inv.forward_batch_strided(&arena, &layout, &mut igot, batch);
                assert_eq!(igot, iwant, "idct2 strided batch {n1}x{n2} b={batch} {exec:?}");
            }
        }
    }
}

#[test]
fn strided_rfft2_is_bit_identical_to_contiguous() {
    let mut rng = Rng::new(902);
    for &(n1, n2) in &SHAPES {
        let plan = Rfft2Plan::new(n1, n2);
        let x = rng.normal_vec(n1 * n2);
        let h2 = n2 / 2 + 1;
        let mut want = vec![mddct::fft::C64::default(); n1 * h2];
        plan.forward(&x, &mut want);
        let (arena, layout) = stride_blocks(&x, n1, n2, 1, 1, 3);
        let mut got = vec![mddct::fft::C64::default(); n1 * h2];
        plan.forward_strided(&arena, &layout, &mut got);
        assert_eq!(got, want, "rfft2 {n1}x{n2}");
    }
}

/// Max relative error of `got` against an f64 oracle, scaled by the
/// oracle's max magnitude (coefficients span orders of magnitude, so
/// per-element relative error would over-penalize near-zeros).
fn rel_err(got: &[f32], want: &[f64]) -> f64 {
    let scale = want.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    got.iter()
        .zip(want)
        .map(|(g, w)| (f64::from(*g) - w).abs() / scale)
        .fold(0.0, f64::max)
}

#[test]
fn f32_plans_track_the_f64_oracle() {
    let mut rng = Rng::new(903);
    for &(n1, n2) in &SHAPES {
        let numel = n1 * n2;
        for batch in [1usize, 4] {
            let xs = rng.normal_vec(numel * batch);
            let xs32: Vec<f32> = xs.iter().map(|&v| v as f32).collect();

            let oracle = Dct2::new(n1, n2);
            let mut want = vec![0.0; numel * batch];
            oracle.forward_batch(&xs, &mut want, batch);

            let plan = Dct2F32::new(n1, n2);
            let mut got = vec![0.0f32; numel * batch];
            plan.forward_batch(&xs32, &mut got, batch);
            let err = rel_err(&got, &want);
            assert!(err <= 1e-4, "dct2 f32 {n1}x{n2} b={batch}: rel err {err:.2e}");

            // inverse roundtrips back to the input at f32 accuracy
            let inv = Idct2F32::new(n1, n2);
            let mut back = vec![0.0f32; numel * batch];
            inv.forward_batch(&got, &mut back, batch);
            let err = rel_err(&back, &xs);
            assert!(err <= 1e-3, "idct2(dct2) f32 {n1}x{n2} b={batch}: rel err {err:.2e}");
        }
    }
}
