//! End-to-end tracing through the service: a band-sharded 2D request
//! and a packed same-shape 1D..2D batch must leave (a) coordinator
//! pipeline spans in the Chrome export, (b) a per-(op, shape) stage
//! breakdown whose stage times sum to the recorded op execution time
//! within 10%, and (c) a Perfetto-loadable trace file on disk.
//!
//! One #[test] on purpose: tracing state (enable flag, span buffers,
//! breakdown table) is process-wide, and this integration binary owns
//! its process.

#![cfg(not(feature = "trace-off"))]

use mddct::coordinator::{BatchPolicy, Service, ServiceConfig, TransformOp};
use mddct::obs;
use mddct::parallel::{ExecPolicy, ShardPolicy};
use mddct::util::json::Json;
use mddct::util::rng::Rng;

fn stage_total(ctx: &str, stage: &str) -> (u64, f64) {
    obs::stage_stats(ctx, stage)
        .unwrap_or_else(|| panic!("stage {stage} missing for ctx {ctx}"))
}

#[test]
fn service_traffic_produces_trace_and_consistent_breakdown() {
    let svc = Service::start_native(ServiceConfig {
        workers: 1,
        batch: BatchPolicy {
            max_batch: 32,
            max_wait: std::time::Duration::from_millis(20),
            ..Default::default()
        },
        exec: ExecPolicy::Serial,
        shard: ShardPolicy::MaxShards(3),
        trace: true, // the ServiceConfig hook must flip the global flag
        default_deadline: None,
        max_inflight_elems: usize::MAX,
    });
    let (n1, n2) = (256usize, 260usize); // >= the 2D shard gate
    let mut rng = Rng::new(700);

    // warm both plans first so the measured spans see cache hits, not
    // one-off plan builds inside the execute window
    svc.transform(TransformOp::Idct2d, vec![n1, n2], rng.normal_vec(n1 * n2)).unwrap();
    svc.transform(TransformOp::Dct2d, vec![8, 8], rng.normal_vec(64)).unwrap();
    obs::reset_events();
    obs::reset_breakdown();

    // --- sharded solo path: 4 large idct2d requests ------------------
    for _ in 0..4 {
        let r = svc.transform(TransformOp::Idct2d, vec![n1, n2], rng.normal_vec(n1 * n2)).unwrap();
        assert_eq!(r.backend, "native");
    }

    // --- packed batch path: 16 same-shape dct2d requests -------------
    let reqs: Vec<_> = (0..16)
        .map(|_| (TransformOp::Dct2d, vec![8usize, 8], rng.normal_vec(64)))
        .collect();
    svc.transform_many(reqs).unwrap();
    let snap = svc.snapshot();
    let packed_batches = snap
        .get("dct2d")
        .and_then(|d| d.get("packed_batches"))
        .and_then(Json::as_f64)
        .unwrap();
    assert!(packed_batches >= 1.0, "the burst must have packed at least once");

    // --- breakdown: stage times vs recorded execute time -------------
    let ctx = format!("idct2d/{n1}x{n2}");
    let (pre_n, pre) = stage_total(&ctx, "idct2.pre");
    let (fft_n, fft) = stage_total(&ctx, "idct2.fft");
    let (post_n, post) = stage_total(&ctx, "idct2.post");
    let (exec_n, exec_total) = stage_total(&ctx, "svc.execute");
    assert_eq!((pre_n, fft_n, post_n, exec_n), (4, 4, 4, 4));
    let stage_sum = pre + fft + post;
    let ratio = stage_sum / exec_total;
    assert!(
        (0.9..=1.02).contains(&ratio),
        "stage sum {stage_sum:.6}s vs svc.execute {exec_total:.6}s (ratio {ratio:.3}): \
         the breakdown must account for the op latency within 10%"
    );

    // the snapshot embeds the same table plus the plan-cache section
    let bd = snap.get("_stage_breakdown").expect("snapshot carries the live breakdown");
    assert!(bd.get(&ctx).and_then(|c| c.get("idct2.fft")).is_some());
    let pc = snap.get("_plan_cache").expect("snapshot carries plan-cache stats");
    assert!(pc.get("hits").unwrap().as_f64().unwrap() >= 4.0);
    assert!(pc.get("misses").unwrap().as_f64().unwrap() >= 2.0);
    assert!(snap.get("_scratch").is_some());

    // --- Chrome export: the coordinator pipeline left its spans ------
    let trace = obs::chrome_trace();
    let events = trace.get("traceEvents").unwrap().as_arr().unwrap();
    let count = |name: &str| {
        events.iter().filter(|e| e.get("name").and_then(Json::as_str) == Some(name)).count()
    };
    assert!(count("svc.queue_wait") >= 20, "every request waits in the queue");
    // 4 from the big solo requests; small requests the batcher flushed
    // alone (timing-dependent) add more
    assert!(count("svc.execute") >= 4, "one execute span per solo request");
    assert!(count("svc.pack") >= 1, "the packed path must have packed");
    assert!(count("svc.execute_batch") >= 1);
    assert!(count("svc.scatter") >= 1);
    assert!(count("plan_cache.hit") >= 4);
    // the sharded idct2 postprocess fans its bands out to the pool
    assert!(count("pool.job") >= 4 * 3, "3 band jobs per sharded request");
    // spans attribute to their request shape in the export too
    let tagged = events.iter().any(|e| {
        e.get("name").and_then(Json::as_str) == Some("idct2.fft")
            && e.get("args").and_then(|a| a.get("ctx")).and_then(Json::as_str)
                == Some(ctx.as_str())
    });
    assert!(tagged, "idct2.fft spans must carry the (op, shape) ctx label");

    // --- the file on disk parses back as trace-event JSON ------------
    let path = std::env::temp_dir().join("mddct-trace-integration.json");
    let path = path.to_str().unwrap();
    // events were drained by chrome_trace() above; record fresh traffic
    svc.transform(TransformOp::Dct2d, vec![8, 8], rng.normal_vec(64)).unwrap();
    obs::write_chrome_trace(path).unwrap();
    let parsed = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
    assert_eq!(parsed.get("displayTimeUnit").unwrap().as_str().unwrap(), "ms");
    assert!(
        !parsed.get("traceEvents").unwrap().as_arr().unwrap().is_empty(),
        "written trace must carry events"
    );
    let _ = std::fs::remove_file(path);
    obs::set_enabled(false);
}
