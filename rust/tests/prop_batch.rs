//! Batched-vs-solo equivalence properties for the batch execution
//! engine: `forward_batch` over B packed blocks must be *bit-identical*
//! (for a fixed FFT kernel) to B independent `forward` calls, for every
//! batch size and shape class — the batch path reuses the serial
//! per-block kernels and only restructures *where* the lanes fan out,
//! so no arithmetic may change. Cross-kernel agreement stays at the
//! usual <= 1e-10 rounding envelope.

use mddct::dct::{Algo1d, Combo, Dct1d, Dct2, Dst2, Idct1d, Idct2, Idst2, IdxstCombo};
use mddct::fft::{onesided_len, C64, FftKernel, Rfft2Plan, RfftPlan};
use mddct::parallel::ExecPolicy;
use mddct::util::rng::Rng;

/// The ISSUE's batch sizes: trivial, tiny, non-divisible, wide.
const BATCHES: &[usize] = &[1, 2, 7, 64];

/// Non-power-of-two shapes (Bluestein on one or both axes) plus one
/// power-of-two control.
const SHAPES: &[(usize, usize)] = &[(9, 15), (13, 7), (12, 10), (16, 16), (1, 9), (6, 1)];

#[test]
fn dct2_forward_batch_is_bit_identical_to_solo_loop() {
    let mut rng = Rng::new(700);
    for &(n1, n2) in SHAPES {
        let numel = n1 * n2;
        for &batch in BATCHES {
            let xs = rng.normal_vec(numel * batch);
            for exec in [ExecPolicy::Serial, ExecPolicy::Threads(4), ExecPolicy::Auto] {
                let plan = Dct2::with_policy(n1, n2, exec);
                let mut want = vec![0.0; numel * batch];
                for (b, w) in want.chunks_mut(numel).enumerate() {
                    plan.forward(&xs[b * numel..(b + 1) * numel], w);
                }
                let mut got = vec![0.0; numel * batch];
                plan.forward_batch(&xs, &mut got, batch);
                assert_eq!(got, want, "dct2 ({n1},{n2}) B={batch} {exec:?}");
            }
        }
    }
}

#[test]
fn idct2_forward_batch_is_bit_identical_to_solo_loop() {
    let mut rng = Rng::new(701);
    for &(n1, n2) in SHAPES {
        let numel = n1 * n2;
        for &batch in BATCHES {
            let xs = rng.normal_vec(numel * batch);
            let plan = Idct2::with_policy(n1, n2, ExecPolicy::Threads(3));
            let mut want = vec![0.0; numel * batch];
            for (b, w) in want.chunks_mut(numel).enumerate() {
                plan.forward(&xs[b * numel..(b + 1) * numel], w);
            }
            let mut got = vec![0.0; numel * batch];
            plan.forward_batch(&xs, &mut got, batch);
            assert_eq!(got, want, "idct2 ({n1},{n2}) B={batch}");
        }
    }
}

#[test]
fn dst2_and_idst2_forward_batch_are_bit_identical_to_solo_loop() {
    // DST-II/III ride the DCT substrate through sign folds; their batch
    // path (new with the packed-batch gate extension) must keep the same
    // bit-identity contract as the DCT plans above
    let mut rng = Rng::new(705);
    for &(n1, n2) in SHAPES {
        let numel = n1 * n2;
        for &batch in BATCHES {
            let xs = rng.normal_vec(numel * batch);
            let dst = Dst2::new(n1, n2);
            let mut want = vec![0.0; numel * batch];
            for (b, w) in want.chunks_mut(numel).enumerate() {
                dst.forward(&xs[b * numel..(b + 1) * numel], w);
            }
            let mut got = vec![0.0; numel * batch];
            dst.forward_batch(&xs, &mut got, batch);
            assert_eq!(got, want, "dst2 ({n1},{n2}) B={batch}");

            let idst = Idst2::new(n1, n2);
            let mut want = vec![0.0; numel * batch];
            for (b, w) in want.chunks_mut(numel).enumerate() {
                idst.forward(&xs[b * numel..(b + 1) * numel], w);
            }
            let mut got = vec![0.0; numel * batch];
            idst.forward_batch(&xs, &mut got, batch);
            assert_eq!(got, want, "idst2 ({n1},{n2}) B={batch}");
        }
    }
}

#[test]
fn combo_forward_batch_is_bit_identical_to_solo_loop() {
    // the DREAMPlace combos close the carried-over batch gap: their
    // shift/sign folds sweep per block around the inner Idct2 batch
    // path, so the whole-batch output must stay bit-equal to B
    // independent forwards — same contract as every plan above
    let mut rng = Rng::new(706);
    for combo in [Combo::IdctIdxst, Combo::IdxstIdct] {
        for &(n1, n2) in SHAPES {
            let numel = n1 * n2;
            for &batch in BATCHES {
                let xs = rng.normal_vec(numel * batch);
                let plan = IdxstCombo::new(n1, n2, combo);
                let mut want = vec![0.0; numel * batch];
                for (b, w) in want.chunks_mut(numel).enumerate() {
                    plan.forward(&xs[b * numel..(b + 1) * numel], w);
                }
                let mut got = vec![0.0; numel * batch];
                plan.forward_batch(&xs, &mut got, batch);
                assert_eq!(got, want, "{combo:?} ({n1},{n2}) B={batch}");
            }
        }
    }
}

#[test]
fn dct1d_batch_is_bit_identical_across_all_algorithms() {
    let mut rng = Rng::new(702);
    for &n in &[1usize, 5, 9, 15, 16, 33] {
        for &batch in BATCHES {
            let xs = rng.normal_vec(n * batch);
            for algo in Algo1d::ALL {
                let plan = Dct1d::with_exec(n, algo, ExecPolicy::Threads(4));
                let mut want = vec![0.0; n * batch];
                for (b, w) in want.chunks_mut(n).enumerate() {
                    plan.forward(&xs[b * n..(b + 1) * n], w);
                }
                let mut got = vec![0.0; n * batch];
                plan.forward_batch(&xs, &mut got, batch);
                assert_eq!(got, want, "dct1d {} n={n} B={batch}", algo.name());
            }
            let inv = Idct1d::with_exec(n, ExecPolicy::Threads(4));
            let mut want = vec![0.0; n * batch];
            for (b, w) in want.chunks_mut(n).enumerate() {
                inv.forward(&xs[b * n..(b + 1) * n], w);
            }
            let mut got = vec![0.0; n * batch];
            inv.forward_batch(&xs, &mut got, batch);
            assert_eq!(got, want, "idct1d n={n} B={batch}");
        }
    }
}

#[test]
fn rfft2_batch_roundtrips_and_matches_solo() {
    let mut rng = Rng::new(703);
    for &(n1, n2) in &[(9usize, 15usize), (16, 16), (5, 8)] {
        let plan = Rfft2Plan::with_policy(n1, n2, ExecPolicy::Threads(4));
        let h2 = onesided_len(n2);
        for &batch in &[2usize, 7] {
            let xs = rng.normal_vec(n1 * n2 * batch);
            let mut want = vec![C64::default(); n1 * h2 * batch];
            for (b, w) in want.chunks_mut(n1 * h2).enumerate() {
                plan.forward(&xs[b * n1 * n2..(b + 1) * n1 * n2], w);
            }
            let mut got = vec![C64::default(); n1 * h2 * batch];
            plan.forward_batch(&xs, &mut got, batch);
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!((*a - *b).abs() == 0.0, "rfft2 ({n1},{n2}) B={batch} idx={i}");
            }
            let mut back = vec![0.0; n1 * n2 * batch];
            plan.inverse_batch(&got, &mut back, batch);
            for (a, b) in back.iter().zip(&xs) {
                assert!((a - b).abs() < 1e-9, "rfft2 roundtrip ({n1},{n2}) B={batch}");
            }
        }
    }
}

#[test]
fn cross_kernel_batch_outputs_agree_to_rounding() {
    // the bit-identity above is per kernel; across kernels the batch
    // path must stay inside the usual 1e-10 relative envelope
    let mut rng = Rng::new(704);
    let (n, batch) = (24usize, 7usize);
    let xs = rng.normal_vec(n * batch);
    let mut outs: Vec<Vec<f64>> = Vec::new();
    for kernel in [FftKernel::ScalarRadix2, FftKernel::SplitRadixSoa] {
        // drive the 1D pipeline through an explicit-kernel RFFT the way
        // the DCT postprocess consumes it
        let rfft = RfftPlan::with_kernel(n, kernel);
        let h = onesided_len(n);
        let mut spec = vec![C64::default(); h * batch];
        rfft.forward_batch(&xs, &mut spec, 4);
        let mut mags = vec![0.0; h * batch];
        for (m, s) in mags.iter_mut().zip(&spec) {
            *m = s.abs();
        }
        outs.push(mags);
    }
    let scale = outs[0].iter().fold(1.0f64, |m, v| m.max(v.abs()));
    for (a, b) in outs[0].iter().zip(&outs[1]) {
        assert!((a - b).abs() <= 1e-10 * scale, "{a} vs {b}");
    }
}
