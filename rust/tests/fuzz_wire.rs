//! Seeded fuzz sweep over the wire decoder: no input may panic, OOM,
//! or produce anything other than a clean decode or a typed
//! `TransformError::InvalidRequest`.
//!
//! Inputs are grown from valid frames by a seeded mutator
//! (`util::rng`): byte flips, truncation, splices, length-prefix
//! corruption, hostile token injection (`NaN`, `Infinity`, `1e999`,
//! deep nesting), and raw random bytes (usually non-UTF8). Every input
//! runs through `read_frame_slice` + `decode_request` under
//! `catch_unwind`; a panic or an unexpected error variant fails the
//! test with the seed, iteration, and a hex dump for replay.
//!
//! Knobs: `MDDCT_FUZZ_SEED` (default 20260808, always logged) and
//! `MDDCT_FUZZ_ITERS` (default 10_000).

use mddct::coordinator::TransformOp;
use mddct::server::proto::{self, WireRequest};
use mddct::util::error::TransformError;
use mddct::util::rng::Rng;

const MAX_FRAME: usize = 1 << 20;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn hex(bytes: &[u8]) -> String {
    let shown = &bytes[..bytes.len().min(256)];
    let mut s: String = shown.iter().map(|b| format!("{b:02x}")).collect();
    if bytes.len() > shown.len() {
        s.push_str(&format!("... ({} bytes total)", bytes.len()));
    }
    s
}

/// A small pool of valid frames the mutator grows from, so mutations
/// explore the "almost valid" space where parser bugs live.
fn seed_corpus() -> Vec<Vec<u8>> {
    let reqs = [
        WireRequest {
            id: 1,
            op: TransformOp::Dct2d,
            shape: vec![4, 4],
            batch: 1,
            deadline_ms: None,
            tenant: None,
            priority: 0,
            data: (0..16).map(|i| i as f64 - 7.5).collect(),
        },
        WireRequest {
            id: u64::MAX >> 12,
            op: TransformOp::IdxstIdct,
            shape: vec![3, 5],
            batch: 2,
            deadline_ms: Some(250),
            tenant: Some("fuzz-tenant".to_string()),
            priority: 3,
            data: (0..30).map(|i| (i as f64) * 1e-3).collect(),
        },
        WireRequest {
            id: 0,
            op: TransformOp::Dct3d,
            shape: vec![2, 3, 4],
            batch: 1,
            deadline_ms: Some(0),
            tenant: None,
            priority: 0,
            data: vec![0.0; 24],
        },
    ];
    let mut corpus: Vec<Vec<u8>> = Vec::new();
    for r in &reqs {
        let body = proto::encode_request(r);
        let mut frame = Vec::new();
        proto::write_frame(&mut frame, body.as_bytes()).unwrap();
        corpus.push(frame);
    }
    let mut metrics = Vec::new();
    proto::write_frame(&mut metrics, proto::encode_metrics_request().as_bytes()).unwrap();
    corpus.push(metrics);
    corpus
}

/// One seeded mutation: pick a corpus entry, apply 1..=4 mutators.
fn mutate(rng: &mut Rng, corpus: &[Vec<u8>]) -> Vec<u8> {
    let mut buf = corpus[rng.below(corpus.len())].clone();
    for _ in 0..rng.range(1, 4) {
        match rng.below(8) {
            // flip random bytes
            0 => {
                for _ in 0..rng.range(1, 8) {
                    if !buf.is_empty() {
                        let i = rng.below(buf.len());
                        buf[i] ^= rng.next_u64() as u8;
                    }
                }
            }
            // truncate anywhere, including inside the length prefix
            1 => buf.truncate(rng.below(buf.len() + 1)),
            // corrupt the length prefix (oversized / mismatched)
            2 => {
                let word = (rng.next_u64() as u32).to_be_bytes();
                for (i, b) in word.iter().enumerate() {
                    if i < buf.len() {
                        buf[i] = *b;
                    }
                }
            }
            // splice a chunk of another corpus entry into the body
            3 => {
                let other = &corpus[rng.below(corpus.len())];
                let at = rng.below(buf.len() + 1);
                let from = rng.below(other.len());
                let upto = rng.range(from, other.len());
                let tail: Vec<u8> = buf.split_off(at);
                buf.extend_from_slice(&other[from..upto]);
                buf.extend_from_slice(&tail);
            }
            // inject hostile JSON tokens into the body
            4 => {
                let tok: &[u8] = [
                    &b"NaN"[..],
                    b"Infinity",
                    b"-Infinity",
                    b"1e999",
                    b"-1e999",
                    b"1e-999",
                    b"18446744073709551616",
                    b"\"\\udead\"",
                ][rng.below(8)];
                let at = 4.min(buf.len()) + rng.below(buf.len().saturating_sub(4) + 1);
                let tail: Vec<u8> = buf.split_off(at.min(buf.len()));
                buf.extend_from_slice(tok);
                buf.extend_from_slice(&tail);
            }
            // wrap the payload in deep nesting
            5 => {
                let depth = rng.range(1, 200);
                let mut body = vec![b'['; depth];
                body.extend_from_slice(&buf[4.min(buf.len())..]);
                body.extend_from_slice(&vec![b']'; depth]);
                buf = frame(&body);
            }
            // raw random bytes (usually non-UTF8 garbage)
            6 => {
                let n = rng.range(0, 128);
                buf = (0..n + 4).map(|_| rng.next_u64() as u8).collect();
            }
            // duplicate the buffer (multi-frame / trailing garbage)
            7 => {
                let copy = buf.clone();
                buf.extend_from_slice(&copy);
            }
            _ => unreachable!(),
        }
        if buf.len() > MAX_FRAME + 8 {
            buf.truncate(MAX_FRAME + 8);
        }
    }
    buf
}

fn frame(body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(body.len() + 4);
    proto::write_frame(&mut out, body).unwrap();
    out
}

/// Decode one fuzz input the way a connection would: frame first, then
/// body. Returns whether the input was accepted (for the accept-rate
/// log line); any non-InvalidRequest failure panics with diagnostics.
fn check_one(input: &[u8], seed: u64, iter: u64) -> bool {
    let outcome = std::panic::catch_unwind(|| {
        match proto::read_frame_slice(input, MAX_FRAME) {
            Ok(None) => Ok(false),
            Err(TransformError::InvalidRequest(_)) => Ok(false),
            Err(other) => Err(format!("frame error not typed InvalidRequest: {other:?}")),
            Ok(Some((body, _))) => match proto::decode_request(body) {
                Ok(_) => Ok(true),
                Err(TransformError::InvalidRequest(_)) => Ok(false),
                Err(other) => Err(format!("decode error not typed InvalidRequest: {other:?}")),
            },
        }
    });
    match outcome {
        Ok(Ok(accepted)) => accepted,
        Ok(Err(msg)) => {
            panic!("fuzz_wire seed={seed} iter={iter}: {msg}\ninput: {}", hex(input))
        }
        Err(_) => {
            panic!("fuzz_wire seed={seed} iter={iter}: decoder PANICKED\ninput: {}", hex(input))
        }
    }
}

#[test]
fn fuzz_decoder_never_panics_and_rejections_are_typed() {
    let seed = env_u64("MDDCT_FUZZ_SEED", 20_260_808);
    let iters = env_u64("MDDCT_FUZZ_ITERS", 10_000);
    println!("fuzz_wire: seed={seed} iters={iters} (MDDCT_FUZZ_SEED / MDDCT_FUZZ_ITERS)");
    let corpus = seed_corpus();
    let mut rng = Rng::new(seed);
    let mut accepted = 0u64;
    for iter in 0..iters {
        let input = mutate(&mut rng, &corpus);
        if check_one(&input, seed, iter) {
            accepted += 1;
        }
    }
    println!(
        "fuzz_wire: {iters} inputs, {accepted} still decoded cleanly ({:.1}%), zero panics",
        100.0 * accepted as f64 / iters.max(1) as f64
    );
}

#[test]
fn hostile_nesting_is_rejected_without_stack_overflow() {
    // unknown keys run through skip_value, the recursive path a depth
    // bomb targets; far past MAX_DEPTH, unbounded recursion would blow
    // the stack long before finishing
    let mut arrays = b"{\"junk\":".to_vec();
    arrays.extend_from_slice(&vec![b'['; 100_000]);
    arrays.extend_from_slice(&vec![b']'; 100_000]);
    arrays.push(b'}');
    match proto::decode_request(&arrays) {
        Err(TransformError::InvalidRequest(_)) => {}
        other => panic!("wanted typed rejection, got {other:?}"),
    }
    let objects = "{\"junk\":".repeat(5_000) + "0" + &"}".repeat(5_000);
    match proto::decode_request(objects.as_bytes()) {
        Err(TransformError::InvalidRequest(_)) => {}
        other => panic!("wanted typed rejection, got {other:?}"),
    }
}

#[test]
fn nonfinite_and_nonutf8_payloads_are_typed_rejections() {
    let cases: &[&[u8]] = &[
        br#"{"op":"dct2d","shape":[1,1],"data":[NaN]}"#,
        br#"{"op":"dct2d","shape":[1,1],"data":[Infinity]}"#,
        br#"{"op":"dct2d","shape":[1,1],"data":[1e999]}"#,
        br#"{"op":"dct2d","shape":[1,1],"data":[-1e999]}"#,
        b"{\"op\":\"dct2d\",\"shape\":[1,1],\"data\":[1.0],\"x\":\"\xff\xfe\"}",
        b"\xff\xff\xff\xff",
    ];
    for body in cases {
        match proto::decode_request(body) {
            Err(TransformError::InvalidRequest(_)) => {}
            other => panic!("wanted typed rejection for {:?}, got {other:?}", hex(body)),
        }
    }
}
