//! Wire-to-worker chaos soak (the ISSUE's resilience acceptance path):
//! mixed-tenant loopback load under injected connection faults
//! (`garbage` / `close` at site `conn`) crossed with execution panics,
//! plus targeted scenarios for each hardening feature — slowloris
//! partial frames, idle reaping, the decode-violation budget, and
//! graceful drain under load.
//!
//! Invariants held throughout:
//!
//! * survivors are *bit-identical* to the serial oracle (the degrade
//!   path guarantees this even when the primary plan panics);
//! * victims get typed error frames or a clean close — never a hang,
//!   never a panic across the wire;
//! * no reader thread leaks: after the server drops, the process
//!   thread count returns to its pre-server baseline;
//! * a drain under load finishes in-flight work, answers everything
//!   else `shutting_down`, and flips the health route to `draining`.
//!
//! Fault state is process-global, so every test serializes on one mutex
//! and clears the spec on exit (same discipline as
//! `tests/fault_injection.rs`).

#![cfg(not(feature = "fault-off"))]

use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use mddct::coordinator::fault;
use mddct::coordinator::{
    parse_spec, set_faults, BatchPolicy, Service, ServiceConfig, TransformError, TransformOp,
};
use mddct::dct::Dct2;
use mddct::parallel::{ExecPolicy, ShardPolicy};
use mddct::server::proto::{self, WireReply, WireRequest};
use mddct::server::{Server, ServerConfig, MAX_CONN_VIOLATIONS};
use mddct::util::rng::Rng;

/// Serializes tests that install process-wide fault specs (and keeps
/// the thread-count assertions deterministic).
fn guard() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Serial, unsharded primary plans: primary and degraded outputs are
/// bit-equal, so survivors can be compared to one oracle.
fn cfg(workers: usize) -> ServiceConfig {
    ServiceConfig {
        workers,
        batch: BatchPolicy::default(),
        exec: ExecPolicy::Serial,
        shard: ShardPolicy::Auto,
        trace: false,
        default_deadline: None,
        max_inflight_elems: usize::MAX,
    }
}

#[cfg(target_os = "linux")]
fn thread_count() -> usize {
    std::fs::read_dir("/proc/self/task").map(|d| d.count()).unwrap_or(0)
}

/// Fallible request/reply exchange: any framing, socket, or decode
/// failure comes back as `Err` so chaos victims can reconnect.
fn try_exchange(stream: &mut TcpStream, body: &str) -> Result<WireReply, String> {
    proto::write_frame(stream, body.as_bytes()).map_err(|e| e.to_string())?;
    let frame = proto::read_frame(stream, proto::DEFAULT_MAX_FRAME_BYTES)
        .map_err(|e| e.to_string())?
        .ok_or_else(|| "eof before reply".to_string())?;
    proto::decode_reply(&frame).map_err(|e| e.to_string())
}

fn serial_oracle(n1: usize, n2: usize, x: &[f64]) -> Vec<f64> {
    let mut want = vec![0.0; n1 * n2];
    Dct2::with_policy(n1, n2, ExecPolicy::Serial).forward(x, &mut want);
    want
}

#[test]
fn mixed_tenant_soak_survives_connection_chaos_without_leaking_threads() {
    let _g = guard();
    fault::clear();
    let svc = Arc::new(Service::start_native(cfg(2)));
    #[cfg(target_os = "linux")]
    let baseline = thread_count();
    // short read timeout so chaos-torn frames cannot stall a reader (or
    // this test) for the default 30 s
    let server_cfg = ServerConfig {
        read_timeout: Some(Duration::from_millis(250)),
        ..ServerConfig::ephemeral()
    };
    let server = Server::start(server_cfg, svc.clone()).expect("bind ephemeral");
    let addr = server.addr();

    let n = 8usize;
    let mut rng = Rng::new(0xC4A05);
    let x = rng.normal_vec(n * n);
    let want = serial_oracle(n, n, &x);

    // conn faults tear frames on the wire; the execution panic crosses
    // them with the degrade-and-retry path. The CI chaos job appends
    // its own spec (e.g. a conn stall) through MDDCT_FAULT.
    let mut spec = String::from("garbage:conn:0.05,close:conn:0.02,panic:dct2d:0.2");
    if let Ok(extra) = std::env::var("MDDCT_FAULT") {
        if !extra.is_empty() {
            spec.push(',');
            spec.push_str(&extra);
        }
    }
    set_faults(parse_spec(&spec).unwrap_or_else(|e| panic!("bad soak spec '{spec}': {e}")));

    let tenants = ["alice", "bob", "carol"];
    let mut joins = Vec::new();
    for (t_idx, tenant) in tenants.iter().enumerate() {
        let (x, want) = (x.clone(), want.clone());
        joins.push(std::thread::spawn(move || {
            let mut ok = 0usize;
            let mut victims = 0usize;
            let mut stream: Option<TcpStream> = None;
            for i in 0..40u64 {
                let mut s = match stream.take() {
                    Some(s) => s,
                    None => match TcpStream::connect(addr) {
                        Ok(s) => {
                            // a torn reply must not hang the client
                            let _ = s.set_read_timeout(Some(Duration::from_secs(2)));
                            s
                        }
                        Err(_) => {
                            victims += 1;
                            continue;
                        }
                    },
                };
                let req = WireRequest {
                    id: i,
                    op: TransformOp::Dct2d,
                    shape: vec![n, n],
                    batch: 1,
                    deadline_ms: Some(10_000),
                    tenant: Some(tenant.to_string()),
                    priority: t_idx as u8,
                    data: x.clone(),
                };
                match try_exchange(&mut s, &proto::encode_request(&req)) {
                    Ok(WireReply::Ok { id, data, .. }) => {
                        assert_eq!(id, i, "{tenant}: correlation id");
                        assert_eq!(data, want, "{tenant}: survivor must be bit-equal");
                        ok += 1;
                        stream = Some(s); // healthy connection: reuse
                    }
                    // typed error frame: a legitimate victim — reconnect
                    Ok(WireReply::Err { .. }) => victims += 1,
                    Ok(other) => panic!("{tenant}: unexpected reply {other:?}"),
                    // torn frame / injected close: reconnect
                    Err(_) => victims += 1,
                }
            }
            (ok, victims)
        }));
    }
    let mut total_ok = 0usize;
    for j in joins {
        total_ok += j.join().expect("client thread must not panic").0;
    }
    fault::clear();
    assert!(total_ok > 0, "some requests must survive the chaos");

    // per-tenant accounting surfaced in the snapshot
    let snap = svc.snapshot();
    let tenants_section = snap.get("_tenants").expect("_tenants section after tenanted traffic");
    for t in tenants {
        let submitted = tenants_section
            .get(t)
            .and_then(|row| row.get("submitted"))
            .and_then(mddct::util::json::Json::as_f64)
            .unwrap_or_else(|| panic!("missing _tenants.{t}.submitted"));
        assert!(submitted >= 1.0, "{t}: submitted {submitted}");
    }

    // clean drain under no remaining load, then no thread leak
    drop(server);
    #[cfg(target_os = "linux")]
    {
        let t0 = Instant::now();
        loop {
            let now = thread_count();
            if now <= baseline {
                break;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "reader threads leaked: {now} > baseline {baseline}"
            );
            std::thread::sleep(Duration::from_millis(50));
        }
    }
}

#[test]
fn slowloris_partial_frame_gets_a_typed_timeout_frame() {
    let _g = guard();
    fault::clear();
    let svc = Arc::new(Service::start_native(cfg(1)));
    let server_cfg = ServerConfig {
        read_timeout: Some(Duration::from_millis(200)),
        ..ServerConfig::ephemeral()
    };
    let server = Server::start(server_cfg, svc).expect("bind ephemeral");
    let mut stream = TcpStream::connect(server.addr()).expect("connect");
    // two of four length-prefix bytes, then silence: the frame has
    // started, so the per-frame deadline applies
    stream.write_all(&[0x00, 0x00]).expect("partial prefix");
    stream.flush().expect("flush");
    let frame = proto::read_frame(&mut stream, proto::DEFAULT_MAX_FRAME_BYTES)
        .expect("reply readable")
        .expect("typed frame before close");
    match proto::decode_reply(&frame).expect("decode") {
        WireReply::Err { error: TransformError::InvalidRequest(m), .. } => {
            assert!(m.contains("timed out"), "{m}");
        }
        other => panic!("wanted invalid_request timeout frame, got {other:?}"),
    }
    assert!(
        proto::read_frame(&mut stream, proto::DEFAULT_MAX_FRAME_BYTES)
            .map(|f| f.is_none())
            .unwrap_or(true),
        "connection closed after the timeout frame"
    );
    assert!(server.stats().read_timeouts.load(Ordering::Relaxed) >= 1);
}

#[test]
fn idle_connections_are_reaped_silently() {
    let _g = guard();
    fault::clear();
    let svc = Arc::new(Service::start_native(cfg(1)));
    let server_cfg = ServerConfig {
        idle_timeout: Some(Duration::from_millis(150)),
        ..ServerConfig::ephemeral()
    };
    let server = Server::start(server_cfg, svc).expect("bind ephemeral");
    let mut stream = TcpStream::connect(server.addr()).expect("connect");
    // send nothing: between frames the idle timeout governs, and the
    // close is silent (there is no frame to answer)
    assert!(
        proto::read_frame(&mut stream, proto::DEFAULT_MAX_FRAME_BYTES)
            .map(|f| f.is_none())
            .unwrap_or(true),
        "idle connection closed without a frame"
    );
    assert!(server.stats().idle_timeouts.load(Ordering::Relaxed) >= 1);
}

#[test]
fn repeated_decode_violations_close_the_connection() {
    let _g = guard();
    fault::clear();
    let svc = Arc::new(Service::start_native(cfg(1)));
    let server = Server::start(ServerConfig::ephemeral(), svc).expect("bind ephemeral");
    let mut stream = TcpStream::connect(server.addr()).expect("connect");
    // every strike is answered with a typed frame while the budget lasts
    for i in 0..MAX_CONN_VIOLATIONS {
        match try_exchange(&mut stream, "{never json") {
            Ok(WireReply::Err { error: TransformError::InvalidRequest(_), .. }) => {}
            other => panic!("strike {i}: wanted typed invalid_request, got {other:?}"),
        }
    }
    // the budget is spent: the connection is gone
    assert!(
        proto::read_frame(&mut stream, proto::DEFAULT_MAX_FRAME_BYTES)
            .map(|f| f.is_none())
            .unwrap_or(true),
        "connection closed after {MAX_CONN_VIOLATIONS} violations"
    );
    assert_eq!(server.stats().violation_closes.load(Ordering::Relaxed), 1);
    assert_eq!(
        server.stats().decode_errors.load(Ordering::Relaxed),
        MAX_CONN_VIOLATIONS as u64
    );
}

#[test]
fn drain_under_load_completes_inflight_work_and_flips_health() {
    let _g = guard();
    fault::clear();
    let svc = Arc::new(Service::start_native(cfg(1)));
    let mut server = Server::start(ServerConfig::ephemeral(), svc).expect("bind ephemeral");
    let addr = server.addr();

    let mut rng = Rng::new(31);
    let x = rng.normal_vec(64);
    let want = serial_oracle(8, 8, &x);

    // a probe connection opened before the drain starts (the accept
    // loop stops once it begins)
    let mut probe = TcpStream::connect(addr).expect("probe connect");
    match try_exchange(&mut probe, &proto::encode_health_request()).expect("health") {
        WireReply::Health { status, ready } => {
            assert_eq!((status.as_str(), ready), ("ok", true));
        }
        other => panic!("wanted health reply, got {other:?}"),
    }

    // slow the execution down so the request is still in flight when
    // the drain begins
    set_faults(parse_spec("delay:execute:400ms").unwrap());
    let data = x.clone();
    let worker = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).expect("worker connect");
        let req = WireRequest {
            id: 7,
            op: TransformOp::Dct2d,
            shape: vec![8, 8],
            batch: 1,
            deadline_ms: Some(10_000),
            tenant: Some("drain-tenant".to_string()),
            priority: 1,
            data,
        };
        try_exchange(&mut s, &proto::encode_request(&req)).expect("in-flight reply")
    });
    // wait until that request is actually in flight
    let t0 = Instant::now();
    while server.stats().inflight_requests.load(Ordering::SeqCst) == 0 {
        assert!(t0.elapsed() < Duration::from_secs(5), "request never became in-flight");
        std::thread::sleep(Duration::from_millis(5));
    }
    // a second probe watches the health route flip while draining
    let prober = std::thread::spawn(move || {
        for _ in 0..200 {
            match try_exchange(&mut probe, &proto::encode_health_request()) {
                Ok(WireReply::Health { status, ready }) => {
                    if status == "draining" {
                        assert!(!ready, "draining implies not ready");
                        return (true, probe);
                    }
                }
                Ok(other) => panic!("wanted health reply, got {other:?}"),
                Err(_) => return (false, probe),
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        (false, probe)
    });
    let drained = server.drain(Duration::from_secs(10));
    fault::clear();
    assert!(drained, "in-flight work must finish inside the grace period");

    // the in-flight request survived the drain, bit-equal
    match worker.join().expect("worker thread") {
        WireReply::Ok { id, data, .. } => {
            assert_eq!(id, 7);
            assert_eq!(data, want, "drained survivor must be bit-equal");
        }
        other => panic!("wanted ok reply for the in-flight request, got {other:?}"),
    }
    let (saw_draining, mut probe) = prober.join().expect("prober thread");
    assert!(saw_draining, "health route must report draining during the grace period");
    // after the grace period the probe's connection gets the goodbye
    let goodbye = proto::read_frame(&mut probe, proto::DEFAULT_MAX_FRAME_BYTES)
        .expect("goodbye readable")
        .expect("goodbye frame before close");
    match proto::decode_reply(&goodbye).expect("decode goodbye") {
        WireReply::Err { error: TransformError::ShuttingDown, .. } => {}
        other => panic!("wanted shutting_down goodbye, got {other:?}"),
    }
    assert_eq!(server.stats().draining.load(Ordering::Relaxed), 1);
}
