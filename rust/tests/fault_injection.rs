//! Fault-injection matrix for the request lifecycle: panic / error /
//! delay faults at the coordinator's execution seams, crossed with the
//! solo, packed-batch, and band-sharded routes. The contract under test
//! (ISSUE "degrade-and-retry"):
//!
//! * a failing primary execution never fails the request — it is
//!   retried once on the degraded serial plan and the answer is
//!   *bit-equal* to what the serial path would have produced;
//! * the poisoned plan key is quarantined, so later same-shape requests
//!   skip straight to the degraded plan (no second crash);
//! * delays compose with deadlines (queued requests expire instead of
//!   wasting pool work) and with admission control (a saturated budget
//!   sheds with `Overloaded` instead of queueing without bound);
//! * every conclusion shows up in `Service::snapshot()` counters.
//!
//! Fault state is process-global (like the obs trace flag), so every
//! test serializes on one mutex and clears the fault set on exit.

#![cfg(not(feature = "fault-off"))]

use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

use mddct::coordinator::fault;
use mddct::coordinator::{
    parse_spec, set_faults, BatchPolicy, Service, ServiceConfig, TransformError, TransformOp,
};
use mddct::dct::{Dct2, Idct2};
use mddct::parallel::{ExecPolicy, ShardPolicy};
use mddct::util::json::Json;
use mddct::util::rng::Rng;

/// Serializes tests that install process-wide fault specs.
fn guard() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// A service whose primary plans are serial and unsharded unless a test
/// overrides them — that makes primary and degraded outputs bit-equal,
/// so assert_eq! can distinguish "degraded correctly" from "close".
fn cfg(workers: usize) -> ServiceConfig {
    ServiceConfig {
        workers,
        batch: BatchPolicy::default(),
        exec: ExecPolicy::Serial,
        shard: ShardPolicy::Auto,
        trace: false,
        default_deadline: None,
        max_inflight_elems: usize::MAX,
    }
}

fn counter(snap: &Json, op: &str, field: &str) -> f64 {
    snap.get(op)
        .and_then(|d| d.get(field))
        .and_then(Json::as_f64)
        .unwrap_or_else(|| panic!("snapshot missing {op}.{field}"))
}

#[test]
fn panic_on_solo_execute_degrades_retries_and_quarantines() {
    let _g = guard();
    set_faults(parse_spec("panic:execute").unwrap());
    let s = Service::start_native(cfg(1));
    let (n1, n2) = (8usize, 12usize);
    let mut rng = Rng::new(900);
    let x = rng.normal_vec(n1 * n2);
    let mut want = vec![0.0; n1 * n2];
    Dct2::with_policy(n1, n2, ExecPolicy::Serial).forward(&x, &mut want);

    // the injected panic hits the primary path; the degraded serial
    // retry must answer bit-equal to the serial oracle
    let r = s.transform(TransformOp::Dct2d, vec![n1, n2], x.clone()).unwrap();
    assert_eq!(r.backend, "native-degraded");
    assert_eq!(r.output, want, "degraded answer must be bit-equal to the serial plan");

    let snap = s.snapshot();
    assert_eq!(counter(&snap, "dct2d", "retried_degraded"), 1.0);
    assert_eq!(counter(&snap, "dct2d", "errors"), 0.0, "the retry succeeded");
    let pc = snap.get("_plan_cache").unwrap();
    assert_eq!(pc.get("quarantined").unwrap().as_f64().unwrap(), 1.0);

    // faults off, key still quarantined: served degraded *without* a
    // second retry (no new crash, no retried_degraded bump)
    fault::clear();
    let r2 = s.transform(TransformOp::Dct2d, vec![n1, n2], x).unwrap();
    assert_eq!(r2.backend, "native-degraded");
    assert_eq!(r2.output, want);
    assert_eq!(counter(&s.snapshot(), "dct2d", "retried_degraded"), 1.0);
    // a different shape is a different key — not quarantined, runs primary
    let other = s.transform(TransformOp::Dct2d, vec![4, 4], vec![1.0; 16]).unwrap();
    assert_eq!(other.backend, "native");
    assert_eq!(s.inflight.in_use(), 0, "all budget returned");
}

#[test]
fn error_fault_on_op_degrades_packed_and_solo_requests_alike() {
    let _g = guard();
    // op-name site: fires at every seam dct2d crosses (pack,
    // execute_batch, execute) but leaves other ops alone
    set_faults(parse_spec("error:dct2d").unwrap());
    let s = Service::start_native(ServiceConfig {
        batch: BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_millis(20),
            ..Default::default()
        },
        ..cfg(1)
    });
    let (n1, n2) = (8usize, 8usize);
    let mut rng = Rng::new(901);
    let serial = Dct2::with_policy(n1, n2, ExecPolicy::Serial);
    let reqs: Vec<_> = (0..16)
        .map(|_| (TransformOp::Dct2d, vec![n1, n2], rng.normal_vec(n1 * n2)))
        .collect();
    let wants: Vec<Vec<f64>> = reqs
        .iter()
        .map(|(_, _, x)| {
            let mut w = vec![0.0; n1 * n2];
            serial.forward(x, &mut w);
            w
        })
        .collect();

    // every request must conclude successfully on the degraded plan —
    // whether its batch was packed (pack/execute_batch seams), flushed
    // solo (execute seam), or arrived after the quarantine kicked in
    let out = s.transform_many(reqs).unwrap();
    for (r, w) in out.iter().zip(&wants) {
        assert_eq!(r.backend, "native-degraded");
        assert_eq!(&r.output, w, "degraded answers are bit-equal to the serial plan");
    }
    let snap = s.snapshot();
    assert!(counter(&snap, "dct2d", "retried_degraded") >= 1.0);
    assert_eq!(counter(&snap, "dct2d", "requests"), 16.0);
    assert_eq!(snap.get("_plan_cache").unwrap().get("quarantined").unwrap().as_f64(), Some(1.0));

    // the fault is scoped to dct2d: idct2d executes its primary plan
    let x = rng.normal_vec(n1 * n2);
    let r = s.transform(TransformOp::Idct2d, vec![n1, n2], x).unwrap();
    assert_eq!(r.backend, "native");
    fault::clear();
}

#[test]
fn panic_on_sharded_route_degrades_to_single_band_serial() {
    let _g = guard();
    set_faults(parse_spec("panic:idct2d").unwrap());
    // a shard-gate-sized request on a force-sharding policy: the primary
    // plan is banded; the degraded plan is the single-band serial one
    let s = Service::start_native(ServiceConfig {
        shard: ShardPolicy::MaxShards(3),
        ..cfg(2)
    });
    let (n1, n2) = (256usize, 260usize);
    let mut rng = Rng::new(902);
    let x = rng.normal_vec(n1 * n2);
    let mut want = vec![0.0; n1 * n2];
    Idct2::with_policy(n1, n2, ExecPolicy::Serial).forward(&x, &mut want);

    let r = s.transform(TransformOp::Idct2d, vec![n1, n2], x).unwrap();
    assert_eq!(r.backend, "native-degraded");
    assert_eq!(r.output, want, "sharded failure must fall back to the serial plan, bit-equal");
    let snap = s.snapshot();
    assert_eq!(counter(&snap, "idct2d", "retried_degraded"), 1.0);
    fault::clear();
}

#[test]
fn delay_fault_expires_queued_deadlines_instead_of_executing_them() {
    let _g = guard();
    set_faults(parse_spec("delay:execute:150ms").unwrap());
    let s = Service::start_native(ServiceConfig {
        batch: BatchPolicy { max_wait: Duration::from_millis(1), ..Default::default() },
        ..cfg(1)
    });
    // request 1 (no deadline) occupies the single worker for >= 150ms
    let slow = s.submit(TransformOp::Dct2d, vec![8, 8], vec![1.0; 64]).unwrap();
    std::thread::sleep(Duration::from_millis(30)); // let the worker start sleeping
    // request 2's deadline passes while it waits behind the delay; the
    // worker must expire it at dequeue, not execute it
    let doomed = s
        .submit_with_deadline(
            TransformOp::Dct2d,
            vec![6, 6],
            vec![1.0; 36],
            Some(Instant::now() + Duration::from_millis(50)),
        )
        .unwrap();
    assert!(matches!(doomed.wait(), Err(TransformError::DeadlineExceeded)));
    assert!(slow.wait().is_ok(), "the delayed request itself still completes");
    let snap = s.snapshot();
    assert_eq!(counter(&snap, "dct2d", "expired_requests"), 1.0);
    assert_eq!(s.inflight.in_use(), 0, "expired requests release their budget");
    fault::clear();
}

#[test]
fn saturated_budget_sheds_overloaded_while_a_delayed_request_holds_it() {
    let _g = guard();
    set_faults(parse_spec("delay:execute:50ms").unwrap());
    let s = Service::start_native(ServiceConfig {
        max_inflight_elems: 64, // exactly one 8x8 payload
        ..cfg(1)
    });
    // request 1 takes the whole budget and holds it for >= 50ms
    let h = s.submit(TransformOp::Dct2d, vec![8, 8], vec![1.0; 64]).unwrap();
    // request 2 arrives while the budget is held: shed, immediately
    let err = s.submit(TransformOp::Dct2d, vec![8, 8], vec![2.0; 64]).unwrap_err();
    match err {
        TransformError::Overloaded { retry_after } => {
            assert!(retry_after > Duration::ZERO, "Overloaded must carry a backoff hint")
        }
        other => panic!("expected Overloaded, got {other}"),
    }
    assert!(err.is_retryable());
    assert!(h.wait().is_ok());
    // the reply released the budget: the next arrival is admitted
    assert!(s.transform(TransformOp::Dct2d, vec![8, 8], vec![3.0; 64]).is_ok());
    let snap = s.snapshot();
    assert_eq!(counter(&snap, "dct2d", "shed_requests"), 1.0);
    assert_eq!(
        snap.get("_admission").unwrap().get("max_inflight_elems").unwrap().as_f64(),
        Some(64.0)
    );
    fault::clear();
}

#[test]
fn env_spec_grammar_drives_real_traffic() {
    // CI runs this binary once with MDDCT_FAULT=delay:execute:2ms set;
    // without the env knob there is nothing env-specific to check
    let Ok(spec) = std::env::var("MDDCT_FAULT") else { return };
    let _g = guard();
    let parsed = parse_spec(&spec).expect("CI must set a well-formed MDDCT_FAULT");
    set_faults(parsed);
    let s = Service::start_native(cfg(2));
    let mut rng = Rng::new(903);
    let x = rng.normal_vec(10 * 10);
    // a delay-only spec perturbs timing, never correctness
    let r = s.transform(TransformOp::Dct2d, vec![10, 10], x).unwrap();
    assert!(r.output.iter().all(|v| v.is_finite()));
    fault::clear();
}
