//! Service-level integration: the coordinator under concurrency, mixed
//! ops, and (when artifacts exist) the PJRT routing path.

use std::sync::Arc;

use mddct::coordinator::{
    BatchPolicy, Router, Service, ServiceConfig, TransformOp,
};
use mddct::dct::direct::dct2d_direct;
use mddct::runtime::{Manifest, PjrtHandle, DEFAULT_ARTIFACT_DIR};
use mddct::util::rng::Rng;

fn assert_close(got: &[f64], want: &[f64], tol: f64) {
    let scale = want.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    for (g, w) in got.iter().zip(want) {
        assert!((g - w).abs() <= tol * scale, "{g} vs {w}");
    }
}

#[test]
fn concurrent_clients_all_served_correctly() {
    let svc = Arc::new(Service::start_native(ServiceConfig {
        workers: 4,
        batch: BatchPolicy::default(),
        ..Default::default()
    }));
    let mut joins = Vec::new();
    for c in 0..8u64 {
        let svc = svc.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(600 + c);
            for _ in 0..16 {
                let n = [8, 12, 16][rng.below(3)];
                let x = rng.normal_vec(n * n);
                let r = svc
                    .transform(TransformOp::Dct2d, vec![n, n], x.clone())
                    .expect("transform");
                assert_close(&r.output, &dct2d_direct(&x, n, n), 1e-9);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    assert_eq!(svc.metrics.total_requests(), 8 * 16);
}

#[test]
fn metrics_snapshot_has_op_rows() {
    let svc = Service::start_native(ServiceConfig {
        workers: 2,
        batch: BatchPolicy::default(),
        ..Default::default()
    });
    let mut rng = Rng::new(601);
    for _ in 0..4 {
        svc.transform(TransformOp::Idct2d, vec![8, 8], rng.normal_vec(64)).unwrap();
    }
    let snap = svc.metrics.snapshot();
    let row = snap.get("idct2d").expect("idct2d metrics row");
    assert_eq!(row.get("requests").unwrap().as_f64().unwrap(), 4.0);
    assert!(row.get("mean_latency_s").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn pjrt_routing_matches_native_results() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let manifest = Manifest::load(DEFAULT_ARTIFACT_DIR).unwrap();
    let handle = PjrtHandle::spawn(DEFAULT_ARTIFACT_DIR);
    let svc = Service::start(
        ServiceConfig { workers: 2, batch: BatchPolicy::default(), ..Default::default() },
        Router::with_pjrt(handle, &manifest),
    );
    let mut rng = Rng::new(602);
    // 128x128 has an artifact -> pjrt; 96x96 doesn't -> native
    let x = rng.normal_vec(128 * 128);
    let r = svc.transform(TransformOp::Dct2d, vec![128, 128], x.clone()).unwrap();
    assert_eq!(r.backend, "pjrt");
    assert_close(&r.output, &dct2d_direct(&x, 128, 128), 2e-4);
    let y = rng.normal_vec(96 * 96);
    let r2 = svc.transform(TransformOp::Dct2d, vec![96, 96], y.clone()).unwrap();
    assert_eq!(r2.backend, "native");
    assert_close(&r2.output, &dct2d_direct(&y, 96, 96), 1e-9);
}

#[test]
fn batch_of_identical_shapes_is_cobatched() {
    let svc = Service::start_native(ServiceConfig {
        workers: 1,
        batch: BatchPolicy {
            max_batch: 32,
            max_wait: std::time::Duration::from_millis(20),
            ..Default::default()
        },
        ..Default::default()
    });
    let mut rng = Rng::new(603);
    let reqs: Vec<_> = (0..24)
        .map(|_| (TransformOp::Dct2d, vec![16usize, 16], rng.normal_vec(256)))
        .collect();
    let out = svc.transform_many(reqs).unwrap();
    let max_batch = out.iter().map(|r| r.batch_size).max().unwrap();
    assert!(max_batch > 1, "expected co-batching, max batch {max_batch}");
}

#[test]
fn same_shape_requests_pack_into_one_batched_execution() {
    // one worker + a generous co-batching window: the batcher coalesces
    // the same-(op, shape) burst into one batch, and the worker must
    // execute it through the packed stage-fused path (metrics prove it)
    // with every answer still exact
    let svc = Service::start_native(ServiceConfig {
        workers: 1,
        batch: BatchPolicy {
            max_batch: 32,
            max_wait: std::time::Duration::from_millis(20),
            ..Default::default()
        },
        ..Default::default()
    });
    let mut rng = Rng::new(610);
    let mut reqs = Vec::new();
    let mut wants = Vec::new();
    for _ in 0..16 {
        let x = rng.normal_vec(8 * 8);
        wants.push(dct2d_direct(&x, 8, 8));
        reqs.push((TransformOp::Dct2d, vec![8usize, 8], x));
    }
    let out = svc.transform_many(reqs).unwrap();
    for (r, w) in out.iter().zip(&wants) {
        assert_close(&r.output, w, 1e-9);
    }
    let snap = svc.metrics.snapshot();
    let d = snap.get("dct2d").expect("dct2d metrics row");
    let packed_batches = d.get("packed_batches").unwrap().as_f64().unwrap();
    let packed_requests = d.get("packed_requests").unwrap().as_f64().unwrap();
    let max_packed = d.get("max_packed_batch").unwrap().as_f64().unwrap();
    assert!(packed_batches >= 1.0, "no packed batch executed");
    assert!(max_packed >= 2.0, "packed batches never exceeded one request");
    assert!(packed_requests >= 2.0, "fewer than two requests went through the packed path");
    assert_eq!(d.get("requests").unwrap().as_f64().unwrap(), 16.0);
    assert!(d.get("packed_batch_hist").is_some(), "histogram missing");

    // a lone request of a new shape cannot pack: it runs solo and the
    // packed counters stay put
    let x = rng.normal_vec(4 * 4);
    svc.transform(TransformOp::Dct2d, vec![4, 4], x).unwrap();
    let snap2 = svc.metrics.snapshot();
    let d2 = snap2.get("dct2d").unwrap();
    assert_eq!(
        d2.get("packed_batches").unwrap().as_f64().unwrap(),
        packed_batches,
        "a solo request must not count as packed"
    );
}

#[test]
fn views_capable_ops_pack_without_an_input_copy() {
    // dct2d/idct2d batches take the zero-copy views path (payloads
    // borrowed in place, no contiguous input pack); a same-size dst2d
    // burst through the same service still uses the copy path — the
    // packed_zero_copy counter tells the two apart
    let svc = Service::start_native(ServiceConfig {
        workers: 1,
        batch: BatchPolicy {
            max_batch: 32,
            max_wait: std::time::Duration::from_millis(20),
            ..Default::default()
        },
        ..Default::default()
    });
    let mut rng = Rng::new(611);
    for op in [TransformOp::Dct2d, TransformOp::Idct2d, TransformOp::Dst2d] {
        let mut reqs = Vec::new();
        for _ in 0..12 {
            reqs.push((op, vec![8usize, 8], rng.normal_vec(64)));
        }
        let out = svc.transform_many(reqs).unwrap();
        assert!(out.iter().any(|r| r.batch_size > 1), "{op:?}: never co-batched");
    }
    let snap = svc.metrics.snapshot();
    for op in ["dct2d", "idct2d"] {
        let row = snap.get(op).expect("metrics row");
        let batches = row.get("packed_batches").unwrap().as_f64().unwrap();
        let zero_copy = row.get("packed_zero_copy").unwrap().as_f64().unwrap();
        assert!(batches >= 1.0, "{op}: no packed batch executed");
        assert!(zero_copy >= 1.0, "{op}: packed batches never went zero-copy");
        assert!(zero_copy <= batches, "{op}: zero-copy count exceeds batch count");
    }
    let dst = snap.get("dst2d").expect("dst2d metrics row");
    assert!(dst.get("packed_batches").unwrap().as_f64().unwrap() >= 1.0);
    assert_eq!(
        dst.get("packed_zero_copy").unwrap().as_f64().unwrap(),
        0.0,
        "dst2d has no views path and must stay on the copy pack"
    );
    // correctness didn't regress on the zero-copy path
    let x = rng.normal_vec(64);
    let r = svc.transform(TransformOp::Dct2d, vec![8, 8], x.clone()).unwrap();
    assert_close(&r.output, &dct2d_direct(&x, 8, 8), 1e-9);
}

#[test]
fn sharded_3d_request_executes_as_slabs_through_the_service() {
    use mddct::dct::Dct3d;
    use mddct::parallel::{ExecPolicy, ShardPolicy};
    // a 3D DCT-II at the shard gate must execute as N > 1 slab bands
    // through the service (metrics prove it) and match ExecPolicy::Serial
    // to <= 1e-10 — the ISSUE's 3D acceptance criterion
    let svc = Service::start_native(ServiceConfig {
        workers: 2,
        batch: BatchPolicy::default(),
        exec: ExecPolicy::Serial,
        shard: ShardPolicy::MaxShards(4),
        ..Default::default()
    });
    let (n1, n2, n3) = (64usize, 64usize, 64usize); // numel == SHARD_MIN_NUMEL_3D
    let mut rng = Rng::new(605);
    let x = rng.normal_vec(n1 * n2 * n3);
    let r = svc.transform(TransformOp::Dct3d, vec![n1, n2, n3], x.clone()).unwrap();
    assert_eq!(r.backend, "native");
    let mut want = vec![0.0; x.len()];
    Dct3d::with_policy(n1, n2, n3, ExecPolicy::Serial).forward(&x, &mut want);
    assert_close(&r.output, &want, 1e-10);
    // a small 3D request through the same service stays unsharded
    let small = rng.normal_vec(8 * 8 * 8);
    svc.transform(TransformOp::Dct3d, vec![8, 8, 8], small).unwrap();
    let snap = svc.metrics.snapshot();
    let d = snap.get("dct3d").expect("dct3d metrics row");
    assert_eq!(d.get("sharded_requests").unwrap().as_f64().unwrap(), 1.0);
    assert_eq!(d.get("max_bands").unwrap().as_f64().unwrap(), 4.0);
    // the per-dimensionality breakdown attributes the fan-out to 3D
    let by_rank = snap.get("_sharding_by_rank").expect("rank breakdown");
    let d3 = by_rank.get("3d").expect("3d bucket");
    assert_eq!(d3.get("requests").unwrap().as_f64().unwrap(), 2.0);
    assert_eq!(d3.get("sharded_requests").unwrap().as_f64().unwrap(), 1.0);
    assert_eq!(d3.get("max_bands").unwrap().as_f64().unwrap(), 4.0);
    assert!(by_rank.get("2d").is_none(), "no 2D traffic was sent");
}

#[test]
fn sharded_service_matches_unsharded_service() {
    use mddct::parallel::{ExecPolicy, ShardPolicy};
    // same traffic through a single-band service and a band-sharded one:
    // responses must agree to <= 1e-10 (the sharding correctness contract)
    let serial = Service::start_native(ServiceConfig {
        workers: 1,
        batch: BatchPolicy::default(),
        exec: ExecPolicy::Serial,
        shard: ShardPolicy::MaxShards(1),
        ..Default::default()
    });
    let sharded = Service::start_native(ServiceConfig {
        workers: 2,
        batch: BatchPolicy::default(),
        exec: ExecPolicy::Serial,
        shard: ShardPolicy::MaxShards(5),
        ..Default::default()
    });
    let mut rng = Rng::new(604);
    for op in [TransformOp::Dct2d, TransformOp::Idct2d, TransformOp::IdctIdxst] {
        let (n1, n2) = (257usize, 256usize); // above threshold, prime leading dim
        let x = rng.normal_vec(n1 * n2);
        let a = serial.transform(op, vec![n1, n2], x.clone()).unwrap();
        let b = sharded.transform(op, vec![n1, n2], x).unwrap();
        assert_close(&b.output, &a.output, 1e-10);
    }
}
