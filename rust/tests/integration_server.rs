//! End-to-end loopback integration for the TCP front-end (the ISSUE's
//! "mddct serve" acceptance path): concurrent mixed-shape clients over
//! real sockets must get results *bit-identical* to direct in-process
//! [`Service`] calls, and the PR-7 lifecycle must surface over the wire
//! as typed error frames — a queued request whose deadline lapses comes
//! back `deadline_exceeded`, a request the admission budget cannot
//! admit comes back `overloaded` with a retry hint. The metrics route
//! returns one merged document whose `_server` section counts the very
//! frames this test sent.
//!
//! The lifecycle tests hold the single worker busy with the PR-7 fault
//! layer (`delay:execute`), which is process-global — those tests
//! serialize on one mutex and clear the spec on exit, exactly like
//! `tests/fault_injection.rs`.

use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use mddct::coordinator::{BatchPolicy, Service, ServiceConfig, TransformError, TransformOp};
use mddct::parallel::{ExecPolicy, ShardPolicy};
use mddct::server::proto::{self, WireReply, WireRequest};
use mddct::server::{Server, ServerConfig};
use mddct::util::json::Json;
use mddct::util::rng::Rng;

fn cfg(workers: usize) -> ServiceConfig {
    ServiceConfig {
        workers,
        batch: BatchPolicy::default(),
        exec: ExecPolicy::Serial,
        shard: ShardPolicy::Auto,
        trace: false,
        default_deadline: None,
        max_inflight_elems: usize::MAX,
    }
}

fn serve(config: ServiceConfig) -> (Server, Arc<Service>) {
    let svc = Arc::new(Service::start_native(config));
    let server = Server::start(ServerConfig::ephemeral(), svc.clone()).expect("bind ephemeral");
    (server, svc)
}

/// One blocking request/reply exchange on an open connection.
fn exchange(stream: &mut TcpStream, body: &str) -> WireReply {
    proto::write_frame(stream, body.as_bytes()).expect("write frame");
    let reply = proto::read_frame(stream, proto::DEFAULT_MAX_FRAME_BYTES)
        .expect("read frame")
        .expect("reply before EOF");
    proto::decode_reply(&reply).expect("decode reply")
}

/// The ISSUE's mixed-shape request stream: pow2 and Bluestein 2D, a
/// fused combo, 1D, and a 3D volume.
fn request_mix() -> Vec<(TransformOp, Vec<usize>)> {
    vec![
        (TransformOp::Dct2d, vec![8, 8]),
        (TransformOp::Idct2d, vec![9, 15]),
        (TransformOp::IdctIdxst, vec![8, 12]),
        (TransformOp::Dct1d(mddct::dct::Algo1d::NPoint), vec![16]),
        (TransformOp::Dct3d, vec![4, 4, 4]),
    ]
}

#[test]
fn concurrent_mixed_shape_clients_are_bit_equal_to_direct_calls() {
    let (server, svc) = serve(cfg(2));
    let addr = server.addr();
    let clients: Vec<_> = (0..4u64)
        .map(|c| {
            let svc = svc.clone();
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).expect("connect");
                let mut rng = Rng::new(0xC0FFEE + c);
                for (round, (op, shape)) in request_mix().into_iter().enumerate() {
                    let numel: usize = shape.iter().product();
                    let data = rng.normal_vec(numel);
                    // the in-process oracle: same service, same plans
                    let want =
                        svc.transform(op, shape.clone(), data.clone()).expect("direct call");
                    let req = WireRequest {
                        id: c * 100 + round as u64,
                        op,
                        shape: shape.clone(),
                        batch: 1,
                        deadline_ms: None,
                        tenant: None,
                        priority: 0,
                        data,
                    };
                    match exchange(&mut stream, &proto::encode_request(&req)) {
                        WireReply::Ok { id, data, .. } => {
                            assert_eq!(id, req.id, "client {c} round {round}: id echo");
                            assert_eq!(
                                data.len(),
                                want.output.len(),
                                "client {c} round {round}: length"
                            );
                            for (i, (g, w)) in data.iter().zip(&want.output).enumerate() {
                                assert_eq!(
                                    g.to_bits(),
                                    w.to_bits(),
                                    "client {c} {op:?} {shape:?} elem {i}: wire vs direct"
                                );
                            }
                        }
                        other => panic!("client {c} round {round}: wanted ok, got {other:?}"),
                    }
                }
            })
        })
        .collect();
    for c in clients {
        c.join().expect("client thread");
    }
    // 4 clients x 5 requests, one reply frame each
    let stats = server.stats();
    assert_eq!(stats.frames_in.load(std::sync::atomic::Ordering::Relaxed), 20);
    assert_eq!(stats.frames_out.load(std::sync::atomic::Ordering::Relaxed), 20);
}

#[test]
fn wire_batch_equals_per_block_direct_calls() {
    let (server, svc) = serve(cfg(2));
    let mut stream = TcpStream::connect(server.addr()).expect("connect");
    let (n1, n2, batch) = (9usize, 7usize, 3usize);
    let mut rng = Rng::new(31);
    let data = rng.normal_vec(n1 * n2 * batch);
    let mut want: Vec<f64> = Vec::with_capacity(data.len());
    for b in 0..batch {
        let block = data[b * n1 * n2..(b + 1) * n1 * n2].to_vec();
        want.extend_from_slice(
            &svc.transform(TransformOp::Idct2d, vec![n1, n2], block).expect("direct").output,
        );
    }
    let req = WireRequest {
        id: 5,
        op: TransformOp::Idct2d,
        shape: vec![n1, n2],
        batch,
        deadline_ms: None,
        tenant: None,
        priority: 0,
        data,
    };
    match exchange(&mut stream, &proto::encode_request(&req)) {
        WireReply::Ok { data, .. } => {
            assert_eq!(data.len(), want.len());
            for (g, w) in data.iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits(), "batched wire vs per-block direct");
            }
        }
        other => panic!("wanted ok reply, got {other:?}"),
    }
}

#[test]
fn metrics_route_reports_the_traffic_this_connection_sent() {
    let (server, svc) = serve(cfg(1));
    let mut stream = TcpStream::connect(server.addr()).expect("connect");
    let mut rng = Rng::new(7);
    let req = WireRequest {
        id: 1,
        op: TransformOp::Dct2d,
        shape: vec![8, 8],
        batch: 1,
        deadline_ms: None,
        tenant: None,
        priority: 0,
        data: rng.normal_vec(64),
    };
    match exchange(&mut stream, &proto::encode_request(&req)) {
        WireReply::Ok { .. } => {}
        other => panic!("wanted ok reply, got {other:?}"),
    }
    match exchange(&mut stream, &proto::encode_metrics_request()) {
        WireReply::Metrics(snap) => {
            let srv = snap.get("_server").expect("_server section");
            // the transform frame above, counted by the time the
            // metrics frame is answered
            assert_eq!(srv.get("frames_in").and_then(Json::as_f64), Some(2.0));
            assert_eq!(srv.get("accepted_conns").and_then(Json::as_f64), Some(1.0));
            assert!(
                snap.get("dct2d").and_then(|d| d.get("requests")).and_then(Json::as_f64)
                    >= Some(1.0),
                "coordinator per-op rows ride in the same document"
            );
            assert!(snap.get("_admission").is_some());
        }
        other => panic!("wanted metrics reply, got {other:?}"),
    }
    drop(svc);
}

/// Lifecycle tests below install process-global fault specs; serialize
/// them (same idiom as `tests/fault_injection.rs`).
#[cfg(not(feature = "fault-off"))]
mod lifecycle {
    use super::*;
    use mddct::coordinator::{fault, parse_spec, set_faults};
    use std::sync::{Mutex, MutexGuard};

    fn guard() -> MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn req_8x8(id: u64, deadline_ms: Option<u64>, fill: f64) -> String {
        proto::encode_request(&WireRequest {
            id,
            op: TransformOp::Dct2d,
            shape: vec![8, 8],
            batch: 1,
            deadline_ms,
            tenant: None,
            priority: 0,
            data: vec![fill; 64],
        })
    }

    #[test]
    fn queued_past_deadline_requests_come_back_as_deadline_exceeded_frames() {
        let _g = guard();
        set_faults(parse_spec("delay:execute:80ms").unwrap());
        let (server, _svc) = serve(cfg(1));
        // conn A occupies the single worker for >= 80ms
        let mut a = TcpStream::connect(server.addr()).expect("connect A");
        proto::write_frame(&mut a, req_8x8(1, None, 1.0).as_bytes()).expect("send A");
        std::thread::sleep(Duration::from_millis(15));
        // conn B's request waits behind A, so its 10ms deadline lapses
        // in the queue and the dequeue-side admit gate answers it
        let mut b = TcpStream::connect(server.addr()).expect("connect B");
        match exchange(&mut b, &req_8x8(2, Some(10), 2.0)) {
            WireReply::Err { id, error: TransformError::DeadlineExceeded } => assert_eq!(id, 2),
            other => panic!("wanted deadline_exceeded frame, got {other:?}"),
        }
        // conn A's request was never expired — it completes normally
        let reply = proto::read_frame(&mut a, proto::DEFAULT_MAX_FRAME_BYTES)
            .expect("read A")
            .expect("A reply");
        match proto::decode_reply(&reply).expect("decode A") {
            WireReply::Ok { id, .. } => assert_eq!(id, 1),
            other => panic!("wanted ok frame for A, got {other:?}"),
        }
        fault::clear();
    }

    #[test]
    fn shed_requests_come_back_as_overloaded_frames_with_a_retry_hint() {
        let _g = guard();
        set_faults(parse_spec("delay:execute:80ms").unwrap());
        let (server, _svc) = serve(ServiceConfig {
            max_inflight_elems: 64, // exactly one 8x8 payload
            ..cfg(1)
        });
        // conn A takes the whole budget and holds it inside the delay
        let mut a = TcpStream::connect(server.addr()).expect("connect A");
        proto::write_frame(&mut a, req_8x8(1, None, 1.0).as_bytes()).expect("send A");
        std::thread::sleep(Duration::from_millis(15));
        // conn B arrives while the budget is held: shed at submit,
        // surfaced as a typed overloaded frame carrying the backoff hint
        let mut b = TcpStream::connect(server.addr()).expect("connect B");
        match exchange(&mut b, &req_8x8(2, None, 2.0)) {
            WireReply::Err { id, error: TransformError::Overloaded { retry_after } } => {
                assert_eq!(id, 2);
                assert!(retry_after > Duration::ZERO, "overloaded frame carries retry_after_ms");
            }
            other => panic!("wanted overloaded frame, got {other:?}"),
        }
        let reply = proto::read_frame(&mut a, proto::DEFAULT_MAX_FRAME_BYTES)
            .expect("read A")
            .expect("A reply");
        match proto::decode_reply(&reply).expect("decode A") {
            WireReply::Ok { id, .. } => assert_eq!(id, 1),
            other => panic!("wanted ok frame for A, got {other:?}"),
        }
        fault::clear();
    }
}
