//! Parallel-vs-serial equivalence properties for the `parallel`
//! execution layer: every transform must produce the same answer no
//! matter how many lanes it fans out over, across the radix-2 and
//! Bluestein FFT paths, and `Threads(1)` must be *bit-identical* to
//! `Serial` (they take the same code path by construction).
//!
//! The same contract extends to band-sharded execution: any shard
//! count must match `ExecPolicy::Serial` to <= 1e-10, across
//! non-divisible band splits and prime (Bluestein) dimensions — in 2D
//! (row bands) and in 3D (dim-0 slab bands).

use mddct::dct::{Combo, Dct2, Dct3d, Idct2, Idct3d, IdxstCombo, RowColumn};
use mddct::fft::{C64, Rfft2Plan, Rfft3Plan};
use mddct::parallel::{default_threads, ExecPolicy, ShardPolicy};
use mddct::util::rng::Rng;

/// Shapes covering every interesting FFT dispatch: odd sizes, primes
/// (Bluestein on one or both axes), powers of two (radix-2 fast paths),
/// mixed, and degenerate single-row/column cases.
const SHAPES: &[(usize, usize)] = &[
    (9, 15),   // odd x odd
    (7, 13),   // prime x prime (Bluestein both axes)
    (17, 31),  // larger primes
    (16, 16),  // power of two
    (64, 32),  // power of two, rectangular
    (12, 10),  // even composites (half-size RFFT packing)
    (1, 24),   // single row
    (24, 1),   // single column
    (5, 64),   // Bluestein rows x radix-2 columns
];

fn close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol,
            "{what} at {i}: {x} vs {y} (diff {})",
            (x - y).abs()
        );
    }
}

#[test]
fn dct2_parallel_matches_serial() {
    let mut rng = Rng::new(700);
    for &(n1, n2) in SHAPES {
        let x = rng.normal_vec(n1 * n2);
        let mut serial = vec![0.0; n1 * n2];
        Dct2::with_policy(n1, n2, ExecPolicy::Serial).forward(&x, &mut serial);
        for lanes in [2usize, 4, 7] {
            let mut par = vec![0.0; n1 * n2];
            Dct2::with_policy(n1, n2, ExecPolicy::Threads(lanes)).forward(&x, &mut par);
            close(&par, &serial, 1e-10, &format!("dct2 ({n1},{n2}) lanes={lanes}"));
        }
    }
}

#[test]
fn idct2_parallel_matches_serial() {
    let mut rng = Rng::new(701);
    for &(n1, n2) in SHAPES {
        let x = rng.normal_vec(n1 * n2);
        let mut serial = vec![0.0; n1 * n2];
        Idct2::with_policy(n1, n2, ExecPolicy::Serial).forward(&x, &mut serial);
        for lanes in [2usize, 4, 7] {
            let mut par = vec![0.0; n1 * n2];
            Idct2::with_policy(n1, n2, ExecPolicy::Threads(lanes)).forward(&x, &mut par);
            close(&par, &serial, 1e-10, &format!("idct2 ({n1},{n2}) lanes={lanes}"));
        }
    }
}

#[test]
fn row_column_parallel_matches_serial() {
    let mut rng = Rng::new(702);
    for &(n1, n2) in SHAPES {
        let x = rng.normal_vec(n1 * n2);
        let mut serial = vec![0.0; n1 * n2];
        RowColumn::dct2(n1, n2)
            .with_policy(ExecPolicy::Serial)
            .forward(&x, &mut serial);
        for lanes in [2usize, 4] {
            let mut par = vec![0.0; n1 * n2];
            RowColumn::dct2(n1, n2)
                .with_policy(ExecPolicy::Threads(lanes))
                .forward(&x, &mut par);
            close(&par, &serial, 1e-10, &format!("rc ({n1},{n2}) lanes={lanes}"));
        }
        // inverse flavour too
        let mut iserial = vec![0.0; n1 * n2];
        RowColumn::idct2(n1, n2)
            .with_policy(ExecPolicy::Serial)
            .forward(&x, &mut iserial);
        let mut ipar = vec![0.0; n1 * n2];
        RowColumn::idct2(n1, n2)
            .with_policy(ExecPolicy::Threads(4))
            .forward(&x, &mut ipar);
        close(&ipar, &iserial, 1e-10, &format!("rc idct ({n1},{n2})"));
    }
}

#[test]
fn dct3d_parallel_matches_serial() {
    let mut rng = Rng::new(703);
    for &(n1, n2, n3) in &[(4usize, 6usize, 8usize), (3, 5, 7), (8, 8, 8), (1, 9, 4)] {
        let x = rng.normal_vec(n1 * n2 * n3);
        let mut serial = vec![0.0; x.len()];
        Dct3d::with_policy(n1, n2, n3, ExecPolicy::Serial).forward(&x, &mut serial);
        let mut par = vec![0.0; x.len()];
        Dct3d::with_policy(n1, n2, n3, ExecPolicy::Threads(4)).forward(&x, &mut par);
        close(&par, &serial, 1e-10, &format!("dct3d ({n1},{n2},{n3})"));
    }
}

#[test]
fn threads_one_is_bit_identical_to_serial() {
    let mut rng = Rng::new(704);
    for &(n1, n2) in SHAPES {
        let x = rng.normal_vec(n1 * n2);
        let mut a = vec![0.0; n1 * n2];
        let mut b = vec![0.0; n1 * n2];
        Dct2::with_policy(n1, n2, ExecPolicy::Serial).forward(&x, &mut a);
        Dct2::with_policy(n1, n2, ExecPolicy::Threads(1)).forward(&x, &mut b);
        assert_eq!(a, b, "dct2 threads(1) != serial at ({n1},{n2})");
        Idct2::with_policy(n1, n2, ExecPolicy::Serial).forward(&x, &mut a);
        Idct2::with_policy(n1, n2, ExecPolicy::Threads(1)).forward(&x, &mut b);
        assert_eq!(a, b, "idct2 threads(1) != serial at ({n1},{n2})");
        RowColumn::dct2(n1, n2).with_policy(ExecPolicy::Serial).forward(&x, &mut a);
        RowColumn::dct2(n1, n2).with_policy(ExecPolicy::Threads(1)).forward(&x, &mut b);
        assert_eq!(a, b, "rc threads(1) != serial at ({n1},{n2})");
    }
}

#[test]
fn auto_policy_is_consistent_with_serial_above_threshold() {
    // 128x128 is past AUTO_MIN_WORK, so Auto may fan out; results must
    // still agree with the serial reference.
    let (n1, n2) = (128usize, 128usize);
    let mut rng = Rng::new(705);
    let x = rng.normal_vec(n1 * n2);
    let mut serial = vec![0.0; n1 * n2];
    Dct2::with_policy(n1, n2, ExecPolicy::Serial).forward(&x, &mut serial);
    let mut auto = vec![0.0; n1 * n2];
    Dct2::with_policy(n1, n2, ExecPolicy::Auto).forward(&x, &mut auto);
    close(&auto, &serial, 1e-10, "auto vs serial 128x128");
    assert!(default_threads() >= 1);
}

/// Shard counts the ISSUE contract calls out: 1 (degenerate), small
/// even/odd, and 7 (never divides the power-of-two shapes evenly).
const SHARD_COUNTS: [usize; 4] = [1, 2, 3, 7];

/// Shapes stressing the band math: rows not divisible by any shard
/// count, prime (Bluestein) dimensions on either axis, and a
/// power-of-two reference.
const SHARD_SHAPES: &[(usize, usize)] = &[
    (9, 15),   // odd x odd, rows < some shard counts
    (13, 7),   // prime x prime (Bluestein both axes)
    (33, 17),  // non-divisible by 2, 3, and 7
    (16, 16),  // power of two
    (31, 8),   // prime rows x radix-2 columns
    (64, 12),  // divisible rows, even composite columns
];

#[test]
fn dct2_sharded_matches_serial_for_all_shard_counts() {
    let mut rng = Rng::new(710);
    for &(n1, n2) in SHARD_SHAPES {
        let x = rng.normal_vec(n1 * n2);
        let mut serial = vec![0.0; n1 * n2];
        Dct2::with_policy(n1, n2, ExecPolicy::Serial).forward(&x, &mut serial);
        for shards in SHARD_COUNTS {
            let mut sharded = vec![0.0; n1 * n2];
            Dct2::with_policy(n1, n2, ExecPolicy::Serial)
                .with_shards(ShardPolicy::MaxShards(shards))
                .forward(&x, &mut sharded);
            close(
                &sharded,
                &serial,
                1e-10,
                &format!("dct2 ({n1},{n2}) shards={shards}"),
            );
        }
    }
}

#[test]
fn idct2_sharded_matches_serial_for_all_shard_counts() {
    let mut rng = Rng::new(711);
    for &(n1, n2) in SHARD_SHAPES {
        let x = rng.normal_vec(n1 * n2);
        let mut serial = vec![0.0; n1 * n2];
        Idct2::with_policy(n1, n2, ExecPolicy::Serial).forward(&x, &mut serial);
        for shards in SHARD_COUNTS {
            let mut sharded = vec![0.0; n1 * n2];
            Idct2::with_policy(n1, n2, ExecPolicy::Serial)
                .with_shards(ShardPolicy::MaxShards(shards))
                .forward(&x, &mut sharded);
            close(
                &sharded,
                &serial,
                1e-10,
                &format!("idct2 ({n1},{n2}) shards={shards}"),
            );
        }
    }
}

#[test]
fn rfft2_sharded_matches_serial_for_all_shard_counts() {
    let mut rng = Rng::new(712);
    for &(n1, n2) in SHARD_SHAPES {
        let x = rng.normal_vec(n1 * n2);
        let serial_plan = Rfft2Plan::with_policy(n1, n2, ExecPolicy::Serial);
        let h2 = serial_plan.h2;
        let mut serial = vec![C64::default(); n1 * h2];
        serial_plan.forward(&x, &mut serial);
        for shards in SHARD_COUNTS {
            let plan = Rfft2Plan::with_policy(n1, n2, ExecPolicy::Serial)
                .with_shards(ShardPolicy::MaxShards(shards));
            let mut sharded = vec![C64::default(); n1 * h2];
            plan.forward(&x, &mut sharded);
            for (i, (a, b)) in serial.iter().zip(&sharded).enumerate() {
                assert!(
                    (*a - *b).abs() <= 1e-10,
                    "rfft2 ({n1},{n2}) shards={shards} at {i}"
                );
            }
            // inverse too: spectrum back to the original samples
            let mut back = vec![0.0; n1 * n2];
            plan.inverse(&sharded, &mut back);
            close(&back, &x, 1e-9, &format!("irfft2 ({n1},{n2}) shards={shards}"));
        }
    }
}

#[test]
fn idxst_combo_sharded_matches_serial() {
    let mut rng = Rng::new(713);
    for &(n1, n2) in &[(9usize, 15usize), (33, 17), (16, 16)] {
        let x = rng.normal_vec(n1 * n2);
        for combo in [Combo::IdctIdxst, Combo::IdxstIdct] {
            let mut serial = vec![0.0; n1 * n2];
            IdxstCombo::with_policy(n1, n2, combo, ExecPolicy::Serial)
                .forward(&x, &mut serial);
            for shards in SHARD_COUNTS {
                let mut sharded = vec![0.0; n1 * n2];
                IdxstCombo::with_policy(n1, n2, combo, ExecPolicy::Serial)
                    .with_shards(ShardPolicy::MaxShards(shards))
                    .forward(&x, &mut sharded);
                close(
                    &sharded,
                    &serial,
                    1e-10,
                    &format!("{combo:?} ({n1},{n2}) shards={shards}"),
                );
            }
        }
    }
}

#[test]
fn min_rows_per_shard_matches_serial() {
    let mut rng = Rng::new(714);
    for &(n1, n2) in &[(33usize, 17usize), (64, 12), (13, 7)] {
        let x = rng.normal_vec(n1 * n2);
        let mut serial = vec![0.0; n1 * n2];
        Dct2::with_policy(n1, n2, ExecPolicy::Serial).forward(&x, &mut serial);
        for min_rows in [1usize, 2, 5, 1000] {
            let mut sharded = vec![0.0; n1 * n2];
            Dct2::with_policy(n1, n2, ExecPolicy::Serial)
                .with_shards(ShardPolicy::MinRowsPerShard(min_rows))
                .forward(&x, &mut sharded);
            close(
                &sharded,
                &serial,
                1e-10,
                &format!("dct2 ({n1},{n2}) min_rows={min_rows}"),
            );
        }
    }
}

/// 3D shapes stressing the slab-band math: slab counts not divisible by
/// any shard count, prime (Bluestein) dimensions on every axis, a
/// power-of-two reference, and a single-slab degenerate.
const SHARD_SHAPES_3D: &[(usize, usize, usize)] = &[
    (9, 6, 10),  // slabs not divisible by 2 or 7
    (5, 3, 7),   // prime on all three axes (Bluestein everywhere)
    (13, 4, 6),  // prime slab count x even composites
    (8, 8, 8),   // power of two
    (1, 9, 4),   // single slab
];

#[test]
fn dct3d_sharded_matches_serial_for_all_slab_counts() {
    let mut rng = Rng::new(720);
    for &(n1, n2, n3) in SHARD_SHAPES_3D {
        let x = rng.normal_vec(n1 * n2 * n3);
        let mut serial = vec![0.0; x.len()];
        Dct3d::with_policy(n1, n2, n3, ExecPolicy::Serial).forward(&x, &mut serial);
        for shards in SHARD_COUNTS {
            let mut sharded = vec![0.0; x.len()];
            Dct3d::with_policy(n1, n2, n3, ExecPolicy::Serial)
                .with_shards(ShardPolicy::MaxShards(shards))
                .forward(&x, &mut sharded);
            close(
                &sharded,
                &serial,
                1e-10,
                &format!("dct3d ({n1},{n2},{n3}) shards={shards}"),
            );
        }
    }
}

#[test]
fn idct3d_sharded_matches_serial_for_all_slab_counts() {
    let mut rng = Rng::new(721);
    for &(n1, n2, n3) in SHARD_SHAPES_3D {
        let x = rng.normal_vec(n1 * n2 * n3);
        let mut serial = vec![0.0; x.len()];
        Idct3d::with_policy(n1, n2, n3, ExecPolicy::Serial).forward(&x, &mut serial);
        for shards in SHARD_COUNTS {
            let mut sharded = vec![0.0; x.len()];
            Idct3d::with_policy(n1, n2, n3, ExecPolicy::Serial)
                .with_shards(ShardPolicy::MaxShards(shards))
                .forward(&x, &mut sharded);
            close(
                &sharded,
                &serial,
                1e-10,
                &format!("idct3d ({n1},{n2},{n3}) shards={shards}"),
            );
        }
    }
}

#[test]
fn rfft3_sharded_matches_serial_for_all_slab_counts() {
    let mut rng = Rng::new(722);
    for &(n1, n2, n3) in SHARD_SHAPES_3D {
        let x = rng.normal_vec(n1 * n2 * n3);
        let serial_plan = Rfft3Plan::with_policy(n1, n2, n3, ExecPolicy::Serial);
        let h3 = serial_plan.h3;
        let mut serial = vec![C64::default(); n1 * n2 * h3];
        serial_plan.forward(&x, &mut serial);
        for shards in SHARD_COUNTS {
            let plan = Rfft3Plan::with_policy(n1, n2, n3, ExecPolicy::Serial)
                .with_shards(ShardPolicy::MaxShards(shards));
            let mut sharded = vec![C64::default(); n1 * n2 * h3];
            plan.forward(&x, &mut sharded);
            for (i, (a, b)) in serial.iter().zip(&sharded).enumerate() {
                assert!(
                    (*a - *b).abs() <= 1e-10,
                    "rfft3 ({n1},{n2},{n3}) shards={shards} at {i}"
                );
            }
            // inverse too: spectrum back to the original samples
            let mut back = vec![0.0; n1 * n2 * n3];
            plan.inverse(&sharded, &mut back);
            close(
                &back,
                &x,
                1e-9,
                &format!("irfft3 ({n1},{n2},{n3}) shards={shards}"),
            );
        }
    }
}

#[test]
fn idct3d_inverts_dct3d_under_shards_and_lanes() {
    // the roundtrip holds when forward and inverse run different
    // decompositions (sharded forward, lane-parallel inverse)
    let mut rng = Rng::new(723);
    for &(n1, n2, n3) in &[(9usize, 6usize, 10usize), (5, 3, 7), (8, 8, 8)] {
        let x = rng.normal_vec(n1 * n2 * n3);
        let mut y = vec![0.0; x.len()];
        Dct3d::with_policy(n1, n2, n3, ExecPolicy::Serial)
            .with_shards(ShardPolicy::MaxShards(3))
            .forward(&x, &mut y);
        let mut back = vec![0.0; x.len()];
        Idct3d::with_policy(n1, n2, n3, ExecPolicy::Threads(4)).forward(&y, &mut back);
        close(&back, &x, 1e-9, &format!("3d roundtrip ({n1},{n2},{n3})"));
    }
}

#[test]
fn shard_policy_composes_with_parallel_exec() {
    // sharding on top of a multi-lane exec policy must still agree with
    // the serial reference
    let mut rng = Rng::new(715);
    let (n1, n2) = (48usize, 36usize);
    let x = rng.normal_vec(n1 * n2);
    let mut serial = vec![0.0; n1 * n2];
    Dct2::with_policy(n1, n2, ExecPolicy::Serial).forward(&x, &mut serial);
    for shards in [ShardPolicy::MaxShards(3), ShardPolicy::MinRowsPerShard(8)] {
        let mut out = vec![0.0; n1 * n2];
        Dct2::with_policy(n1, n2, ExecPolicy::Threads(4))
            .with_shards(shards)
            .forward(&x, &mut out);
        close(&out, &serial, 1e-10, &format!("threads(4) + {}", shards.label()));
    }
}

#[test]
fn roundtrip_under_parallel_policy() {
    let mut rng = Rng::new(706);
    for &(n1, n2) in &[(48usize, 36usize), (13, 29), (64, 64)] {
        let x = rng.normal_vec(n1 * n2);
        let mut y = vec![0.0; n1 * n2];
        Dct2::with_policy(n1, n2, ExecPolicy::Threads(4)).forward(&x, &mut y);
        let mut back = vec![0.0; n1 * n2];
        Idct2::with_policy(n1, n2, ExecPolicy::Threads(4)).forward(&y, &mut back);
        close(&back, &x, 1e-9, &format!("roundtrip ({n1},{n2})"));
    }
}
