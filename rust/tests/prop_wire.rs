//! Wire-protocol round-trip properties: `decode(encode(x)) == x`
//! bit-identically, across every `TransformOp`, ranks 1–3, pow2 and
//! Bluestein shapes, batched payloads, and adversarial f64 values
//! (-0.0, subnormals, huge magnitudes).

use std::time::Duration;

use mddct::coordinator::TransformOp;
use mddct::server::proto::{self, WireMsg, WireReply, WireRequest};
use mddct::util::error::TransformError;
use mddct::util::rng::Rng;

const BATCHES: [usize; 3] = [1, 2, 5];

/// One power-of-two and one Bluestein (mixed odd-factor) shape per rank.
fn shapes_for(rank: usize) -> Vec<Vec<usize>> {
    match rank {
        1 => vec![vec![16], vec![15]],
        2 => vec![vec![8, 8], vec![9, 15]],
        _ => vec![vec![4, 4, 4], vec![3, 5, 7]],
    }
}

/// Random payload with the adversarial f64 values the shortest
/// round-trip formatter must preserve spliced into the front.
fn payload(rng: &mut Rng, n: usize) -> Vec<f64> {
    let mut data = rng.normal_vec(n);
    let specials = [-0.0, 5e-324, -2.2250738585072014e-308, 1e300, -1e300, 1.0 + f64::EPSILON];
    for (slot, s) in data.iter_mut().zip(specials.iter()) {
        *slot = *s;
    }
    data
}

fn assert_bits_eq(got: &[f64], want: &[f64], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{ctx}: element {i} ({g:?} vs {w:?})");
    }
}

#[test]
fn requests_round_trip_bit_identically_across_all_ops() {
    let mut rng = Rng::new(0x5eed);
    for op in TransformOp::ALL {
        for shape in shapes_for(op.rank()) {
            let numel: usize = shape.iter().product();
            for batch in BATCHES {
                // 1 << 53 is the largest deadline the integer grammar
                // carries exactly (the decoder rejects anything above)
                for deadline_ms in [None, Some(0), Some(250), Some(1u64 << 53)] {
                    // vary tenant/priority alongside the deadline so
                    // every combination of optional fields round-trips
                    let tenant = match deadline_ms {
                        Some(250) => Some("tenant-a".to_string()),
                        Some(0) => Some(String::new()),
                        _ => None,
                    };
                    let priority = (rng.next_u64() % 256) as u8;
                    let req = WireRequest {
                        id: rng.next_u64() >> 12,
                        op,
                        shape: shape.clone(),
                        batch,
                        deadline_ms,
                        tenant,
                        priority,
                        data: payload(&mut rng, numel * batch),
                    };
                    let body = proto::encode_request(&req);
                    let ctx = format!("{op:?} {shape:?} batch={batch}");
                    match proto::decode_request(body.as_bytes()) {
                        Ok(WireMsg::Transform(back)) => {
                            assert_eq!(back.id, req.id, "{ctx}: id");
                            assert_eq!(back.op, req.op, "{ctx}: op");
                            assert_eq!(back.shape, req.shape, "{ctx}: shape");
                            assert_eq!(back.batch, req.batch, "{ctx}: batch");
                            assert_eq!(back.deadline_ms, req.deadline_ms, "{ctx}: deadline");
                            assert_eq!(back.tenant, req.tenant, "{ctx}: tenant");
                            assert_eq!(back.priority, req.priority, "{ctx}: priority");
                            assert_bits_eq(&back.data, &req.data, &ctx);
                        }
                        other => panic!("{ctx}: decode failed: {other:?}"),
                    }
                }
            }
        }
    }
}

#[test]
fn second_encode_is_byte_identical() {
    // encode -> decode -> encode is a fixpoint: the wire form is
    // canonical, so clients and fuzz corpora can compare bytes
    let mut rng = Rng::new(77);
    for op in [TransformOp::Dct2d, TransformOp::IdctIdxst, TransformOp::Dct3d] {
        let shape = shapes_for(op.rank()).pop().unwrap();
        let numel: usize = shape.iter().product();
        let req = WireRequest {
            id: 9,
            op,
            shape,
            batch: 2,
            deadline_ms: Some(5),
            tenant: None,
            priority: 0,
            data: payload(&mut rng, numel * 2),
        };
        let first = proto::encode_request(&req);
        let back = match proto::decode_request(first.as_bytes()) {
            Ok(WireMsg::Transform(r)) => r,
            other => panic!("decode failed: {other:?}"),
        };
        assert_eq!(proto::encode_request(&back), first);
    }
}

#[test]
fn replies_round_trip_bit_identically() {
    let mut rng = Rng::new(4242);
    for n in [0usize, 1, 7, 256] {
        let data = payload(&mut rng, n);
        let body = proto::encode_response(11, "native", 4, 0.125, &data);
        match proto::decode_reply(body.as_bytes()) {
            Ok(WireReply::Ok { id, backend, batch, latency_ms, data: back }) => {
                assert_eq!((id, backend.as_str(), batch), (11, "native", 4));
                assert_eq!(latency_ms.to_bits(), 0.125f64.to_bits());
                assert_bits_eq(&back, &data, &format!("reply n={n}"));
            }
            other => panic!("reply n={n}: decode failed: {other:?}"),
        }
    }
}

#[test]
fn error_frames_reconstruct_every_variant() {
    let errors = [
        TransformError::InvalidRequest("shape [0] has a zero dim".into()),
        TransformError::InvalidRequest("weird \"quotes\" and \\ backslashes \u{1f980}".into()),
        TransformError::DeadlineExceeded,
        TransformError::Overloaded { retry_after: Duration::from_millis(5) },
        TransformError::Overloaded { retry_after: Duration::from_millis(12_000) },
        TransformError::ExecutionPanicked("worker died".into()),
        TransformError::ExecutionFailed("plan rejected".into()),
        TransformError::ShuttingDown,
    ];
    for (i, err) in errors.iter().enumerate() {
        let body = proto::encode_error(i as u64, err);
        match proto::decode_reply(body.as_bytes()) {
            Ok(WireReply::Err { id, error }) => {
                assert_eq!(id, i as u64);
                assert_eq!(proto::error_code(&error), proto::error_code(err));
                assert_eq!(error.to_string(), err.to_string());
                assert_eq!(error.is_retryable(), err.is_retryable());
            }
            other => panic!("error {err:?}: decode failed: {other:?}"),
        }
    }
}

#[test]
fn frames_round_trip_through_the_slice_reader() {
    let mut rng = Rng::new(99);
    let mut stream = Vec::new();
    let mut bodies = Vec::new();
    for _ in 0..20 {
        let n = rng.below(64);
        let body: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        proto::write_frame(&mut stream, &body).unwrap();
        bodies.push(body);
    }
    let mut at = 0usize;
    for (i, want) in bodies.iter().enumerate() {
        let (body, used) = proto::read_frame_slice(&stream[at..], 1 << 20)
            .unwrap()
            .unwrap_or_else(|| panic!("frame {i} missing"));
        assert_eq!(body, &want[..], "frame {i}");
        at += used;
    }
    assert!(proto::read_frame_slice(&stream[at..], 1 << 20).unwrap().is_none());
}
