//! Cross-module integration over the native backend: transforms
//! composed through the public API agree with each other and with the
//! direct oracles at realistic sizes.

use mddct::apps::{synthetic_image, Compressor, PoissonSolver, SolverBackend};
use mddct::dct::direct::{dct2d_direct, idct_idxst_direct};
use mddct::dct::{Algo1d, Combo, Dct1d, Dct2, Idct2, IdxstCombo, RowColumn};
use mddct::util::rng::Rng;

fn assert_close(got: &[f64], want: &[f64], tol: f64, what: &str) {
    assert_eq!(got.len(), want.len());
    let scale = want.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!((g - w).abs() <= tol * scale, "{what}@{i}: {g} vs {w}");
    }
}

#[test]
fn fused_row_column_and_oracle_agree_at_scale() {
    let (n1, n2) = (192, 160);
    let mut rng = Rng::new(500);
    let x = rng.normal_vec(n1 * n2);
    let mut fused = vec![0.0; n1 * n2];
    Dct2::new(n1, n2).forward(&x, &mut fused);
    let mut rc = vec![0.0; n1 * n2];
    RowColumn::dct2(n1, n2).forward(&x, &mut rc);
    assert_close(&fused, &rc, 1e-10, "fused vs rc");
    assert_close(&fused, &dct2d_direct(&x, n1, n2), 1e-9, "fused vs direct");
}

#[test]
fn separable_1d_passes_equal_fused_2d() {
    // manually compose 1D N-point DCTs (rows then cols) == Dct2
    let (n1, n2) = (48, 32);
    let mut rng = Rng::new(501);
    let x = rng.normal_vec(n1 * n2);
    let row = Dct1d::new(n2, Algo1d::NPoint);
    let col = Dct1d::new(n1, Algo1d::NPoint);
    let mut a = vec![0.0; n1 * n2];
    for r in 0..n1 {
        row.forward(&x[r * n2..(r + 1) * n2], &mut a[r * n2..(r + 1) * n2]);
    }
    let mut out = vec![0.0; n1 * n2];
    let mut colbuf = vec![0.0; n1];
    let mut colout = vec![0.0; n1];
    for c in 0..n2 {
        for r in 0..n1 {
            colbuf[r] = a[r * n2 + c];
        }
        col.forward(&colbuf, &mut colout);
        for r in 0..n1 {
            out[r * n2 + c] = colout[r];
        }
    }
    let mut fused = vec![0.0; n1 * n2];
    Dct2::new(n1, n2).forward(&x, &mut fused);
    assert_close(&out, &fused, 1e-10, "manual separable vs fused");
}

#[test]
fn compression_pipeline_end_to_end() {
    let n = 128;
    let img = synthetic_image(n, n, 7);
    let c = Compressor::new(n, n);
    let rep = c.report(&img, 30.0);
    assert!(rep.sparsity > 0.0 && rep.sparsity < 1.0);
    assert!(rep.psnr_db > 30.0, "psnr {}", rep.psnr_db);
}

#[test]
fn poisson_solver_consistent_with_combo_plans() {
    let n = 48;
    let mut rng = Rng::new(502);
    let rho = rng.normal_vec(n * n);
    let (field, _) = PoissonSolver::new(n, n, SolverBackend::Fused).solve(&rho);
    // reconstruct xi_x by hand: a = dct2(rho); scale; idct_idxst
    let a = dct2d_direct(&rho, n, n);
    let mut cx = vec![0.0; n * n];
    for u in 0..n {
        for v in 0..n {
            let wu = std::f64::consts::PI * u as f64 / n as f64;
            let wv = std::f64::consts::PI * v as f64 / n as f64;
            let w2 = wu * wu + wv * wv;
            cx[u * n + v] = if w2 > 0.0 { a[u * n + v] * wu / w2 } else { 0.0 };
        }
    }
    assert_close(&field.xi_x, &idct_idxst_direct(&cx, n, n), 1e-8, "xi_x");
}

#[test]
fn combos_equal_their_row_column_forms_at_scale() {
    let (n1, n2) = (96, 128);
    let mut rng = Rng::new(503);
    let x = rng.normal_vec(n1 * n2);
    for (combo, rc) in [
        (Combo::IdctIdxst, RowColumn::idct_idxst(n1, n2)),
        (Combo::IdxstIdct, RowColumn::idxst_idct(n1, n2)),
    ] {
        let mut a = vec![0.0; n1 * n2];
        IdxstCombo::new(n1, n2, combo).forward(&x, &mut a);
        let mut b = vec![0.0; n1 * n2];
        rc.forward(&x, &mut b);
        assert_close(&a, &b, 1e-9, "combo vs rc");
    }
}

#[test]
fn dct_idct_roundtrip_large_non_pow2() {
    let (n1, n2) = (300, 500); // Bluestein path on both axes
    let mut rng = Rng::new(504);
    let x = rng.normal_vec(n1 * n2);
    let mut y = vec![0.0; n1 * n2];
    Dct2::new(n1, n2).forward(&x, &mut y);
    let mut back = vec![0.0; n1 * n2];
    Idct2::new(n1, n2).forward(&y, &mut back);
    assert_close(&back, &x, 1e-8, "non-pow2 roundtrip");
}
