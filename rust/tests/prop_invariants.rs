//! Property-based invariants over the whole stack (util::prop framework):
//! the mathematical identities the paper's correctness rests on, checked
//! on randomized shapes/payloads.

use mddct::coordinator::{PlanKey, Router, TransformOp};
use mddct::dct::{Algo1d, Dct1d, Dct2, Idct1d, Idct2};
use mddct::fft::{onesided_len, C64, RfftPlan};
use mddct::util::prop::{check_close, forall, shapes, sizes};

#[test]
fn prop_dct_roundtrip_1d() {
    forall(60, sizes(1, 200), |rng, &n| {
        let x = rng.normal_vec(n);
        let mut y = vec![0.0; n];
        Dct1d::new(n, Algo1d::NPoint).forward(&x, &mut y);
        let mut back = vec![0.0; n];
        Idct1d::new(n).forward(&y, &mut back);
        check_close(&back, &x, 1e-9)
    });
}

#[test]
fn prop_dct2_linearity() {
    forall(30, shapes(1, 32), |rng, &(n1, n2)| {
        let x = rng.normal_vec(n1 * n2);
        let y = rng.normal_vec(n1 * n2);
        let plan = Dct2::new(n1, n2);
        let combo: Vec<f64> = x.iter().zip(&y).map(|(a, b)| 1.5 * a + 2.0 * b).collect();
        let mut fc = vec![0.0; n1 * n2];
        plan.forward(&combo, &mut fc);
        let mut fx = vec![0.0; n1 * n2];
        plan.forward(&x, &mut fx);
        let mut fy = vec![0.0; n1 * n2];
        plan.forward(&y, &mut fy);
        let want: Vec<f64> = fx.iter().zip(&fy).map(|(a, b)| 1.5 * a + 2.0 * b).collect();
        check_close(&fc, &want, 1e-9)
    });
}

#[test]
fn prop_rfft_hermitian_symmetry() {
    // Eq. (12): X(n) = X*(N-n) — the redundancy the paradigm exploits
    forall(40, sizes(2, 128), |rng, &n| {
        let x = rng.normal_vec(n);
        let plan = RfftPlan::new(n);
        let mut spec = vec![C64::default(); onesided_len(n)];
        plan.forward(&x, &mut spec);
        // DC & Nyquist bins must be real
        if spec[0].im.abs() > 1e-9 {
            return Err(format!("DC imag {}", spec[0].im));
        }
        if n % 2 == 0 && spec[n / 2].im.abs() > 1e-9 {
            return Err(format!("Nyquist imag {}", spec[n / 2].im));
        }
        Ok(())
    });
}

#[test]
fn prop_dct2_energy_bounded() {
    // |DCT2D(x)|_2^2 <= 16 N1 N2 |x|_2^2: per axis the unnormalized
    // DCT-II has singular values sqrt(2N) (sqrt(4N) for the DC row), so
    // the 2D operator norm is 4 sqrt(N1 N2) — catches scaling drift
    forall(30, shapes(1, 24), |rng, &(n1, n2)| {
        let x = rng.normal_vec(n1 * n2);
        let mut y = vec![0.0; n1 * n2];
        Dct2::new(n1, n2).forward(&x, &mut y);
        let ex: f64 = x.iter().map(|v| v * v).sum();
        let ey: f64 = y.iter().map(|v| v * v).sum();
        if ey <= 16.0 * (n1 * n2) as f64 * ex + 1e-6 {
            Ok(())
        } else {
            Err(format!("energy blew up: {ey} vs {ex}"))
        }
    });
}

#[test]
fn prop_idct2_of_delta_is_bounded_basis_function() {
    // each IDCT basis function has |.|_inf <= 1 in our convention's
    // inverse scaling (x[0]+2*sum(cos))/2N <= (2N-1)/(2N) < 1 per axis
    forall(20, shapes(2, 16), |rng, &(n1, n2)| {
        let mut x = vec![0.0; n1 * n2];
        let idx = rng.below(n1 * n2);
        x[idx] = 1.0;
        let mut y = vec![0.0; n1 * n2];
        Idct2::new(n1, n2).forward(&x, &mut y);
        let m = y.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        if m <= 1.0 + 1e-9 {
            Ok(())
        } else {
            Err(format!("basis function overshoot {m}"))
        }
    });
}

#[test]
fn prop_router_deterministic_and_native_correct() {
    let router = Router::native_only();
    forall(25, shapes(1, 20), |rng, &(n1, n2)| {
        let key = PlanKey { op: TransformOp::Dct2d, shape: vec![n1, n2] };
        let x = rng.normal_vec(n1 * n2);
        let (a, ra) = router.execute(&key, &x).map_err(|e| e)?;
        let (b, rb) = router.execute(&key, &x).map_err(|e| e)?;
        if ra != rb {
            return Err("route flapped".into());
        }
        check_close(&a, &b, 0.0)
    });
}

#[test]
fn prop_request_validation_total() {
    // validation never panics, accepts exactly the consistent requests
    forall(50, shapes(1, 16), |rng, &(n1, n2)| {
        let numel = n1 * n2;
        let len = if rng.f64() < 0.5 { numel } else { rng.range(0, 2 * numel) };
        let req = mddct::coordinator::Request {
            id: 1,
            op: TransformOp::Dct2d,
            shape: vec![n1, n2],
            data: vec![0.0; len],
        };
        match (req.validate(), len == numel) {
            (Ok(()), true) | (Err(_), false) => Ok(()),
            (Ok(()), false) => Err("accepted bad payload".into()),
            (Err(e), true) => Err(format!("rejected good payload: {e}")),
        }
    });
}
