//! Property-based invariants over the whole stack (util::prop framework):
//! the mathematical identities the paper's correctness rests on, checked
//! on randomized shapes/payloads.

use mddct::coordinator::{PlanKey, Router, TransformOp};
use mddct::dct::{Algo1d, Dct1d, Dct2, Idct1d, Idct2};
use mddct::fft::radix2::dft_naive;
use mddct::fft::{onesided_len, C64, FftKernel, FftPlan, RfftPlan};
use mddct::util::prop::{check_close, forall, shapes, sizes};
use mddct::util::rng::Rng;

/// Every power-of-two size the kernel layer must handle: 1..=4096.
fn pow2_all() -> Vec<usize> {
    (0..=12).map(|e| 1usize << e).collect()
}

const KERNELS: [FftKernel; 2] = [FftKernel::ScalarRadix2, FftKernel::SplitRadixSoa];

fn rand_c(rng: &mut Rng, n: usize) -> Vec<C64> {
    (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect()
}

#[test]
fn prop_fft_kernels_match_naive_dft_all_pow2() {
    // every kernel variant against the O(N^2) oracle on all pow2 sizes
    let mut rng = Rng::new(0x4A11);
    for n in pow2_all() {
        let x = rand_c(&mut rng, n);
        let want = dft_naive(&x, false);
        for kernel in KERNELS {
            let plan = FftPlan::with_kernel(n, kernel);
            let mut got = x.clone();
            plan.forward(&mut got);
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (*a - *b).abs() < 1e-9 * (n as f64).max(1.0),
                    "kernel={} n={n} idx={i}: {a:?} vs {b:?}",
                    kernel.name()
                );
            }
        }
    }
}

#[test]
fn prop_fft_kernels_roundtrip_and_parseval_all_pow2() {
    let mut rng = Rng::new(0x4A12);
    for n in pow2_all() {
        let x = rand_c(&mut rng, n);
        let ex: f64 = x.iter().map(|c| c.norm_sqr()).sum();
        for kernel in KERNELS {
            let plan = FftPlan::with_kernel(n, kernel);
            let mut y = x.clone();
            plan.forward(&mut y);
            // Parseval: sum |X|^2 = N sum |x|^2
            let ey: f64 = y.iter().map(|c| c.norm_sqr()).sum();
            assert!(
                (ey - n as f64 * ex).abs() <= 1e-9 * ey.max(1.0) * (n as f64).sqrt(),
                "kernel={} n={n}: parseval {ey} vs {}",
                kernel.name(),
                n as f64 * ex
            );
            plan.inverse(&mut y);
            for (i, (a, b)) in y.iter().zip(&x).enumerate() {
                assert!(
                    (*a - *b).abs() < 1e-10 * b.abs().max(1.0) * (n as f64).max(1.0).log2().max(1.0),
                    "kernel={} n={n} idx={i} roundtrip",
                    kernel.name()
                );
            }
        }
    }
}

#[test]
fn prop_fft_cross_kernel_equivalence_all_pow2() {
    // new split-radix/SoA kernel vs old scalar radix-2, forward and
    // inverse, within 1e-10 (relative to magnitude)
    let mut rng = Rng::new(0x4A13);
    for n in pow2_all() {
        let x = rand_c(&mut rng, n);
        for invert in [false, true] {
            let mut old = x.clone();
            let mut new = x.clone();
            let po = FftPlan::with_kernel(n, FftKernel::ScalarRadix2);
            let pn = FftPlan::with_kernel(n, FftKernel::SplitRadixSoa);
            if invert {
                po.inverse(&mut old);
                pn.inverse(&mut new);
            } else {
                po.forward(&mut old);
                pn.forward(&mut new);
            }
            for (i, (a, b)) in new.iter().zip(&old).enumerate() {
                assert!(
                    (*a - *b).abs() < 1e-10 * b.abs().max(1.0),
                    "n={n} invert={invert} idx={i}: {a:?} vs {b:?}"
                );
            }
        }
    }
}

#[test]
fn prop_blocked_transform_cols_matches_per_column_1d() {
    // the blocked column path of every kernel vs a per-column 1D loop of
    // the same kernel — exact (bitwise) agreement is the contract the
    // parallel layer's Serial == Threads(n) equality rests on
    let mut rng = Rng::new(0x4A14);
    for e in 0..=10 {
        let n = 1usize << e;
        // 67 and 130 straddle the 64-column panel boundary
        for ncols in [1usize, 3, 67, 130] {
            let base = rand_c(&mut rng, n * ncols);
            for kernel in KERNELS {
                let plan = FftPlan::with_kernel(n, kernel);
                for invert in [false, true] {
                    let mut blocked = base.clone();
                    assert!(plan.try_transform_cols(&mut blocked, ncols, invert));
                    let mut want = base.clone();
                    let mut col = vec![C64::default(); n];
                    for c in 0..ncols {
                        for r in 0..n {
                            col[r] = want[r * ncols + c];
                        }
                        if invert {
                            plan.inverse(&mut col);
                        } else {
                            plan.forward(&mut col);
                        }
                        for r in 0..n {
                            want[r * ncols + c] = col[r];
                        }
                    }
                    for (i, (a, b)) in blocked.iter().zip(&want).enumerate() {
                        assert!(
                            a == b,
                            "kernel={} n={n} ncols={ncols} invert={invert} idx={i}: {a:?} vs {b:?}",
                            kernel.name()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn prop_rfft_kernel_variants_agree() {
    // the RFFT recombination on top of each kernel: same spectrum to
    // 1e-10, and each roundtrips
    let mut rng = Rng::new(0x4A15);
    for &n in &[2usize, 8, 64, 256, 1024, 4096] {
        let x = rng.normal_vec(n);
        let mut specs: Vec<Vec<C64>> = Vec::new();
        for kernel in KERNELS {
            let plan = RfftPlan::with_kernel(n, kernel);
            let mut spec = vec![C64::default(); onesided_len(n)];
            plan.forward(&x, &mut spec);
            let mut back = vec![0.0; n];
            plan.inverse(&spec, &mut back);
            for (a, b) in back.iter().zip(&x) {
                assert!((a - b).abs() < 1e-9, "kernel={} n={n}", kernel.name());
            }
            specs.push(spec);
        }
        for (k, (a, b)) in specs[0].iter().zip(&specs[1]).enumerate() {
            assert!(
                (*a - *b).abs() < 1e-10 * a.abs().max(1.0),
                "rfft kernels disagree n={n} k={k}"
            );
        }
    }
}

#[test]
fn prop_dct_roundtrip_1d() {
    forall(60, sizes(1, 200), |rng, &n| {
        let x = rng.normal_vec(n);
        let mut y = vec![0.0; n];
        Dct1d::new(n, Algo1d::NPoint).forward(&x, &mut y);
        let mut back = vec![0.0; n];
        Idct1d::new(n).forward(&y, &mut back);
        check_close(&back, &x, 1e-9)
    });
}

#[test]
fn prop_dct2_linearity() {
    forall(30, shapes(1, 32), |rng, &(n1, n2)| {
        let x = rng.normal_vec(n1 * n2);
        let y = rng.normal_vec(n1 * n2);
        let plan = Dct2::new(n1, n2);
        let combo: Vec<f64> = x.iter().zip(&y).map(|(a, b)| 1.5 * a + 2.0 * b).collect();
        let mut fc = vec![0.0; n1 * n2];
        plan.forward(&combo, &mut fc);
        let mut fx = vec![0.0; n1 * n2];
        plan.forward(&x, &mut fx);
        let mut fy = vec![0.0; n1 * n2];
        plan.forward(&y, &mut fy);
        let want: Vec<f64> = fx.iter().zip(&fy).map(|(a, b)| 1.5 * a + 2.0 * b).collect();
        check_close(&fc, &want, 1e-9)
    });
}

#[test]
fn prop_rfft_hermitian_symmetry() {
    // Eq. (12): X(n) = X*(N-n) — the redundancy the paradigm exploits
    forall(40, sizes(2, 128), |rng, &n| {
        let x = rng.normal_vec(n);
        let plan = RfftPlan::new(n);
        let mut spec = vec![C64::default(); onesided_len(n)];
        plan.forward(&x, &mut spec);
        // DC & Nyquist bins must be real
        if spec[0].im.abs() > 1e-9 {
            return Err(format!("DC imag {}", spec[0].im));
        }
        if n % 2 == 0 && spec[n / 2].im.abs() > 1e-9 {
            return Err(format!("Nyquist imag {}", spec[n / 2].im));
        }
        Ok(())
    });
}

#[test]
fn prop_dct2_energy_bounded() {
    // |DCT2D(x)|_2^2 <= 16 N1 N2 |x|_2^2: per axis the unnormalized
    // DCT-II has singular values sqrt(2N) (sqrt(4N) for the DC row), so
    // the 2D operator norm is 4 sqrt(N1 N2) — catches scaling drift
    forall(30, shapes(1, 24), |rng, &(n1, n2)| {
        let x = rng.normal_vec(n1 * n2);
        let mut y = vec![0.0; n1 * n2];
        Dct2::new(n1, n2).forward(&x, &mut y);
        let ex: f64 = x.iter().map(|v| v * v).sum();
        let ey: f64 = y.iter().map(|v| v * v).sum();
        if ey <= 16.0 * (n1 * n2) as f64 * ex + 1e-6 {
            Ok(())
        } else {
            Err(format!("energy blew up: {ey} vs {ex}"))
        }
    });
}

#[test]
fn prop_idct2_of_delta_is_bounded_basis_function() {
    // each IDCT basis function has |.|_inf <= 1 in our convention's
    // inverse scaling (x[0]+2*sum(cos))/2N <= (2N-1)/(2N) < 1 per axis
    forall(20, shapes(2, 16), |rng, &(n1, n2)| {
        let mut x = vec![0.0; n1 * n2];
        let idx = rng.below(n1 * n2);
        x[idx] = 1.0;
        let mut y = vec![0.0; n1 * n2];
        Idct2::new(n1, n2).forward(&x, &mut y);
        let m = y.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        if m <= 1.0 + 1e-9 {
            Ok(())
        } else {
            Err(format!("basis function overshoot {m}"))
        }
    });
}

#[test]
fn prop_router_deterministic_and_native_correct() {
    let router = Router::native_only();
    forall(25, shapes(1, 20), |rng, &(n1, n2)| {
        let key = PlanKey::new(TransformOp::Dct2d, vec![n1, n2]);
        let x = rng.normal_vec(n1 * n2);
        let (a, ra) = router.execute(&key, &x).map_err(|e| e.to_string())?;
        let (b, rb) = router.execute(&key, &x).map_err(|e| e.to_string())?;
        if ra != rb {
            return Err("route flapped".into());
        }
        check_close(&a, &b, 0.0)
    });
}

#[test]
fn prop_request_validation_total() {
    // validation never panics, accepts exactly the consistent requests
    forall(50, shapes(1, 16), |rng, &(n1, n2)| {
        let numel = n1 * n2;
        let len = if rng.f64() < 0.5 { numel } else { rng.range(0, 2 * numel) };
        let req = mddct::coordinator::Request {
            id: 1,
            op: TransformOp::Dct2d,
            shape: vec![n1, n2],
            data: vec![0.0; len],
            deadline: None,
            tenant: None,
            priority: 0,
        };
        match (req.validate(), len == numel) {
            (Ok(()), true) | (Err(_), false) => Ok(()),
            (Ok(()), false) => Err("accepted bad payload".into()),
            (Err(e), true) => Err(format!("rejected good payload: {e}")),
        }
    });
}
