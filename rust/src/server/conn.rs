//! Per-connection frame loop: read a frame, answer a frame.
//!
//! Each accepted socket gets one blocking reader thread running
//! [`handle_conn`]. Every request frame produces exactly one reply
//! frame, in order, so clients may pipeline. Decode failures answer a
//! typed `invalid_request` error frame; framing violations (truncated
//! or oversized frames) answer one best-effort error frame and close
//! the connection, since the stream offset can no longer be trusted.

use std::io;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::proto;
use super::ServerStats;
use crate::coordinator::{Handle, Service, TransformError};

/// Everything a connection thread needs, cloned per connection.
pub(crate) struct ConnCtx {
    pub(crate) service: Arc<Service>,
    pub(crate) stats: Arc<ServerStats>,
    pub(crate) max_frame_bytes: usize,
}

/// Serve one connection until EOF, a framing violation, or a socket
/// error.
pub(crate) fn handle_conn(mut stream: TcpStream, ctx: &ConnCtx) {
    let _ = stream.set_nodelay(true);
    loop {
        match proto::read_frame(&mut stream, ctx.max_frame_bytes) {
            Ok(None) => break,
            Ok(Some(body)) => {
                ctx.stats.add_frame_in(body.len());
                let reply = respond(&body, ctx);
                ctx.stats.add_frame_out(reply.len());
                if proto::write_frame(&mut stream, reply.as_bytes()).is_err() {
                    break;
                }
            }
            Err(e)
                if e.kind() == io::ErrorKind::InvalidData
                    || e.kind() == io::ErrorKind::UnexpectedEof =>
            {
                // framing violation: answer once, then close
                ctx.stats.record_decode_error();
                let reply =
                    proto::encode_error(0, &TransformError::InvalidRequest(e.to_string()));
                let reply_len = reply.len();
                if proto::write_frame(&mut stream, reply.as_bytes()).is_ok() {
                    ctx.stats.add_frame_out(reply_len);
                }
                break;
            }
            Err(_) => break,
        }
    }
}

/// Map one request body to one reply body.
fn respond(body: &[u8], ctx: &ConnCtx) -> String {
    match proto::decode_request(body) {
        Err(e) => {
            ctx.stats.record_decode_error();
            proto::encode_error(0, &e)
        }
        Ok(proto::WireMsg::Metrics) => {
            let snap = ctx.service.snapshot_with(&[("_server", ctx.stats.snapshot())]);
            proto::encode_metrics_reply(&snap)
        }
        Ok(proto::WireMsg::Transform(req)) => serve_transform(req, ctx),
    }
}

/// Submit a wire request's blocks and assemble the reply. A wire batch
/// of B blocks becomes B individual submits — the service batcher
/// co-batches same-plan work on its own — so the concatenated output is
/// bit-identical to B direct [`Service::transform`] calls.
fn serve_transform(req: proto::WireRequest, ctx: &ConnCtx) -> String {
    let numel = req.data.len() / req.batch; // decoder guarantees batch >= 1 and exact division
    let deadline =
        req.deadline_ms.map(|ms| Instant::now().checked_add(Duration::from_millis(ms)));
    let mut handles: Vec<Handle> = Vec::with_capacity(req.batch);
    for b in 0..req.batch {
        let block = req.data[b * numel..(b + 1) * numel].to_vec();
        let submitted = match deadline {
            // explicit wire deadline (a checked_add overflow means
            // "effectively unbounded", i.e. no deadline)
            Some(d) => ctx.service.submit_with_deadline(req.op, req.shape.clone(), block, d),
            None => ctx.service.submit(req.op, req.shape.clone(), block),
        };
        match submitted {
            Ok(h) => handles.push(h),
            // dropping already-submitted handles cancels them
            Err(e) => return proto::encode_error(req.id, &e),
        }
    }
    let mut out: Vec<f64> = Vec::with_capacity(req.data.len());
    let mut backend = "native";
    let mut latency_ms = 0.0f64;
    let mut co_batch = 1usize;
    for h in handles {
        match h.wait() {
            Ok(resp) => {
                out.extend_from_slice(&resp.output);
                backend = resp.backend;
                latency_ms = latency_ms.max(resp.latency * 1e3);
                co_batch = co_batch.max(resp.batch_size);
            }
            Err(e) => return proto::encode_error(req.id, &e),
        }
    }
    proto::encode_response(req.id, backend, co_batch, latency_ms, &out)
}
