//! Per-connection frame loop: read a frame, answer a frame.
//!
//! Each accepted socket gets one blocking reader thread running
//! [`handle_conn`]. Every request frame produces exactly one reply
//! frame, in order, so clients may pipeline; a per-connection in-flight
//! window caps how many decoded transform frames may be outstanding in
//! the service at once.
//!
//! Hardening:
//!
//! * **Idle timeout** — a connection silent between frames for longer
//!   than `idle_timeout` is closed without a reply.
//! * **Read timeout** — once a frame's first byte arrives, the rest
//!   must land within `read_timeout` or the reader answers one typed
//!   `invalid_request` frame and closes (anti-slowloris: a peer
//!   trickling bytes cannot pin the thread).
//! * **Violation budget** — JSON decode failures answer a typed error
//!   and count a strike; at [`MAX_CONN_VIOLATIONS`](super::MAX_CONN_VIOLATIONS)
//!   strikes the connection is closed. Framing violations (truncated or
//!   oversized frames) close immediately, since the stream offset can
//!   no longer be trusted.
//! * **Chaos seam** — all reads and writes flow through [`FaultStream`],
//!   which applies injected network faults (`stall` / `truncate` /
//!   `garbage` / `close` at site `conn`) so the chaos suite can exercise
//!   every failure path above on a real socket.

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::{proto, ConnShared, ServerStats};
use crate::coordinator::fault::{self, FaultKind};
use crate::coordinator::{Handle, Service, SubmitOptions, TransformError};

/// Frame bodies are read in chunks this large so a hostile length
/// prefix under the cap still cannot force a large up-front allocation.
const READ_CHUNK: usize = 16 * 1024;

/// Everything a connection thread needs, cloned per connection.
pub(crate) struct ConnCtx {
    pub(crate) service: Arc<Service>,
    pub(crate) stats: Arc<ServerStats>,
    /// Shared write half + raw handle (drain says goodbye through it).
    pub(crate) conn: Arc<ConnShared>,
    /// Flips when a graceful drain starts.
    pub(crate) draining: Arc<AtomicBool>,
    pub(crate) max_frame_bytes: usize,
    /// Per-frame read deadline once a frame has started (`None` = unbounded).
    pub(crate) read_timeout: Option<Duration>,
    /// Close connections silent between frames this long (`None` = never).
    pub(crate) idle_timeout: Option<Duration>,
    /// Cap on outstanding transform submissions from one wire batch.
    pub(crate) max_conn_inflight: usize,
}

/// Stream adapter applying injected connection faults
/// ([`fault::conn_fault`]) to every read and write. With no faults
/// configured (or under the `fault-off` feature) each call collapses to
/// a plain delegate.
struct FaultStream<'a, S> {
    inner: &'a mut S,
}

impl<S: Read> Read for FaultStream<'_, S> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match fault::conn_fault() {
            Some(FaultKind::Stall(d)) => {
                std::thread::sleep(d);
                self.inner.read(buf)
            }
            Some(FaultKind::Truncate) => Ok(0), // looks like a clean EOF
            Some(FaultKind::Garbage) => {
                let n = self.inner.read(buf)?;
                if n > 0 {
                    buf[0] ^= 0xA5;
                }
                Ok(n)
            }
            Some(FaultKind::Close) => {
                Err(io::Error::new(io::ErrorKind::ConnectionAborted, "injected connection close"))
            }
            _ => self.inner.read(buf),
        }
    }
}

impl<S: Write> Write for FaultStream<'_, S> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match fault::conn_fault() {
            Some(FaultKind::Stall(d)) => {
                std::thread::sleep(d);
                self.inner.write(buf)
            }
            Some(FaultKind::Truncate) => {
                // deliver half the bytes, then fail: the peer sees a
                // torn frame
                let half = if buf.len() <= 1 { buf.len() } else { buf.len() / 2 };
                self.inner.write_all(&buf[..half])?;
                Err(io::Error::new(io::ErrorKind::ConnectionAborted, "injected write truncation"))
            }
            Some(FaultKind::Garbage) => {
                let mut corrupted = buf.to_vec();
                if let Some(b) = corrupted.first_mut() {
                    *b ^= 0xA5;
                }
                self.inner.write_all(&corrupted)?;
                Ok(buf.len())
            }
            Some(FaultKind::Close) => {
                Err(io::Error::new(io::ErrorKind::ConnectionAborted, "injected connection close"))
            }
            _ => self.inner.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

/// RAII increment of the server-wide in-flight request gauge — what
/// [`Server::drain`](super::Server::drain) waits on during the grace
/// period.
struct InflightGuard<'a>(&'a ServerStats);

impl<'a> InflightGuard<'a> {
    fn new(stats: &'a ServerStats) -> Self {
        stats.inflight_requests.fetch_add(1, Ordering::SeqCst);
        InflightGuard(stats)
    }
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.inflight_requests.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Outcome of one timed frame read.
enum FrameRead {
    /// A complete frame body.
    Frame(Vec<u8>),
    /// Clean EOF before any prefix byte.
    Eof,
    /// No frame started within the idle timeout.
    Idle,
    /// A frame started but stalled past the read deadline.
    TimedOut,
    /// Framing violation (oversized or truncated frame) — the stream
    /// offset can no longer be trusted.
    Violation(String),
    /// Unrecoverable socket error.
    Io,
}

/// Outcome of one deadline-bounded `read_exact`-style fill.
enum TimedRead {
    Done,
    Eof,
    TimedOut,
    Io,
}

fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Fill `buf` completely, or fail by `deadline`. Each underlying read
/// gets `set_read_timeout(remaining)` so a trickling peer makes
/// progress toward the deadline instead of resetting it.
fn read_within(stream: &mut TcpStream, buf: &mut [u8], deadline: Option<Instant>) -> TimedRead {
    let mut filled = 0usize;
    while filled < buf.len() {
        let timeout = match deadline {
            Some(d) => {
                let now = Instant::now();
                if now >= d {
                    return TimedRead::TimedOut;
                }
                Some(d - now)
            }
            None => None,
        };
        if stream.set_read_timeout(timeout).is_err() {
            return TimedRead::Io;
        }
        let mut fs = FaultStream { inner: stream };
        match fs.read(&mut buf[filled..]) {
            Ok(0) => return TimedRead::Eof,
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) => return TimedRead::TimedOut,
            Err(_) => return TimedRead::Io,
        }
    }
    TimedRead::Done
}

/// Read one frame under the connection's timeout policy: the wait for a
/// frame to *start* is bounded by the idle timeout; once the first
/// prefix byte arrives, the whole frame must land before the per-frame
/// read deadline.
fn read_frame_timed(stream: &mut TcpStream, ctx: &ConnCtx) -> FrameRead {
    // Phase 1: wait (up to idle_timeout) for the first prefix byte.
    if stream.set_read_timeout(ctx.idle_timeout).is_err() {
        return FrameRead::Io;
    }
    let mut first = [0u8; 1];
    loop {
        let mut fs = FaultStream { inner: stream };
        match fs.read(&mut first) {
            Ok(0) => return FrameRead::Eof,
            Ok(_) => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) => return FrameRead::Idle,
            Err(_) => return FrameRead::Io,
        }
    }
    // Phase 2: the frame has started — hard deadline for the rest.
    let deadline = ctx.read_timeout.map(|t| Instant::now() + t);
    let mut rest = [0u8; 3];
    match read_within(stream, &mut rest, deadline) {
        TimedRead::Done => {}
        TimedRead::Eof => return FrameRead::Violation("truncated length prefix".to_string()),
        TimedRead::TimedOut => return FrameRead::TimedOut,
        TimedRead::Io => return FrameRead::Io,
    }
    let len = u32::from_be_bytes([first[0], rest[0], rest[1], rest[2]]) as usize;
    if len > ctx.max_frame_bytes {
        return FrameRead::Violation(format!(
            "frame length {len} exceeds cap {}",
            ctx.max_frame_bytes
        ));
    }
    let mut body = Vec::new();
    while body.len() < len {
        let chunk = (len - body.len()).min(READ_CHUNK);
        let old = body.len();
        body.resize(old + chunk, 0);
        match read_within(stream, &mut body[old..], deadline) {
            TimedRead::Done => {}
            TimedRead::Eof => {
                return FrameRead::Violation(format!(
                    "truncated frame: need {len} body bytes, stream ended early"
                ));
            }
            TimedRead::TimedOut => return FrameRead::TimedOut,
            TimedRead::Io => return FrameRead::Io,
        }
    }
    FrameRead::Frame(body)
}

/// Write one reply frame through the connection's shared (locked) write
/// half, applying injected connection faults.
fn send_reply(ctx: &ConnCtx, reply: &str) -> io::Result<()> {
    let mut w = super::lock(&ctx.conn.writer);
    let mut fs = FaultStream { inner: &mut *w };
    proto::write_frame(&mut fs, reply.as_bytes())?;
    ctx.stats.add_frame_out(reply.len());
    Ok(())
}

/// Serve one connection until EOF, a timeout, a framing violation, too
/// many decode strikes, or a socket error.
pub(crate) fn handle_conn(mut stream: TcpStream, ctx: &ConnCtx) {
    let _ = stream.set_nodelay(true);
    let mut violations: u32 = 0;
    loop {
        match read_frame_timed(&mut stream, ctx) {
            FrameRead::Eof | FrameRead::Io => break,
            FrameRead::Idle => {
                // silent peer: close without a reply — there is no
                // frame to answer
                ctx.stats.idle_timeouts.fetch_add(1, Ordering::Relaxed);
                break;
            }
            FrameRead::TimedOut => {
                ctx.stats.read_timeouts.fetch_add(1, Ordering::Relaxed);
                let e = TransformError::InvalidRequest("wire: read timed out mid-frame".into());
                let _ = send_reply(ctx, &proto::encode_error(0, &e));
                break;
            }
            FrameRead::Violation(msg) => {
                ctx.stats.record_decode_error();
                ctx.stats.violation_closes.fetch_add(1, Ordering::Relaxed);
                let e = TransformError::InvalidRequest(format!("wire: {msg}"));
                let _ = send_reply(ctx, &proto::encode_error(0, &e));
                break;
            }
            FrameRead::Frame(body) => {
                ctx.stats.add_frame_in(body.len());
                let _guard = InflightGuard::new(&ctx.stats);
                let reply = match proto::decode_request(&body) {
                    Err(e) => {
                        // recoverable (the framing layer is intact):
                        // answer a typed error, count a strike
                        ctx.stats.record_decode_error();
                        violations += 1;
                        let closing = violations >= super::MAX_CONN_VIOLATIONS;
                        if closing {
                            ctx.stats.violation_closes.fetch_add(1, Ordering::Relaxed);
                        }
                        if send_reply(ctx, &proto::encode_error(0, &e)).is_err() || closing {
                            break;
                        }
                        continue;
                    }
                    Ok(msg) => respond(msg, ctx),
                };
                if send_reply(ctx, &reply).is_err() {
                    break;
                }
            }
        }
    }
}

/// Map one decoded request to one reply body.
fn respond(msg: proto::WireMsg, ctx: &ConnCtx) -> String {
    match msg {
        proto::WireMsg::Metrics => {
            let snap = ctx.service.snapshot_with(&[("_server", ctx.stats.snapshot())]);
            proto::encode_metrics_reply(&snap)
        }
        proto::WireMsg::Health | proto::WireMsg::Ready => {
            proto::encode_health_reply(ctx.draining.load(Ordering::SeqCst))
        }
        proto::WireMsg::Transform(req) => {
            if ctx.draining.load(Ordering::SeqCst) {
                proto::encode_error(req.id, &TransformError::ShuttingDown)
            } else {
                serve_transform(req, ctx)
            }
        }
    }
}

/// Running aggregate over the per-block service responses.
struct Agg {
    out: Vec<f64>,
    backend: &'static str,
    latency_ms: f64,
    co_batch: usize,
}

impl Agg {
    fn take(&mut self, h: Handle) -> Result<(), TransformError> {
        let resp = h.wait()?;
        self.out.extend_from_slice(&resp.output);
        self.backend = resp.backend;
        self.latency_ms = self.latency_ms.max(resp.latency * 1e3);
        self.co_batch = self.co_batch.max(resp.batch_size);
        Ok(())
    }
}

/// Submit a wire request's blocks and assemble the reply. A wire batch
/// of B blocks becomes B individual submits — the service batcher
/// co-batches same-plan work on its own — so the concatenated output is
/// bit-identical to B direct [`Service::transform`] calls. At most
/// `max_conn_inflight` blocks are outstanding at once; the window
/// retires oldest-first, which also keeps the output in block order.
fn serve_transform(req: proto::WireRequest, ctx: &ConnCtx) -> String {
    let numel = req.data.len() / req.batch; // decoder guarantees batch >= 1 and exact division
    let deadline = match req.deadline_ms {
        // explicit wire deadline (a checked_add overflow means
        // "effectively unbounded", i.e. no deadline)
        Some(ms) => Instant::now().checked_add(Duration::from_millis(ms)),
        None => ctx.service.default_deadline().map(|d| Instant::now() + d),
    };
    let mut agg = Agg {
        out: Vec::with_capacity(req.data.len()),
        backend: "native",
        latency_ms: 0.0,
        co_batch: 1,
    };
    let mut window: VecDeque<Handle> = VecDeque::new();
    for b in 0..req.batch {
        if window.len() >= ctx.max_conn_inflight {
            let oldest = window.pop_front().expect("window is non-empty at the cap");
            if let Err(e) = agg.take(oldest) {
                // dropping the rest of the window cancels those blocks
                return proto::encode_error(req.id, &e);
            }
        }
        let block = req.data[b * numel..(b + 1) * numel].to_vec();
        let opts = SubmitOptions { deadline, tenant: req.tenant.clone(), priority: req.priority };
        match ctx.service.submit_opts(req.op, req.shape.clone(), block, opts) {
            Ok(h) => window.push_back(h),
            Err(e) => return proto::encode_error(req.id, &e),
        }
    }
    for h in window {
        if let Err(e) = agg.take(h) {
            return proto::encode_error(req.id, &e);
        }
    }
    proto::encode_response(req.id, agg.backend, agg.co_batch, agg.latency_ms, &agg.out)
}
