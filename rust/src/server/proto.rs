//! Wire protocol for the TCP front-end: length-framed incremental JSON.
//!
//! A frame is a 4-byte big-endian `u32` length prefix followed by that
//! many bytes of UTF-8 JSON. Requests decode through the pull-based
//! [`JsonReader`](crate::util::json::JsonReader) straight into the
//! transform buffer — no intermediate DOM — and replies serialize
//! zero-copy from the output slice via
//! [`JsonWriter`](crate::util::json::JsonWriter).
//!
//! Request body:
//!
//! ```json
//! {"op":"dct2d","shape":[8,8],"batch":1,"id":7,"deadline_ms":250,"data":[...]}
//! ```
//!
//! `id`, `batch`, and `deadline_ms` are optional (`0`, `1`, and "no
//! explicit deadline"), as are `tenant` (a string naming the fair-share
//! budget bucket to bill; absent = the shared default bucket) and
//! `priority` (`0..=255`, higher drains first under pressure).
//! `{"op":"metrics"}` routes to the observability snapshot instead of a
//! transform; `{"op":"health"}` / `{"op":"ready"}` answer the liveness
//! probe `{"ok":true,"health":"ok"|"draining","ready":true|false}`
//! (`ready` flips false the moment a graceful drain starts). Replies
//! are either
//!
//! ```json
//! {"ok":true,"id":7,"backend":"native","batch":4,"latency_ms":0.4,"data":[...]}
//! ```
//!
//! or a typed error frame mirroring
//! [`TransformError`](crate::util::error::TransformError):
//!
//! ```json
//! {"ok":false,"id":7,"error":"overloaded","message":"...","retryable":true,"retry_after_ms":5}
//! ```
//!
//! Every decode failure — truncated frame, oversized prefix, malformed
//! JSON, non-finite number, wrong payload length — is a typed
//! [`TransformError::InvalidRequest`], never a panic; the fuzz harness
//! (`tests/fuzz_wire.rs`) holds the protocol to that contract.

use std::io::{self, Read, Write};
use std::time::Duration;

use crate::coordinator::TransformOp;
use crate::util::error::TransformError;
use crate::util::json::{Json, JsonReader, JsonWriter};

/// Default cap on a single frame body (64 MiB); override with
/// `MDDCT_MAX_FRAME_BYTES`.
pub const DEFAULT_MAX_FRAME_BYTES: usize = 64 << 20;

/// Write one length-prefixed frame (4-byte big-endian length, then the
/// body) to `w`.
pub fn write_frame(w: &mut impl Write, body: &[u8]) -> io::Result<()> {
    let len = u32::try_from(body.len()).map_err(|_| {
        io::Error::new(io::ErrorKind::InvalidInput, "frame body exceeds u32 length prefix")
    })?;
    w.write_all(&len.to_be_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Pull one frame out of an in-memory buffer. Returns `Ok(None)` on an
/// empty buffer (clean end of stream), `Ok(Some((body, consumed)))` on
/// a complete frame, and a typed [`TransformError::InvalidRequest`] for
/// a truncated prefix, a truncated body, or a length prefix above
/// `max_bytes`. This is the allocation-free entry point the fuzz and
/// property harnesses drive.
pub fn read_frame_slice(
    buf: &[u8],
    max_bytes: usize,
) -> Result<Option<(&[u8], usize)>, TransformError> {
    if buf.is_empty() {
        return Ok(None);
    }
    if buf.len() < 4 {
        return Err(invalid(&format!("truncated length prefix: {} of 4 bytes", buf.len())));
    }
    let len = u32::from_be_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    if len > max_bytes {
        return Err(invalid(&format!("frame length {len} exceeds cap {max_bytes}")));
    }
    match buf.len() - 4 {
        have if have < len => {
            Err(invalid(&format!("truncated frame: need {len} body bytes, have {have}")))
        }
        _ => Ok(Some((&buf[4..4 + len], 4 + len))),
    }
}

/// Read one frame from a stream. Returns `Ok(None)` on clean EOF before
/// any prefix byte. A prefix above `max_bytes` maps to
/// [`io::ErrorKind::InvalidData`]; EOF mid-frame surfaces as
/// [`io::ErrorKind::UnexpectedEof`] from `read_exact`.
pub fn read_frame(r: &mut impl Read, max_bytes: usize) -> io::Result<Option<Vec<u8>>> {
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Ok(None),
            Ok(_) => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    let mut rest = [0u8; 3];
    r.read_exact(&mut rest)?;
    let len = u32::from_be_bytes([first[0], rest[0], rest[1], rest[2]]) as usize;
    if len > max_bytes {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds cap {max_bytes}"),
        ));
    }
    // Growth is driven by what actually arrives, so a hostile prefix
    // under the cap still cannot force a large up-front allocation.
    let mut body = Vec::new();
    let mut taken = r.take(len as u64);
    taken.read_to_end(&mut body)?;
    if body.len() < len {
        return Err(io::Error::new(
            io::ErrorKind::UnexpectedEof,
            format!("truncated frame: need {len} body bytes, have {}", body.len()),
        ));
    }
    Ok(Some(body))
}

/// One decoded transform request as it appears on the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    /// Client-chosen correlation id, echoed verbatim in the reply.
    pub id: u64,
    /// Which transform to run.
    pub op: TransformOp,
    /// Logical shape of ONE payload block.
    pub shape: Vec<usize>,
    /// Number of contiguous blocks packed in `data` (>= 1).
    pub batch: usize,
    /// Relative deadline in milliseconds; `None` inherits the service
    /// default.
    pub deadline_ms: Option<u64>,
    /// Tenant billed for this request in the fair-share admission
    /// budget; `None` = the shared default bucket.
    pub tenant: Option<String>,
    /// Scheduling priority (higher drains first under pressure; 0 =
    /// normal).
    pub priority: u8,
    /// Row-major payload, `numel(shape) * batch` elements.
    pub data: Vec<f64>,
}

/// A decoded request frame: a transform or one of the service routes.
#[derive(Debug, Clone, PartialEq)]
pub enum WireMsg {
    /// Run a transform.
    Transform(WireRequest),
    /// Return the service observability snapshot (`{"op":"metrics"}`).
    Metrics,
    /// Liveness probe (`{"op":"health"}`).
    Health,
    /// Readiness probe (`{"op":"ready"}`) — same reply as `health`;
    /// clients typically branch on the `ready` bool.
    Ready,
}

/// A decoded reply frame (client side of the protocol).
#[derive(Debug, Clone, PartialEq)]
pub enum WireReply {
    /// Successful transform.
    Ok {
        /// Echoed correlation id.
        id: u64,
        /// Backend that executed the request (`native` / `pjrt`).
        backend: String,
        /// Largest server-side co-batch the request's blocks rode in.
        batch: usize,
        /// Worker-observed execution latency, milliseconds.
        latency_ms: f64,
        /// Transform output, blocks concatenated in request order.
        data: Vec<f64>,
    },
    /// Typed error frame reconstructed into the originating
    /// [`TransformError`].
    Err {
        /// Echoed correlation id (0 when decode failed before the id).
        id: u64,
        /// The reconstructed error.
        error: TransformError,
    },
    /// Metrics snapshot (DOM — cold path).
    Metrics(Json),
    /// Health/ready probe reply.
    Health {
        /// `"ok"` while serving, `"draining"` once a drain started.
        status: String,
        /// Whether the server accepts new transform work.
        ready: bool,
    },
}

fn invalid(msg: &str) -> TransformError {
    TransformError::InvalidRequest(format!("wire: {msg}"))
}

/// Decode one request body. All failures are typed
/// [`TransformError::InvalidRequest`]; unknown keys are skipped for
/// forward compatibility.
pub fn decode_request(body: &[u8]) -> Result<WireMsg, TransformError> {
    let mut r = JsonReader::new(body);
    r.obj_begin()?;
    let mut op: Option<String> = None;
    let mut shape: Option<Vec<usize>> = None;
    let mut batch: usize = 1;
    let mut id: u64 = 0;
    let mut deadline_ms: Option<u64> = None;
    let mut tenant: Option<String> = None;
    let mut priority: u8 = 0;
    let mut data: Option<Vec<f64>> = None;
    let mut first = true;
    while let Some(key) = r.obj_key(first)? {
        first = false;
        match key.as_str() {
            "op" => op = Some(r.string_value()?),
            "shape" => {
                let mut dims = Vec::new();
                r.arr_begin()?;
                let mut first_dim = true;
                while r.arr_next(first_dim)? {
                    first_dim = false;
                    dims.push(r.u64_value()? as usize);
                }
                shape = Some(dims);
            }
            "batch" => batch = r.u64_value()? as usize,
            "id" => id = r.u64_value()?,
            "deadline_ms" => deadline_ms = Some(r.u64_value()?),
            "tenant" => tenant = Some(r.string_value()?),
            "priority" => {
                let v = r.u64_value()?;
                if v > u8::MAX as u64 {
                    return Err(invalid(&format!("priority {v} must be 0..=255")));
                }
                priority = v as u8;
            }
            "data" => {
                let mut v = Vec::new();
                r.read_f64_array(&mut v)?;
                data = Some(v);
            }
            _ => r.skip_value()?,
        }
    }
    r.end()?;
    let op_name = op.ok_or_else(|| invalid("missing 'op'"))?;
    match op_name.as_str() {
        "metrics" => return Ok(WireMsg::Metrics),
        "health" => return Ok(WireMsg::Health),
        "ready" => return Ok(WireMsg::Ready),
        _ => {}
    }
    let op = TransformOp::parse(&op_name)
        .ok_or_else(|| invalid(&format!("unknown op '{op_name}'")))?;
    let shape = shape.ok_or_else(|| invalid("missing 'shape'"))?;
    let data = data.ok_or_else(|| invalid("missing 'data'"))?;
    if batch == 0 {
        return Err(invalid("batch must be >= 1"));
    }
    let numel = shape
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .ok_or_else(|| invalid(&format!("shape {shape:?} element count overflows")))?;
    let expected = numel
        .checked_mul(batch)
        .ok_or_else(|| invalid(&format!("shape {shape:?} x batch {batch} overflows")))?;
    if data.len() != expected {
        return Err(invalid(&format!(
            "payload has {} elements, shape {:?} x batch {} needs {}",
            data.len(),
            shape,
            batch,
            expected
        )));
    }
    Ok(WireMsg::Transform(WireRequest {
        id,
        op,
        shape,
        batch,
        deadline_ms,
        tenant,
        priority,
        data,
    }))
}

/// Encode a transform request body (client side; also the generator the
/// `encode(decode(x)) == x` property pins down).
pub fn encode_request(req: &WireRequest) -> String {
    let mut w = JsonWriter::with_capacity(64 + 20 * req.data.len());
    w.obj_begin();
    w.key("op").str_value(&req.op.name());
    w.key("shape").arr_begin();
    for &d in &req.shape {
        w.u64_value(d as u64);
    }
    w.arr_end();
    w.key("batch").u64_value(req.batch as u64);
    w.key("id").u64_value(req.id);
    if let Some(ms) = req.deadline_ms {
        w.key("deadline_ms").u64_value(ms);
    }
    if let Some(tenant) = &req.tenant {
        w.key("tenant").str_value(tenant);
    }
    if req.priority != 0 {
        w.key("priority").u64_value(req.priority as u64);
    }
    w.key("data").f64_slice(&req.data);
    w.obj_end();
    w.finish()
}

/// Encode the metrics-route request body.
pub fn encode_metrics_request() -> String {
    let mut w = JsonWriter::with_capacity(16);
    w.obj_begin().key("op").str_value("metrics").obj_end();
    w.finish()
}

/// Encode the health-route request body (`{"op":"health"}`).
pub fn encode_health_request() -> String {
    let mut w = JsonWriter::with_capacity(16);
    w.obj_begin().key("op").str_value("health").obj_end();
    w.finish()
}

/// Encode the readiness-route request body (`{"op":"ready"}`).
pub fn encode_ready_request() -> String {
    let mut w = JsonWriter::with_capacity(16);
    w.obj_begin().key("op").str_value("ready").obj_end();
    w.finish()
}

/// Encode the health/ready reply. `draining` reports the server's drain
/// state: once a graceful drain starts, `health` flips to `"draining"`
/// and `ready` to `false` so load balancers stop routing new work while
/// in-flight requests finish.
pub fn encode_health_reply(draining: bool) -> String {
    let mut w = JsonWriter::with_capacity(64);
    w.obj_begin();
    w.key("ok").bool_value(true);
    w.key("health").str_value(if draining { "draining" } else { "ok" });
    w.key("ready").bool_value(!draining);
    w.obj_end();
    w.finish()
}

/// Encode a successful reply; `data` serializes zero-copy from the
/// output slice.
pub fn encode_response(
    id: u64,
    backend: &str,
    batch: usize,
    latency_ms: f64,
    data: &[f64],
) -> String {
    let mut w = JsonWriter::with_capacity(96 + 20 * data.len());
    w.obj_begin();
    w.key("ok").bool_value(true);
    w.key("id").u64_value(id);
    w.key("backend").str_value(backend);
    w.key("batch").u64_value(batch as u64);
    w.key("latency_ms").f64_value(latency_ms);
    w.key("data").f64_slice(data);
    w.obj_end();
    w.finish()
}

/// Encode a typed error frame. `retry_after_ms` appears only on
/// [`TransformError::Overloaded`].
pub fn encode_error(id: u64, err: &TransformError) -> String {
    let mut w = JsonWriter::with_capacity(128);
    w.obj_begin();
    w.key("ok").bool_value(false);
    w.key("id").u64_value(id);
    w.key("error").str_value(error_code(err));
    let message = match err {
        TransformError::InvalidRequest(m)
        | TransformError::ExecutionPanicked(m)
        | TransformError::ExecutionFailed(m) => m.clone(),
        other => other.to_string(),
    };
    w.key("message").str_value(&message);
    w.key("retryable").bool_value(err.is_retryable());
    if let TransformError::Overloaded { retry_after } = err {
        w.key("retry_after_ms").u64_value(retry_after.as_millis() as u64);
    }
    w.obj_end();
    w.finish()
}

/// Encode the metrics-route reply around a pre-rendered snapshot.
pub fn encode_metrics_reply(snapshot: &Json) -> String {
    let mut w = JsonWriter::with_capacity(512);
    w.obj_begin();
    w.key("ok").bool_value(true);
    w.key("metrics").raw(&snapshot.to_string());
    w.obj_end();
    w.finish()
}

/// Stable wire code for each [`TransformError`] variant.
pub fn error_code(err: &TransformError) -> &'static str {
    match err {
        TransformError::InvalidRequest(_) => "invalid_request",
        TransformError::DeadlineExceeded => "deadline_exceeded",
        TransformError::Overloaded { .. } => "overloaded",
        TransformError::ExecutionPanicked(_) => "execution_panicked",
        TransformError::ExecutionFailed(_) => "execution_failed",
        TransformError::ShuttingDown => "shutting_down",
    }
}

fn error_from_code(code: &str, message: String, retry_after_ms: u64) -> TransformError {
    match code {
        "invalid_request" => TransformError::InvalidRequest(message),
        "deadline_exceeded" => TransformError::DeadlineExceeded,
        "overloaded" => {
            TransformError::Overloaded { retry_after: Duration::from_millis(retry_after_ms) }
        }
        "execution_panicked" => TransformError::ExecutionPanicked(message),
        "execution_failed" => TransformError::ExecutionFailed(message),
        "shutting_down" => TransformError::ShuttingDown,
        other => TransformError::InvalidRequest(format!("unknown error code '{other}'")),
    }
}

/// Decode one reply body (client side). Error frames reconstruct the
/// originating [`TransformError`] from the `error` code.
pub fn decode_reply(body: &[u8]) -> Result<WireReply, TransformError> {
    let mut r = JsonReader::new(body);
    r.obj_begin()?;
    let mut ok: Option<bool> = None;
    let mut id: u64 = 0;
    let mut backend = String::new();
    let mut batch: usize = 1;
    let mut latency_ms: f64 = 0.0;
    let mut data: Vec<f64> = Vec::new();
    let mut code: Option<String> = None;
    let mut message = String::new();
    let mut retry_after_ms: u64 = 0;
    let mut metrics: Option<Json> = None;
    let mut health: Option<String> = None;
    let mut ready = false;
    let mut first = true;
    while let Some(key) = r.obj_key(first)? {
        first = false;
        match key.as_str() {
            "ok" => ok = Some(r.bool_value()?),
            "id" => id = r.u64_value()?,
            "backend" => backend = r.string_value()?,
            "batch" => batch = r.u64_value()? as usize,
            "latency_ms" => latency_ms = r.f64_value()?,
            "data" => {
                r.read_f64_array(&mut data)?;
            }
            "error" => code = Some(r.string_value()?),
            "message" => message = r.string_value()?,
            "retry_after_ms" => retry_after_ms = r.u64_value()?,
            "metrics" => metrics = Some(r.value()?),
            "health" => health = Some(r.string_value()?),
            "ready" => ready = r.bool_value()?,
            _ => r.skip_value()?,
        }
    }
    r.end()?;
    match ok {
        Some(true) => match (health, metrics) {
            (Some(status), _) => Ok(WireReply::Health { status, ready }),
            (None, Some(m)) => Ok(WireReply::Metrics(m)),
            (None, None) => Ok(WireReply::Ok { id, backend, batch, latency_ms, data }),
        },
        Some(false) => {
            let code = code.ok_or_else(|| invalid("error frame missing 'error' code"))?;
            Ok(WireReply::Err { id, error: error_from_code(&code, message, retry_after_ms) })
        }
        None => Err(invalid("reply missing 'ok'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_round_trip_through_a_stream() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, b"world").unwrap();
        let mut cursor = io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cursor, 1024).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut cursor, 1024).unwrap().unwrap(), b"");
        assert_eq!(read_frame(&mut cursor, 1024).unwrap().unwrap(), b"world");
        assert!(read_frame(&mut cursor, 1024).unwrap().is_none());
    }

    #[test]
    fn frame_slice_reports_typed_errors() {
        assert!(read_frame_slice(b"", 1024).unwrap().is_none());
        for bad in [&b"\x00"[..], &b"\x00\x00\x00"[..], &b"\x00\x00\x00\x05hi"[..]] {
            match read_frame_slice(bad, 1024) {
                Err(TransformError::InvalidRequest(_)) => {}
                other => panic!("wanted InvalidRequest for {bad:?}, got {other:?}"),
            }
        }
        // oversized prefix is rejected before any body is touched
        match read_frame_slice(b"\xff\xff\xff\xff", 1024) {
            Err(TransformError::InvalidRequest(m)) => assert!(m.contains("exceeds cap")),
            other => panic!("wanted oversized-frame error, got {other:?}"),
        }
        let mut buf = Vec::new();
        write_frame(&mut buf, b"abc").unwrap();
        write_frame(&mut buf, b"de").unwrap();
        let (body, used) = read_frame_slice(&buf, 1024).unwrap().unwrap();
        assert_eq!((body, used), (&b"abc"[..], 7));
        let (body, used) = read_frame_slice(&buf[used..], 1024).unwrap().unwrap();
        assert_eq!((body, used), (&b"de"[..], 6));
    }

    #[test]
    fn oversized_stream_frame_is_invalid_data() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&u32::MAX.to_be_bytes());
        let err = read_frame(&mut io::Cursor::new(buf), 1024).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn requests_round_trip() {
        let req = WireRequest {
            id: 42,
            op: TransformOp::Dct2d,
            shape: vec![3, 5],
            batch: 2,
            deadline_ms: Some(250),
            tenant: Some("alice".into()),
            priority: 7,
            data: (0..30).map(|i| i as f64 * 0.5 - 7.0).collect(),
        };
        let body = encode_request(&req);
        match decode_request(body.as_bytes()).unwrap() {
            WireMsg::Transform(back) => assert_eq!(back, req),
            other => panic!("wanted transform, got {other:?}"),
        }
        // Defaults (no tenant, priority 0) stay off the wire and decode
        // back to themselves.
        let plain = WireRequest { tenant: None, priority: 0, ..req };
        let body = encode_request(&plain);
        assert!(!body.contains("tenant") && !body.contains("priority"));
        match decode_request(body.as_bytes()).unwrap() {
            WireMsg::Transform(back) => assert_eq!(back, plain),
            other => panic!("wanted transform, got {other:?}"),
        }
        match decode_request(encode_metrics_request().as_bytes()).unwrap() {
            WireMsg::Metrics => {}
            other => panic!("wanted metrics route, got {other:?}"),
        }
        match decode_request(encode_health_request().as_bytes()).unwrap() {
            WireMsg::Health => {}
            other => panic!("wanted health route, got {other:?}"),
        }
        match decode_request(encode_ready_request().as_bytes()).unwrap() {
            WireMsg::Ready => {}
            other => panic!("wanted ready route, got {other:?}"),
        }
    }

    #[test]
    fn priority_above_255_is_a_typed_error() {
        let body = r#"{"op":"dct2d","shape":[1,1],"priority":256,"data":[1.0]}"#;
        match decode_request(body.as_bytes()) {
            Err(TransformError::InvalidRequest(m)) => assert!(m.contains("priority")),
            other => panic!("wanted priority rejection, got {other:?}"),
        }
    }

    #[test]
    fn health_replies_round_trip_both_drain_states() {
        for (draining, status, ready) in [(false, "ok", true), (true, "draining", false)] {
            let body = encode_health_reply(draining);
            match decode_reply(body.as_bytes()).unwrap() {
                WireReply::Health { status: s, ready: r } => {
                    assert_eq!((s.as_str(), r), (status, ready));
                }
                other => panic!("wanted health reply, got {other:?}"),
            }
        }
    }

    #[test]
    fn decode_rejects_semantic_violations_with_typed_errors() {
        let cases: &[&str] = &[
            r#"{"shape":[2],"data":[1.0,2.0]}"#,                       // missing op
            r#"{"op":"nope","shape":[2],"data":[1.0,2.0]}"#,           // unknown op
            r#"{"op":"dct2d","data":[1.0]}"#,                          // missing shape
            r#"{"op":"dct2d","shape":[1,1]}"#,                         // missing data
            r#"{"op":"dct2d","shape":[1,2],"batch":0,"data":[1,2]}"#,  // batch 0
            r#"{"op":"dct2d","shape":[2,2],"data":[1.0]}"#,            // length mismatch
            r#"{"op":"dct2d","shape":[2,2],"data":[1,2,3,"x"]}"#,      // non-number payload
            "{",                                                       // malformed
        ];
        for body in cases {
            match decode_request(body.as_bytes()) {
                Err(TransformError::InvalidRequest(_)) => {}
                other => panic!("wanted InvalidRequest for {body}, got {other:?}"),
            }
        }
        // shape element-count overflow must be caught before multiplying
        let huge = format!(
            r#"{{"op":"dct2d","shape":[{m},{m}],"data":[]}}"#,
            m = 1u64 << 40
        );
        match decode_request(huge.as_bytes()) {
            Err(TransformError::InvalidRequest(m)) => assert!(m.contains("overflow")),
            other => panic!("wanted overflow rejection, got {other:?}"),
        }
    }

    #[test]
    fn replies_round_trip_including_typed_errors() {
        let body = encode_response(9, "native", 4, 0.375, &[1.5, -0.0, 2e-308]);
        match decode_reply(body.as_bytes()).unwrap() {
            WireReply::Ok { id, backend, batch, latency_ms, data } => {
                assert_eq!((id, backend.as_str(), batch, latency_ms), (9, "native", 4, 0.375));
                let bits: Vec<u64> = data.iter().map(|v| v.to_bits()).collect();
                assert_eq!(bits, vec![1.5f64.to_bits(), (-0.0f64).to_bits(), 2e-308f64.to_bits()]);
            }
            other => panic!("wanted ok reply, got {other:?}"),
        }
        let errors = [
            TransformError::InvalidRequest("bad shape".into()),
            TransformError::DeadlineExceeded,
            TransformError::Overloaded { retry_after: Duration::from_millis(5) },
            TransformError::ExecutionPanicked("boom".into()),
            TransformError::ExecutionFailed("plan".into()),
            TransformError::ShuttingDown,
        ];
        for err in errors {
            let body = encode_error(7, &err);
            match decode_reply(body.as_bytes()).unwrap() {
                WireReply::Err { id, error } => {
                    assert_eq!(id, 7);
                    assert_eq!(error_code(&error), error_code(&err));
                    assert_eq!(error.to_string(), err.to_string());
                }
                other => panic!("wanted error frame, got {other:?}"),
            }
        }
    }

    #[test]
    fn metrics_reply_round_trips_as_dom() {
        let snap = Json::parse(r#"{"_server":{"frames_in":3}}"#).unwrap();
        let body = encode_metrics_reply(&snap);
        match decode_reply(body.as_bytes()).unwrap() {
            WireReply::Metrics(m) => {
                let v = m.get("_server").and_then(|s| s.get("frames_in")).and_then(Json::as_f64);
                assert_eq!(v, Some(3.0));
            }
            other => panic!("wanted metrics reply, got {other:?}"),
        }
    }
}
