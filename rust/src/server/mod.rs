//! L4 server: dependency-free blocking-TCP front-end over the
//! coordinator service.
//!
//! * [`proto`] — length-framed incremental JSON wire protocol (frame
//!   I/O, request/reply encode/decode, the
//!   [`TransformError`](crate::util::error::TransformError) <-> wire
//!   error-code mapping)
//! * `conn` — per-connection frame loop (one blocking reader thread per
//!   accepted socket; one reply frame per request frame, in order)
//!
//! [`Server::start`] binds a listener and spawns an accept thread; each
//! accepted connection gets its own thread sharing one
//! [`Arc<Service>`]. Connections over
//! [`ServerConfig::max_conns`] are answered with a single `overloaded`
//! error frame and closed. Dropping the [`Server`] shuts everything
//! down: the accept loop is poked awake, live sockets are shut down,
//! and every thread is joined.
//!
//! ```no_run
//! use std::sync::Arc;
//! use mddct::coordinator::{Service, ServiceConfig};
//! use mddct::server::{Server, ServerConfig};
//!
//! let svc = Arc::new(Service::start_native(ServiceConfig::default()));
//! let server = Server::start(ServerConfig::default(), svc).unwrap();
//! println!("listening on {}", server.addr());
//! # drop(server);
//! ```
//!
//! Environment knobs (all optional): `MDDCT_BIND` (default
//! `127.0.0.1`), `MDDCT_PORT` (default [`DEFAULT_PORT`]),
//! `MDDCT_MAX_CONNS` (default [`DEFAULT_MAX_CONNS`]),
//! `MDDCT_MAX_FRAME_BYTES` (default
//! [`proto::DEFAULT_MAX_FRAME_BYTES`]).

#![warn(missing_docs)]

mod conn;
pub mod proto;

use std::collections::HashMap;
use std::io;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::{Service, TransformError};
use crate::util::json::Json;

/// Default TCP port when `MDDCT_PORT` is unset and no `--port` is given.
pub const DEFAULT_PORT: u16 = 7243;

/// Default cap on concurrently served connections
/// (`MDDCT_MAX_CONNS`).
pub const DEFAULT_MAX_CONNS: usize = 256;

/// Retry hint attached to the `overloaded` frame a connection over the
/// cap receives before being closed.
const CONN_RETRY_AFTER: Duration = Duration::from_millis(50);

fn env_u16(name: &str) -> Option<u16> {
    crate::util::env_usize(name).and_then(|v| u16::try_from(v).ok())
}

/// TCP front-end configuration. [`ServerConfig::default`] reads the
/// `MDDCT_BIND` / `MDDCT_PORT` / `MDDCT_MAX_CONNS` /
/// `MDDCT_MAX_FRAME_BYTES` environment knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Address to bind (`MDDCT_BIND`, default `127.0.0.1`).
    pub bind: String,
    /// TCP port; 0 asks the OS for an ephemeral port (`MDDCT_PORT`).
    pub port: u16,
    /// Cap on concurrently served connections (`MDDCT_MAX_CONNS`).
    pub max_conns: usize,
    /// Cap on a single frame body in bytes (`MDDCT_MAX_FRAME_BYTES`).
    pub max_frame_bytes: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            bind: std::env::var("MDDCT_BIND").unwrap_or_else(|_| "127.0.0.1".to_string()),
            port: env_u16("MDDCT_PORT").unwrap_or(DEFAULT_PORT),
            max_conns: crate::util::env_usize("MDDCT_MAX_CONNS").unwrap_or(DEFAULT_MAX_CONNS),
            max_frame_bytes: crate::util::env_usize("MDDCT_MAX_FRAME_BYTES")
                .unwrap_or(proto::DEFAULT_MAX_FRAME_BYTES),
        }
    }
}

impl ServerConfig {
    /// Same config on an OS-assigned ephemeral port (tests, loopback
    /// benches).
    pub fn ephemeral() -> ServerConfig {
        ServerConfig { port: 0, ..ServerConfig::default() }
    }
}

/// Wire-level counters, exported as the `_server` section of the
/// metrics snapshot. All counters are monotonic except `active_conns`,
/// which is a gauge.
#[derive(Debug, Default)]
pub struct ServerStats {
    /// Connections accepted and served.
    pub accepted_conns: AtomicU64,
    /// Connections currently being served (gauge).
    pub active_conns: AtomicU64,
    /// Connections shed at the [`ServerConfig::max_conns`] cap.
    pub rejected_conns: AtomicU64,
    /// Request frames received.
    pub frames_in: AtomicU64,
    /// Reply frames sent.
    pub frames_out: AtomicU64,
    /// Bytes received (frame bodies + length prefixes).
    pub bytes_in: AtomicU64,
    /// Bytes sent (frame bodies + length prefixes).
    pub bytes_out: AtomicU64,
    /// Frames rejected as malformed (framing or JSON decode failures).
    pub decode_errors: AtomicU64,
}

impl ServerStats {
    /// Fresh zeroed counters.
    pub fn new() -> ServerStats {
        ServerStats::default()
    }

    pub(crate) fn add_frame_in(&self, body_len: usize) {
        self.frames_in.fetch_add(1, Ordering::Relaxed);
        self.bytes_in.fetch_add(body_len as u64 + 4, Ordering::Relaxed);
    }

    pub(crate) fn add_frame_out(&self, body_len: usize) {
        self.frames_out.fetch_add(1, Ordering::Relaxed);
        self.bytes_out.fetch_add(body_len as u64 + 4, Ordering::Relaxed);
    }

    pub(crate) fn record_decode_error(&self) {
        self.decode_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Counters as a JSON object (the `_server` snapshot section).
    pub fn snapshot(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        let mut put = |k: &str, v: &AtomicU64| {
            m.insert(k.to_string(), Json::Num(v.load(Ordering::Relaxed) as f64));
        };
        put("accepted_conns", &self.accepted_conns);
        put("active_conns", &self.active_conns);
        put("bytes_in", &self.bytes_in);
        put("bytes_out", &self.bytes_out);
        put("decode_errors", &self.decode_errors);
        put("frames_in", &self.frames_in);
        put("frames_out", &self.frames_out);
        put("rejected_conns", &self.rejected_conns);
        Json::Obj(m)
    }
}

/// State shared between the accept loop, connection threads, and
/// shutdown.
struct Shared {
    /// Stream clones by connection id, so shutdown can unblock readers.
    conns: Mutex<HashMap<u64, TcpStream>>,
    /// Join handles for spawned connection threads.
    joins: Mutex<Vec<JoinHandle<()>>>,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// A running TCP front-end. Dropping it shuts the listener and every
/// live connection down and joins all threads.
pub struct Server {
    addr: SocketAddr,
    stats: Arc<ServerStats>,
    stop: Arc<AtomicBool>,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind `config.bind:config.port` and start serving `service`.
    pub fn start(config: ServerConfig, service: Arc<Service>) -> io::Result<Server> {
        let listener = TcpListener::bind((config.bind.as_str(), config.port))?;
        let addr = listener.local_addr()?;
        let stats = Arc::new(ServerStats::new());
        let stop = Arc::new(AtomicBool::new(false));
        let shared = Arc::new(Shared {
            conns: Mutex::new(HashMap::new()),
            joins: Mutex::new(Vec::new()),
        });
        let accept = {
            let (stats, stop, shared) = (stats.clone(), stop.clone(), shared.clone());
            let (max_conns, max_frame_bytes) = (config.max_conns, config.max_frame_bytes);
            std::thread::Builder::new().name("mddct-accept".into()).spawn(move || {
                let mut next_conn: u64 = 0;
                for incoming in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let stream = match incoming {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    if stats.active_conns.load(Ordering::SeqCst) >= max_conns as u64 {
                        stats.rejected_conns.fetch_add(1, Ordering::Relaxed);
                        let mut s = stream;
                        let reply = proto::encode_error(
                            0,
                            &TransformError::Overloaded { retry_after: CONN_RETRY_AFTER },
                        );
                        let _ = proto::write_frame(&mut s, reply.as_bytes());
                        continue; // drop closes the socket
                    }
                    stats.accepted_conns.fetch_add(1, Ordering::Relaxed);
                    stats.active_conns.fetch_add(1, Ordering::SeqCst);
                    let conn_id = next_conn;
                    next_conn += 1;
                    if let Ok(clone) = stream.try_clone() {
                        lock(&shared.conns).insert(conn_id, clone);
                    }
                    let ctx = conn::ConnCtx {
                        service: service.clone(),
                        stats: stats.clone(),
                        max_frame_bytes,
                    };
                    let (shared2, stats2) = (shared.clone(), stats.clone());
                    let join = std::thread::Builder::new()
                        .name(format!("mddct-conn-{conn_id}"))
                        .spawn(move || {
                            conn::handle_conn(stream, &ctx);
                            stats2.active_conns.fetch_sub(1, Ordering::SeqCst);
                            lock(&shared2.conns).remove(&conn_id);
                        })
                        .expect("spawn connection thread");
                    lock(&shared.joins).push(join);
                }
            })?
        };
        Ok(Server { addr, stats, stop, shared, accept: Some(accept) })
    }

    /// The bound address (carries the OS-assigned port when the config
    /// asked for port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Wire-level counters for this server.
    pub fn stats(&self) -> &Arc<ServerStats> {
        &self.stats
    }

    /// Stop accepting, shut every live connection down, and join all
    /// threads. Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke the accept loop out of its blocking `incoming()`
        let poke = if self.addr.ip().is_unspecified() {
            SocketAddr::from(([127, 0, 0, 1], self.addr.port()))
        } else {
            self.addr
        };
        let _ = TcpStream::connect(poke);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        // unblock reader threads parked in read_frame
        for (_, s) in lock(&self.shared.conns).drain() {
            let _ = s.shutdown(Shutdown::Both);
        }
        let joins: Vec<_> = lock(&self.shared.joins).drain(..).collect();
        for j in joins {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{ServiceConfig, TransformOp};
    use std::io::Write;

    fn serve(max_conns: usize) -> (Server, Arc<Service>) {
        let svc = Arc::new(Service::start_native(ServiceConfig {
            workers: 2,
            ..ServiceConfig::default()
        }));
        let cfg = ServerConfig { max_conns, ..ServerConfig::ephemeral() };
        let server = Server::start(cfg, svc.clone()).unwrap();
        (server, svc)
    }

    fn roundtrip(stream: &mut TcpStream, body: &str) -> proto::WireReply {
        proto::write_frame(stream, body.as_bytes()).unwrap();
        let reply = proto::read_frame(stream, proto::DEFAULT_MAX_FRAME_BYTES).unwrap().unwrap();
        proto::decode_reply(&reply).unwrap()
    }

    #[test]
    fn serves_a_transform_and_counts_frames() {
        let (server, svc) = serve(4);
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        let req = proto::WireRequest {
            id: 3,
            op: TransformOp::Dct2d,
            shape: vec![4, 4],
            batch: 1,
            deadline_ms: None,
            data: (0..16).map(|i| i as f64).collect(),
        };
        let want = svc
            .transform(TransformOp::Dct2d, vec![4, 4], (0..16).map(|i| i as f64).collect())
            .unwrap();
        match roundtrip(&mut stream, &proto::encode_request(&req)) {
            proto::WireReply::Ok { id, data, .. } => {
                assert_eq!(id, 3);
                assert_eq!(data, want.output);
            }
            other => panic!("wanted ok reply, got {other:?}"),
        }
        assert_eq!(server.stats().frames_in.load(Ordering::Relaxed), 1);
        assert_eq!(server.stats().frames_out.load(Ordering::Relaxed), 1);
        assert!(server.stats().bytes_in.load(Ordering::Relaxed) > 4);
    }

    #[test]
    fn malformed_json_gets_a_typed_error_frame() {
        let (server, _svc) = serve(4);
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        match roundtrip(&mut stream, "{not json") {
            proto::WireReply::Err { error: TransformError::InvalidRequest(_), .. } => {}
            other => panic!("wanted invalid_request frame, got {other:?}"),
        }
        assert_eq!(server.stats().decode_errors.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn oversized_frame_answers_once_and_closes() {
        let (server, _svc) = serve(4);
        let cfg_max = proto::DEFAULT_MAX_FRAME_BYTES;
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream.write_all(&u32::MAX.to_be_bytes()).unwrap();
        stream.flush().unwrap();
        let reply = proto::read_frame(&mut stream, cfg_max).unwrap().unwrap();
        match proto::decode_reply(&reply).unwrap() {
            proto::WireReply::Err { error: TransformError::InvalidRequest(m), .. } => {
                assert!(m.contains("exceeds cap"), "{m}");
            }
            other => panic!("wanted invalid_request frame, got {other:?}"),
        }
        // server closed its side after the violation
        assert!(proto::read_frame(&mut stream, cfg_max).unwrap().is_none());
        drop(server);
    }

    #[test]
    fn metrics_route_merges_the_server_section() {
        let (server, _svc) = serve(4);
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        match roundtrip(&mut stream, &proto::encode_metrics_request()) {
            proto::WireReply::Metrics(snap) => {
                let frames = snap
                    .get("_server")
                    .and_then(|s| s.get("frames_in"))
                    .and_then(Json::as_f64);
                assert_eq!(frames, Some(1.0));
                assert!(snap.get("_admission").is_some(), "service sections survive the merge");
            }
            other => panic!("wanted metrics reply, got {other:?}"),
        }
    }

    #[test]
    fn connections_over_the_cap_are_shed_with_overloaded() {
        let (server, _svc) = serve(1);
        let mut keep = TcpStream::connect(server.addr()).unwrap();
        // ensure the first connection is fully registered before probing
        match roundtrip(&mut keep, &proto::encode_metrics_request()) {
            proto::WireReply::Metrics(_) => {}
            other => panic!("wanted metrics reply, got {other:?}"),
        }
        let mut extra = TcpStream::connect(server.addr()).unwrap();
        let reply =
            proto::read_frame(&mut extra, proto::DEFAULT_MAX_FRAME_BYTES).unwrap().unwrap();
        match proto::decode_reply(&reply).unwrap() {
            proto::WireReply::Err { error: TransformError::Overloaded { .. }, .. } => {}
            other => panic!("wanted overloaded frame, got {other:?}"),
        }
        assert_eq!(server.stats().rejected_conns.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn shutdown_is_idempotent_and_unblocks_idle_connections() {
        let (mut server, _svc) = serve(4);
        let mut idle = TcpStream::connect(server.addr()).unwrap();
        match roundtrip(&mut idle, &proto::encode_metrics_request()) {
            proto::WireReply::Metrics(_) => {}
            other => panic!("wanted metrics reply, got {other:?}"),
        }
        server.shutdown();
        server.shutdown();
        assert!(
            proto::read_frame(&mut idle, proto::DEFAULT_MAX_FRAME_BYTES)
                .map(|f| f.is_none())
                .unwrap_or(true),
            "idle connection is released by shutdown"
        );
    }
}
