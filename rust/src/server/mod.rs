//! L4 server: dependency-free blocking-TCP front-end over the
//! coordinator service.
//!
//! * [`proto`] — length-framed incremental JSON wire protocol (frame
//!   I/O, request/reply encode/decode, the
//!   [`TransformError`](crate::util::error::TransformError) <-> wire
//!   error-code mapping)
//! * `conn` — per-connection frame loop (one blocking reader thread per
//!   accepted socket; one reply frame per request frame, in order)
//!
//! [`Server::start`] binds a listener and spawns an accept thread; each
//! accepted connection gets its own thread sharing one
//! [`Arc<Service>`]. Connections over
//! [`ServerConfig::max_conns`] are answered with a single `overloaded`
//! error frame (with an occupancy-scaled `retry_after_ms` hint) and
//! closed. Reader threads are protected against slowloris peers by a
//! per-frame read timeout and an optional idle timeout, and a
//! connection that keeps sending malformed frames is closed after
//! [`MAX_CONN_VIOLATIONS`] strikes.
//!
//! Shutdown comes in two flavors. [`Server::shutdown`] (also the drop
//! path) stops accepting, sends every live connection a final typed
//! `shutting_down` frame, and joins all threads. [`Server::drain`]
//! additionally grants in-flight requests a grace period first: the
//! server flips to the draining state (`{"op":"health"}` reports
//! `"draining"` / `ready:false`, new transforms get `shutting_down`
//! frames), waits up to the deadline for in-flight work, then closes as
//! above.
//!
//! ```no_run
//! use std::sync::Arc;
//! use mddct::coordinator::{Service, ServiceConfig};
//! use mddct::server::{Server, ServerConfig};
//!
//! let svc = Arc::new(Service::start_native(ServiceConfig::default()));
//! let server = Server::start(ServerConfig::default(), svc).unwrap();
//! println!("listening on {}", server.addr());
//! # drop(server);
//! ```
//!
//! Environment knobs (all optional): `MDDCT_BIND` (default
//! `127.0.0.1`), `MDDCT_PORT` (default [`DEFAULT_PORT`]),
//! `MDDCT_MAX_CONNS` (default [`DEFAULT_MAX_CONNS`]),
//! `MDDCT_MAX_FRAME_BYTES` (default
//! [`proto::DEFAULT_MAX_FRAME_BYTES`]), `MDDCT_READ_TIMEOUT_MS`
//! (per-frame read deadline once a frame starts, default
//! [`DEFAULT_READ_TIMEOUT`], `0` disables), `MDDCT_IDLE_TIMEOUT_MS`
//! (close connections silent between frames, default off), and
//! `MDDCT_CONN_INFLIGHT` (per-connection in-flight request cap, default
//! [`DEFAULT_CONN_INFLIGHT`]).

#![warn(missing_docs)]

mod conn;
pub mod proto;

use std::collections::HashMap;
use std::io;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::{Service, TransformError};
use crate::util::json::Json;

/// Default TCP port when `MDDCT_PORT` is unset and no `--port` is given.
pub const DEFAULT_PORT: u16 = 7243;

/// Default cap on concurrently served connections
/// (`MDDCT_MAX_CONNS`).
pub const DEFAULT_MAX_CONNS: usize = 256;

/// Default per-frame read deadline (`MDDCT_READ_TIMEOUT_MS`): once a
/// frame's first byte arrives, the rest must follow within this window
/// or the reader answers with a typed error and closes — a slowloris
/// peer trickling a length prefix cannot pin a reader thread.
pub const DEFAULT_READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Default per-connection in-flight request cap
/// (`MDDCT_CONN_INFLIGHT`): how many decoded transform frames one
/// connection may have outstanding in the service before the reader
/// waits for replies to retire.
pub const DEFAULT_CONN_INFLIGHT: usize = 64;

/// Framing/decode violations tolerated before a connection is closed.
pub const MAX_CONN_VIOLATIONS: u32 = 8;

/// Base of the `retry_after_ms` hint on shed connections.
const CONN_RETRY_AFTER_BASE: Duration = Duration::from_millis(10);

/// Extra `retry_after_ms` added as the connection table fills.
const CONN_RETRY_AFTER_FULL_EXTRA: Duration = Duration::from_millis(80);

/// Retry hint for a connection shed at the `max_conns` cap, scaled by
/// how far over the cap the accept loop currently is — the fuller the
/// table, the longer the hinted backoff.
fn conn_retry_after(active: u64, max_conns: usize) -> Duration {
    let occupancy = if max_conns == 0 {
        1.0
    } else {
        (active as f64 / max_conns as f64).min(1.0)
    };
    CONN_RETRY_AFTER_BASE + CONN_RETRY_AFTER_FULL_EXTRA.mul_f64(occupancy)
}

fn env_u16(name: &str) -> Option<u16> {
    crate::util::env_usize(name).and_then(|v| u16::try_from(v).ok())
}

/// Millisecond timeout knob: unset keeps `default`, `0` disables.
fn env_timeout_ms(name: &str, default: Option<Duration>) -> Option<Duration> {
    match crate::util::env_usize(name) {
        Some(0) => None,
        Some(ms) => Some(Duration::from_millis(ms as u64)),
        None => default,
    }
}

/// TCP front-end configuration. [`ServerConfig::default`] reads the
/// `MDDCT_BIND` / `MDDCT_PORT` / `MDDCT_MAX_CONNS` /
/// `MDDCT_MAX_FRAME_BYTES` environment knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Address to bind (`MDDCT_BIND`, default `127.0.0.1`).
    pub bind: String,
    /// TCP port; 0 asks the OS for an ephemeral port (`MDDCT_PORT`).
    pub port: u16,
    /// Cap on concurrently served connections (`MDDCT_MAX_CONNS`).
    pub max_conns: usize,
    /// Cap on a single frame body in bytes (`MDDCT_MAX_FRAME_BYTES`).
    pub max_frame_bytes: usize,
    /// Per-frame read deadline once a frame has started arriving
    /// (`MDDCT_READ_TIMEOUT_MS`; `None` = unbounded).
    pub read_timeout: Option<Duration>,
    /// Close connections silent between frames for this long
    /// (`MDDCT_IDLE_TIMEOUT_MS`; `None` = never).
    pub idle_timeout: Option<Duration>,
    /// Per-connection in-flight request cap (`MDDCT_CONN_INFLIGHT`).
    pub max_conn_inflight: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            bind: std::env::var("MDDCT_BIND").unwrap_or_else(|_| "127.0.0.1".to_string()),
            port: env_u16("MDDCT_PORT").unwrap_or(DEFAULT_PORT),
            max_conns: crate::util::env_usize("MDDCT_MAX_CONNS").unwrap_or(DEFAULT_MAX_CONNS),
            max_frame_bytes: crate::util::env_usize("MDDCT_MAX_FRAME_BYTES")
                .unwrap_or(proto::DEFAULT_MAX_FRAME_BYTES),
            read_timeout: env_timeout_ms("MDDCT_READ_TIMEOUT_MS", Some(DEFAULT_READ_TIMEOUT)),
            idle_timeout: env_timeout_ms("MDDCT_IDLE_TIMEOUT_MS", None),
            max_conn_inflight: crate::util::env_usize("MDDCT_CONN_INFLIGHT")
                .unwrap_or(DEFAULT_CONN_INFLIGHT)
                .max(1),
        }
    }
}

impl ServerConfig {
    /// Same config on an OS-assigned ephemeral port (tests, loopback
    /// benches).
    pub fn ephemeral() -> ServerConfig {
        ServerConfig { port: 0, ..ServerConfig::default() }
    }
}

/// Wire-level counters, exported as the `_server` section of the
/// metrics snapshot. All counters are monotonic except `active_conns`,
/// which is a gauge.
#[derive(Debug, Default)]
pub struct ServerStats {
    /// Connections accepted and served.
    pub accepted_conns: AtomicU64,
    /// Connections currently being served (gauge).
    pub active_conns: AtomicU64,
    /// Connections shed at the [`ServerConfig::max_conns`] cap.
    pub rejected_conns: AtomicU64,
    /// Request frames received.
    pub frames_in: AtomicU64,
    /// Reply frames sent.
    pub frames_out: AtomicU64,
    /// Bytes received (frame bodies + length prefixes).
    pub bytes_in: AtomicU64,
    /// Bytes sent (frame bodies + length prefixes).
    pub bytes_out: AtomicU64,
    /// Frames rejected as malformed (framing or JSON decode failures).
    pub decode_errors: AtomicU64,
    /// Connections closed for exceeding the between-frames idle timeout.
    pub idle_timeouts: AtomicU64,
    /// Frames abandoned at the mid-frame read deadline (slowloris).
    pub read_timeouts: AtomicU64,
    /// Connections closed after [`MAX_CONN_VIOLATIONS`] decode strikes.
    pub violation_closes: AtomicU64,
    /// Transform requests currently in flight across all connections
    /// (gauge; what [`Server::drain`] waits on).
    pub inflight_requests: AtomicU64,
    /// 1 once a drain/shutdown has started (gauge).
    pub draining: AtomicU64,
}

impl ServerStats {
    /// Fresh zeroed counters.
    pub fn new() -> ServerStats {
        ServerStats::default()
    }

    pub(crate) fn add_frame_in(&self, body_len: usize) {
        self.frames_in.fetch_add(1, Ordering::Relaxed);
        self.bytes_in.fetch_add(body_len as u64 + 4, Ordering::Relaxed);
    }

    pub(crate) fn add_frame_out(&self, body_len: usize) {
        self.frames_out.fetch_add(1, Ordering::Relaxed);
        self.bytes_out.fetch_add(body_len as u64 + 4, Ordering::Relaxed);
    }

    pub(crate) fn record_decode_error(&self) {
        self.decode_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Counters as a JSON object (the `_server` snapshot section).
    pub fn snapshot(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        let mut put = |k: &str, v: &AtomicU64| {
            m.insert(k.to_string(), Json::Num(v.load(Ordering::Relaxed) as f64));
        };
        put("accepted_conns", &self.accepted_conns);
        put("active_conns", &self.active_conns);
        put("bytes_in", &self.bytes_in);
        put("bytes_out", &self.bytes_out);
        put("decode_errors", &self.decode_errors);
        put("draining", &self.draining);
        put("frames_in", &self.frames_in);
        put("frames_out", &self.frames_out);
        put("idle_timeouts", &self.idle_timeouts);
        put("inflight_requests", &self.inflight_requests);
        put("read_timeouts", &self.read_timeouts);
        put("rejected_conns", &self.rejected_conns);
        put("violation_closes", &self.violation_closes);
        Json::Obj(m)
    }
}

/// Per-connection handles shared between the reader thread and the
/// drain/shutdown path.
pub(crate) struct ConnShared {
    /// Serialized write half: reply frames and the final
    /// `shutting_down` goodbye both go through this lock so drain never
    /// interleaves bytes with an in-flight reply.
    pub(crate) writer: Mutex<TcpStream>,
    /// Un-locked clone used only for `shutdown()` — lets drain unblock
    /// a reader even when the writer lock is held by a stuck peer.
    raw: TcpStream,
}

/// State shared between the accept loop, connection threads, and
/// shutdown.
struct Shared {
    /// Live connections by id, so drain can say goodbye and unblock
    /// readers.
    conns: Mutex<HashMap<u64, Arc<ConnShared>>>,
    /// Join handles for spawned connection threads.
    joins: Mutex<Vec<JoinHandle<()>>>,
}

pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// A running TCP front-end. Dropping it shuts the listener and every
/// live connection down and joins all threads.
pub struct Server {
    addr: SocketAddr,
    stats: Arc<ServerStats>,
    stop: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind `config.bind:config.port` and start serving `service`.
    pub fn start(config: ServerConfig, service: Arc<Service>) -> io::Result<Server> {
        let listener = TcpListener::bind((config.bind.as_str(), config.port))?;
        let addr = listener.local_addr()?;
        let stats = Arc::new(ServerStats::new());
        let stop = Arc::new(AtomicBool::new(false));
        let draining = Arc::new(AtomicBool::new(false));
        let shared = Arc::new(Shared {
            conns: Mutex::new(HashMap::new()),
            joins: Mutex::new(Vec::new()),
        });
        let accept = {
            let (stats, stop, shared) = (stats.clone(), stop.clone(), shared.clone());
            let draining = draining.clone();
            let (max_conns, max_frame_bytes) = (config.max_conns, config.max_frame_bytes);
            let (read_timeout, idle_timeout) = (config.read_timeout, config.idle_timeout);
            let max_conn_inflight = config.max_conn_inflight.max(1);
            std::thread::Builder::new().name("mddct-accept".into()).spawn(move || {
                let mut next_conn: u64 = 0;
                for incoming in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let stream = match incoming {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    let active = stats.active_conns.load(Ordering::SeqCst);
                    if active >= max_conns as u64 {
                        stats.rejected_conns.fetch_add(1, Ordering::Relaxed);
                        let mut s = stream;
                        let reply = proto::encode_error(
                            0,
                            &TransformError::Overloaded {
                                retry_after: conn_retry_after(active, max_conns),
                            },
                        );
                        let _ = proto::write_frame(&mut s, reply.as_bytes());
                        continue; // drop closes the socket
                    }
                    // Both clones must exist before the connection is
                    // admitted: without a writer clone there is no way
                    // to answer, and without a raw clone no way to
                    // unblock the reader at drain time.
                    let (writer, raw) = match (stream.try_clone(), stream.try_clone()) {
                        (Ok(w), Ok(r)) => (w, r),
                        _ => continue, // drop closes the socket
                    };
                    stats.accepted_conns.fetch_add(1, Ordering::Relaxed);
                    stats.active_conns.fetch_add(1, Ordering::SeqCst);
                    let conn_id = next_conn;
                    next_conn += 1;
                    let handle = Arc::new(ConnShared { writer: Mutex::new(writer), raw });
                    lock(&shared.conns).insert(conn_id, handle.clone());
                    let ctx = conn::ConnCtx {
                        service: service.clone(),
                        stats: stats.clone(),
                        conn: handle,
                        draining: draining.clone(),
                        max_frame_bytes,
                        read_timeout,
                        idle_timeout,
                        max_conn_inflight,
                    };
                    let (shared2, stats2) = (shared.clone(), stats.clone());
                    let join = std::thread::Builder::new()
                        .name(format!("mddct-conn-{conn_id}"))
                        .spawn(move || {
                            conn::handle_conn(stream, &ctx);
                            stats2.active_conns.fetch_sub(1, Ordering::SeqCst);
                            lock(&shared2.conns).remove(&conn_id);
                        })
                        .expect("spawn connection thread");
                    lock(&shared.joins).push(join);
                }
            })?
        };
        Ok(Server { addr, stats, stop, draining, shared, accept: Some(accept) })
    }

    /// The bound address (carries the OS-assigned port when the config
    /// asked for port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Wire-level counters for this server.
    pub fn stats(&self) -> &Arc<ServerStats> {
        &self.stats
    }

    /// Whether a drain/shutdown has started. Once true, transform
    /// frames are answered `shutting_down` and the health route reports
    /// `"draining"` / `ready:false`.
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Stop accepting, shut every live connection down (after a final
    /// typed `shutting_down` frame), and join all threads. Equivalent
    /// to [`Server::drain`] with a zero grace period. Idempotent; also
    /// runs on drop.
    pub fn shutdown(&mut self) {
        self.drain(Duration::ZERO);
    }

    /// Gracefully drain: stop accepting, flip the draining state (new
    /// transforms get `shutting_down`, health reports `"draining"`),
    /// wait up to `grace` for in-flight requests to finish, then send
    /// every remaining connection a final typed `shutting_down` frame,
    /// close the sockets, and join all threads. Returns `true` when all
    /// in-flight work finished inside the grace period. Idempotent.
    pub fn drain(&mut self, grace: Duration) -> bool {
        self.draining.store(true, Ordering::SeqCst);
        self.stats.draining.store(1, Ordering::Relaxed);
        self.stop.store(true, Ordering::SeqCst);
        // poke the accept loop out of its blocking `incoming()`
        let poke = if self.addr.ip().is_unspecified() {
            SocketAddr::from(([127, 0, 0, 1], self.addr.port()))
        } else {
            self.addr
        };
        let _ = TcpStream::connect(poke);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        // grace period: connections stay open so in-flight replies can
        // still be delivered
        let deadline = Instant::now() + grace;
        let mut finished = true;
        while self.stats.inflight_requests.load(Ordering::SeqCst) > 0 {
            if Instant::now() >= deadline {
                finished = false;
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        // goodbye frame, then unblock reader threads parked in a read
        let conns: Vec<_> = lock(&self.shared.conns).drain().map(|(_, c)| c).collect();
        for c in conns {
            // try_lock: a writer wedged mid-reply (stuck peer) must not
            // stall the drain — the raw shutdown below still fires.
            if let Ok(mut w) = c.writer.try_lock() {
                let goodbye = proto::encode_error(0, &TransformError::ShuttingDown);
                if proto::write_frame(&mut *w, goodbye.as_bytes()).is_ok() {
                    self.stats.add_frame_out(goodbye.len());
                }
            }
            let _ = c.raw.shutdown(Shutdown::Both);
        }
        let joins: Vec<_> = lock(&self.shared.joins).drain(..).collect();
        for j in joins {
            let _ = j.join();
        }
        finished
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{ServiceConfig, TransformOp};
    use std::io::Write;

    fn serve(max_conns: usize) -> (Server, Arc<Service>) {
        let svc = Arc::new(Service::start_native(ServiceConfig {
            workers: 2,
            ..ServiceConfig::default()
        }));
        let cfg = ServerConfig { max_conns, ..ServerConfig::ephemeral() };
        let server = Server::start(cfg, svc.clone()).unwrap();
        (server, svc)
    }

    fn roundtrip(stream: &mut TcpStream, body: &str) -> proto::WireReply {
        proto::write_frame(stream, body.as_bytes()).unwrap();
        let reply = proto::read_frame(stream, proto::DEFAULT_MAX_FRAME_BYTES).unwrap().unwrap();
        proto::decode_reply(&reply).unwrap()
    }

    #[test]
    fn serves_a_transform_and_counts_frames() {
        let (server, svc) = serve(4);
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        let req = proto::WireRequest {
            id: 3,
            op: TransformOp::Dct2d,
            shape: vec![4, 4],
            batch: 1,
            deadline_ms: None,
            tenant: None,
            priority: 0,
            data: (0..16).map(|i| i as f64).collect(),
        };
        let want = svc
            .transform(TransformOp::Dct2d, vec![4, 4], (0..16).map(|i| i as f64).collect())
            .unwrap();
        match roundtrip(&mut stream, &proto::encode_request(&req)) {
            proto::WireReply::Ok { id, data, .. } => {
                assert_eq!(id, 3);
                assert_eq!(data, want.output);
            }
            other => panic!("wanted ok reply, got {other:?}"),
        }
        assert_eq!(server.stats().frames_in.load(Ordering::Relaxed), 1);
        assert_eq!(server.stats().frames_out.load(Ordering::Relaxed), 1);
        assert!(server.stats().bytes_in.load(Ordering::Relaxed) > 4);
    }

    #[test]
    fn malformed_json_gets_a_typed_error_frame() {
        let (server, _svc) = serve(4);
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        match roundtrip(&mut stream, "{not json") {
            proto::WireReply::Err { error: TransformError::InvalidRequest(_), .. } => {}
            other => panic!("wanted invalid_request frame, got {other:?}"),
        }
        assert_eq!(server.stats().decode_errors.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn oversized_frame_answers_once_and_closes() {
        let (server, _svc) = serve(4);
        let cfg_max = proto::DEFAULT_MAX_FRAME_BYTES;
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream.write_all(&u32::MAX.to_be_bytes()).unwrap();
        stream.flush().unwrap();
        let reply = proto::read_frame(&mut stream, cfg_max).unwrap().unwrap();
        match proto::decode_reply(&reply).unwrap() {
            proto::WireReply::Err { error: TransformError::InvalidRequest(m), .. } => {
                assert!(m.contains("exceeds cap"), "{m}");
            }
            other => panic!("wanted invalid_request frame, got {other:?}"),
        }
        // server closed its side after the violation
        assert!(proto::read_frame(&mut stream, cfg_max).unwrap().is_none());
        drop(server);
    }

    #[test]
    fn metrics_route_merges_the_server_section() {
        let (server, _svc) = serve(4);
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        match roundtrip(&mut stream, &proto::encode_metrics_request()) {
            proto::WireReply::Metrics(snap) => {
                let frames = snap
                    .get("_server")
                    .and_then(|s| s.get("frames_in"))
                    .and_then(Json::as_f64);
                assert_eq!(frames, Some(1.0));
                assert!(snap.get("_admission").is_some(), "service sections survive the merge");
            }
            other => panic!("wanted metrics reply, got {other:?}"),
        }
    }

    #[test]
    fn connections_over_the_cap_are_shed_with_overloaded() {
        let (server, _svc) = serve(1);
        let mut keep = TcpStream::connect(server.addr()).unwrap();
        // ensure the first connection is fully registered before probing
        match roundtrip(&mut keep, &proto::encode_metrics_request()) {
            proto::WireReply::Metrics(_) => {}
            other => panic!("wanted metrics reply, got {other:?}"),
        }
        let mut extra = TcpStream::connect(server.addr()).unwrap();
        let reply =
            proto::read_frame(&mut extra, proto::DEFAULT_MAX_FRAME_BYTES).unwrap().unwrap();
        match proto::decode_reply(&reply).unwrap() {
            proto::WireReply::Err { error: TransformError::Overloaded { .. }, .. } => {}
            other => panic!("wanted overloaded frame, got {other:?}"),
        }
        assert_eq!(server.stats().rejected_conns.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn shutdown_is_idempotent_and_unblocks_idle_connections() {
        let (mut server, _svc) = serve(4);
        let mut idle = TcpStream::connect(server.addr()).unwrap();
        match roundtrip(&mut idle, &proto::encode_metrics_request()) {
            proto::WireReply::Metrics(_) => {}
            other => panic!("wanted metrics reply, got {other:?}"),
        }
        server.shutdown();
        server.shutdown();
        // the idle connection gets a final typed goodbye frame ...
        let goodbye = proto::read_frame(&mut idle, proto::DEFAULT_MAX_FRAME_BYTES)
            .expect("goodbye frame readable")
            .expect("goodbye frame before close");
        match proto::decode_reply(&goodbye).unwrap() {
            proto::WireReply::Err { error: TransformError::ShuttingDown, .. } => {}
            other => panic!("wanted shutting_down frame, got {other:?}"),
        }
        // ... and is then released
        assert!(
            proto::read_frame(&mut idle, proto::DEFAULT_MAX_FRAME_BYTES)
                .map(|f| f.is_none())
                .unwrap_or(true),
            "idle connection is released by shutdown"
        );
    }

    #[test]
    fn conn_retry_after_hint_grows_with_occupancy() {
        let empty = conn_retry_after(0, 8);
        let half = conn_retry_after(4, 8);
        let full = conn_retry_after(8, 8);
        let over = conn_retry_after(100, 8);
        assert!(empty < half && half < full, "{empty:?} {half:?} {full:?}");
        assert_eq!(full, over, "occupancy saturates at 1.0");
        assert_eq!(empty, CONN_RETRY_AFTER_BASE);
    }

    #[test]
    fn health_routes_flip_during_drain() {
        let (mut server, _svc) = serve(4);
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        match roundtrip(&mut stream, &proto::encode_health_request()) {
            proto::WireReply::Health { status, ready } => {
                assert_eq!((status.as_str(), ready), ("ok", true));
            }
            other => panic!("wanted health reply, got {other:?}"),
        }
        match roundtrip(&mut stream, &proto::encode_ready_request()) {
            proto::WireReply::Health { ready: true, .. } => {}
            other => panic!("wanted ready reply, got {other:?}"),
        }
        assert!(!server.is_draining());
        assert!(server.drain(Duration::from_millis(200)), "no in-flight work to wait for");
        assert!(server.is_draining());
        assert_eq!(server.stats().draining.load(Ordering::Relaxed), 1);
    }
}
