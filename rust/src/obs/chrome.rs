//! Chrome trace-event export: drains the span buffers into the JSON
//! object format `chrome://tracing` and Perfetto load directly.
//!
//! Mapping: spans become `ph:"X"` complete events (`ts`/`dur` in
//! microseconds), counters become `ph:"C"`, instants `ph:"i"` (thread
//! scope), and each thread contributes a `thread_name` metadata record
//! so tracks are labeled (`mddct-worker-0`, `mddct-par-3`, ...). The
//! ctx label, when present, is attached under `args.ctx` so the trace
//! UI can filter by request shape.

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::span::{take_events, EventKind};

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

/// Drain all buffered events into one Chrome trace-event JSON document
/// (`{"traceEvents": [...], "displayTimeUnit": "ms"}`). Draining means
/// consecutive exports partition the event stream; call once at the end
/// of the window being profiled.
pub fn chrome_trace() -> Json {
    let pid = std::process::id() as f64;
    let mut events = Vec::new();
    for t in take_events() {
        let tid = t.tid as f64;
        events.push(obj(vec![
            ("name", Json::Str("thread_name".to_string())),
            ("ph", Json::Str("M".to_string())),
            ("pid", Json::Num(pid)),
            ("tid", Json::Num(tid)),
            ("args", obj(vec![("name", Json::Str(t.thread_name.clone()))])),
        ]));
        for ev in t.events {
            let ts_us = ev.t0_ns as f64 / 1e3;
            let mut fields = vec![
                ("name", Json::Str(ev.name.to_string())),
                ("pid", Json::Num(pid)),
                ("tid", Json::Num(tid)),
                ("ts", Json::Num(ts_us)),
            ];
            let mut args = Vec::new();
            if let Some(ctx) = &ev.ctx {
                args.push(("ctx", Json::Str(ctx.to_string())));
            }
            match ev.kind {
                EventKind::Span { dur_ns } => {
                    fields.push(("ph", Json::Str("X".to_string())));
                    fields.push(("dur", Json::Num(dur_ns as f64 / 1e3)));
                    fields.push(("cat", Json::Str("mddct".to_string())));
                }
                EventKind::Counter { value } => {
                    fields.push(("ph", Json::Str("C".to_string())));
                    args.push(("value", Json::Num(value)));
                }
                EventKind::Instant => {
                    fields.push(("ph", Json::Str("i".to_string())));
                    fields.push(("s", Json::Str("t".to_string())));
                    fields.push(("cat", Json::Str("mddct".to_string())));
                }
            }
            if !args.is_empty() {
                fields.push(("args", obj(args)));
            }
            events.push(obj(fields));
        }
    }
    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

/// [`chrome_trace`] serialized to `path`.
pub fn write_chrome_trace(path: &str) -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", chrome_trace()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs;

    #[test]
    fn export_is_parseable_and_typed() {
        let _g = obs::test_guard();
        obs::set_enabled(true);
        #[cfg(not(feature = "trace-off"))]
        {
            obs::reset_events();
            {
                let ctx = obs::op_ctx("chrometest", &[8, 8]);
                let _c = obs::with_ctx(ctx);
                let _s = obs::SpanGuard::begin("chrome.span");
                obs::counter("chrome.counter", 4.0);
                obs::instant_event("chrome.instant");
            }
            let doc = chrome_trace();
            // round-trips through the writer grammar
            let parsed = Json::parse(&doc.to_string()).unwrap();
            assert_eq!(
                parsed.get("displayTimeUnit").unwrap().as_str().unwrap(),
                "ms"
            );
            let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
            let find = |name: &str| {
                evs.iter()
                    .find(|e| e.get("name").and_then(Json::as_str) == Some(name))
                    .unwrap_or_else(|| panic!("missing event {name}"))
            };
            let meta = find("thread_name");
            assert_eq!(meta.get("ph").unwrap().as_str().unwrap(), "M");
            let span = find("chrome.span");
            assert_eq!(span.get("ph").unwrap().as_str().unwrap(), "X");
            assert!(span.get("dur").unwrap().as_f64().unwrap() >= 0.0);
            assert_eq!(
                span.get("args").unwrap().get("ctx").unwrap().as_str().unwrap(),
                "chrometest/8x8"
            );
            let ctr = find("chrome.counter");
            assert_eq!(ctr.get("ph").unwrap().as_str().unwrap(), "C");
            assert_eq!(
                ctr.get("args").unwrap().get("value").unwrap().as_f64().unwrap(),
                4.0
            );
            let inst = find("chrome.instant");
            assert_eq!(inst.get("ph").unwrap().as_str().unwrap(), "i");
            // drained: a second export no longer carries these events
            // (other concurrently-running tests may record unrelated
            // events, so only our names are asserted gone)
            let again = chrome_trace();
            let gone = again
                .get("traceEvents")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .all(|e| e.get("name").and_then(Json::as_str) != Some("chrome.span"));
            assert!(gone, "chrome.span must have been drained");
            obs::reset_breakdown();
        }
        obs::set_enabled(false);
    }
}
