//! Event model, thread-local span buffers, and the process-wide buffer
//! registry.
//!
//! Recording path (tracing enabled): a closing span reads the monotonic
//! clock twice per span lifetime (open + close), bumps the live
//! breakdown when it carries a ctx, and pushes one [`Event`] into its
//! thread's buffer. The buffer `Mutex` is uncontended in steady state —
//! only [`take_events`] (trace export) ever locks it from another
//! thread — so the lock is a compare-and-swap, not a syscall. Buffers
//! are registered in a global list and owned by `Arc`, so events
//! survive thread exit until drained.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use super::{current_ctx, enabled};

/// What a recorded [`Event`] represents.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// A closed interval lasting `dur_ns` nanoseconds.
    Span {
        /// Interval length in nanoseconds.
        dur_ns: u64,
    },
    /// A sampled counter value (e.g. pool queue depth).
    Counter {
        /// The sampled value.
        value: f64,
    },
    /// A zero-duration marker (e.g. a plan-cache miss).
    Instant,
}

/// One recorded trace event.
#[derive(Debug, Clone)]
pub struct Event {
    /// Static stage name, e.g. `"dct2.fft"` or `"svc.queue_wait"`.
    pub name: &'static str,
    /// The `(op, shape)` context active on the recording thread, when
    /// any (see [`super::op_ctx`]).
    pub ctx: Option<Arc<str>>,
    /// Event start, nanoseconds since the process trace epoch.
    pub t0_ns: u64,
    /// Span / counter / instant payload.
    pub kind: EventKind,
}

/// The process trace epoch: all timestamps are relative to the first
/// event recorded anywhere in the process.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds from the trace epoch to `t` (saturating at zero for
/// instants captured before the epoch was pinned).
fn since_epoch(t: Instant) -> u64 {
    t.duration_since(epoch()).as_nanos() as u64
}

/// Per-thread event buffer cap (`MDDCT_TRACE_BUF`, default 65536).
/// Overflow increments a drop counter instead of growing the buffer.
fn buf_cap() -> usize {
    static CAP: OnceLock<usize> = OnceLock::new();
    *CAP.get_or_init(|| crate::util::env_usize("MDDCT_TRACE_BUF").unwrap_or(65536))
}

/// One thread's buffer, shared between the owning thread (push) and the
/// registry (drain).
struct ThreadBuf {
    tid: u32,
    name: String,
    events: Mutex<Vec<Event>>,
    dropped: AtomicU64,
}

/// Process-wide registry of every thread buffer ever created. Buffers
/// are tiny when unused; threads are bounded by the pool + service
/// worker counts, so the registry never needs eviction.
fn registry() -> &'static Mutex<Vec<Arc<ThreadBuf>>> {
    static REG: OnceLock<Mutex<Vec<Arc<ThreadBuf>>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static LOCAL: Arc<ThreadBuf> = {
        static NEXT_TID: AtomicU32 = AtomicU32::new(1);
        let buf = Arc::new(ThreadBuf {
            tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
            name: std::thread::current().name().unwrap_or("thread").to_string(),
            events: Mutex::new(Vec::new()),
            dropped: AtomicU64::new(0),
        });
        registry().lock().unwrap_or_else(|e| e.into_inner()).push(buf.clone());
        buf
    };
}

/// Push one event into the current thread's buffer, feeding the live
/// breakdown first when the event is a ctx-carrying span.
fn record(ev: Event) {
    if let (Some(ctx), EventKind::Span { dur_ns }) = (&ev.ctx, ev.kind) {
        super::agg::bump(ctx, ev.name, dur_ns);
    }
    LOCAL.with(|b| {
        let mut q = b.events.lock().unwrap_or_else(|e| e.into_inner());
        if q.len() < buf_cap() {
            q.push(ev);
        } else {
            b.dropped.fetch_add(1, Ordering::Relaxed);
        }
    });
}

/// RAII span: opens at [`SpanGuard::begin`], records on drop. When
/// tracing is disabled the guard is inert — no clock read, no ctx
/// lookup, nothing recorded.
pub struct SpanGuard {
    open: Option<(&'static str, Instant)>,
}

impl SpanGuard {
    /// Open a span named `name` (a no-op guard when tracing is off).
    #[inline]
    pub fn begin(name: &'static str) -> SpanGuard {
        if !enabled() {
            return SpanGuard { open: None };
        }
        SpanGuard { open: Some((name, Instant::now())) }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some((name, t0)) = self.open.take() {
            let dur_ns = t0.elapsed().as_nanos() as u64;
            record(Event {
                name,
                ctx: current_ctx(),
                t0_ns: since_epoch(t0),
                kind: EventKind::Span { dur_ns },
            });
        }
    }
}

/// Record a span over an interval the caller already timed (the fused
/// plans reuse their `forward_timed` instants, so the trace and the
/// returned [`crate::dct::StageTimes`] come from one clock capture).
#[inline]
pub fn stage_span(name: &'static str, t0: Instant, t1: Instant) {
    if !enabled() {
        return;
    }
    record(Event {
        name,
        ctx: current_ctx(),
        t0_ns: since_epoch(t0),
        kind: EventKind::Span { dur_ns: t1.duration_since(t0).as_nanos() as u64 },
    });
}

/// Record a span from `t0` to now (queue-wait style measurements where
/// the opening instant was captured on another thread).
#[inline]
pub fn span_since(name: &'static str, t0: Instant) {
    if !enabled() {
        return;
    }
    stage_span(name, t0, Instant::now());
}

/// Record a counter sample.
#[inline]
pub fn counter(name: &'static str, value: f64) {
    if !enabled() {
        return;
    }
    record(Event {
        name,
        ctx: current_ctx(),
        t0_ns: since_epoch(Instant::now()),
        kind: EventKind::Counter { value },
    });
}

/// Record a zero-duration marker.
#[inline]
pub fn instant_event(name: &'static str) {
    if !enabled() {
        return;
    }
    record(Event {
        name,
        ctx: current_ctx(),
        t0_ns: since_epoch(Instant::now()),
        kind: EventKind::Instant,
    });
}

/// One thread's drained events (see [`take_events`]).
pub struct ThreadEvents {
    /// Stable small integer id (trace `tid`).
    pub tid: u32,
    /// OS thread name at buffer creation.
    pub thread_name: String,
    /// The drained events, in record order.
    pub events: Vec<Event>,
}

/// Drain every thread's buffer (events recorded after the drain go into
/// the next export). Threads with empty buffers are skipped.
pub fn take_events() -> Vec<ThreadEvents> {
    let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    let mut out = Vec::new();
    for buf in reg.iter() {
        let events =
            std::mem::take(&mut *buf.events.lock().unwrap_or_else(|e| e.into_inner()));
        if !events.is_empty() {
            out.push(ThreadEvents {
                tid: buf.tid,
                thread_name: buf.name.clone(),
                events,
            });
        }
    }
    out
}

/// Total events dropped to the per-thread cap since process start.
pub fn dropped_events() -> u64 {
    let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    reg.iter().map(|b| b.dropped.load(Ordering::Relaxed)).sum()
}

/// Discard all buffered events (tests / long-running services that
/// exported elsewhere).
pub fn reset_events() {
    let _ = take_events();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_counters_and_instants_are_buffered_in_order() {
        let _g = super::super::test_guard();
        super::super::set_enabled(true);
        #[cfg(not(feature = "trace-off"))]
        {
            reset_events();
            {
                let _s = SpanGuard::begin("test.span.outer");
                counter("test.counter", 3.0);
                instant_event("test.instant");
            }
            let t0 = Instant::now();
            stage_span("test.span.stage", t0, Instant::now());
            let mine: Vec<Event> = take_events()
                .into_iter()
                .flat_map(|t| t.events)
                .filter(|e| e.name.starts_with("test."))
                .collect();
            assert_eq!(mine.len(), 4);
            // drop order: counter and instant record before the guard
            assert_eq!(mine[0].name, "test.counter");
            assert!(matches!(mine[0].kind, EventKind::Counter { value } if value == 3.0));
            assert_eq!(mine[1].name, "test.instant");
            assert!(matches!(mine[1].kind, EventKind::Instant));
            assert_eq!(mine[2].name, "test.span.outer");
            assert!(matches!(mine[2].kind, EventKind::Span { .. }));
            assert_eq!(mine[3].name, "test.span.stage");
            // the guard opened before the counter events inside it
            assert!(mine[2].t0_ns <= mine[0].t0_ns);
        }
        super::super::set_enabled(false);
    }

    #[test]
    fn disabled_tracing_buffers_nothing() {
        let _g = super::super::test_guard();
        super::super::set_enabled(false);
        reset_events();
        {
            let _s = SpanGuard::begin("test.off.span");
            counter("test.off.counter", 1.0);
            instant_event("test.off.instant");
        }
        let leaked: usize = take_events()
            .into_iter()
            .flat_map(|t| t.events)
            .filter(|e| e.name.starts_with("test.off."))
            .count();
        assert_eq!(leaked, 0);
    }

    #[test]
    fn events_from_other_threads_are_drained_with_their_tid() {
        let _g = super::super::test_guard();
        super::super::set_enabled(true);
        #[cfg(not(feature = "trace-off"))]
        {
            reset_events();
            std::thread::Builder::new()
                .name("obs-test-worker".into())
                .spawn(|| {
                    let _s = SpanGuard::begin("test.cross.span");
                })
                .unwrap()
                .join()
                .unwrap();
            let drained = take_events();
            let t = drained
                .iter()
                .find(|t| t.events.iter().any(|e| e.name == "test.cross.span"))
                .expect("worker events drained after thread exit");
            assert_eq!(t.thread_name, "obs-test-worker");
            assert!(t.tid > 0);
        }
        super::super::set_enabled(false);
    }
}
