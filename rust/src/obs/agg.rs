//! Live stage-breakdown aggregation: ctx → stage → (count, total time).
//!
//! Fed at record time by ctx-carrying spans (see `span::record`), so a
//! running service always has the current Fig.-6-style per-(op, shape)
//! breakdown available without replaying a trace. [`breakdown_json`] is
//! embedded into the coordinator's metrics snapshot under the
//! `_stage_breakdown` key; `benches/fig6_breakdown.rs` reads the same
//! table through [`stage_stats`], so bench and production numbers come
//! from one instrumentation path.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

use crate::util::json::Json;

#[derive(Debug, Default, Clone, Copy)]
struct StageAgg {
    count: u64,
    total_ns: u64,
}

/// ctx label → stage name → accumulated count/time. BTreeMaps keep the
/// JSON deterministic.
fn table() -> &'static Mutex<BTreeMap<String, BTreeMap<&'static str, StageAgg>>> {
    static TABLE: OnceLock<Mutex<BTreeMap<String, BTreeMap<&'static str, StageAgg>>>> =
        OnceLock::new();
    TABLE.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Add one closed span to the aggregation (called from the record path
/// for ctx-carrying spans only; never on the disabled path).
pub(crate) fn bump(ctx: &str, stage: &'static str, dur_ns: u64) {
    let mut t = table().lock().unwrap_or_else(|e| e.into_inner());
    // entry_ref has no stable equivalent without hashbrown; the ctx
    // string is a few dozen bytes and tracing is explicitly enabled, so
    // the clone is acceptable
    let e = t.entry(ctx.to_string()).or_default().entry(stage).or_default();
    e.count += 1;
    e.total_ns += dur_ns;
}

/// `(count, total_seconds)` accumulated for one `(ctx, stage)` cell, or
/// `None` if that cell never recorded.
pub fn stage_stats(ctx: &str, stage: &str) -> Option<(u64, f64)> {
    let t = table().lock().unwrap_or_else(|e| e.into_inner());
    let agg = t.get(ctx)?.get(stage)?;
    Some((agg.count, agg.total_ns as f64 * 1e-9))
}

/// The full breakdown as JSON: one object per ctx label, one object per
/// stage with `count` / `total_s` / `mean_s` fields. Empty (`{}`) when
/// tracing never recorded a ctx span.
pub fn breakdown_json() -> Json {
    let t = table().lock().unwrap_or_else(|e| e.into_inner());
    let mut root = BTreeMap::new();
    for (ctx, stages) in t.iter() {
        let mut by_stage = BTreeMap::new();
        for (stage, agg) in stages.iter() {
            let total_s = agg.total_ns as f64 * 1e-9;
            let mut o = BTreeMap::new();
            o.insert("count".to_string(), Json::Num(agg.count as f64));
            o.insert("total_s".to_string(), Json::Num(total_s));
            o.insert(
                "mean_s".to_string(),
                Json::Num(if agg.count > 0 { total_s / agg.count as f64 } else { 0.0 }),
            );
            by_stage.insert(stage.to_string(), Json::Obj(o));
        }
        root.insert(ctx.clone(), Json::Obj(by_stage));
    }
    Json::Obj(root)
}

/// Clear the aggregation (benches reset between shapes; tests isolate).
pub fn reset_breakdown() {
    table().lock().unwrap_or_else(|e| e.into_inner()).clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctx_spans_feed_the_breakdown() {
        let _g = super::super::test_guard();
        super::super::set_enabled(true);
        #[cfg(not(feature = "trace-off"))]
        {
            reset_breakdown();
            let ctx = super::super::op_ctx("aggtest", &[16, 16]).unwrap();
            let _c = super::super::with_ctx(Some(ctx));
            let t0 = std::time::Instant::now();
            super::super::stage_span("agg.stage_a", t0, t0 + std::time::Duration::from_micros(5));
            super::super::stage_span("agg.stage_a", t0, t0 + std::time::Duration::from_micros(7));
            super::super::stage_span("agg.stage_b", t0, t0 + std::time::Duration::from_micros(2));
            let (count, total) = stage_stats("aggtest/16x16", "agg.stage_a").unwrap();
            assert_eq!(count, 2);
            assert!((total - 12e-6).abs() < 1e-9, "total {total}");
            let bd = breakdown_json();
            let cell = bd.get("aggtest/16x16").unwrap().get("agg.stage_a").unwrap();
            assert_eq!(cell.get("count").unwrap().as_f64().unwrap(), 2.0);
            let mean = cell.get("mean_s").unwrap().as_f64().unwrap();
            assert!((mean - 6e-6).abs() < 1e-9, "mean {mean}");
            // spans closing after the ctx guard dropped never aggregate
            drop(_c);
            super::super::stage_span("agg.dropped", t0, t0 + std::time::Duration::from_micros(1));
            assert!(stage_stats("aggtest/16x16", "agg.dropped").is_none());
            reset_breakdown();
            assert!(stage_stats("aggtest/16x16", "agg.stage_a").is_none());
            super::super::reset_events();
        }
        super::super::set_enabled(false);
    }
}
