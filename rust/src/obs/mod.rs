//! Cross-layer tracing & stage profiling (the observability substrate).
//!
//! Every hot layer — the fused DCT plan stages, the 2D/3D RFFT
//! internals, the shared thread pool, and the coordinator pipeline —
//! emits lightweight *span* events through this module. On top of the
//! raw event stream sit two consumers:
//!
//! * a **live aggregation** ([`breakdown_json`]) keyed by an `(op,
//!   shape)` context label, yielding the paper's Fig.-6-style per-stage
//!   runtime breakdown for *any* run, not just the dedicated bench;
//! * a **Chrome trace-event export** ([`chrome_trace`] /
//!   [`write_chrome_trace`]) loadable in Perfetto / `chrome://tracing`,
//!   with one track per thread.
//!
//! # Overhead model
//!
//! Tracing is off by default and the disabled path is a single relaxed
//! atomic load per potential event — no clock reads, no allocation, no
//! locking. Three switches control it:
//!
//! * `MDDCT_TRACE=1` env var — resolved lazily on the first event site
//!   hit (any non-empty value other than `0` / `off` / `false` enables);
//! * [`set_enabled`] — programmatic override (the CLI `trace` subcommand
//!   and `ServiceConfig::trace` use this);
//! * the `trace-off` cargo feature — compiles [`enabled`] to a constant
//!   `false`, so the optimizer deletes every event site outright. CI
//!   asserts the *default* build's disabled path costs < 2% against a
//!   `trace-off` build (`benches/trace_overhead.rs`).
//!
//! When tracing is on, events go to per-thread buffers (a process-wide
//! registry of [`span::ThreadEvents`] sources, capped by
//! `MDDCT_TRACE_BUF` events per thread, default 65536; overflow is
//! counted, never reallocated), and ctx-carrying spans additionally bump
//! the breakdown aggregation at record time.
//!
//! # Context labels
//!
//! A span records the thread-local *context* active when it closes: an
//! `"op/N1xN2"` label installed by the service worker (see [`op_ctx`] /
//! [`with_ctx`]) so plan-internal stage spans attribute to the request
//! shape that caused them. Spans on pool workers (band jobs) carry no
//! ctx; the breakdown aggregates ctx-carrying spans only.

#![warn(missing_docs)]

mod agg;
mod chrome;
mod span;

pub use agg::{breakdown_json, reset_breakdown, stage_stats};
pub use chrome::{chrome_trace, write_chrome_trace};
pub use span::{
    counter, dropped_events, instant_event, reset_events, span_since, stage_span, take_events,
    Event, EventKind, SpanGuard, ThreadEvents,
};

use std::cell::RefCell;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Arc;

/// Tri-state enable flag: 0 = uninitialized (resolve `MDDCT_TRACE` on
/// first query), 1 = off, 2 = on.
static STATE: AtomicU8 = AtomicU8::new(0);

/// Whether tracing is currently enabled. This is the *only* check on
/// the disabled hot path: one relaxed atomic load (a constant `false`
/// under the `trace-off` feature, letting every event site fold away).
#[cfg(not(feature = "trace-off"))]
#[inline]
pub fn enabled() -> bool {
    match STATE.load(Ordering::Relaxed) {
        2 => true,
        1 => false,
        _ => resolve_from_env(),
    }
}

/// Compiled-out variant: tracing can never be enabled.
#[cfg(feature = "trace-off")]
#[inline(always)]
pub fn enabled() -> bool {
    false
}

#[cfg(not(feature = "trace-off"))]
#[cold]
fn resolve_from_env() -> bool {
    let on = std::env::var("MDDCT_TRACE")
        .map(|v| {
            let v = v.trim();
            !(v.is_empty()
                || v == "0"
                || v.eq_ignore_ascii_case("off")
                || v.eq_ignore_ascii_case("false"))
        })
        .unwrap_or(false);
    STATE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
    on
}

/// Force tracing on or off, overriding `MDDCT_TRACE`. A no-op in effect
/// under the `trace-off` feature (the flag flips but [`enabled`] stays
/// `false`).
pub fn set_enabled(on: bool) {
    STATE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
}

thread_local! {
    /// The `(op, shape)` label stage spans on this thread attribute to.
    static CTX: RefCell<Option<Arc<str>>> = const { RefCell::new(None) };
}

/// Build the `"op/N1xN2[xN3]"` context label for a request, or `None`
/// when tracing is disabled (so callers skip the allocation entirely).
pub fn op_ctx(op: &str, shape: &[usize]) -> Option<Arc<str>> {
    if !enabled() {
        return None;
    }
    let mut s = String::with_capacity(op.len() + 1 + 6 * shape.len());
    s.push_str(op);
    s.push('/');
    for (i, d) in shape.iter().enumerate() {
        if i > 0 {
            s.push('x');
        }
        s.push_str(&d.to_string());
    }
    Some(Arc::from(s.as_str()))
}

/// Install `ctx` as this thread's span context until the guard drops
/// (the previous context is restored — contexts nest).
pub fn with_ctx(ctx: Option<Arc<str>>) -> CtxGuard {
    let prev = CTX.with(|c| c.replace(ctx));
    CtxGuard { prev }
}

/// The label spans closing on this thread attribute to right now.
pub(crate) fn current_ctx() -> Option<Arc<str>> {
    CTX.with(|c| c.borrow().clone())
}

/// RAII guard restoring the previous span context on drop.
pub struct CtxGuard {
    prev: Option<Arc<str>>,
}

impl Drop for CtxGuard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        CTX.with(|c| *c.borrow_mut() = prev);
    }
}

/// Open a span for the rest of the enclosing scope:
/// `span!("svc.pack");` expands to a named [`SpanGuard`] binding. Costs
/// one atomic load when tracing is disabled.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        let _mddct_span_guard = $crate::obs::SpanGuard::begin($name);
    };
}

/// Serializes tests that flip the process-wide enable flag or drain the
/// process-wide buffers (unit tests run concurrently in one process).
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctx_labels_format_and_nest() {
        let _g = test_guard();
        set_enabled(true);
        #[cfg(not(feature = "trace-off"))]
        {
            let c = op_ctx("dct2d", &[512, 260]).unwrap();
            assert_eq!(&*c, "dct2d/512x260");
            let c3 = op_ctx("dct3d", &[4, 5, 6]).unwrap();
            assert_eq!(&*c3, "dct3d/4x5x6");
            let outer = with_ctx(Some(c.clone()));
            assert_eq!(current_ctx().as_deref(), Some("dct2d/512x260"));
            {
                let _inner = with_ctx(Some(c3));
                assert_eq!(current_ctx().as_deref(), Some("dct3d/4x5x6"));
            }
            assert_eq!(current_ctx().as_deref(), Some("dct2d/512x260"));
            drop(outer);
            assert_eq!(current_ctx(), None);
        }
        set_enabled(false);
        assert!(op_ctx("dct2d", &[8, 8]).is_none());
    }

    #[test]
    fn disabled_guard_records_nothing() {
        let _g = test_guard();
        set_enabled(false);
        let g = SpanGuard::begin("test.noop");
        drop(g);
        // no assertion on buffers here (other tests share them); the
        // guard simply must not panic and must cost no clock read
        assert!(!enabled());
    }
}
