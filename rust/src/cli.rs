//! Minimal CLI argument parser (clap substitute): subcommands with
//! `--flag value` / `--flag` options and positional arguments.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse `argv[1..]`: first bare word = subcommand, `--k v` or
    /// `--k=v` = option, `--k` before another flag/end = boolean.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(stripped.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(stripped.to_string(), "true".to_string());
                        }
                    }
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_bool(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> usize {
        self.flag(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Optional numeric flag with no default: `None` when absent or
    /// unparseable (e.g. `mddct serve --port 0` vs no `--port` at all).
    pub fn flag_opt_usize(&self, name: &str) -> Option<usize> {
        self.flag(name).and_then(|v| v.parse().ok())
    }

    pub fn flag_f64(&self, name: &str, default: f64) -> f64 {
        self.flag(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("transform --op dct2d --n1 512 --n2=1024 input.bin");
        assert_eq!(a.command.as_deref(), Some("transform"));
        assert_eq!(a.flag("op"), Some("dct2d"));
        assert_eq!(a.flag_usize("n1", 0), 512);
        assert_eq!(a.flag_usize("n2", 0), 1024);
        assert_eq!(a.positional, vec!["input.bin"]);
    }

    #[test]
    fn boolean_flags() {
        let a = parse("serve --pjrt --workers 4");
        assert!(a.flag_bool("pjrt"));
        assert_eq!(a.flag_usize("workers", 1), 4);
        assert!(!a.flag_bool("missing"));
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.flag_f64("eps", 2.5), 2.5);
        assert_eq!(a.flag_str("backend", "native"), "native");
    }

    #[test]
    fn trailing_bool_flag() {
        let a = parse("bench --quick");
        assert!(a.flag_bool("quick"));
    }

    #[test]
    fn optional_numeric_flag_distinguishes_absent_from_zero() {
        let a = parse("serve --port 0");
        assert_eq!(a.flag_opt_usize("port"), Some(0));
        assert_eq!(a.flag_opt_usize("missing"), None);
        let b = parse("serve --port nope");
        assert_eq!(b.flag_opt_usize("port"), None);
    }
}
