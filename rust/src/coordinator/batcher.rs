//! Dynamic batching: drain the request queue, group by (op, shape) plan
//! key, and emit batches bounded by `max_batch` / `max_wait`.
//!
//! The paper's transforms are stateless and shape-specialized, so
//! batching = amortizing plan lookup + improving cache locality by
//! running same-shape requests back to back on one worker (and, for the
//! multi-GPU discussion in §III-D, the unit of embarrassing
//! parallelism across devices — here across worker threads).
//!
//! Large requests take a solo fast path ([`BatchPolicy::solo_numel`]):
//! a transform big enough to band-shard gains nothing from co-batching
//! (its runtime dwarfs the plan lookup it would amortize), so holding
//! it back `max_wait` only adds latency — it is flushed to a worker
//! immediately and fans out across the shared pool from there.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

use super::request::{PlanKey, Request, Response};
use super::shard::{shard_min_numel, shard_min_numel_3d};
use crate::util::env_usize;

/// A queued request plus its reply channel and enqueue timestamp.
pub struct Pending {
    /// The validated request.
    pub request: Request,
    /// Where the worker sends the response.
    pub reply: Sender<Result<Response, String>>,
    /// When the request entered the service (latency accounting).
    pub enqueued: Instant,
}

/// A batch of same-key requests ready for one worker.
pub struct Batch {
    /// The shared (op, shape) plan key.
    pub key: PlanKey,
    /// The co-batched requests, submission order preserved.
    pub items: Vec<Pending>,
}

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// max requests per batch
    pub max_batch: usize,
    /// max time a request may wait for co-batching
    pub max_wait: Duration,
    /// payload size (elements) at which a request skips the co-batching
    /// wait and its key flushes immediately (the band-sharding fast
    /// path; defaults to the effective 2D force-shard gate,
    /// [`shard_min_numel`], env override included). Rank-3 requests
    /// additionally flush solo at their own gate
    /// ([`shard_min_numel_3d`]), so lowering the 3D gate never disables
    /// co-batching for unrelated 2D/1D traffic.
    pub solo_numel: usize,
    /// max total payload elements one batch may accumulate: a key
    /// flushes as soon as its queued requests reach this many elements,
    /// bounding the contiguous pack buffer the packed execution path
    /// builds (and the latency a full-but-small batch window can add).
    /// Defaults to [`max_batch_elems`] (`MDDCT_MAX_BATCH_ELEMS` env
    /// override included).
    pub max_batch_elems: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_micros(200),
            solo_numel: shard_min_numel(),
            max_batch_elems: max_batch_elems(),
        }
    }
}

/// Default cap on the total elements one batch accumulates before it
/// flushes: 4 Mi elements (32 MiB of f64 payload — enough for 65536
/// co-batched 8x8 blocks, small enough that the packed buffer and its
/// output stay comfortably in memory).
pub const DEFAULT_MAX_BATCH_ELEMS: usize = 4 << 20;

/// Effective batch-elements cap: `MDDCT_MAX_BATCH_ELEMS` env override,
/// else [`DEFAULT_MAX_BATCH_ELEMS`]. Resolved once per process.
pub fn max_batch_elems() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| env_usize("MDDCT_MAX_BATCH_ELEMS").unwrap_or(DEFAULT_MAX_BATCH_ELEMS))
}

/// Run the batching loop: drain `rx`, form batches, push to `tx`.
/// Returns when the request channel closes.
pub fn run_batcher(rx: Receiver<Pending>, tx: Sender<Batch>, policy: BatchPolicy) {
    let mut open: HashMap<PlanKey, Vec<Pending>> = HashMap::new();
    let mut oldest: Option<Instant> = None;
    loop {
        // Wait for work, bounded by the flush deadline of the oldest
        // request currently held back for co-batching.
        let timeout = match oldest {
            Some(t0) => policy
                .max_wait
                .checked_sub(t0.elapsed())
                .unwrap_or(Duration::ZERO),
            None => Duration::from_millis(50),
        };
        match rx.recv_timeout(timeout) {
            Ok(p) => {
                let key = p.request.key();
                let numel = p.request.data.len();
                // a request big enough to band-shard gains nothing from
                // co-batching: flush at the configured threshold, or at
                // the 3D force-shard gate for rank-3 ops
                let solo = numel >= policy.solo_numel
                    || (p.request.op.rank() == 3 && numel >= shard_min_numel_3d());
                if oldest.is_none() {
                    oldest = Some(p.enqueued);
                }
                let q = open.entry(key.clone()).or_default();
                q.push(p);
                // same-key requests share a shape, so the queue's total
                // payload is len * numel
                let full_elems = q.len().saturating_mul(numel) >= policy.max_batch_elems;
                if q.len() >= policy.max_batch || full_elems || solo {
                    let items = open.remove(&key).unwrap();
                    if tx.send(Batch { key, items }).is_err() {
                        return;
                    }
                    if open.is_empty() {
                        oldest = None;
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                // flush everything currently held
                for (key, items) in open.drain() {
                    if tx.send(Batch { key, items }).is_err() {
                        return;
                    }
                }
                oldest = None;
            }
            Err(RecvTimeoutError::Disconnected) => {
                for (key, items) in open.drain() {
                    let _ = tx.send(Batch { key, items });
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::TransformOp;
    use std::sync::mpsc::channel;

    fn pending(id: u64, shape: Vec<usize>) -> (Pending, Receiver<Result<Response, String>>) {
        let (tx, rx) = channel();
        let numel = shape.iter().product();
        (
            Pending {
                request: Request {
                    id,
                    op: TransformOp::Dct2d,
                    shape,
                    data: vec![0.0; numel],
                },
                reply: tx,
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    #[test]
    fn groups_same_key_and_flushes_on_timeout() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel();
        let policy =
            BatchPolicy { max_batch: 10, max_wait: Duration::from_millis(5), ..Default::default() };
        let h = std::thread::spawn(move || run_batcher(req_rx, batch_tx, policy));

        let (p1, _r1) = pending(1, vec![4, 4]);
        let (p2, _r2) = pending(2, vec![4, 4]);
        let (p3, _r3) = pending(3, vec![8, 8]);
        req_tx.send(p1).unwrap();
        req_tx.send(p2).unwrap();
        req_tx.send(p3).unwrap();

        let mut batches = vec![batch_rx.recv_timeout(Duration::from_secs(1)).unwrap()];
        batches.push(batch_rx.recv_timeout(Duration::from_secs(1)).unwrap());
        batches.sort_by_key(|b| b.items.len());
        assert_eq!(batches[0].items.len(), 1); // the 8x8 singleton
        assert_eq!(batches[1].items.len(), 2); // the two 4x4s co-batched
        drop(req_tx);
        h.join().unwrap();
    }

    #[test]
    fn emits_full_batch_immediately() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel();
        let policy =
            BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(10), ..Default::default() };
        let h = std::thread::spawn(move || run_batcher(req_rx, batch_tx, policy));
        let (p1, _r1) = pending(1, vec![4, 4]);
        let (p2, _r2) = pending(2, vec![4, 4]);
        req_tx.send(p1).unwrap();
        req_tx.send(p2).unwrap();
        // despite the huge max_wait, a full batch must flush at once
        let b = batch_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(b.items.len(), 2);
        drop(req_tx);
        h.join().unwrap();
    }

    #[test]
    fn large_request_skips_the_cobatching_wait() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel();
        // huge max_wait: only the solo fast path can flush early
        let policy = BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_secs(10),
            solo_numel: 256 * 256,
            ..Default::default()
        };
        let h = std::thread::spawn(move || run_batcher(req_rx, batch_tx, policy));
        let (big, _rb) = pending(1, vec![256, 256]);
        req_tx.send(big).unwrap();
        let b = batch_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(b.items.len(), 1);
        assert_eq!(b.key.shape, vec![256, 256]);
        drop(req_tx);
        h.join().unwrap();
    }

    #[test]
    fn large_3d_request_skips_the_cobatching_wait() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel();
        let policy = BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_secs(10),
            solo_numel: 256 * 256,
            ..Default::default()
        };
        let h = std::thread::spawn(move || run_batcher(req_rx, batch_tx, policy));
        // a shard-gate-sized 3D volume must flush immediately as well
        let (reply, _rx) = channel();
        let shape = vec![64usize, 64, 64];
        let numel: usize = shape.iter().product();
        req_tx
            .send(Pending {
                request: Request {
                    id: 1,
                    op: TransformOp::Dct3d,
                    shape: shape.clone(),
                    data: vec![0.0; numel],
                },
                reply,
                enqueued: Instant::now(),
            })
            .unwrap();
        let b = batch_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(b.items.len(), 1);
        assert_eq!(b.key.shape, shape);
        drop(req_tx);
        h.join().unwrap();
    }

    #[test]
    fn elems_cap_flushes_a_growing_batch() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel();
        // 4x4 = 16 elements per request; cap at 48 elements -> every
        // third same-key request must force a flush despite the huge
        // count cap and wait window
        let policy = BatchPolicy {
            max_batch: 1000,
            max_wait: Duration::from_secs(10),
            solo_numel: usize::MAX,
            max_batch_elems: 48,
        };
        let h = std::thread::spawn(move || run_batcher(req_rx, batch_tx, policy));
        for id in 0..6 {
            let (p, _r) = pending(id, vec![4, 4]);
            req_tx.send(p).unwrap();
        }
        let a = batch_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        let b = batch_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(a.items.len(), 3);
        assert_eq!(b.items.len(), 3);
        drop(req_tx);
        h.join().unwrap();
    }

    #[test]
    fn drains_on_disconnect() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel();
        let h = std::thread::spawn(move || {
            run_batcher(req_rx, batch_tx, BatchPolicy::default())
        });
        let (p1, _r1) = pending(1, vec![2, 2]);
        req_tx.send(p1).unwrap();
        drop(req_tx);
        let b = batch_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(b.items.len(), 1);
        h.join().unwrap();
    }
}
