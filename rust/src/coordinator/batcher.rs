//! Dynamic batching: drain the request queue, group by (op, shape) plan
//! key, and emit batches bounded by `max_batch` / `max_wait`.
//!
//! The paper's transforms are stateless and shape-specialized, so
//! batching = amortizing plan lookup + improving cache locality by
//! running same-shape requests back to back on one worker (and, for the
//! multi-GPU discussion in §III-D, the unit of embarrassing
//! parallelism across devices — here across worker threads).
//!
//! Large requests take a solo fast path ([`BatchPolicy::solo_numel`]):
//! a transform big enough to band-shard gains nothing from co-batching
//! (its runtime dwarfs the plan lookup it would amortize), so holding
//! it back `max_wait` only adds latency — it is flushed to a worker
//! immediately and fans out across the shared pool from there.
//!
//! The batcher is also the first line of the failure model: at dequeue
//! and at flush time it drops requests whose deadline already passed
//! (answering [`TransformError::DeadlineExceeded`]) and requests whose
//! client dropped the reply handle ([`Pending::cancelled`]) — neither
//! deserves pool work. The [`InflightBudget`] it shares with
//! `Service::submit` bounds the total queued payload, turning pool
//! saturation into explicit `Overloaded` shedding at the front door
//! instead of unbounded queue growth here.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

use super::metrics::Metrics;
use super::request::{PlanKey, Request, Response, TransformOp};
use super::shard::{shard_min_numel, shard_min_numel_3d};
use crate::util::env_usize;
use crate::util::error::TransformError;

/// A queued request plus its reply channel and enqueue timestamp.
pub struct Pending {
    /// The validated request.
    pub request: Request,
    /// Where the worker sends the response.
    pub reply: Sender<Result<Response, TransformError>>,
    /// When the request entered the service (latency accounting).
    pub enqueued: Instant,
    /// Set by the client `Handle`'s drop: nobody is waiting anymore, so
    /// the batcher/worker skips computing for this request entirely.
    pub cancelled: Arc<AtomicBool>,
}

impl Pending {
    /// Wrap a validated request with a fresh (un-cancelled) flag.
    pub fn new(request: Request, reply: Sender<Result<Response, TransformError>>) -> Pending {
        Pending {
            request,
            reply,
            enqueued: Instant::now(),
            cancelled: Arc::new(AtomicBool::new(false)),
        }
    }
}

/// Elems-weighted admission budget shared by `Service::submit` (acquire)
/// and the batcher/workers (release at every reply or drop): the total
/// payload in flight — queued, batching, or executing — never exceeds
/// `max_elems`, so a saturated pool sheds new arrivals with
/// [`TransformError::Overloaded`] instead of growing queues without
/// bound. Weighting by elements (like [`BatchPolicy::max_batch_elems`])
/// makes one huge volume and ten thousand 8x8 blocks count the same way
/// memory actually bills them.
#[derive(Debug)]
pub struct InflightBudget {
    max_elems: usize,
    current: AtomicUsize,
}

impl InflightBudget {
    /// Budget capped at `max_elems` total in-flight payload elements.
    pub fn new(max_elems: usize) -> InflightBudget {
        InflightBudget { max_elems, current: AtomicUsize::new(0) }
    }

    /// Effectively unbounded budget (admission control off).
    pub fn unlimited() -> InflightBudget {
        Self::new(usize::MAX)
    }

    /// Try to admit `elems` more payload; `false` = over budget (the
    /// optimistic add is rolled back, nothing is held).
    pub fn try_acquire(&self, elems: usize) -> bool {
        let prev = self.current.fetch_add(elems, Ordering::AcqRel);
        if prev.saturating_add(elems) > self.max_elems {
            self.current.fetch_sub(elems, Ordering::AcqRel);
            return false;
        }
        true
    }

    /// Return `elems` of budget (request answered or dropped).
    pub fn release(&self, elems: usize) {
        self.current.fetch_sub(elems, Ordering::AcqRel);
    }

    /// Payload elements currently admitted.
    pub fn in_use(&self) -> usize {
        self.current.load(Ordering::Acquire)
    }

    /// The configured cap.
    pub fn max_elems(&self) -> usize {
        self.max_elems
    }
}

/// Lifecycle gate applied wherever a request leaves a queue: pass live
/// requests through, and conclude dead ones — cancelled (client handle
/// dropped: skip computing, count `dropped_replies`) or expired
/// (deadline passed while queued: answer `DeadlineExceeded`, count
/// `expired_requests`). Dead requests release their budget here.
pub(crate) fn admit(p: Pending, metrics: &Metrics, budget: &InflightBudget) -> Option<Pending> {
    if p.cancelled.load(Ordering::Relaxed) {
        metrics.record_dropped_reply(&p.request.op.name());
        crate::obs::instant_event("svc.dropped_reply");
        budget.release(p.request.data.len());
        return None;
    }
    if p.request.expired() {
        metrics.record_expired(&p.request.op.name());
        crate::obs::instant_event("svc.expired");
        budget.release(p.request.data.len());
        let _ = p.reply.send(Err(TransformError::DeadlineExceeded));
        return None;
    }
    Some(p)
}

/// A batch of same-key requests ready for one worker.
pub struct Batch {
    /// The shared (op, shape) plan key.
    pub key: PlanKey,
    /// The co-batched requests, submission order preserved.
    pub items: Vec<Pending>,
}

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// max requests per batch
    pub max_batch: usize,
    /// max time a request may wait for co-batching
    pub max_wait: Duration,
    /// payload size (elements) at which a request skips the co-batching
    /// wait and its key flushes immediately (the band-sharding fast
    /// path; defaults to the effective 2D force-shard gate,
    /// [`shard_min_numel`], env override included). Rank-3 requests
    /// additionally flush solo at their own gate
    /// ([`shard_min_numel_3d`]), so lowering the 3D gate never disables
    /// co-batching for unrelated 2D/1D traffic.
    pub solo_numel: usize,
    /// max elements of batch buffers one batch may *materialize*: a key
    /// flushes as soon as its queued requests' footprint
    /// ([`batch_footprint`]) reaches this many elements, bounding the
    /// contiguous buffers the packed execution path builds (and the
    /// latency a full-but-small batch window can add). Ops on the
    /// zero-copy views path ([`TransformOp::supports_batch_views`])
    /// materialize only the packed output, so they count `queued *
    /// numel`; copy ops build an input pack too and count double.
    /// Defaults to [`max_batch_elems`] (`MDDCT_MAX_BATCH_ELEMS` env
    /// override included).
    pub max_batch_elems: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_micros(200),
            solo_numel: shard_min_numel(),
            max_batch_elems: max_batch_elems(),
        }
    }
}

/// Default cap on the total elements one batch accumulates before it
/// flushes: 4 Mi elements (32 MiB of f64 payload — enough for 65536
/// co-batched 8x8 blocks, small enough that the packed buffer and its
/// output stay comfortably in memory).
pub const DEFAULT_MAX_BATCH_ELEMS: usize = 4 << 20;

/// Effective batch-elements cap: `MDDCT_MAX_BATCH_ELEMS` env override,
/// else [`DEFAULT_MAX_BATCH_ELEMS`]. Resolved once per process.
pub fn max_batch_elems() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| env_usize("MDDCT_MAX_BATCH_ELEMS").unwrap_or(DEFAULT_MAX_BATCH_ELEMS))
}

/// Batch-buffer elements a packed execution of `queued` same-key
/// requests of `numel` elements each will materialize — the quantity
/// [`BatchPolicy::max_batch_elems`] caps. Ops whose plans accept
/// per-request views never build an input pack (the payloads are
/// borrowed in place), so only the packed output counts; every other
/// op materializes an input pack *and* an output, so its requests
/// count twice. Before this distinction the batcher charged both op
/// classes identically, halving the useful batch depth of the
/// zero-copy ops for no memory saved.
pub fn batch_footprint(op: TransformOp, queued: usize, numel: usize) -> usize {
    let payload = queued.saturating_mul(numel);
    if op.supports_batch_views() {
        payload
    } else {
        payload.saturating_mul(2)
    }
}

/// Run the batching loop: drain `rx`, form batches, push to `tx`.
/// Cancelled/expired requests are concluded at dequeue and again at
/// flush time (see [`admit`]) so stale work never reaches a worker.
/// Returns when the request channel closes.
pub fn run_batcher(
    rx: Receiver<Pending>,
    tx: Sender<Batch>,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
    budget: Arc<InflightBudget>,
) {
    let mut open: HashMap<PlanKey, Vec<Pending>> = HashMap::new();
    let mut oldest: Option<Instant> = None;
    // flush one key's accumulated requests, re-gating each (a deadline
    // may have passed during the co-batching wait)
    let flush = |key: PlanKey, items: Vec<Pending>| -> Result<(), ()> {
        let items: Vec<Pending> =
            items.into_iter().filter_map(|p| admit(p, &metrics, &budget)).collect();
        if items.is_empty() {
            return Ok(());
        }
        tx.send(Batch { key, items }).map_err(|_| ())
    };
    loop {
        // Wait for work, bounded by the flush deadline of the oldest
        // request currently held back for co-batching.
        let timeout = match oldest {
            Some(t0) => policy
                .max_wait
                .checked_sub(t0.elapsed())
                .unwrap_or(Duration::ZERO),
            None => Duration::from_millis(50),
        };
        match rx.recv_timeout(timeout) {
            Ok(p) => {
                let Some(p) = admit(p, &metrics, &budget) else {
                    continue;
                };
                let key = p.request.key();
                let numel = p.request.data.len();
                // a request big enough to band-shard gains nothing from
                // co-batching: flush at the configured threshold, or at
                // the 3D force-shard gate for rank-3 ops
                let solo = numel >= policy.solo_numel
                    || (p.request.op.rank() == 3 && numel >= shard_min_numel_3d());
                if oldest.is_none() {
                    oldest = Some(p.enqueued);
                }
                let q = open.entry(key.clone()).or_default();
                q.push(p);
                // same-key requests share a shape, so the queue's
                // materialized footprint is a closed form of its length
                let full_elems =
                    batch_footprint(key.op, q.len(), numel) >= policy.max_batch_elems;
                if q.len() >= policy.max_batch || full_elems || solo {
                    let items = open.remove(&key).unwrap();
                    if flush(key, items).is_err() {
                        return;
                    }
                    if open.is_empty() {
                        oldest = None;
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                // flush everything currently held
                for (key, items) in open.drain() {
                    if flush(key, items).is_err() {
                        return;
                    }
                }
                oldest = None;
            }
            Err(RecvTimeoutError::Disconnected) => {
                for (key, items) in open.drain() {
                    let _ = flush(key, items);
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::TransformOp;
    use std::sync::mpsc::channel;

    fn pending(
        id: u64,
        shape: Vec<usize>,
    ) -> (Pending, Receiver<Result<Response, TransformError>>) {
        let (tx, rx) = channel();
        let numel = shape.iter().product();
        (
            Pending::new(
                Request {
                    id,
                    op: TransformOp::Dct2d,
                    shape,
                    data: vec![0.0; numel],
                    deadline: None,
                },
                tx,
            ),
            rx,
        )
    }

    fn spawn_batcher(
        rx: Receiver<Pending>,
        tx: Sender<Batch>,
        policy: BatchPolicy,
    ) -> std::thread::JoinHandle<()> {
        let metrics = Arc::new(Metrics::new());
        let budget = Arc::new(InflightBudget::unlimited());
        std::thread::spawn(move || run_batcher(rx, tx, policy, metrics, budget))
    }

    #[test]
    fn groups_same_key_and_flushes_on_timeout() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel();
        let policy =
            BatchPolicy { max_batch: 10, max_wait: Duration::from_millis(5), ..Default::default() };
        let h = spawn_batcher(req_rx, batch_tx, policy);

        let (p1, _r1) = pending(1, vec![4, 4]);
        let (p2, _r2) = pending(2, vec![4, 4]);
        let (p3, _r3) = pending(3, vec![8, 8]);
        req_tx.send(p1).unwrap();
        req_tx.send(p2).unwrap();
        req_tx.send(p3).unwrap();

        let mut batches = vec![batch_rx.recv_timeout(Duration::from_secs(1)).unwrap()];
        batches.push(batch_rx.recv_timeout(Duration::from_secs(1)).unwrap());
        batches.sort_by_key(|b| b.items.len());
        assert_eq!(batches[0].items.len(), 1); // the 8x8 singleton
        assert_eq!(batches[1].items.len(), 2); // the two 4x4s co-batched
        drop(req_tx);
        h.join().unwrap();
    }

    #[test]
    fn emits_full_batch_immediately() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel();
        let policy =
            BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(10), ..Default::default() };
        let h = spawn_batcher(req_rx, batch_tx, policy);
        let (p1, _r1) = pending(1, vec![4, 4]);
        let (p2, _r2) = pending(2, vec![4, 4]);
        req_tx.send(p1).unwrap();
        req_tx.send(p2).unwrap();
        // despite the huge max_wait, a full batch must flush at once
        let b = batch_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(b.items.len(), 2);
        drop(req_tx);
        h.join().unwrap();
    }

    #[test]
    fn large_request_skips_the_cobatching_wait() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel();
        // huge max_wait: only the solo fast path can flush early
        let policy = BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_secs(10),
            solo_numel: 256 * 256,
            ..Default::default()
        };
        let h = spawn_batcher(req_rx, batch_tx, policy);
        let (big, _rb) = pending(1, vec![256, 256]);
        req_tx.send(big).unwrap();
        let b = batch_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(b.items.len(), 1);
        assert_eq!(b.key.shape, vec![256, 256]);
        drop(req_tx);
        h.join().unwrap();
    }

    #[test]
    fn large_3d_request_skips_the_cobatching_wait() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel();
        let policy = BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_secs(10),
            solo_numel: 256 * 256,
            ..Default::default()
        };
        let h = spawn_batcher(req_rx, batch_tx, policy);
        // a shard-gate-sized 3D volume must flush immediately as well
        let (reply, _rx) = channel();
        let shape = vec![64usize, 64, 64];
        let numel: usize = shape.iter().product();
        req_tx
            .send(Pending::new(
                Request {
                    id: 1,
                    op: TransformOp::Dct3d,
                    shape: shape.clone(),
                    data: vec![0.0; numel],
                    deadline: None,
                },
                reply,
            ))
            .unwrap();
        let b = batch_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(b.items.len(), 1);
        assert_eq!(b.key.shape, shape);
        drop(req_tx);
        h.join().unwrap();
    }

    #[test]
    fn elems_cap_flushes_a_growing_batch() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel();
        // 4x4 = 16 elements per request; cap at 48 elements -> every
        // third same-key request must force a flush despite the huge
        // count cap and wait window
        let policy = BatchPolicy {
            max_batch: 1000,
            max_wait: Duration::from_secs(10),
            solo_numel: usize::MAX,
            max_batch_elems: 48,
        };
        let h = spawn_batcher(req_rx, batch_tx, policy);
        for id in 0..6 {
            let (p, _r) = pending(id, vec![4, 4]);
            req_tx.send(p).unwrap();
        }
        let a = batch_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        let b = batch_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(a.items.len(), 3);
        assert_eq!(b.items.len(), 3);
        drop(req_tx);
        h.join().unwrap();
    }

    #[test]
    fn elems_cap_charges_copy_ops_double() {
        // footprint accounting: a zero-copy op (dct2d) materializes only
        // the packed output, a copy op (dst2d) an input pack too
        assert_eq!(batch_footprint(TransformOp::Dct2d, 4, 16), 64);
        assert_eq!(batch_footprint(TransformOp::Dst2d, 2, 16), 64);
        assert_eq!(batch_footprint(TransformOp::RcDct2d, 2, 16), 64);
        assert_eq!(batch_footprint(TransformOp::Dst2d, usize::MAX, 2), usize::MAX);

        // under one 64-element cap, dst2d must flush every 2 requests
        // while dct2d accumulates 4 — and the admission budget drains
        // back to zero either way
        let metrics = Arc::new(Metrics::new());
        let budget = Arc::new(InflightBudget::new(1 << 20));
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel();
        let policy = BatchPolicy {
            max_batch: 1000,
            max_wait: Duration::from_secs(10),
            solo_numel: usize::MAX,
            max_batch_elems: 64,
        };
        let h = {
            let (m, b) = (metrics.clone(), budget.clone());
            std::thread::spawn(move || run_batcher(req_rx, batch_tx, policy, m, b))
        };
        let mut replies = Vec::new();
        for (id, op) in
            [TransformOp::Dst2d; 4].into_iter().chain([TransformOp::Dct2d; 4]).enumerate()
        {
            let (tx, rx) = channel();
            replies.push(rx);
            let req = Request {
                id: id as u64,
                op,
                shape: vec![4, 4],
                data: vec![0.0; 16],
                deadline: None,
            };
            assert!(budget.try_acquire(req.data.len()));
            req_tx.send(Pending::new(req, tx)).unwrap();
        }
        let mut sizes: Vec<(TransformOp, usize)> = (0..3)
            .map(|_| {
                let b = batch_rx.recv_timeout(Duration::from_secs(1)).unwrap();
                for p in &b.items {
                    budget.release(p.request.data.len());
                }
                (b.key.op, b.items.len())
            })
            .collect();
        sizes.sort_by_key(|&(op, _)| op.name());
        assert_eq!(
            sizes,
            vec![
                (TransformOp::Dct2d, 4),
                (TransformOp::Dst2d, 2),
                (TransformOp::Dst2d, 2),
            ]
        );
        assert_eq!(budget.in_use(), 0, "admission budget must stay truthful");
        drop(req_tx);
        h.join().unwrap();
    }

    #[test]
    fn expired_requests_are_answered_not_forwarded() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel::<Batch>();
        let metrics = Arc::new(Metrics::new());
        let budget = Arc::new(InflightBudget::new(1000));
        let h = {
            let (m, b) = (metrics.clone(), budget.clone());
            std::thread::spawn(move || run_batcher(req_rx, batch_tx, BatchPolicy::default(), m, b))
        };
        let (mut p, r) = pending(1, vec![4, 4]);
        assert!(budget.try_acquire(p.request.data.len()));
        p.request.deadline = Some(Instant::now() - Duration::from_millis(1));
        req_tx.send(p).unwrap();
        // the batcher answers DeadlineExceeded itself and releases budget
        assert!(matches!(
            r.recv_timeout(Duration::from_secs(1)).unwrap(),
            Err(TransformError::DeadlineExceeded)
        ));
        assert!(batch_rx.recv_timeout(Duration::from_millis(100)).is_err());
        assert_eq!(budget.in_use(), 0);
        drop(req_tx);
        h.join().unwrap();
        let snap = metrics.snapshot();
        let expired =
            snap.get("dct2d").and_then(|d| d.get("expired_requests")).and_then(|v| v.as_f64());
        assert_eq!(expired, Some(1.0));
    }

    #[test]
    fn cancelled_requests_are_dropped_silently() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel::<Batch>();
        let metrics = Arc::new(Metrics::new());
        let budget = Arc::new(InflightBudget::unlimited());
        let h = {
            let (m, b) = (metrics.clone(), budget.clone());
            std::thread::spawn(move || run_batcher(req_rx, batch_tx, BatchPolicy::default(), m, b))
        };
        let (p, _r) = pending(1, vec![4, 4]);
        p.cancelled.store(true, Ordering::Relaxed);
        req_tx.send(p).unwrap();
        assert!(batch_rx.recv_timeout(Duration::from_millis(100)).is_err());
        drop(req_tx);
        h.join().unwrap();
        let snap = metrics.snapshot();
        let dropped =
            snap.get("dct2d").and_then(|d| d.get("dropped_replies")).and_then(|v| v.as_f64());
        assert_eq!(dropped, Some(1.0));
    }

    #[test]
    fn inflight_budget_admits_releases_and_sheds() {
        let b = InflightBudget::new(100);
        assert_eq!(b.max_elems(), 100);
        assert!(b.try_acquire(60));
        assert!(b.try_acquire(40));
        assert_eq!(b.in_use(), 100);
        // over budget: rejected AND rolled back (no phantom reservation)
        assert!(!b.try_acquire(1));
        assert_eq!(b.in_use(), 100);
        b.release(40);
        assert!(b.try_acquire(30));
        b.release(90);
        assert_eq!(b.in_use(), 0);
        // an oversized single request never fits a tiny budget...
        assert!(!InflightBudget::new(16).try_acquire(64));
        // ...but always fits the unlimited one
        assert!(InflightBudget::unlimited().try_acquire(usize::MAX / 2));
    }

    #[test]
    fn drains_on_disconnect() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel();
        let h = spawn_batcher(req_rx, batch_tx, BatchPolicy::default());
        let (p1, _r1) = pending(1, vec![2, 2]);
        req_tx.send(p1).unwrap();
        drop(req_tx);
        let b = batch_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(b.items.len(), 1);
        h.join().unwrap();
    }
}
