//! Dynamic batching: drain the request queue, group by (op, shape) plan
//! key, and emit batches bounded by `max_batch` / `max_wait`.
//!
//! The paper's transforms are stateless and shape-specialized, so
//! batching = amortizing plan lookup + improving cache locality by
//! running same-shape requests back to back on one worker (and, for the
//! multi-GPU discussion in §III-D, the unit of embarrassing
//! parallelism across devices — here across worker threads).
//!
//! Large requests take a solo fast path ([`BatchPolicy::solo_numel`]):
//! a transform big enough to band-shard gains nothing from co-batching
//! (its runtime dwarfs the plan lookup it would amortize), so holding
//! it back `max_wait` only adds latency — it is flushed to a worker
//! immediately and fans out across the shared pool from there.
//!
//! The batcher is also the first line of the failure model: at dequeue
//! and at flush time it drops requests whose deadline already passed
//! (answering [`TransformError::DeadlineExceeded`]) and requests whose
//! client dropped the reply handle ([`Pending::cancelled`]) — neither
//! deserves pool work. The [`InflightBudget`] it shares with
//! `Service::submit` bounds the total queued payload, turning pool
//! saturation into explicit `Overloaded` shedding at the front door
//! instead of unbounded queue growth here.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use super::metrics::Metrics;
use super::request::{PlanKey, Request, Response, TransformOp, DEFAULT_TENANT};
use super::shard::{shard_min_numel, shard_min_numel_3d};
use crate::util::env_usize;
use crate::util::error::TransformError;

/// A queued request plus its reply channel and enqueue timestamp.
pub struct Pending {
    /// The validated request.
    pub request: Request,
    /// Where the worker sends the response.
    pub reply: Sender<Result<Response, TransformError>>,
    /// When the request entered the service (latency accounting).
    pub enqueued: Instant,
    /// Set by the client `Handle`'s drop: nobody is waiting anymore, so
    /// the batcher/worker skips computing for this request entirely.
    pub cancelled: Arc<AtomicBool>,
}

impl Pending {
    /// Wrap a validated request with a fresh (un-cancelled) flag.
    pub fn new(request: Request, reply: Sender<Result<Response, TransformError>>) -> Pending {
        Pending {
            request,
            reply,
            enqueued: Instant::now(),
            cancelled: Arc::new(AtomicBool::new(false)),
        }
    }
}

/// How long a quiescent tenant stays *active* for fair-share purposes
/// after its last acquire attempt. A starved tenant becomes active on
/// its very first (even rejected) attempt, which immediately reserves
/// its share against further over-share borrowing by the hogs; once it
/// goes quiet for this long while holding nothing, the reservation
/// lapses and the budget is fully work-conserving again.
const TENANT_ACTIVE_WINDOW: Duration = Duration::from_millis(500);

/// Stale-entry sweep threshold for the per-tenant usage table: past
/// this many tracked tenants, inactive zero-usage entries are dropped
/// on the next acquire (bounds the table under hostile tenant churn).
const TENANT_TABLE_SWEEP: usize = 256;

/// Floor of the `Overloaded{retry_after}` hint (near-empty budget) ...
const RETRY_AFTER_BASE: Duration = Duration::from_millis(1);
/// ... and the extra backoff a fully occupied budget adds on top; the
/// hint grows linearly with occupancy between the two.
const RETRY_AFTER_FULL_EXTRA: Duration = Duration::from_millis(9);

/// Parse a `MDDCT_TENANT_QUOTA`-style weight spec: comma-separated
/// `tenant:weight` entries (e.g. `alice:3,bob:1`). Weights must be
/// finite and positive; tenants not listed get weight 1.0.
pub fn parse_tenant_quota(spec: &str) -> Result<Vec<(String, f64)>, String> {
    let mut out = Vec::new();
    for entry in spec.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let Some((name, weight)) = entry.split_once(':') else {
            return Err(format!("quota entry '{entry}': expected tenant:weight"));
        };
        let name = name.trim();
        if name.is_empty() {
            return Err(format!("quota entry '{entry}': empty tenant name"));
        }
        let w: f64 = weight
            .trim()
            .parse()
            .map_err(|_| format!("quota entry '{entry}': bad weight '{weight}'"))?;
        if !w.is_finite() || w <= 0.0 {
            return Err(format!("quota entry '{entry}': weight must be finite and > 0"));
        }
        out.push((name.to_string(), w));
    }
    Ok(out)
}

/// The `MDDCT_TENANT_QUOTA` weight table (empty = equal shares for
/// every tenant); a malformed spec is reported and ignored.
pub fn tenant_quota_from_env() -> Vec<(String, f64)> {
    std::env::var("MDDCT_TENANT_QUOTA")
        .ok()
        .and_then(|v| match parse_tenant_quota(&v) {
            Ok(q) => Some(q),
            Err(e) => {
                eprintln!("MDDCT_TENANT_QUOTA ignored: {e}");
                None
            }
        })
        .unwrap_or_default()
}

/// Per-tenant in-flight payload plus the instant of its last acquire
/// attempt (admitted or not), which is what keeps its share reserved.
#[derive(Debug)]
struct TenantUsage {
    elems: usize,
    last_seen: Instant,
}

#[derive(Debug, Default)]
struct TenantTable {
    /// Configured fair-share weights (`MDDCT_TENANT_QUOTA`); anyone not
    /// listed weighs 1.0.
    weights: HashMap<String, f64>,
    usage: HashMap<String, TenantUsage>,
}

impl TenantTable {
    fn weight(&self, tenant: &str) -> f64 {
        self.weights.get(tenant).copied().unwrap_or(1.0)
    }

    fn is_active(&self, u: &TenantUsage, now: Instant) -> bool {
        u.elems > 0 || now.duration_since(u.last_seen) <= TENANT_ACTIVE_WINDOW
    }
}

/// Elems-weighted admission budget shared by `Service::submit` (acquire)
/// and the batcher/workers (release at every reply or drop): the total
/// payload in flight — queued, batching, or executing — never exceeds
/// `max_elems`, so a saturated pool sheds new arrivals with
/// [`TransformError::Overloaded`] instead of growing queues without
/// bound. Weighting by elements (like [`BatchPolicy::max_batch_elems`])
/// makes one huge volume and ten thousand 8x8 blocks count the same way
/// memory actually bills them.
///
/// The budget is split between tenants as a *weighted fair share with
/// work-conserving borrowing*: a lone tenant may fill the whole budget,
/// but while other tenants are active (holding payload, or having
/// attempted an acquire within [`TENANT_ACTIVE_WINDOW`]) each tenant is
/// guaranteed `max_elems * w / Σw` of capacity — over-share borrowing is
/// admitted only into capacity no active tenant's unused share lays
/// claim to. Weights come from `MDDCT_TENANT_QUOTA`
/// ([`parse_tenant_quota`]); requests without a tenant share the
/// [`DEFAULT_TENANT`] bucket.
#[derive(Debug)]
pub struct InflightBudget {
    max_elems: usize,
    current: AtomicUsize,
    tenants: Mutex<TenantTable>,
}

impl InflightBudget {
    /// Budget capped at `max_elems` total in-flight payload elements,
    /// with tenant weights taken from `MDDCT_TENANT_QUOTA`.
    pub fn new(max_elems: usize) -> InflightBudget {
        Self::with_quota(max_elems, tenant_quota_from_env())
    }

    /// Budget capped at `max_elems` with an explicit tenant weight
    /// table (tenants not listed weigh 1.0).
    pub fn with_quota(max_elems: usize, quota: Vec<(String, f64)>) -> InflightBudget {
        InflightBudget {
            max_elems,
            current: AtomicUsize::new(0),
            tenants: Mutex::new(TenantTable {
                weights: quota.into_iter().collect(),
                usage: HashMap::new(),
            }),
        }
    }

    /// Effectively unbounded budget (admission control off — tenant
    /// accounting is skipped entirely, nothing can shed).
    pub fn unlimited() -> InflightBudget {
        Self::with_quota(usize::MAX, Vec::new())
    }

    fn table(&self) -> std::sync::MutexGuard<'_, TenantTable> {
        self.tenants.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Try to admit `elems` more payload for the [`DEFAULT_TENANT`];
    /// `false` = over budget (nothing is held).
    pub fn try_acquire(&self, elems: usize) -> bool {
        self.try_acquire_for(DEFAULT_TENANT, elems)
    }

    /// Try to admit `elems` more payload charged to `tenant`; `false`
    /// = over the global budget, or over this tenant's fair share while
    /// other active tenants' unused shares cover the remaining space.
    pub fn try_acquire_for(&self, tenant: &str, elems: usize) -> bool {
        if self.max_elems == usize::MAX {
            self.current.fetch_add(elems, Ordering::AcqRel);
            return true;
        }
        let now = Instant::now();
        let mut t = self.table();
        if t.usage.len() > TENANT_TABLE_SWEEP {
            t.usage.retain(|_, u| {
                u.elems > 0 || now.duration_since(u.last_seen) <= TENANT_ACTIVE_WINDOW
            });
        }
        // mark the applicant seen first: a rejected attempt still
        // reserves its share against the tenants crowding it out
        match t.usage.get_mut(tenant) {
            Some(u) => u.last_seen = now,
            None => {
                t.usage.insert(tenant.to_string(), TenantUsage { elems: 0, last_seen: now });
            }
        }
        let in_use = self.current.load(Ordering::Acquire);
        if in_use.saturating_add(elems) > self.max_elems {
            return false;
        }
        let wsum: f64 = t
            .usage
            .iter()
            .filter(|(_, u)| t.is_active(u, now))
            .map(|(name, _)| t.weight(name.as_str()))
            .sum();
        let share = |name: &str| self.max_elems as f64 * t.weight(name) / wsum;
        let usage_t = t.usage[tenant].elems;
        let admit = if (usage_t + elems) as f64 <= share(tenant) {
            true
        } else {
            // over-share borrowing: only into capacity not reserved by
            // another active tenant's unused share
            let reserved: f64 = t
                .usage
                .iter()
                .filter(|(name, u)| name.as_str() != tenant && t.is_active(u, now))
                .map(|(name, u)| (share(name.as_str()) - u.elems as f64).max(0.0))
                .sum();
            (in_use + elems) as f64 + reserved <= self.max_elems as f64
        };
        if admit {
            t.usage.get_mut(tenant).expect("marked seen above").elems += elems;
            self.current.fetch_add(elems, Ordering::AcqRel);
        }
        admit
    }

    /// Return `elems` of [`DEFAULT_TENANT`] budget.
    pub fn release(&self, elems: usize) {
        self.release_for(DEFAULT_TENANT, elems);
    }

    /// Return `elems` of `tenant`'s budget (request answered or
    /// dropped).
    pub fn release_for(&self, tenant: &str, elems: usize) {
        self.current.fetch_sub(elems, Ordering::AcqRel);
        if self.max_elems == usize::MAX {
            return;
        }
        if let Some(u) = self.table().usage.get_mut(tenant) {
            u.elems = u.elems.saturating_sub(elems);
        }
    }

    /// Payload elements currently admitted.
    pub fn in_use(&self) -> usize {
        self.current.load(Ordering::Acquire)
    }

    /// The configured cap.
    pub fn max_elems(&self) -> usize {
        self.max_elems
    }

    /// Backoff hint for an `Overloaded` shed, derived from current
    /// budget occupancy: [`RETRY_AFTER_BASE`] when the budget is empty
    /// (the request was simply too big), growing monotonically by
    /// [`RETRY_AFTER_FULL_EXTRA`] at full occupancy — clients back off
    /// proportionally to actual pressure.
    pub fn retry_after(&self) -> Duration {
        if self.max_elems == 0 || self.max_elems == usize::MAX {
            return RETRY_AFTER_BASE;
        }
        let occupancy = (self.in_use() as f64 / self.max_elems as f64).clamp(0.0, 1.0);
        RETRY_AFTER_BASE + RETRY_AFTER_FULL_EXTRA.mul_f64(occupancy)
    }
}

/// Lifecycle gate applied wherever a request leaves a queue: pass live
/// requests through, and conclude dead ones — cancelled (client handle
/// dropped: skip computing, count `dropped_replies`) or expired
/// (deadline passed while queued: answer `DeadlineExceeded`, count
/// `expired_requests`). Dead requests release their budget here.
pub(crate) fn admit(p: Pending, metrics: &Metrics, budget: &InflightBudget) -> Option<Pending> {
    if p.cancelled.load(Ordering::Relaxed) {
        metrics.record_dropped_reply(&p.request.op.name());
        crate::obs::instant_event("svc.dropped_reply");
        budget.release_for(p.request.tenant_name(), p.request.data.len());
        return None;
    }
    if p.request.expired() {
        metrics.record_expired(&p.request.op.name());
        if let Some(t) = &p.request.tenant {
            metrics.record_tenant_expired(t);
        }
        crate::obs::instant_event("svc.expired");
        budget.release_for(p.request.tenant_name(), p.request.data.len());
        let _ = p.reply.send(Err(TransformError::DeadlineExceeded));
        return None;
    }
    Some(p)
}

/// A batch of same-key requests ready for one worker.
pub struct Batch {
    /// The shared (op, shape) plan key.
    pub key: PlanKey,
    /// The co-batched requests, submission order preserved.
    pub items: Vec<Pending>,
}

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// max requests per batch
    pub max_batch: usize,
    /// max time a request may wait for co-batching
    pub max_wait: Duration,
    /// payload size (elements) at which a request skips the co-batching
    /// wait and its key flushes immediately (the band-sharding fast
    /// path; defaults to the effective 2D force-shard gate,
    /// [`shard_min_numel`], env override included). Rank-3 requests
    /// additionally flush solo at their own gate
    /// ([`shard_min_numel_3d`]), so lowering the 3D gate never disables
    /// co-batching for unrelated 2D/1D traffic.
    pub solo_numel: usize,
    /// max elements of batch buffers one batch may *materialize*: a key
    /// flushes as soon as its queued requests' footprint
    /// ([`batch_footprint`]) reaches this many elements, bounding the
    /// contiguous buffers the packed execution path builds (and the
    /// latency a full-but-small batch window can add). Ops on the
    /// zero-copy views path ([`TransformOp::supports_batch_views`])
    /// materialize only the packed output, so they count `queued *
    /// numel`; copy ops build an input pack too and count double.
    /// Defaults to [`max_batch_elems`] (`MDDCT_MAX_BATCH_ELEMS` env
    /// override included).
    pub max_batch_elems: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_micros(200),
            solo_numel: shard_min_numel(),
            max_batch_elems: max_batch_elems(),
        }
    }
}

/// Default cap on the total elements one batch accumulates before it
/// flushes: 4 Mi elements (32 MiB of f64 payload — enough for 65536
/// co-batched 8x8 blocks, small enough that the packed buffer and its
/// output stay comfortably in memory).
pub const DEFAULT_MAX_BATCH_ELEMS: usize = 4 << 20;

/// Effective batch-elements cap: `MDDCT_MAX_BATCH_ELEMS` env override,
/// else [`DEFAULT_MAX_BATCH_ELEMS`]. Resolved once per process.
pub fn max_batch_elems() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| env_usize("MDDCT_MAX_BATCH_ELEMS").unwrap_or(DEFAULT_MAX_BATCH_ELEMS))
}

/// Batch-buffer elements a packed execution of `queued` same-key
/// requests of `numel` elements each will materialize — the quantity
/// [`BatchPolicy::max_batch_elems`] caps. Ops whose plans accept
/// per-request views never build an input pack (the payloads are
/// borrowed in place), so only the packed output counts; every other
/// op materializes an input pack *and* an output, so its requests
/// count twice. Before this distinction the batcher charged both op
/// classes identically, halving the useful batch depth of the
/// zero-copy ops for no memory saved.
pub fn batch_footprint(op: TransformOp, queued: usize, numel: usize) -> usize {
    let payload = queued.saturating_mul(numel);
    if op.supports_batch_views() {
        payload
    } else {
        payload.saturating_mul(2)
    }
}

/// Flush order for a multi-key drain (co-batching window expired, or
/// the request channel closed): highest max-priority key first, then
/// earliest deadline (keys with no deadline last), then oldest
/// enqueued — so under pressure the urgent work reaches a worker while
/// the rest of the drain may still expire behind it.
fn drain_order(open: &HashMap<PlanKey, Vec<Pending>>) -> Vec<PlanKey> {
    let mut ranked: Vec<(u8, Option<Instant>, Instant, PlanKey)> = open
        .iter()
        .map(|(key, q)| {
            let priority = q.iter().map(|p| p.request.priority).max().unwrap_or(0);
            let deadline = q.iter().filter_map(|p| p.request.deadline).min();
            let enqueued = q.iter().map(|p| p.enqueued).min();
            (priority, deadline, enqueued.unwrap_or_else(Instant::now), key.clone())
        })
        .collect();
    ranked.sort_by(|a, b| {
        b.0.cmp(&a.0)
            .then_with(|| match (a.1, b.1) {
                (Some(x), Some(y)) => x.cmp(&y),
                (Some(_), None) => std::cmp::Ordering::Less,
                (None, Some(_)) => std::cmp::Ordering::Greater,
                (None, None) => std::cmp::Ordering::Equal,
            })
            .then_with(|| a.2.cmp(&b.2))
    });
    ranked.into_iter().map(|(.., key)| key).collect()
}

/// Run the batching loop: drain `rx`, form batches, push to `tx`.
/// Cancelled/expired requests are concluded at dequeue and again at
/// flush time (see [`admit`]) so stale work never reaches a worker.
/// Returns when the request channel closes.
pub fn run_batcher(
    rx: Receiver<Pending>,
    tx: Sender<Batch>,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
    budget: Arc<InflightBudget>,
) {
    let mut open: HashMap<PlanKey, Vec<Pending>> = HashMap::new();
    let mut oldest: Option<Instant> = None;
    // flush one key's accumulated requests, re-gating each (a deadline
    // may have passed during the co-batching wait)
    let flush = |key: PlanKey, items: Vec<Pending>| -> Result<(), ()> {
        let items: Vec<Pending> =
            items.into_iter().filter_map(|p| admit(p, &metrics, &budget)).collect();
        if items.is_empty() {
            return Ok(());
        }
        tx.send(Batch { key, items }).map_err(|_| ())
    };
    loop {
        // Wait for work, bounded by the flush deadline of the oldest
        // request currently held back for co-batching.
        let timeout = match oldest {
            Some(t0) => policy
                .max_wait
                .checked_sub(t0.elapsed())
                .unwrap_or(Duration::ZERO),
            None => Duration::from_millis(50),
        };
        match rx.recv_timeout(timeout) {
            Ok(p) => {
                let Some(p) = admit(p, &metrics, &budget) else {
                    continue;
                };
                let key = p.request.key();
                let numel = p.request.data.len();
                // a request big enough to band-shard gains nothing from
                // co-batching: flush at the configured threshold, or at
                // the 3D force-shard gate for rank-3 ops
                let solo = numel >= policy.solo_numel
                    || (p.request.op.rank() == 3 && numel >= shard_min_numel_3d());
                if oldest.is_none() {
                    oldest = Some(p.enqueued);
                }
                let q = open.entry(key.clone()).or_default();
                q.push(p);
                // same-key requests share a shape, so the queue's
                // materialized footprint is a closed form of its length
                let full_elems =
                    batch_footprint(key.op, q.len(), numel) >= policy.max_batch_elems;
                if q.len() >= policy.max_batch || full_elems || solo {
                    let items = open.remove(&key).unwrap();
                    if flush(key, items).is_err() {
                        return;
                    }
                    if open.is_empty() {
                        oldest = None;
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                // flush everything currently held, most urgent key first
                for key in drain_order(&open) {
                    let items = open.remove(&key).expect("drain_order keys come from open");
                    if flush(key, items).is_err() {
                        return;
                    }
                }
                oldest = None;
            }
            Err(RecvTimeoutError::Disconnected) => {
                for key in drain_order(&open) {
                    let items = open.remove(&key).expect("drain_order keys come from open");
                    let _ = flush(key, items);
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::TransformOp;
    use std::sync::mpsc::channel;

    fn pending(
        id: u64,
        shape: Vec<usize>,
    ) -> (Pending, Receiver<Result<Response, TransformError>>) {
        let (tx, rx) = channel();
        let numel = shape.iter().product();
        (
            Pending::new(
                Request {
                    id,
                    op: TransformOp::Dct2d,
                    shape,
                    data: vec![0.0; numel],
                    deadline: None,
                    tenant: None,
                    priority: 0,
                },
                tx,
            ),
            rx,
        )
    }

    fn spawn_batcher(
        rx: Receiver<Pending>,
        tx: Sender<Batch>,
        policy: BatchPolicy,
    ) -> std::thread::JoinHandle<()> {
        let metrics = Arc::new(Metrics::new());
        let budget = Arc::new(InflightBudget::unlimited());
        std::thread::spawn(move || run_batcher(rx, tx, policy, metrics, budget))
    }

    #[test]
    fn groups_same_key_and_flushes_on_timeout() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel();
        let policy =
            BatchPolicy { max_batch: 10, max_wait: Duration::from_millis(5), ..Default::default() };
        let h = spawn_batcher(req_rx, batch_tx, policy);

        let (p1, _r1) = pending(1, vec![4, 4]);
        let (p2, _r2) = pending(2, vec![4, 4]);
        let (p3, _r3) = pending(3, vec![8, 8]);
        req_tx.send(p1).unwrap();
        req_tx.send(p2).unwrap();
        req_tx.send(p3).unwrap();

        let mut batches = vec![batch_rx.recv_timeout(Duration::from_secs(1)).unwrap()];
        batches.push(batch_rx.recv_timeout(Duration::from_secs(1)).unwrap());
        batches.sort_by_key(|b| b.items.len());
        assert_eq!(batches[0].items.len(), 1); // the 8x8 singleton
        assert_eq!(batches[1].items.len(), 2); // the two 4x4s co-batched
        drop(req_tx);
        h.join().unwrap();
    }

    #[test]
    fn emits_full_batch_immediately() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel();
        let policy =
            BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(10), ..Default::default() };
        let h = spawn_batcher(req_rx, batch_tx, policy);
        let (p1, _r1) = pending(1, vec![4, 4]);
        let (p2, _r2) = pending(2, vec![4, 4]);
        req_tx.send(p1).unwrap();
        req_tx.send(p2).unwrap();
        // despite the huge max_wait, a full batch must flush at once
        let b = batch_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(b.items.len(), 2);
        drop(req_tx);
        h.join().unwrap();
    }

    #[test]
    fn large_request_skips_the_cobatching_wait() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel();
        // huge max_wait: only the solo fast path can flush early
        let policy = BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_secs(10),
            solo_numel: 256 * 256,
            ..Default::default()
        };
        let h = spawn_batcher(req_rx, batch_tx, policy);
        let (big, _rb) = pending(1, vec![256, 256]);
        req_tx.send(big).unwrap();
        let b = batch_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(b.items.len(), 1);
        assert_eq!(b.key.shape, vec![256, 256]);
        drop(req_tx);
        h.join().unwrap();
    }

    #[test]
    fn large_3d_request_skips_the_cobatching_wait() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel();
        let policy = BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_secs(10),
            solo_numel: 256 * 256,
            ..Default::default()
        };
        let h = spawn_batcher(req_rx, batch_tx, policy);
        // a shard-gate-sized 3D volume must flush immediately as well
        let (reply, _rx) = channel();
        let shape = vec![64usize, 64, 64];
        let numel: usize = shape.iter().product();
        req_tx
            .send(Pending::new(
                Request {
                    id: 1,
                    op: TransformOp::Dct3d,
                    shape: shape.clone(),
                    data: vec![0.0; numel],
                    deadline: None,
                    tenant: None,
                    priority: 0,
                },
                reply,
            ))
            .unwrap();
        let b = batch_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(b.items.len(), 1);
        assert_eq!(b.key.shape, shape);
        drop(req_tx);
        h.join().unwrap();
    }

    #[test]
    fn elems_cap_flushes_a_growing_batch() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel();
        // 4x4 = 16 elements per request; cap at 48 elements -> every
        // third same-key request must force a flush despite the huge
        // count cap and wait window
        let policy = BatchPolicy {
            max_batch: 1000,
            max_wait: Duration::from_secs(10),
            solo_numel: usize::MAX,
            max_batch_elems: 48,
        };
        let h = spawn_batcher(req_rx, batch_tx, policy);
        for id in 0..6 {
            let (p, _r) = pending(id, vec![4, 4]);
            req_tx.send(p).unwrap();
        }
        let a = batch_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        let b = batch_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(a.items.len(), 3);
        assert_eq!(b.items.len(), 3);
        drop(req_tx);
        h.join().unwrap();
    }

    #[test]
    fn elems_cap_charges_copy_ops_double() {
        // footprint accounting: a zero-copy op (dct2d) materializes only
        // the packed output, a copy op (dst2d) an input pack too
        assert_eq!(batch_footprint(TransformOp::Dct2d, 4, 16), 64);
        assert_eq!(batch_footprint(TransformOp::Dst2d, 2, 16), 64);
        assert_eq!(batch_footprint(TransformOp::RcDct2d, 2, 16), 64);
        assert_eq!(batch_footprint(TransformOp::Dst2d, usize::MAX, 2), usize::MAX);

        // under one 64-element cap, dst2d must flush every 2 requests
        // while dct2d accumulates 4 — and the admission budget drains
        // back to zero either way
        let metrics = Arc::new(Metrics::new());
        let budget = Arc::new(InflightBudget::new(1 << 20));
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel();
        let policy = BatchPolicy {
            max_batch: 1000,
            max_wait: Duration::from_secs(10),
            solo_numel: usize::MAX,
            max_batch_elems: 64,
        };
        let h = {
            let (m, b) = (metrics.clone(), budget.clone());
            std::thread::spawn(move || run_batcher(req_rx, batch_tx, policy, m, b))
        };
        let mut replies = Vec::new();
        for (id, op) in
            [TransformOp::Dst2d; 4].into_iter().chain([TransformOp::Dct2d; 4]).enumerate()
        {
            let (tx, rx) = channel();
            replies.push(rx);
            let req = Request {
                id: id as u64,
                op,
                shape: vec![4, 4],
                data: vec![0.0; 16],
                deadline: None,
                tenant: None,
                priority: 0,
            };
            assert!(budget.try_acquire(req.data.len()));
            req_tx.send(Pending::new(req, tx)).unwrap();
        }
        let mut sizes: Vec<(TransformOp, usize)> = (0..3)
            .map(|_| {
                let b = batch_rx.recv_timeout(Duration::from_secs(1)).unwrap();
                for p in &b.items {
                    budget.release(p.request.data.len());
                }
                (b.key.op, b.items.len())
            })
            .collect();
        sizes.sort_by_key(|&(op, _)| op.name());
        assert_eq!(
            sizes,
            vec![
                (TransformOp::Dct2d, 4),
                (TransformOp::Dst2d, 2),
                (TransformOp::Dst2d, 2),
            ]
        );
        assert_eq!(budget.in_use(), 0, "admission budget must stay truthful");
        drop(req_tx);
        h.join().unwrap();
    }

    #[test]
    fn expired_requests_are_answered_not_forwarded() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel::<Batch>();
        let metrics = Arc::new(Metrics::new());
        let budget = Arc::new(InflightBudget::new(1000));
        let h = {
            let (m, b) = (metrics.clone(), budget.clone());
            std::thread::spawn(move || run_batcher(req_rx, batch_tx, BatchPolicy::default(), m, b))
        };
        let (mut p, r) = pending(1, vec![4, 4]);
        assert!(budget.try_acquire(p.request.data.len()));
        p.request.deadline = Some(Instant::now() - Duration::from_millis(1));
        req_tx.send(p).unwrap();
        // the batcher answers DeadlineExceeded itself and releases budget
        assert!(matches!(
            r.recv_timeout(Duration::from_secs(1)).unwrap(),
            Err(TransformError::DeadlineExceeded)
        ));
        assert!(batch_rx.recv_timeout(Duration::from_millis(100)).is_err());
        assert_eq!(budget.in_use(), 0);
        drop(req_tx);
        h.join().unwrap();
        let snap = metrics.snapshot();
        let expired =
            snap.get("dct2d").and_then(|d| d.get("expired_requests")).and_then(|v| v.as_f64());
        assert_eq!(expired, Some(1.0));
    }

    #[test]
    fn cancelled_requests_are_dropped_silently() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel::<Batch>();
        let metrics = Arc::new(Metrics::new());
        let budget = Arc::new(InflightBudget::unlimited());
        let h = {
            let (m, b) = (metrics.clone(), budget.clone());
            std::thread::spawn(move || run_batcher(req_rx, batch_tx, BatchPolicy::default(), m, b))
        };
        let (p, _r) = pending(1, vec![4, 4]);
        p.cancelled.store(true, Ordering::Relaxed);
        req_tx.send(p).unwrap();
        assert!(batch_rx.recv_timeout(Duration::from_millis(100)).is_err());
        drop(req_tx);
        h.join().unwrap();
        let snap = metrics.snapshot();
        let dropped =
            snap.get("dct2d").and_then(|d| d.get("dropped_replies")).and_then(|v| v.as_f64());
        assert_eq!(dropped, Some(1.0));
    }

    #[test]
    fn inflight_budget_admits_releases_and_sheds() {
        let b = InflightBudget::new(100);
        assert_eq!(b.max_elems(), 100);
        assert!(b.try_acquire(60));
        assert!(b.try_acquire(40));
        assert_eq!(b.in_use(), 100);
        // over budget: rejected AND rolled back (no phantom reservation)
        assert!(!b.try_acquire(1));
        assert_eq!(b.in_use(), 100);
        b.release(40);
        assert!(b.try_acquire(30));
        b.release(90);
        assert_eq!(b.in_use(), 0);
        // an oversized single request never fits a tiny budget...
        assert!(!InflightBudget::new(16).try_acquire(64));
        // ...but always fits the unlimited one
        assert!(InflightBudget::unlimited().try_acquire(usize::MAX / 2));
    }

    #[test]
    fn tenant_fair_share_guards_a_starved_tenant() {
        // equal weights, budget 100: a lone tenant is work-conserving
        // and may fill everything ...
        let b = InflightBudget::with_quota(100, Vec::new());
        assert!(b.try_acquire_for("hog", 100));
        // ... a newly arriving tenant is rejected right now (budget
        // full) but its attempt reserves its share
        assert!(!b.try_acquire_for("victim", 10));
        // the hog can no longer borrow past its 50-share ...
        b.release_for("hog", 10);
        assert!(!b.try_acquire_for("hog", 10));
        // ... while the victim gets in as capacity frees up
        assert!(b.try_acquire_for("victim", 10));
        assert_eq!(b.in_use(), 100);
        // under its share the victim keeps being admitted even though
        // the hog would love the space back
        b.release_for("hog", 40);
        assert!(b.try_acquire_for("victim", 40));
        assert!(!b.try_acquire_for("hog", 10));
        b.release_for("victim", 50);
        b.release_for("hog", 50);
        assert_eq!(b.in_use(), 0);
    }

    #[test]
    fn tenant_quota_weights_skew_the_shares() {
        // 3:1 weights over 100 elements -> shares 75 / 25
        let quota = vec![("alice".to_string(), 3.0), ("bob".to_string(), 1.0)];
        let b = InflightBudget::with_quota(100, quota);
        // both active: alice under 75 admits, bob under 25 admits
        assert!(b.try_acquire_for("alice", 70));
        assert!(b.try_acquire_for("bob", 20));
        // bob over his 25-share cannot borrow: alice's unused 5 is
        // reserved and the request would exceed it
        assert!(!b.try_acquire_for("bob", 10));
        // alice still fits under her share
        assert!(b.try_acquire_for("alice", 5));
        assert_eq!(b.in_use(), 95);
    }

    #[test]
    fn tenant_quota_spec_parses_and_rejects() {
        let q = parse_tenant_quota("alice:3, bob:0.5").unwrap();
        assert_eq!(q, vec![("alice".to_string(), 3.0), ("bob".to_string(), 0.5)]);
        assert!(parse_tenant_quota("").unwrap().is_empty());
        assert!(parse_tenant_quota(" , ").unwrap().is_empty());
        assert!(parse_tenant_quota("alice").is_err()); // no weight
        assert!(parse_tenant_quota(":3").is_err()); // no name
        assert!(parse_tenant_quota("alice:zero").is_err()); // bad weight
        assert!(parse_tenant_quota("alice:0").is_err()); // not > 0
        assert!(parse_tenant_quota("alice:-1").is_err());
        assert!(parse_tenant_quota("alice:inf").is_err());
    }

    #[test]
    fn retry_after_hint_grows_with_occupancy() {
        let b = InflightBudget::with_quota(100, Vec::new());
        let empty = b.retry_after();
        assert!(b.try_acquire(50));
        let half = b.retry_after();
        assert!(b.try_acquire(50));
        let full = b.retry_after();
        assert!(empty < half, "{empty:?} !< {half:?}");
        assert!(half < full, "{half:?} !< {full:?}");
        // degenerate budgets still give a sane floor
        assert_eq!(InflightBudget::unlimited().retry_after(), empty);
    }

    #[test]
    fn drain_flushes_urgent_keys_first() {
        let mut open: HashMap<PlanKey, Vec<Pending>> = HashMap::new();
        let mut put = |shape: Vec<usize>, priority: u8, deadline: Option<Instant>| {
            let (mut p, _r) = pending(shape[0] as u64, shape);
            p.request.priority = priority;
            p.request.deadline = deadline;
            open.entry(p.request.key()).or_default().push(p);
        };
        let soon = Instant::now() + Duration::from_millis(5);
        let later = Instant::now() + Duration::from_secs(5);
        put(vec![2, 2], 0, None);
        put(vec![4, 4], 0, Some(later));
        put(vec![8, 8], 0, Some(soon));
        put(vec![16, 16], 3, None);
        let order: Vec<Vec<usize>> =
            drain_order(&open).into_iter().map(|k| k.shape).collect();
        // priority 3 first, then by deadline, deadline-free last
        assert_eq!(order, vec![vec![16, 16], vec![8, 8], vec![4, 4], vec![2, 2]]);
    }

    #[test]
    fn drains_on_disconnect() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = channel();
        let h = spawn_batcher(req_rx, batch_tx, BatchPolicy::default());
        let (p1, _r1) = pending(1, vec![2, 2]);
        req_tx.send(p1).unwrap();
        drop(req_tx);
        let b = batch_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(b.items.len(), 1);
        h.join().unwrap();
    }
}
