//! Band-sharded transform execution: split one large transform into
//! row-band work items that the shared process pool interleaves with
//! every other request's work.
//!
//! # Why
//!
//! The coordinator's workers execute whole transforms: without
//! sharding, one huge request occupies a single worker for its full
//! duration while the remaining pool capacity idles (or, worse, the
//! request serializes behind small ones). Popovici et al.'s flexible
//! parallel MD-DFT framework and Korotkevich's SMP-parallel 2D FFT
//! subroutines both arrive at the same standard answer: slab/band
//! decomposition across workers. Here the band unit already exists —
//! the tiled transpose (`parallel::transpose`) splits its output into
//! contiguous row bands — so sharding reuses that boundary instead of
//! inventing a new one.
//!
//! # Shard lifecycle
//!
//! ```text
//!   request (op, shape, data)
//!        │  decide(): fused-2D/3D op and numel >= its rank's gate
//!        │            (SHARD_MIN_NUMEL / SHARD_MIN_NUMEL_3D)
//!        │            ? service policy : Auto
//!        ▼
//!   plan built with ShardPolicy      (PlanCache::get, per (op, shape))
//!        │
//!        ▼
//!   stage 1  row-band shards      [band 0][band 1] ... [band B-1]
//!        │      each band = one pool work item (row FFTs + reorders)
//!        ▼
//!   barrier  tiled transpose      (parallel::transpose_into — the
//!        │                         natural shard boundary: bands meet,
//!        │                         panels are re-dealt tile-aligned)
//!        ▼
//!   stage 2  column-panel shards  [panel 0][panel 1] ... (contiguous
//!        │                         rows of the transposed matrix)
//!        ▼
//!   stage 3  pre/post permutation shards (DCT reorder rows / §III-B
//!        │                         postprocess row pairs)
//!        ▼
//!   response (output, backend, latency, bands recorded in metrics)
//! ```
//!
//! 3D requests run the same lifecycle with the dim-0 **i-slab** as the
//! band unit: the n3-axis row-FFT batch bands over all `n1*n2` rows,
//! the n2-axis column FFTs are slab-local work items, and the n1-axis
//! stage re-bands over the `n2*h3` transposed rows across the
//! dim-1/dim-2 barrier (see [`crate::fft::Rfft3Plan`] and
//! [`crate::dct::Dct3d::with_shards`]).
//!
//! Because every shard is just a scoped job on the one process-wide
//! pool, a sharded large request and a batch of small requests
//! co-schedule automatically: the pool drains work items from both, and
//! the batcher additionally fast-tracks huge requests
//! ([`crate::coordinator::batcher::BatchPolicy::solo_numel`]) so they
//! never wait on co-batching they cannot benefit from.
//!
//! # Correctness contract
//!
//! Sharded execution must match [`crate::parallel::ExecPolicy::Serial`] output to
//! <= 1e-10 for every shard count; in practice the banded stage kernels
//! are arithmetic-order-preserving per element, so outputs are
//! bit-equal for a fixed FFT kernel (see `tests/prop_parallel.rs`).

use std::ops::Range;
use std::sync::OnceLock;

use crate::parallel::{band_spans, policy::env_usize, slab_spans};
pub use crate::parallel::ShardPolicy;

use super::request::PlanKey;

/// Element count below which the service never force-shards a 2D (or
/// 1D) request: a 256x256 fused DCT runs in well under a millisecond,
/// so splitting it into bands buys nothing and costs fork/join traffic.
/// Requests at or above the threshold inherit the service's configured
/// policy. Override per process with `MDDCT_SHARD_MIN_NUMEL`.
pub const SHARD_MIN_NUMEL: usize = 256 * 256;

/// Element count below which the service never force-shards a 3D
/// request. 3D requests carry more work per leading-dimension row (a
/// whole n2 x n3 slab), so the gate sits higher than the 2D one: a
/// 64^3 fused DCT is the smallest volume where slab fan-out beats its
/// fork/join cost. Override per process with
/// `MDDCT_SHARD_MIN_NUMEL_3D`.
pub const SHARD_MIN_NUMEL_3D: usize = 64 * 64 * 64;

/// Effective 2D force-shard gate: `MDDCT_SHARD_MIN_NUMEL` env override,
/// else [`SHARD_MIN_NUMEL`]. Resolved once per process.
pub fn shard_min_numel() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| env_usize("MDDCT_SHARD_MIN_NUMEL").unwrap_or(SHARD_MIN_NUMEL))
}

/// Effective 3D force-shard gate: `MDDCT_SHARD_MIN_NUMEL_3D` env
/// override, else [`SHARD_MIN_NUMEL_3D`]. Resolved once per process.
pub fn shard_min_numel_3d() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| env_usize("MDDCT_SHARD_MIN_NUMEL_3D").unwrap_or(SHARD_MIN_NUMEL_3D))
}

/// Effective shard policy for one request: small requests and ops
/// whose plans do not honor explicit band counts (see
/// [`super::request::TransformOp::supports_sharding`]) stay on
/// [`ShardPolicy::Auto`] — their plans fan out only as far as their
/// [`crate::parallel::ExecPolicy`] allows; large fused-2D/3D requests
/// get the service's configured policy. The numel gate is
/// per-dimensionality: rank-3 ops gate on [`shard_min_numel_3d`],
/// everything else on [`shard_min_numel`].
pub fn decide(service: ShardPolicy, key: &PlanKey) -> ShardPolicy {
    let numel: usize = key.shape.iter().product();
    let gate = if key.op.rank() == 3 {
        shard_min_numel_3d()
    } else {
        shard_min_numel()
    };
    if !key.op.supports_sharding() || numel < gate {
        ShardPolicy::Auto
    } else {
        service
    }
}

/// Band count a request is *explicitly sharded* into, without
/// materializing the spans: the work items a non-`Auto` effective
/// policy pins, or 1 otherwise. `Auto` deliberately reports 1 — its
/// exec-lane fan-out is lane parallelism, not sharding, and ops outside
/// the fused-2D/3D families never shard at all — so a default-config service
/// does not report every large request as sharded. Equals
/// `ShardPlan::for_request(..).band_count()`; recorded in the service
/// metrics per batch.
pub fn band_count_for(key: &PlanKey, service: ShardPolicy) -> usize {
    match decide(service, key) {
        ShardPolicy::Auto => 1,
        policy => {
            let rows = key.shape.first().copied().unwrap_or(1);
            // explicit variants ignore the exec lane count by design
            policy.bands(rows, 1)
        }
    }
}

/// The explicit stage-1 band decomposition of one request: which
/// contiguous runs of leading-dimension rows (dim-0 slabs for rank-3
/// requests) become independent pool
/// work items. A single band covering all rows means the request is not
/// explicitly sharded (it may still fan out over exec lanes inside its
/// plan). Used by the service for metrics (band counts per op) and
/// exposed for introspection; the identical split is what an
/// explicitly-sharded plan's banded stages execute (see
/// [`crate::parallel::band_spans`]).
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// Effective policy after [`decide`].
    pub policy: ShardPolicy,
    /// Leading-dimension row count being banded.
    pub rows: usize,
    /// Contiguous row spans, one per shard work item.
    pub bands: Vec<Range<usize>>,
}

impl ShardPlan {
    /// Band decomposition for `key` under the service's shard policy
    /// (rank-3 keys decompose into dim-0 slab spans — the same math,
    /// via [`crate::parallel::slab_spans`]).
    pub fn for_request(key: &PlanKey, service: ShardPolicy) -> ShardPlan {
        let rows = key.shape.first().copied().unwrap_or(1);
        let n = band_count_for(key, service);
        let bands = if key.op.rank() == 3 {
            slab_spans(rows, n)
        } else {
            band_spans(rows, n)
        };
        ShardPlan { policy: decide(service, key), rows, bands }
    }

    /// Number of shard work items (1 = unsharded).
    pub fn band_count(&self) -> usize {
        self.bands.len().max(1)
    }

    /// Whether this request actually splits into multiple work items.
    pub fn is_sharded(&self) -> bool {
        self.bands.len() > 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::plan_cache::PlanCache;
    use crate::coordinator::request::TransformOp;
    use crate::dct::direct::dct2d_direct;
    use crate::parallel::ExecPolicy;
    use crate::util::prop::check_close;
    use crate::util::rng::Rng;

    fn key(op: TransformOp, shape: &[usize]) -> PlanKey {
        PlanKey::new(op, shape.to_vec())
    }

    #[test]
    fn decide_leaves_small_and_unsupported_requests_unsharded() {
        let policy = ShardPolicy::MaxShards(8);
        // rank 1: never force-sharded
        assert_eq!(decide(policy, &key(TransformOp::Idct1d, &[1 << 20])), ShardPolicy::Auto);
        // ops whose plans ignore explicit band counts: no sharding claim
        assert_eq!(
            decide(policy, &key(TransformOp::RcDct2d, &[1024, 1024])),
            ShardPolicy::Auto
        );
        // small 2D: below SHARD_MIN_NUMEL
        assert_eq!(decide(policy, &key(TransformOp::Dct2d, &[64, 64])), ShardPolicy::Auto);
        // small 3D: below the (higher) SHARD_MIN_NUMEL_3D gate, even
        // though its numel would pass the 2D gate
        assert_eq!(
            decide(policy, &key(TransformOp::Dct3d, &[32, 64, 64])),
            ShardPolicy::Auto
        );
        // large fused 2D: service policy applies
        assert_eq!(decide(policy, &key(TransformOp::Dct2d, &[1024, 1024])), policy);
        assert_eq!(decide(policy, &key(TransformOp::Idst2d, &[1024, 1024])), policy);
        // large fused 3D: the slab-sharded plans take the policy too
        assert_eq!(decide(policy, &key(TransformOp::Dct3d, &[128, 128, 128])), policy);
        assert_eq!(decide(policy, &key(TransformOp::Idct3d, &[128, 128, 128])), policy);
        // exactly at the per-rank thresholds counts as large
        assert_eq!(decide(policy, &key(TransformOp::Dct2d, &[256, 256])), policy);
        assert_eq!(decide(policy, &key(TransformOp::Dct3d, &[64, 64, 64])), policy);
    }

    #[test]
    fn per_rank_gates_default_to_their_consts() {
        // skip the assertions when the env knobs are set (the OnceLock
        // pins whatever the process saw first); the default path is
        // what this test pins down
        if std::env::var("MDDCT_SHARD_MIN_NUMEL").is_err() {
            assert_eq!(shard_min_numel(), SHARD_MIN_NUMEL);
        }
        if std::env::var("MDDCT_SHARD_MIN_NUMEL_3D").is_err() {
            assert_eq!(shard_min_numel_3d(), SHARD_MIN_NUMEL_3D);
        }
    }

    #[test]
    fn shard_plan_bands_cover_all_rows() {
        let k = key(TransformOp::Dct2d, &[1000, 1024]);
        let plan = ShardPlan::for_request(&k, ShardPolicy::MaxShards(7));
        assert_eq!(plan.band_count(), 7);
        assert!(plan.is_sharded());
        let mut next = 0;
        for b in &plan.bands {
            assert_eq!(b.start, next);
            next = b.end;
        }
        assert_eq!(next, 1000);
        // non-divisible split stays near-equal
        let lens: Vec<usize> = plan.bands.iter().map(|b| b.len()).collect();
        let (lo, hi) = (*lens.iter().min().unwrap(), *lens.iter().max().unwrap());
        assert!(hi - lo <= 1, "{lens:?}");
    }

    #[test]
    fn band_count_for_agrees_with_shard_plan() {
        for (op, shape, policy) in [
            (TransformOp::Dct2d, vec![1000usize, 1024], ShardPolicy::MaxShards(7)),
            (TransformOp::Dct2d, vec![32, 32], ShardPolicy::MaxShards(8)),
            (TransformOp::Idst2d, vec![512, 512], ShardPolicy::MinRowsPerShard(100)),
            (TransformOp::RcDct2d, vec![1024, 1024], ShardPolicy::MaxShards(4)),
            (TransformOp::Dct3d, vec![128, 64, 64], ShardPolicy::MaxShards(6)),
            (TransformOp::Idct3d, vec![32, 32, 32], ShardPolicy::MaxShards(6)),
        ] {
            let k = key(op, &shape);
            assert_eq!(
                band_count_for(&k, policy),
                ShardPlan::for_request(&k, policy).band_count(),
                "{op:?} {shape:?}"
            );
        }
    }

    #[test]
    fn auto_lane_fanout_is_not_reported_as_sharding() {
        // default-config service (shard = Auto): a large request may fan
        // out over exec lanes inside its plan, but the shard-facing count
        // must stay 1 — lane parallelism is not the shard feature engaging
        let big = key(TransformOp::Dct2d, &[1024, 1024]);
        assert_eq!(band_count_for(&big, ShardPolicy::Auto), 1);
        assert!(!ShardPlan::for_request(&big, ShardPolicy::Auto).is_sharded());
        // ops that never shard report 1 even under an explicit policy
        let oned = key(TransformOp::Idct1d, &[1 << 20]);
        assert_eq!(band_count_for(&oned, ShardPolicy::MaxShards(6)), 1);
        assert!(!ShardPlan::for_request(&oned, ShardPolicy::MaxShards(6)).is_sharded());
        // an explicit policy on a large fused-2D request does report bands
        assert_eq!(band_count_for(&big, ShardPolicy::MaxShards(6)), 6);
        // ...but not when decide() filters it out (small request)
        let small = key(TransformOp::Dct2d, &[32, 32]);
        assert_eq!(band_count_for(&small, ShardPolicy::MaxShards(6)), 1);
    }

    #[test]
    fn shard_plan_is_single_band_for_small_requests() {
        let k = key(TransformOp::Dct2d, &[32, 32]);
        let plan = ShardPlan::for_request(&k, ShardPolicy::MaxShards(8));
        assert_eq!(plan.band_count(), 1);
        assert!(!plan.is_sharded());
    }

    #[test]
    fn sharded_plan_cache_output_matches_serial() {
        // end to end through the plan cache: a sharded cache and a serial
        // cache must agree to <= 1e-10 (the ISSUE's correctness contract)
        let mut rng = Rng::new(95);
        let (n1, n2) = (256usize, 257usize); // above threshold, odd n2
        let x = rng.normal_vec(n1 * n2);
        let serial = PlanCache::with_policy(ExecPolicy::Serial);
        let sharded =
            PlanCache::with_policies(ExecPolicy::Serial, ShardPolicy::MaxShards(5));
        let k = key(TransformOp::Dct2d, &[n1, n2]);
        let a = serial.get(&k).execute(&x);
        let b = sharded.get(&k).execute(&x);
        check_close(&b, &a, 1e-10).unwrap();
        // sanity against the direct oracle on a band boundary subcase
        let small = rng.normal_vec(8 * 8);
        let ks = key(TransformOp::Dct2d, &[8, 8]);
        check_close(&sharded.get(&ks).execute(&small), &dct2d_direct(&small, 8, 8), 1e-9)
            .unwrap();
    }

    #[test]
    fn sharded_3d_plan_cache_output_matches_serial() {
        // the 3D analogue of the 2D cache test: a >= gate volume through
        // a slab-sharded cache must match the serial cache to <= 1e-10
        let mut rng = Rng::new(96);
        let (n1, n2, n3) = (65usize, 64usize, 64usize); // above the 3D gate, odd slabs
        let x = rng.normal_vec(n1 * n2 * n3);
        let serial = PlanCache::with_policy(ExecPolicy::Serial);
        let sharded = PlanCache::with_policies(ExecPolicy::Serial, ShardPolicy::MaxShards(5));
        for op in [TransformOp::Dct3d, TransformOp::Idct3d] {
            let k = key(op, &[n1, n2, n3]);
            let a = serial.get(&k).execute(&x);
            let b = sharded.get(&k).execute(&x);
            check_close(&b, &a, 1e-10).unwrap_or_else(|e| panic!("{op:?}: {e}"));
        }
    }
}
