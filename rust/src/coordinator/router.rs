//! Backend routing: decide, per (op, shape), whether a batch runs on the
//! native Rust transform library or on an AOT PJRT artifact, and execute
//! it there.
//!
//! The PJRT backend is reached through [`PjrtHandle`] (a channel to the
//! single-owner PJRT thread); routing decisions use the parsed manifest
//! directly, so no PJRT call is needed to decide.

use std::collections::BTreeSet;

use super::plan_cache::PlanCache;
use super::request::PlanKey;
use crate::parallel::ExecPolicy;
use crate::runtime::{Manifest, PjrtHandle};

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendPolicy {
    /// Always the native Rust library (works for every size).
    #[default]
    NativeOnly,
    /// Use a PJRT artifact when the manifest has this exact (op, shape);
    /// fall back to native otherwise.
    PreferPjrt,
}

/// Where a batch was routed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    Native,
    Pjrt,
}

impl Route {
    pub fn label(self) -> &'static str {
        match self {
            Route::Native => "native",
            Route::Pjrt => "pjrt",
        }
    }
}

/// The router owns the native plan cache and (optionally) the PJRT handle.
pub struct Router {
    pub policy: BackendPolicy,
    pub plans: PlanCache,
    pjrt: Option<PjrtHandle>,
    artifact_names: BTreeSet<String>,
}

impl Router {
    pub fn native_only() -> Router {
        Self::native_only_with(ExecPolicy::Auto)
    }

    /// Native backend whose plans carry an explicit execution policy
    /// (the service threads its `ServiceConfig::exec` through here, so
    /// workers fan transform stages onto the shared pool rather than
    /// spawning their own threads).
    pub fn native_only_with(exec: ExecPolicy) -> Router {
        Router {
            policy: BackendPolicy::NativeOnly,
            plans: PlanCache::with_policy(exec),
            pjrt: None,
            artifact_names: BTreeSet::new(),
        }
    }

    /// Prefer PJRT artifacts listed in `manifest`, executing via `handle`.
    pub fn with_pjrt(handle: PjrtHandle, manifest: &Manifest) -> Router {
        Router {
            policy: BackendPolicy::PreferPjrt,
            plans: PlanCache::new(),
            pjrt: Some(handle),
            artifact_names: manifest.entries.keys().cloned().collect(),
        }
    }

    /// Make `exec` the policy of this router's native plans. Called by
    /// `Service::start` so `ServiceConfig::exec` stays authoritative no
    /// matter how the router was built; swaps the plan cache only when
    /// the policy actually differs (plans are built lazily, so this is
    /// cheap at startup).
    pub(crate) fn set_exec_policy(&mut self, exec: ExecPolicy) {
        if self.plans.policy() != exec {
            self.plans = PlanCache::with_policy(exec);
        }
    }

    /// Decide the route for a key (PJRT only when an artifact exists).
    pub fn route(&self, key: &PlanKey) -> Route {
        if self.policy == BackendPolicy::PreferPjrt && self.pjrt.is_some() {
            if let Some(name) = key.op.artifact_name(&key.shape) {
                if self.artifact_names.contains(&name) {
                    return Route::Pjrt;
                }
            }
        }
        Route::Native
    }

    /// Execute one payload for a key on the routed backend.
    pub fn execute(&self, key: &PlanKey, data: &[f64]) -> Result<(Vec<f64>, Route), String> {
        match self.route(key) {
            Route::Native => {
                let plan = self.plans.get(key);
                Ok((plan.execute(data), Route::Native))
            }
            Route::Pjrt => {
                let handle = self.pjrt.as_ref().expect("route checked");
                let name = key.op.artifact_name(&key.shape).expect("route checked");
                let outs = handle
                    .run(&name, vec![data.to_vec()])
                    .map_err(|e| format!("{e:#}"))?;
                Ok((outs.into_iter().next().unwrap_or_default(), Route::Pjrt))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::TransformOp;
    use crate::dct::direct::dct2d_direct;
    use crate::util::prop::check_close;
    use crate::util::rng::Rng;

    #[test]
    fn native_only_routes_native() {
        let r = Router::native_only();
        let key = PlanKey { op: TransformOp::Dct2d, shape: vec![8, 8] };
        assert_eq!(r.route(&key), Route::Native);
        let mut rng = Rng::new(90);
        let x = rng.normal_vec(64);
        let (y, route) = r.execute(&key, &x).unwrap();
        assert_eq!(route, Route::Native);
        check_close(&y, &dct2d_direct(&x, 8, 8), 1e-9).unwrap();
    }

    #[test]
    fn ops_without_artifacts_stay_native() {
        let r = Router::native_only();
        let key = PlanKey { op: TransformOp::Dct3d, shape: vec![4, 4, 4] };
        assert_eq!(r.route(&key), Route::Native);
    }

    #[test]
    fn prefer_pjrt_falls_back_when_shape_missing() {
        // manifest without the requested shape -> native route
        let manifest = Manifest::parse(
            r#"{"version":1,"dtype":"f32","entries":[
                {"name":"dct2d_64x64","pipeline":"dct2d","file":"x.hlo.txt",
                 "inputs":[{"shape":[64,64],"dtype":"f32"}],
                 "outputs":[{"shape":[64,64],"dtype":"f32"}]}]}"#,
            std::path::PathBuf::from("/nonexistent"),
        )
        .unwrap();
        let handle = PjrtHandle::spawn("/nonexistent");
        let r = Router::with_pjrt(handle, &manifest);
        let hit = PlanKey { op: TransformOp::Dct2d, shape: vec![64, 64] };
        let miss = PlanKey { op: TransformOp::Dct2d, shape: vec![63, 63] };
        assert_eq!(r.route(&hit), Route::Pjrt);
        assert_eq!(r.route(&miss), Route::Native);
    }
}
