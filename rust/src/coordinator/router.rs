//! Backend routing: decide, per (op, shape), whether a batch runs on the
//! native Rust transform library or on an AOT PJRT artifact, and execute
//! it there.
//!
//! The PJRT backend is reached through [`PjrtHandle`] (a channel to the
//! single-owner PJRT thread); routing decisions use the parsed manifest
//! directly, so no PJRT call is needed to decide.

use std::collections::BTreeSet;

use super::plan_cache::PlanCache;
use super::request::PlanKey;
use super::shard::ShardPlan;
use crate::parallel::{ExecPolicy, ShardPolicy};
use crate::runtime::{Manifest, PjrtHandle};
use crate::util::error::TransformError;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendPolicy {
    /// Always the native Rust library (works for every size).
    #[default]
    NativeOnly,
    /// Use a PJRT artifact when the manifest has this exact (op, shape);
    /// fall back to native otherwise.
    PreferPjrt,
}

/// Where a batch was routed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// The native Rust transform library.
    Native,
    /// An AOT-compiled PJRT artifact.
    Pjrt,
}

impl Route {
    /// Stable label for metrics / responses.
    pub fn label(self) -> &'static str {
        match self {
            Route::Native => "native",
            Route::Pjrt => "pjrt",
        }
    }
}

/// The router owns the native plan cache and (optionally) the PJRT handle.
pub struct Router {
    /// Backend selection policy.
    pub policy: BackendPolicy,
    /// Native plan cache (carries the exec + shard policies).
    pub plans: PlanCache,
    pjrt: Option<PjrtHandle>,
    artifact_names: BTreeSet<String>,
}

impl Router {
    /// Native backend with the default (`Auto`) exec + shard policies.
    pub fn native_only() -> Router {
        Self::native_only_with(ExecPolicy::Auto)
    }

    /// Native backend whose plans carry an explicit execution policy
    /// (the service threads its `ServiceConfig::exec` through here, so
    /// workers fan transform stages onto the shared pool rather than
    /// spawning their own threads).
    pub fn native_only_with(exec: ExecPolicy) -> Router {
        Router {
            policy: BackendPolicy::NativeOnly,
            plans: PlanCache::with_policy(exec),
            pjrt: None,
            artifact_names: BTreeSet::new(),
        }
    }

    /// Prefer PJRT artifacts listed in `manifest`, executing via `handle`.
    pub fn with_pjrt(handle: PjrtHandle, manifest: &Manifest) -> Router {
        Router {
            policy: BackendPolicy::PreferPjrt,
            plans: PlanCache::new(),
            pjrt: Some(handle),
            artifact_names: manifest.entries.keys().cloned().collect(),
        }
    }

    /// Make `exec` the policy of this router's native plans. Called by
    /// `Service::start` so `ServiceConfig::exec` stays authoritative no
    /// matter how the router was built; swaps the plan cache only when
    /// the policy actually differs (plans are built lazily, so this is
    /// cheap at startup).
    pub(crate) fn set_exec_policy(&mut self, exec: ExecPolicy) {
        if self.plans.policy() != exec {
            self.plans = PlanCache::with_policies(exec, self.plans.shard_policy());
        }
    }

    /// Make `shard` the band-shard policy of this router's native plans
    /// (applied per request through [`super::shard::decide`]). Called by
    /// `Service::start` so `ServiceConfig::shard` stays authoritative;
    /// like [`Router::set_exec_policy`] it swaps the lazily-built plan
    /// cache only when the policy actually differs.
    pub(crate) fn set_shard_policy(&mut self, shard: ShardPolicy) {
        if self.plans.shard_policy() != shard {
            self.plans = PlanCache::with_policies(self.plans.policy(), shard);
        }
    }

    /// The explicit band decomposition a native request for `key` will
    /// execute with (a single band = not explicitly sharded; the plan
    /// may still fan out over exec lanes).
    pub fn shard_plan(&self, key: &PlanKey) -> ShardPlan {
        ShardPlan::for_request(key, self.plans.shard_policy())
    }

    /// Band work items an *explicit* shard policy pins for `key`
    /// (1 = unsharded or plain `Auto` lane fan-out), allocation-free —
    /// the service's worker loop records this in metrics per batch.
    pub fn shard_bands(&self, key: &PlanKey) -> usize {
        super::shard::band_count_for(key, self.plans.shard_policy())
    }

    /// Decide the route for a key (PJRT only when an artifact exists).
    pub fn route(&self, key: &PlanKey) -> Route {
        if self.policy == BackendPolicy::PreferPjrt && self.pjrt.is_some() {
            if let Some(name) = key.op.artifact_name(&key.shape) {
                if self.artifact_names.contains(&name) {
                    return Route::Pjrt;
                }
            }
        }
        Route::Native
    }

    /// Execute a packed batch of `batch` same-key payloads on the
    /// native backend (the only backend with a batched path — the
    /// worker loop falls back to per-item [`Router::execute`] for PJRT
    /// routes). Output is packed in input order.
    pub fn execute_batch(
        &self,
        key: &PlanKey,
        packed: &[f64],
        batch: usize,
    ) -> Result<(Vec<f64>, Route), TransformError> {
        let plan = self.plans.get(key);
        Ok((plan.execute_batch(packed, batch), Route::Native))
    }

    /// Execute a batch of same-key payloads given one borrowed view per
    /// request, with no packed input copy (the coordinator's zero-copy
    /// packed path; see
    /// [`super::plan_cache::NativePlan::execute_batch_views`]). Native
    /// only, like [`Router::execute_batch`]. Output is packed in view
    /// order, bit-identical to the copy path.
    pub fn execute_batch_views(
        &self,
        key: &PlanKey,
        views: &[&[f64]],
    ) -> Result<(Vec<f64>, Route), TransformError> {
        let plan = self.plans.get(key);
        Ok((plan.execute_batch_views(views), Route::Native))
    }

    /// Execute one payload for a key on the routed backend.
    pub fn execute(
        &self,
        key: &PlanKey,
        data: &[f64],
    ) -> Result<(Vec<f64>, Route), TransformError> {
        match self.route(key) {
            Route::Native => {
                let plan = self.plans.get(key);
                Ok((plan.execute(data), Route::Native))
            }
            Route::Pjrt => {
                let handle = self.pjrt.as_ref().expect("route checked");
                let name = key.op.artifact_name(&key.shape).expect("route checked");
                let outs = handle
                    .run(&name, vec![data.to_vec()])
                    .map_err(|e| TransformError::ExecutionFailed(format!("{e:#}")))?;
                Ok((outs.into_iter().next().unwrap_or_default(), Route::Pjrt))
            }
        }
    }

    /// Execute one payload on the degraded serial plan — the one-shot
    /// retry target after a primary native execution fails, and the
    /// serving path for quarantined keys. Never routes to PJRT; panics
    /// propagate to the caller's `catch_unwind`.
    pub fn execute_degraded(&self, key: &PlanKey, data: &[f64]) -> Vec<f64> {
        self.plans.degraded(key).execute(data)
    }

    /// Quarantine a key's primary native plan (see
    /// [`PlanCache::quarantine`]).
    pub fn quarantine(&self, key: &PlanKey) {
        self.plans.quarantine(key);
    }

    /// Whether a key's primary native plan is quarantined.
    pub fn is_quarantined(&self, key: &PlanKey) -> bool {
        self.plans.is_quarantined(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::TransformOp;
    use crate::dct::direct::dct2d_direct;
    use crate::util::prop::check_close;
    use crate::util::rng::Rng;

    #[test]
    fn native_only_routes_native() {
        let r = Router::native_only();
        let key = PlanKey::new(TransformOp::Dct2d, vec![8, 8]);
        assert_eq!(r.route(&key), Route::Native);
        let mut rng = Rng::new(90);
        let x = rng.normal_vec(64);
        let (y, route) = r.execute(&key, &x).unwrap();
        assert_eq!(route, Route::Native);
        check_close(&y, &dct2d_direct(&x, 8, 8), 1e-9).unwrap();
    }

    #[test]
    fn shard_policy_threads_into_band_plans() {
        use crate::parallel::{ExecPolicy, ShardPolicy};
        let mut r = Router::native_only_with(ExecPolicy::Serial);
        r.set_shard_policy(ShardPolicy::MaxShards(4));
        // large request: sharded into 4 bands
        let big = PlanKey::new(TransformOp::Dct2d, vec![512, 512]);
        assert_eq!(r.shard_plan(&big).band_count(), 4);
        assert_eq!(r.shard_bands(&big), 4);
        // small request: decide() keeps it unsharded
        let small = PlanKey::new(TransformOp::Dct2d, vec![16, 16]);
        assert_eq!(r.shard_plan(&small).band_count(), 1);
        // large 3D request: sharded into 4 dim-0 slab bands
        let big3 = PlanKey::new(TransformOp::Dct3d, vec![64, 64, 64]);
        assert_eq!(r.shard_plan(&big3).band_count(), 4);
        assert_eq!(r.shard_bands(&big3), 4);
        // small 3D request: below the 3D gate, unsharded
        let small3 = PlanKey::new(TransformOp::Idct3d, vec![16, 16, 16]);
        assert_eq!(r.shard_plan(&small3).band_count(), 1);
        // sharded execution still produces correct output
        let mut rng = Rng::new(91);
        let x = rng.normal_vec(16 * 16);
        let (y, _) = r.execute(&small, &x).unwrap();
        check_close(&y, &dct2d_direct(&x, 16, 16), 1e-9).unwrap();
    }

    #[test]
    fn degraded_execution_matches_primary() {
        use crate::parallel::{ExecPolicy, ShardPolicy};
        let mut r = Router::native_only_with(ExecPolicy::Threads(4));
        r.set_shard_policy(ShardPolicy::MaxShards(4));
        let key = PlanKey::new(TransformOp::Dct2d, vec![32, 32]);
        let mut rng = Rng::new(92);
        let x = rng.normal_vec(32 * 32);
        let degraded = r.execute_degraded(&key, &x);
        check_close(&degraded, &dct2d_direct(&x, 32, 32), 1e-9).unwrap();
        // quarantining makes the plain execute() path serve the same
        // degraded plan (bit-identical output)
        r.quarantine(&key);
        assert!(r.is_quarantined(&key));
        let (y, route) = r.execute(&key, &x).unwrap();
        assert_eq!(route, Route::Native);
        assert_eq!(y, degraded);
    }

    #[test]
    fn batch_views_matches_packed_batch_bitwise() {
        let r = Router::native_only();
        let mut rng = Rng::new(93);
        for op in [TransformOp::Dct2d, TransformOp::Idct2d] {
            let key = PlanKey::new(op, vec![8, 12]);
            let (numel, batch) = (96usize, 4usize);
            let packed = rng.normal_vec(numel * batch);
            let views: Vec<&[f64]> = packed.chunks(numel).collect();
            let (got, route) = r.execute_batch_views(&key, &views).unwrap();
            assert_eq!(route, Route::Native);
            let (want, _) = r.execute_batch(&key, &packed, batch).unwrap();
            assert_eq!(got, want, "{op:?}");
        }
    }

    #[test]
    fn ops_without_artifacts_stay_native() {
        let r = Router::native_only();
        let key = PlanKey::new(TransformOp::Dct3d, vec![4, 4, 4]);
        assert_eq!(r.route(&key), Route::Native);
    }

    #[test]
    fn prefer_pjrt_falls_back_when_shape_missing() {
        // manifest without the requested shape -> native route
        let manifest = Manifest::parse(
            r#"{"version":1,"dtype":"f32","entries":[
                {"name":"dct2d_64x64","pipeline":"dct2d","file":"x.hlo.txt",
                 "inputs":[{"shape":[64,64],"dtype":"f32"}],
                 "outputs":[{"shape":[64,64],"dtype":"f32"}]}]}"#,
            std::path::PathBuf::from("/nonexistent"),
        )
        .unwrap();
        let handle = PjrtHandle::spawn("/nonexistent");
        let r = Router::with_pjrt(handle, &manifest);
        let hit = PlanKey::new(TransformOp::Dct2d, vec![64, 64]);
        let miss = PlanKey::new(TransformOp::Dct2d, vec![63, 63]);
        assert_eq!(r.route(&hit), Route::Pjrt);
        assert_eq!(r.route(&miss), Route::Native);
    }
}
