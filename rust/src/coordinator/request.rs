//! Request/response types for the transform service.

use std::time::Instant;

use crate::dct::Algo1d;
use crate::layout::ElemType;
use crate::util::error::TransformError;

/// A transform the service can execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TransformOp {
    /// Fused 2D DCT (the paper's headline path)
    Dct2d,
    /// Fused 2D IDCT
    Idct2d,
    /// Row-column 2D DCT (baseline; exposed for A/B benchmarking)
    RcDct2d,
    /// Row-column 2D IDCT
    RcIdct2d,
    /// 1D DCT with a chosen Algorithm-1 variant
    Dct1d(Algo1d),
    /// 1D inverse DCT
    Idct1d,
    /// 1D IDXST (DREAMPlace Eq. 21)
    Idxst1d,
    /// Fused IDCT_IDXST (rows IDCT, cols IDXST)
    IdctIdxst,
    /// Fused IDXST_IDCT
    IdxstIdct,
    /// Fused 3D DCT
    Dct3d,
    /// Fused 3D IDCT
    Idct3d,
    /// Fused 2D DST-II (DST family via folds, §III-D extensibility)
    Dst2d,
    /// Fused 2D inverse DST
    Idst2d,
}

impl TransformOp {
    /// Tensor rank this op expects.
    pub fn rank(self) -> usize {
        match self {
            TransformOp::Dct1d(_) | TransformOp::Idct1d | TransformOp::Idxst1d => 1,
            TransformOp::Dct3d | TransformOp::Idct3d => 3,
            _ => 2,
        }
    }

    /// Whether this op's native plan honors an explicit band-shard
    /// policy: the fused 2D family threads `ShardPolicy` through its
    /// row-banded stages, and the fused 3D pair through its dim-0
    /// slab-banded stages; the row-column baseline and 1D plans fan out
    /// by exec lanes only (see `coordinator::shard`).
    pub fn supports_sharding(self) -> bool {
        matches!(
            self,
            TransformOp::Dct2d
                | TransformOp::Idct2d
                | TransformOp::IdctIdxst
                | TransformOp::IdxstIdct
                | TransformOp::Dst2d
                | TransformOp::Idst2d
                | TransformOp::Dct3d
                | TransformOp::Idct3d
        )
    }

    /// Whether this op's native plan can execute a batch directly over
    /// caller-provided per-request views (`forward_batch_views`) with no
    /// input pack copy — the coordinator's zero-copy packed path.
    /// Currently the fused 2D DCT/IDCT pair; every other batch-capable
    /// op still packs its inputs contiguously first.
    pub fn supports_batch_views(self) -> bool {
        matches!(self, TransformOp::Dct2d | TransformOp::Idct2d)
    }

    /// Whether this op's native plan has a true batched execution path
    /// (stage-fused across a packed same-shape batch via
    /// `forward_batch`): the fused 2D DCT/IDCT and DST/IDST pairs, the
    /// DREAMPlace combos (DST and combo plans batch their shift/sign
    /// folds around the inner DCT/IDCT batch path), and the 1D DCT/IDCT
    /// family. Other ops still co-batch for plan-lookup amortization
    /// but execute item by item.
    pub fn supports_batch(self) -> bool {
        matches!(
            self,
            TransformOp::Dct2d
                | TransformOp::Idct2d
                | TransformOp::Dst2d
                | TransformOp::Idst2d
                | TransformOp::IdctIdxst
                | TransformOp::IdxstIdct
                | TransformOp::Dct1d(_)
                | TransformOp::Idct1d
        )
    }

    /// Artifact-name prefix for the PJRT backend (None = native only).
    pub fn artifact_prefix(self) -> Option<&'static str> {
        match self {
            TransformOp::Dct2d => Some("dct2d_"),
            TransformOp::Idct2d => Some("idct2d_"),
            TransformOp::RcDct2d => Some("rc_dct2d_"),
            TransformOp::RcIdct2d => Some("rc_idct2d_"),
            TransformOp::Dct1d(Algo1d::NPoint) => Some("dct1d_n_"),
            TransformOp::Dct1d(Algo1d::FourN) => Some("dct1d_4n_"),
            TransformOp::Dct1d(Algo1d::Mirror2N) => Some("dct1d_2n_mirror_"),
            TransformOp::Dct1d(Algo1d::Pad2N) => Some("dct1d_2n_pad_"),
            TransformOp::Idct1d => Some("idct1d_"),
            TransformOp::IdctIdxst => Some("idct_idxst_"),
            TransformOp::IdxstIdct => Some("idxst_idct_"),
            TransformOp::Dst2d => Some("dst2d_"),
            TransformOp::Idst2d => Some("idst2d_"),
            TransformOp::Idxst1d | TransformOp::Dct3d | TransformOp::Idct3d => None,
        }
    }

    /// Artifact name for a concrete shape, e.g. `dct2d_256x256`.
    pub fn artifact_name(self, shape: &[usize]) -> Option<String> {
        let prefix = self.artifact_prefix()?;
        let dims: Vec<String> = shape.iter().map(|d| d.to_string()).collect();
        Some(format!("{prefix}{}", dims.join("x")))
    }

    /// Parse a stable op name back to the op — the inverse of
    /// [`TransformOp::name`], shared by the CLI's `--op` flag and the
    /// wire protocol's `"op"` field. Also accepts the bare `dct1d`
    /// alias for the N-point variant.
    pub fn parse(name: &str) -> Option<TransformOp> {
        Some(match name {
            "dct2d" => TransformOp::Dct2d,
            "idct2d" => TransformOp::Idct2d,
            "rc_dct2d" => TransformOp::RcDct2d,
            "rc_idct2d" => TransformOp::RcIdct2d,
            "dct1d" | "dct1d_n" => TransformOp::Dct1d(Algo1d::NPoint),
            "dct1d_4n" => TransformOp::Dct1d(Algo1d::FourN),
            "dct1d_2n_mirror" => TransformOp::Dct1d(Algo1d::Mirror2N),
            "dct1d_2n_pad" => TransformOp::Dct1d(Algo1d::Pad2N),
            "idct1d" => TransformOp::Idct1d,
            "idxst1d" => TransformOp::Idxst1d,
            "idct_idxst" => TransformOp::IdctIdxst,
            "idxst_idct" => TransformOp::IdxstIdct,
            "dct3d" => TransformOp::Dct3d,
            "idct3d" => TransformOp::Idct3d,
            "dst2d" => TransformOp::Dst2d,
            "idst2d" => TransformOp::Idst2d,
            _ => return None,
        })
    }

    /// Every op, each Algorithm-1 variant included (test/bench sweeps).
    pub const ALL: [TransformOp; 16] = [
        TransformOp::Dct2d,
        TransformOp::Idct2d,
        TransformOp::RcDct2d,
        TransformOp::RcIdct2d,
        TransformOp::Dct1d(Algo1d::NPoint),
        TransformOp::Dct1d(Algo1d::FourN),
        TransformOp::Dct1d(Algo1d::Mirror2N),
        TransformOp::Dct1d(Algo1d::Pad2N),
        TransformOp::Idct1d,
        TransformOp::Idxst1d,
        TransformOp::IdctIdxst,
        TransformOp::IdxstIdct,
        TransformOp::Dct3d,
        TransformOp::Idct3d,
        TransformOp::Dst2d,
        TransformOp::Idst2d,
    ];

    /// Stable lower-case op name (metrics keys, CLI `--op` values).
    pub fn name(self) -> String {
        match self {
            TransformOp::Dct2d => "dct2d".into(),
            TransformOp::Idct2d => "idct2d".into(),
            TransformOp::RcDct2d => "rc_dct2d".into(),
            TransformOp::RcIdct2d => "rc_idct2d".into(),
            TransformOp::Dct1d(a) => format!("dct1d_{}", a.name()),
            TransformOp::Idct1d => "idct1d".into(),
            TransformOp::Idxst1d => "idxst1d".into(),
            TransformOp::IdctIdxst => "idct_idxst".into(),
            TransformOp::IdxstIdct => "idxst_idct".into(),
            TransformOp::Dct3d => "dct3d".into(),
            TransformOp::Idct3d => "idct3d".into(),
            TransformOp::Dst2d => "dst2d".into(),
            TransformOp::Idst2d => "idst2d".into(),
        }
    }
}

/// Routing key: requests with equal keys share a plan / executable and can
/// be batched together.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// The transform to run.
    pub op: TransformOp,
    /// Input tensor shape, row-major.
    pub shape: Vec<usize>,
    /// Element type the plan executes in ([`ElemType::F64`] is the
    /// native precision; [`ElemType::F32`] selects the reduced-precision
    /// generic plans where available).
    pub elem: ElemType,
}

impl PlanKey {
    /// Key for the default (f64, contiguous) execution of `op` on `shape`.
    pub fn new(op: TransformOp, shape: Vec<usize>) -> PlanKey {
        PlanKey { op, shape, elem: ElemType::F64 }
    }

    /// Same key, re-targeted at a different element type.
    pub fn with_elem(mut self, elem: ElemType) -> PlanKey {
        self.elem = elem;
        self
    }
}

/// Tenant name charged for requests submitted without an explicit
/// tenant: they all share one fair-share bucket in the
/// [`InflightBudget`](super::batcher::InflightBudget).
pub const DEFAULT_TENANT: &str = "default";

/// A transform request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Service-assigned request id (monotonic per service).
    pub id: u64,
    /// The transform to run.
    pub op: TransformOp,
    /// Input tensor shape, row-major.
    pub shape: Vec<usize>,
    /// Row-major input payload (`shape.iter().product()` elements).
    pub data: Vec<f64>,
    /// Absolute completion deadline. A request whose deadline passes
    /// while it is still queued is dropped (answered
    /// [`TransformError::DeadlineExceeded`]) instead of consuming pool
    /// work; `None` = no deadline.
    pub deadline: Option<Instant>,
    /// Tenant this request's payload is charged to in the weighted
    /// fair-share admission budget; `None` bills the shared
    /// [`DEFAULT_TENANT`] bucket.
    pub tenant: Option<String>,
    /// Scheduling priority (higher = flushed first when the batcher
    /// drains multiple plan keys at once; 0 = normal).
    pub priority: u8,
}

impl Request {
    /// The (op, shape) key this request batches and plans under.
    pub fn key(&self) -> PlanKey {
        PlanKey::new(self.op, self.shape.clone())
    }

    /// The tenant charged for this request ([`DEFAULT_TENANT`] when
    /// none was set).
    pub fn tenant_name(&self) -> &str {
        self.tenant.as_deref().unwrap_or(DEFAULT_TENANT)
    }

    /// Whether this request's deadline has already passed.
    pub fn expired(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() > d)
    }

    /// Validate shape/rank/payload consistency.
    pub fn validate(&self) -> Result<(), TransformError> {
        if self.shape.len() != self.op.rank() {
            return Err(TransformError::InvalidRequest(format!(
                "{} expects rank {}, got shape {:?}",
                self.op.name(),
                self.op.rank(),
                self.shape
            )));
        }
        if self.shape.iter().any(|&d| d == 0) {
            return Err(TransformError::InvalidRequest(format!(
                "zero dimension in shape {:?}",
                self.shape
            )));
        }
        // checked: a hostile shape like [u32::MAX, u32::MAX] (reachable
        // through the wire decoder's pre-checks only by construction)
        // must error, not overflow-panic in debug builds
        let numel = self
            .shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .ok_or_else(|| {
                TransformError::InvalidRequest(format!(
                    "shape {:?} element count overflows",
                    self.shape
                ))
            })?;
        if self.data.len() != numel {
            return Err(TransformError::InvalidRequest(format!(
                "payload {} elements, shape {:?} needs {numel}",
                self.data.len(),
                self.shape
            )));
        }
        Ok(())
    }
}

/// A completed transform.
#[derive(Debug, Clone)]
pub struct Response {
    /// Id of the request this answers.
    pub id: u64,
    /// transform outputs (single tensor for all current ops)
    pub output: Vec<f64>,
    /// which backend executed it
    pub backend: &'static str,
    /// end-to-end seconds inside the service (queue + execute)
    pub latency: f64,
    /// how many requests shared the executing batch
    pub batch_size: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranks() {
        assert_eq!(TransformOp::Dct2d.rank(), 2);
        assert_eq!(TransformOp::Idct1d.rank(), 1);
        assert_eq!(TransformOp::Dct3d.rank(), 3);
        assert_eq!(TransformOp::Idct3d.rank(), 3);
    }

    #[test]
    fn sharding_support_covers_the_fused_2d_and_3d_families() {
        assert!(TransformOp::Dct2d.supports_sharding());
        assert!(TransformOp::Idct2d.supports_sharding());
        assert!(TransformOp::IdxstIdct.supports_sharding());
        assert!(TransformOp::Dst2d.supports_sharding());
        assert!(TransformOp::Dct3d.supports_sharding());
        assert!(TransformOp::Idct3d.supports_sharding());
        assert!(!TransformOp::RcDct2d.supports_sharding());
        assert!(!TransformOp::Idct1d.supports_sharding());
    }

    #[test]
    fn batch_support_covers_the_stage_fused_plans() {
        assert!(TransformOp::Dct2d.supports_batch());
        assert!(TransformOp::Idct2d.supports_batch());
        assert!(TransformOp::Dst2d.supports_batch());
        assert!(TransformOp::Idst2d.supports_batch());
        assert!(TransformOp::Dct1d(Algo1d::NPoint).supports_batch());
        assert!(TransformOp::Idct1d.supports_batch());
        assert!(TransformOp::IdctIdxst.supports_batch());
        assert!(TransformOp::IdxstIdct.supports_batch());
        assert!(!TransformOp::RcDct2d.supports_batch());
        assert!(!TransformOp::Dct3d.supports_batch());
    }

    #[test]
    fn op_names_round_trip_through_parse() {
        for op in TransformOp::ALL {
            assert_eq!(TransformOp::parse(&op.name()), Some(op), "{op:?}");
        }
        // the CLI's bare-1D alias
        assert_eq!(TransformOp::parse("dct1d"), Some(TransformOp::Dct1d(Algo1d::NPoint)));
        assert_eq!(TransformOp::parse("nope"), None);
    }

    #[test]
    fn artifact_names() {
        assert_eq!(
            TransformOp::Dct2d.artifact_name(&[256, 256]).unwrap(),
            "dct2d_256x256"
        );
        assert_eq!(
            TransformOp::Dct1d(Algo1d::NPoint).artifact_name(&[1024]).unwrap(),
            "dct1d_n_1024"
        );
        assert!(TransformOp::Dct3d.artifact_name(&[4, 4, 4]).is_none());
    }

    fn req(id: u64, op: TransformOp, shape: Vec<usize>, data: Vec<f64>) -> Request {
        Request { id, op, shape, data, deadline: None, tenant: None, priority: 0 }
    }

    #[test]
    fn validation() {
        let ok = req(1, TransformOp::Dct2d, vec![4, 4], vec![0.0; 16]);
        assert!(ok.validate().is_ok());
        let bad_rank = req(2, TransformOp::Dct2d, vec![4], vec![0.0; 4]);
        assert!(matches!(bad_rank.validate(), Err(TransformError::InvalidRequest(_))));
        let bad_len = req(3, TransformOp::Dct2d, vec![4, 4], vec![0.0; 15]);
        assert!(bad_len.validate().is_err());
        let zero_dim = req(4, TransformOp::Dct2d, vec![0, 4], vec![]);
        assert!(zero_dim.validate().is_err());
        // element-count overflow is a typed error, not a panic
        let huge = req(5, TransformOp::Dct2d, vec![usize::MAX, usize::MAX], vec![]);
        assert!(matches!(huge.validate(), Err(TransformError::InvalidRequest(_))));
    }

    #[test]
    fn deadlines_expire() {
        let mut r = req(1, TransformOp::Dct2d, vec![4, 4], vec![0.0; 16]);
        assert!(!r.expired(), "no deadline never expires");
        r.deadline = Some(Instant::now() + std::time::Duration::from_secs(3600));
        assert!(!r.expired());
        r.deadline = Some(Instant::now() - std::time::Duration::from_millis(1));
        assert!(r.expired());
    }

    #[test]
    fn plan_keys_group_by_op_and_shape() {
        let a = req(1, TransformOp::Dct2d, vec![8, 8], vec![0.0; 64]);
        let b = req(2, TransformOp::Dct2d, vec![8, 8], vec![1.0; 64]);
        let c = req(3, TransformOp::Idct2d, vec![8, 8], vec![1.0; 64]);
        assert_eq!(a.key(), b.key());
        assert_ne!(a.key(), c.key());
    }

    #[test]
    fn plan_keys_distinguish_element_type() {
        let a = req(1, TransformOp::Dct2d, vec![8, 8], vec![0.0; 64]);
        assert_eq!(a.key().elem, ElemType::F64, "requests default to f64 plans");
        let f32_key = a.key().with_elem(ElemType::F32);
        assert_ne!(a.key(), f32_key);
        assert_eq!(f32_key.op, a.key().op);
        assert_eq!(f32_key.shape, a.key().shape);
    }

    #[test]
    fn batch_views_ops_are_a_subset_of_batch_ops() {
        for op in TransformOp::ALL {
            if op.supports_batch_views() {
                assert!(op.supports_batch(), "{}: views implies batch", op.name());
            }
        }
        assert!(TransformOp::Dct2d.supports_batch_views());
        assert!(TransformOp::Idct2d.supports_batch_views());
        assert!(!TransformOp::Dst2d.supports_batch_views());
        assert!(!TransformOp::RcDct2d.supports_batch_views());
    }
}
