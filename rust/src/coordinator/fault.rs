//! Deterministic fault injection for the coordinator (the chaos layer).
//!
//! Every robustness behavior in the request lifecycle — panic isolation,
//! degrade-and-retry, plan quarantine, deadline expiry under slow
//! execution — needs a way to *cause* the failure on demand. This module
//! injects panics, errors, and delays at the coordinator's execution
//! seams, controlled by a spec string:
//!
//! ```text
//! MDDCT_FAULT=panic:dct2d:0.5,delay:execute:20ms,error:pack
//! ```
//!
//! Each comma-separated entry is `kind:site[:arg][:prob]`:
//!
//! * `kind` — `panic` | `error` | `delay` at an execution seam, or a
//!   network kind `stall` | `truncate` | `garbage` | `close` applied at
//!   the wire (`site` must be `conn`; see [`conn_fault`]);
//! * `site` — either a seam name (`execute`, `execute_batch`, `pack`)
//!   or a transform-op name (`dct2d`, …), matching every seam that op
//!   crosses; network kinds use the pseudo-site `conn`;
//! * `arg` — for `delay` and `stall` only: a duration (`20ms`, `500us`,
//!   `1s`, or a bare number meaning milliseconds);
//! * `prob` — firing probability in `[0, 1]`, default 1.0 (rolled per
//!   seam crossing with a per-thread deterministic RNG).
//!
//! Like the `obs` enable flag, the disabled hot path is a single relaxed
//! atomic load, resolved lazily from `MDDCT_FAULT` on first query;
//! [`set_faults`] / [`clear`] override it programmatically (the test
//! harness and the CLI `--fault` flag use this). The `fault-off` cargo
//! feature compiles [`enabled`] to a constant `false` so every injection
//! site folds away in production builds, mirroring `trace-off`.
//!
//! Injection sites live *inside* the worker's `catch_unwind` and *only*
//! on the primary execution path — the degraded-serial retry path does
//! not cross them, so a probability-1.0 panic spec still lets every
//! request complete via degradation (which is exactly what the fault
//! matrix in `tests/fault_injection.rs` asserts).

use std::time::Duration;

use crate::util::error::TransformError;

/// What an injected fault does when it fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// `panic!` at the seam (exercises `catch_unwind` isolation and the
    /// degrade-and-retry path).
    Panic,
    /// Return a [`TransformError::ExecutionFailed`] from the seam
    /// (exercises the non-panic error path).
    Error,
    /// Sleep at the seam (exercises deadlines and overload shedding).
    Delay(Duration),
    /// Conn-site: sleep before the next socket read/write (a slow or
    /// stalling peer; exercises the read/idle timeouts).
    Stall(Duration),
    /// Conn-site: the next socket read reports EOF / the next write
    /// stops short (a peer that vanished mid-frame).
    Truncate,
    /// Conn-site: corrupt a byte of the next read/write (exercises the
    /// typed `invalid_request` + close-on-violation path).
    Garbage,
    /// Conn-site: the next socket operation fails as if the connection
    /// was reset.
    Close,
}

impl FaultKind {
    /// Whether this kind fires at the wire ([`conn_fault`]) rather than
    /// at a coordinator execution seam ([`fire`]).
    pub fn is_conn(&self) -> bool {
        matches!(
            self,
            FaultKind::Stall(_) | FaultKind::Truncate | FaultKind::Garbage | FaultKind::Close
        )
    }
}

/// One parsed `kind:site[:arg][:prob]` entry.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// What to inject.
    pub kind: FaultKind,
    /// Seam name (`execute`, `execute_batch`, `pack`) or op name.
    pub site: String,
    /// Firing probability in `[0, 1]`.
    pub prob: f64,
}

/// Parse a `MDDCT_FAULT`-style spec string into fault entries.
/// Whitespace around entries is tolerated; an empty string yields no
/// faults. Errors name the offending entry.
pub fn parse_spec(spec: &str) -> Result<Vec<FaultSpec>, String> {
    let mut out = Vec::new();
    for entry in spec.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let parts: Vec<&str> = entry.split(':').collect();
        if parts.len() < 2 {
            return Err(format!("fault entry '{entry}': expected kind:site[:arg][:prob]"));
        }
        let site = parts[1].trim().to_string();
        if site.is_empty() {
            return Err(format!("fault entry '{entry}': empty site"));
        }
        let (kind, rest) = match parts[0].trim() {
            "panic" => (FaultKind::Panic, &parts[2..]),
            "error" => (FaultKind::Error, &parts[2..]),
            "truncate" => (FaultKind::Truncate, &parts[2..]),
            "garbage" => (FaultKind::Garbage, &parts[2..]),
            "close" => (FaultKind::Close, &parts[2..]),
            "delay" | "stall" => {
                let Some(arg) = parts.get(2) else {
                    return Err(format!("fault entry '{entry}': {} needs a duration", parts[0]));
                };
                let d = parse_duration(arg.trim())?;
                let kind = if parts[0].trim() == "delay" {
                    FaultKind::Delay(d)
                } else {
                    FaultKind::Stall(d)
                };
                (kind, &parts[3..])
            }
            other => return Err(format!("fault entry '{entry}': unknown kind '{other}'")),
        };
        if kind.is_conn() && site != "conn" {
            return Err(format!("fault entry '{entry}': network kinds need site 'conn'"));
        }
        let prob = match rest.first() {
            None => 1.0,
            Some(p) => {
                let p: f64 = p
                    .trim()
                    .parse()
                    .map_err(|_| format!("fault entry '{entry}': bad probability '{p}'"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("fault entry '{entry}': probability {p} not in [0, 1]"));
                }
                p
            }
        };
        out.push(FaultSpec { kind, site, prob });
    }
    Ok(out)
}

/// Parse `20ms` / `500us` / `1s` / bare-number-means-ms durations.
fn parse_duration(s: &str) -> Result<Duration, String> {
    let (num, mult_us) = if let Some(n) = s.strip_suffix("ms") {
        (n, 1_000.0)
    } else if let Some(n) = s.strip_suffix("us") {
        (n, 1.0)
    } else if let Some(n) = s.strip_suffix('s') {
        (n, 1_000_000.0)
    } else {
        (s, 1_000.0)
    };
    let v: f64 = num.trim().parse().map_err(|_| format!("bad duration '{s}'"))?;
    if v < 0.0 || !v.is_finite() {
        return Err(format!("bad duration '{s}'"));
    }
    Ok(Duration::from_micros((v * mult_us) as u64))
}

#[cfg(not(feature = "fault-off"))]
mod state {
    use super::FaultSpec;
    use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
    use std::sync::Mutex;

    /// Tri-state like `obs::STATE`: 0 = uninitialized (resolve
    /// `MDDCT_FAULT` on first query), 1 = off, 2 = on.
    static STATE: AtomicU8 = AtomicU8::new(0);
    static SPECS: Mutex<Vec<FaultSpec>> = Mutex::new(Vec::new());
    /// Per-thread RNG seeds (deterministic but distinct across threads).
    static SEED: AtomicU64 = AtomicU64::new(0x9e37_79b9_7f4a_7c15);

    pub(super) fn enabled() -> bool {
        match STATE.load(Ordering::Relaxed) {
            2 => true,
            1 => false,
            _ => resolve_from_env(),
        }
    }

    #[cold]
    fn resolve_from_env() -> bool {
        let specs = std::env::var("MDDCT_FAULT")
            .ok()
            .and_then(|v| match super::parse_spec(&v) {
                Ok(s) => Some(s),
                Err(e) => {
                    eprintln!("MDDCT_FAULT ignored: {e}");
                    None
                }
            })
            .unwrap_or_default();
        let on = !specs.is_empty();
        *SPECS.lock().unwrap_or_else(|e| e.into_inner()) = specs;
        STATE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
        on
    }

    pub(super) fn install(specs: Vec<FaultSpec>) {
        let on = !specs.is_empty();
        *SPECS.lock().unwrap_or_else(|e| e.into_inner()) = specs;
        STATE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
    }

    pub(super) fn with_specs<T>(f: impl FnOnce(&[FaultSpec]) -> T) -> T {
        f(&SPECS.lock().unwrap_or_else(|e| e.into_inner()))
    }

    thread_local! {
        static RNG: std::cell::RefCell<crate::util::rng::Rng> =
            std::cell::RefCell::new(crate::util::rng::Rng::new(
                SEED.fetch_add(0x517c_c1b7_2722_0a95, Ordering::Relaxed),
            ));
    }

    pub(super) fn roll(prob: f64) -> bool {
        if prob >= 1.0 {
            return true;
        }
        if prob <= 0.0 {
            return false;
        }
        RNG.with(|r| r.borrow_mut().f64()) < prob
    }
}

/// Whether fault injection is active. One relaxed atomic load when the
/// env var has been resolved; a constant `false` under `fault-off`.
#[cfg(not(feature = "fault-off"))]
#[inline]
pub fn enabled() -> bool {
    state::enabled()
}

/// Compiled-out variant: faults can never fire.
#[cfg(feature = "fault-off")]
#[inline(always)]
pub fn enabled() -> bool {
    false
}

/// Install `specs` as the active fault set (empty = off), overriding
/// `MDDCT_FAULT`. A no-op under the `fault-off` feature.
pub fn set_faults(specs: Vec<FaultSpec>) {
    #[cfg(not(feature = "fault-off"))]
    state::install(specs);
    #[cfg(feature = "fault-off")]
    drop(specs);
}

/// Disable fault injection (overriding `MDDCT_FAULT`).
pub fn clear() {
    set_faults(Vec::new());
}

/// Cross a fault seam: fire the first matching spec whose probability
/// roll succeeds. `seam` is the pipeline location (`execute`,
/// `execute_batch`, `pack`); `op` is the transform-op name — a spec
/// site matching either fires here. `Panic` panics (caught by the
/// worker's `catch_unwind`), `Delay` sleeps then passes, `Error`
/// returns an [`TransformError::ExecutionFailed`]. Costs one atomic
/// load when disabled; compiles to `Ok(())` under `fault-off`.
#[cfg(not(feature = "fault-off"))]
pub fn fire(seam: &str, op: &str) -> Result<(), TransformError> {
    if !enabled() {
        return Ok(());
    }
    fire_slow(seam, op)
}

/// Compiled-out variant: never fires.
#[cfg(feature = "fault-off")]
#[inline(always)]
pub fn fire(_seam: &str, _op: &str) -> Result<(), TransformError> {
    Ok(())
}

#[cfg(not(feature = "fault-off"))]
#[cold]
fn fire_slow(seam: &str, op: &str) -> Result<(), TransformError> {
    let hit = state::with_specs(|specs| {
        specs
            .iter()
            .find(|s| !s.kind.is_conn() && (s.site == seam || s.site == op) && state::roll(s.prob))
            .map(|s| s.kind)
    });
    match hit {
        None => Ok(()),
        Some(FaultKind::Delay(d)) => {
            crate::obs::instant_event("fault.delay");
            std::thread::sleep(d);
            Ok(())
        }
        Some(FaultKind::Error) => {
            crate::obs::instant_event("fault.error");
            Err(TransformError::ExecutionFailed(format!(
                "injected fault: error at {seam} ({op})"
            )))
        }
        Some(FaultKind::Panic) => {
            crate::obs::instant_event("fault.panic");
            panic!("injected fault: panic at {seam} ({op})");
        }
        // conn kinds are filtered out of the seam search above
        Some(_) => Ok(()),
    }
}

/// Cross the wire fault seam: the first `conn`-site network spec
/// (`stall` / `truncate` / `garbage` / `close`) whose probability roll
/// succeeds is returned for the caller (the server's `FaultStream`) to
/// apply to the next socket operation. Costs one atomic load when
/// disabled; compiles to `None` under `fault-off`.
#[cfg(not(feature = "fault-off"))]
pub fn conn_fault() -> Option<FaultKind> {
    if !enabled() {
        return None;
    }
    conn_fault_slow()
}

/// Compiled-out variant: never fires.
#[cfg(feature = "fault-off")]
#[inline(always)]
pub fn conn_fault() -> Option<FaultKind> {
    None
}

#[cfg(not(feature = "fault-off"))]
#[cold]
fn conn_fault_slow() -> Option<FaultKind> {
    let hit = state::with_specs(|specs| {
        specs
            .iter()
            .find(|s| s.kind.is_conn() && s.site == "conn" && state::roll(s.prob))
            .map(|s| s.kind)
    });
    if hit.is_some() {
        crate::obs::instant_event("fault.conn");
    }
    hit
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parsing_roundtrips_the_issue_grammar() {
        let specs = parse_spec("panic:dct2d:0.5,delay:execute:20ms,error:pack").unwrap();
        assert_eq!(specs.len(), 3);
        assert_eq!(
            specs[0],
            FaultSpec { kind: FaultKind::Panic, site: "dct2d".into(), prob: 0.5 }
        );
        assert_eq!(
            specs[1],
            FaultSpec {
                kind: FaultKind::Delay(Duration::from_millis(20)),
                site: "execute".into(),
                prob: 1.0
            }
        );
        assert_eq!(
            specs[2],
            FaultSpec { kind: FaultKind::Error, site: "pack".into(), prob: 1.0 }
        );
        // delays accept us / s / bare-ms, and take an optional prob
        let d = parse_spec("delay:execute:500us:0.25").unwrap();
        assert_eq!(
            d[0],
            FaultSpec {
                kind: FaultKind::Delay(Duration::from_micros(500)),
                site: "execute".into(),
                prob: 0.25
            }
        );
        assert_eq!(
            parse_spec("delay:x:2").unwrap()[0].kind,
            FaultKind::Delay(Duration::from_millis(2))
        );
        assert!(parse_spec("").unwrap().is_empty());
        assert!(parse_spec(" , ").unwrap().is_empty());
    }

    #[test]
    fn spec_parsing_rejects_malformed_entries() {
        assert!(parse_spec("panic").is_err()); // no site
        assert!(parse_spec("explode:dct2d").is_err()); // unknown kind
        assert!(parse_spec("delay:execute").is_err()); // delay w/o duration
        assert!(parse_spec("panic:dct2d:1.5").is_err()); // prob out of range
        assert!(parse_spec("delay:execute:fast").is_err()); // bad duration
        assert!(parse_spec("stall:conn").is_err()); // stall w/o duration
        assert!(parse_spec("truncate:execute").is_err()); // conn kind off-site
    }

    #[test]
    fn conn_kinds_parse_with_the_conn_site() {
        let specs = parse_spec("stall:conn:2ms:0.5,truncate:conn,garbage:conn:0.1,close:conn")
            .unwrap();
        assert_eq!(specs.len(), 4);
        assert_eq!(specs[0].kind, FaultKind::Stall(Duration::from_millis(2)));
        assert_eq!(specs[0].prob, 0.5);
        assert_eq!(specs[1].kind, FaultKind::Truncate);
        assert_eq!(specs[2], FaultSpec { kind: FaultKind::Garbage, site: "conn".into(), prob: 0.1 });
        assert_eq!(specs[3].kind, FaultKind::Close);
        assert!(specs.iter().all(|s| s.kind.is_conn()));
    }

    #[cfg(not(feature = "fault-off"))]
    #[test]
    fn conn_faults_fire_at_the_wire_seam_only() {
        let _g = crate::obs::test_guard();
        set_faults(parse_spec("close:conn").unwrap());
        // the execution seams never see a conn kind ...
        assert!(fire("execute", "dct2d").is_ok());
        assert!(fire("conn", "dct2d").is_ok());
        // ... and the wire seam does
        assert_eq!(conn_fault(), Some(FaultKind::Close));
        // mixed spec: the wire seam skips execution kinds
        set_faults(parse_spec("error:execute,stall:conn:1ms").unwrap());
        assert_eq!(conn_fault(), Some(FaultKind::Stall(Duration::from_millis(1))));
        assert!(fire("execute", "dct2d").is_err());
        clear();
        assert_eq!(conn_fault(), None);
    }

    #[cfg(not(feature = "fault-off"))]
    #[test]
    fn programmatic_faults_fire_and_clear() {
        let _g = crate::obs::test_guard();
        set_faults(parse_spec("error:myseam").unwrap());
        assert!(enabled());
        assert!(fire("myseam", "dct2d").is_err());
        assert!(fire("otherseam", "dct2d").is_ok()); // site mismatch
        // op-name sites match at any seam
        set_faults(parse_spec("error:dct2d").unwrap());
        assert!(fire("execute", "dct2d").is_err());
        assert!(fire("execute", "idct2d").is_ok());
        // prob 0 never fires; clearing disables everything
        set_faults(parse_spec("error:execute:0.0").unwrap());
        assert!(fire("execute", "dct2d").is_ok());
        clear();
        assert!(!enabled());
        assert!(fire("execute", "dct2d").is_ok());
    }

    #[cfg(feature = "fault-off")]
    #[test]
    fn fault_off_feature_compiles_everything_out() {
        set_faults(parse_spec("panic:execute").unwrap());
        assert!(!enabled());
        assert!(fire("execute", "dct2d").is_ok());
    }
}
