//! The transform service: the L3 coordinator facade.
//!
//! Architecture (std-thread substitute for the usual tokio stack — the
//! offline crate set has no async runtime):
//!
//! ```text
//!  submit() ──> request mpsc ──> batcher thread ──> batch mpsc ──┐
//!                                                                ▼
//!                                                      worker pool (N threads)
//!                                                                │
//!  Handle::wait() <── per-request reply channel <────────────────┘
//! ```
//!
//! Workers execute batches through the [`Router`] (native plans or PJRT
//! artifacts) and record metrics. Shape-specialized plans are cached, so
//! steady-state request cost is transform + channel hops only.
//!
//! Failure model (see ARCHITECTURE.md "Failure model"):
//!
//! * **Admission control** — `submit` acquires from an elems-weighted
//!   [`InflightBudget`] and sheds with [`TransformError::Overloaded`]
//!   when the pool is saturated, so queues never grow without bound.
//! * **Deadlines** — requests carry an optional absolute deadline
//!   ([`ServiceConfig::default_deadline`], `MDDCT_DEADLINE_MS`); the
//!   batcher and workers drop expired requests at every dequeue instead
//!   of spending pool work on answers nobody can use.
//! * **Degrade-and-retry** — a panicking or erroring primary execution
//!   is retried once per request on the degraded serial plan (the
//!   bottom of the degradation lattice the three-stage factorization
//!   provides: fused-sharded-batched → fused-serial compute the same
//!   transform), and the poisoned plan key is quarantined so later
//!   requests skip straight to the degraded path.
//! * **Fault injection** — the [`super::fault`] chaos layer makes all of
//!   the above deterministically testable via `MDDCT_FAULT`.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{admit, run_batcher, Batch, BatchPolicy, InflightBudget, Pending};
use super::fault;
use super::metrics::Metrics;
use super::request::{PlanKey, Request, Response, TransformOp};
use super::router::{Route, Router};
use crate::parallel::{ExecPolicy, ShardPolicy};
use crate::util::error::TransformError;

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Dispatch worker threads (pull batches, hand stages to the shared
    /// pool). Defaults to `MDDCT_WORKERS`, else available parallelism —
    /// the configured value is always respected as-is by `start`.
    pub workers: usize,
    /// Dynamic-batching knobs (co-batching window, solo fast path).
    pub batch: BatchPolicy,
    /// Execution policy baked into native plans built by this service's
    /// router (the transform stages run on the shared process pool).
    pub exec: ExecPolicy,
    /// Band-shard policy for large native requests (applied per request
    /// through [`super::shard::decide`], which gates 2D and 3D requests
    /// on their own numel thresholds; small requests never force-shard).
    /// Defaults to the `MDDCT_SHARD_MIN_ROWS` / `MDDCT_MAX_SHARDS` env
    /// knobs, else `Auto`.
    pub shard: ShardPolicy,
    /// Enable cross-layer span tracing ([`crate::obs`]) when the service
    /// starts. `false` leaves the process-wide trace flag as-is (so the
    /// `MDDCT_TRACE` env knob still applies); `true` force-enables it.
    pub trace: bool,
    /// Deadline stamped on every request submitted without an explicit
    /// one (`submit` = now + this). Defaults to the `MDDCT_DEADLINE_MS`
    /// env knob, else `None` (no deadline).
    pub default_deadline: Option<Duration>,
    /// Admission-control cap on total in-flight payload elements
    /// (queued + executing), weighted like
    /// [`BatchPolicy::max_batch_elems`]. When an arrival would push past
    /// it, `submit` sheds with [`TransformError::Overloaded`]. Defaults
    /// to the `MDDCT_MAX_INFLIGHT` env knob, else
    /// [`DEFAULT_MAX_INFLIGHT_ELEMS`]; `usize::MAX` = unbounded.
    pub max_inflight_elems: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: default_workers(),
            batch: BatchPolicy::default(),
            exec: ExecPolicy::Auto,
            shard: ShardPolicy::from_env(),
            trace: false,
            default_deadline: default_deadline_from_env(),
            max_inflight_elems: default_max_inflight_elems(),
        }
    }
}

/// Worker-count default: `MDDCT_WORKERS` env override, else the
/// machine's available parallelism.
pub fn default_workers() -> usize {
    crate::parallel::policy::env_usize("MDDCT_WORKERS")
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4))
}

/// Default admission cap: 64 Mi in-flight payload elements (512 MiB of
/// f64 — a full co-batching window for every worker with headroom, far
/// below the point where queue memory endangers the process).
pub const DEFAULT_MAX_INFLIGHT_ELEMS: usize = 64 << 20;

/// Default request deadline: `MDDCT_DEADLINE_MS` env knob, else none.
pub fn default_deadline_from_env() -> Option<Duration> {
    crate::util::env_usize("MDDCT_DEADLINE_MS").map(|ms| Duration::from_millis(ms as u64))
}

/// Default admission cap: `MDDCT_MAX_INFLIGHT` env knob (elements), else
/// [`DEFAULT_MAX_INFLIGHT_ELEMS`].
pub fn default_max_inflight_elems() -> usize {
    crate::util::env_usize("MDDCT_MAX_INFLIGHT").unwrap_or(DEFAULT_MAX_INFLIGHT_ELEMS)
}

/// Per-request submission options beyond the payload itself. `Default`
/// gives an untenanted, normal-priority request with no deadline.
#[derive(Debug, Clone, Default)]
pub struct SubmitOptions {
    /// Absolute completion deadline (`None` = no deadline). Authoritative
    /// as given — [`Service::submit`] stamps the service default before
    /// delegating here.
    pub deadline: Option<Instant>,
    /// Tenant charged for the payload in the weighted fair-share
    /// admission budget; `None` bills the shared default bucket.
    pub tenant: Option<String>,
    /// Scheduling priority (higher = flushed first on a multi-key
    /// batcher drain).
    pub priority: u8,
}

/// Handle to an in-flight request. Dropping it without waiting marks
/// the request cancelled: the batcher/workers skip computing for it at
/// their next dequeue (counted as `dropped_replies`).
pub struct Handle {
    rx: Receiver<Result<Response, TransformError>>,
    cancelled: Arc<AtomicBool>,
}

impl Handle {
    /// Block until the transform completes.
    pub fn wait(self) -> Result<Response, TransformError> {
        // After recv returns, the request is already concluded, so the
        // cancellation flag Drop sets below is never read.
        self.rx.recv().map_err(|_| TransformError::ShuttingDown)?
    }
}

impl Drop for Handle {
    fn drop(&mut self) {
        self.cancelled.store(true, Ordering::Relaxed);
    }
}

/// The running transform service.
pub struct Service {
    req_tx: Option<Sender<Pending>>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    default_deadline: Option<Duration>,
    /// Live per-op counters/latency/batch/band metrics.
    pub metrics: Arc<Metrics>,
    /// The routing + plan-cache backend this service executes on.
    pub router: Arc<Router>,
    /// Elems-weighted admission budget (acquired by `submit`, released
    /// at every reply or drop).
    pub inflight: Arc<InflightBudget>,
}

impl Service {
    /// Start the service with `router` as the execution backend. The
    /// config's exec and shard policies are authoritative: they are
    /// applied to the router's native plan cache regardless of how the
    /// router was built.
    pub fn start(config: ServiceConfig, mut router: Router) -> Service {
        if config.trace {
            crate::obs::set_enabled(true);
        }
        // resolve MDDCT_FAULT eagerly so a malformed spec is reported at
        // startup, not at the first execution seam
        let _ = fault::enabled();
        router.set_exec_policy(config.exec);
        router.set_shard_policy(config.shard);
        let router = Arc::new(router);
        let metrics = Arc::new(Metrics::new());
        let inflight = Arc::new(InflightBudget::new(config.max_inflight_elems));
        let (req_tx, req_rx) = channel::<Pending>();
        let (batch_tx, batch_rx) = channel::<Batch>();
        let policy = config.batch;
        let batcher = {
            let metrics = metrics.clone();
            let budget = inflight.clone();
            std::thread::spawn(move || run_batcher(req_rx, batch_tx, policy, metrics, budget))
        };

        // Work distribution: workers pull batches from the shared queue.
        let shared_rx = Arc::new(Mutex::new(batch_rx));
        let mut workers = Vec::new();
        for w in 0..config.workers.max(1) {
            let rx = shared_rx.clone();
            let router = router.clone();
            let metrics = metrics.clone();
            let budget = inflight.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("mddct-worker-{w}"))
                    .spawn(move || worker_loop(rx, router, metrics, budget))
                    .expect("spawn worker"),
            );
        }
        Service {
            req_tx: Some(req_tx),
            batcher: Some(batcher),
            workers,
            next_id: AtomicU64::new(1),
            default_deadline: config.default_deadline,
            metrics,
            router,
            inflight,
        }
    }

    /// Start with the native backend only (the common configuration);
    /// the config's exec policy is threaded into the router's plans.
    pub fn start_native(config: ServiceConfig) -> Service {
        Self::start(config, Router::native_only())
    }

    /// Submit a transform; returns immediately with a wait handle. The
    /// request carries the service's default deadline (if configured).
    pub fn submit(
        &self,
        op: TransformOp,
        shape: Vec<usize>,
        data: Vec<f64>,
    ) -> Result<Handle, TransformError> {
        let deadline = self.default_deadline.map(|d| Instant::now() + d);
        self.submit_opts(op, shape, data, SubmitOptions { deadline, ..Default::default() })
    }

    /// Submit a transform with an explicit absolute deadline (`None` =
    /// no deadline, overriding the service default).
    pub fn submit_with_deadline(
        &self,
        op: TransformOp,
        shape: Vec<usize>,
        data: Vec<f64>,
        deadline: Option<Instant>,
    ) -> Result<Handle, TransformError> {
        self.submit_opts(op, shape, data, SubmitOptions { deadline, ..Default::default() })
    }

    /// Submit a transform with full per-request options (deadline,
    /// tenant, priority — all authoritative as given). Validation and
    /// admission control happen here, synchronously: a malformed request
    /// fails [`TransformError::InvalidRequest`], and one the inflight
    /// budget cannot admit — globally, or past its tenant's fair share —
    /// is shed [`TransformError::Overloaded`] without ever entering the
    /// queue, with a `retry_after` hint scaled to current budget
    /// occupancy.
    pub fn submit_opts(
        &self,
        op: TransformOp,
        shape: Vec<usize>,
        data: Vec<f64>,
        opts: SubmitOptions,
    ) -> Result<Handle, TransformError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let SubmitOptions { deadline, tenant, priority } = opts;
        let request = Request { id, op, shape, data, deadline, tenant, priority };
        request.validate()?;
        if let Some(t) = &request.tenant {
            self.metrics.record_tenant_submitted(t);
        }
        if !self.inflight.try_acquire_for(request.tenant_name(), request.data.len()) {
            self.metrics.record_shed(&op.name());
            if let Some(t) = &request.tenant {
                self.metrics.record_tenant_shed(t);
            }
            crate::obs::instant_event("svc.shed");
            return Err(TransformError::Overloaded { retry_after: self.inflight.retry_after() });
        }
        let (reply, rx) = channel();
        let pending = Pending::new(request, reply);
        let cancelled = pending.cancelled.clone();
        match self.req_tx.as_ref().expect("service running").send(pending) {
            Ok(()) => Ok(Handle { rx, cancelled }),
            Err(dead) => {
                self.inflight.release_for(dead.0.request.tenant_name(), dead.0.request.data.len());
                Err(TransformError::ShuttingDown)
            }
        }
    }

    /// The deadline stamped on requests submitted without an explicit
    /// one ([`ServiceConfig::default_deadline`]).
    pub fn default_deadline(&self) -> Option<Duration> {
        self.default_deadline
    }

    /// Submit and block for the result.
    pub fn transform(
        &self,
        op: TransformOp,
        shape: Vec<usize>,
        data: Vec<f64>,
    ) -> Result<Response, TransformError> {
        self.submit(op, shape, data)?.wait()
    }

    /// Submit many, wait for all (order preserved).
    pub fn transform_many(
        &self,
        reqs: Vec<(TransformOp, Vec<usize>, Vec<f64>)>,
    ) -> Result<Vec<Response>, TransformError> {
        let handles: Result<Vec<Handle>, TransformError> = reqs
            .into_iter()
            .map(|(op, shape, data)| self.submit(op, shape, data))
            .collect();
        handles?.into_iter().map(Handle::wait).collect()
    }

    /// Full observability snapshot: the metrics JSON (per-op counters,
    /// `_sharding_by_rank`, `_scratch`, and — when tracing has recorded
    /// stage spans — the live `_stage_breakdown` table) merged with a
    /// `_plan_cache` section carrying this service's native plan-cache
    /// hit/miss/quarantine counters and resident plan count, and an
    /// `_admission` section with the inflight budget's cap and current
    /// occupancy.
    pub fn snapshot(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        use std::collections::BTreeMap;
        let mut root = match self.metrics.snapshot() {
            Json::Obj(o) => o,
            other => BTreeMap::from([("_metrics".to_string(), other)]),
        };
        let stats = self.router.plans.stats();
        let mut pc = BTreeMap::new();
        pc.insert("hits".to_string(), Json::Num(stats.hits as f64));
        pc.insert("misses".to_string(), Json::Num(stats.misses as f64));
        pc.insert("quarantined".to_string(), Json::Num(stats.quarantined as f64));
        pc.insert("plans".to_string(), Json::Num(self.router.plans.len() as f64));
        root.insert("_plan_cache".to_string(), Json::Obj(pc));
        let mut adm = BTreeMap::new();
        adm.insert(
            "max_inflight_elems".to_string(),
            Json::Num(self.inflight.max_elems() as f64),
        );
        adm.insert("inflight_elems".to_string(), Json::Num(self.inflight.in_use() as f64));
        root.insert("_admission".to_string(), Json::Obj(adm));
        Json::Obj(root)
    }

    /// [`Service::snapshot`] with extra `_`-prefixed sections merged in
    /// — the seam a front-end uses to publish its own counters (the TCP
    /// server adds `_server`) in the same document as the coordinator
    /// metrics, so one `/metrics`-style route covers every layer.
    pub fn snapshot_with(
        &self,
        sections: &[(&str, crate::util::json::Json)],
    ) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut root = match self.snapshot() {
            Json::Obj(o) => o,
            other => std::collections::BTreeMap::from([("_metrics".to_string(), other)]),
        };
        for (name, section) in sections {
            root.insert((*name).to_string(), section.clone());
        }
        Json::Obj(root)
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        // closing the request channel winds down batcher then workers
        self.req_tx.take();
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Render a caught worker panic as a typed request error.
fn panic_message(op: &str, panic: Box<dyn std::any::Any + Send>) -> TransformError {
    let what = panic
        .downcast_ref::<&'static str>()
        .map(|s| (*s).to_string())
        .or_else(|| panic.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string());
    TransformError::ExecutionPanicked(format!("worker panicked executing {op}: {what}"))
}

/// Answer one request on the degraded serial plan — the one-shot retry
/// after a primary failure (`primary = Some(..)`, counted
/// `retried_degraded`) or the direct serving path for a quarantined key
/// (`primary = None`). Never injects faults: the degradation lattice
/// bottoms out here, deterministically. If even this path fails, the
/// request is failed with the *primary* error (it names the plan that
/// actually poisoned the key). Releases the request's inflight budget.
#[allow(clippy::too_many_arguments)]
fn serve_degraded(
    key: &PlanKey,
    pending: Pending,
    router: &Router,
    metrics: &Metrics,
    op_name: &str,
    rank: usize,
    budget: &InflightBudget,
    primary: Option<TransformError>,
) {
    let retry = primary.is_some();
    if retry {
        crate::obs::instant_event("svc.retry_degraded");
    }
    let elems = pending.request.data.len();
    let result = {
        let _s = crate::obs::SpanGuard::begin("svc.execute_degraded");
        catch_unwind(AssertUnwindSafe(|| router.execute_degraded(key, &pending.request.data)))
            .map_err(|panic| panic_message(op_name, panic))
    };
    // release before replying so a client that resubmits the moment
    // `wait` returns is never spuriously shed by budget still held here
    budget.release_for(pending.request.tenant_name(), elems);
    match result {
        Ok(output) => {
            if retry {
                metrics.record_retried_degraded(op_name);
            }
            let latency = pending.enqueued.elapsed().as_secs_f64();
            metrics.record(op_name, rank, latency, 1, 1);
            if let Some(t) = &pending.request.tenant {
                metrics.record_tenant_done(t, latency);
            }
            let sent = pending.reply.send(Ok(Response {
                id: pending.request.id,
                output,
                backend: "native-degraded",
                latency,
                batch_size: 1,
            }));
            if sent.is_err() {
                metrics.record_dropped_reply(op_name);
            }
        }
        Err(degraded) => {
            metrics.record_error(op_name);
            if pending.reply.send(Err(primary.unwrap_or(degraded))).is_err() {
                metrics.record_dropped_reply(op_name);
            }
        }
    }
}

/// Execute a multi-request batch through the packed stage-fused path:
/// run one batched plan call (each transform stage sweeps the whole
/// batch), scatter the outputs back to the per-request reply channels.
/// Ops whose plans take per-request views
/// ([`TransformOp::supports_batch_views`]) skip the input pack copy
/// entirely — the request payloads are borrowed in place and fed to
/// `execute_batch_views` (counted by the `packed_zero_copy` metric);
/// everything else packs the payloads contiguously first and runs
/// `execute_batch`. A panic or error quarantines the key and retries
/// every affected request once, individually, on the degraded serial
/// plan (`pack` and `execute_batch` fault seams, both paths).
#[allow(clippy::too_many_arguments)]
fn execute_packed(
    key: PlanKey,
    items: Vec<Pending>,
    router: &Router,
    metrics: &Metrics,
    op_name: &str,
    rank: usize,
    bands: usize,
    budget: &InflightBudget,
) {
    let numel: usize = key.shape.iter().product();
    let n = items.len();
    for p in &items {
        crate::obs::span_since("svc.queue_wait", p.enqueued);
    }
    let zero_copy = key.op.supports_batch_views();
    let result = {
        let _s = crate::obs::SpanGuard::begin("svc.execute_batch");
        catch_unwind(AssertUnwindSafe(|| {
            fault::fire("pack", op_name)?;
            if zero_copy {
                // borrow the payloads in place — no pack copy at all
                let views: Vec<&[f64]> =
                    items.iter().map(|p| p.request.data.as_slice()).collect();
                fault::fire("execute_batch", op_name)?;
                router.execute_batch_views(&key, &views)
            } else {
                let mut packed = Vec::with_capacity(n * numel);
                {
                    let _s = crate::obs::SpanGuard::begin("svc.pack");
                    for p in &items {
                        packed.extend_from_slice(&p.request.data);
                    }
                }
                fault::fire("execute_batch", op_name)?;
                router.execute_batch(&key, &packed, n)
            }
        }))
        .unwrap_or_else(|panic| Err(panic_message(op_name, panic)))
    };
    match result {
        Ok((output, route)) => {
            let _s = crate::obs::SpanGuard::begin("svc.scatter");
            metrics.record_packed(op_name, n);
            if zero_copy {
                metrics.record_packed_zero_copy(op_name);
            }
            for (i, pending) in items.into_iter().enumerate() {
                let latency = pending.enqueued.elapsed().as_secs_f64();
                metrics.record(op_name, rank, latency, n, bands);
                if let Some(t) = &pending.request.tenant {
                    metrics.record_tenant_done(t, latency);
                }
                budget.release_for(pending.request.tenant_name(), pending.request.data.len());
                let sent = pending.reply.send(Ok(Response {
                    id: pending.request.id,
                    output: output[i * numel..(i + 1) * numel].to_vec(),
                    backend: route.label(),
                    latency,
                    batch_size: n,
                }));
                if sent.is_err() {
                    metrics.record_dropped_reply(op_name);
                }
            }
        }
        Err(primary) => {
            // the packed path only runs on the native route, so the
            // poisoned key is always a native plan key
            router.quarantine(&key);
            for pending in items {
                serve_degraded(
                    &key,
                    pending,
                    router,
                    metrics,
                    op_name,
                    rank,
                    budget,
                    Some(primary.clone()),
                );
            }
        }
    }
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<Batch>>>,
    router: Arc<Router>,
    metrics: Arc<Metrics>,
    budget: Arc<InflightBudget>,
) {
    loop {
        // hold the lock only while receiving, not while executing
        let batch = match rx.lock().unwrap().recv() {
            Ok(b) => b,
            Err(_) => return,
        };
        let op_name = batch.key.op.name();
        let rank = batch.key.op.rank();
        // (op, shape) context for the duration of this batch: stage
        // spans recorded on this thread (plan pre/fft/post, the svc.*
        // pipeline spans) aggregate into the live per-(op,shape)
        // breakdown under this label
        let _ctx = crate::obs::with_ctx(crate::obs::op_ctx(&op_name, &batch.key.shape));
        // lifecycle re-gate at execution time: a deadline may have
        // passed (or a client hung up) while the batch sat queued
        let key = batch.key;
        let items: Vec<Pending> =
            batch.items.into_iter().filter_map(|p| admit(p, &metrics, &budget)).collect();
        if items.is_empty() {
            continue;
        }
        let n = items.len();
        // explicit shard fan-out of this batch (1 = unsharded; plain
        // Auto lane parallelism is not counted as sharding); recorded
        // so operators can see the shard feature actually engage.
        // PJRT batches run on the artifact, not the banded native plan.
        let route = router.route(&key);
        let bands = match route {
            Route::Native => router.shard_bands(&key),
            Route::Pjrt => 1,
        };
        // a quarantined native key skips its poisoned primary plan and
        // serves every request straight from the degraded serial one
        if route == Route::Native && router.is_quarantined(&key) {
            for pending in items {
                serve_degraded(&key, pending, &router, &metrics, &op_name, rank, &budget, None);
            }
            continue;
        }
        // a multi-request native batch of a stage-fused op executes
        // packed: one buffer, one batched plan call, outputs scattered.
        // Requests an explicit shard policy would band (bands > 1) stay
        // on the per-item path — forward_batch does not apply the shard
        // decomposition, and the metrics' band count must stay truthful
        // (in practice the batcher's solo fast path already flushes
        // shard-gate-sized requests alone, so this gate rarely bites).
        if n > 1 && route == Route::Native && bands <= 1 && key.op.supports_batch() {
            execute_packed(key, items, &router, &metrics, &op_name, rank, bands, &budget);
            continue;
        }
        for pending in items {
            let t0 = pending.enqueued;
            crate::obs::span_since("svc.queue_wait", t0);
            // A panicking plan must not kill the worker (which would
            // strand every queued batch): catch it, quarantine the
            // poisoned key, and retry once on the degraded serial plan
            // (the `execute` fault seam fires before the primary call).
            let result = {
                let _s = crate::obs::SpanGuard::begin("svc.execute");
                catch_unwind(AssertUnwindSafe(|| {
                    fault::fire("execute", &op_name)?;
                    router.execute(&key, &pending.request.data)
                }))
                .unwrap_or_else(|panic| Err(panic_message(&op_name, panic)))
            };
            match result {
                Ok((output, route)) => {
                    let latency = t0.elapsed().as_secs_f64();
                    metrics.record(&op_name, rank, latency, n, bands);
                    if let Some(t) = &pending.request.tenant {
                        metrics.record_tenant_done(t, latency);
                    }
                    budget.release_for(pending.request.tenant_name(), pending.request.data.len());
                    let sent = pending.reply.send(Ok(Response {
                        id: pending.request.id,
                        output,
                        backend: route.label(),
                        latency,
                        batch_size: n,
                    }));
                    if sent.is_err() {
                        metrics.record_dropped_reply(&op_name);
                    }
                }
                Err(primary) => {
                    if route == Route::Native {
                        router.quarantine(&key);
                    }
                    serve_degraded(
                        &key,
                        pending,
                        &router,
                        &metrics,
                        &op_name,
                        rank,
                        &budget,
                        Some(primary),
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::direct::{dct2d_direct, idct2d_direct};
    use crate::util::prop::check_close;
    use crate::util::rng::Rng;

    fn svc(workers: usize) -> Service {
        Service::start_native(ServiceConfig {
            workers,
            batch: BatchPolicy::default(),
            exec: crate::parallel::ExecPolicy::Auto,
            shard: ShardPolicy::Auto,
            trace: false,
            default_deadline: None,
            max_inflight_elems: usize::MAX,
        })
    }

    #[test]
    fn transform_roundtrip() {
        let s = svc(2);
        let mut rng = Rng::new(200);
        let x = rng.normal_vec(12 * 12);
        let r = s.transform(TransformOp::Dct2d, vec![12, 12], x.clone()).unwrap();
        check_close(&r.output, &dct2d_direct(&x, 12, 12), 1e-9).unwrap();
        assert_eq!(r.backend, "native");
        let back = s
            .transform(TransformOp::Idct2d, vec![12, 12], r.output.clone())
            .unwrap();
        check_close(&back.output, &x, 1e-9).unwrap();
        assert!(s.metrics.total_requests() >= 2);
        // every answered request returned its admission budget
        assert_eq!(s.inflight.in_use(), 0);
    }

    #[test]
    fn rejects_invalid_requests() {
        let s = svc(1);
        assert!(matches!(
            s.transform(TransformOp::Dct2d, vec![4], vec![0.0; 4]),
            Err(TransformError::InvalidRequest(_))
        ));
        assert!(matches!(
            s.transform(TransformOp::Dct2d, vec![4, 4], vec![0.0; 3]),
            Err(TransformError::InvalidRequest(_))
        ));
        // invalid requests never hold budget
        assert_eq!(s.inflight.in_use(), 0);
    }

    #[test]
    fn many_concurrent_requests_no_loss() {
        let s = svc(4);
        let mut rng = Rng::new(201);
        let mut reqs = Vec::new();
        let mut wants = Vec::new();
        for i in 0..64 {
            let (n1, n2) = if i % 2 == 0 { (8, 8) } else { (6, 10) };
            let x = rng.normal_vec(n1 * n2);
            wants.push(dct2d_direct(&x, n1, n2));
            reqs.push((TransformOp::Dct2d, vec![n1, n2], x));
        }
        let out = s.transform_many(reqs).unwrap();
        assert_eq!(out.len(), 64);
        for (r, w) in out.iter().zip(&wants) {
            check_close(&r.output, w, 1e-9).unwrap();
        }
        // same-shape requests must have been co-batched at least once
        let snap = s.metrics.snapshot();
        let mb = snap
            .get("dct2d")
            .and_then(|d| d.get("max_batch"))
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!(mb >= 1.0);
    }

    #[test]
    fn mixed_ops_route_correctly() {
        let s = svc(2);
        let mut rng = Rng::new(202);
        let x = rng.normal_vec(9 * 9);
        let a = s.transform(TransformOp::IdctIdxst, vec![9, 9], x.clone()).unwrap();
        let b = s.transform(TransformOp::RcIdct2d, vec![9, 9], x.clone()).unwrap();
        assert!(a.output.iter().all(|v| v.is_finite()));
        check_close(&b.output, &idct2d_direct(&x, 9, 9), 1e-9).unwrap();
    }

    #[test]
    fn shutdown_is_clean() {
        let s = svc(2);
        let _ = s.transform(TransformOp::Dct2d, vec![4, 4], vec![1.0; 16]);
        drop(s); // must not hang or panic
    }

    #[test]
    fn expired_deadline_is_answered_without_executing() {
        let s = svc(1);
        // a deadline already in the past: the batcher concludes it at
        // dequeue — deterministic, no timing race
        let h = s
            .submit_with_deadline(
                TransformOp::Dct2d,
                vec![4, 4],
                vec![1.0; 16],
                Some(Instant::now() - Duration::from_millis(1)),
            )
            .unwrap();
        assert!(matches!(h.wait(), Err(TransformError::DeadlineExceeded)));
        let snap = s.snapshot();
        let expired = snap
            .get("dct2d")
            .and_then(|d| d.get("expired_requests"))
            .and_then(|v| v.as_f64())
            .unwrap();
        assert_eq!(expired, 1.0);
        assert_eq!(s.inflight.in_use(), 0);
    }

    #[test]
    fn saturated_budget_sheds_with_overloaded() {
        // budget smaller than a single request: every submit sheds,
        // deterministically
        let s = Service::start_native(ServiceConfig {
            workers: 1,
            batch: BatchPolicy::default(),
            exec: crate::parallel::ExecPolicy::Serial,
            shard: ShardPolicy::Auto,
            trace: false,
            default_deadline: None,
            max_inflight_elems: 8,
        });
        let err = s.transform(TransformOp::Dct2d, vec![4, 4], vec![1.0; 16]).unwrap_err();
        assert!(matches!(err, TransformError::Overloaded { .. }));
        assert!(err.is_retryable());
        let snap = s.snapshot();
        let shed = snap
            .get("dct2d")
            .and_then(|d| d.get("shed_requests"))
            .and_then(|v| v.as_f64())
            .unwrap();
        assert_eq!(shed, 1.0);
        let adm = snap.get("_admission").unwrap();
        assert_eq!(adm.get("max_inflight_elems").unwrap().as_f64().unwrap(), 8.0);
        assert_eq!(adm.get("inflight_elems").unwrap().as_f64().unwrap(), 0.0);
        // a request that fits still goes through
        let ok = s.transform(TransformOp::Dct2d, vec![2, 2], vec![1.0; 4]).unwrap();
        assert_eq!(ok.output.len(), 4);
    }

    #[test]
    fn tenanted_requests_flow_and_surface_in_metrics() {
        let s = svc(2);
        let mut rng = Rng::new(206);
        let x = rng.normal_vec(8 * 8);
        let opts = SubmitOptions { tenant: Some("alice".into()), priority: 2, ..Default::default() };
        let h = s.submit_opts(TransformOp::Dct2d, vec![8, 8], x.clone(), opts).unwrap();
        let r = h.wait().unwrap();
        check_close(&r.output, &dct2d_direct(&x, 8, 8), 1e-9).unwrap();
        assert_eq!(s.inflight.in_use(), 0);
        let snap = s.snapshot();
        let a = snap.get("_tenants").and_then(|t| t.get("alice")).unwrap();
        assert_eq!(a.get("submitted").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(a.get("completed").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(a.get("shed_requests").unwrap().as_f64().unwrap(), 0.0);
        // untenanted traffic adds no tenant row
        let _ = s.transform(TransformOp::Dct2d, vec![4, 4], vec![1.0; 16]).unwrap();
        let snap = s.snapshot();
        let tenants = snap.get("_tenants").unwrap();
        assert!(tenants.get("alice").is_some());
        assert!(tenants.get("default").is_none());
    }

    #[test]
    fn shed_retry_after_scales_with_occupancy() {
        // budget smaller than the request: the shed hint on an empty
        // budget is the floor; a fuller budget hints a longer backoff
        let s = Service::start_native(ServiceConfig {
            workers: 1,
            batch: BatchPolicy::default(),
            exec: crate::parallel::ExecPolicy::Serial,
            shard: ShardPolicy::Auto,
            trace: false,
            default_deadline: None,
            max_inflight_elems: 8,
        });
        let err = s.transform(TransformOp::Dct2d, vec![4, 4], vec![1.0; 16]).unwrap_err();
        let TransformError::Overloaded { retry_after: empty_hint } = err else {
            panic!("expected Overloaded, got {err:?}");
        };
        assert_eq!(empty_hint, s.inflight.retry_after());
        assert!(s.inflight.try_acquire(8));
        assert!(s.inflight.retry_after() > empty_hint);
        s.inflight.release(8);
    }

    #[test]
    fn worker_panic_becomes_request_error_and_worker_survives() {
        use super::super::batcher::{Batch, Pending};
        use super::super::request::{PlanKey, Request};
        use std::sync::mpsc::channel;

        let router = Arc::new(Router::native_only());
        let metrics = Arc::new(Metrics::new());
        let budget = Arc::new(InflightBudget::unlimited());
        let (batch_tx, batch_rx) = channel::<Batch>();
        let shared_rx = Arc::new(Mutex::new(batch_rx));
        let worker = {
            let rx = shared_rx.clone();
            let router = router.clone();
            let metrics = metrics.clone();
            let budget = budget.clone();
            std::thread::spawn(move || worker_loop(rx, router, metrics, budget))
        };

        // A rank-mismatched key slips past validate only if constructed
        // by hand; plan building then panics inside the worker — on the
        // primary plan AND on the degraded retry, so the request fails
        // with the primary panic error.
        let (reply_bad, rx_bad) = channel();
        batch_tx
            .send(Batch {
                key: PlanKey::new(TransformOp::Dct2d, vec![4]),
                items: vec![Pending::new(
                    Request {
                        id: 1,
                        op: TransformOp::Dct2d,
                        shape: vec![4],
                        data: vec![0.0; 4],
                        deadline: None,
                        tenant: None,
                        priority: 0,
                    },
                    reply_bad,
                )],
            })
            .unwrap();
        let bad = rx_bad.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        let err = bad.expect_err("panicking plan must surface as an error");
        assert!(err.to_string().contains("panicked"), "got: {err}");
        // the poisoned key is quarantined for later requests
        assert!(router.is_quarantined(&PlanKey::new(TransformOp::Dct2d, vec![4])));

        // the same worker thread must still serve well-formed batches
        let (reply_ok, rx_ok) = channel();
        let mut rng = Rng::new(203);
        let x = rng.normal_vec(16);
        batch_tx
            .send(Batch {
                key: PlanKey::new(TransformOp::Dct2d, vec![4, 4]),
                items: vec![Pending::new(
                    Request {
                        id: 2,
                        op: TransformOp::Dct2d,
                        shape: vec![4, 4],
                        data: x.clone(),
                        deadline: None,
                        tenant: None,
                        priority: 0,
                    },
                    reply_ok,
                )],
            })
            .unwrap();
        let ok = rx_ok.recv_timeout(std::time::Duration::from_secs(5)).unwrap().unwrap();
        check_close(&ok.output, &dct2d_direct(&x, 4, 4), 1e-9).unwrap();
        drop(batch_tx);
        worker.join().expect("worker exits cleanly after channel close");
    }

    #[test]
    fn sharded_large_request_coschedules_with_small_ones() {
        // one above-threshold request sharded into bands + a stream of
        // small requests: everything completes, answers are exact, and
        // the metrics show the large op actually ran sharded
        let s = Service::start_native(ServiceConfig {
            workers: 2,
            batch: BatchPolicy::default(),
            exec: crate::parallel::ExecPolicy::Serial,
            shard: ShardPolicy::MaxShards(3),
            trace: false,
            default_deadline: None,
            max_inflight_elems: usize::MAX,
        });
        let mut rng = Rng::new(205);
        let (n1, n2) = (256usize, 260usize); // >= SHARD_MIN_NUMEL, non-divisible by 3
        let big = rng.normal_vec(n1 * n2);
        let big_handle = s.submit(TransformOp::Idct2d, vec![n1, n2], big.clone()).unwrap();
        let mut small_reqs = Vec::new();
        let mut wants = Vec::new();
        for _ in 0..12 {
            let x = rng.normal_vec(8 * 8);
            wants.push(dct2d_direct(&x, 8, 8));
            small_reqs.push((TransformOp::Dct2d, vec![8usize, 8usize], x));
        }
        let small_out = s.transform_many(small_reqs).unwrap();
        for (r, w) in small_out.iter().zip(&wants) {
            check_close(&r.output, w, 1e-9).unwrap();
        }
        let big_out = big_handle.wait().unwrap();
        // sharded output must match a single-band serial plan to <= 1e-10
        let mut want_big = vec![0.0; n1 * n2];
        crate::dct::Idct2::with_policy(n1, n2, crate::parallel::ExecPolicy::Serial)
            .forward(&big, &mut want_big);
        check_close(&big_out.output, &want_big, 1e-10).unwrap();
        let snap = s.metrics.snapshot();
        let bands = snap
            .get("idct2d")
            .and_then(|d| d.get("max_bands"))
            .and_then(|v| v.as_f64())
            .unwrap();
        assert_eq!(bands, 3.0, "large idct2d should have run as 3 band shards");
        let small_bands = snap
            .get("dct2d")
            .and_then(|d| d.get("max_bands"))
            .and_then(|v| v.as_f64())
            .unwrap();
        assert_eq!(small_bands, 1.0, "small requests must stay unsharded");
    }

    #[test]
    fn service_is_shareable_across_connection_threads() {
        // the TCP front-end holds the service in an Arc and submits
        // from per-connection threads; that requires Send + Sync
        // (mpsc::Sender is Sync since Rust 1.72)
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Service>();
        assert_send_sync::<Arc<Service>>();
    }

    #[test]
    fn snapshot_with_merges_extra_sections() {
        use crate::util::json::Json;
        let s = svc(1);
        let mut section = std::collections::BTreeMap::new();
        section.insert("frames_in".to_string(), Json::Num(3.0));
        let snap = s.snapshot_with(&[("_server", Json::Obj(section))]);
        // the extra section and the stock ones coexist
        let srv = snap.get("_server").unwrap();
        assert_eq!(srv.get("frames_in").unwrap().as_f64().unwrap(), 3.0);
        assert!(snap.get("_admission").is_some());
        assert!(snap.get("_plan_cache").is_some());
    }

    #[test]
    fn config_worker_count_is_respected() {
        // 1 worker must still drain many requests (no hidden
        // available_parallelism override)
        let s = svc(1);
        let mut rng = Rng::new(204);
        let reqs: Vec<_> = (0..16)
            .map(|_| (TransformOp::Dct2d, vec![8usize, 8usize], rng.normal_vec(64)))
            .collect();
        let out = s.transform_many(reqs).unwrap();
        assert_eq!(out.len(), 16);
    }
}
