//! The transform service: the L3 coordinator facade.
//!
//! Architecture (std-thread substitute for the usual tokio stack — the
//! offline crate set has no async runtime):
//!
//! ```text
//!  submit() ──> request mpsc ──> batcher thread ──> batch mpsc ──┐
//!                                                                ▼
//!                                                      worker pool (N threads)
//!                                                                │
//!  Handle::wait() <── per-request reply channel <────────────────┘
//! ```
//!
//! Workers execute batches through the [`Router`] (native plans or PJRT
//! artifacts) and record metrics. Shape-specialized plans are cached, so
//! steady-state request cost is transform + channel hops only.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::batcher::{run_batcher, Batch, BatchPolicy, Pending};
use super::metrics::Metrics;
use super::request::{Request, Response, TransformOp};
use super::router::{Route, Router};
use crate::parallel::{ExecPolicy, ShardPolicy};

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Dispatch worker threads (pull batches, hand stages to the shared
    /// pool). Defaults to `MDDCT_WORKERS`, else available parallelism —
    /// the configured value is always respected as-is by `start`.
    pub workers: usize,
    /// Dynamic-batching knobs (co-batching window, solo fast path).
    pub batch: BatchPolicy,
    /// Execution policy baked into native plans built by this service's
    /// router (the transform stages run on the shared process pool).
    pub exec: ExecPolicy,
    /// Band-shard policy for large native requests (applied per request
    /// through [`super::shard::decide`], which gates 2D and 3D requests
    /// on their own numel thresholds; small requests never force-shard).
    /// Defaults to the `MDDCT_SHARD_MIN_ROWS` / `MDDCT_MAX_SHARDS` env
    /// knobs, else `Auto`.
    pub shard: ShardPolicy,
    /// Enable cross-layer span tracing ([`crate::obs`]) when the service
    /// starts. `false` leaves the process-wide trace flag as-is (so the
    /// `MDDCT_TRACE` env knob still applies); `true` force-enables it.
    pub trace: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: default_workers(),
            batch: BatchPolicy::default(),
            exec: ExecPolicy::Auto,
            shard: ShardPolicy::from_env(),
            trace: false,
        }
    }
}

/// Worker-count default: `MDDCT_WORKERS` env override, else the
/// machine's available parallelism.
pub fn default_workers() -> usize {
    crate::parallel::policy::env_usize("MDDCT_WORKERS")
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4))
}

/// Handle to an in-flight request.
pub struct Handle {
    rx: Receiver<Result<Response, String>>,
}

impl Handle {
    /// Block until the transform completes.
    pub fn wait(self) -> Result<Response, String> {
        self.rx.recv().map_err(|_| "service shut down".to_string())?
    }
}

/// The running transform service.
pub struct Service {
    req_tx: Option<Sender<Pending>>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    /// Live per-op counters/latency/batch/band metrics.
    pub metrics: Arc<Metrics>,
    /// The routing + plan-cache backend this service executes on.
    pub router: Arc<Router>,
}

impl Service {
    /// Start the service with `router` as the execution backend. The
    /// config's exec and shard policies are authoritative: they are
    /// applied to the router's native plan cache regardless of how the
    /// router was built.
    pub fn start(config: ServiceConfig, mut router: Router) -> Service {
        if config.trace {
            crate::obs::set_enabled(true);
        }
        router.set_exec_policy(config.exec);
        router.set_shard_policy(config.shard);
        let router = Arc::new(router);
        let metrics = Arc::new(Metrics::new());
        let (req_tx, req_rx) = channel::<Pending>();
        let (batch_tx, batch_rx) = channel::<Batch>();
        let policy = config.batch;
        let batcher =
            std::thread::spawn(move || run_batcher(req_rx, batch_tx, policy));

        // Work distribution: workers pull batches from the shared queue.
        let shared_rx = Arc::new(Mutex::new(batch_rx));
        let mut workers = Vec::new();
        for w in 0..config.workers.max(1) {
            let rx = shared_rx.clone();
            let router = router.clone();
            let metrics = metrics.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("mddct-worker-{w}"))
                    .spawn(move || worker_loop(rx, router, metrics))
                    .expect("spawn worker"),
            );
        }
        Service {
            req_tx: Some(req_tx),
            batcher: Some(batcher),
            workers,
            next_id: AtomicU64::new(1),
            metrics,
            router,
        }
    }

    /// Start with the native backend only (the common configuration);
    /// the config's exec policy is threaded into the router's plans.
    pub fn start_native(config: ServiceConfig) -> Service {
        Self::start(config, Router::native_only())
    }

    /// Submit a transform; returns immediately with a wait handle.
    pub fn submit(
        &self,
        op: TransformOp,
        shape: Vec<usize>,
        data: Vec<f64>,
    ) -> Result<Handle, String> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let request = Request { id, op, shape, data };
        request.validate()?;
        let (reply, rx) = channel();
        self.req_tx
            .as_ref()
            .expect("service running")
            .send(Pending { request, reply, enqueued: Instant::now() })
            .map_err(|_| "service shut down".to_string())?;
        Ok(Handle { rx })
    }

    /// Submit and block for the result.
    pub fn transform(
        &self,
        op: TransformOp,
        shape: Vec<usize>,
        data: Vec<f64>,
    ) -> Result<Response, String> {
        self.submit(op, shape, data)?.wait()
    }

    /// Submit many, wait for all (order preserved).
    pub fn transform_many(
        &self,
        reqs: Vec<(TransformOp, Vec<usize>, Vec<f64>)>,
    ) -> Result<Vec<Response>, String> {
        let handles: Result<Vec<Handle>, String> = reqs
            .into_iter()
            .map(|(op, shape, data)| self.submit(op, shape, data))
            .collect();
        handles?.into_iter().map(Handle::wait).collect()
    }

    /// Full observability snapshot: the metrics JSON (per-op counters,
    /// `_sharding_by_rank`, `_scratch`, and — when tracing has recorded
    /// stage spans — the live `_stage_breakdown` table) merged with a
    /// `_plan_cache` section carrying this service's native plan-cache
    /// hit/miss counters and resident plan count.
    pub fn snapshot(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        use std::collections::BTreeMap;
        let mut root = match self.metrics.snapshot() {
            Json::Obj(o) => o,
            other => BTreeMap::from([("_metrics".to_string(), other)]),
        };
        let stats = self.router.plans.stats();
        let mut pc = BTreeMap::new();
        pc.insert("hits".to_string(), Json::Num(stats.hits as f64));
        pc.insert("misses".to_string(), Json::Num(stats.misses as f64));
        pc.insert("plans".to_string(), Json::Num(self.router.plans.len() as f64));
        root.insert("_plan_cache".to_string(), Json::Obj(pc));
        Json::Obj(root)
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        // closing the request channel winds down batcher then workers
        self.req_tx.take();
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Render a caught worker panic as a request error string.
fn panic_message(op: &str, panic: Box<dyn std::any::Any + Send>) -> String {
    let what = panic
        .downcast_ref::<&'static str>()
        .map(|s| (*s).to_string())
        .or_else(|| panic.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string());
    format!("worker panicked executing {op}: {what}")
}

/// Execute a multi-request batch through the packed stage-fused path:
/// pack the payloads contiguously, run one `execute_batch` (each
/// transform stage sweeps the whole batch), scatter the outputs back to
/// the per-request reply channels. A panic or error fails every request
/// in the batch, like any backend failure would.
fn execute_packed(
    batch: Batch,
    router: &Router,
    metrics: &Metrics,
    op_name: &str,
    rank: usize,
    bands: usize,
) {
    let numel: usize = batch.key.shape.iter().product();
    let n = batch.items.len();
    for p in &batch.items {
        crate::obs::span_since("svc.queue_wait", p.enqueued);
    }
    let mut packed = Vec::with_capacity(n * numel);
    {
        let _s = crate::obs::SpanGuard::begin("svc.pack");
        for p in &batch.items {
            packed.extend_from_slice(&p.request.data);
        }
    }
    let result = {
        let _s = crate::obs::SpanGuard::begin("svc.execute_batch");
        catch_unwind(AssertUnwindSafe(|| router.execute_batch(&batch.key, &packed, n)))
            .unwrap_or_else(|panic| Err(panic_message(op_name, panic)))
    };
    match result {
        Ok((output, route)) => {
            let _s = crate::obs::SpanGuard::begin("svc.scatter");
            metrics.record_packed(op_name, n);
            for (i, pending) in batch.items.into_iter().enumerate() {
                let latency = pending.enqueued.elapsed().as_secs_f64();
                metrics.record(op_name, rank, latency, n, bands);
                let _ = pending.reply.send(Ok(Response {
                    id: pending.request.id,
                    output: output[i * numel..(i + 1) * numel].to_vec(),
                    backend: route.label(),
                    latency,
                    batch_size: n,
                }));
            }
        }
        Err(e) => {
            for pending in batch.items {
                metrics.record_error(op_name);
                let _ = pending.reply.send(Err(e.clone()));
            }
        }
    }
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<Batch>>>,
    router: Arc<Router>,
    metrics: Arc<Metrics>,
) {
    loop {
        // hold the lock only while receiving, not while executing
        let batch = match rx.lock().unwrap().recv() {
            Ok(b) => b,
            Err(_) => return,
        };
        let n = batch.items.len();
        let op_name = batch.key.op.name();
        let rank = batch.key.op.rank();
        // (op, shape) context for the duration of this batch: stage
        // spans recorded on this thread (plan pre/fft/post, the svc.*
        // pipeline spans) aggregate into the live per-(op,shape)
        // breakdown under this label
        let _ctx = crate::obs::with_ctx(crate::obs::op_ctx(&op_name, &batch.key.shape));
        // explicit shard fan-out of this batch (1 = unsharded; plain
        // Auto lane parallelism is not counted as sharding); recorded
        // so operators can see the shard feature actually engage.
        // PJRT batches run on the artifact, not the banded native plan.
        let route = router.route(&batch.key);
        let bands = match route {
            Route::Native => router.shard_bands(&batch.key),
            Route::Pjrt => 1,
        };
        // a multi-request native batch of a stage-fused op executes
        // packed: one buffer, one batched plan call, outputs scattered.
        // Requests an explicit shard policy would band (bands > 1) stay
        // on the per-item path — forward_batch does not apply the shard
        // decomposition, and the metrics' band count must stay truthful
        // (in practice the batcher's solo fast path already flushes
        // shard-gate-sized requests alone, so this gate rarely bites).
        if n > 1 && route == Route::Native && bands <= 1 && batch.key.op.supports_batch() {
            execute_packed(batch, &router, &metrics, &op_name, rank, bands);
            continue;
        }
        for pending in batch.items {
            let t0 = pending.enqueued;
            crate::obs::span_since("svc.queue_wait", t0);
            // A panicking plan must not kill the worker (which would
            // strand every queued batch): catch it and surface it as a
            // request error, like any backend failure.
            let result = {
                let _s = crate::obs::SpanGuard::begin("svc.execute");
                catch_unwind(AssertUnwindSafe(|| {
                    router.execute(&batch.key, &pending.request.data)
                }))
                .unwrap_or_else(|panic| Err(panic_message(&op_name, panic)))
            };
            let latency = t0.elapsed().as_secs_f64();
            let response = match result {
                Ok((output, route)) => {
                    metrics.record(&op_name, rank, latency, n, bands);
                    Ok(Response {
                        id: pending.request.id,
                        output,
                        backend: route.label(),
                        latency,
                        batch_size: n,
                    })
                }
                Err(e) => {
                    metrics.record_error(&op_name);
                    Err(e)
                }
            };
            let _ = pending.reply.send(response);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::direct::{dct2d_direct, idct2d_direct};
    use crate::util::prop::check_close;
    use crate::util::rng::Rng;

    fn svc(workers: usize) -> Service {
        Service::start_native(ServiceConfig {
            workers,
            batch: BatchPolicy::default(),
            exec: crate::parallel::ExecPolicy::Auto,
            shard: ShardPolicy::Auto,
            trace: false,
        })
    }

    #[test]
    fn transform_roundtrip() {
        let s = svc(2);
        let mut rng = Rng::new(200);
        let x = rng.normal_vec(12 * 12);
        let r = s.transform(TransformOp::Dct2d, vec![12, 12], x.clone()).unwrap();
        check_close(&r.output, &dct2d_direct(&x, 12, 12), 1e-9).unwrap();
        assert_eq!(r.backend, "native");
        let back = s
            .transform(TransformOp::Idct2d, vec![12, 12], r.output.clone())
            .unwrap();
        check_close(&back.output, &x, 1e-9).unwrap();
        assert!(s.metrics.total_requests() >= 2);
    }

    #[test]
    fn rejects_invalid_requests() {
        let s = svc(1);
        assert!(s.transform(TransformOp::Dct2d, vec![4], vec![0.0; 4]).is_err());
        assert!(s.transform(TransformOp::Dct2d, vec![4, 4], vec![0.0; 3]).is_err());
    }

    #[test]
    fn many_concurrent_requests_no_loss() {
        let s = svc(4);
        let mut rng = Rng::new(201);
        let mut reqs = Vec::new();
        let mut wants = Vec::new();
        for i in 0..64 {
            let (n1, n2) = if i % 2 == 0 { (8, 8) } else { (6, 10) };
            let x = rng.normal_vec(n1 * n2);
            wants.push(dct2d_direct(&x, n1, n2));
            reqs.push((TransformOp::Dct2d, vec![n1, n2], x));
        }
        let out = s.transform_many(reqs).unwrap();
        assert_eq!(out.len(), 64);
        for (r, w) in out.iter().zip(&wants) {
            check_close(&r.output, w, 1e-9).unwrap();
        }
        // same-shape requests must have been co-batched at least once
        let snap = s.metrics.snapshot();
        let mb = snap
            .get("dct2d")
            .and_then(|d| d.get("max_batch"))
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!(mb >= 1.0);
    }

    #[test]
    fn mixed_ops_route_correctly() {
        let s = svc(2);
        let mut rng = Rng::new(202);
        let x = rng.normal_vec(9 * 9);
        let a = s.transform(TransformOp::IdctIdxst, vec![9, 9], x.clone()).unwrap();
        let b = s.transform(TransformOp::RcIdct2d, vec![9, 9], x.clone()).unwrap();
        assert!(a.output.iter().all(|v| v.is_finite()));
        check_close(&b.output, &idct2d_direct(&x, 9, 9), 1e-9).unwrap();
    }

    #[test]
    fn shutdown_is_clean() {
        let s = svc(2);
        let _ = s.transform(TransformOp::Dct2d, vec![4, 4], vec![1.0; 16]);
        drop(s); // must not hang or panic
    }

    #[test]
    fn worker_panic_becomes_request_error_and_worker_survives() {
        use super::super::batcher::{Batch, Pending};
        use super::super::request::{PlanKey, Request};
        use std::sync::mpsc::channel;

        let router = Arc::new(Router::native_only());
        let metrics = Arc::new(Metrics::new());
        let (batch_tx, batch_rx) = channel::<Batch>();
        let shared_rx = Arc::new(Mutex::new(batch_rx));
        let worker = {
            let rx = shared_rx.clone();
            let router = router.clone();
            let metrics = metrics.clone();
            std::thread::spawn(move || worker_loop(rx, router, metrics))
        };

        // A rank-mismatched key slips past validate only if constructed
        // by hand; plan building then panics inside the worker.
        let (reply_bad, rx_bad) = channel();
        batch_tx
            .send(Batch {
                key: PlanKey { op: TransformOp::Dct2d, shape: vec![4] },
                items: vec![Pending {
                    request: Request {
                        id: 1,
                        op: TransformOp::Dct2d,
                        shape: vec![4],
                        data: vec![0.0; 4],
                    },
                    reply: reply_bad,
                    enqueued: Instant::now(),
                }],
            })
            .unwrap();
        let bad = rx_bad.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        let err = bad.expect_err("panicking plan must surface as an error");
        assert!(err.contains("panicked"), "got: {err}");

        // the same worker thread must still serve well-formed batches
        let (reply_ok, rx_ok) = channel();
        let mut rng = Rng::new(203);
        let x = rng.normal_vec(16);
        batch_tx
            .send(Batch {
                key: PlanKey { op: TransformOp::Dct2d, shape: vec![4, 4] },
                items: vec![Pending {
                    request: Request {
                        id: 2,
                        op: TransformOp::Dct2d,
                        shape: vec![4, 4],
                        data: x.clone(),
                    },
                    reply: reply_ok,
                    enqueued: Instant::now(),
                }],
            })
            .unwrap();
        let ok = rx_ok.recv_timeout(std::time::Duration::from_secs(5)).unwrap().unwrap();
        check_close(&ok.output, &dct2d_direct(&x, 4, 4), 1e-9).unwrap();
        drop(batch_tx);
        worker.join().expect("worker exits cleanly after channel close");
    }

    #[test]
    fn sharded_large_request_coschedules_with_small_ones() {
        // one above-threshold request sharded into bands + a stream of
        // small requests: everything completes, answers are exact, and
        // the metrics show the large op actually ran sharded
        let s = Service::start_native(ServiceConfig {
            workers: 2,
            batch: BatchPolicy::default(),
            exec: crate::parallel::ExecPolicy::Serial,
            shard: ShardPolicy::MaxShards(3),
            trace: false,
        });
        let mut rng = Rng::new(205);
        let (n1, n2) = (256usize, 260usize); // >= SHARD_MIN_NUMEL, non-divisible by 3
        let big = rng.normal_vec(n1 * n2);
        let big_handle = s.submit(TransformOp::Idct2d, vec![n1, n2], big.clone()).unwrap();
        let mut small_reqs = Vec::new();
        let mut wants = Vec::new();
        for _ in 0..12 {
            let x = rng.normal_vec(8 * 8);
            wants.push(dct2d_direct(&x, 8, 8));
            small_reqs.push((TransformOp::Dct2d, vec![8usize, 8usize], x));
        }
        let small_out = s.transform_many(small_reqs).unwrap();
        for (r, w) in small_out.iter().zip(&wants) {
            check_close(&r.output, w, 1e-9).unwrap();
        }
        let big_out = big_handle.wait().unwrap();
        // sharded output must match a single-band serial plan to <= 1e-10
        let mut want_big = vec![0.0; n1 * n2];
        crate::dct::Idct2::with_policy(n1, n2, crate::parallel::ExecPolicy::Serial)
            .forward(&big, &mut want_big);
        check_close(&big_out.output, &want_big, 1e-10).unwrap();
        let snap = s.metrics.snapshot();
        let bands = snap
            .get("idct2d")
            .and_then(|d| d.get("max_bands"))
            .and_then(|v| v.as_f64())
            .unwrap();
        assert_eq!(bands, 3.0, "large idct2d should have run as 3 band shards");
        let small_bands = snap
            .get("dct2d")
            .and_then(|d| d.get("max_bands"))
            .and_then(|v| v.as_f64())
            .unwrap();
        assert_eq!(small_bands, 1.0, "small requests must stay unsharded");
    }

    #[test]
    fn config_worker_count_is_respected() {
        // 1 worker must still drain many requests (no hidden
        // available_parallelism override)
        let s = svc(1);
        let mut rng = Rng::new(204);
        let reqs: Vec<_> = (0..16)
            .map(|_| (TransformOp::Dct2d, vec![8usize, 8usize], rng.normal_vec(64)))
            .collect();
        let out = s.transform_many(reqs).unwrap();
        assert_eq!(out.len(), 16);
    }
}
