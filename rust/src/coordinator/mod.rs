//! L3 coordinator: the transform service a downstream application embeds
//! or runs as a daemon.
//!
//! * [`request`]    — ops, requests, responses, plan keys
//! * [`plan_cache`] — shape-specialized native plan cache
//! * [`router`]     — native vs PJRT-artifact backend routing
//! * [`batcher`]    — dynamic batching by (op, shape)
//! * [`service`]    — thread-pool service facade (submit/wait)
//! * [`metrics`]    — counters + latency/batch histograms

pub mod batcher;
pub mod metrics;
pub mod plan_cache;
pub mod request;
pub mod router;
pub mod service;

pub use batcher::BatchPolicy;
pub use plan_cache::{NativePlan, PlanCache};
pub use request::{PlanKey, Request, Response, TransformOp};
pub use router::{BackendPolicy, Route, Router};
pub use service::{default_workers, Handle, Service, ServiceConfig};
