//! L3 coordinator: the transform service a downstream application embeds
//! or runs as a daemon.
//!
//! * [`request`]    — ops, requests, responses, plan keys
//! * [`plan_cache`] — shape-specialized native plan cache (carries the
//!   exec + shard policies)
//! * [`router`]     — native vs PJRT-artifact backend routing
//! * [`batcher`]    — dynamic batching by (op, shape), with a solo fast
//!   path for large (shardable) requests, lifecycle gating
//!   (deadlines/cancellation), and the inflight admission budget
//! * [`shard`]      — band-sharded execution of large transforms
//! * [`service`]    — thread-pool service facade (submit/wait)
//! * [`metrics`]    — counters + latency/batch/band histograms
//! * [`fault`]      — deterministic fault injection at the execution
//!   seams (`MDDCT_FAULT`; compiled out under `fault-off`)
//!
//! ```
//! use mddct::coordinator::{Service, ServiceConfig, TransformOp};
//!
//! let svc = Service::start_native(ServiceConfig::default());
//! let r = svc.transform(TransformOp::Dct2d, vec![4, 4], vec![1.0; 16]).unwrap();
//! assert_eq!(r.output.len(), 16);
//! assert_eq!(r.backend, "native");
//! ```

#![warn(missing_docs)]

pub mod batcher;
pub mod fault;
pub mod metrics;
pub mod plan_cache;
pub mod request;
pub mod router;
pub mod service;
pub mod shard;

pub use crate::util::error::TransformError;
pub use batcher::{
    max_batch_elems, parse_tenant_quota, tenant_quota_from_env, BatchPolicy, InflightBudget,
    DEFAULT_MAX_BATCH_ELEMS,
};
pub use fault::{conn_fault, parse_spec, set_faults, FaultKind, FaultSpec};
pub use metrics::Metrics;
pub use plan_cache::{NativePlan, PlanCache};
pub use request::{PlanKey, Request, Response, TransformOp, DEFAULT_TENANT};
pub use router::{BackendPolicy, Route, Router};
pub use service::{
    default_workers, Handle, Service, ServiceConfig, SubmitOptions, DEFAULT_MAX_INFLIGHT_ELEMS,
};
pub use shard::{
    shard_min_numel, shard_min_numel_3d, ShardPlan, ShardPolicy, SHARD_MIN_NUMEL,
    SHARD_MIN_NUMEL_3D,
};
