//! Service metrics: per-op counters, latency histograms, batch sizes
//! (co-batched *and* packed-executed, the latter with a log2 size
//! histogram), and band-shard fan-out — the latter broken down by
//! transform dimensionality too, so dashboards can tell the 2D row-band
//! path and the 3D slab path apart.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::Json;
use crate::util::stats::LatencyHistogram;

/// Log2 buckets for packed-batch sizes: bucket i counts batches of
/// `2^i ..= 2^(i+1)-1` requests, the last bucket absorbing everything
/// larger (4096+).
const PACKED_BUCKETS: usize = 13;

#[derive(Debug, Default)]
struct OpMetrics {
    requests: u64,
    errors: u64,
    latency: LatencyHistogram,
    batch_sum: u64,
    batch_max: usize,
    /// requests that executed under an explicit shard policy (>1 bands)
    sharded: u64,
    bands_max: usize,
    /// requests that executed through the packed stage-fused batch path
    packed_requests: u64,
    /// packed batches executed
    packed_batches: u64,
    /// packed batches that ran the zero-copy views path (no input pack
    /// copy; requests fed to the plan as borrowed per-request views)
    packed_zero_copy: u64,
    packed_max: usize,
    /// log2 histogram of packed batch sizes
    packed_hist: [u64; PACKED_BUCKETS],
    /// requests shed at admission (inflight budget exhausted)
    shed: u64,
    /// requests dropped because their deadline passed while queued
    expired: u64,
    /// replies that found the client's receiver already dropped
    dropped_replies: u64,
    /// requests that failed on the primary plan and succeeded on the
    /// one-shot degraded serial retry
    retried_degraded: u64,
}

/// Shard fan-out aggregated per transform rank (1D/2D/3D), across ops.
#[derive(Debug, Default, Clone, Copy)]
struct RankMetrics {
    requests: u64,
    sharded: u64,
    bands_max: usize,
}

/// Lifecycle counters and latency per explicit tenant (requests
/// submitted without a tenant are not tracked here — they are billed to
/// the shared default budget bucket but add no metrics row).
#[derive(Debug, Default)]
struct TenantMetrics {
    submitted: u64,
    shed: u64,
    expired: u64,
    latency: LatencyHistogram,
}

/// All metric tables behind one lock, so a snapshot always sees the
/// per-op, per-rank, and per-tenant aggregates in agreement.
#[derive(Default)]
struct Tables {
    ops: BTreeMap<String, OpMetrics>,
    by_rank: BTreeMap<usize, RankMetrics>,
    tenants: BTreeMap<String, TenantMetrics>,
}

/// Thread-safe metrics registry.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Tables>,
}

impl Metrics {
    /// Fresh, empty registry.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one completed request: its transform rank (1/2/3 — the
    /// dimensionality bucket for the shard breakdown), queue+execute
    /// latency, the size of the batch it shared, and the band work items
    /// an explicit shard policy split it into (1 = unsharded; `Auto`
    /// lane fan-out is not reported as sharding).
    pub fn record(&self, op: &str, rank: usize, latency: f64, batch: usize, bands: usize) {
        let mut t = self.inner.lock().unwrap();
        let e = t.ops.entry(op.to_string()).or_default();
        e.requests += 1;
        e.latency.record(latency);
        e.batch_sum += batch as u64;
        e.batch_max = e.batch_max.max(batch);
        if bands > 1 {
            e.sharded += 1;
        }
        e.bands_max = e.bands_max.max(bands);
        let r = t.by_rank.entry(rank).or_default();
        r.requests += 1;
        if bands > 1 {
            r.sharded += 1;
        }
        r.bands_max = r.bands_max.max(bands);
    }

    /// Record one packed batch execution: `size` same-shape requests
    /// ran through the stage-fused `forward_batch` path as one unit.
    pub fn record_packed(&self, op: &str, size: usize) {
        let mut t = self.inner.lock().unwrap();
        let e = t.ops.entry(op.to_string()).or_default();
        e.packed_batches += 1;
        e.packed_requests += size as u64;
        e.packed_max = e.packed_max.max(size);
        let bucket = (usize::BITS - 1 - size.max(1).leading_zeros()) as usize;
        e.packed_hist[bucket.min(PACKED_BUCKETS - 1)] += 1;
    }

    /// Record one packed batch that executed through the zero-copy views
    /// path (no input pack copy) — always recorded *in addition to*
    /// [`Metrics::record_packed`], so `packed_zero_copy <= packed_batches`.
    pub fn record_packed_zero_copy(&self, op: &str) {
        let mut t = self.inner.lock().unwrap();
        t.ops.entry(op.to_string()).or_default().packed_zero_copy += 1;
    }

    /// Record one failed request.
    pub fn record_error(&self, op: &str) {
        let mut t = self.inner.lock().unwrap();
        t.ops.entry(op.to_string()).or_default().errors += 1;
    }

    /// Record one request shed at admission (`Overloaded`).
    pub fn record_shed(&self, op: &str) {
        let mut t = self.inner.lock().unwrap();
        t.ops.entry(op.to_string()).or_default().shed += 1;
    }

    /// Record one request dropped at dequeue/flush with its deadline
    /// already passed (`DeadlineExceeded`).
    pub fn record_expired(&self, op: &str) {
        let mut t = self.inner.lock().unwrap();
        t.ops.entry(op.to_string()).or_default().expired += 1;
    }

    /// Record one reply whose receiver was already dropped (either the
    /// client hung up before dequeue, or the send itself failed).
    pub fn record_dropped_reply(&self, op: &str) {
        let mut t = self.inner.lock().unwrap();
        t.ops.entry(op.to_string()).or_default().dropped_replies += 1;
    }

    /// Record one request that failed on its primary plan and succeeded
    /// on the one-shot degraded serial retry.
    pub fn record_retried_degraded(&self, op: &str) {
        let mut t = self.inner.lock().unwrap();
        t.ops.entry(op.to_string()).or_default().retried_degraded += 1;
    }

    /// Record one request entering admission under an explicit tenant.
    pub fn record_tenant_submitted(&self, tenant: &str) {
        let mut t = self.inner.lock().unwrap();
        t.tenants.entry(tenant.to_string()).or_default().submitted += 1;
    }

    /// Record one explicit-tenant request shed at admission.
    pub fn record_tenant_shed(&self, tenant: &str) {
        let mut t = self.inner.lock().unwrap();
        t.tenants.entry(tenant.to_string()).or_default().shed += 1;
    }

    /// Record one explicit-tenant request expired while queued.
    pub fn record_tenant_expired(&self, tenant: &str) {
        let mut t = self.inner.lock().unwrap();
        t.tenants.entry(tenant.to_string()).or_default().expired += 1;
    }

    /// Record one completed explicit-tenant request with its
    /// queue+execute latency (seconds).
    pub fn record_tenant_done(&self, tenant: &str, latency: f64) {
        let mut t = self.inner.lock().unwrap();
        t.tenants.entry(tenant.to_string()).or_default().latency.record(latency);
    }

    /// Total successful requests across all ops.
    pub fn total_requests(&self) -> u64 {
        self.inner.lock().unwrap().ops.values().map(|e| e.requests).sum()
    }

    /// JSON snapshot (dumped by the CLI's `metrics` output): one object
    /// per op, plus reserved `_`-prefixed sections (op names are
    /// lower-case identifiers, so the prefix cannot collide):
    ///
    /// * `_sharding_by_rank` — shard fan-out keyed `"1d"` / `"2d"` /
    ///   `"3d"`, aggregating per transform dimensionality;
    /// * `_tenants` — per-tenant lifecycle counters and latency
    ///   quantiles, present only when explicit-tenant traffic was seen;
    /// * `_scratch` — process-wide scratch-pool statistics
    ///   ([`crate::util::scratch::stats_json`]), always present;
    /// * `_stage_breakdown` — the live Fig.-6-style per-(op,shape) stage
    ///   timing table ([`crate::obs::breakdown_json`]), present only when
    ///   tracing has aggregated at least one stage span.
    pub fn snapshot(&self) -> Json {
        let t = self.inner.lock().unwrap();
        let mut root = BTreeMap::new();
        for (op, e) in t.ops.iter() {
            let mut o = BTreeMap::new();
            o.insert("requests".into(), Json::Num(e.requests as f64));
            o.insert("errors".into(), Json::Num(e.errors as f64));
            o.insert("shed_requests".into(), Json::Num(e.shed as f64));
            o.insert("expired_requests".into(), Json::Num(e.expired as f64));
            o.insert("dropped_replies".into(), Json::Num(e.dropped_replies as f64));
            o.insert("retried_degraded".into(), Json::Num(e.retried_degraded as f64));
            o.insert("mean_latency_s".into(), Json::Num(e.latency.mean()));
            o.insert("p50_latency_s".into(), Json::Num(e.latency.quantile(0.5)));
            o.insert("p95_latency_s".into(), Json::Num(e.latency.quantile(0.95)));
            o.insert("max_latency_s".into(), Json::Num(e.latency.max));
            let mean_batch = if e.requests > 0 {
                e.batch_sum as f64 / e.requests as f64
            } else {
                0.0
            };
            o.insert("mean_batch".into(), Json::Num(mean_batch));
            o.insert("max_batch".into(), Json::Num(e.batch_max as f64));
            o.insert("sharded_requests".into(), Json::Num(e.sharded as f64));
            o.insert("max_bands".into(), Json::Num(e.bands_max as f64));
            o.insert("packed_requests".into(), Json::Num(e.packed_requests as f64));
            o.insert("packed_batches".into(), Json::Num(e.packed_batches as f64));
            o.insert("packed_zero_copy".into(), Json::Num(e.packed_zero_copy as f64));
            o.insert("max_packed_batch".into(), Json::Num(e.packed_max as f64));
            if e.packed_batches > 0 {
                // log2 size histogram, non-empty buckets only, keyed by
                // the bucket's lower bound ("4096" = 4096 and up)
                let mut hist = BTreeMap::new();
                for (i, &c) in e.packed_hist.iter().enumerate() {
                    if c > 0 {
                        hist.insert((1usize << i).to_string(), Json::Num(c as f64));
                    }
                }
                o.insert("packed_batch_hist".into(), Json::Obj(hist));
            }
            root.insert(op.clone(), Json::Obj(o));
        }
        if !t.by_rank.is_empty() {
            let mut ranks = BTreeMap::new();
            for (rank, e) in t.by_rank.iter() {
                let mut o = BTreeMap::new();
                o.insert("requests".into(), Json::Num(e.requests as f64));
                o.insert("sharded_requests".into(), Json::Num(e.sharded as f64));
                o.insert("max_bands".into(), Json::Num(e.bands_max as f64));
                ranks.insert(format!("{rank}d"), Json::Obj(o));
            }
            root.insert("_sharding_by_rank".into(), Json::Obj(ranks));
        }
        if !t.tenants.is_empty() {
            let mut tenants = BTreeMap::new();
            for (name, e) in t.tenants.iter() {
                let mut o = BTreeMap::new();
                o.insert("submitted".into(), Json::Num(e.submitted as f64));
                o.insert("completed".into(), Json::Num(e.latency.total as f64));
                o.insert("shed_requests".into(), Json::Num(e.shed as f64));
                o.insert("expired_requests".into(), Json::Num(e.expired as f64));
                o.insert("mean_latency_s".into(), Json::Num(e.latency.mean()));
                o.insert("p50_latency_s".into(), Json::Num(e.latency.quantile(0.5)));
                o.insert("p95_latency_s".into(), Json::Num(e.latency.quantile(0.95)));
                o.insert("p99_latency_s".into(), Json::Num(e.latency.quantile(0.99)));
                tenants.insert(name.clone(), Json::Obj(o));
            }
            root.insert("_tenants".into(), Json::Obj(tenants));
        }
        root.insert("_scratch".into(), crate::util::scratch::stats_json());
        let breakdown = crate::obs::breakdown_json();
        if !matches!(&breakdown, Json::Obj(o) if o.is_empty()) {
            root.insert("_stage_breakdown".into(), breakdown);
        }
        Json::Obj(root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record("dct2d", 2, 0.001, 4, 1);
        m.record("dct2d", 2, 0.003, 2, 6);
        m.record_error("idct2d");
        assert_eq!(m.total_requests(), 2);
        let snap = m.snapshot();
        let d = snap.get("dct2d").unwrap();
        assert_eq!(d.get("requests").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(d.get("mean_batch").unwrap().as_f64().unwrap(), 3.0);
        // one of the two requests ran band-sharded, with 6 bands
        assert_eq!(d.get("sharded_requests").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(d.get("max_bands").unwrap().as_f64().unwrap(), 6.0);
        assert_eq!(
            snap.get("idct2d").unwrap().get("errors").unwrap().as_f64().unwrap(),
            1.0
        );
        // the scratch-pool section rides along on every snapshot
        let scratch = snap.get("_scratch").unwrap();
        assert!(scratch.get("pool_misses").unwrap().as_f64().is_some());
        assert!(scratch.get("retained_buffers").unwrap().as_f64().is_some());
    }

    #[test]
    fn packed_batches_are_counted_and_histogrammed() {
        let m = Metrics::new();
        m.record_packed("dct2d", 2);
        m.record_packed("dct2d", 3);
        m.record_packed("dct2d", 16);
        m.record_packed("dct2d", 1 << 14); // clamps into the 4096+ bucket
        m.record_packed_zero_copy("dct2d");
        m.record_packed_zero_copy("dct2d");
        let snap = m.snapshot();
        let d = snap.get("dct2d").unwrap();
        assert_eq!(d.get("packed_batches").unwrap().as_f64().unwrap(), 4.0);
        assert_eq!(d.get("packed_zero_copy").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(
            d.get("packed_requests").unwrap().as_f64().unwrap(),
            (2 + 3 + 16 + (1 << 14)) as f64
        );
        assert_eq!(
            d.get("max_packed_batch").unwrap().as_f64().unwrap(),
            (1 << 14) as f64
        );
        let hist = d.get("packed_batch_hist").unwrap();
        assert_eq!(hist.get("2").unwrap().as_f64().unwrap(), 2.0); // sizes 2 and 3
        assert_eq!(hist.get("16").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(hist.get("4096").unwrap().as_f64().unwrap(), 1.0);
        // an op that never packed reports zero and omits the histogram
        m.record("idct2d", 2, 0.001, 1, 1);
        let snap = m.snapshot();
        let i = snap.get("idct2d").unwrap();
        assert_eq!(i.get("packed_batches").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(i.get("packed_zero_copy").unwrap().as_f64().unwrap(), 0.0);
        assert!(i.get("packed_batch_hist").is_none());
    }

    #[test]
    fn lifecycle_counters_ride_every_row() {
        let m = Metrics::new();
        m.record_shed("dct2d");
        m.record_shed("dct2d");
        m.record_expired("dct2d");
        m.record_dropped_reply("dct2d");
        m.record_retried_degraded("dct2d");
        // a plain-traffic op still reports the counters (as zeros)
        m.record("idct2d", 2, 0.001, 1, 1);
        let snap = m.snapshot();
        let d = snap.get("dct2d").unwrap();
        assert_eq!(d.get("shed_requests").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(d.get("expired_requests").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(d.get("dropped_replies").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(d.get("retried_degraded").unwrap().as_f64().unwrap(), 1.0);
        let i = snap.get("idct2d").unwrap();
        assert_eq!(i.get("shed_requests").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(i.get("expired_requests").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(i.get("dropped_replies").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(i.get("retried_degraded").unwrap().as_f64().unwrap(), 0.0);
    }

    #[test]
    fn tenant_section_appears_only_with_explicit_tenants() {
        let m = Metrics::new();
        m.record("dct2d", 2, 0.001, 1, 1);
        assert!(m.snapshot().get("_tenants").is_none());
        m.record_tenant_submitted("alice");
        m.record_tenant_submitted("alice");
        m.record_tenant_done("alice", 0.002);
        m.record_tenant_shed("bob");
        m.record_tenant_expired("bob");
        let snap = m.snapshot();
        let tenants = snap.get("_tenants").unwrap();
        let a = tenants.get("alice").unwrap();
        assert_eq!(a.get("submitted").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(a.get("completed").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(a.get("shed_requests").unwrap().as_f64().unwrap(), 0.0);
        assert!(a.get("p99_latency_s").unwrap().as_f64().unwrap() > 0.0);
        let b = tenants.get("bob").unwrap();
        assert_eq!(b.get("submitted").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(b.get("shed_requests").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(b.get("expired_requests").unwrap().as_f64().unwrap(), 1.0);
    }

    #[test]
    fn shard_fanout_breaks_down_by_rank() {
        let m = Metrics::new();
        // 2D traffic: one sharded (4 bands), one not
        m.record("dct2d", 2, 0.001, 1, 4);
        m.record("idct2d", 2, 0.001, 1, 1);
        // 3D traffic: both ops sharded (8 slabs is the max)
        m.record("dct3d", 3, 0.010, 1, 8);
        m.record("idct3d", 3, 0.010, 1, 5);
        let snap = m.snapshot();
        let by_rank = snap.get("_sharding_by_rank").unwrap();
        let d2 = by_rank.get("2d").unwrap();
        assert_eq!(d2.get("requests").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(d2.get("sharded_requests").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(d2.get("max_bands").unwrap().as_f64().unwrap(), 4.0);
        let d3 = by_rank.get("3d").unwrap();
        assert_eq!(d3.get("requests").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(d3.get("sharded_requests").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(d3.get("max_bands").unwrap().as_f64().unwrap(), 8.0);
        // no 1D traffic recorded -> no 1d bucket
        assert!(by_rank.get("1d").is_none());
    }
}
