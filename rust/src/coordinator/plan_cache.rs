//! Native-backend plan cache: one prepared transform plan per
//! (op, shape), built on first use and shared across workers.
//!
//! This is the service-level analogue of cuFFT plan reuse: the paper
//! amortizes twiddle precomputation across repeated calls; we amortize
//! whole plan objects (twiddles + FFT plans + permutations).

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex, RwLock};

use crate::dct::{
    Combo, Dct1d, Dct2, Dct2F32, Dct3d, Dst2, Idct1d, Idct2, Idct2F32, Idct3d, Idst2, Idxst1d,
    IdxstCombo, RowColumn,
};
use crate::layout::ElemType;
use crate::parallel::{ExecPolicy, ShardPolicy};
use crate::util::scratch;

use super::request::{PlanKey, TransformOp};
use super::shard;

/// A prepared native transform plan.
pub enum NativePlan {
    /// Fused 2D DCT ([`Dct2`]).
    Dct2(Dct2),
    /// Fused 2D IDCT ([`Idct2`]).
    Idct2(Idct2),
    /// Row-column baseline 2D DCT.
    RcDct2(RowColumn),
    /// Row-column baseline 2D IDCT.
    RcIdct2(RowColumn),
    /// 1D DCT (one of the four Algorithm-1 variants).
    Dct1(Dct1d),
    /// 1D inverse DCT.
    Idct1(Idct1d),
    /// 1D IDXST.
    Idxst1(Idxst1d),
    /// Fused IDCT_IDXST / IDXST_IDCT combination.
    Combo(IdxstCombo),
    /// Fused 3D DCT.
    Dct3(Dct3d),
    /// Fused 3D IDCT.
    Idct3(Idct3d),
    /// Fused 2D DST-II.
    Dst2(Dst2),
    /// Fused 2D inverse DST.
    Idst2(Idst2),
    /// Fused 2D DCT executed in f32 ([`Dct2F32`]); the service's f64
    /// payloads are narrowed at the plan boundary.
    Dct2F32(Dct2F32),
    /// Fused 2D IDCT executed in f32 ([`Idct2F32`]).
    Idct2F32(Idct2F32),
}

/// Run `f` over f32 copies of `data`/`out`, widening the result back
/// into `out`. The f32 staging buffers come from (and return to) the
/// thread-local scratch pool, so steady-state callers stay
/// allocation-free.
fn run_f32(data: &[f64], out: &mut [f64], f: impl FnOnce(&[f32], &mut [f32])) {
    let mut xs = scratch::take_f32(data.len());
    for (d, s) in xs.iter_mut().zip(data) {
        *d = *s as f32;
    }
    let mut ys = scratch::take_f32(out.len());
    f(&xs, &mut ys);
    for (d, s) in out.iter_mut().zip(&ys) {
        *d = f64::from(*s);
    }
    scratch::give_f32(xs);
    scratch::give_f32(ys);
}

impl NativePlan {
    /// Build the plan for a key with the default (`Auto`) policies.
    pub fn build(key: &PlanKey) -> NativePlan {
        Self::build_with(key, ExecPolicy::Auto, ShardPolicy::Auto)
    }

    /// Build the plan for a key, threading `policy` into the plans that
    /// have parallel stages and `shards` into the fused 2D and 3D plans
    /// whose banded stages support explicit shard counts (the row-column
    /// baseline and 1D plans fan out by exec lanes only). Panics on
    /// rank mismatch (validated upstream by `Request::validate`).
    pub fn build_with(key: &PlanKey, policy: ExecPolicy, shards: ShardPolicy) -> NativePlan {
        let s = &key.shape;
        if key.elem == ElemType::F32 {
            // The reduced-precision element path exists for the fused 2D
            // pair; every other op serves an F32 key with its f64 plan
            // (correct, just not narrowed).
            match key.op {
                TransformOp::Dct2d => {
                    return NativePlan::Dct2F32(Dct2F32::with_policy(s[0], s[1], policy));
                }
                TransformOp::Idct2d => {
                    return NativePlan::Idct2F32(Idct2F32::with_policy(s[0], s[1], policy));
                }
                _ => {}
            }
        }
        match key.op {
            TransformOp::Dct2d => {
                NativePlan::Dct2(Dct2::with_policy(s[0], s[1], policy).with_shards(shards))
            }
            TransformOp::Idct2d => {
                NativePlan::Idct2(Idct2::with_policy(s[0], s[1], policy).with_shards(shards))
            }
            TransformOp::RcDct2d => {
                NativePlan::RcDct2(RowColumn::dct2(s[0], s[1]).with_policy(policy))
            }
            TransformOp::RcIdct2d => {
                NativePlan::RcIdct2(RowColumn::idct2(s[0], s[1]).with_policy(policy))
            }
            TransformOp::Dct1d(algo) => NativePlan::Dct1(Dct1d::new(s[0], algo)),
            TransformOp::Idct1d => NativePlan::Idct1(Idct1d::new(s[0])),
            TransformOp::Idxst1d => NativePlan::Idxst1(Idxst1d::new(s[0])),
            TransformOp::IdctIdxst => NativePlan::Combo(
                IdxstCombo::with_policy(s[0], s[1], Combo::IdctIdxst, policy)
                    .with_shards(shards),
            ),
            TransformOp::IdxstIdct => NativePlan::Combo(
                IdxstCombo::with_policy(s[0], s[1], Combo::IdxstIdct, policy)
                    .with_shards(shards),
            ),
            TransformOp::Dct3d => NativePlan::Dct3(
                Dct3d::with_policy(s[0], s[1], s[2], policy).with_shards(shards),
            ),
            TransformOp::Idct3d => NativePlan::Idct3(
                Idct3d::with_policy(s[0], s[1], s[2], policy).with_shards(shards),
            ),
            TransformOp::Dst2d => {
                NativePlan::Dst2(Dst2::with_policy(s[0], s[1], policy).with_shards(shards))
            }
            TransformOp::Idst2d => {
                NativePlan::Idst2(Idst2::with_policy(s[0], s[1], policy).with_shards(shards))
            }
        }
    }

    /// Execute on one payload into a caller-provided output buffer.
    pub fn execute_into(&self, data: &[f64], out: &mut [f64]) {
        match self {
            NativePlan::Dct2(p) => p.forward(data, out),
            NativePlan::Idct2(p) => p.forward(data, out),
            NativePlan::RcDct2(p) | NativePlan::RcIdct2(p) => p.forward(data, out),
            NativePlan::Dct1(p) => p.forward(data, out),
            NativePlan::Idct1(p) => p.forward(data, out),
            NativePlan::Idxst1(p) => p.forward(data, out),
            NativePlan::Combo(p) => p.forward(data, out),
            NativePlan::Dct3(p) => p.forward(data, out),
            NativePlan::Idct3(p) => p.forward(data, out),
            NativePlan::Dst2(p) => p.forward(data, out),
            NativePlan::Idst2(p) => p.forward(data, out),
            NativePlan::Dct2F32(p) => run_f32(data, out, |x, y| p.forward(x, y)),
            NativePlan::Idct2F32(p) => run_f32(data, out, |x, y| p.forward(x, y)),
        }
    }

    /// Execute on one payload.
    pub fn execute(&self, data: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; data.len()];
        self.execute_into(data, &mut out);
        out
    }

    /// Whether [`NativePlan::execute_batch`] runs the true stage-fused
    /// batch path for this plan (see
    /// [`super::request::TransformOp::supports_batch`]).
    pub fn supports_batch(&self) -> bool {
        matches!(
            self,
            NativePlan::Dct2(_)
                | NativePlan::Idct2(_)
                | NativePlan::Dst2(_)
                | NativePlan::Idst2(_)
                | NativePlan::Combo(_)
                | NativePlan::Dct1(_)
                | NativePlan::Idct1(_)
                | NativePlan::Dct2F32(_)
                | NativePlan::Idct2F32(_)
        )
    }

    /// Whether [`NativePlan::execute_batch_views`] runs the zero-copy
    /// per-request-view batch path for this plan (see
    /// [`super::request::TransformOp::supports_batch_views`]).
    pub fn supports_batch_views(&self) -> bool {
        matches!(self, NativePlan::Dct2(_) | NativePlan::Idct2(_))
    }

    /// Execute a batch given one borrowed slice per payload, with no
    /// packed input copy: the fused 2D DCT/IDCT pair feeds the views
    /// straight into its batched stage-1 reorder; other plans fall back
    /// to a per-item loop over the views. Output is packed in view
    /// order and is bit-identical to [`NativePlan::execute_batch`] on
    /// the concatenation of the views.
    pub fn execute_batch_views(&self, views: &[&[f64]]) -> Vec<f64> {
        let batch = views.len();
        if batch == 0 {
            return Vec::new();
        }
        let numel = views[0].len();
        let mut out = vec![0.0; batch * numel];
        match self {
            NativePlan::Dct2(p) => p.forward_batch_views(views, &mut out),
            NativePlan::Idct2(p) => p.forward_batch_views(views, &mut out),
            _ => {
                for (xb, ob) in views.iter().zip(out.chunks_mut(numel)) {
                    self.execute_into(xb, ob);
                }
            }
        }
        out
    }

    /// Execute a packed batch of `batch` same-shape payloads: the
    /// stage-fused `forward_batch` for the plans that implement it
    /// (pre/FFT/post each swept once across the whole batch), a
    /// per-item loop otherwise. Output is packed in input order and is
    /// bit-identical to `batch` solo [`NativePlan::execute`] calls.
    pub fn execute_batch(&self, data: &[f64], batch: usize) -> Vec<f64> {
        let mut out = vec![0.0; data.len()];
        if batch == 0 {
            return out;
        }
        match self {
            NativePlan::Dct2(p) => p.forward_batch(data, &mut out, batch),
            NativePlan::Idct2(p) => p.forward_batch(data, &mut out, batch),
            NativePlan::Dst2(p) => p.forward_batch(data, &mut out, batch),
            NativePlan::Idst2(p) => p.forward_batch(data, &mut out, batch),
            NativePlan::Combo(p) => p.forward_batch(data, &mut out, batch),
            NativePlan::Dct1(p) => p.forward_batch(data, &mut out, batch),
            NativePlan::Idct1(p) => p.forward_batch(data, &mut out, batch),
            NativePlan::Dct2F32(p) => {
                run_f32(data, &mut out, |x, y| p.forward_batch(x, y, batch))
            }
            NativePlan::Idct2F32(p) => {
                run_f32(data, &mut out, |x, y| p.forward_batch(x, y, batch))
            }
            _ => {
                let numel = data.len() / batch;
                if numel > 0 {
                    for (xb, ob) in data.chunks(numel).zip(out.chunks_mut(numel)) {
                        self.execute_into(xb, ob);
                    }
                }
            }
        }
        out
    }
}

/// Cache statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Requests served from an already-built plan.
    pub hits: u64,
    /// Requests that had to build (and insert) a new plan.
    pub misses: u64,
    /// Keys quarantined after their primary plan panicked or errored;
    /// later lookups skip straight to the degraded serial plan.
    pub quarantined: u64,
}

/// Thread-safe (op, shape) -> plan cache.
///
/// Besides the primary plans (built with the cache's exec/shard
/// policies), the cache holds a *degraded* table: serial, unsharded
/// plans (`ExecPolicy::Serial` + `ShardPolicy::MaxShards(1)`) used for
/// the one-shot retry after a primary execution fails, and served
/// directly for keys that have been [`PlanCache::quarantine`]d. The
/// three-stage factorization makes the two plans compute the identical
/// transform, so degrading is invisible to the client beyond latency.
pub struct PlanCache {
    plans: RwLock<HashMap<PlanKey, Arc<NativePlan>>>,
    degraded: RwLock<HashMap<PlanKey, Arc<NativePlan>>>,
    quarantined: RwLock<HashSet<PlanKey>>,
    stats: Mutex<CacheStats>,
    policy: ExecPolicy,
    shard: ShardPolicy,
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::with_policy(ExecPolicy::Auto)
    }
}

impl PlanCache {
    /// Cache with the default (`Auto`) exec and shard policies.
    pub fn new() -> PlanCache {
        Self::default()
    }

    /// Cache whose plans all carry `policy` (shard policy stays `Auto`).
    pub fn with_policy(policy: ExecPolicy) -> PlanCache {
        Self::with_policies(policy, ShardPolicy::Auto)
    }

    /// Cache whose plans carry both an exec and a shard policy; the
    /// shard policy is applied per request through
    /// [`shard::decide`], so small requests never force-shard.
    pub fn with_policies(policy: ExecPolicy, shard: ShardPolicy) -> PlanCache {
        PlanCache {
            plans: RwLock::new(HashMap::new()),
            degraded: RwLock::new(HashMap::new()),
            quarantined: RwLock::new(HashSet::new()),
            stats: Mutex::new(CacheStats::default()),
            policy,
            shard,
        }
    }

    /// Execution policy baked into newly built plans.
    pub fn policy(&self) -> ExecPolicy {
        self.policy
    }

    /// Shard policy applied (via [`shard::decide`]) to newly built plans.
    pub fn shard_policy(&self) -> ShardPolicy {
        self.shard
    }

    /// Fetch (or build) the plan for a key.
    ///
    /// Lock poisoning is recovered deliberately: a plan build that
    /// panics (malformed key) unwinds before touching the map, so the
    /// cache invariant is intact and later requests must keep working —
    /// the service turns the panic itself into a request error.
    pub fn get(&self, key: &PlanKey) -> Arc<NativePlan> {
        if self.is_quarantined(key) {
            // the primary plan for this key is poisoned: skip straight
            // to the degraded serial plan instead of re-tripping it
            return self.degraded(key);
        }
        if let Some(p) = self.read_plans().get(key) {
            self.bump(|s| s.hits += 1);
            crate::obs::instant_event("plan_cache.hit");
            return p.clone();
        }
        let mut w = self.plans.write().unwrap_or_else(|e| e.into_inner());
        // double-checked: another thread may have built it meanwhile
        if let Some(p) = w.get(key) {
            self.bump(|s| s.hits += 1);
            crate::obs::instant_event("plan_cache.hit");
            return p.clone();
        }
        let plan =
            Arc::new(NativePlan::build_with(key, self.policy, shard::decide(self.shard, key)));
        w.insert(key.clone(), plan.clone());
        self.bump(|s| s.misses += 1);
        crate::obs::instant_event("plan_cache.miss");
        plan
    }

    fn read_plans(&self) -> std::sync::RwLockReadGuard<'_, HashMap<PlanKey, Arc<NativePlan>>> {
        self.plans.read().unwrap_or_else(|e| e.into_inner())
    }

    fn bump(&self, f: impl FnOnce(&mut CacheStats)) {
        f(&mut self.stats.lock().unwrap_or_else(|e| e.into_inner()));
    }

    /// Number of cached plans.
    pub fn len(&self) -> usize {
        self.read_plans().len()
    }

    /// Whether no plan has been built yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hit/miss counters since construction.
    pub fn stats(&self) -> CacheStats {
        *self.stats.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Fetch (or build) the degraded plan for a key: serial, unsharded,
    /// unbatched — the bottom of the degradation lattice, used for the
    /// one-shot retry after a primary execution fails and for all
    /// traffic on quarantined keys.
    pub fn degraded(&self, key: &PlanKey) -> Arc<NativePlan> {
        if let Some(p) = self.degraded.read().unwrap_or_else(|e| e.into_inner()).get(key) {
            return p.clone();
        }
        let mut w = self.degraded.write().unwrap_or_else(|e| e.into_inner());
        if let Some(p) = w.get(key) {
            return p.clone();
        }
        let plan =
            Arc::new(NativePlan::build_with(key, ExecPolicy::Serial, ShardPolicy::MaxShards(1)));
        w.insert(key.clone(), plan.clone());
        plan
    }

    /// Quarantine a key whose primary plan panicked or errored: every
    /// later [`PlanCache::get`] for it returns the degraded serial plan
    /// without touching the poisoned primary. Idempotent; only the
    /// first call bumps the counter.
    pub fn quarantine(&self, key: &PlanKey) {
        let fresh =
            self.quarantined.write().unwrap_or_else(|e| e.into_inner()).insert(key.clone());
        if fresh {
            self.bump(|s| s.quarantined += 1);
            crate::obs::instant_event("plan_cache.quarantine");
        }
    }

    /// Whether a key is currently quarantined.
    pub fn is_quarantined(&self, key: &PlanKey) -> bool {
        self.quarantined.read().unwrap_or_else(|e| e.into_inner()).contains(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::direct::dct2d_direct;
    use crate::dct::Algo1d;
    use crate::util::prop::check_close;
    use crate::util::rng::Rng;

    fn key(op: TransformOp, shape: &[usize]) -> PlanKey {
        PlanKey::new(op, shape.to_vec())
    }

    #[test]
    fn plans_execute_correctly() {
        let mut rng = Rng::new(80);
        let x = rng.normal_vec(8 * 12);
        let cache = PlanCache::new();
        let plan = cache.get(&key(TransformOp::Dct2d, &[8, 12]));
        check_close(&plan.execute(&x), &dct2d_direct(&x, 8, 12), 1e-9).unwrap();
        // fused == row-column through the cache too
        let rc = cache.get(&key(TransformOp::RcDct2d, &[8, 12]));
        check_close(&rc.execute(&x), &dct2d_direct(&x, 8, 12), 1e-9).unwrap();
    }

    #[test]
    fn execute_batch_matches_per_item_execution() {
        let mut rng = Rng::new(82);
        let cache = PlanCache::new();
        // stage-fused path (dct2d) and fallback loop (rc_dct2d / dct3d)
        for (op, shape) in [
            (TransformOp::Dct2d, vec![8usize, 12]),
            (TransformOp::Idct2d, vec![9, 7]),
            (TransformOp::Dst2d, vec![8, 12]),
            (TransformOp::Idst2d, vec![9, 7]),
            (TransformOp::IdctIdxst, vec![8, 12]),
            (TransformOp::IdxstIdct, vec![9, 7]),
            (TransformOp::Dct1d(Algo1d::NPoint), vec![16]),
            (TransformOp::Idct1d, vec![15]),
            (TransformOp::RcDct2d, vec![6, 8]),
            (TransformOp::Dct3d, vec![3, 4, 5]),
        ] {
            let numel: usize = shape.iter().product();
            let batch = 5;
            let packed = rng.normal_vec(numel * batch);
            let plan = cache.get(&key(op, &shape));
            assert_eq!(plan.supports_batch(), op.supports_batch(), "{op:?}");
            let got = plan.execute_batch(&packed, batch);
            for b in 0..batch {
                let want = plan.execute(&packed[b * numel..(b + 1) * numel]);
                assert_eq!(got[b * numel..(b + 1) * numel], want[..], "{op:?} item {b}");
            }
        }
    }

    #[test]
    fn execute_batch_views_matches_packed_execution() {
        let mut rng = Rng::new(85);
        let cache = PlanCache::new();
        for (op, shape) in [
            (TransformOp::Dct2d, vec![8usize, 12]),
            (TransformOp::Idct2d, vec![9, 7]),
            (TransformOp::Dst2d, vec![8, 12]), // per-item fallback
        ] {
            let numel: usize = shape.iter().product();
            let batch = 4;
            let packed = rng.normal_vec(numel * batch);
            let views: Vec<&[f64]> = packed.chunks(numel).collect();
            let plan = cache.get(&key(op, &shape));
            assert_eq!(
                plan.supports_batch_views(),
                op.supports_batch_views(),
                "{op:?}"
            );
            let got = plan.execute_batch_views(&views);
            let want = plan.execute_batch(&packed, batch);
            assert_eq!(got, want, "{op:?}: views batch must match packed batch bitwise");
        }
        assert!(NativePlan::build(&key(TransformOp::Dct2d, &[4, 4]))
            .execute_batch_views(&[])
            .is_empty());
    }

    #[test]
    fn f32_plans_build_and_approximate_the_f64_transform() {
        let mut rng = Rng::new(86);
        let cache = PlanCache::new();
        let x = rng.normal_vec(8 * 12);
        for op in [TransformOp::Dct2d, TransformOp::Idct2d] {
            let k64 = key(op, &[8, 12]);
            let k32 = k64.clone().with_elem(ElemType::F32);
            let p64 = cache.get(&k64);
            let p32 = cache.get(&k32);
            assert!(!Arc::ptr_eq(&p64, &p32), "{op:?}: elem must split cache entries");
            let y64 = p64.execute(&x);
            let y32 = p32.execute(&x);
            let scale: f64 =
                y64.iter().map(|v| v.abs()).fold(0.0, f64::max).max(1.0);
            for (a, b) in y64.iter().zip(&y32) {
                assert!(
                    (a - b).abs() <= 1e-4 * scale,
                    "{op:?}: f32 path drifted: {a} vs {b}"
                );
            }
            // batch path stays consistent with solo f32 execution
            let batch = 3;
            let packed = rng.normal_vec(8 * 12 * batch);
            let got = p32.execute_batch(&packed, batch);
            for b in 0..batch {
                let want = p32.execute(&packed[b * 96..(b + 1) * 96]);
                assert_eq!(got[b * 96..(b + 1) * 96], want[..], "{op:?} item {b}");
            }
        }
        // ops without a narrowed plan serve F32 keys with the f64 build
        let fallback =
            cache.get(&key(TransformOp::Dst2d, &[8, 12]).with_elem(ElemType::F32));
        check_close(&fallback.execute(&x), &cache.get(&key(TransformOp::Dst2d, &[8, 12])).execute(&x), 0.0)
            .unwrap();
    }

    #[test]
    fn cache_hit_miss_accounting() {
        let cache = PlanCache::new();
        let k = key(TransformOp::Dct2d, &[16, 16]);
        let a = cache.get(&k);
        let b = cache.get(&k);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1, ..Default::default() });
        assert_eq!(cache.len(), 1);
        cache.get(&key(TransformOp::Idct2d, &[16, 16]));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn quarantine_reroutes_to_the_degraded_plan() {
        let mut rng = Rng::new(83);
        let cache = PlanCache::with_policies(ExecPolicy::Threads(4), ShardPolicy::MaxShards(4));
        let k = key(TransformOp::Dct2d, &[8, 12]);
        let primary = cache.get(&k);
        let x = rng.normal_vec(8 * 12);
        assert!(!cache.is_quarantined(&k));
        cache.quarantine(&k);
        cache.quarantine(&k); // idempotent
        assert!(cache.is_quarantined(&k));
        assert_eq!(cache.stats().quarantined, 1);
        // get() now serves the degraded plan, not the primary...
        let served = cache.get(&k);
        assert!(!Arc::ptr_eq(&served, &primary));
        assert!(Arc::ptr_eq(&served, &cache.degraded(&k)));
        // ...and the lattice bottom computes the identical transform
        check_close(&served.execute(&x), &dct2d_direct(&x, 8, 12), 1e-9).unwrap();
        // other keys are untouched
        let other = key(TransformOp::Dct2d, &[16, 16]);
        assert!(!cache.is_quarantined(&other));
    }

    #[test]
    fn all_ops_build_and_roundtrip_sane() {
        let mut rng = Rng::new(81);
        let cache = PlanCache::new();
        let x1 = rng.normal_vec(16);
        for op in [
            TransformOp::Dct1d(Algo1d::NPoint),
            TransformOp::Dct1d(Algo1d::FourN),
            TransformOp::Idct1d,
            TransformOp::Idxst1d,
        ] {
            let y = cache.get(&key(op, &[16])).execute(&x1);
            assert_eq!(y.len(), 16);
            assert!(y.iter().all(|v| v.is_finite()), "{op:?}");
        }
        let x2 = rng.normal_vec(6 * 8);
        for op in [
            TransformOp::Dct2d,
            TransformOp::Idct2d,
            TransformOp::RcDct2d,
            TransformOp::RcIdct2d,
            TransformOp::IdctIdxst,
            TransformOp::IdxstIdct,
        ] {
            let y = cache.get(&key(op, &[6, 8])).execute(&x2);
            assert!(y.iter().all(|v| v.is_finite()), "{op:?}");
        }
        let x3 = rng.normal_vec(4 * 4 * 4);
        let y = cache.get(&key(TransformOp::Dct3d, &[4, 4, 4])).execute(&x3);
        assert!(y.iter().all(|v| v.is_finite()));
        // the 3D inverse undoes the 3D forward through the cache
        let back = cache.get(&key(TransformOp::Idct3d, &[4, 4, 4])).execute(&y);
        for (a, b) in back.iter().zip(&x3) {
            assert!((a - b).abs() < 1e-9, "idct3d(dct3d(x)) != x");
        }
    }
}
