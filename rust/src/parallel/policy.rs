//! Execution policy: how many lanes a transform stage may fan out to.
//!
//! Every plan carries an [`ExecPolicy`]; hot paths ask it for a lane
//! count sized to the work at hand. `Serial` and `Threads(1)` take the
//! exact same single-threaded code path (bit-identical results), `Auto`
//! falls back to serial below a work threshold where fork/join overhead
//! would dominate the transform itself.

use std::sync::OnceLock;

/// Work size (elements) below which `Auto` stays serial. A 64x64 fused
/// DCT runs in ~10us — about the cost of one fork/join round trip — so
/// anything smaller is not worth distributing.
pub const AUTO_MIN_WORK: usize = 64 * 64;

/// How a plan distributes its batched stages.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecPolicy {
    /// Always single-threaded (the paper's measured baseline).
    Serial,
    /// Exactly this many lanes, regardless of work size (n is clamped to
    /// at least 1). `Threads(1)` is bit-identical to `Serial`.
    Threads(usize),
    /// Serial below [`AUTO_MIN_WORK`], otherwise [`default_threads`].
    #[default]
    Auto,
}

impl ExecPolicy {
    /// Lane count for a stage touching `work` elements; 1 means "take
    /// the serial path".
    pub fn lanes(self, work: usize) -> usize {
        match self {
            ExecPolicy::Serial => 1,
            ExecPolicy::Threads(n) => n.max(1),
            ExecPolicy::Auto => {
                if work < AUTO_MIN_WORK {
                    1
                } else {
                    default_threads()
                }
            }
        }
    }

    /// Human-readable label (bench tables / metrics).
    pub fn label(self) -> String {
        match self {
            ExecPolicy::Serial => "serial".to_string(),
            ExecPolicy::Threads(n) => format!("threads({n})"),
            ExecPolicy::Auto => format!("auto({})", default_threads()),
        }
    }
}

/// Parse a positive usize from an env var (see [`crate::util::env_usize`];
/// re-exported here because the thread/worker-count defaults historically
/// lived in this module).
pub use crate::util::env_usize;

/// Process-wide default lane count: `MDDCT_THREADS` env override, else
/// the machine's available parallelism. Resolved once.
pub fn default_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        env_usize("MDDCT_THREADS")
            .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_is_one_lane() {
        assert_eq!(ExecPolicy::Serial.lanes(1 << 30), 1);
    }

    #[test]
    fn threads_clamps_to_one() {
        assert_eq!(ExecPolicy::Threads(0).lanes(10), 1);
        assert_eq!(ExecPolicy::Threads(5).lanes(10), 5);
    }

    #[test]
    fn auto_respects_threshold() {
        assert_eq!(ExecPolicy::Auto.lanes(AUTO_MIN_WORK - 1), 1);
        assert!(ExecPolicy::Auto.lanes(AUTO_MIN_WORK) >= 1);
    }

    #[test]
    fn default_policy_is_auto() {
        assert_eq!(ExecPolicy::default(), ExecPolicy::Auto);
    }
}
