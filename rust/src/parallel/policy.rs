//! Execution policies: how many lanes a transform stage may fan out to
//! ([`ExecPolicy`]), and how a single large transform is decomposed into
//! row-band shards ([`ShardPolicy`]).
//!
//! Every plan carries an [`ExecPolicy`]; hot paths ask it for a lane
//! count sized to the work at hand. `Serial` and `Threads(1)` take the
//! exact same single-threaded code path (bit-identical results), `Auto`
//! falls back to serial below a work threshold where fork/join overhead
//! would dominate the transform itself.
//!
//! [`ShardPolicy`] is the second, orthogonal axis: instead of asking
//! "how many threads may run", it pins "how many band work items one
//! transform becomes". The coordinator threads it through the plan
//! cache so one huge request can be split into bands that interleave on
//! the shared pool with other requests' work (see
//! [`crate::coordinator::shard`]).

use std::sync::OnceLock;

/// Work size (elements) below which `Auto` stays serial. A 64x64 fused
/// DCT runs in ~10us — about the cost of one fork/join round trip — so
/// anything smaller is not worth distributing.
pub const AUTO_MIN_WORK: usize = 64 * 64;

/// How a plan distributes its batched stages.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecPolicy {
    /// Always single-threaded (the paper's measured baseline).
    Serial,
    /// Exactly this many lanes, regardless of work size (n is clamped to
    /// at least 1). `Threads(1)` is bit-identical to `Serial`.
    Threads(usize),
    /// Serial below [`AUTO_MIN_WORK`], otherwise [`default_threads`].
    #[default]
    Auto,
}

impl ExecPolicy {
    /// Lane count for a stage touching `work` elements; 1 means "take
    /// the serial path".
    pub fn lanes(self, work: usize) -> usize {
        match self {
            ExecPolicy::Serial => 1,
            ExecPolicy::Threads(n) => n.max(1),
            ExecPolicy::Auto => {
                if work < AUTO_MIN_WORK {
                    1
                } else {
                    default_threads()
                }
            }
        }
    }

    /// Human-readable label (bench tables / metrics).
    pub fn label(self) -> String {
        match self {
            ExecPolicy::Serial => "serial".to_string(),
            ExecPolicy::Threads(n) => format!("threads({n})"),
            ExecPolicy::Auto => format!("auto({})", default_threads()),
        }
    }
}

/// How a single transform's banded stages are decomposed into shard
/// work items.
///
/// An [`ExecPolicy`] answers "how many lanes may run at once"; a
/// `ShardPolicy` answers "how many independent band work items does one
/// transform become". The two compose: under the default `Auto` the
/// band count simply equals the exec lane count (the pre-sharding
/// behaviour, bit-for-bit), while the explicit variants pin the
/// decomposition regardless of the exec policy — `MaxShards(1)` forces
/// single-band (serial-equivalent) execution even on a `Threads(n)`
/// plan, and `MaxShards(n)` fans a `Serial` plan out over `n` bands.
///
/// Every banded stage applies the policy with its own row count: the
/// stage-1 row FFTs band over the `n1` input rows, the column stage
/// (after the tiled-transpose barrier) over the `h2` spectrum rows, and
/// the DCT pre/post permutations over their row/pair counts. The 3D
/// plans apply the identical math with the dim-0 **i-slab** as the row
/// unit (`rows` = the tensor's leading dimension), re-banding over the
/// `n2*h3` transposed rows across their dim-1/dim-2 barrier — see
/// [`crate::parallel::slab_spans`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShardPolicy {
    /// Band count = the plan's exec lane count (the pre-sharding
    /// default; `Serial` plans stay serial).
    #[default]
    Auto,
    /// Every shard keeps at least this many rows: a stage of `rows`
    /// rows becomes `max(1, rows / m)` bands. Guards small requests
    /// against over-splitting while still fanning large ones wide.
    MinRowsPerShard(usize),
    /// At most this many bands (clamped to the row count); the explicit
    /// shard count for large transforms, independent of exec lanes.
    MaxShards(usize),
}

impl ShardPolicy {
    /// Number of band work items for a stage of `rows` rows, given the
    /// lane count `exec_lanes` the plan's [`ExecPolicy`] granted.
    /// Always at least 1 and at most `rows` (a band owns whole rows).
    pub fn bands(self, rows: usize, exec_lanes: usize) -> usize {
        let rows = rows.max(1);
        match self {
            ShardPolicy::Auto => exec_lanes.max(1).min(rows),
            ShardPolicy::MinRowsPerShard(m) => (rows / m.max(1)).clamp(1, rows),
            ShardPolicy::MaxShards(k) => k.clamp(1, rows),
        }
    }

    /// Process-default shard policy: `MDDCT_SHARD_MIN_ROWS` maps to
    /// [`ShardPolicy::MinRowsPerShard`], else `MDDCT_MAX_SHARDS` to
    /// [`ShardPolicy::MaxShards`], else [`ShardPolicy::Auto`].
    pub fn from_env() -> ShardPolicy {
        if let Some(m) = env_usize("MDDCT_SHARD_MIN_ROWS") {
            return ShardPolicy::MinRowsPerShard(m);
        }
        if let Some(k) = env_usize("MDDCT_MAX_SHARDS") {
            return ShardPolicy::MaxShards(k);
        }
        ShardPolicy::Auto
    }

    /// Human-readable label (bench tables / metrics).
    pub fn label(self) -> String {
        match self {
            ShardPolicy::Auto => "shard-auto".to_string(),
            ShardPolicy::MinRowsPerShard(m) => format!("min-rows({m})"),
            ShardPolicy::MaxShards(k) => format!("max-shards({k})"),
        }
    }
}

/// Parse a positive usize from an env var (see [`crate::util::env_usize`];
/// re-exported here because the thread/worker-count defaults historically
/// lived in this module).
pub use crate::util::env_usize;

/// Process-wide default lane count: `MDDCT_THREADS` env override, else
/// the machine's available parallelism. Resolved once.
pub fn default_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        env_usize("MDDCT_THREADS")
            .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_is_one_lane() {
        assert_eq!(ExecPolicy::Serial.lanes(1 << 30), 1);
    }

    #[test]
    fn threads_clamps_to_one() {
        assert_eq!(ExecPolicy::Threads(0).lanes(10), 1);
        assert_eq!(ExecPolicy::Threads(5).lanes(10), 5);
    }

    #[test]
    fn auto_respects_threshold() {
        assert_eq!(ExecPolicy::Auto.lanes(AUTO_MIN_WORK - 1), 1);
        assert!(ExecPolicy::Auto.lanes(AUTO_MIN_WORK) >= 1);
    }

    #[test]
    fn default_policy_is_auto() {
        assert_eq!(ExecPolicy::default(), ExecPolicy::Auto);
    }

    #[test]
    fn shard_auto_defers_to_exec_lanes() {
        assert_eq!(ShardPolicy::Auto.bands(1024, 1), 1);
        assert_eq!(ShardPolicy::Auto.bands(1024, 8), 8);
        // clamped to whole rows
        assert_eq!(ShardPolicy::Auto.bands(3, 8), 3);
        assert_eq!(ShardPolicy::default(), ShardPolicy::Auto);
    }

    #[test]
    fn max_shards_pins_band_count() {
        // independent of exec lanes in both directions
        assert_eq!(ShardPolicy::MaxShards(4).bands(1024, 1), 4);
        assert_eq!(ShardPolicy::MaxShards(1).bands(1024, 16), 1);
        assert_eq!(ShardPolicy::MaxShards(7).bands(3, 16), 3);
        assert_eq!(ShardPolicy::MaxShards(0).bands(10, 2), 1);
    }

    #[test]
    fn min_rows_per_shard_guarantees_band_height() {
        for (rows, m) in [(1024usize, 128usize), (1000, 7), (5, 2), (8192, 1)] {
            let bands = ShardPolicy::MinRowsPerShard(m).bands(rows, 1);
            assert!(bands >= 1 && bands <= rows);
            // near-equal split keeps every band at >= m rows
            assert!(rows / bands >= m, "rows={rows} m={m} bands={bands}");
        }
        // small requests collapse to one band instead of over-splitting
        assert_eq!(ShardPolicy::MinRowsPerShard(64).bands(16, 8), 1);
        assert_eq!(ShardPolicy::MinRowsPerShard(0).bands(16, 8), 16);
    }

    #[test]
    fn shard_labels_are_stable() {
        assert_eq!(ShardPolicy::Auto.label(), "shard-auto");
        assert_eq!(ShardPolicy::MaxShards(4).label(), "max-shards(4)");
        assert_eq!(ShardPolicy::MinRowsPerShard(64).label(), "min-rows(64)");
    }
}
