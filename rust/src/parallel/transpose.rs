//! Cache-blocked, optionally parallel tiled transpose.
//!
//! The row-column baseline spends two of its eight full-matrix memory
//! stages here (Fig. 5), and the parallel 2D RFFT reuses it to turn the
//! strided column-FFT stage into contiguous row FFTs. Work is split into
//! bands of output rows — each band is one contiguous slice of `out`, so
//! the fan-out needs no aliasing tricks — and each band is walked in
//! `TILE` x `TILE` blocks so both the strided reads and the sequential
//! writes stay cache-resident.
//!
//! These output-row bands are also the natural shard boundary for
//! band-sharded transform execution (see [`crate::coordinator::shard`]):
//! the transpose is the barrier where row-stage shards meet and
//! column-stage shards are re-dealt, so anything that owns whole bands
//! on both sides composes with it without extra synchronization.

use super::ceil_div;
use super::par_iter::par_chunks_mut;

/// Tile edge (doubles as the band-rounding unit). 32x32 f64 tiles are
/// 8 KiB read + 8 KiB written: comfortably L1-resident.
pub const TILE: usize = 32;

/// Rows per output band when transposing into `out_rows` rows over up
/// to `lanes` workers: the per-lane share rounded up to whole tiles, so
/// no two lanes ever split a tile row between them. This is the band
/// height shard work items inherit at the transpose barrier.
pub fn band_rows(out_rows: usize, lanes: usize) -> usize {
    if lanes <= 1 {
        out_rows
    } else {
        (ceil_div(ceil_div(out_rows, lanes), TILE) * TILE).min(out_rows)
    }
}

/// Transpose row-major `x` (n1 x n2) into `out` (n2 x n1), fanning out
/// over up to `lanes` workers. `lanes <= 1` is the serial blocked loop.
pub fn transpose_into<T>(x: &[T], out: &mut [T], n1: usize, n2: usize, lanes: usize)
where
    T: Copy + Send + Sync,
{
    assert_eq!(x.len(), n1 * n2);
    assert_eq!(out.len(), n1 * n2);
    if n1 == 0 || n2 == 0 {
        return;
    }
    // band = a run of output rows, rounded to whole tiles so lanes do not
    // split a tile row between them
    let band_rows = band_rows(n2, lanes);
    par_chunks_mut(out, band_rows * n1, lanes, |band_idx, band| {
        let r0 = band_idx * band_rows; // first output row of this band
        let rows = band.len() / n1;
        for rb in (0..rows).step_by(TILE) {
            let rend = (rb + TILE).min(rows);
            for cb in (0..n1).step_by(TILE) {
                let cend = (cb + TILE).min(n1);
                for r in rb..rend {
                    let src_col = r0 + r; // output row r = input column
                    let dst = &mut band[r * n1..r * n1 + n1];
                    for (c, d) in dst[cb..cend].iter_mut().enumerate() {
                        *d = x[(cb + c) * n2 + src_col];
                    }
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive<T: Copy + Default>(x: &[T], n1: usize, n2: usize) -> Vec<T> {
        let mut out = vec![T::default(); n1 * n2];
        for r in 0..n1 {
            for c in 0..n2 {
                out[c * n1 + r] = x[r * n2 + c];
            }
        }
        out
    }

    #[test]
    fn matches_naive_all_lane_counts() {
        for &(n1, n2) in &[(1usize, 1usize), (3, 7), (32, 32), (33, 65), (128, 20), (5, 200)]
        {
            let x: Vec<f64> = (0..n1 * n2).map(|i| i as f64).collect();
            let want = naive(&x, n1, n2);
            for lanes in [1usize, 2, 3, 8] {
                let mut out = vec![0.0; n1 * n2];
                transpose_into(&x, &mut out, n1, n2, lanes);
                assert_eq!(out, want, "({n1},{n2}) lanes={lanes}");
            }
        }
    }

    #[test]
    fn double_transpose_is_identity() {
        let (n1, n2) = (37, 91);
        let x: Vec<f64> = (0..n1 * n2).map(|i| (i as f64).sin()).collect();
        let mut t = vec![0.0; n1 * n2];
        let mut back = vec![0.0; n1 * n2];
        transpose_into(&x, &mut t, n1, n2, 4);
        transpose_into(&t, &mut back, n2, n1, 4);
        assert_eq!(back, x);
    }

    #[test]
    fn band_rows_is_tile_aligned_and_covering() {
        assert_eq!(band_rows(100, 1), 100);
        for (rows, lanes) in [(100usize, 4usize), (64, 2), (33, 8), (8192, 6), (7, 3)] {
            let b = band_rows(rows, lanes);
            assert!(b >= 1 && b <= rows);
            // tile-aligned unless a single band covers everything
            assert!(b == rows || b % TILE == 0, "rows={rows} lanes={lanes} b={b}");
            // the rounded bands still cover all rows with <= lanes bands
            assert!(crate::parallel::ceil_div(rows, b) <= lanes.max(1));
        }
    }

    #[test]
    fn works_for_non_f64_payloads() {
        let (n1, n2) = (4, 6);
        let x: Vec<u32> = (0..24).collect();
        let mut out = vec![0u32; 24];
        transpose_into(&x, &mut out, n1, n2, 2);
        assert_eq!(out, naive(&x, n1, n2));
    }
}
