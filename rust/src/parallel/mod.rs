//! Work-sharing execution layer for the fused transform pipeline.
//!
//! The paper's three-stage `preprocess -> MD FFT -> postprocess` pipeline
//! is embarrassingly parallel inside every stage (row batches of 1D
//! FFTs, per-row reorders, paired-row postprocess, tiled transposes);
//! this module supplies the CPU execution substrate that exploits it,
//! in the spirit of EFFT's and Korotkevich's SMP-parallel 2D FFT
//! subroutines:
//!
//! * [`pool`]      — process-wide scoped thread pool with work-sharing
//!   waits (nested scopes cannot deadlock) and caller-side panic
//!   propagation; spawned once, shared by plans and the service;
//! * [`par_iter`]  — `parallel_for` / `parallel_for_chunks` /
//!   `par_chunks_mut` chunked loops with inline serial fallback;
//! * [`transpose`] — cache-blocked parallel tiled transpose (the
//!   row-column baseline's stages 2/6, and the trick that turns column
//!   FFTs into contiguous row FFTs);
//! * [`policy`]    — [`ExecPolicy`] (`Serial` / `Threads(n)` / `Auto`)
//!   carried by every plan (`Auto` stays serial below a work threshold),
//!   and [`ShardPolicy`] (`Auto` / `MinRowsPerShard` / `MaxShards`)
//!   pinning how many row-band work items a banded stage becomes — the
//!   substrate of the coordinator's band-sharded execution
//!   ([`crate::coordinator::shard`]).
//!
//! ```
//! use mddct::parallel::{band_spans, ExecPolicy, ShardPolicy};
//!
//! // ExecPolicy answers "how many lanes may run at once" ...
//! assert_eq!(ExecPolicy::Threads(4).lanes(1 << 20), 4);
//! // ... ShardPolicy answers "how many band work items one stage becomes"
//! assert_eq!(ShardPolicy::MaxShards(8).bands(1024, 1), 8);
//! // and band_spans is the row decomposition those work items own
//! let spans = band_spans(10, 3);
//! assert_eq!(spans, vec![0..4, 4..7, 7..10]);
//! ```
//!
//! Determinism contract, stated *per FFT kernel* (see
//! [`crate::fft::FftKernel`]): `Serial` and `Threads(1)` run the
//! identical instruction stream (bit-equal outputs), and for a fixed
//! kernel the parallel paths are arithmetic-order-preserving per
//! element — each kernel's blocked column path performs the same f64
//! operation sequence as its 1D path — so `Threads(n)` matches `Serial`
//! bit-for-bit on every transform in the crate *given the same kernel
//! selection*. Outputs of different kernels (scalar radix-2 vs
//! split-radix/radix-4 SoA) agree only to rounding, not bit-for-bit.

#![warn(missing_docs)]

pub mod par_iter;
pub mod policy;
pub mod pool;
pub mod transpose;

/// Ceiling division, shared by the chunking and tiling math.
pub(crate) fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

pub use par_iter::{
    band_spans, par_chunks_mut, par_strided_chunks_mut, parallel_for, parallel_for_chunks,
    slab_spans, split_groups,
};
pub use policy::{default_threads, ExecPolicy, ShardPolicy, AUTO_MIN_WORK};
pub use pool::{global as global_pool, ThreadPool};
pub use transpose::transpose_into;
