//! Chunked data-parallel loops over the shared pool.
//!
//! Three shapes cover every hot path in the crate:
//! * [`parallel_for_chunks`] — index-range fan-out (read-only or
//!   interior-disjoint work);
//! * [`parallel_for`] — per-index convenience over the same machinery;
//! * [`par_chunks_mut`] — split a mutable slice into fixed-size chunks
//!   (rows, slabs) and fan the chunks out; this is the safe primitive
//!   behind the row-batched FFT/DCT stages.
//!
//! Every entry point degrades to a plain inline loop when it gets one
//! lane (or one chunk), so `ExecPolicy::Serial` / `Threads(1)` execute
//! the exact same instruction stream as the pre-parallel code.

use std::ops::Range;

use super::{ceil_div, pool};

/// Split `0..n` into at most `lanes` contiguous ranges of at least
/// `min_chunk` items (the last range may be shorter only when `n` is).
pub fn chunk_ranges(n: usize, lanes: usize, min_chunk: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let min_chunk = min_chunk.max(1);
    let pieces = ceil_div(n, min_chunk).min(lanes.max(1));
    let per = n / pieces;
    let extra = n % pieces;
    let mut out = Vec::with_capacity(pieces);
    let mut start = 0;
    for i in 0..pieces {
        let len = per + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Run `f` over contiguous sub-ranges of `0..n` on up to `lanes` workers.
/// Serial (inline, zero pool traffic) when one lane or one range suffices.
pub fn parallel_for_chunks<F>(n: usize, lanes: usize, min_chunk: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    if n == 0 {
        return;
    }
    if lanes <= 1 {
        f(0..n);
        return;
    }
    let ranges = chunk_ranges(n, lanes, min_chunk);
    if ranges.len() <= 1 {
        f(0..n);
        return;
    }
    let fref = &f;
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = ranges
        .into_iter()
        .map(|r| Box::new(move || fref(r)) as Box<dyn FnOnce() + Send + '_>)
        .collect();
    pool::global().scope(jobs);
}

/// Per-index parallel loop (`f(i)` for i in 0..n) over up to `lanes`
/// workers; indices are handed out in contiguous blocks.
pub fn parallel_for<F>(n: usize, lanes: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    parallel_for_chunks(n, lanes, 1, |r| {
        for i in r {
            f(i);
        }
    });
}

/// Apply `f(chunk_index, chunk)` to each consecutive `chunk_len`-slice of
/// `data` (the trailing chunk may be shorter), distributing groups of
/// consecutive chunks across up to `lanes` workers. Chunk indices and
/// visit order within a lane match the serial `chunks_mut` loop.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, lanes: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    let nchunks = ceil_div(data.len(), chunk_len);
    if lanes <= 1 || nchunks <= 1 {
        for (i, ch) in data.chunks_mut(chunk_len).enumerate() {
            f(i, ch);
        }
        return;
    }
    let lanes = lanes.min(nchunks);
    let per = nchunks / lanes;
    let extra = nchunks % lanes;
    let fref = &f;
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(lanes);
    let mut rest = data;
    let mut first_chunk = 0;
    for lane in 0..lanes {
        let take_chunks = per + usize::from(lane < extra);
        let take_elems = (take_chunks * chunk_len).min(rest.len());
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(take_elems);
        rest = tail;
        let first = first_chunk;
        first_chunk += take_chunks;
        jobs.push(Box::new(move || {
            for (j, ch) in head.chunks_mut(chunk_len).enumerate() {
                fref(first + j, ch);
            }
        }));
    }
    pool::global().scope(jobs);
}

/// Strided sibling of [`par_chunks_mut`]: apply `f(i, chunk)` to
/// `nchunks` fixed-size chunks that start `stride` elements apart in
/// `data` (so there may be a gap of `stride - chunk_len` untouched
/// elements between consecutive chunks — the padded-batch output shape
/// a [`crate::layout::Layout`] with `batch_stride > numel` describes).
/// The trailing chunk needs no padding after it: `data` must hold
/// `(nchunks - 1) * stride + chunk_len` elements. Gap elements are
/// never read or written. With `stride == chunk_len` and
/// `data.len() == nchunks * chunk_len` this visits exactly the chunks
/// [`par_chunks_mut`] would.
pub fn par_strided_chunks_mut<T, F>(
    data: &mut [T],
    chunk_len: usize,
    stride: usize,
    nchunks: usize,
    lanes: usize,
    f: F,
) where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    assert!(stride >= chunk_len, "stride must cover the chunk (chunks may not overlap)");
    if nchunks == 0 {
        return;
    }
    assert!(
        data.len() >= (nchunks - 1) * stride + chunk_len,
        "data too short for {nchunks} strided chunks"
    );
    if lanes <= 1 || nchunks <= 1 {
        for i in 0..nchunks {
            f(i, &mut data[i * stride..i * stride + chunk_len]);
        }
        return;
    }
    // carve every chunk slice up front (disjoint because stride >=
    // chunk_len), then distribute groups of consecutive chunks exactly
    // like par_chunks_mut
    let mut chunks: Vec<(usize, &mut [T])> = Vec::with_capacity(nchunks);
    let mut rest = data;
    let mut consumed = 0;
    for i in 0..nchunks {
        let skip = i * stride - consumed;
        let (_gap, tail) = std::mem::take(&mut rest).split_at_mut(skip);
        let (chunk, tail) = tail.split_at_mut(chunk_len);
        rest = tail;
        consumed = i * stride + chunk_len;
        chunks.push((i, chunk));
    }
    let fref = &f;
    let groups = split_groups(chunks, lanes);
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = groups
        .into_iter()
        .map(|group| {
            Box::new(move || {
                for (i, ch) in group {
                    fref(i, ch);
                }
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool::global().scope(jobs);
}

/// Split `0..rows` into exactly `min(bands, rows)` contiguous row spans
/// of near-equal height (earlier spans take the one extra row when the
/// split is not divisible). This is the shard-band math: a span is the
/// set of rows one shard work item owns, and it delegates to the same
/// [`chunk_ranges`] distribution [`par_chunks_mut`] hands each lane, so
/// a band plan computed here describes the slices the pool will
/// actually execute.
pub fn band_spans(rows: usize, bands: usize) -> Vec<Range<usize>> {
    chunk_ranges(rows, bands.max(1), 1)
}

/// Split `0..slabs` dim-0 slabs of a 3D tensor into `min(bands, slabs)`
/// contiguous slab spans — identical math to [`band_spans`] (a slab is
/// a band of the tensor's leading dimension), named separately so 3D
/// call sites read as slabs and kept delegating so the 2D band and 3D
/// slab decompositions can never drift apart.
pub fn slab_spans(slabs: usize, bands: usize) -> Vec<Range<usize>> {
    band_spans(slabs, bands)
}

/// Split an owned vec into up to `lanes` contiguous groups (used to
/// distribute non-uniform work items, e.g. postprocess row pairs).
pub fn split_groups<T>(mut items: Vec<T>, lanes: usize) -> Vec<Vec<T>> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let lanes = lanes.max(1).min(n);
    let per = n / lanes;
    let extra = n % lanes;
    let mut out = Vec::with_capacity(lanes);
    // carve from the back so each drain is O(group)
    for lane in (0..lanes).rev() {
        let take = per + usize::from(lane < extra);
        let group: Vec<T> = items.split_off(items.len() - take);
        out.push(group);
    }
    out.reverse();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunk_ranges_cover_exactly() {
        for &(n, lanes, min) in
            &[(10usize, 3usize, 1usize), (7, 16, 1), (100, 4, 8), (5, 2, 10), (64, 8, 16)]
        {
            let rs = chunk_ranges(n, lanes, min);
            assert!(rs.len() <= lanes.max(1));
            let mut next = 0;
            for r in &rs {
                assert_eq!(r.start, next);
                assert!(!r.is_empty());
                next = r.end;
            }
            assert_eq!(next, n, "n={n} lanes={lanes} min={min}");
        }
        assert!(chunk_ranges(0, 4, 1).is_empty());
    }

    #[test]
    fn parallel_for_visits_every_index_once() {
        let n = 1000;
        let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(n, 4, |i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_chunks_mut_matches_serial_chunks() {
        for &(len, chunk, lanes) in
            &[(64usize, 8usize, 4usize), (65, 8, 4), (7, 8, 4), (100, 9, 3), (12, 1, 16)]
        {
            let mut par = vec![0usize; len];
            par_chunks_mut(&mut par, chunk, lanes, |i, ch| {
                for (j, v) in ch.iter_mut().enumerate() {
                    *v = i * 1000 + j;
                }
            });
            let mut ser = vec![0usize; len];
            for (i, ch) in ser.chunks_mut(chunk).enumerate() {
                for (j, v) in ch.iter_mut().enumerate() {
                    *v = i * 1000 + j;
                }
            }
            assert_eq!(par, ser, "len={len} chunk={chunk} lanes={lanes}");
        }
    }

    #[test]
    fn single_lane_runs_inline() {
        // runs on the calling thread: a non-Send-hostile check via thread id
        let caller = std::thread::current().id();
        let mut data = vec![0u8; 16];
        par_chunks_mut(&mut data, 4, 1, |_, ch| {
            assert_eq!(std::thread::current().id(), caller);
            ch.fill(1);
        });
        assert!(data.iter().all(|&b| b == 1));
    }

    #[test]
    fn par_strided_chunks_mut_touches_only_chunks() {
        for &(chunk, stride, nchunks, lanes) in &[
            (4usize, 7usize, 5usize, 1usize),
            (4, 7, 5, 3),
            (4, 4, 6, 4), // degenerate: stride == chunk_len
            (1, 3, 9, 16),
            (8, 13, 1, 4),
        ] {
            let len = (nchunks - 1) * stride + chunk;
            let mut par = vec![0usize; len + 2]; // slack after the last chunk
            par_strided_chunks_mut(&mut par, chunk, stride, nchunks, lanes, |i, ch| {
                assert_eq!(ch.len(), chunk);
                for (j, v) in ch.iter_mut().enumerate() {
                    *v = i * 1000 + j + 1;
                }
            });
            let mut ser = vec![0usize; len + 2];
            for i in 0..nchunks {
                for j in 0..chunk {
                    ser[i * stride + j] = i * 1000 + j + 1;
                }
            }
            assert_eq!(par, ser, "chunk={chunk} stride={stride} nchunks={nchunks} lanes={lanes}");
            // gap elements stayed zero
            let touched: usize = par.iter().filter(|&&v| v != 0).count();
            assert_eq!(touched, nchunks * chunk);
        }
        // nchunks == 0 is a no-op
        let mut empty = vec![1u8; 4];
        par_strided_chunks_mut(&mut empty, 2, 3, 0, 4, |_, _| panic!("no chunks"));
        assert_eq!(empty, vec![1u8; 4]);
    }

    #[test]
    fn band_spans_cover_rows_near_equally() {
        for &(rows, bands) in
            &[(10usize, 3usize), (7, 7), (7, 16), (8192, 6), (1, 1), (33, 2), (100, 1)]
        {
            let spans = band_spans(rows, bands);
            assert_eq!(spans.len(), bands.min(rows));
            let mut next = 0;
            let (mut lo, mut hi) = (usize::MAX, 0usize);
            for s in &spans {
                assert_eq!(s.start, next);
                assert!(!s.is_empty());
                lo = lo.min(s.len());
                hi = hi.max(s.len());
                next = s.end;
            }
            assert_eq!(next, rows, "rows={rows} bands={bands}");
            assert!(hi - lo <= 1, "near-equal split: rows={rows} bands={bands}");
        }
        assert!(band_spans(0, 4).is_empty());
    }

    #[test]
    fn slab_spans_is_band_spans() {
        for &(slabs, bands) in &[(64usize, 4usize), (7, 3), (1, 8), (9, 7)] {
            assert_eq!(slab_spans(slabs, bands), band_spans(slabs, bands));
        }
    }

    #[test]
    fn split_groups_preserves_order_and_len() {
        let items: Vec<usize> = (0..11).collect();
        let groups = split_groups(items.clone(), 3);
        assert_eq!(groups.len(), 3);
        let flat: Vec<usize> = groups.into_iter().flatten().collect();
        assert_eq!(flat, items);
        assert_eq!(split_groups(Vec::<u8>::new(), 4).len(), 0);
        let one = split_groups(vec![42], 8);
        assert_eq!(one, vec![vec![42]]);
    }
}
