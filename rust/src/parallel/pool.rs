//! Process-wide scoped thread pool (dependency-free rayon-core
//! substitute).
//!
//! One pool of `default_threads()` workers is spawned lazily and shared
//! by every plan, the row-column baseline, and the coordinator's
//! workers — transforms never spawn ad-hoc threads. [`ThreadPool::scope`]
//! runs a batch of jobs that may borrow the caller's stack: the caller
//! blocks until the whole scope drains, which is what makes the lifetime
//! erasure sound.
//!
//! Two properties matter for the service layer:
//! * **work sharing** — a caller waiting on its scope executes queued
//!   jobs (its own or another scope's) instead of parking, so nested
//!   scopes cannot deadlock even when every worker is itself blocked
//!   inside a scope;
//! * **panic isolation** — jobs run under `catch_unwind`; a panicking
//!   job marks its scope and the panic is re-raised on the *caller's*
//!   thread once the scope drains, so pool workers never die and
//!   unrelated scopes are unaffected.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Duration;

use super::policy::default_threads;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Completion latch for one scope: outstanding-job count plus a sticky
/// "did any job panic" flag.
struct Latch {
    state: Mutex<(usize, bool)>,
    cv: Condvar,
}

impl Latch {
    fn new(jobs: usize) -> Latch {
        Latch { state: Mutex::new((jobs, false)), cv: Condvar::new() }
    }

    fn complete(&self, panicked: bool) {
        let mut s = self.state.lock().unwrap();
        s.0 -= 1;
        s.1 |= panicked;
        if s.0 == 0 {
            self.cv.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        self.state.lock().unwrap().0 == 0
    }

    /// Block until done or `timeout`, whichever first.
    fn wait_timeout(&self, timeout: Duration) {
        let s = self.state.lock().unwrap();
        if s.0 > 0 {
            let _ = self.cv.wait_timeout(s, timeout).unwrap();
        }
    }

    fn panicked(&self) -> bool {
        self.state.lock().unwrap().1
    }
}

/// A fixed-size pool of worker threads executing scoped job batches.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    rx: Arc<Mutex<Receiver<Job>>>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
    /// Jobs enqueued but not yet started (sampled into the trace as the
    /// `pool.queue_depth` counter when tracing is enabled).
    depth: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn a pool with `size` workers (clamped to at least 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("mddct-par-{i}"))
                    .spawn(move || worker_loop(rx))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), rx, workers, size, depth: Arc::new(AtomicUsize::new(0)) }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Jobs enqueued but not yet started.
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Run `jobs` to completion. Jobs may borrow from the caller's stack
    /// (`'scope`); the call does not return until every job has finished.
    /// The calling thread work-shares while it waits. If any job
    /// panicked, the panic is re-raised here after the scope drains.
    pub fn scope<'scope>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        if jobs.is_empty() {
            return;
        }
        let _scope_span = crate::obs::SpanGuard::begin("pool.scope");
        let latch = Arc::new(Latch::new(jobs.len()));
        let tx = self.tx.as_ref().expect("pool running");
        for job in jobs {
            // SAFETY: `scope` blocks below until the latch has counted
            // every job complete, so borrows with lifetime 'scope outlive
            // every possible execution of `job`. The transmute erases
            // only the lifetime parameter of the trait object; the fat
            // pointer layout is identical.
            let job: Job = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 'scope>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(job)
            };
            let latch = latch.clone();
            let depth = self.depth.clone();
            let wrapped: Job = Box::new(move || {
                depth.fetch_sub(1, Ordering::Relaxed);
                let panicked = {
                    // per-job span: on a pool worker this is the
                    // worker's busy interval; gaps between job spans on
                    // one track are its idle time
                    let _job_span = crate::obs::SpanGuard::begin("pool.job");
                    catch_unwind(AssertUnwindSafe(|| job())).is_err()
                };
                latch.complete(panicked);
            });
            let queued = self.depth.fetch_add(1, Ordering::Relaxed) + 1;
            crate::obs::counter("pool.queue_depth", queued as f64);
            tx.send(wrapped).expect("pool workers alive");
        }
        // Work-share while waiting: if the queue is empty our jobs are
        // already running (or done) on workers, so a bounded wait on the
        // latch is safe; the timeout re-polls the queue for late arrivals
        // from other scopes to keep draining global progress.
        loop {
            if latch.is_done() {
                break;
            }
            match self.try_pop() {
                Some(job) => job(),
                None => latch.wait_timeout(Duration::from_micros(200)),
            }
        }
        if latch.panicked() {
            panic!("mddct parallel worker panicked (original panic above)");
        }
    }

    fn try_pop(&self) -> Option<Job> {
        // try_lock, not lock: an idle worker parks inside `recv()` while
        // holding the mutex, so a blocking lock here would hang the
        // caller until the next unrelated send. Failing to grab the lock
        // just means someone else is already draining the queue.
        match self.rx.try_lock() {
            Ok(rx) => rx.try_recv().ok(),
            Err(_) => None,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Closing the channel lets workers observe RecvError and exit.
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Job>>>) {
    loop {
        // Hold the lock only while receiving, never while executing.
        let job = match rx.lock().unwrap().recv() {
            Ok(j) => j,
            Err(_) => return,
        };
        job(); // wrapped: catches panics and counts down its latch
    }
}

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

/// The process-wide shared pool (size = [`default_threads`]), spawned on
/// first use and alive for the life of the process.
pub fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| ThreadPool::new(default_threads()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn boxed<'a>(f: impl FnOnce() + Send + 'a) -> Box<dyn FnOnce() + Send + 'a> {
        Box::new(f)
    }

    #[test]
    fn scope_runs_all_jobs_with_borrows() {
        let pool = ThreadPool::new(4);
        let mut out = vec![0usize; 64];
        {
            let jobs = out
                .chunks_mut(8)
                .enumerate()
                .map(|(i, ch)| {
                    boxed(move || {
                        for (j, v) in ch.iter_mut().enumerate() {
                            *v = i * 8 + j;
                        }
                    })
                })
                .collect();
            pool.scope(jobs);
        }
        let want: Vec<usize> = (0..64).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn empty_scope_is_a_noop() {
        let pool = ThreadPool::new(2);
        pool.scope(Vec::new());
    }

    #[test]
    fn nested_scopes_do_not_deadlock() {
        let pool = ThreadPool::new(2);
        let hits = AtomicUsize::new(0);
        let hits_ref = &hits;
        let pool_ref = &pool;
        // every outer job opens an inner scope on the same 2-worker pool
        let jobs = (0..4)
            .map(|_| {
                boxed(move || {
                    let inner = (0..4)
                        .map(|_| {
                            boxed(move || {
                                hits_ref.fetch_add(1, Ordering::Relaxed);
                            })
                        })
                        .collect();
                    pool_ref.scope(inner);
                })
            })
            .collect();
        pool.scope(jobs);
        assert_eq!(hits.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn panic_propagates_to_caller_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.scope(vec![
                boxed(|| {}),
                boxed(|| panic!("job boom")),
                boxed(|| {}),
            ]);
        }));
        assert!(caught.is_err(), "scope must re-raise the job panic");
        // pool still works after the panic
        let ok = AtomicUsize::new(0);
        let ok_ref = &ok;
        pool.scope(vec![boxed(move || {
            ok_ref.fetch_add(1, Ordering::Relaxed);
        })]);
        assert_eq!(ok.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn queue_depth_drains_to_zero_after_scope() {
        let pool = ThreadPool::new(2);
        let jobs = (0..8).map(|_| boxed(|| {})).collect();
        pool.scope(jobs);
        assert_eq!(pool.queue_depth(), 0);
    }

    #[test]
    fn global_pool_is_shared_and_sized() {
        let a = global();
        let b = global();
        assert!(std::ptr::eq(a, b));
        assert!(a.size() >= 1);
    }
}
