//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime. `make artifacts` writes `artifacts/manifest.json` +
//! one `<name>.hlo.txt` per compiled pipeline; this module parses it.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};
use crate::{anyhow, bail};

use crate::util::json::Json;

/// Tensor spec (shape + dtype) for one artifact input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("spec missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("spec missing dtype"))?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One AOT-compiled pipeline.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub pipeline: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub dtype: String,
    pub entries: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (tested without touching the filesystem).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest is not valid json")?;
        let version = j.get("version").and_then(Json::as_f64).unwrap_or(0.0);
        if version != 1.0 {
            bail!("unsupported manifest version {version}");
        }
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .unwrap_or("f32")
            .to_string();
        let mut entries = BTreeMap::new();
        for e in j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing entries"))?
        {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("entry missing name"))?
                .to_string();
            let pipeline = e
                .get("pipeline")
                .and_then(Json::as_str)
                .unwrap_or(&name)
                .to_string();
            let file = dir.join(
                e.get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("entry {name} missing file"))?,
            );
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                e.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("entry {name} missing {key}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            let entry = ArtifactEntry {
                name: name.clone(),
                pipeline,
                file,
                inputs: parse_specs("inputs")?,
                outputs: parse_specs("outputs")?,
            };
            entries.insert(name, entry);
        }
        Ok(Manifest { dir, dtype, entries })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactEntry> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest ({} entries)", self.entries.len()))
    }

    /// Names matching a prefix (e.g. all `dct2d_*` shapes).
    pub fn names_with_prefix(&self, prefix: &str) -> Vec<&str> {
        self.entries
            .keys()
            .filter(|k| k.starts_with(prefix))
            .map(|s| s.as_str())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "dtype": "f32",
      "entries": [
        {"name": "dct2d_8x8", "pipeline": "dct2d", "file": "dct2d_8x8.hlo.txt",
         "inputs": [{"shape": [8, 8], "dtype": "f32"}],
         "outputs": [{"shape": [8, 8], "dtype": "f32"}]},
        {"name": "rfft2d_8x8", "pipeline": "rfft2d", "file": "rfft2d_8x8.hlo.txt",
         "inputs": [{"shape": [8, 8], "dtype": "f32"}],
         "outputs": [{"shape": [8, 5], "dtype": "f32"}, {"shape": [8, 5], "dtype": "f32"}]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.dtype, "f32");
        assert_eq!(m.entries.len(), 2);
        let e = m.get("dct2d_8x8").unwrap();
        assert_eq!(e.inputs[0].shape, vec![8, 8]);
        assert_eq!(e.inputs[0].numel(), 64);
        assert_eq!(e.file, PathBuf::from("/tmp/a/dct2d_8x8.hlo.txt"));
        let r = m.get("rfft2d_8x8").unwrap();
        assert_eq!(r.outputs.len(), 2);
        assert_eq!(r.outputs[0].shape, vec![8, 5]);
    }

    #[test]
    fn prefix_lookup() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/x")).unwrap();
        assert_eq!(m.names_with_prefix("dct2d_"), vec!["dct2d_8x8"]);
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn rejects_bad_version() {
        let bad = r#"{"version": 9, "entries": []}"#;
        assert!(Manifest::parse(bad, PathBuf::new()).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        let bad = r#"{"version": 1, "entries": [{"name": "x"}]}"#;
        assert!(Manifest::parse(bad, PathBuf::new()).is_err());
    }
}
