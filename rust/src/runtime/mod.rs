//! PJRT runtime: load + execute the AOT artifacts produced by
//! `python/compile/aot.py` (HLO text -> XLA compile -> execute), with a
//! compiled-executable cache. Python is never on this path — artifacts
//! are plain text files on disk.

pub mod artifact;
pub mod client;
pub mod exec_thread;

pub use artifact::{ArtifactEntry, Manifest, TensorSpec};
pub use client::{Executable, ExecStats, PjrtRuntime};
pub use exec_thread::PjrtHandle;

/// Default artifact directory (relative to the repo root / cwd).
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";
