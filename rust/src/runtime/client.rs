//! PJRT execution of AOT artifacts (the `xla` crate over xla_extension).
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `PjRtClient::compile` -> `execute`. Executables are compiled once per
//! artifact and cached (the cuFFT-plan analogue at the runtime level).
//!
//! All pipelines are lowered with `return_tuple=True`, so outputs always
//! arrive as a tuple literal that we decompose.
//!
//! The real client needs the `xla` crate, which the offline toolchain
//! cannot resolve; it is gated behind the `pjrt` cargo feature. The
//! default build compiles [`stub`] instead: the same API surface, every
//! entry point reporting the backend as unavailable, so the coordinator,
//! CLI, and examples build and route natively without artifacts.

use std::sync::Mutex;

/// Runtime statistics for one executable.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    pub compile_seconds: f64,
    pub executions: u64,
    pub exec_seconds_total: f64,
}

#[cfg(feature = "pjrt")]
mod real {
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex};
    use std::time::Instant;

    use super::ExecStats;
    use crate::util::error::{Context, Result};
    use crate::bail;

    use super::super::artifact::{ArtifactEntry, Manifest};

    /// A compiled artifact ready to run.
    pub struct Executable {
        pub entry: ArtifactEntry,
        exe: xla::PjRtLoadedExecutable,
        stats: Mutex<ExecStats>,
    }

    impl Executable {
        /// Execute with f32 inputs (row-major), returning one Vec per output.
        pub fn run_f32(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            if inputs.len() != self.entry.inputs.len() {
                bail!(
                    "artifact {} expects {} inputs, got {}",
                    self.entry.name,
                    self.entry.inputs.len(),
                    inputs.len()
                );
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for (spec, data) in self.entry.inputs.iter().zip(inputs) {
                if data.len() != spec.numel() {
                    bail!(
                        "artifact {}: input size {} != spec {} ({:?})",
                        self.entry.name,
                        data.len(),
                        spec.numel(),
                        spec.shape
                    );
                }
                let lit = xla::Literal::vec1(data);
                let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                literals.push(
                    if dims.len() == 1 && data.len() == spec.numel() && dims[0] as usize == data.len() {
                        lit
                    } else {
                        lit.reshape(&dims)
                            .with_context(|| format!("reshape input for {}", self.entry.name))?
                    },
                );
            }
            let t0 = Instant::now();
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("executing {}", self.entry.name))?;
            let tuple = result[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            let parts = tuple.to_tuple().context("decomposing result tuple")?;
            if parts.len() != self.entry.outputs.len() {
                bail!(
                    "artifact {}: got {} outputs, manifest says {}",
                    self.entry.name,
                    parts.len(),
                    self.entry.outputs.len()
                );
            }
            let mut out = Vec::with_capacity(parts.len());
            for part in parts {
                out.push(part.to_vec::<f32>().context("reading output")?);
            }
            let dt = t0.elapsed().as_secs_f64();
            let mut s = self.stats.lock().unwrap();
            s.executions += 1;
            s.exec_seconds_total += dt;
            Ok(out)
        }

        /// Convenience: f64 in/out (the native backend's element type).
        pub fn run_f64(&self, inputs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
            let f32_in: Vec<Vec<f32>> = inputs
                .iter()
                .map(|v| v.iter().map(|&x| x as f32).collect())
                .collect();
            Ok(self
                .run_f32(&f32_in)?
                .into_iter()
                .map(|v| v.into_iter().map(|x| x as f64).collect())
                .collect())
        }

        pub fn stats(&self) -> ExecStats {
            *self.stats.lock().unwrap()
        }
    }

    /// PJRT client + compiled-executable cache over one artifact directory.
    pub struct PjrtRuntime {
        pub manifest: Manifest,
        client: xla::PjRtClient,
        cache: Mutex<HashMap<String, Arc<Executable>>>,
    }

    impl PjrtRuntime {
        /// Create a CPU PJRT client over `dir` (usually `artifacts/`).
        pub fn new(dir: impl AsRef<std::path::Path>) -> Result<PjrtRuntime> {
            let manifest = Manifest::load(dir)?;
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(PjrtRuntime { manifest, client, cache: Mutex::new(HashMap::new()) })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch cached) executable for a manifest entry.
        pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
            if let Some(e) = self.cache.lock().unwrap().get(name) {
                return Ok(e.clone());
            }
            let entry = self.manifest.get(name)?.clone();
            let t0 = Instant::now();
            let path = entry
                .file
                .to_str()
                .context("non-utf8 artifact path")?
                .to_string();
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("XLA compile of {name}"))?;
            let compile_seconds = t0.elapsed().as_secs_f64();
            let executable = Arc::new(Executable {
                entry,
                exe,
                stats: Mutex::new(ExecStats { compile_seconds, ..Default::default() }),
            });
            self.cache
                .lock()
                .unwrap()
                .insert(name.to_string(), executable.clone());
            Ok(executable)
        }

        /// Number of compiled executables currently cached.
        pub fn cached_count(&self) -> usize {
            self.cache.lock().unwrap().len()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::sync::Arc;

    use super::ExecStats;
    use crate::anyhow;
    use crate::util::error::Result;

    use super::super::artifact::{ArtifactEntry, Manifest};

    const UNAVAILABLE: &str =
        "PJRT backend not compiled in (build with `--features pjrt` and an `xla` dependency)";

    /// Stub executable: the type exists so the coordinator compiles, but
    /// no value is ever constructed (the stub runtime never loads).
    pub struct Executable {
        pub entry: ArtifactEntry,
        stats: super::Mutex<ExecStats>,
    }

    impl Executable {
        pub fn run_f32(&self, _inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            Err(anyhow!("{UNAVAILABLE}"))
        }

        pub fn run_f64(&self, _inputs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
            Err(anyhow!("{UNAVAILABLE}"))
        }

        pub fn stats(&self) -> ExecStats {
            *self.stats.lock().unwrap()
        }
    }

    /// Stub runtime: manifest parsing still works (routing decisions need
    /// it), but client construction always reports unavailable.
    pub struct PjrtRuntime {
        pub manifest: Manifest,
    }

    impl PjrtRuntime {
        pub fn new(dir: impl AsRef<std::path::Path>) -> Result<PjrtRuntime> {
            // Parse the manifest first so missing-artifact errors keep
            // their usual shape, then report the missing backend.
            let _manifest = Manifest::load(dir)?;
            Err(anyhow!("{UNAVAILABLE}"))
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn load(&self, _name: &str) -> Result<Arc<Executable>> {
            Err(anyhow!("{UNAVAILABLE}"))
        }

        pub fn cached_count(&self) -> usize {
            0
        }
    }
}

#[cfg(feature = "pjrt")]
pub use real::{Executable, PjrtRuntime};
#[cfg(not(feature = "pjrt"))]
pub use stub::{Executable, PjrtRuntime};
