//! Single-owner PJRT executor thread.
//!
//! The `xla` crate's PJRT wrappers are `Rc`-based (`!Send`), so the
//! client and its executables must live on one thread. This module gives
//! the multi-threaded coordinator a `Send + Clone` handle: jobs go over a
//! channel to the owner thread, which lazily creates the client, caches
//! compiled executables, and replies per job. (Device-owner threads are
//! the standard pattern for single-context accelerators.)

use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};

use crate::anyhow;
use crate::util::error::Result;

use super::client::PjrtRuntime;

enum Job {
    Run {
        name: String,
        inputs: Vec<Vec<f64>>,
        reply: Sender<Result<Vec<Vec<f64>>, String>>,
    },
    Warmup {
        name: String,
        reply: Sender<Result<f64, String>>,
    },
    Platform {
        reply: Sender<Result<String, String>>,
    },
}

/// Cloneable, `Send` handle to the PJRT owner thread.
#[derive(Clone)]
pub struct PjrtHandle {
    tx: Sender<Job>,
}

impl PjrtHandle {
    /// Spawn the owner thread over an artifact directory.
    pub fn spawn(dir: impl Into<PathBuf>) -> PjrtHandle {
        let dir = dir.into();
        let (tx, rx) = channel::<Job>();
        std::thread::Builder::new()
            .name("mddct-pjrt".into())
            .spawn(move || {
                let rt = PjrtRuntime::new(&dir);
                for job in rx {
                    match (&rt, job) {
                        (Ok(rt), Job::Run { name, inputs, reply }) => {
                            let res = rt
                                .load(&name)
                                .and_then(|exe| exe.run_f64(&inputs))
                                .map_err(|e| format!("{e:#}"));
                            let _ = reply.send(res);
                        }
                        (Ok(rt), Job::Warmup { name, reply }) => {
                            let res = rt
                                .load(&name)
                                .map(|exe| exe.stats().compile_seconds)
                                .map_err(|e| format!("{e:#}"));
                            let _ = reply.send(res);
                        }
                        (Ok(rt), Job::Platform { reply }) => {
                            let _ = reply.send(Ok(rt.platform()));
                        }
                        (Err(e), job) => {
                            let msg = format!("pjrt unavailable: {e:#}");
                            match job {
                                Job::Run { reply, .. } => {
                                    let _ = reply.send(Err(msg));
                                }
                                Job::Warmup { reply, .. } => {
                                    let _ = reply.send(Err(msg));
                                }
                                Job::Platform { reply } => {
                                    let _ = reply.send(Err(msg));
                                }
                            }
                        }
                    }
                }
            })
            .expect("spawn pjrt thread");
        PjrtHandle { tx }
    }

    /// Execute an artifact by name (blocks the calling worker only).
    pub fn run(&self, name: &str, inputs: Vec<Vec<f64>>) -> Result<Vec<Vec<f64>>> {
        let (reply, rx) = channel();
        self.tx
            .send(Job::Run { name: name.to_string(), inputs, reply })
            .map_err(|_| anyhow!("pjrt thread gone"))?;
        rx.recv().map_err(|_| anyhow!("pjrt thread gone"))?.map_err(|e| anyhow!(e))
    }

    /// Pre-compile an artifact; returns compile seconds.
    pub fn warmup(&self, name: &str) -> Result<f64> {
        let (reply, rx) = channel();
        self.tx
            .send(Job::Warmup { name: name.to_string(), reply })
            .map_err(|_| anyhow!("pjrt thread gone"))?;
        rx.recv().map_err(|_| anyhow!("pjrt thread gone"))?.map_err(|e| anyhow!(e))
    }

    /// PJRT platform name (e.g. "cpu"); errors if the runtime failed.
    pub fn platform(&self) -> Result<String> {
        let (reply, rx) = channel();
        self.tx
            .send(Job::Platform { reply })
            .map_err(|_| anyhow!("pjrt thread gone"))?;
        rx.recv().map_err(|_| anyhow!("pjrt thread gone"))?.map_err(|e| anyhow!(e))
    }
}
