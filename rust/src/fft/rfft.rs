//! Real-input FFT (RFFT) and its inverse, exploiting Hermitian symmetry.
//!
//! For even N the classic N/2-complex packing trick halves the transform
//! size (Sorensen et al., the optimization cuFFT's R2C path uses); odd N
//! falls back to a full complex FFT. Output is the onesided spectrum of
//! length H = N/2 + 1, matching cuFFT/numpy `rfft`.

use std::sync::Arc;

use super::complex::C64;
use super::plan::{plan, FftPlan};
use crate::util::scratch;

/// Onesided spectrum length for a length-n real signal.
#[inline]
pub fn onesided_len(n: usize) -> usize {
    n / 2 + 1
}

/// Plan for real-input FFTs of one size.
#[derive(Debug, Clone)]
pub struct RfftPlan {
    /// Real input length.
    pub n: usize,
    /// half-size complex plan (even n), or full-size plan (odd n)
    inner: Arc<FftPlan>,
    /// split twiddles e^{-j pi k / (n/2)}... for the even-n recombination
    twiddle: Vec<C64>,
    even: bool,
}

impl RfftPlan {
    /// Plan a real-input FFT of length `n` (shared complex-plan cache).
    pub fn new(n: usize) -> RfftPlan {
        RfftPlan::build(n, plan)
    }

    /// Plan whose complex FFT runs an explicit power-of-two kernel
    /// (uncached; the shared [`plan`] cache keeps the process default).
    pub fn with_kernel(n: usize, kernel: crate::fft::FftKernel) -> RfftPlan {
        RfftPlan::build(n, |sz| Arc::new(FftPlan::with_kernel(sz, kernel)))
    }

    fn build(n: usize, inner_plan: impl Fn(usize) -> Arc<FftPlan>) -> RfftPlan {
        assert!(n >= 1);
        let even = n % 2 == 0 && n > 1;
        if even {
            let half = n / 2;
            let tw = (0..half / 2 + 1)
                .map(|k| C64::cis(-2.0 * std::f64::consts::PI * k as f64 / n as f64))
                .collect();
            RfftPlan { n, inner: inner_plan(half), twiddle: tw, even }
        } else {
            RfftPlan { n, inner: inner_plan(n), twiddle: Vec::new(), even }
        }
    }

    /// Register one transform's scratch classes: the packed complex
    /// buffer (half size for even n, full for odd) plus the inner
    /// complex plan's own scratch while that buffer is held.
    pub(crate) fn register_scratch(&self, ws: &mut crate::util::scratch::Workspace) {
        ws.add_c64(if self.even { self.n / 2 } else { self.n });
        self.inner.register_scratch(ws);
    }

    /// Forward RFFT: real input (len n) -> onesided spectrum (len n/2+1).
    pub fn forward(&self, x: &[f64], out: &mut [C64]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(out.len(), onesided_len(self.n));
        if !self.even {
            // full complex transform of the (real) input
            let mut buf = scratch::take_c64(self.n);
            for (b, &r) in buf.iter_mut().zip(x) {
                *b = C64::new(r, 0.0);
            }
            self.inner.forward(&mut buf);
            out.copy_from_slice(&buf[..onesided_len(self.n)]);
            scratch::give_c64(buf);
            return;
        }
        let half = self.n / 2;
        // pack: z[m] = x[2m] + j x[2m+1]
        let mut z = scratch::take_c64(half);
        for (m, zm) in z.iter_mut().enumerate() {
            *zm = C64::new(x[2 * m], x[2 * m + 1]);
        }
        self.inner.forward(&mut z);
        // unpack: X[k] = E[k] + w^k O[k]
        //   E[k] = (Z[k] + conj(Z[h-k]))/2, O[k] = -j(Z[k] - conj(Z[h-k]))/2
        for k in 0..=half {
            let zk = if k == half { z[0] } else { z[k] };
            let zc = z[(half - k) % half].conj();
            let e = (zk + zc).scale(0.5);
            let o = (zk - zc).mul_j().scale(-0.5);
            out[k] = e + self.twiddle_at(k) * o;
        }
        scratch::give_c64(z);
    }

    /// Strided forward RFFT: the length-n real signal lives in `x` at
    /// element stride `stride` (`x[m * stride]` is sample m). Gathers
    /// exactly the values a contiguous [`RfftPlan::forward`] call would
    /// see, in the same arithmetic order, so the output is
    /// bit-identical; `stride == 1` *is* the contiguous call.
    pub fn forward_strided(&self, x: &[f64], stride: usize, out: &mut [C64]) {
        assert!(stride >= 1, "stride must be positive");
        if stride == 1 {
            self.forward(&x[..self.n], out);
            return;
        }
        assert!(x.len() > (self.n - 1) * stride, "strided input too short");
        assert_eq!(out.len(), onesided_len(self.n));
        if !self.even {
            let mut buf = scratch::take_c64(self.n);
            for (i, b) in buf.iter_mut().enumerate() {
                *b = C64::new(x[i * stride], 0.0);
            }
            self.inner.forward(&mut buf);
            out.copy_from_slice(&buf[..onesided_len(self.n)]);
            scratch::give_c64(buf);
            return;
        }
        let half = self.n / 2;
        // pack straight from the strided view: z[m] = x[2m·s] + j x[(2m+1)·s]
        let mut z = scratch::take_c64(half);
        for (m, zm) in z.iter_mut().enumerate() {
            *zm = C64::new(x[2 * m * stride], x[(2 * m + 1) * stride]);
        }
        self.inner.forward(&mut z);
        for k in 0..=half {
            let zk = if k == half { z[0] } else { z[k] };
            let zc = z[(half - k) % half].conj();
            let e = (zk + zc).scale(0.5);
            let o = (zk - zc).mul_j().scale(-0.5);
            out[k] = e + self.twiddle_at(k) * o;
        }
        scratch::give_c64(z);
    }

    /// Batched forward RFFT: `batch` packed rows of length `n` in `x`,
    /// `batch` onesided rows of length `n/2+1` in `out`, fanned out over
    /// up to `lanes` pool workers (`lanes <= 1` = inline serial loop).
    /// Row scratch is per-thread, so workers never contend.
    pub fn forward_batch(&self, x: &[f64], out: &mut [C64], lanes: usize) {
        let (n, h) = (self.n, onesided_len(self.n));
        assert_eq!(x.len() % n, 0, "input not a whole number of rows");
        let batch = x.len() / n;
        assert_eq!(out.len(), batch * h);
        crate::parallel::par_chunks_mut(out, h, lanes, |r, orow| {
            self.forward(&x[r * n..(r + 1) * n], orow);
        });
    }

    /// Batched inverse RFFT: `batch` onesided rows -> `batch` real rows.
    pub fn inverse_batch(&self, spec: &[C64], out: &mut [f64], lanes: usize) {
        let (n, h) = (self.n, onesided_len(self.n));
        assert_eq!(spec.len() % h, 0, "spectrum not a whole number of rows");
        let batch = spec.len() / h;
        assert_eq!(out.len(), batch * n);
        crate::parallel::par_chunks_mut(out, n, lanes, |r, orow| {
            self.inverse(&spec[r * h..(r + 1) * h], orow);
        });
    }

    fn twiddle_at(&self, k: usize) -> C64 {
        let half = self.n / 2;
        if k <= half / 2 {
            self.twiddle[k]
        } else {
            // w^k = -conj(w^{half-k}) since w^{half} = e^{-j pi} = -1
            -self.twiddle[half - k].conj()
        }
    }

    /// Inverse RFFT: onesided spectrum -> real output (len n), normalized.
    pub fn inverse(&self, spec: &[C64], out: &mut [f64]) {
        assert_eq!(spec.len(), onesided_len(self.n));
        assert_eq!(out.len(), self.n);
        if !self.even {
            // reconstruct the full Hermitian spectrum, inverse, take re
            let n = self.n;
            let mut buf = scratch::take_c64(n);
            buf[..spec.len()].copy_from_slice(spec);
            for k in spec.len()..n {
                buf[k] = spec[n - k].conj();
            }
            self.inner.inverse(&mut buf);
            for (o, b) in out.iter_mut().zip(buf.iter()) {
                *o = b.re;
            }
            scratch::give_c64(buf);
            return;
        }
        let half = self.n / 2;
        // invert the unpack: Z[k] = E[k] + j w^{-k}-weighted O[k]
        let mut z = scratch::take_c64(half);
        for k in 0..half {
            let xk = spec[k];
            let xc = spec[half - k].conj();
            let e = (xk + xc).scale(0.5);
            let o = (xk - xc).scale(0.5) * self.twiddle_at(k).conj();
            // z[k] = e + j*o
            z[k] = e + o.mul_j();
        }
        self.inner.inverse(&mut z);
        for m in 0..half {
            out[2 * m] = z[m].re;
            out[2 * m + 1] = z[m].im;
        }
        scratch::give_c64(z);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::radix2::dft_naive;
    use crate::util::rng::Rng;

    #[test]
    fn forward_matches_full_dft() {
        let mut rng = Rng::new(20);
        for &n in &[1usize, 2, 3, 4, 5, 8, 12, 15, 16, 64, 100, 257] {
            let x = rng.normal_vec(n);
            let cx: Vec<C64> = x.iter().map(|&r| C64::new(r, 0.0)).collect();
            let want = dft_naive(&cx, false);
            let plan = RfftPlan::new(n);
            let mut got = vec![C64::default(); onesided_len(n)];
            plan.forward(&x, &mut got);
            for k in 0..onesided_len(n) {
                assert!(
                    (got[k] - want[k]).abs() < 1e-8 * (n as f64).max(1.0),
                    "n={n} k={k}: {:?} vs {:?}",
                    got[k],
                    want[k]
                );
            }
        }
    }

    #[test]
    fn roundtrip_even_and_odd() {
        let mut rng = Rng::new(21);
        for &n in &[2usize, 4, 6, 7, 9, 16, 33, 128, 1000] {
            let x = rng.normal_vec(n);
            let plan = RfftPlan::new(n);
            let mut spec = vec![C64::default(); onesided_len(n)];
            plan.forward(&x, &mut spec);
            let mut back = vec![0.0; n];
            plan.inverse(&spec, &mut back);
            for (a, b) in back.iter().zip(&x) {
                assert!((a - b).abs() < 1e-9, "n={n}");
            }
        }
    }

    #[test]
    fn batch_entry_points_match_row_loop() {
        let mut rng = Rng::new(23);
        for &(n, batch) in &[(16usize, 8usize), (15, 5), (9, 7), (64, 3)] {
            let plan = RfftPlan::new(n);
            let h = onesided_len(n);
            let x = rng.normal_vec(n * batch);
            // serial reference: one row at a time
            let mut want = vec![C64::default(); batch * h];
            for r in 0..batch {
                plan.forward(&x[r * n..(r + 1) * n], &mut want[r * h..(r + 1) * h]);
            }
            for lanes in [1usize, 4] {
                let mut got = vec![C64::default(); batch * h];
                plan.forward_batch(&x, &mut got, lanes);
                assert_eq!(got.len(), want.len());
                for (a, b) in got.iter().zip(&want) {
                    assert!((*a - *b).abs() == 0.0, "n={n} lanes={lanes}");
                }
                let mut back = vec![0.0; n * batch];
                plan.inverse_batch(&got, &mut back, lanes);
                for (a, b) in back.iter().zip(&x) {
                    assert!((a - b).abs() < 1e-9, "n={n} lanes={lanes}");
                }
            }
        }
    }

    #[test]
    fn forward_strided_is_bit_identical() {
        let mut rng = Rng::new(24);
        for &n in &[1usize, 2, 4, 7, 9, 16, 15, 64] {
            for &stride in &[1usize, 2, 3, 5] {
                let x = rng.normal_vec(n);
                let mut arena = vec![0.0; (n - 1) * stride + 1];
                for (i, &v) in x.iter().enumerate() {
                    arena[i * stride] = v;
                }
                let plan = RfftPlan::new(n);
                let mut want = vec![C64::default(); onesided_len(n)];
                plan.forward(&x, &mut want);
                let mut got = vec![C64::default(); onesided_len(n)];
                plan.forward_strided(&arena, stride, &mut got);
                assert_eq!(got, want, "n={n} stride={stride}");
            }
        }
    }

    #[test]
    fn dc_and_nyquist_are_real() {
        let mut rng = Rng::new(22);
        let n = 64;
        let x = rng.normal_vec(n);
        let plan = RfftPlan::new(n);
        let mut spec = vec![C64::default(); onesided_len(n)];
        plan.forward(&x, &mut spec);
        assert!(spec[0].im.abs() < 1e-10);
        assert!(spec[n / 2].im.abs() < 1e-10);
        let sum: f64 = x.iter().sum();
        assert!((spec[0].re - sum).abs() < 1e-9);
    }
}
