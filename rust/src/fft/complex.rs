//! Double-precision complex numbers (num-complex substitute).

use std::ops::{Add, AddAssign, Div, Mul, MulAssign, Neg, Sub, SubAssign};

/// A complex number with f64 components.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct C64 {
    /// Real part.
    pub re: f64,
    /// Imaginary part.
    pub im: f64,
}

/// The additive identity, `0 + 0j`.
pub const ZERO: C64 = C64 { re: 0.0, im: 0.0 };
/// The multiplicative identity, `1 + 0j`.
pub const ONE: C64 = C64 { re: 1.0, im: 0.0 };

impl C64 {
    /// Build a complex number from its real and imaginary parts.
    #[inline(always)]
    pub fn new(re: f64, im: f64) -> C64 {
        C64 { re, im }
    }

    /// e^{j theta}
    #[inline(always)]
    pub fn cis(theta: f64) -> C64 {
        let (s, c) = theta.sin_cos();
        C64 { re: c, im: s }
    }

    /// Complex conjugate (negated imaginary part).
    #[inline(always)]
    pub fn conj(self) -> C64 {
        C64 { re: self.re, im: -self.im }
    }

    /// Squared magnitude `re^2 + im^2` (no square root).
    #[inline(always)]
    pub fn norm_sqr(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    /// Magnitude `|z| = sqrt(re^2 + im^2)`.
    pub fn abs(self) -> f64 {
        self.norm_sqr().sqrt()
    }

    /// Multiply by j (90° rotation) without multiplications.
    #[inline(always)]
    pub fn mul_j(self) -> C64 {
        C64 { re: -self.im, im: self.re }
    }

    /// Scale by a real factor.
    #[inline(always)]
    pub fn scale(self, s: f64) -> C64 {
        C64 { re: self.re * s, im: self.im * s }
    }
}

impl Add for C64 {
    type Output = C64;
    #[inline(always)]
    fn add(self, o: C64) -> C64 {
        C64 { re: self.re + o.re, im: self.im + o.im }
    }
}

impl Sub for C64 {
    type Output = C64;
    #[inline(always)]
    fn sub(self, o: C64) -> C64 {
        C64 { re: self.re - o.re, im: self.im - o.im }
    }
}

impl Mul for C64 {
    type Output = C64;
    #[inline(always)]
    fn mul(self, o: C64) -> C64 {
        C64 {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

impl Div for C64 {
    type Output = C64;
    #[inline(always)]
    fn div(self, o: C64) -> C64 {
        let d = o.norm_sqr();
        C64 {
            re: (self.re * o.re + self.im * o.im) / d,
            im: (self.im * o.re - self.re * o.im) / d,
        }
    }
}

impl Neg for C64 {
    type Output = C64;
    #[inline(always)]
    fn neg(self) -> C64 {
        C64 { re: -self.re, im: -self.im }
    }
}

impl AddAssign for C64 {
    #[inline(always)]
    fn add_assign(&mut self, o: C64) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl SubAssign for C64 {
    #[inline(always)]
    fn sub_assign(&mut self, o: C64) {
        self.re -= o.re;
        self.im -= o.im;
    }
}

impl MulAssign for C64 {
    #[inline(always)]
    fn mul_assign(&mut self, o: C64) {
        *self = *self * o;
    }
}

impl From<f64> for C64 {
    fn from(re: f64) -> C64 {
        C64 { re, im: 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: C64, b: C64) -> bool {
        (a.re - b.re).abs() < 1e-12 && (a.im - b.im).abs() < 1e-12
    }

    #[test]
    fn field_ops() {
        let a = C64::new(1.0, 2.0);
        let b = C64::new(3.0, -1.0);
        assert!(close(a + b, C64::new(4.0, 1.0)));
        assert!(close(a - b, C64::new(-2.0, 3.0)));
        assert!(close(a * b, C64::new(5.0, 5.0)));
        assert!(close((a * b) / b, a));
        assert!(close(-a, C64::new(-1.0, -2.0)));
    }

    #[test]
    fn cis_and_conj() {
        let w = C64::cis(std::f64::consts::FRAC_PI_2);
        assert!(close(w, C64::new(0.0, 1.0)));
        assert!(close(w.conj(), C64::new(0.0, -1.0)));
        assert!((C64::cis(0.7).abs() - 1.0).abs() < 1e-14);
    }

    #[test]
    fn mul_j_is_rotation() {
        let a = C64::new(2.0, 3.0);
        assert!(close(a.mul_j(), a * C64::new(0.0, 1.0)));
    }
}
