//! Multi-dimensional FFTs over row-major matrices (the paper's "MD FFT"
//! stage): 2D RFFT/IRFFT (rows real-to-complex, columns complex) and a 3D
//! RFFT for the 3D-DCT extension discussed in §III-D.
//!
//! Parallel execution: plans carry an [`ExecPolicy`]. Multi-lane runs
//! fan the row batch out over the shared pool and run the column stage
//! as transpose -> contiguous row FFTs -> transpose (each transpose is
//! the parallel tiled one), which keeps every lane's memory access
//! sequential — the same locality argument as the serial
//! `transform_cols` vectorization, but scalable across cores. The
//! per-element arithmetic is identical in serial and parallel paths, so
//! outputs agree bit-for-bit.
//!
//! Band sharding: a [`ShardPolicy`] (see [`Rfft2Plan::with_shards`])
//! additionally pins how many row-band work items each stage becomes —
//! the row-FFT batch bands over the `n1` input rows, and after the
//! tiled-transpose barrier the column stage bands over the `h2`
//! spectrum rows. In 3D ([`Rfft3Plan::with_shards`]) the n3-axis row
//! RFFT batch bands over all `n1*n2` rows, the n2-axis stage over the
//! `n1` dim-0 **i-slabs** (each slab's column FFTs are local to its
//! contiguous (n2 x h3) plane), and the n1-axis stage re-bands over the
//! transposed `n2*h3` rows. Under the default `ShardPolicy::Auto` the
//! band count equals the exec lane count, i.e. exactly the pre-sharding
//! behaviour.

use super::complex::C64;
use super::plan::plan;
use super::rfft::{onesided_len, RfftPlan};
use crate::layout::Layout;
use crate::parallel::{par_chunks_mut, transpose_into, ExecPolicy, ShardPolicy};
use crate::util::scratch;

/// 2D RFFT plan for an (n1 x n2) real matrix -> (n1 x h2) onesided spectrum.
#[derive(Debug, Clone)]
pub struct Rfft2Plan {
    /// Number of rows (first, slower axis).
    pub n1: usize,
    /// Number of columns (second, contiguous axis).
    pub n2: usize,
    /// Onesided spectrum width, `n2 / 2 + 1`.
    pub h2: usize,
    row: RfftPlan,
    col: std::sync::Arc<super::plan::FftPlan>,
    policy: ExecPolicy,
    shards: ShardPolicy,
}

impl Rfft2Plan {
    /// Plan an `n1 x n2` real 2D FFT with the auto execution policy.
    pub fn new(n1: usize, n2: usize) -> Rfft2Plan {
        Self::with_policy(n1, n2, ExecPolicy::Auto)
    }

    /// Plan with an explicit execution policy.
    pub fn with_policy(n1: usize, n2: usize, policy: ExecPolicy) -> Rfft2Plan {
        let p = Rfft2Plan {
            n1,
            n2,
            h2: onesided_len(n2),
            row: RfftPlan::new(n2),
            col: plan(n1),
            policy,
            shards: ShardPolicy::Auto,
        };
        p.workspace().prewarm();
        p
    }

    /// Scratch manifest of one `forward`/`inverse` call (see
    /// [`crate::util::scratch::Workspace`]): the per-row RFFT scratch,
    /// the column stage's in-place panel or transpose route, and the
    /// inverse's working copy of the spectrum.
    pub fn workspace(&self) -> scratch::Workspace {
        let mut ws = scratch::Workspace::new();
        self.row.register_scratch(&mut ws);
        // column stage, in-place blocked path
        self.col.register_scratch_cols(&mut ws, self.h2);
        // column stage, transpose route: the transposed copy is held
        // while the per-row 1D transforms run
        ws.add_c64(self.n1 * self.h2);
        self.col.register_scratch(&mut ws);
        // inverse holds its working spectrum copy across the column
        // stage (same class as the transpose buffer, so multiplicity 2)
        ws.add_c64(self.n1 * self.h2);
        ws
    }

    /// Same plan with an explicit band-shard policy: every banded stage
    /// is split into the work-item count `shards` dictates (see
    /// [`ShardPolicy::bands`]) instead of one band per exec lane.
    /// `ShardPolicy::MaxShards(1)` forces single-band (serial-order)
    /// execution regardless of the exec policy.
    pub fn with_shards(mut self, shards: ShardPolicy) -> Rfft2Plan {
        self.shards = shards;
        self
    }

    /// Band work items for the row stage (`rows` rows) under this
    /// plan's exec + shard policies.
    fn bands(&self, rows: usize) -> usize {
        self.shards.bands(rows, self.policy.lanes(self.n1 * self.n2))
    }

    /// Forward: real row-major (n1*n2) -> complex row-major (n1*h2).
    pub fn forward(&self, x: &[f64], out: &mut [C64]) {
        let (n1, h2) = (self.n1, self.h2);
        assert_eq!(x.len(), n1 * self.n2);
        assert_eq!(out.len(), n1 * h2);
        let (row_bands, col_bands) = (self.bands(n1), self.bands(h2));
        if row_bands > 1 || col_bands > 1 {
            {
                let _s = crate::obs::SpanGuard::begin("rfft2.rows");
                self.row.forward_batch(x, out, row_bands);
            }
            let _s = crate::obs::SpanGuard::begin("rfft2.cols");
            self.col_fft_via_transpose(out, false, col_bands);
            return;
        }
        // rows: real FFT
        {
            let _s = crate::obs::SpanGuard::begin("rfft2.rows");
            for r in 0..n1 {
                self.row
                    .forward(&x[r * self.n2..(r + 1) * self.n2], &mut out[r * h2..(r + 1) * h2]);
            }
        }
        // columns: blocked column kernel when n1 is a power of two;
        // Bluestein sizes take the same transpose -> contiguous row FFTs
        // -> transpose route as the parallel branch, just with one lane
        // (the old per-column gather/scatter loop was the last strided
        // stage left in the serial path).
        let _s = crate::obs::SpanGuard::begin("rfft2.cols");
        if !self.col.try_transform_cols(out, h2, false) {
            self.col_fft_via_transpose(out, false, 1);
        }
    }

    /// Forward over a strided real view: the (n1 x n2) input block is
    /// read at `layout` strides (`x[i1*s1 + i2*s2]`) straight from the
    /// caller's buffer — no gather copy — into the same contiguous
    /// (n1*h2) onesided spectrum as [`Rfft2Plan::forward`]. Per-row
    /// arithmetic is [`RfftPlan::forward_strided`], which performs the
    /// identical operation sequence as the contiguous row path, so the
    /// output is bit-identical to packing the view and calling
    /// `forward`. `layout` must be a 2D f64 descriptor matching this
    /// plan's shape (see [`Layout::expect_2d_f64`]).
    pub fn forward_strided(&self, x: &[f64], layout: &Layout, out: &mut [C64]) {
        let (n1, n2, h2) = (self.n1, self.n2, self.h2);
        let (s1, s2) = layout.expect_2d_f64(n1, n2);
        if s2 == 1 && s1 == n2 {
            // contiguous view: the plain path, sliced to the block
            self.forward(&x[..n1 * n2], out);
            return;
        }
        assert!(
            x.len() > (n1 - 1) * s1 + (n2 - 1) * s2,
            "strided view out of bounds: len {} for shape ({n1},{n2}) strides ({s1},{s2})",
            x.len()
        );
        assert_eq!(out.len(), n1 * h2);
        let (row_bands, col_bands) = (self.bands(n1), self.bands(h2));
        {
            // rows: real FFT straight off the strided view (each output
            // row is an independent h2 chunk, so the banded fan-out is
            // bit-identical to the serial row loop)
            let _s = crate::obs::SpanGuard::begin("rfft2.rows");
            let row = &self.row;
            par_chunks_mut(out, h2, row_bands, |r, orow| {
                row.forward_strided(&x[r * s1..], s2, orow);
            });
        }
        // columns: identical to the contiguous forward — the spectrum
        // is already contiguous at this point
        let _s = crate::obs::SpanGuard::begin("rfft2.cols");
        if col_bands > 1 {
            self.col_fft_via_transpose(out, false, col_bands);
        } else if !self.col.try_transform_cols(out, h2, false) {
            self.col_fft_via_transpose(out, false, 1);
        }
    }

    /// Inverse: complex onesided (n1*h2) -> real (n1*n2), normalized.
    pub fn inverse(&self, spec: &[C64], out: &mut [f64]) {
        let (n1, h2) = (self.n1, self.h2);
        assert_eq!(spec.len(), n1 * h2);
        assert_eq!(out.len(), n1 * self.n2);
        let (row_bands, col_bands) = (self.bands(n1), self.bands(h2));
        let mut work = scratch::take_c64(spec.len());
        work.copy_from_slice(spec);
        if row_bands > 1 || col_bands > 1 {
            {
                let _s = crate::obs::SpanGuard::begin("rfft2.inv_cols");
                self.col_fft_via_transpose(&mut work, true, col_bands);
            }
            let _s = crate::obs::SpanGuard::begin("rfft2.inv_rows");
            self.row.inverse_batch(&work, out, row_bands);
            drop(_s);
            scratch::give_c64(work);
            return;
        }
        {
            let _s = crate::obs::SpanGuard::begin("rfft2.inv_cols");
            if !self.col.try_transform_cols(&mut work, h2, true) {
                self.col_fft_via_transpose(&mut work, true, 1);
            }
        }
        {
            let _s = crate::obs::SpanGuard::begin("rfft2.inv_rows");
            for r in 0..n1 {
                self.row
                    .inverse(&work[r * h2..(r + 1) * h2], &mut out[r * self.n2..(r + 1) * self.n2]);
            }
        }
        scratch::give_c64(work);
    }

    /// Batched forward: `batch` independent (n1 x n2) blocks packed in
    /// `x` -> `batch` (n1 x h2) onesided blocks in `out`. The row stage
    /// runs as **one** batched RFFT over all `batch*n1` rows (one pool
    /// dispatch, twiddle tables and bit-reversal schedules shared),
    /// then the column stage fans out per block, each block running the
    /// same serial column kernel as a solo [`Rfft2Plan::forward`] — so
    /// the output is bit-identical to looping `forward` block by block
    /// with a serial plan.
    pub fn forward_batch(&self, x: &[f64], out: &mut [C64], batch: usize) {
        let (n1, n2, h2) = (self.n1, self.n2, self.h2);
        assert_eq!(x.len(), batch * n1 * n2);
        assert_eq!(out.len(), batch * n1 * h2);
        if batch == 0 {
            return;
        }
        let lanes = self.policy.lanes(batch * n1 * n2);
        self.row.forward_batch(x, out, lanes);
        par_chunks_mut(out, n1 * h2, lanes, |_b, block| {
            if !self.col.try_transform_cols(block, h2, false) {
                self.col_fft_via_transpose(block, false, 1);
            }
        });
    }

    /// Batched inverse: `batch` onesided (n1 x h2) blocks -> `batch`
    /// real (n1 x n2) blocks, normalized; the exact batched mirror of
    /// [`Rfft2Plan::forward_batch`] (per-block column stage first, then
    /// one batched inverse RFFT over all rows).
    pub fn inverse_batch(&self, spec: &[C64], out: &mut [f64], batch: usize) {
        let (n1, n2, h2) = (self.n1, self.n2, self.h2);
        assert_eq!(spec.len(), batch * n1 * h2);
        assert_eq!(out.len(), batch * n1 * n2);
        if batch == 0 {
            return;
        }
        let lanes = self.policy.lanes(batch * n1 * n2);
        let mut work = scratch::take_c64(spec.len());
        work.copy_from_slice(spec);
        par_chunks_mut(&mut work, n1 * h2, lanes, |_b, block| {
            if !self.col.try_transform_cols(block, h2, true) {
                self.col_fft_via_transpose(block, true, 1);
            }
        });
        self.row.inverse_batch(&work, out, lanes);
        scratch::give_c64(work);
    }

    /// Column-axis FFT via locality transform: transpose so columns
    /// become contiguous rows, run the n1-plan per row (fanned over the
    /// pool when `lanes > 1`, inline when 1), transpose back. Both
    /// transposes are the cache-blocked tiled ones.
    fn col_fft_via_transpose(&self, data: &mut [C64], invert: bool, lanes: usize) {
        let (n1, h2) = (self.n1, self.h2);
        if n1 <= 1 {
            return; // length-1 column FFT is the identity
        }
        let mut t = scratch::take_c64(n1 * h2);
        transpose_into(data, &mut t, n1, h2, lanes);
        let col = &self.col;
        par_chunks_mut(&mut t, n1, lanes, |_c, colbuf| {
            if invert {
                col.inverse(colbuf);
            } else {
                col.forward(colbuf);
            }
        });
        transpose_into(&t, data, h2, n1, lanes);
        scratch::give_c64(t);
    }
}

/// Full complex 2D FFT (tests / odd corners); row-major in place.
pub fn fft2_inplace(data: &mut [C64], n1: usize, n2: usize, invert: bool) {
    assert_eq!(data.len(), n1 * n2);
    let prow = plan(n2);
    for r in 0..n1 {
        let row = &mut data[r * n2..(r + 1) * n2];
        if invert {
            prow.inverse(row);
        } else {
            prow.forward(row);
        }
    }
    let pcol = plan(n1);
    let mut colbuf = vec![C64::default(); n1];
    for c in 0..n2 {
        for r in 0..n1 {
            colbuf[r] = data[r * n2 + c];
        }
        if invert {
            pcol.inverse(&mut colbuf);
        } else {
            pcol.forward(&mut colbuf);
        }
        for r in 0..n1 {
            data[r * n2 + c] = colbuf[r];
        }
    }
}

/// 3D RFFT plan for an (n1 x n2 x n3) real tensor -> (n1 x n2 x h3)
/// onesided spectrum, with the dim-0 **i-slab** as the band-shard unit
/// of the middle stage.
///
/// Stage structure mirrors [`Rfft2Plan`] one dimension up: the n3-axis
/// row RFFT batch bands over all `n1*n2` rows (so a flat volume with
/// few slabs still fans wide); the n2-axis column FFTs are local to a
/// contiguous (n2 x h3) i-slab, so slabs fan out as independent work
/// items; the n1-axis stage crosses every slab and runs through the
/// tiled-transpose barrier, **re-banding** over the `n2*h3` rows of the
/// transposed matrix (or in place via the blocked column kernel when a
/// single band suffices and n1 is a power of two). Under
/// `ShardPolicy::Auto` the band counts equal the exec lane count — the
/// pre-plan behaviour of the old `rfft3_threads` free function,
/// bit-for-bit.
#[derive(Debug, Clone)]
pub struct Rfft3Plan {
    /// Leading (slab) dimension.
    pub n1: usize,
    /// Middle dimension.
    pub n2: usize,
    /// Innermost (real-FFT) dimension.
    pub n3: usize,
    /// Onesided spectrum length along dim 2 (`n3/2 + 1`).
    pub h3: usize,
    row: RfftPlan,
    p1: std::sync::Arc<super::plan::FftPlan>,
    p2: std::sync::Arc<super::plan::FftPlan>,
    policy: ExecPolicy,
    shards: ShardPolicy,
}

impl Rfft3Plan {
    /// Plan with the default (`Auto`) execution policy.
    pub fn new(n1: usize, n2: usize, n3: usize) -> Rfft3Plan {
        Self::with_policy(n1, n2, n3, ExecPolicy::Auto)
    }

    /// Plan with an explicit execution policy.
    pub fn with_policy(n1: usize, n2: usize, n3: usize, policy: ExecPolicy) -> Rfft3Plan {
        let p = Rfft3Plan {
            n1,
            n2,
            n3,
            h3: onesided_len(n3),
            row: RfftPlan::new(n3),
            p1: plan(n1),
            p2: plan(n2),
            policy,
            shards: ShardPolicy::Auto,
        };
        p.workspace().prewarm();
        p
    }

    /// Scratch manifest of one `forward`/`inverse` call (see
    /// [`crate::util::scratch::Workspace`]): per-row RFFT scratch, the
    /// n2-axis stage's panel or per-column buffer, the n1-axis stage's
    /// transpose route, and the inverse's working spectrum copy.
    pub fn workspace(&self) -> scratch::Workspace {
        let (n1, n2, h3) = (self.n1, self.n2, self.h3);
        let mut ws = scratch::Workspace::new();
        self.row.register_scratch(&mut ws);
        // n2-axis stage: blocked in-place panel, or the per-column
        // gather buffer + inner 1D scratch on Bluestein sizes
        self.p2.register_scratch_cols(&mut ws, h3);
        ws.add_c64(n2);
        self.p2.register_scratch(&mut ws);
        // n1-axis stage: in-place panel or transpose route
        self.p1.register_scratch_cols(&mut ws, n2 * h3);
        ws.add_c64(n1 * n2 * h3);
        self.p1.register_scratch(&mut ws);
        // inverse holds its working spectrum copy across both stages
        ws.add_c64(n1 * n2 * h3);
        ws
    }

    /// Same plan with an explicit band-shard policy: every banded stage
    /// becomes the work-item count [`ShardPolicy::bands`] dictates for
    /// its own row count — the n3-axis row batch over `n1*n2` rows, the
    /// n2-axis stage over the `n1` dim-0 slabs, and the n1-axis stage
    /// over the `n2*h3` transposed rows. `ShardPolicy::MaxShards(1)`
    /// forces single-band (serial-order) execution regardless of the
    /// exec policy.
    pub fn with_shards(mut self, shards: ShardPolicy) -> Rfft3Plan {
        self.shards = shards;
        self
    }

    /// Band work items for a stage of `rows` rows (dim-0 slabs, or
    /// transposed spectrum rows) under this plan's exec + shard policies.
    fn bands(&self, rows: usize) -> usize {
        self.shards.bands(rows, self.policy.lanes(self.n1 * self.n2 * self.n3))
    }

    /// Forward: real row-major (n1*n2*n3) -> onesided complex (n1*n2*h3).
    pub fn forward(&self, x: &[f64], out: &mut [C64]) {
        let (n2, n3, h3) = (self.n2, self.n3, self.h3);
        assert_eq!(x.len(), self.n1 * n2 * n3);
        assert_eq!(out.len(), self.n1 * n2 * h3);
        // stage 1: the n3-axis row RFFT batch bands over all n1*n2 rows
        // (mirroring the 2D plan's row stage — a flat volume with few
        // slabs still fans its row FFTs wide)
        {
            let _s = crate::obs::SpanGuard::begin("rfft3.rows");
            self.row.forward_batch(x, out, self.bands(self.n1 * n2));
        }
        self.n2_axis_fft(out, false);
        self.axis0_fft(out, false);
    }

    /// Inverse: onesided complex (n1*n2*h3) -> real (n1*n2*n3),
    /// normalized (exact inverse of [`Rfft3Plan::forward`]).
    pub fn inverse(&self, spec: &[C64], out: &mut [f64]) {
        let (n2, n3, h3) = (self.n2, self.n3, self.h3);
        assert_eq!(spec.len(), self.n1 * n2 * h3);
        assert_eq!(out.len(), self.n1 * n2 * n3);
        let mut work = scratch::take_c64(spec.len());
        work.copy_from_slice(spec);
        // reverse stage order: n1-axis first, then per-slab n2-axis, then
        // the n3-axis inverse RFFT rows into the real output
        self.axis0_fft(&mut work, true);
        self.n2_axis_fft(&mut work, true);
        // the n3-axis inverse RFFT batch bands over all n1*n2 rows,
        // like the forward row stage
        {
            let _s = crate::obs::SpanGuard::begin("rfft3.inv_rows");
            self.row.inverse_batch(&work, out, self.bands(self.n1 * n2));
        }
        scratch::give_c64(work);
    }

    /// n2-axis FFT, slab-local: each dim-0 slab is a contiguous
    /// (n2 x h3) plane, so slabs are the shard work items; inside a
    /// slab the blocked column kernel runs when n2 is a power of two,
    /// else the per-column Bluestein loop.
    fn n2_axis_fft(&self, data: &mut [C64], invert: bool) {
        let (n2, h3) = (self.n2, self.h3);
        let _s = crate::obs::SpanGuard::begin(if invert {
            "rfft3.inv_n2axis"
        } else {
            "rfft3.n2axis"
        });
        let slabs = self.bands(self.n1);
        let p2 = &self.p2;
        par_chunks_mut(data, n2 * h3, slabs, |_i, slab| {
            if !p2.try_transform_cols(slab, h3, invert) {
                let mut buf2 = scratch::take_c64(n2);
                for c in 0..h3 {
                    for j in 0..n2 {
                        buf2[j] = slab[j * h3 + c];
                    }
                    if invert {
                        p2.inverse(&mut buf2);
                    } else {
                        p2.forward(&mut buf2);
                    }
                    for j in 0..n2 {
                        slab[j * h3 + c] = buf2[j];
                    }
                }
                scratch::give_c64(buf2);
            }
        });
    }

    /// n1-axis FFT across slabs: view the tensor as an (n1 x n2*h3)
    /// matrix. A single band with power-of-two n1 runs the blocked
    /// column kernel in place; otherwise transpose -> contiguous row
    /// FFTs -> transpose, re-banded over the `n2*h3` transposed rows
    /// (the dim-1/dim-2 barrier the slab decomposition crosses).
    fn axis0_fft(&self, data: &mut [C64], invert: bool) {
        let (n1, m) = (self.n1, self.n2 * self.h3);
        if n1 <= 1 {
            return; // length-1 axis FFT is the identity
        }
        let _s = crate::obs::SpanGuard::begin(if invert {
            "rfft3.inv_axis0"
        } else {
            "rfft3.axis0"
        });
        let bands = self.bands(m);
        if bands <= 1 && self.p1.try_transform_cols(data, m, invert) {
            return;
        }
        let mut t = scratch::take_c64(n1 * m);
        transpose_into(data, &mut t, n1, m, bands);
        let p1 = &self.p1;
        par_chunks_mut(&mut t, n1, bands, |_r, colbuf| {
            if invert {
                p1.inverse(colbuf);
            } else {
                p1.forward(colbuf);
            }
        });
        transpose_into(&t, data, m, n1, bands);
        scratch::give_c64(t);
    }
}

/// 3D RFFT: (n1 x n2 x n3) real -> (n1 x n2 x h3) onesided complex.
/// Convenience wrapper over a one-shot serial [`Rfft3Plan`]; used by the
/// 3D-DCT extension (paper §III-D).
pub fn rfft3(x: &[f64], n1: usize, n2: usize, n3: usize) -> Vec<C64> {
    rfft3_threads(x, n1, n2, n3, 1)
}

/// [`rfft3`] fanned out over up to `lanes` pool workers via a one-shot
/// [`Rfft3Plan`] carrying `ExecPolicy::Threads(lanes)`; `lanes <= 1` is
/// the serial reference path. Repeated callers should hold an
/// [`Rfft3Plan`] instead and amortize its sub-plan construction.
pub fn rfft3_threads(x: &[f64], n1: usize, n2: usize, n3: usize, lanes: usize) -> Vec<C64> {
    let p = Rfft3Plan::with_policy(n1, n2, n3, ExecPolicy::Threads(lanes.max(1)));
    let mut out = vec![C64::default(); n1 * n2 * p.h3];
    p.forward(x, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// O(N^2) 2D DFT oracle.
    fn dft2_naive(x: &[f64], n1: usize, n2: usize) -> Vec<C64> {
        let mut out = vec![C64::default(); n1 * n2];
        for k1 in 0..n1 {
            for k2 in 0..n2 {
                let mut acc = C64::default();
                for m1 in 0..n1 {
                    for m2 in 0..n2 {
                        let theta = -2.0 * std::f64::consts::PI
                            * (k1 as f64 * m1 as f64 / n1 as f64
                                + k2 as f64 * m2 as f64 / n2 as f64);
                        acc += C64::cis(theta).scale(x[m1 * n2 + m2]);
                    }
                }
                out[k1 * n2 + k2] = acc;
            }
        }
        out
    }

    #[test]
    fn rfft2_matches_naive() {
        let mut rng = Rng::new(30);
        for &(n1, n2) in &[(2usize, 2usize), (4, 4), (3, 5), (8, 6), (5, 8), (16, 16)] {
            let x = rng.normal_vec(n1 * n2);
            let want = dft2_naive(&x, n1, n2);
            let plan = Rfft2Plan::new(n1, n2);
            let mut got = vec![C64::default(); n1 * plan.h2];
            plan.forward(&x, &mut got);
            for r in 0..n1 {
                for c in 0..plan.h2 {
                    let diff = (got[r * plan.h2 + c] - want[r * n2 + c]).abs();
                    assert!(diff < 1e-8, "({n1},{n2}) at ({r},{c}): {diff}");
                }
            }
        }
    }

    #[test]
    fn rfft2_roundtrip() {
        let mut rng = Rng::new(31);
        for &(n1, n2) in &[(4usize, 4usize), (6, 10), (5, 7), (32, 32), (16, 48)] {
            let x = rng.normal_vec(n1 * n2);
            let plan = Rfft2Plan::new(n1, n2);
            let mut spec = vec![C64::default(); n1 * plan.h2];
            plan.forward(&x, &mut spec);
            let mut back = vec![0.0; n1 * n2];
            plan.inverse(&spec, &mut back);
            for (a, b) in back.iter().zip(&x) {
                assert!((a - b).abs() < 1e-9, "({n1},{n2})");
            }
        }
    }

    #[test]
    fn fft2_inplace_roundtrip() {
        let mut rng = Rng::new(32);
        let (n1, n2) = (8, 12);
        let x: Vec<C64> =
            (0..n1 * n2).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        let mut y = x.clone();
        fft2_inplace(&mut y, n1, n2, false);
        fft2_inplace(&mut y, n1, n2, true);
        for (a, b) in y.iter().zip(&x) {
            assert!((*a - *b).abs() < 1e-9);
        }
    }

    #[test]
    fn parallel_policy_matches_serial_bitwise() {
        let mut rng = Rng::new(34);
        // odd, prime (Bluestein columns), and power-of-two shapes
        for &(n1, n2) in &[(9usize, 15usize), (7, 13), (16, 16), (31, 8), (12, 10)] {
            let x = rng.normal_vec(n1 * n2);
            let serial = Rfft2Plan::with_policy(n1, n2, crate::parallel::ExecPolicy::Serial);
            let par = Rfft2Plan::with_policy(n1, n2, crate::parallel::ExecPolicy::Threads(4));
            let mut a = vec![C64::default(); n1 * serial.h2];
            let mut b = vec![C64::default(); n1 * par.h2];
            serial.forward(&x, &mut a);
            par.forward(&x, &mut b);
            for (u, v) in a.iter().zip(&b) {
                assert!((*u - *v).abs() == 0.0, "({n1},{n2}) forward");
            }
            let mut ba = vec![0.0; n1 * n2];
            let mut bb = vec![0.0; n1 * n2];
            serial.inverse(&a, &mut ba);
            par.inverse(&b, &mut bb);
            assert_eq!(ba, bb, "({n1},{n2}) inverse");
        }
    }

    #[test]
    fn sharded_plan_matches_serial_bitwise() {
        use crate::parallel::ShardPolicy;
        let mut rng = Rng::new(36);
        for &(n1, n2) in &[(9usize, 15usize), (16, 16), (7, 13), (33, 17)] {
            let x = rng.normal_vec(n1 * n2);
            let serial = Rfft2Plan::with_policy(n1, n2, crate::parallel::ExecPolicy::Serial);
            let mut a = vec![C64::default(); n1 * serial.h2];
            serial.forward(&x, &mut a);
            for shards in [1usize, 2, 3, 7] {
                // Serial exec + explicit shard count: the shard policy alone
                // drives the fan-out
                let plan = Rfft2Plan::with_policy(n1, n2, crate::parallel::ExecPolicy::Serial)
                    .with_shards(ShardPolicy::MaxShards(shards));
                let mut b = vec![C64::default(); n1 * plan.h2];
                plan.forward(&x, &mut b);
                for (u, v) in a.iter().zip(&b) {
                    assert!((*u - *v).abs() == 0.0, "({n1},{n2}) shards={shards}");
                }
            }
        }
    }

    #[test]
    fn forward_strided_is_bit_identical() {
        use crate::layout::Layout;
        let mut rng = Rng::new(39);
        // pow2, odd (Bluestein columns/rows), and mixed shapes
        for &(n1, n2) in &[(4usize, 4usize), (8, 8), (9, 15), (7, 13), (1, 8), (16, 6)] {
            let x = rng.normal_vec(n1 * n2);
            let plan = Rfft2Plan::new(n1, n2);
            let mut want = vec![C64::default(); n1 * plan.h2];
            plan.forward(&x, &mut want);
            for &(r1, r2) in &[(1usize, 1usize), (3, 1), (1, 2), (4, 3)] {
                // embed the block in a padded arena at strides (s1, s2)
                let (s2, s1) = (r2, n2 * r2 * r1 + 1);
                let mut arena = vec![f64::NAN; (n1 - 1) * s1 + (n2 - 1) * s2 + 1];
                for i1 in 0..n1 {
                    for i2 in 0..n2 {
                        arena[i1 * s1 + i2 * s2] = x[i1 * n2 + i2];
                    }
                }
                let layout =
                    Layout::contiguous(&[n1, n2]).with_strides(&[s1, s2]).with_batch_stride(
                        (n1 - 1) * s1 + (n2 - 1) * s2 + 1,
                    );
                let mut got = vec![C64::default(); n1 * plan.h2];
                plan.forward_strided(&arena, &layout, &mut got);
                assert_eq!(got, want, "({n1},{n2}) strides ({s1},{s2})");
            }
        }
    }

    #[test]
    fn rfft3_threads_matches_serial() {
        let mut rng = Rng::new(35);
        for &(n1, n2, n3) in &[(4usize, 6usize, 8usize), (3, 5, 7), (8, 8, 8)] {
            let x = rng.normal_vec(n1 * n2 * n3);
            let a = rfft3(&x, n1, n2, n3);
            let b = rfft3_threads(&x, n1, n2, n3, 4);
            for (u, v) in a.iter().zip(&b) {
                assert!((*u - *v).abs() == 0.0, "({n1},{n2},{n3})");
            }
        }
    }

    #[test]
    fn rfft3_plan_sharded_matches_serial_bitwise() {
        use crate::parallel::ShardPolicy;
        let mut rng = Rng::new(37);
        for &(n1, n2, n3) in &[(4usize, 6usize, 8usize), (3, 5, 7), (8, 8, 8), (9, 4, 6)] {
            let x = rng.normal_vec(n1 * n2 * n3);
            let serial = Rfft3Plan::with_policy(n1, n2, n3, crate::parallel::ExecPolicy::Serial);
            let mut a = vec![C64::default(); n1 * n2 * serial.h3];
            serial.forward(&x, &mut a);
            for shards in [1usize, 2, 3, 7] {
                // serial exec + explicit slab count: the shard policy
                // alone drives the fan-out
                let p = Rfft3Plan::with_policy(n1, n2, n3, crate::parallel::ExecPolicy::Serial)
                    .with_shards(ShardPolicy::MaxShards(shards));
                let mut b = vec![C64::default(); n1 * n2 * p.h3];
                p.forward(&x, &mut b);
                for (u, v) in a.iter().zip(&b) {
                    assert!((*u - *v).abs() == 0.0, "({n1},{n2},{n3}) shards={shards}");
                }
            }
        }
    }

    #[test]
    fn rfft3_plan_roundtrip() {
        use crate::parallel::ShardPolicy;
        let mut rng = Rng::new(38);
        for &(n1, n2, n3) in &[(4usize, 6usize, 8usize), (3, 5, 7), (8, 8, 8), (1, 9, 4)] {
            let x = rng.normal_vec(n1 * n2 * n3);
            for shards in [1usize, 3] {
                let p = Rfft3Plan::new(n1, n2, n3).with_shards(ShardPolicy::MaxShards(shards));
                let mut spec = vec![C64::default(); n1 * n2 * p.h3];
                p.forward(&x, &mut spec);
                let mut back = vec![0.0; n1 * n2 * n3];
                p.inverse(&spec, &mut back);
                for (a, b) in back.iter().zip(&x) {
                    assert!((a - b).abs() < 1e-9, "({n1},{n2},{n3}) shards={shards}");
                }
            }
        }
    }

    #[test]
    fn rfft3_dc_bin_is_total_sum() {
        let mut rng = Rng::new(33);
        let (n1, n2, n3) = (4, 6, 8);
        let x = rng.normal_vec(n1 * n2 * n3);
        let spec = rfft3(&x, n1, n2, n3);
        let total: f64 = x.iter().sum();
        assert!((spec[0].re - total).abs() < 1e-9);
        assert!(spec[0].im.abs() < 1e-10);
    }
}
