//! Multi-dimensional FFTs over row-major matrices (the paper's "MD FFT"
//! stage): 2D RFFT/IRFFT (rows real-to-complex, columns complex) and a 3D
//! RFFT for the 3D-DCT extension discussed in §III-D.

use super::complex::C64;
use super::plan::plan;
use super::rfft::{onesided_len, RfftPlan};

/// 2D RFFT plan for an (n1 x n2) real matrix -> (n1 x h2) onesided spectrum.
#[derive(Debug, Clone)]
pub struct Rfft2Plan {
    pub n1: usize,
    pub n2: usize,
    pub h2: usize,
    row: RfftPlan,
    col: std::sync::Arc<super::plan::FftPlan>,
}

impl Rfft2Plan {
    pub fn new(n1: usize, n2: usize) -> Rfft2Plan {
        Rfft2Plan {
            n1,
            n2,
            h2: onesided_len(n2),
            row: RfftPlan::new(n2),
            col: plan(n1),
        }
    }

    /// Forward: real row-major (n1*n2) -> complex row-major (n1*h2).
    pub fn forward(&self, x: &[f64], out: &mut [C64]) {
        let (n1, h2) = (self.n1, self.h2);
        assert_eq!(x.len(), n1 * self.n2);
        assert_eq!(out.len(), n1 * h2);
        // rows: real FFT
        for r in 0..n1 {
            self.row
                .forward(&x[r * self.n2..(r + 1) * self.n2], &mut out[r * h2..(r + 1) * h2]);
        }
        // columns: complex FFT along axis 0, vectorized across columns
        // when n1 is a power of two (sequential access); fallback to
        // column-at-a-time for Bluestein sizes.
        match &*self.col {
            super::plan::FftPlan::Radix2(p) => p.transform_cols(out, h2, false),
            _ => {
                let mut colbuf = vec![C64::default(); n1];
                for c in 0..h2 {
                    for r in 0..n1 {
                        colbuf[r] = out[r * h2 + c];
                    }
                    self.col.forward(&mut colbuf);
                    for r in 0..n1 {
                        out[r * h2 + c] = colbuf[r];
                    }
                }
            }
        }
    }

    /// Inverse: complex onesided (n1*h2) -> real (n1*n2), normalized.
    pub fn inverse(&self, spec: &[C64], out: &mut [f64]) {
        let (n1, h2) = (self.n1, self.h2);
        assert_eq!(spec.len(), n1 * h2);
        assert_eq!(out.len(), n1 * self.n2);
        let mut work = crate::util::scratch::take_c64(spec.len());
        work.copy_from_slice(spec);
        match &*self.col {
            super::plan::FftPlan::Radix2(p) => p.transform_cols(&mut work, h2, true),
            _ => {
                let mut colbuf = vec![C64::default(); n1];
                for c in 0..h2 {
                    for r in 0..n1 {
                        colbuf[r] = work[r * h2 + c];
                    }
                    self.col.inverse(&mut colbuf);
                    for r in 0..n1 {
                        work[r * h2 + c] = colbuf[r];
                    }
                }
            }
        }
        for r in 0..n1 {
            self.row
                .inverse(&work[r * h2..(r + 1) * h2], &mut out[r * self.n2..(r + 1) * self.n2]);
        }
        crate::util::scratch::give_c64(work);
    }
}

/// Full complex 2D FFT (tests / odd corners); row-major in place.
pub fn fft2_inplace(data: &mut [C64], n1: usize, n2: usize, invert: bool) {
    assert_eq!(data.len(), n1 * n2);
    let prow = plan(n2);
    for r in 0..n1 {
        let row = &mut data[r * n2..(r + 1) * n2];
        if invert {
            prow.inverse(row);
        } else {
            prow.forward(row);
        }
    }
    let pcol = plan(n1);
    let mut colbuf = vec![C64::default(); n1];
    for c in 0..n2 {
        for r in 0..n1 {
            colbuf[r] = data[r * n2 + c];
        }
        if invert {
            pcol.inverse(&mut colbuf);
        } else {
            pcol.forward(&mut colbuf);
        }
        for r in 0..n1 {
            data[r * n2 + c] = colbuf[r];
        }
    }
}

/// 3D RFFT: (n1 x n2 x n3) real -> (n1 x n2 x h3) onesided complex.
/// Used by the 3D-DCT extension (paper §III-D).
pub fn rfft3(x: &[f64], n1: usize, n2: usize, n3: usize) -> Vec<C64> {
    assert_eq!(x.len(), n1 * n2 * n3);
    let h3 = onesided_len(n3);
    let rp = RfftPlan::new(n3);
    let mut out = vec![C64::default(); n1 * n2 * h3];
    for s in 0..n1 * n2 {
        rp.forward(&x[s * n3..(s + 1) * n3], &mut out[s * h3..(s + 1) * h3]);
    }
    // FFT along dim 2 (n2) then dim 1 (n1)
    let p2 = plan(n2);
    let mut buf2 = vec![C64::default(); n2];
    for i in 0..n1 {
        for c in 0..h3 {
            for j in 0..n2 {
                buf2[j] = out[(i * n2 + j) * h3 + c];
            }
            p2.forward(&mut buf2);
            for j in 0..n2 {
                out[(i * n2 + j) * h3 + c] = buf2[j];
            }
        }
    }
    let p1 = plan(n1);
    let mut buf1 = vec![C64::default(); n1];
    for j in 0..n2 {
        for c in 0..h3 {
            for i in 0..n1 {
                buf1[i] = out[(i * n2 + j) * h3 + c];
            }
            p1.forward(&mut buf1);
            for i in 0..n1 {
                out[(i * n2 + j) * h3 + c] = buf1[i];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// O(N^2) 2D DFT oracle.
    fn dft2_naive(x: &[f64], n1: usize, n2: usize) -> Vec<C64> {
        let mut out = vec![C64::default(); n1 * n2];
        for k1 in 0..n1 {
            for k2 in 0..n2 {
                let mut acc = C64::default();
                for m1 in 0..n1 {
                    for m2 in 0..n2 {
                        let theta = -2.0 * std::f64::consts::PI
                            * (k1 as f64 * m1 as f64 / n1 as f64
                                + k2 as f64 * m2 as f64 / n2 as f64);
                        acc += C64::cis(theta).scale(x[m1 * n2 + m2]);
                    }
                }
                out[k1 * n2 + k2] = acc;
            }
        }
        out
    }

    #[test]
    fn rfft2_matches_naive() {
        let mut rng = Rng::new(30);
        for &(n1, n2) in &[(2usize, 2usize), (4, 4), (3, 5), (8, 6), (5, 8), (16, 16)] {
            let x = rng.normal_vec(n1 * n2);
            let want = dft2_naive(&x, n1, n2);
            let plan = Rfft2Plan::new(n1, n2);
            let mut got = vec![C64::default(); n1 * plan.h2];
            plan.forward(&x, &mut got);
            for r in 0..n1 {
                for c in 0..plan.h2 {
                    let diff = (got[r * plan.h2 + c] - want[r * n2 + c]).abs();
                    assert!(diff < 1e-8, "({n1},{n2}) at ({r},{c}): {diff}");
                }
            }
        }
    }

    #[test]
    fn rfft2_roundtrip() {
        let mut rng = Rng::new(31);
        for &(n1, n2) in &[(4usize, 4usize), (6, 10), (5, 7), (32, 32), (16, 48)] {
            let x = rng.normal_vec(n1 * n2);
            let plan = Rfft2Plan::new(n1, n2);
            let mut spec = vec![C64::default(); n1 * plan.h2];
            plan.forward(&x, &mut spec);
            let mut back = vec![0.0; n1 * n2];
            plan.inverse(&spec, &mut back);
            for (a, b) in back.iter().zip(&x) {
                assert!((a - b).abs() < 1e-9, "({n1},{n2})");
            }
        }
    }

    #[test]
    fn fft2_inplace_roundtrip() {
        let mut rng = Rng::new(32);
        let (n1, n2) = (8, 12);
        let x: Vec<C64> =
            (0..n1 * n2).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        let mut y = x.clone();
        fft2_inplace(&mut y, n1, n2, false);
        fft2_inplace(&mut y, n1, n2, true);
        for (a, b) in y.iter().zip(&x) {
            assert!((*a - *b).abs() < 1e-9);
        }
    }

    #[test]
    fn rfft3_dc_bin_is_total_sum() {
        let mut rng = Rng::new(33);
        let (n1, n2, n3) = (4, 6, 8);
        let x = rng.normal_vec(n1 * n2 * n3);
        let spec = rfft3(&x, n1, n2, n3);
        let total: f64 = x.iter().sum();
        assert!((spec[0].re - total).abs() < 1e-9);
        assert!(spec[0].im.abs() < 1e-10);
    }
}
