//! Native FFT substrate (cuFFT/FFTW substitute, built from scratch).
//!
//! The paper's paradigm delegates the O(N log N) stage to a
//! highly-optimized FFT library; in the native Rust backend that library
//! is this module: radix-2 + Bluestein complex FFTs, a real-input RFFT
//! with the even-N packing trick, 2D/3D transforms, and a process-wide
//! plan cache.

pub mod bluestein;
pub mod complex;
pub mod nd;
pub mod plan;
pub mod radix2;
pub mod rfft;

pub use complex::C64;
pub use nd::Rfft2Plan;
pub use plan::{cached_plan_count, plan, FftPlan};
pub use rfft::{onesided_len, RfftPlan};
