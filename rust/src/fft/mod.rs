//! Native FFT substrate (cuFFT/FFTW substitute, built from scratch).
//!
//! The paper's paradigm delegates the O(N log N) stage to a
//! highly-optimized FFT library; in the native Rust backend that library
//! is this module: power-of-two complex FFTs behind a per-plan kernel
//! selector ([`FftKernel`]: scalar radix-2 reference vs the
//! split-radix/radix-4 SoA throughput kernel), Bluestein for arbitrary
//! N, a real-input RFFT with the even-N packing trick, 2D/3D
//! transforms (whose banded stages honor the
//! [`crate::parallel::ShardPolicy`] band decomposition — see
//! [`Rfft2Plan::with_shards`] and the slab-sharded
//! [`Rfft3Plan::with_shards`]), and a process-wide plan cache.
//!
//! ```
//! use mddct::fft::{onesided_len, RfftPlan, C64};
//!
//! let plan = RfftPlan::new(8);
//! let x = [1.0f64; 8];
//! let mut spec = vec![C64::default(); onesided_len(8)];
//! plan.forward(&x, &mut spec);
//! // DC bin of a real signal is its sum; all other bins of a constant
//! // signal vanish
//! assert!((spec[0].re - 8.0).abs() < 1e-12);
//! assert!(spec[1..].iter().all(|c| c.abs() < 1e-12));
//! ```
#![warn(missing_docs)]

pub mod bluestein;
pub mod complex;
pub mod elem;
pub mod generic;
pub mod kernel;
pub mod nd;
pub mod plan;
pub mod radix2;
pub mod rfft;
pub mod soa;

pub use complex::C64;
pub use elem::{Cx, Element};
pub use generic::{GenFft, GenRfft, GenRfft2};
pub use kernel::{panel_cols, FftKernel, Pow2Plan};
pub use nd::{Rfft2Plan, Rfft3Plan};
pub use plan::{cached_plan_count, plan, FftPlan};
pub use rfft::{onesided_len, RfftPlan};
pub use soa::SoaPlan;
