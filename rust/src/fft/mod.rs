//! Native FFT substrate (cuFFT/FFTW substitute, built from scratch).
//!
//! The paper's paradigm delegates the O(N log N) stage to a
//! highly-optimized FFT library; in the native Rust backend that library
//! is this module: power-of-two complex FFTs behind a per-plan kernel
//! selector ([`FftKernel`]: scalar radix-2 reference vs the
//! split-radix/radix-4 SoA throughput kernel), Bluestein for arbitrary
//! N, a real-input RFFT with the even-N packing trick, 2D/3D
//! transforms, and a process-wide plan cache.

pub mod bluestein;
pub mod complex;
pub mod kernel;
pub mod nd;
pub mod plan;
pub mod radix2;
pub mod rfft;
pub mod soa;

pub use complex::C64;
pub use kernel::{panel_cols, FftKernel, Pow2Plan};
pub use nd::Rfft2Plan;
pub use plan::{cached_plan_count, plan, FftPlan};
pub use rfft::{onesided_len, RfftPlan};
pub use soa::SoaPlan;
