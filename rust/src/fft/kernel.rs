//! Power-of-two kernel selector: which butterfly implementation a plan
//! executes, plus the cache-blocking knob for the column path.
//!
//! Two kernels implement the same transform (same twiddle convention,
//! same bit-reversed DIT ordering):
//!
//! * [`FftKernel::ScalarRadix2`] — the original scalar AoS radix-2 loop
//!   ([`Radix2Plan`]), kept as the reference implementation and as the
//!   "old" side of the kernel benches;
//! * [`FftKernel::SplitRadixSoa`] — mixed radix-4/radix-2 butterflies
//!   on planar re/im scratch ([`SoaPlan`]), the autovectorizer-friendly
//!   throughput kernel and the default.
//!
//! The selector is a *plan-level* seam: every consumer (complex plans,
//! RFFT, Bluestein's inner convolution, the 2D/3D paths) goes through
//! [`Pow2Plan`], so benches and tests can instantiate both kernels side
//! by side while production code gets the process default. The parallel
//! layer's bit-equality contract (`Serial == Threads(n)`) is stated per
//! kernel: each kernel's column path performs the identical f64
//! operation sequence as its 1D path, so the equality holds whichever
//! kernel a plan selects — but outputs of *different* kernels only agree
//! to rounding (~1e-15 relative), not bit-for-bit.

use std::sync::OnceLock;

use super::complex::C64;
use super::radix2::Radix2Plan;
use super::soa::SoaPlan;
use crate::util::env_usize;

/// Which butterfly implementation a power-of-two plan executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FftKernel {
    /// Scalar AoS radix-2 (the original reference kernel).
    ScalarRadix2,
    /// Split-radix-style radix-4/radix-2 on planar SoA scratch.
    #[default]
    SplitRadixSoa,
}

impl FftKernel {
    /// Stable label for bench tables / JSON rows.
    pub fn name(self) -> &'static str {
        match self {
            FftKernel::ScalarRadix2 => "scalar-radix2",
            FftKernel::SplitRadixSoa => "splitradix-soa",
        }
    }

    /// Process-wide default kernel: `MDDCT_FFT_KERNEL=scalar` (or
    /// `radix2`) selects the reference kernel, `soa` (or `radix4`,
    /// unset) the SoA split-radix kernel. Any other value panics rather
    /// than silently running the wrong side of an A/B comparison.
    /// Resolved once.
    pub fn default_kernel() -> FftKernel {
        static K: OnceLock<FftKernel> = OnceLock::new();
        *K.get_or_init(|| match std::env::var("MDDCT_FFT_KERNEL").ok().as_deref() {
            Some("scalar") | Some("radix2") | Some("scalar-radix2") => FftKernel::ScalarRadix2,
            None | Some("") | Some("soa") | Some("radix4") | Some("splitradix-soa") => {
                FftKernel::SplitRadixSoa
            }
            Some(other) => panic!(
                "MDDCT_FFT_KERNEL={other:?} not recognized (use \"scalar\" or \"soa\")"
            ),
        })
    }
}

/// Default column-panel width for the blocked column transform: 64
/// columns x 1024 rows of split re/im is a 1 MiB working set — inside
/// L2 on every target we care about, and the panel for smaller row
/// counts fits L1. Tunable per process via `MDDCT_PANEL_COLS` (this and
/// the kernel selector are the auto-tuning surface the bench harness
/// measures).
pub const DEFAULT_PANEL_COLS: usize = 64;

/// Resolved column-panel width (`MDDCT_PANEL_COLS` override, >= 1).
pub fn panel_cols() -> usize {
    static P: OnceLock<usize> = OnceLock::new();
    *P.get_or_init(|| env_usize("MDDCT_PANEL_COLS").unwrap_or(DEFAULT_PANEL_COLS))
}

/// A power-of-two complex FFT plan executing one selected kernel.
#[derive(Debug, Clone)]
pub enum Pow2Plan {
    /// Reference in-place scalar radix-2 kernel.
    Scalar(Radix2Plan),
    /// Split-radix/radix-4 structure-of-arrays throughput kernel.
    SplitRadix(SoaPlan),
}

impl Pow2Plan {
    /// Plan with the process-default kernel; `n` must be a power of two.
    pub fn new(n: usize) -> Pow2Plan {
        Pow2Plan::with_kernel(n, FftKernel::default_kernel())
    }

    /// Plan with an explicit kernel (benches / cross-kernel tests).
    pub fn with_kernel(n: usize, kernel: FftKernel) -> Pow2Plan {
        match kernel {
            FftKernel::ScalarRadix2 => Pow2Plan::Scalar(Radix2Plan::new(n)),
            FftKernel::SplitRadixSoa => Pow2Plan::SplitRadix(SoaPlan::new(n)),
        }
    }

    /// Transform length this plan was built for.
    pub fn n(&self) -> usize {
        match self {
            Pow2Plan::Scalar(p) => p.n,
            Pow2Plan::SplitRadix(p) => p.n,
        }
    }

    /// Which kernel variant this plan dispatches to.
    pub fn kernel(&self) -> FftKernel {
        match self {
            Pow2Plan::Scalar(_) => FftKernel::ScalarRadix2,
            Pow2Plan::SplitRadix(_) => FftKernel::SplitRadixSoa,
        }
    }

    /// Register the scratch classes one transform of this kernel takes
    /// (`ncols <= 1` = the 1D path, else the blocked column path). The
    /// scalar radix-2 kernel runs fully in place and registers nothing.
    pub(crate) fn register_scratch(&self, ws: &mut crate::util::scratch::Workspace, ncols: usize) {
        match self {
            Pow2Plan::Scalar(_) => {}
            Pow2Plan::SplitRadix(p) => p.register_scratch(ws, ncols),
        }
    }

    /// In-place forward FFT (unnormalized).
    pub fn forward(&self, data: &mut [C64]) {
        match self {
            Pow2Plan::Scalar(p) => p.forward(data),
            Pow2Plan::SplitRadix(p) => p.forward(data),
        }
    }

    /// In-place inverse FFT including the 1/N normalization.
    pub fn inverse(&self, data: &mut [C64]) {
        match self {
            Pow2Plan::Scalar(p) => p.inverse(data),
            Pow2Plan::SplitRadix(p) => p.inverse(data),
        }
    }

    /// FFT along axis 0 of a row-major (n x ncols) matrix.
    pub fn transform_cols(&self, data: &mut [C64], ncols: usize, invert: bool) {
        match self {
            Pow2Plan::Scalar(p) => p.transform_cols(data, ncols, invert),
            Pow2Plan::SplitRadix(p) => p.transform_cols(data, ncols, invert),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn explicit_kernels_dispatch() {
        let s = Pow2Plan::with_kernel(16, FftKernel::ScalarRadix2);
        let v = Pow2Plan::with_kernel(16, FftKernel::SplitRadixSoa);
        assert_eq!(s.kernel(), FftKernel::ScalarRadix2);
        assert_eq!(v.kernel(), FftKernel::SplitRadixSoa);
        assert_eq!(s.n(), 16);
        assert_eq!(v.n(), 16);
        assert_eq!(FftKernel::ScalarRadix2.name(), "scalar-radix2");
    }

    #[test]
    fn kernels_agree_on_forward() {
        let mut rng = Rng::new(50);
        let n = 64;
        let x: Vec<C64> = (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        let mut a = x.clone();
        let mut b = x.clone();
        Pow2Plan::with_kernel(n, FftKernel::ScalarRadix2).forward(&mut a);
        Pow2Plan::with_kernel(n, FftKernel::SplitRadixSoa).forward(&mut b);
        for (u, v) in a.iter().zip(&b) {
            assert!((*u - *v).abs() < 1e-10 * n as f64);
        }
    }

    #[test]
    fn panel_width_is_positive() {
        assert!(panel_cols() >= 1);
    }
}
