//! Bluestein (chirp-z) FFT for arbitrary N (the paper's "N can be any
//! positive integer" requirement).
//!
//! x_k = sum_n x_n w^{nk} with w = e^{-2 pi j / N}; writing
//! nk = (n^2 + k^2 - (k-n)^2)/2 turns the DFT into a circular convolution
//! that we evaluate with a power-of-two radix-2 FFT of size M >= 2N-1.

use super::complex::C64;
use super::kernel::{FftKernel, Pow2Plan};

/// Precomputed Bluestein plan for one size.
#[derive(Debug, Clone)]
pub struct BluesteinPlan {
    /// Transform length (any positive integer).
    pub n: usize,
    m: usize,
    /// power-of-two convolution FFT — the hottest consumer of the
    /// kernel selector for prime sizes
    inner: Pow2Plan,
    /// chirp a_n = e^{-j pi n^2 / N}
    chirp: Vec<C64>,
    /// FFT of the zero-padded conjugate-chirp kernel
    kernel_fft: Vec<C64>,
}

impl BluesteinPlan {
    /// Plan an arbitrary-length DFT with the process-default inner kernel.
    pub fn new(n: usize) -> BluesteinPlan {
        BluesteinPlan::with_kernel(n, FftKernel::default_kernel())
    }

    /// Plan whose inner power-of-two convolution runs an explicit kernel.
    pub fn with_kernel(n: usize, kernel: FftKernel) -> BluesteinPlan {
        assert!(n >= 1);
        let m = (2 * n - 1).next_power_of_two();
        let inner = Pow2Plan::with_kernel(m, kernel);
        // n^2 mod 2N avoids precision loss for large n
        let chirp: Vec<C64> = (0..n)
            .map(|i| {
                let sq = (i * i) % (2 * n);
                C64::cis(-std::f64::consts::PI * sq as f64 / n as f64)
            })
            .collect();
        let mut kern = vec![C64::default(); m];
        for i in 0..n {
            let c = chirp[i].conj();
            kern[i] = c;
            if i > 0 {
                kern[m - i] = c;
            }
        }
        inner.forward(&mut kern);
        BluesteinPlan { n, m, inner, chirp, kernel_fft: kern }
    }

    /// Kernel of the inner convolution FFT.
    pub fn kernel(&self) -> FftKernel {
        self.inner.kernel()
    }

    /// Register one transform's scratch: the length-M convolution
    /// buffer plus whatever the inner power-of-two kernel takes while
    /// that buffer is held.
    pub(crate) fn register_scratch(&self, ws: &mut crate::util::scratch::Workspace) {
        ws.add_c64(self.m);
        self.inner.register_scratch(ws, 1);
    }

    /// Forward DFT (unnormalized, negative-exponent convention).
    pub fn forward(&self, data: &mut [C64]) {
        self.transform(data, false)
    }

    /// Inverse DFT including 1/N normalization.
    pub fn inverse(&self, data: &mut [C64]) {
        // IDFT(x)_k = conj(DFT(conj(x))_k) / N
        for v in data.iter_mut() {
            *v = v.conj();
        }
        self.transform(data, false);
        let inv = 1.0 / self.n as f64;
        for v in data.iter_mut() {
            *v = v.conj().scale(inv);
        }
    }

    fn transform(&self, data: &mut [C64], _invert: bool) {
        let (n, m) = (self.n, self.m);
        assert_eq!(data.len(), n);
        let mut buf = crate::util::scratch::take_c64(m);
        buf[n..].fill(C64::default());
        for i in 0..n {
            buf[i] = data[i] * self.chirp[i];
        }
        self.inner.forward(&mut buf);
        for (b, k) in buf.iter_mut().zip(&self.kernel_fft) {
            *b = *b * *k;
        }
        self.inner.inverse(&mut buf);
        for i in 0..n {
            data[i] = buf[i] * self.chirp[i];
        }
        crate::util::scratch::give_c64(buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::radix2::dft_naive;
    use crate::util::rng::Rng;

    fn rand_c(rng: &mut Rng, n: usize) -> Vec<C64> {
        (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect()
    }

    #[test]
    fn matches_naive_dft_arbitrary_n() {
        let mut rng = Rng::new(10);
        for &n in &[1usize, 2, 3, 5, 7, 12, 17, 100, 127, 360] {
            let x = rand_c(&mut rng, n);
            let mut y = x.clone();
            BluesteinPlan::new(n).forward(&mut y);
            let want = dft_naive(&x, false);
            for (i, (a, b)) in y.iter().zip(&want).enumerate() {
                assert!((*a - *b).abs() < 1e-8 * (n as f64), "n={n} idx={i}");
            }
        }
    }

    #[test]
    fn agrees_with_radix2_on_pow2() {
        let mut rng = Rng::new(11);
        let n = 64;
        let x = rand_c(&mut rng, n);
        let mut a = x.clone();
        let mut b = x.clone();
        BluesteinPlan::new(n).forward(&mut a);
        crate::fft::radix2::Radix2Plan::new(n).forward(&mut b);
        for (u, v) in a.iter().zip(&b) {
            assert!((*u - *v).abs() < 1e-9);
        }
    }

    #[test]
    fn inverse_roundtrip() {
        let mut rng = Rng::new(12);
        for &n in &[3usize, 10, 31, 100] {
            let plan = BluesteinPlan::new(n);
            let x = rand_c(&mut rng, n);
            let mut y = x.clone();
            plan.forward(&mut y);
            plan.inverse(&mut y);
            for (u, v) in y.iter().zip(&x) {
                assert!((*u - *v).abs() < 1e-9, "n={n}");
            }
        }
    }

    #[test]
    fn prime_sizes() {
        let mut rng = Rng::new(13);
        for &n in &[101usize, 257, 509] {
            let plan = BluesteinPlan::new(n);
            let x = rand_c(&mut rng, n);
            let mut y = x.clone();
            plan.forward(&mut y);
            plan.inverse(&mut y);
            for (u, v) in y.iter().zip(&x) {
                assert!((*u - *v).abs() < 1e-8, "n={n}");
            }
        }
    }
}
