//! Element-type abstraction behind the generic (f32/f64) transform
//! core.
//!
//! The paper's three-stage factorization is memory-bound at the sizes
//! the coordinator batches, so halving the element width is a direct
//! bandwidth win. Rather than forking every kernel, the generic core in
//! [`crate::fft::generic`] and [`crate::dct::generic`] is written once
//! over the [`Element`] trait; `f64` keeps its hand-tuned dedicated
//! plans (the public API is unchanged) and `f32` instantiates the same
//! stage math at half the traffic.
//!
//! [`Cx`] is the matching generic complex value. Twiddle *construction*
//! always happens in `f64` (via [`Cx::cis`]) and is rounded once to the
//! target element type, so an `f32` table carries correctly-rounded
//! coefficients rather than error accumulated in `f32` recurrences.

use std::fmt::Debug;
use std::ops::{Add, AddAssign, Mul, Neg, Sub, SubAssign};

use crate::layout::ElemType;
use crate::util::scratch::{self, Workspace};

/// A real scalar the generic transform core can run on.
///
/// Implemented for `f64` and `f32`. The trait carries just enough to
/// write the stage sweeps once: arithmetic, conversions through `f64`
/// (used for twiddle construction and API boundaries), and hooks into
/// the per-element-size scratch classes of [`crate::util::scratch`].
pub trait Element:
    Copy
    + Default
    + Debug
    + PartialEq
    + Send
    + Sync
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + 'static
{
    /// Additive identity.
    const ZERO: Self;
    /// Multiplicative identity.
    const ONE: Self;

    /// Round an `f64` to this element type (twiddles, scale factors).
    fn from_f64(v: f64) -> Self;

    /// Widen to `f64` (API boundaries, accuracy checks).
    fn to_f64(self) -> f64;

    /// The [`ElemType`] tag of this element (layout keys, metrics).
    fn elem_type() -> ElemType;

    /// Take a scratch buffer of `len` from this element's pool class.
    fn take_scratch(len: usize) -> Vec<Self>;

    /// Return a scratch buffer to this element's pool class.
    fn give_scratch(buf: Vec<Self>);

    /// Register one scratch buffer of `len` in a plan workspace
    /// manifest (so prewarming covers the generic plans too).
    fn register_scratch(ws: &mut Workspace, len: usize);
}

impl Element for f64 {
    const ZERO: f64 = 0.0;
    const ONE: f64 = 1.0;

    fn from_f64(v: f64) -> f64 {
        v
    }

    fn to_f64(self) -> f64 {
        self
    }

    fn elem_type() -> ElemType {
        ElemType::F64
    }

    fn take_scratch(len: usize) -> Vec<f64> {
        scratch::take_f64(len)
    }

    fn give_scratch(buf: Vec<f64>) {
        scratch::give_f64(buf)
    }

    fn register_scratch(ws: &mut Workspace, len: usize) {
        ws.add_f64(len)
    }
}

impl Element for f32 {
    const ZERO: f32 = 0.0;
    const ONE: f32 = 1.0;

    fn from_f64(v: f64) -> f32 {
        v as f32
    }

    fn to_f64(self) -> f64 {
        self as f64
    }

    fn elem_type() -> ElemType {
        ElemType::F32
    }

    fn take_scratch(len: usize) -> Vec<f32> {
        scratch::take_f32(len)
    }

    fn give_scratch(buf: Vec<f32>) {
        scratch::give_f32(buf)
    }

    fn register_scratch(ws: &mut Workspace, len: usize) {
        ws.add_f32(len)
    }
}

/// Complex value over a generic [`Element`] — the generic counterpart
/// of [`crate::fft::C64`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Cx<E> {
    /// Real part.
    pub re: E,
    /// Imaginary part.
    pub im: E,
}

impl<E: Element> Cx<E> {
    /// Construct from parts.
    pub fn new(re: E, im: E) -> Cx<E> {
        Cx { re, im }
    }

    /// The complex zero.
    pub fn zero() -> Cx<E> {
        Cx { re: E::ZERO, im: E::ZERO }
    }

    /// `e^{i·theta}`, computed in `f64` and rounded once to `E`.
    pub fn cis(theta: f64) -> Cx<E> {
        Cx { re: E::from_f64(theta.cos()), im: E::from_f64(theta.sin()) }
    }

    /// Complex conjugate.
    pub fn conj(self) -> Cx<E> {
        Cx { re: self.re, im: -self.im }
    }

    /// Scale both parts by a real factor.
    pub fn scale(self, s: E) -> Cx<E> {
        Cx { re: self.re * s, im: self.im * s }
    }

    /// Multiply by `i` (the positive quarter turn): `i·(a+bi) = -b + ai`.
    pub fn mul_j(self) -> Cx<E> {
        Cx { re: -self.im, im: self.re }
    }
}

impl<E: Element> Add for Cx<E> {
    type Output = Cx<E>;
    fn add(self, o: Cx<E>) -> Cx<E> {
        Cx { re: self.re + o.re, im: self.im + o.im }
    }
}

impl<E: Element> Sub for Cx<E> {
    type Output = Cx<E>;
    fn sub(self, o: Cx<E>) -> Cx<E> {
        Cx { re: self.re - o.re, im: self.im - o.im }
    }
}

impl<E: Element> Mul for Cx<E> {
    type Output = Cx<E>;
    fn mul(self, o: Cx<E>) -> Cx<E> {
        Cx {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

impl<E: Element> Neg for Cx<E> {
    type Output = Cx<E>;
    fn neg(self) -> Cx<E> {
        Cx { re: -self.re, im: -self.im }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complex_algebra_matches_by_hand() {
        let a: Cx<f64> = Cx::new(1.0, 2.0);
        let b: Cx<f64> = Cx::new(3.0, -1.0);
        assert_eq!(a + b, Cx::new(4.0, 1.0));
        assert_eq!(a - b, Cx::new(-2.0, 3.0));
        assert_eq!(a * b, Cx::new(5.0, 5.0)); // (1+2i)(3-i) = 5+5i
        assert_eq!(a.conj(), Cx::new(1.0, -2.0));
        assert_eq!(a.mul_j(), Cx::new(-2.0, 1.0));
        assert_eq!(a.scale(2.0), Cx::new(2.0, 4.0));
        assert_eq!(-a, Cx::new(-1.0, -2.0));
        assert_eq!(Cx::<f64>::zero(), Cx::new(0.0, 0.0));
    }

    #[test]
    fn cis_rounds_once_from_f64() {
        let t = 0.731;
        let c64: Cx<f64> = Cx::cis(t);
        let c32: Cx<f32> = Cx::cis(t);
        assert_eq!(c32.re, c64.re as f32);
        assert_eq!(c32.im, c64.im as f32);
    }

    #[test]
    fn element_roundtrips_and_tags() {
        assert_eq!(<f32 as Element>::from_f64(1.5).to_f64(), 1.5);
        assert_eq!(<f64 as Element>::from_f64(1.5), 1.5);
        assert_eq!(<f32 as Element>::elem_type(), ElemType::F32);
        assert_eq!(<f64 as Element>::elem_type(), ElemType::F64);
        let buf = <f32 as Element>::take_scratch(8);
        assert_eq!(buf.len(), 8);
        <f32 as Element>::give_scratch(buf);
        let mut ws = Workspace::new();
        <f32 as Element>::register_scratch(&mut ws, 16);
        assert_eq!(ws.f32_elems(), 16);
    }
}
