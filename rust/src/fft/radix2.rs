//! Iterative radix-2 decimation-in-time FFT with precomputed twiddles.
//!
//! This is the scalar AoS reference kernel behind
//! [`FftKernel::ScalarRadix2`](super::kernel::FftKernel): the original
//! power-of-two workhorse, kept selectable so benches can measure
//! old-vs-new and tests can cross-check the split-radix/radix-4 SoA
//! kernel ([`super::soa`]) against it. Twiddle tables are owned by the
//! plan so repeated transforms of the same size pay no trig (the
//! paper's "pre-computed and fixed before the call" convention).

use super::complex::C64;

/// Precomputed state for power-of-two FFTs of one size.
#[derive(Debug, Clone)]
pub struct Radix2Plan {
    /// Transform length (a power of two).
    pub n: usize,
    /// twiddles[s] holds the stage-s factors w_m^k, m = 2^(s+1)
    twiddles: Vec<Vec<C64>>,
    /// bit-reversal permutation
    rev: Vec<u32>,
}

impl Radix2Plan {
    /// Build a plan; `n` must be a power of two (>= 1).
    pub fn new(n: usize) -> Radix2Plan {
        assert!(n.is_power_of_two(), "radix-2 plan needs power-of-two n, got {n}");
        let stages = n.trailing_zeros() as usize;
        let mut twiddles = Vec::with_capacity(stages);
        for s in 0..stages {
            let m = 1usize << (s + 1);
            let half = m / 2;
            let step = -2.0 * std::f64::consts::PI / m as f64;
            twiddles.push((0..half).map(|k| C64::cis(step * k as f64)).collect());
        }
        let bits = stages as u32;
        let rev = (0..n as u32)
            .map(|i| if bits == 0 { 0 } else { i.reverse_bits() >> (32 - bits) })
            .collect();
        Radix2Plan { n, twiddles, rev }
    }

    /// In-place forward FFT (negative-exponent convention, unnormalized).
    pub fn forward(&self, data: &mut [C64]) {
        self.transform(data, false);
    }

    /// In-place inverse FFT including the 1/N normalization.
    pub fn inverse(&self, data: &mut [C64]) {
        self.transform(data, true);
        let inv = 1.0 / self.n as f64;
        for x in data.iter_mut() {
            *x = x.scale(inv);
        }
    }

    fn transform(&self, data: &mut [C64], invert: bool) {
        let n = self.n;
        assert_eq!(data.len(), n, "data length != plan size");
        // bit-reversal permutation
        for i in 0..n {
            let j = self.rev[i] as usize;
            if i < j {
                data.swap(i, j);
            }
        }
        // butterflies (k = 0 has w = 1: no twiddle multiply — the whole
        // first stage and the head of every block are add/sub only)
        for (s, tw) in self.twiddles.iter().enumerate() {
            let m = 1usize << (s + 1);
            let half = m / 2;
            for base in (0..n).step_by(m) {
                let a = data[base];
                let b = data[base + half];
                data[base] = a + b;
                data[base + half] = a - b;
                for k in 1..half {
                    let w = if invert { tw[k].conj() } else { tw[k] };
                    let a = data[base + k];
                    let b = data[base + k + half] * w;
                    data[base + k] = a + b;
                    data[base + k + half] = a - b;
                }
            }
        }
    }
}

impl Radix2Plan {
    /// FFT along axis 0 of a row-major (n x ncols) matrix, vectorized
    /// across columns: every butterfly is a whole-row operation, so all
    /// memory access is sequential (§Perf iteration 2 — replaces the
    /// strided column-at-a-time gather, ~30% off the 2D RFFT).
    pub fn transform_cols(&self, data: &mut [C64], ncols: usize, invert: bool) {
        let n = self.n;
        assert_eq!(data.len(), n * ncols);
        // bit-reversal permutation of whole rows
        for i in 0..n {
            let j = self.rev[i] as usize;
            if i < j {
                for c in 0..ncols {
                    data.swap(i * ncols + c, j * ncols + c);
                }
            }
        }
        for (s, tw) in self.twiddles.iter().enumerate() {
            let m = 1usize << (s + 1);
            let half = m / 2;
            for base in (0..n).step_by(m) {
                for k in 0..half {
                    let w = if invert { tw[k].conj() } else { tw[k] };
                    let unit = k == 0; // w = 1: skip the twiddle multiply
                    let (i, j) = (base + k, base + k + half);
                    // split_at_mut to get both rows safely
                    let (lo, hi) = data.split_at_mut(j * ncols);
                    let row_i = &mut lo[i * ncols..i * ncols + ncols];
                    let row_j = &mut hi[..ncols];
                    if unit {
                        for c in 0..ncols {
                            let a = row_i[c];
                            let b = row_j[c];
                            row_i[c] = a + b;
                            row_j[c] = a - b;
                        }
                    } else {
                        for c in 0..ncols {
                            let a = row_i[c];
                            let b = row_j[c] * w;
                            row_i[c] = a + b;
                            row_j[c] = a - b;
                        }
                    }
                }
            }
        }
        if invert {
            let inv = 1.0 / n as f64;
            for x in data.iter_mut() {
                *x = x.scale(inv);
            }
        }
    }
}

/// Naive O(N^2) DFT used as the correctness oracle in tests.
pub fn dft_naive(x: &[C64], invert: bool) -> Vec<C64> {
    let n = x.len();
    let sign = if invert { 1.0 } else { -1.0 };
    let mut out = vec![C64::default(); n];
    for (k, o) in out.iter_mut().enumerate() {
        let mut acc = C64::default();
        for (m, &v) in x.iter().enumerate() {
            let theta = sign * 2.0 * std::f64::consts::PI * (k * m % n) as f64 / n as f64;
            acc += v * C64::cis(theta);
        }
        *o = if invert { acc.scale(1.0 / n as f64) } else { acc };
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_c(rng: &mut Rng, n: usize) -> Vec<C64> {
        (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect()
    }

    fn close(a: &[C64], b: &[C64], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (*x - *y).abs() < tol,
                "idx {i}: {x:?} vs {y:?} (diff {})",
                (*x - *y).abs()
            );
        }
    }

    #[test]
    fn matches_naive_dft() {
        let mut rng = Rng::new(1);
        for &n in &[1usize, 2, 4, 8, 16, 64, 256] {
            let x = rand_c(&mut rng, n);
            let mut y = x.clone();
            Radix2Plan::new(n).forward(&mut y);
            close(&y, &dft_naive(&x, false), 1e-9 * n as f64);
        }
    }

    #[test]
    fn inverse_roundtrip() {
        let mut rng = Rng::new(2);
        for &n in &[2usize, 8, 32, 128, 1024] {
            let plan = Radix2Plan::new(n);
            let x = rand_c(&mut rng, n);
            let mut y = x.clone();
            plan.forward(&mut y);
            plan.inverse(&mut y);
            close(&y, &x, 1e-10);
        }
    }

    #[test]
    fn parseval() {
        let mut rng = Rng::new(3);
        let n = 512;
        let x = rand_c(&mut rng, n);
        let mut y = x.clone();
        Radix2Plan::new(n).forward(&mut y);
        let ex: f64 = x.iter().map(|c| c.norm_sqr()).sum();
        let ey: f64 = y.iter().map(|c| c.norm_sqr()).sum();
        assert!((ey - n as f64 * ex).abs() < 1e-6 * ey);
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn rejects_non_pow2() {
        Radix2Plan::new(12);
    }

    #[test]
    fn size_one_is_identity() {
        let plan = Radix2Plan::new(1);
        let mut d = [C64::new(3.0, -4.0)];
        plan.forward(&mut d);
        assert_eq!(d[0], C64::new(3.0, -4.0));
        plan.inverse(&mut d);
        assert_eq!(d[0], C64::new(3.0, -4.0));
    }
}
