//! Split-radix-style mixed radix-4/radix-2 FFT on planar (SoA) scratch.
//!
//! This is the throughput kernel behind [`FftKernel::SplitRadixSoa`]
//! (see [`super::kernel`]): the same power-of-two DIT factorization as
//! the scalar radix-2 reference, but
//!
//! * stages are radix-4 wherever possible (one radix-2 stage absorbs an
//!   odd log2), so the data makes half as many passes through memory
//!   and each butterfly spends 3 complex multiplies where two radix-2
//!   stages spend 4;
//! * butterflies operate on split re/im `f64` planes ("structure of
//!   arrays"), so every inner loop is a flat `f64` loop over contiguous
//!   slices — the shape LLVM's autovectorizer turns into SIMD lanes
//!   without any explicit intrinsics (stable Rust only);
//! * the bit-reversal permutation is fused into the first, twiddle-free
//!   stage: the AoS input is gathered in permuted order while it is
//!   deinterleaved into the planes, saving a separate permute pass;
//! * [`SoaPlan::transform_cols`] is cache-blocked into column panels of
//!   [`super::kernel::panel_cols`] columns, so a whole multi-stage
//!   column FFT runs out of an L1/L2-resident panel instead of
//!   streaming the full `n * ncols` matrix through every stage.
//!
//! Radix-4 on bit-reversed (base-2) input needs one reordering fact: at
//! each combine, the four length-L sub-DFTs of a length-4L block sit at
//! offsets {0, 2L, L, 3L} for decimation indices d = {0, 1, 2, 3} (the
//! middle two blocks trade places, because reversing the two low bits
//! of the block index swaps 01 and 10). The butterflies below read with
//! that swap and write in natural order.
//!
//! Contract: for a given plan size, the 1D path and the column path
//! perform the identical sequence of f64 operations per element, so
//! `transform_cols` matches a per-column 1D transform bit-for-bit —
//! that is what keeps the parallel layer's `Serial == Threads(n)`
//! equality exact for this kernel (the parallel column stage runs the
//! 1D kernel on transposed rows).

use super::complex::C64;
use super::kernel::panel_cols;
use crate::util::scratch;

/// Precomputed split-radix/radix-4 state for power-of-two FFTs of one
/// size, executing on planar scratch.
#[derive(Debug, Clone)]
pub struct SoaPlan {
    /// Transform length (a power of two).
    pub n: usize,
    /// base-2 bit-reversal permutation (shared ordering with the scalar
    /// radix-2 kernel)
    rev: Vec<u32>,
    /// log2(n) odd: the fused first stage is radix-2 pairs; even: a
    /// twiddle-free radix-4 stage on gathered quads
    first_radix2: bool,
    /// radix-4 combine stages, in execution order
    stages: Vec<Stage4>,
}

/// Twiddles for one radix-4 stage combining length-`len` sub-DFTs:
/// planar (w^k, w^{2k}, w^{3k}) for w = e^{-2*pi*j/(4*len)}, k in 0..len.
#[derive(Debug, Clone)]
struct Stage4 {
    len: usize,
    w1re: Vec<f64>,
    w1im: Vec<f64>,
    w2re: Vec<f64>,
    w2im: Vec<f64>,
    w3re: Vec<f64>,
    w3im: Vec<f64>,
}

impl Stage4 {
    fn new(len: usize) -> Stage4 {
        let step = -2.0 * std::f64::consts::PI / (4 * len) as f64;
        let mut s = Stage4 {
            len,
            w1re: Vec::with_capacity(len),
            w1im: Vec::with_capacity(len),
            w2re: Vec::with_capacity(len),
            w2im: Vec::with_capacity(len),
            w3re: Vec::with_capacity(len),
            w3im: Vec::with_capacity(len),
        };
        for k in 0..len {
            let w1 = C64::cis(step * k as f64);
            let w2 = C64::cis(step * (2 * k) as f64);
            let w3 = C64::cis(step * (3 * k) as f64);
            s.w1re.push(w1.re);
            s.w1im.push(w1.im);
            s.w2re.push(w2.re);
            s.w2im.push(w2.im);
            s.w3re.push(w3.re);
            s.w3im.push(w3.im);
        }
        s
    }
}

impl SoaPlan {
    /// Build a plan; `n` must be a power of two (>= 1).
    pub fn new(n: usize) -> SoaPlan {
        assert!(n.is_power_of_two(), "radix-4 plan needs power-of-two n, got {n}");
        let bits = n.trailing_zeros();
        let rev = (0..n as u32)
            .map(|i| if bits == 0 { 0 } else { i.reverse_bits() >> (32 - bits) })
            .collect();
        let first_radix2 = bits % 2 == 1;
        let mut stages = Vec::new();
        if n >= 2 {
            let mut l = if first_radix2 { 2 } else { 4 };
            while 4 * l <= n {
                stages.push(Stage4::new(l));
                l *= 4;
            }
        }
        SoaPlan { n, rev, first_radix2, stages }
    }

    /// Register the planar scratch classes one transform takes — the
    /// 1D path's pair of full planes for `ncols <= 1`, the column
    /// path's pair of panel planes otherwise (see
    /// [`crate::util::scratch::Workspace`]).
    pub(crate) fn register_scratch(&self, ws: &mut scratch::Workspace, ncols: usize) {
        if self.n <= 1 {
            return;
        }
        let len = if ncols <= 1 { self.n } else { self.n * panel_cols().min(ncols) };
        ws.add_f64(len);
        ws.add_f64(len);
    }

    /// In-place forward FFT (negative-exponent convention, unnormalized).
    pub fn forward(&self, data: &mut [C64]) {
        self.transform(data, false);
    }

    /// In-place inverse FFT including the 1/N normalization.
    pub fn inverse(&self, data: &mut [C64]) {
        self.transform(data, true);
    }

    fn transform(&self, data: &mut [C64], invert: bool) {
        let n = self.n;
        assert_eq!(data.len(), n, "data length != plan size");
        if n == 1 {
            return;
        }
        let mut re = scratch::take_f64(n);
        let mut im = scratch::take_f64(n);
        self.first_stage_1d(data, &mut re, &mut im, invert);
        for st in &self.stages {
            if invert {
                stage4_1d_inv(&mut re, &mut im, st);
            } else {
                stage4_1d_fwd(&mut re, &mut im, st);
            }
        }
        if invert {
            let inv = 1.0 / n as f64;
            for (d, (r, i)) in data.iter_mut().zip(re.iter().zip(im.iter())) {
                *d = C64::new(r * inv, i * inv);
            }
        } else {
            for (d, (r, i)) in data.iter_mut().zip(re.iter().zip(im.iter())) {
                *d = C64::new(*r, *i);
            }
        }
        scratch::give_f64(re);
        scratch::give_f64(im);
    }

    /// Fused bit-reversal + first (twiddle-free) stage: gather the AoS
    /// input in permuted order straight into the planar scratch while
    /// computing the first butterflies.
    fn first_stage_1d(&self, x: &[C64], re: &mut [f64], im: &mut [f64], invert: bool) {
        let rev = &self.rev;
        if self.first_radix2 {
            for b in 0..self.n / 2 {
                let p = x[rev[2 * b] as usize];
                let q = x[rev[2 * b + 1] as usize];
                re[2 * b] = p.re + q.re;
                im[2 * b] = p.im + q.im;
                re[2 * b + 1] = p.re - q.re;
                im[2 * b + 1] = p.im - q.im;
            }
        } else {
            for b in 0..self.n / 4 {
                // decimation order d = 0,1,2,3 lives at permuted
                // positions 0,2,1,3 of the quad (low-bit reversal)
                let x0 = x[rev[4 * b] as usize];
                let x1 = x[rev[4 * b + 2] as usize];
                let x2 = x[rev[4 * b + 1] as usize];
                let x3 = x[rev[4 * b + 3] as usize];
                let t0r = x0.re + x2.re;
                let t0i = x0.im + x2.im;
                let t1r = x0.re - x2.re;
                let t1i = x0.im - x2.im;
                let t2r = x1.re + x3.re;
                let t2i = x1.im + x3.im;
                let t3r = x1.re - x3.re;
                let t3i = x1.im - x3.im;
                re[4 * b] = t0r + t2r;
                im[4 * b] = t0i + t2i;
                re[4 * b + 2] = t0r - t2r;
                im[4 * b + 2] = t0i - t2i;
                if invert {
                    re[4 * b + 1] = t1r - t3i;
                    im[4 * b + 1] = t1i + t3r;
                    re[4 * b + 3] = t1r + t3i;
                    im[4 * b + 3] = t1i - t3r;
                } else {
                    re[4 * b + 1] = t1r + t3i;
                    im[4 * b + 1] = t1i - t3r;
                    re[4 * b + 3] = t1r - t3i;
                    im[4 * b + 3] = t1i + t3r;
                }
            }
        }
    }

    /// FFT along axis 0 of a row-major (n x ncols) matrix, cache-blocked
    /// into column panels: each panel of up to [`panel_cols`] columns is
    /// gathered (bit-reversed + deinterleaved) into planar scratch, run
    /// through every stage while resident, and scattered back. Inner
    /// loops are flat f64 loops across the panel width with one scalar
    /// twiddle broadcast per butterfly row — the autovectorizer's
    /// favourite shape.
    pub fn transform_cols(&self, data: &mut [C64], ncols: usize, invert: bool) {
        let n = self.n;
        assert_eq!(data.len(), n * ncols);
        if n == 1 || ncols == 0 {
            return;
        }
        let pw = panel_cols().min(ncols);
        let mut re = scratch::take_f64(n * pw);
        let mut im = scratch::take_f64(n * pw);
        let inv = 1.0 / n as f64;
        let mut c0 = 0;
        while c0 < ncols {
            let w = pw.min(ncols - c0);
            let rp = &mut re[..n * w];
            let ip = &mut im[..n * w];
            self.first_stage_cols(data, rp, ip, c0, w, ncols, invert);
            for st in &self.stages {
                if invert {
                    stage4_cols_inv(rp, ip, w, st);
                } else {
                    stage4_cols_fwd(rp, ip, w, st);
                }
            }
            for r in 0..n {
                let row = &mut data[r * ncols + c0..r * ncols + c0 + w];
                let rr = &rp[r * w..r * w + w];
                let ri = &ip[r * w..r * w + w];
                if invert {
                    for c in 0..w {
                        row[c] = C64::new(rr[c] * inv, ri[c] * inv);
                    }
                } else {
                    for c in 0..w {
                        row[c] = C64::new(rr[c], ri[c]);
                    }
                }
            }
            c0 += w;
        }
        scratch::give_f64(re);
        scratch::give_f64(im);
    }

    /// Panel variant of the fused first stage: whole-row butterflies on
    /// bit-reversed source rows, written into the (n x w) planar panel.
    #[allow(clippy::too_many_arguments)]
    fn first_stage_cols(
        &self,
        x: &[C64],
        re: &mut [f64],
        im: &mut [f64],
        c0: usize,
        w: usize,
        ncols: usize,
        invert: bool,
    ) {
        let rev = &self.rev;
        if self.first_radix2 {
            for b in 0..self.n / 2 {
                let sp = rev[2 * b] as usize * ncols + c0;
                let sq = rev[2 * b + 1] as usize * ncols + c0;
                let p = &x[sp..sp + w];
                let q = &x[sq..sq + w];
                let d0 = 2 * b * w;
                let d1 = (2 * b + 1) * w;
                for c in 0..w {
                    re[d0 + c] = p[c].re + q[c].re;
                    im[d0 + c] = p[c].im + q[c].im;
                    re[d1 + c] = p[c].re - q[c].re;
                    im[d1 + c] = p[c].im - q[c].im;
                }
            }
        } else {
            for b in 0..self.n / 4 {
                let s0 = rev[4 * b] as usize * ncols + c0;
                let s1 = rev[4 * b + 2] as usize * ncols + c0;
                let s2 = rev[4 * b + 1] as usize * ncols + c0;
                let s3 = rev[4 * b + 3] as usize * ncols + c0;
                let x0 = &x[s0..s0 + w];
                let x1 = &x[s1..s1 + w];
                let x2 = &x[s2..s2 + w];
                let x3 = &x[s3..s3 + w];
                let d0 = 4 * b * w;
                let d1 = (4 * b + 1) * w;
                let d2 = (4 * b + 2) * w;
                let d3 = (4 * b + 3) * w;
                for c in 0..w {
                    let t0r = x0[c].re + x2[c].re;
                    let t0i = x0[c].im + x2[c].im;
                    let t1r = x0[c].re - x2[c].re;
                    let t1i = x0[c].im - x2[c].im;
                    let t2r = x1[c].re + x3[c].re;
                    let t2i = x1[c].im + x3[c].im;
                    let t3r = x1[c].re - x3[c].re;
                    let t3i = x1[c].im - x3[c].im;
                    re[d0 + c] = t0r + t2r;
                    im[d0 + c] = t0i + t2i;
                    re[d2 + c] = t0r - t2r;
                    im[d2 + c] = t0i - t2i;
                    if invert {
                        re[d1 + c] = t1r - t3i;
                        im[d1 + c] = t1i + t3r;
                        re[d3 + c] = t1r + t3i;
                        im[d3 + c] = t1i - t3r;
                    } else {
                        re[d1 + c] = t1r + t3i;
                        im[d1 + c] = t1i - t3r;
                        re[d3 + c] = t1r - t3i;
                        im[d3 + c] = t1i + t3r;
                    }
                }
            }
        }
    }
}

/// Split a length-4L block of each plane into its four sub-DFT slices.
/// Returned in natural block order (offsets 0, L, 2L, 3L); remember the
/// decimation swap: d=1 input is the slice at +2L, d=2 at +L.
#[inline(always)]
#[allow(clippy::type_complexity)]
fn split4<'a>(
    plane: &'a mut [f64],
    base: usize,
    l: usize,
) -> (&'a mut [f64], &'a mut [f64], &'a mut [f64], &'a mut [f64]) {
    let block = &mut plane[base..base + 4 * l];
    let (s0, rest) = block.split_at_mut(l);
    let (s1, rest) = rest.split_at_mut(l);
    let (s2, s3) = rest.split_at_mut(l);
    (s0, s1, s2, s3)
}

// The 1D and cols stage bodies below are deliberately hand-mirrored
// rather than shared: the 1D variants vectorize across k (twiddle
// arrays are vector operands), the cols variants across the panel
// width (twiddles are scalar broadcasts) — collapsing one into the
// other forfeits that variant's SIMD shape. Their per-element f64
// operation sequences MUST stay identical; that is the bitwise
// cols == per-column-1D contract, asserted by
// `transform_cols_bitwise_matches_per_column_1d` here and
// `prop_blocked_transform_cols_matches_per_column_1d` in tier-1.

/// Forward radix-4 combine over the whole 1D planes: per block, input
/// sub-DFTs (a, b, c, d) = (s0, w1*s2, w2*s1, w3*s3); outputs
/// Y(k+qL) -> sq[k] with the -j rotations of the negative-exponent DFT.
fn stage4_1d_fwd(re: &mut [f64], im: &mut [f64], st: &Stage4) {
    let l = st.len;
    let m = 4 * l;
    let n = re.len();
    let w1r = &st.w1re[..l];
    let w1i = &st.w1im[..l];
    let w2r = &st.w2re[..l];
    let w2i = &st.w2im[..l];
    let w3r = &st.w3re[..l];
    let w3i = &st.w3im[..l];
    for base in (0..n).step_by(m) {
        let (r0, r1, r2, r3) = split4(re, base, l);
        let (i0, i1, i2, i3) = split4(im, base, l);
        for k in 0..l {
            let ar = r0[k];
            let ai = i0[k];
            let br = r2[k] * w1r[k] - i2[k] * w1i[k];
            let bi = r2[k] * w1i[k] + i2[k] * w1r[k];
            let cr = r1[k] * w2r[k] - i1[k] * w2i[k];
            let ci = r1[k] * w2i[k] + i1[k] * w2r[k];
            let dr = r3[k] * w3r[k] - i3[k] * w3i[k];
            let di = r3[k] * w3i[k] + i3[k] * w3r[k];
            let t0r = ar + cr;
            let t0i = ai + ci;
            let t1r = ar - cr;
            let t1i = ai - ci;
            let t2r = br + dr;
            let t2i = bi + di;
            let t3r = br - dr;
            let t3i = bi - di;
            r0[k] = t0r + t2r;
            i0[k] = t0i + t2i;
            r1[k] = t1r + t3i;
            i1[k] = t1i - t3r;
            r2[k] = t0r - t2r;
            i2[k] = t0i - t2i;
            r3[k] = t1r - t3i;
            i3[k] = t1i + t3r;
        }
    }
}

/// Inverse radix-4 combine (conjugate twiddles, +j rotations); the 1/N
/// normalization happens at interleave/scatter time.
fn stage4_1d_inv(re: &mut [f64], im: &mut [f64], st: &Stage4) {
    let l = st.len;
    let m = 4 * l;
    let n = re.len();
    let w1r = &st.w1re[..l];
    let w1i = &st.w1im[..l];
    let w2r = &st.w2re[..l];
    let w2i = &st.w2im[..l];
    let w3r = &st.w3re[..l];
    let w3i = &st.w3im[..l];
    for base in (0..n).step_by(m) {
        let (r0, r1, r2, r3) = split4(re, base, l);
        let (i0, i1, i2, i3) = split4(im, base, l);
        for k in 0..l {
            let ar = r0[k];
            let ai = i0[k];
            let br = r2[k] * w1r[k] + i2[k] * w1i[k];
            let bi = i2[k] * w1r[k] - r2[k] * w1i[k];
            let cr = r1[k] * w2r[k] + i1[k] * w2i[k];
            let ci = i1[k] * w2r[k] - r1[k] * w2i[k];
            let dr = r3[k] * w3r[k] + i3[k] * w3i[k];
            let di = i3[k] * w3r[k] - r3[k] * w3i[k];
            let t0r = ar + cr;
            let t0i = ai + ci;
            let t1r = ar - cr;
            let t1i = ai - ci;
            let t2r = br + dr;
            let t2i = bi + di;
            let t3r = br - dr;
            let t3i = bi - di;
            r0[k] = t0r + t2r;
            i0[k] = t0i + t2i;
            r1[k] = t1r - t3i;
            i1[k] = t1i + t3r;
            r2[k] = t0r - t2r;
            i2[k] = t0i - t2i;
            r3[k] = t1r + t3i;
            i3[k] = t1i - t3r;
        }
    }
}

/// Forward radix-4 combine over an (nrows x w) planar panel: identical
/// arithmetic to [`stage4_1d_fwd`] per column element, with the scalar
/// twiddle pair broadcast across the flat inner loop over the panel.
fn stage4_cols_fwd(re: &mut [f64], im: &mut [f64], w: usize, st: &Stage4) {
    let l = st.len;
    let nrows = re.len() / w;
    for base in (0..nrows).step_by(4 * l) {
        let (r0, r1, r2, r3) = split4(re, base * w, l * w);
        let (i0, i1, i2, i3) = split4(im, base * w, l * w);
        for k in 0..l {
            let w1r = st.w1re[k];
            let w1i = st.w1im[k];
            let w2r = st.w2re[k];
            let w2i = st.w2im[k];
            let w3r = st.w3re[k];
            let w3i = st.w3im[k];
            let o = k * w;
            for c in o..o + w {
                let ar = r0[c];
                let ai = i0[c];
                let br = r2[c] * w1r - i2[c] * w1i;
                let bi = r2[c] * w1i + i2[c] * w1r;
                let cr = r1[c] * w2r - i1[c] * w2i;
                let ci = r1[c] * w2i + i1[c] * w2r;
                let dr = r3[c] * w3r - i3[c] * w3i;
                let di = r3[c] * w3i + i3[c] * w3r;
                let t0r = ar + cr;
                let t0i = ai + ci;
                let t1r = ar - cr;
                let t1i = ai - ci;
                let t2r = br + dr;
                let t2i = bi + di;
                let t3r = br - dr;
                let t3i = bi - di;
                r0[c] = t0r + t2r;
                i0[c] = t0i + t2i;
                r1[c] = t1r + t3i;
                i1[c] = t1i - t3r;
                r2[c] = t0r - t2r;
                i2[c] = t0i - t2i;
                r3[c] = t1r - t3i;
                i3[c] = t1i + t3r;
            }
        }
    }
}

/// Inverse counterpart of [`stage4_cols_fwd`] (conjugate twiddles, +j
/// rotations), arithmetic mirrored from [`stage4_1d_inv`].
fn stage4_cols_inv(re: &mut [f64], im: &mut [f64], w: usize, st: &Stage4) {
    let l = st.len;
    let nrows = re.len() / w;
    for base in (0..nrows).step_by(4 * l) {
        let (r0, r1, r2, r3) = split4(re, base * w, l * w);
        let (i0, i1, i2, i3) = split4(im, base * w, l * w);
        for k in 0..l {
            let w1r = st.w1re[k];
            let w1i = st.w1im[k];
            let w2r = st.w2re[k];
            let w2i = st.w2im[k];
            let w3r = st.w3re[k];
            let w3i = st.w3im[k];
            let o = k * w;
            for c in o..o + w {
                let ar = r0[c];
                let ai = i0[c];
                let br = r2[c] * w1r + i2[c] * w1i;
                let bi = i2[c] * w1r - r2[c] * w1i;
                let cr = r1[c] * w2r + i1[c] * w2i;
                let ci = i1[c] * w2r - r1[c] * w2i;
                let dr = r3[c] * w3r + i3[c] * w3i;
                let di = i3[c] * w3r - r3[c] * w3i;
                let t0r = ar + cr;
                let t0i = ai + ci;
                let t1r = ar - cr;
                let t1i = ai - ci;
                let t2r = br + dr;
                let t2i = bi + di;
                let t3r = br - dr;
                let t3i = bi - di;
                r0[c] = t0r + t2r;
                i0[c] = t0i + t2i;
                r1[c] = t1r - t3i;
                i1[c] = t1i + t3r;
                r2[c] = t0r - t2r;
                i2[c] = t0i - t2i;
                r3[c] = t1r + t3i;
                i3[c] = t1i - t3r;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::radix2::{dft_naive, Radix2Plan};
    use crate::util::rng::Rng;

    fn rand_c(rng: &mut Rng, n: usize) -> Vec<C64> {
        (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect()
    }

    fn close(a: &[C64], b: &[C64], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (*x - *y).abs() < tol,
                "idx {i}: {x:?} vs {y:?} (diff {})",
                (*x - *y).abs()
            );
        }
    }

    #[test]
    fn matches_naive_dft_even_and_odd_log2() {
        let mut rng = Rng::new(41);
        // exercises both first-stage shapes: 2,8,32,128 are odd log2;
        // 1,4,16,64,256 even
        for &n in &[1usize, 2, 4, 8, 16, 32, 64, 128, 256] {
            let x = rand_c(&mut rng, n);
            let mut y = x.clone();
            SoaPlan::new(n).forward(&mut y);
            close(&y, &dft_naive(&x, false), 1e-9 * (n as f64).max(1.0));
        }
    }

    #[test]
    fn inverse_roundtrip() {
        let mut rng = Rng::new(42);
        for &n in &[2usize, 4, 8, 64, 512, 1024] {
            let plan = SoaPlan::new(n);
            let x = rand_c(&mut rng, n);
            let mut y = x.clone();
            plan.forward(&mut y);
            plan.inverse(&mut y);
            close(&y, &x, 1e-10);
        }
    }

    #[test]
    fn agrees_with_scalar_radix2() {
        let mut rng = Rng::new(43);
        for &n in &[4usize, 8, 16, 128, 1024] {
            let x = rand_c(&mut rng, n);
            let mut a = x.clone();
            let mut b = x.clone();
            SoaPlan::new(n).forward(&mut a);
            Radix2Plan::new(n).forward(&mut b);
            close(&a, &b, 1e-10 * (n as f64));
        }
    }

    #[test]
    fn transform_cols_bitwise_matches_per_column_1d() {
        let mut rng = Rng::new(44);
        // ncols > panel width forces multiple panels at default 64
        for &(n, ncols) in &[(2usize, 3usize), (8, 70), (16, 64), (64, 5), (128, 130)] {
            let plan = SoaPlan::new(n);
            let base = rand_c(&mut rng, n * ncols);
            for invert in [false, true] {
                let mut blocked = base.clone();
                plan.transform_cols(&mut blocked, ncols, invert);
                let mut want = base.clone();
                let mut col = vec![C64::default(); n];
                for c in 0..ncols {
                    for r in 0..n {
                        col[r] = want[r * ncols + c];
                    }
                    if invert {
                        plan.inverse(&mut col);
                    } else {
                        plan.forward(&mut col);
                    }
                    for r in 0..n {
                        want[r * ncols + c] = col[r];
                    }
                }
                for (i, (a, b)) in blocked.iter().zip(&want).enumerate() {
                    assert!(
                        a == b,
                        "n={n} ncols={ncols} invert={invert} idx={i}: {a:?} vs {b:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn size_one_is_identity() {
        let plan = SoaPlan::new(1);
        let mut d = [C64::new(3.0, -4.0)];
        plan.forward(&mut d);
        assert_eq!(d[0], C64::new(3.0, -4.0));
        plan.inverse(&mut d);
        assert_eq!(d[0], C64::new(3.0, -4.0));
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn rejects_non_pow2() {
        SoaPlan::new(24);
    }
}
