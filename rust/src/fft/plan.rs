//! Size-dispatching FFT plans + a process-wide plan cache.
//!
//! Mirrors the cuFFT/FFTW "plan" concept the paper relies on: building a
//! plan does all trig/permutation precomputation; executing it is
//! allocation-light. Plans are cached per size in a global table so the
//! service hot path never rebuilds twiddles.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::bluestein::BluesteinPlan;
use super::complex::C64;
use super::radix2::Radix2Plan;

/// A complex FFT plan for one size (radix-2 when possible, Bluestein else).
#[derive(Debug, Clone)]
pub enum FftPlan {
    Radix2(Radix2Plan),
    Bluestein(BluesteinPlan),
}

impl FftPlan {
    pub fn new(n: usize) -> FftPlan {
        if n.is_power_of_two() {
            FftPlan::Radix2(Radix2Plan::new(n))
        } else {
            FftPlan::Bluestein(BluesteinPlan::new(n))
        }
    }

    pub fn len(&self) -> usize {
        match self {
            FftPlan::Radix2(p) => p.n,
            FftPlan::Bluestein(p) => p.n,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// In-place forward DFT (unnormalized).
    pub fn forward(&self, data: &mut [C64]) {
        match self {
            FftPlan::Radix2(p) => p.forward(data),
            FftPlan::Bluestein(p) => p.forward(data),
        }
    }

    /// In-place inverse DFT (normalized by 1/N).
    pub fn inverse(&self, data: &mut [C64]) {
        match self {
            FftPlan::Radix2(p) => p.inverse(data),
            FftPlan::Bluestein(p) => p.inverse(data),
        }
    }
}

static PLAN_CACHE: OnceLock<Mutex<HashMap<usize, Arc<FftPlan>>>> = OnceLock::new();

fn plan_cache() -> &'static Mutex<HashMap<usize, Arc<FftPlan>>> {
    PLAN_CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Fetch (or build and cache) the plan for size `n`.
pub fn plan(n: usize) -> Arc<FftPlan> {
    let mut cache = plan_cache().lock().unwrap();
    cache.entry(n).or_insert_with(|| Arc::new(FftPlan::new(n))).clone()
}

/// Number of cached FFT plans (metrics/introspection).
pub fn cached_plan_count() -> usize {
    plan_cache().lock().unwrap().len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dispatches_by_size() {
        assert!(matches!(FftPlan::new(64), FftPlan::Radix2(_)));
        assert!(matches!(FftPlan::new(100), FftPlan::Bluestein(_)));
        assert_eq!(FftPlan::new(100).len(), 100);
    }

    #[test]
    fn cache_returns_same_plan() {
        let a = plan(48);
        let b = plan(48);
        assert!(Arc::ptr_eq(&a, &b));
        assert!(cached_plan_count() >= 1);
    }

    #[test]
    fn plan_roundtrip_mixed_sizes() {
        let mut rng = Rng::new(4);
        for &n in &[6usize, 8, 30, 128] {
            let p = plan(n);
            let x: Vec<C64> =
                (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect();
            let mut y = x.clone();
            p.forward(&mut y);
            p.inverse(&mut y);
            for (u, v) in y.iter().zip(&x) {
                assert!((*u - *v).abs() < 1e-9);
            }
        }
    }
}
