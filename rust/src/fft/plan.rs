//! Size-dispatching FFT plans + a process-wide plan cache.
//!
//! Mirrors the cuFFT/FFTW "plan" concept the paper relies on: building a
//! plan does all trig/permutation precomputation; executing it is
//! allocation-light. Plans are cached per size in a global table so the
//! service hot path never rebuilds twiddles. Cached plans carry the
//! process-default [`FftKernel`]; benches and tests build explicit
//! kernels with [`FftPlan::with_kernel`] (uncached).

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::bluestein::BluesteinPlan;
use super::complex::C64;
use super::kernel::{FftKernel, Pow2Plan};

/// A complex FFT plan for one size (power-of-two kernel when possible,
/// Bluestein else).
#[derive(Debug, Clone)]
pub enum FftPlan {
    /// Power-of-two size: direct radix kernel.
    Pow2(Pow2Plan),
    /// Any other size: chirp-z via a padded power-of-two convolution.
    Bluestein(BluesteinPlan),
}

impl FftPlan {
    /// Plan a complex DFT of length `n` with the process-default kernel.
    pub fn new(n: usize) -> FftPlan {
        FftPlan::with_kernel(n, FftKernel::default_kernel())
    }

    /// Plan with an explicit power-of-two kernel; for non-power-of-two
    /// sizes the kernel selects Bluestein's inner convolution FFT.
    pub fn with_kernel(n: usize, kernel: FftKernel) -> FftPlan {
        if n.is_power_of_two() {
            FftPlan::Pow2(Pow2Plan::with_kernel(n, kernel))
        } else {
            FftPlan::Bluestein(BluesteinPlan::with_kernel(n, kernel))
        }
    }

    /// Transform length this plan was built for.
    pub fn len(&self) -> usize {
        match self {
            FftPlan::Pow2(p) => p.n(),
            FftPlan::Bluestein(p) => p.n,
        }
    }

    /// True iff the planned length is zero.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The power-of-two kernel this plan executes (Bluestein reports the
    /// kernel of its inner convolution FFT).
    pub fn kernel(&self) -> FftKernel {
        match self {
            FftPlan::Pow2(p) => p.kernel(),
            FftPlan::Bluestein(p) => p.kernel(),
        }
    }

    /// In-place forward DFT (unnormalized).
    pub fn forward(&self, data: &mut [C64]) {
        match self {
            FftPlan::Pow2(p) => p.forward(data),
            FftPlan::Bluestein(p) => p.forward(data),
        }
    }

    /// In-place inverse DFT (normalized by 1/N).
    pub fn inverse(&self, data: &mut [C64]) {
        match self {
            FftPlan::Pow2(p) => p.inverse(data),
            FftPlan::Bluestein(p) => p.inverse(data),
        }
    }

    /// Register the scratch classes one 1D transform takes (Bluestein's
    /// convolution buffer + inner kernel scratch; the SoA kernel's
    /// planar pair; nothing for the scalar kernel).
    pub(crate) fn register_scratch(&self, ws: &mut crate::util::scratch::Workspace) {
        match self {
            FftPlan::Pow2(p) => p.register_scratch(ws, 1),
            FftPlan::Bluestein(p) => p.register_scratch(ws),
        }
    }

    /// Register the scratch one *column-stage* call takes for `ncols`
    /// columns: the blocked in-place panel path for power-of-two sizes;
    /// Bluestein sizes run per-row 1D transforms behind a transpose (the
    /// transpose buffer itself belongs to the caller and is registered
    /// there).
    pub(crate) fn register_scratch_cols(
        &self,
        ws: &mut crate::util::scratch::Workspace,
        ncols: usize,
    ) {
        match self {
            FftPlan::Pow2(p) => p.register_scratch(ws, ncols),
            FftPlan::Bluestein(p) => p.register_scratch(ws),
        }
    }

    /// Axis-0 FFT of a row-major (n x ncols) matrix when this plan has a
    /// power-of-two kernel; returns false (data untouched) for Bluestein
    /// sizes, whose column stages go through the transpose path instead.
    pub fn try_transform_cols(&self, data: &mut [C64], ncols: usize, invert: bool) -> bool {
        match self {
            FftPlan::Pow2(p) => {
                p.transform_cols(data, ncols, invert);
                true
            }
            FftPlan::Bluestein(_) => false,
        }
    }
}

static PLAN_CACHE: OnceLock<Mutex<HashMap<usize, Arc<FftPlan>>>> = OnceLock::new();

fn plan_cache() -> &'static Mutex<HashMap<usize, Arc<FftPlan>>> {
    PLAN_CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Fetch (or build and cache) the plan for size `n`.
pub fn plan(n: usize) -> Arc<FftPlan> {
    let mut cache = plan_cache().lock().unwrap();
    cache.entry(n).or_insert_with(|| Arc::new(FftPlan::new(n))).clone()
}

/// Number of cached FFT plans (metrics/introspection).
pub fn cached_plan_count() -> usize {
    plan_cache().lock().unwrap().len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dispatches_by_size() {
        assert!(matches!(FftPlan::new(64), FftPlan::Pow2(_)));
        assert!(matches!(FftPlan::new(100), FftPlan::Bluestein(_)));
        assert_eq!(FftPlan::new(100).len(), 100);
    }

    #[test]
    fn explicit_kernel_reaches_bluestein_inner() {
        let p = FftPlan::with_kernel(100, FftKernel::ScalarRadix2);
        assert_eq!(p.kernel(), FftKernel::ScalarRadix2);
        let q = FftPlan::with_kernel(100, FftKernel::SplitRadixSoa);
        assert_eq!(q.kernel(), FftKernel::SplitRadixSoa);
    }

    #[test]
    fn cache_returns_same_plan() {
        let a = plan(48);
        let b = plan(48);
        assert!(Arc::ptr_eq(&a, &b));
        assert!(cached_plan_count() >= 1);
    }

    #[test]
    fn try_transform_cols_only_for_pow2() {
        let mut rng = Rng::new(8);
        let (n, ncols) = (8usize, 3usize);
        let mut data: Vec<C64> =
            (0..n * ncols).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        assert!(plan(n).try_transform_cols(&mut data, ncols, false));
        let mut data3: Vec<C64> =
            (0..3 * ncols).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        assert!(!plan(3).try_transform_cols(&mut data3, ncols, false));
    }

    #[test]
    fn plan_roundtrip_mixed_sizes() {
        let mut rng = Rng::new(4);
        for &n in &[6usize, 8, 30, 128] {
            let p = plan(n);
            let x: Vec<C64> =
                (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect();
            let mut y = x.clone();
            p.forward(&mut y);
            p.inverse(&mut y);
            for (u, v) in y.iter().zip(&x) {
                assert!((*u - *v).abs() < 1e-9);
            }
        }
    }
}
