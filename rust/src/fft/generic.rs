//! Generic-over-element FFT core: the `f32` instantiation path.
//!
//! These plans mirror the dedicated `f64` plans ([`super::kernel`],
//! [`super::bluestein`], [`super::rfft`], [`super::nd`]) stage for
//! stage, but are written once over [`Element`](crate::fft::elem::Element)
//! and store complex data as split re/im planes (structure-of-arrays:
//! better vectorization and exactly the element width of traffic —
//! the point of the `f32` path on a memory-bound transform).
//!
//! Design choices, deliberately boring:
//! - the power-of-two kernel is an iterative radix-2 DIT with
//!   precomputed per-stage twiddle tables (concatenated, stage `h`
//!   starting at offset `h - 1`), the same scheme as
//!   [`super::radix2::Radix2Plan`];
//! - arbitrary sizes go through the same chirp-z construction as
//!   [`super::bluestein::BluesteinPlan`], including the `i² mod 2n`
//!   precision guard;
//! - the real-input path packs even sizes into a half-length complex
//!   transform with the identical unpack recombination as
//!   [`super::rfft::RfftPlan`].
//!
//! All twiddles are computed in `f64` and rounded once to the target
//! element, so `f32` tables are correctly rounded. Accuracy of the
//! `f32` instantiation against the `f64` oracle is pinned by
//! `tests/prop_layout.rs` (≤ 1e-4 relative).

use std::f64::consts::PI;

use super::elem::{Cx, Element};
use crate::util::scratch::Workspace;

/// Iterative radix-2 DIT FFT over split re/im planes, power-of-two
/// sizes only.
#[derive(Debug, Clone)]
pub struct GenPow2<E> {
    n: usize,
    /// bit-reversal permutation table
    rev: Vec<u32>,
    /// per-stage twiddle tables, concatenated; stage `h` (half-butterfly
    /// span) occupies `[h-1 .. 2h-1)` with entry k = e^{-j π k / h}
    tw_re: Vec<E>,
    tw_im: Vec<E>,
}

impl<E: Element> GenPow2<E> {
    /// Build a plan for power-of-two `n`.
    pub fn new(n: usize) -> GenPow2<E> {
        assert!(n.is_power_of_two(), "GenPow2 requires a power of two, got {n}");
        let bits = n.trailing_zeros();
        let mut rev = vec![0u32; n];
        if bits > 0 {
            for (i, r) in rev.iter_mut().enumerate() {
                *r = (i as u32).reverse_bits() >> (32 - bits);
            }
        }
        let mut tw_re = Vec::with_capacity(n.saturating_sub(1));
        let mut tw_im = Vec::with_capacity(n.saturating_sub(1));
        let mut h = 1;
        while h < n {
            for k in 0..h {
                let w: Cx<E> = Cx::cis(-PI * k as f64 / h as f64);
                tw_re.push(w.re);
                tw_im.push(w.im);
            }
            h *= 2;
        }
        GenPow2 { n, rev, tw_re, tw_im }
    }

    /// Transform size.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Forward DFT (unnormalized, negative-exponent convention),
    /// in place over the two planes.
    pub fn forward(&self, re: &mut [E], im: &mut [E]) {
        self.run(re, im, false);
    }

    /// Inverse DFT including 1/N normalization, in place.
    pub fn inverse(&self, re: &mut [E], im: &mut [E]) {
        self.run(re, im, true);
        let s = E::from_f64(1.0 / self.n as f64);
        for v in re.iter_mut() {
            *v = *v * s;
        }
        for v in im.iter_mut() {
            *v = *v * s;
        }
    }

    fn run(&self, re: &mut [E], im: &mut [E], invert: bool) {
        let n = self.n;
        assert_eq!(re.len(), n);
        assert_eq!(im.len(), n);
        for i in 0..n {
            let j = self.rev[i] as usize;
            if j > i {
                re.swap(i, j);
                im.swap(i, j);
            }
        }
        let mut h = 1;
        while h < n {
            let twr = &self.tw_re[h - 1..2 * h - 1];
            let twi = &self.tw_im[h - 1..2 * h - 1];
            let mut s = 0;
            while s < n {
                for k in 0..h {
                    let (i0, i1) = (s + k, s + k + h);
                    let wr = twr[k];
                    let wi = if invert { -twi[k] } else { twi[k] };
                    let (ar, ai) = (re[i1], im[i1]);
                    let tr = wr * ar - wi * ai;
                    let ti = wr * ai + wi * ar;
                    let (br, bi) = (re[i0], im[i0]);
                    re[i1] = br - tr;
                    im[i1] = bi - ti;
                    re[i0] = br + tr;
                    im[i0] = bi + ti;
                }
                s += 2 * h;
            }
            h *= 2;
        }
    }
}

/// Chirp-z (Bluestein) DFT over split planes for arbitrary sizes,
/// mirroring [`super::bluestein::BluesteinPlan`].
#[derive(Debug, Clone)]
pub struct GenBluestein<E> {
    n: usize,
    m: usize,
    inner: GenPow2<E>,
    chirp_re: Vec<E>,
    chirp_im: Vec<E>,
    kern_re: Vec<E>,
    kern_im: Vec<E>,
}

impl<E: Element> GenBluestein<E> {
    /// Build a plan for any `n >= 1`.
    pub fn new(n: usize) -> GenBluestein<E> {
        assert!(n >= 1);
        let m = (2 * n - 1).next_power_of_two();
        let inner = GenPow2::new(m);
        let mut chirp_re = Vec::with_capacity(n);
        let mut chirp_im = Vec::with_capacity(n);
        for i in 0..n {
            // i² mod 2N keeps the angle argument small for large n
            let sq = (i * i) % (2 * n);
            let c: Cx<E> = Cx::cis(-PI * sq as f64 / n as f64);
            chirp_re.push(c.re);
            chirp_im.push(c.im);
        }
        let mut kern_re = vec![E::ZERO; m];
        let mut kern_im = vec![E::ZERO; m];
        for i in 0..n {
            // conjugate chirp, mirrored into the tail for the circular
            // convolution
            kern_re[i] = chirp_re[i];
            kern_im[i] = -chirp_im[i];
            if i > 0 {
                kern_re[m - i] = kern_re[i];
                kern_im[m - i] = kern_im[i];
            }
        }
        inner.forward(&mut kern_re, &mut kern_im);
        GenBluestein { n, m, inner, chirp_re, chirp_im, kern_re, kern_im }
    }

    /// Transform size.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Forward DFT (unnormalized), in place over the two planes.
    pub fn forward(&self, re: &mut [E], im: &mut [E]) {
        self.transform(re, im);
    }

    /// Inverse DFT including 1/N normalization, in place:
    /// `IDFT(x) = conj(DFT(conj(x))) / N`.
    pub fn inverse(&self, re: &mut [E], im: &mut [E]) {
        for v in im.iter_mut() {
            *v = -*v;
        }
        self.transform(re, im);
        let inv = E::from_f64(1.0 / self.n as f64);
        for v in re.iter_mut() {
            *v = *v * inv;
        }
        for v in im.iter_mut() {
            *v = -*v * inv;
        }
    }

    fn transform(&self, re: &mut [E], im: &mut [E]) {
        let (n, m) = (self.n, self.m);
        assert_eq!(re.len(), n);
        assert_eq!(im.len(), n);
        let mut br = E::take_scratch(m);
        let mut bi = E::take_scratch(m);
        br[n..].fill(E::ZERO);
        bi[n..].fill(E::ZERO);
        for i in 0..n {
            let (ar, ai) = (re[i], im[i]);
            let (cr, ci) = (self.chirp_re[i], self.chirp_im[i]);
            br[i] = ar * cr - ai * ci;
            bi[i] = ar * ci + ai * cr;
        }
        self.inner.forward(&mut br, &mut bi);
        for i in 0..m {
            let (ar, ai) = (br[i], bi[i]);
            let (kr, ki) = (self.kern_re[i], self.kern_im[i]);
            br[i] = ar * kr - ai * ki;
            bi[i] = ar * ki + ai * kr;
        }
        self.inner.inverse(&mut br, &mut bi);
        for i in 0..n {
            let (ar, ai) = (br[i], bi[i]);
            let (cr, ci) = (self.chirp_re[i], self.chirp_im[i]);
            re[i] = ar * cr - ai * ci;
            im[i] = ar * ci + ai * cr;
        }
        E::give_scratch(br);
        E::give_scratch(bi);
    }
}

/// Size-dispatching complex FFT over split planes: power-of-two sizes
/// use [`GenPow2`], everything else [`GenBluestein`].
#[derive(Debug, Clone)]
pub enum GenFft<E> {
    /// Iterative radix-2 plan (power-of-two sizes).
    Pow2(GenPow2<E>),
    /// Chirp-z plan (all other sizes).
    Bluestein(GenBluestein<E>),
}

impl<E: Element> GenFft<E> {
    /// Build the right plan for `n`.
    pub fn new(n: usize) -> GenFft<E> {
        if n.is_power_of_two() {
            GenFft::Pow2(GenPow2::new(n))
        } else {
            GenFft::Bluestein(GenBluestein::new(n))
        }
    }

    /// Transform size.
    pub fn len(&self) -> usize {
        match self {
            GenFft::Pow2(p) => p.n(),
            GenFft::Bluestein(p) => p.n(),
        }
    }

    /// Whether the size is zero (never true; plans require `n >= 1`).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Forward DFT (unnormalized), in place over the two planes.
    pub fn forward(&self, re: &mut [E], im: &mut [E]) {
        match self {
            GenFft::Pow2(p) => p.forward(re, im),
            GenFft::Bluestein(p) => p.forward(re, im),
        }
    }

    /// Inverse DFT with 1/N normalization, in place.
    pub fn inverse(&self, re: &mut [E], im: &mut [E]) {
        match self {
            GenFft::Pow2(p) => p.inverse(re, im),
            GenFft::Bluestein(p) => p.inverse(re, im),
        }
    }

    /// Register one transform's scratch classes (the Bluestein
    /// convolution planes; the pow2 kernel is allocation-free).
    pub fn register_scratch(&self, ws: &mut Workspace) {
        if let GenFft::Bluestein(p) = self {
            E::register_scratch(ws, p.m);
            E::register_scratch(ws, p.m);
        }
    }
}

/// Real-input FFT over split planes, mirroring
/// [`super::rfft::RfftPlan`]: even sizes pack into a half-length
/// complex transform, odd sizes run the full complex plan.
#[derive(Debug, Clone)]
pub struct GenRfft<E> {
    /// Real input length.
    pub n: usize,
    inner: GenFft<E>,
    /// recombination twiddles e^{-2π j k / n}, k in 0..=half/2
    tw_re: Vec<E>,
    tw_im: Vec<E>,
    even: bool,
}

impl<E: Element> GenRfft<E> {
    /// Build a plan for real inputs of length `n`.
    pub fn new(n: usize) -> GenRfft<E> {
        assert!(n >= 1);
        let even = n % 2 == 0 && n > 1;
        if even {
            let half = n / 2;
            let mut tw_re = Vec::with_capacity(half / 2 + 1);
            let mut tw_im = Vec::with_capacity(half / 2 + 1);
            for k in 0..half / 2 + 1 {
                let w: Cx<E> = Cx::cis(-2.0 * PI * k as f64 / n as f64);
                tw_re.push(w.re);
                tw_im.push(w.im);
            }
            GenRfft { n, inner: GenFft::new(half), tw_re, tw_im, even }
        } else {
            GenRfft { n, inner: GenFft::new(n), tw_re: Vec::new(), tw_im: Vec::new(), even }
        }
    }

    /// Onesided spectrum length, `n/2 + 1`.
    pub fn onesided_len(&self) -> usize {
        self.n / 2 + 1
    }

    fn twiddle_at(&self, k: usize) -> Cx<E> {
        let half = self.n / 2;
        if k <= half / 2 {
            Cx::new(self.tw_re[k], self.tw_im[k])
        } else {
            // w^k = -conj(w^{half-k}) since w^{half} = -1
            Cx::new(-self.tw_re[half - k], self.tw_im[half - k])
        }
    }

    /// Forward RFFT: real input (len n) → onesided spectrum planes
    /// (len n/2+1 each).
    pub fn forward(&self, x: &[E], out_re: &mut [E], out_im: &mut [E]) {
        let h = self.onesided_len();
        assert_eq!(x.len(), self.n);
        assert_eq!(out_re.len(), h);
        assert_eq!(out_im.len(), h);
        if !self.even {
            let mut br = E::take_scratch(self.n);
            let mut bi = E::take_scratch(self.n);
            br.copy_from_slice(x);
            bi.fill(E::ZERO);
            self.inner.forward(&mut br, &mut bi);
            out_re.copy_from_slice(&br[..h]);
            out_im.copy_from_slice(&bi[..h]);
            E::give_scratch(br);
            E::give_scratch(bi);
            return;
        }
        let half = self.n / 2;
        let mut zr = E::take_scratch(half);
        let mut zi = E::take_scratch(half);
        for m in 0..half {
            zr[m] = x[2 * m];
            zi[m] = x[2 * m + 1];
        }
        self.inner.forward(&mut zr, &mut zi);
        let half_e = E::from_f64(0.5);
        for k in 0..=half {
            let zk = if k == half {
                Cx::new(zr[0], zi[0])
            } else {
                Cx::new(zr[k], zi[k])
            };
            let c = (half - k) % half;
            let zc = Cx::new(zr[c], zi[c]).conj();
            let e = (zk + zc).scale(half_e);
            let o = (zk - zc).mul_j().scale(-half_e);
            let v = e + self.twiddle_at(k) * o;
            out_re[k] = v.re;
            out_im[k] = v.im;
        }
        E::give_scratch(zr);
        E::give_scratch(zi);
    }

    /// Inverse RFFT: onesided spectrum planes → real output (len n),
    /// normalized.
    pub fn inverse(&self, sre: &[E], sim: &[E], out: &mut [E]) {
        let h = self.onesided_len();
        assert_eq!(sre.len(), h);
        assert_eq!(sim.len(), h);
        assert_eq!(out.len(), self.n);
        if !self.even {
            let n = self.n;
            let mut br = E::take_scratch(n);
            let mut bi = E::take_scratch(n);
            br[..h].copy_from_slice(sre);
            bi[..h].copy_from_slice(sim);
            for k in h..n {
                br[k] = sre[n - k];
                bi[k] = -sim[n - k];
            }
            self.inner.inverse(&mut br, &mut bi);
            out.copy_from_slice(&br);
            E::give_scratch(br);
            E::give_scratch(bi);
            return;
        }
        let half = self.n / 2;
        let mut zr = E::take_scratch(half);
        let mut zi = E::take_scratch(half);
        let half_e = E::from_f64(0.5);
        for k in 0..half {
            let xk = Cx::new(sre[k], sim[k]);
            let xc = Cx::new(sre[half - k], sim[half - k]).conj();
            let e = (xk + xc).scale(half_e);
            let o = (xk - xc).scale(half_e) * self.twiddle_at(k).conj();
            let z = e + o.mul_j();
            zr[k] = z.re;
            zi[k] = z.im;
        }
        self.inner.inverse(&mut zr, &mut zi);
        for m in 0..half {
            out[2 * m] = zr[m];
            out[2 * m + 1] = zi[m];
        }
        E::give_scratch(zr);
        E::give_scratch(zi);
    }

    /// Register one transform's scratch classes.
    pub fn register_scratch(&self, ws: &mut Workspace) {
        let len = if self.even { self.n / 2 } else { self.n };
        E::register_scratch(ws, len);
        E::register_scratch(ws, len);
        self.inner.register_scratch(ws);
    }
}

/// 2-D real-input FFT over split planes: row RFFTs, then column FFTs
/// routed through a tiled transpose (mirroring
/// [`super::nd::Rfft2Plan`]'s transpose path, stage II of the fused
/// 2-D DCT).
#[derive(Debug, Clone)]
pub struct GenRfft2<E> {
    /// Rows.
    pub n1: usize,
    /// Columns.
    pub n2: usize,
    /// Onesided columns, `n2/2 + 1`.
    pub h2: usize,
    row: GenRfft<E>,
    col: GenFft<E>,
}

impl<E: Element> GenRfft2<E> {
    /// Build a plan for `n1 x n2` real inputs.
    pub fn new(n1: usize, n2: usize) -> GenRfft2<E> {
        assert!(n1 >= 1 && n2 >= 1);
        let row = GenRfft::new(n2);
        let h2 = row.onesided_len();
        GenRfft2 { n1, n2, h2, row, col: GenFft::new(n1) }
    }

    /// Forward: `n1*n2` reals → `n1*h2` onesided spectrum planes.
    pub fn forward(&self, x: &[E], sre: &mut [E], sim: &mut [E]) {
        let (n1, n2, h2) = (self.n1, self.n2, self.h2);
        assert_eq!(x.len(), n1 * n2);
        assert_eq!(sre.len(), n1 * h2);
        assert_eq!(sim.len(), n1 * h2);
        for r in 0..n1 {
            self.row.forward(
                &x[r * n2..(r + 1) * n2],
                &mut sre[r * h2..(r + 1) * h2],
                &mut sim[r * h2..(r + 1) * h2],
            );
        }
        self.col_fft(sre, sim, false);
    }

    /// Inverse: spectrum planes (consumed as scratch) → `n1*n2` reals.
    pub fn inverse(&self, sre: &mut [E], sim: &mut [E], out: &mut [E]) {
        let (n1, n2, h2) = (self.n1, self.n2, self.h2);
        assert_eq!(sre.len(), n1 * h2);
        assert_eq!(sim.len(), n1 * h2);
        assert_eq!(out.len(), n1 * n2);
        self.col_fft(sre, sim, true);
        for r in 0..n1 {
            self.row.inverse(
                &sre[r * h2..(r + 1) * h2],
                &sim[r * h2..(r + 1) * h2],
                &mut out[r * n2..(r + 1) * n2],
            );
        }
    }

    /// Column FFTs via transpose → contiguous row FFTs → transpose back.
    fn col_fft(&self, sre: &mut [E], sim: &mut [E], invert: bool) {
        let (n1, h2) = (self.n1, self.h2);
        if n1 == 1 {
            return; // length-1 column transform is the identity
        }
        let mut tr = E::take_scratch(n1 * h2);
        let mut ti = E::take_scratch(n1 * h2);
        transpose_plane(sre, &mut tr, n1, h2);
        transpose_plane(sim, &mut ti, n1, h2);
        for c in 0..h2 {
            let (re, im) = (&mut tr[c * n1..(c + 1) * n1], &mut ti[c * n1..(c + 1) * n1]);
            if invert {
                self.col.inverse(re, im);
            } else {
                self.col.forward(re, im);
            }
        }
        transpose_plane(&tr, sre, h2, n1);
        transpose_plane(&ti, sim, h2, n1);
        E::give_scratch(tr);
        E::give_scratch(ti);
    }

    /// Register one transform's scratch classes.
    pub fn register_scratch(&self, ws: &mut Workspace) {
        self.row.register_scratch(ws);
        if self.n1 > 1 {
            E::register_scratch(ws, self.n1 * self.h2);
            E::register_scratch(ws, self.n1 * self.h2);
            self.col.register_scratch(ws);
        }
    }
}

/// Cache-blocked out-of-place transpose of a `rows x cols` plane.
fn transpose_plane<E: Element>(src: &[E], dst: &mut [E], rows: usize, cols: usize) {
    const B: usize = 32;
    debug_assert_eq!(src.len(), rows * cols);
    debug_assert_eq!(dst.len(), rows * cols);
    let mut ib = 0;
    while ib < rows {
        let imax = (ib + B).min(rows);
        let mut jb = 0;
        while jb < cols {
            let jmax = (jb + B).min(cols);
            for i in ib..imax {
                for j in jb..jmax {
                    dst[j * rows + i] = src[i * cols + j];
                }
            }
            jb += B;
        }
        ib += B;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::radix2::dft_naive;
    use crate::fft::C64;
    use crate::util::rng::Rng;

    fn planes_from(x: &[C64]) -> (Vec<f64>, Vec<f64>) {
        (x.iter().map(|c| c.re).collect(), x.iter().map(|c| c.im).collect())
    }

    #[test]
    fn gen_pow2_matches_naive_dft() {
        let mut rng = Rng::new(41);
        for &n in &[1usize, 2, 4, 8, 32, 128] {
            let x: Vec<C64> = (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect();
            let want = dft_naive(&x, false);
            let (mut re, mut im) = planes_from(&x);
            let p: GenPow2<f64> = GenPow2::new(n);
            p.forward(&mut re, &mut im);
            for k in 0..n {
                assert!((re[k] - want[k].re).abs() < 1e-8 * n as f64, "n={n} k={k}");
                assert!((im[k] - want[k].im).abs() < 1e-8 * n as f64, "n={n} k={k}");
            }
            p.inverse(&mut re, &mut im);
            for k in 0..n {
                assert!((re[k] - x[k].re).abs() < 1e-9, "n={n}");
                assert!((im[k] - x[k].im).abs() < 1e-9, "n={n}");
            }
        }
    }

    #[test]
    fn gen_bluestein_matches_naive_dft() {
        let mut rng = Rng::new(42);
        for &n in &[1usize, 3, 5, 7, 12, 17, 100] {
            let x: Vec<C64> = (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect();
            let want = dft_naive(&x, false);
            let (mut re, mut im) = planes_from(&x);
            let p: GenBluestein<f64> = GenBluestein::new(n);
            p.forward(&mut re, &mut im);
            for k in 0..n {
                assert!((re[k] - want[k].re).abs() < 1e-8 * n as f64, "n={n} k={k}");
                assert!((im[k] - want[k].im).abs() < 1e-8 * n as f64, "n={n} k={k}");
            }
            p.inverse(&mut re, &mut im);
            for k in 0..n {
                assert!((re[k] - x[k].re).abs() < 1e-9, "n={n}");
                assert!((im[k] - x[k].im).abs() < 1e-9, "n={n}");
            }
        }
    }

    #[test]
    fn gen_rfft_matches_f64_rfft_plan() {
        use crate::fft::rfft::{onesided_len, RfftPlan};
        let mut rng = Rng::new(43);
        for &n in &[1usize, 2, 3, 4, 5, 8, 12, 15, 16, 64, 100] {
            let x = rng.normal_vec(n);
            let oracle = RfftPlan::new(n);
            let mut want = vec![C64::default(); onesided_len(n)];
            oracle.forward(&x, &mut want);
            let p: GenRfft<f64> = GenRfft::new(n);
            let h = p.onesided_len();
            assert_eq!(h, onesided_len(n));
            let mut sre = vec![0.0; h];
            let mut sim = vec![0.0; h];
            p.forward(&x, &mut sre, &mut sim);
            for k in 0..h {
                assert!((sre[k] - want[k].re).abs() < 1e-8 * n as f64, "n={n} k={k}");
                assert!((sim[k] - want[k].im).abs() < 1e-8 * n as f64, "n={n} k={k}");
            }
            let mut back = vec![0.0; n];
            p.inverse(&sre, &sim, &mut back);
            for (a, b) in back.iter().zip(&x) {
                assert!((a - b).abs() < 1e-9, "n={n}");
            }
        }
    }

    #[test]
    fn gen_rfft2_matches_f64_rfft2_plan() {
        use crate::fft::nd::Rfft2Plan;
        let mut rng = Rng::new(44);
        for &(n1, n2) in &[(1usize, 8usize), (4, 4), (8, 8), (5, 7), (9, 16), (16, 12)] {
            let x = rng.normal_vec(n1 * n2);
            let oracle = Rfft2Plan::new(n1, n2);
            let mut want = vec![C64::default(); n1 * oracle.h2];
            oracle.forward(&x, &mut want);
            let p: GenRfft2<f64> = GenRfft2::new(n1, n2);
            let mut sre = vec![0.0; n1 * p.h2];
            let mut sim = vec![0.0; n1 * p.h2];
            p.forward(&x, &mut sre, &mut sim);
            let scale = (n1 * n2) as f64;
            for k in 0..n1 * p.h2 {
                assert!((sre[k] - want[k].re).abs() < 1e-8 * scale, "{n1}x{n2} k={k}");
                assert!((sim[k] - want[k].im).abs() < 1e-8 * scale, "{n1}x{n2} k={k}");
            }
            let mut back = vec![0.0; n1 * n2];
            p.inverse(&mut sre, &mut sim, &mut back);
            for (a, b) in back.iter().zip(&x) {
                assert!((a - b).abs() < 1e-8, "{n1}x{n2}");
            }
        }
    }

    #[test]
    fn f32_instantiation_tracks_f64() {
        let mut rng = Rng::new(45);
        for &n in &[8usize, 15, 32] {
            let x = rng.normal_vec(n);
            let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
            let p64: GenRfft<f64> = GenRfft::new(n);
            let p32: GenRfft<f32> = GenRfft::new(n);
            let h = p64.onesided_len();
            let (mut ar, mut ai) = (vec![0.0f64; h], vec![0.0f64; h]);
            let (mut br, mut bi) = (vec![0.0f32; h], vec![0.0f32; h]);
            p64.forward(&x, &mut ar, &mut ai);
            p32.forward(&x32, &mut br, &mut bi);
            let scale: f64 = ar.iter().chain(ai.iter()).fold(1.0f64, |m, v| m.max(v.abs()));
            for k in 0..h {
                assert!((br[k] as f64 - ar[k]).abs() / scale < 1e-5, "n={n} k={k}");
                assert!((bi[k] as f64 - ai[k]).abs() / scale < 1e-5, "n={n} k={k}");
            }
        }
    }

    #[test]
    fn transpose_plane_roundtrips() {
        let (r, c) = (5usize, 7usize);
        let src: Vec<f64> = (0..r * c).map(|i| i as f64).collect();
        let mut t = vec![0.0; r * c];
        transpose_plane(&src, &mut t, r, c);
        assert_eq!(t[0], 0.0);
        assert_eq!(t[1], src[c]); // (1,0) of the transpose = (0,1) of src... column-major walk
        let mut back = vec![0.0; r * c];
        transpose_plane(&t, &mut back, c, r);
        assert_eq!(back, src);
    }
}
