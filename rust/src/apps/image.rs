//! Image compression via whole-image 2D DCT (paper §V-A, Algorithm 3).
//!
//! Unlike 8x8-block JPEG, the paper's pipeline transforms the full image,
//! thresholds small spectral magnitudes (Eq. 20), and inverse-transforms.
//! Since the threshold fuses with the transform stages, Amdahl's p = 1
//! and the application inherits the full transform speedup.

use crate::dct::{Dct2, Idct2};
use crate::util::rng::Rng;

/// Result of one compression run.
#[derive(Debug, Clone)]
pub struct CompressionReport {
    pub eps: f64,
    /// fraction of spectral coefficients zeroed
    pub sparsity: f64,
    /// peak signal-to-noise ratio of the reconstruction (dB)
    pub psnr_db: f64,
}

/// Whole-image compressor with cached plans.
pub struct Compressor {
    n1: usize,
    n2: usize,
    dct: Dct2,
    idct: Idct2,
}

impl Compressor {
    pub fn new(n1: usize, n2: usize) -> Compressor {
        Compressor { n1, n2, dct: Dct2::new(n1, n2), idct: Idct2::new(n1, n2) }
    }

    /// Algorithm 3: B = DCT(A); C = threshold(B); D = IDCT(C).
    /// Returns (reconstruction, #zeroed).
    pub fn compress(&self, image: &[f64], eps: f64) -> (Vec<f64>, usize) {
        let n = self.n1 * self.n2;
        assert_eq!(image.len(), n);
        let mut spec = vec![0.0; n];
        self.dct.forward(image, &mut spec);
        let mut zeroed = 0;
        for v in spec.iter_mut() {
            if v.abs() < eps {
                *v = 0.0;
                zeroed += 1;
            }
        }
        let mut out = vec![0.0; n];
        self.idct.forward(&spec, &mut out);
        (out, zeroed)
    }

    /// Compress and report sparsity + PSNR against the original.
    pub fn report(&self, image: &[f64], eps: f64) -> CompressionReport {
        let (rec, zeroed) = self.compress(image, eps);
        CompressionReport {
            eps,
            sparsity: zeroed as f64 / image.len() as f64,
            psnr_db: psnr(image, &rec, dynamic_range(image)),
        }
    }
}

fn dynamic_range(x: &[f64]) -> f64 {
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &v in x {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    (hi - lo).max(f64::EPSILON)
}

/// Peak signal-to-noise ratio in dB.
pub fn psnr(a: &[f64], b: &[f64], peak: f64) -> f64 {
    let mse: f64 =
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>() / a.len() as f64;
    if mse == 0.0 {
        f64::INFINITY
    } else {
        10.0 * ((peak * peak) / mse).log10()
    }
}

/// Synthetic test image: smooth low-frequency content + edges + noise
/// (the spectral profile real photographs have, so magnitude
/// thresholding behaves realistically).
pub fn synthetic_image(n1: usize, n2: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut img = vec![0.0; n1 * n2];
    for r in 0..n1 {
        for c in 0..n2 {
            let x = r as f64 / n1 as f64;
            let y = c as f64 / n2 as f64;
            // smooth base
            let mut v = 128.0
                + 60.0 * (2.0 * std::f64::consts::PI * x).sin()
                + 40.0 * (3.0 * std::f64::consts::PI * y).cos()
                + 25.0 * (5.0 * std::f64::consts::PI * (x + y)).sin();
            // blocky structure (edges)
            if (x - 0.5).abs() < 0.2 && (y - 0.5).abs() < 0.3 {
                v += 50.0;
            }
            // sensor noise
            v += 2.0 * rng.normal();
            img[r * n2 + c] = v;
        }
    }
    img
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eps_zero_is_lossless() {
        let img = synthetic_image(32, 32, 1);
        let (rec, zeroed) = Compressor::new(32, 32).compress(&img, 0.0);
        assert_eq!(zeroed, 0);
        for (a, b) in img.iter().zip(&rec) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn higher_eps_more_sparsity_lower_psnr() {
        let img = synthetic_image(64, 64, 2);
        let c = Compressor::new(64, 64);
        let r1 = c.report(&img, 1.0);
        let r2 = c.report(&img, 100.0);
        let r3 = c.report(&img, 2000.0);
        assert!(r1.sparsity <= r2.sparsity && r2.sparsity <= r3.sparsity);
        assert!(r1.psnr_db >= r2.psnr_db && r2.psnr_db >= r3.psnr_db);
        assert!(r3.sparsity > 0.5, "large eps should zero most coefficients");
        assert!(r2.psnr_db > 20.0, "moderate compression should stay faithful");
    }

    #[test]
    fn psnr_of_identical_is_infinite() {
        let x = vec![1.0, 2.0, 3.0];
        assert!(psnr(&x, &x, 1.0).is_infinite());
    }

    #[test]
    fn rectangular_images_work() {
        let img = synthetic_image(24, 56, 3);
        let c = Compressor::new(24, 56);
        let r = c.report(&img, 50.0);
        assert!(r.sparsity > 0.0 && r.psnr_db.is_finite());
    }
}
