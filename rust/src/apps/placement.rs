//! Electrostatic placement engine (DREAMPlace §V-B, Algorithm 4):
//! the end-to-end application driver for the paper's case study.
//!
//! Each iteration:
//!   1. build the density map from cell positions        (scatter)
//!   2. spectral solve: potential + force (the transform-heavy core,
//!      timed separately -- this is the Table VII region)
//!   3. gather per-cell forces from the field, move cells (gradient step)
//!
//! The engine supports both transform backends so examples and Table VII
//! can A/B fused vs row-column with everything else identical.

use std::time::Instant;

use super::ispd::Circuit;
use super::poisson::{PoissonSolver, SolverBackend};

/// Per-iteration report.
#[derive(Debug, Clone)]
pub struct StepReport {
    pub iter: usize,
    /// wall time of the transform-heavy spectral solve (Table VII region)
    pub transform_seconds: f64,
    /// wall time of everything else (density + gather + move)
    pub other_seconds: f64,
    /// density overflow after the step (must trend down)
    pub overflow: f64,
}

/// The placement engine.
pub struct PlacementEngine {
    pub grid: usize,
    solver: PoissonSolver,
    step_size: f64,
}

impl PlacementEngine {
    pub fn new(grid: usize, backend: SolverBackend) -> PlacementEngine {
        PlacementEngine {
            grid,
            solver: PoissonSolver::new(grid, grid, backend),
            step_size: 1.0,
        }
    }

    /// Run one electrostatic spreading iteration in place.
    pub fn step(&self, circuit: &mut Circuit, iter: usize) -> StepReport {
        let grid = self.grid;
        let g = grid as f64;
        let t0 = Instant::now();
        let rho = circuit.density_map(grid);
        let t_density = t0.elapsed().as_secs_f64();

        let (field, transform_seconds) = self.solver.solve(&rho);

        let t1 = Instant::now();
        // gather force at each cell (nearest bin) and move along it
        let scale = self.step_size * g;
        for i in 0..circuit.cells() {
            let ix = ((circuit.x[i] * g) as usize).min(grid - 1);
            let iy = ((circuit.y[i] * g) as usize).min(grid - 1);
            let fx = field.xi_x[ix * grid + iy];
            let fy = field.xi_y[ix * grid + iy];
            circuit.x[i] = (circuit.x[i] + scale * fx).clamp(0.0, 1.0 - 1e-9);
            circuit.y[i] = (circuit.y[i] + scale * fy).clamp(0.0, 1.0 - 1e-9);
        }
        let overflow = circuit.density_overflow(grid);
        let t_gather = t1.elapsed().as_secs_f64();

        StepReport {
            iter,
            transform_seconds,
            other_seconds: t_density + t_gather,
            overflow,
        }
    }

    /// Run `iters` iterations, returning per-step reports.
    pub fn run(&self, circuit: &mut Circuit, iters: usize) -> Vec<StepReport> {
        (0..iters).map(|i| self.step(circuit, i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::ispd::IspdBenchmark;

    fn tiny() -> Circuit {
        IspdBenchmark { name: "tiny", cells: 4000, grid: 32 }.generate(9)
    }

    #[test]
    fn spreading_reduces_density_overflow() {
        let mut c = tiny();
        let before = c.density_overflow(32);
        let engine = PlacementEngine::new(32, SolverBackend::Fused);
        let reports = engine.run(&mut c, 12);
        let after = reports.last().unwrap().overflow;
        assert!(
            after < before * 0.8,
            "overflow should drop: before {before}, after {after}"
        );
    }

    #[test]
    fn fused_and_row_column_trajectories_match() {
        let mut a = tiny();
        let mut b = tiny();
        PlacementEngine::new(32, SolverBackend::Fused).run(&mut a, 3);
        PlacementEngine::new(32, SolverBackend::RowColumn).run(&mut b, 3);
        for (x, y) in a.x.iter().zip(&b.x) {
            assert!((x - y).abs() < 1e-9, "same physics, different backend");
        }
    }

    #[test]
    fn reports_time_both_regions() {
        let mut c = tiny();
        let r = PlacementEngine::new(32, SolverBackend::Fused).step(&mut c, 0);
        assert!(r.transform_seconds > 0.0);
        assert!(r.other_seconds > 0.0);
    }

    #[test]
    fn cells_stay_in_die() {
        let mut c = tiny();
        PlacementEngine::new(32, SolverBackend::Fused).run(&mut c, 5);
        assert!(c.x.iter().all(|&v| (0.0..1.0).contains(&v)));
        assert!(c.y.iter().all(|&v| (0.0..1.0).contains(&v)));
    }
}
