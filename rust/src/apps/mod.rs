//! Applications built on the transform library (paper §V case studies):
//! whole-image compression, the spectral Poisson substrate, and the
//! DREAMPlace-style electrostatic placement engine with synthetic
//! ISPD-2005-scale benchmarks.

pub mod image;
pub mod ispd;
pub mod placement;
pub mod poisson;

pub use image::{psnr, synthetic_image, Compressor};
pub use ispd::{Circuit, IspdBenchmark, ISPD2005};
pub use placement::{PlacementEngine, StepReport};
pub use poisson::{Field, PoissonSolver, SolverBackend};
