//! Spectral Poisson solver (paper §V-B): the electrostatic substrate
//! DREAMPlace builds on.
//!
//! With Neumann (reflective) boundary conditions the cosine basis
//! diagonalizes the Laplacian: for a_uv = DCT2D(rho) and continuous
//! frequencies w_u = pi u / N1, w_v = pi v / N2,
//!
//!   phi  = IDCT2D      ( a_uv       / (w_u^2 + w_v^2) )   potential
//!   xi_x = IDCT_IDXST  ( a_uv  w_u  / (w_u^2 + w_v^2) )   field along rows
//!   xi_y = IDXST_IDCT  ( a_uv  w_v  / (w_u^2 + w_v^2) )   field along cols
//!
//! (gauge: the (0,0) mode is dropped). The sine-basis fields are exactly
//! the analytic -grad phi, which is why DREAMPlace needs IDXST.

use crate::dct::{Combo, Dct2, Idct2, IdxstCombo, StageTimes};

/// Potential + field of one density map.
#[derive(Debug, Clone)]
pub struct Field {
    pub phi: Vec<f64>,
    pub xi_x: Vec<f64>,
    pub xi_y: Vec<f64>,
}

/// Which 2D backend the solver uses (the Table VII A/B switch).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverBackend {
    /// fused three-stage transforms (ours)
    Fused,
    /// row-column transforms (baseline)
    RowColumn,
}

/// Spectral Poisson solver with cached plans for one grid size.
pub struct PoissonSolver {
    pub n1: usize,
    pub n2: usize,
    backend: SolverBackend,
    dct: Dct2,
    idct: Idct2,
    idct_idxst: IdxstCombo,
    idxst_idct: IdxstCombo,
    rc_dct: crate::dct::RowColumn,
    rc_idct: crate::dct::RowColumn,
    rc_idct_idxst: crate::dct::RowColumn,
    rc_idxst_idct: crate::dct::RowColumn,
    /// precomputed 1 / (w_u^2 + w_v^2), zero at (0,0)
    inv_w2: Vec<f64>,
    wu: Vec<f64>,
    wv: Vec<f64>,
}

impl PoissonSolver {
    pub fn new(n1: usize, n2: usize, backend: SolverBackend) -> PoissonSolver {
        let wu: Vec<f64> =
            (0..n1).map(|u| std::f64::consts::PI * u as f64 / n1 as f64).collect();
        let wv: Vec<f64> =
            (0..n2).map(|v| std::f64::consts::PI * v as f64 / n2 as f64).collect();
        let mut inv_w2 = vec![0.0; n1 * n2];
        for u in 0..n1 {
            for v in 0..n2 {
                let w2 = wu[u] * wu[u] + wv[v] * wv[v];
                inv_w2[u * n2 + v] = if w2 > 0.0 { 1.0 / w2 } else { 0.0 };
            }
        }
        PoissonSolver {
            n1,
            n2,
            backend,
            dct: Dct2::new(n1, n2),
            idct: Idct2::new(n1, n2),
            idct_idxst: IdxstCombo::new(n1, n2, Combo::IdctIdxst),
            idxst_idct: IdxstCombo::new(n1, n2, Combo::IdxstIdct),
            rc_dct: crate::dct::RowColumn::dct2(n1, n2),
            rc_idct: crate::dct::RowColumn::idct2(n1, n2),
            rc_idct_idxst: crate::dct::RowColumn::idct_idxst(n1, n2),
            rc_idxst_idct: crate::dct::RowColumn::idxst_idct(n1, n2),
            inv_w2,
            wu,
            wv,
        }
    }

    /// Paper Algorithm 4 lines 2-4: potential + force from a density map.
    /// Returns the field and the transform-stage wall time (for Table VII
    /// the baseline/ours comparison times exactly this region).
    pub fn solve(&self, density: &[f64]) -> (Field, f64) {
        let (n1, n2) = (self.n1, self.n2);
        assert_eq!(density.len(), n1 * n2);
        let t0 = std::time::Instant::now();
        // line 2: a = DCT2D(rho)
        let mut a = vec![0.0; n1 * n2];
        match self.backend {
            SolverBackend::Fused => self.dct.forward(density, &mut a),
            SolverBackend::RowColumn => self.rc_dct.forward(density, &mut a),
        }
        // line 3: scaled coefficient maps
        let mut c_phi = vec![0.0; n1 * n2];
        let mut c_x = vec![0.0; n1 * n2];
        let mut c_y = vec![0.0; n1 * n2];
        for u in 0..n1 {
            for v in 0..n2 {
                let i = u * n2 + v;
                let s = a[i] * self.inv_w2[i];
                c_phi[i] = s;
                c_x[i] = s * self.wu[u];
                c_y[i] = s * self.wv[v];
            }
        }
        // line 4: inverse transforms
        let mut phi = vec![0.0; n1 * n2];
        let mut xi_x = vec![0.0; n1 * n2];
        let mut xi_y = vec![0.0; n1 * n2];
        match self.backend {
            SolverBackend::Fused => {
                self.idct.forward(&c_phi, &mut phi);
                self.idct_idxst.forward(&c_x, &mut xi_x);
                self.idxst_idct.forward(&c_y, &mut xi_y);
            }
            SolverBackend::RowColumn => {
                self.rc_idct.forward(&c_phi, &mut phi);
                self.rc_idct_idxst.forward(&c_x, &mut xi_x);
                self.rc_idxst_idct.forward(&c_y, &mut xi_y);
            }
        }
        (Field { phi, xi_x, xi_y }, t0.elapsed().as_secs_f64())
    }

    /// Stage breakdown of the fused forward DCT (Fig. 6 instrumentation).
    pub fn dct_stage_times(&self, density: &[f64]) -> StageTimes {
        let mut out = vec![0.0; density.len()];
        self.dct.forward_timed(density, &mut out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::direct::dct2d_direct;
    use crate::util::rng::Rng;

    fn gaussian_density(n: usize) -> Vec<f64> {
        let mut rho = vec![0.0; n * n];
        for r in 0..n {
            for c in 0..n {
                let dx = r as f64 - n as f64 / 2.0;
                let dy = c as f64 - n as f64 / 3.0;
                rho[r * n + c] = (-(dx * dx + dy * dy) / (n as f64)).exp();
            }
        }
        rho
    }

    #[test]
    fn fused_and_row_column_agree() {
        let rho = gaussian_density(32);
        let (a, _) = PoissonSolver::new(32, 32, SolverBackend::Fused).solve(&rho);
        let (b, _) = PoissonSolver::new(32, 32, SolverBackend::RowColumn).solve(&rho);
        for (x, y) in a.phi.iter().zip(&b.phi) {
            assert!((x - y).abs() < 1e-9);
        }
        for (x, y) in a.xi_x.iter().zip(&b.xi_x) {
            assert!((x - y).abs() < 1e-9);
        }
        for (x, y) in a.xi_y.iter().zip(&b.xi_y) {
            assert!((x - y).abs() < 1e-9);
        }
    }

    #[test]
    fn potential_solves_poisson_in_spectral_sense() {
        // DCT2D(phi) .* w2 == DCT2D(rho) away from the (0,0) gauge mode
        let mut rng = Rng::new(300);
        let n = 16;
        let rho = rng.normal_vec(n * n);
        let solver = PoissonSolver::new(n, n, SolverBackend::Fused);
        let (f, _) = solver.solve(&rho);
        let a_rho = dct2d_direct(&rho, n, n);
        let a_phi = dct2d_direct(&f.phi, n, n);
        for u in 0..n {
            for v in 0..n {
                if u == 0 && v == 0 {
                    continue;
                }
                let w2 = solver.wu[u].powi(2) + solver.wv[v].powi(2);
                let lhs = a_phi[u * n + v] * w2;
                let rhs = a_rho[u * n + v];
                assert!(
                    (lhs - rhs).abs() < 1e-6 * rhs.abs().max(1.0),
                    "({u},{v}): {lhs} vs {rhs}"
                );
            }
        }
    }

    #[test]
    fn field_points_away_from_charge_blob() {
        // force on the positive-x side of the blob should push further +x
        let n = 32;
        let rho = gaussian_density(n);
        let (f, _) = PoissonSolver::new(n, n, SolverBackend::Fused).solve(&rho);
        // centroid of the blob is ~(n/2, n/3); sample on either side
        let lo = f.xi_x[(n / 2 - 8) * n + n / 3];
        let hi = f.xi_x[(n / 2 + 8) * n + n / 3];
        assert!(lo.signum() != hi.signum(), "field must change sign across blob");
    }

    #[test]
    fn solve_reports_positive_time() {
        let rho = gaussian_density(16);
        let (_, t) = PoissonSolver::new(16, 16, SolverBackend::Fused).solve(&rho);
        assert!(t > 0.0);
    }
}
