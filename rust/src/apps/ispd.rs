//! Synthetic ISPD-2005-like placement benchmarks.
//!
//! The paper's Table VII times DREAMPlace's electric potential + force
//! step on the eight ISPD 2005 contest designs. The real netlists are
//! external data we cannot ship, but the transform-side workload depends
//! only on (a) the density-grid dimensions and (b) the number of movable
//! cells feeding the density map / gradient scatter (the non-transform
//! work that dilutes the end-to-end speedup on the bigger designs —
//! the Amdahl effect the paper calls out). We therefore synthesize
//! circuits with the published cell counts and the bin sizes DREAMPlace
//! derives for them.

use crate::util::rng::Rng;

/// One synthetic benchmark instance.
#[derive(Debug, Clone)]
pub struct IspdBenchmark {
    pub name: &'static str,
    /// movable cell count (published ISPD 2005 sizes)
    pub cells: usize,
    /// density grid (DREAMPlace uses pow2 bins scaled to the design)
    pub grid: usize,
}

/// The eight Table VII designs with their published cell counts.
pub const ISPD2005: [IspdBenchmark; 8] = [
    IspdBenchmark { name: "adaptec1", cells: 211_447, grid: 256 },
    IspdBenchmark { name: "adaptec2", cells: 255_023, grid: 512 },
    IspdBenchmark { name: "adaptec3", cells: 451_650, grid: 512 },
    IspdBenchmark { name: "adaptec4", cells: 496_045, grid: 512 },
    IspdBenchmark { name: "bigblue1", cells: 278_164, grid: 256 },
    IspdBenchmark { name: "bigblue2", cells: 557_866, grid: 512 },
    IspdBenchmark { name: "bigblue3", cells: 1_096_812, grid: 1024 },
    IspdBenchmark { name: "bigblue4", cells: 2_177_353, grid: 1024 },
];

/// A synthetic circuit: cell positions + sizes on a unit die.
#[derive(Debug, Clone)]
pub struct Circuit {
    pub name: &'static str,
    pub grid: usize,
    pub x: Vec<f64>,
    pub y: Vec<f64>,
    pub w: Vec<f64>,
    pub h: Vec<f64>,
}

impl IspdBenchmark {
    /// Generate the synthetic circuit: clustered initial placement
    /// (placers start from heavily overlapping clusters).
    pub fn generate(&self, seed: u64) -> Circuit {
        let mut rng = Rng::new(seed ^ self.cells as u64);
        let n = self.cells;
        let mut x = Vec::with_capacity(n);
        let mut y = Vec::with_capacity(n);
        let mut w = Vec::with_capacity(n);
        let mut h = Vec::with_capacity(n);
        // a handful of attraction clusters, like netlist connectivity creates
        let clusters = 8 + (n / 100_000);
        let centers: Vec<(f64, f64)> = (0..clusters)
            .map(|_| (rng.range_f64(0.2, 0.8), rng.range_f64(0.2, 0.8)))
            .collect();
        let cell_area = 0.5 / n as f64; // ~50% utilization
        let side = cell_area.sqrt();
        for _ in 0..n {
            let (cx, cy) = centers[rng.below(clusters)];
            x.push((cx + 0.08 * rng.normal()).clamp(0.0, 1.0 - side));
            y.push((cy + 0.08 * rng.normal()).clamp(0.0, 1.0 - side));
            let s = rng.range_f64(0.6, 1.8);
            w.push(side * s);
            h.push(side / s);
        }
        Circuit { name: self.name, grid: self.grid, x, y, w, h }
    }
}

impl Circuit {
    pub fn cells(&self) -> usize {
        self.x.len()
    }

    /// Bilinear density-map accumulation (DREAMPlace Alg. 4 line 1 —
    /// part of the non-transform work in the Amdahl analysis).
    pub fn density_map(&self, grid: usize) -> Vec<f64> {
        let mut rho = vec![0.0; grid * grid];
        let g = grid as f64;
        for i in 0..self.cells() {
            let area = self.w[i] * self.h[i];
            let gx = (self.x[i] * g).min(g - 1.000001);
            let gy = (self.y[i] * g).min(g - 1.000001);
            let (ix, iy) = (gx as usize, gy as usize);
            let (fx, fy) = (gx - ix as f64, gy - iy as f64);
            let (ix1, iy1) = ((ix + 1).min(grid - 1), (iy + 1).min(grid - 1));
            rho[ix * grid + iy] += area * (1.0 - fx) * (1.0 - fy);
            rho[ix1 * grid + iy] += area * fx * (1.0 - fy);
            rho[ix * grid + iy1] += area * (1.0 - fx) * fy;
            rho[ix1 * grid + iy1] += area * fx * fy;
        }
        rho
    }

    /// Overlap proxy: sum of squared density above the mean (the
    /// quantity electrostatic spreading minimizes).
    pub fn density_overflow(&self, grid: usize) -> f64 {
        let rho = self.density_map(grid);
        let mean = rho.iter().sum::<f64>() / rho.len() as f64;
        rho.iter().map(|&d| (d - mean).max(0.0).powi(2)).sum::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_has_eight_designs_in_paper_order() {
        assert_eq!(ISPD2005.len(), 8);
        assert_eq!(ISPD2005[0].name, "adaptec1");
        assert_eq!(ISPD2005[7].name, "bigblue4");
        assert!(ISPD2005[7].cells > ISPD2005[0].cells * 10);
    }

    #[test]
    fn generation_is_deterministic() {
        let small = IspdBenchmark { name: "t", cells: 5000, grid: 64 };
        let a = small.generate(7);
        let b = small.generate(7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.w, b.w);
    }

    #[test]
    fn density_conserves_total_area() {
        let small = IspdBenchmark { name: "t", cells: 2000, grid: 64 };
        let c = small.generate(1);
        let rho = c.density_map(64);
        let total_area: f64 = c.w.iter().zip(&c.h).map(|(w, h)| w * h).sum();
        let total_rho: f64 = rho.iter().sum();
        assert!(
            (total_rho - total_area).abs() < 1e-9 * total_area.max(1.0),
            "{total_rho} vs {total_area}"
        );
    }

    #[test]
    fn cells_inside_die() {
        let small = IspdBenchmark { name: "t", cells: 3000, grid: 64 };
        let c = small.generate(2);
        assert!(c.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(c.y.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }
}
