//! Memory-layout descriptors for layout-polymorphic plan execution.
//!
//! Every plan in the tree historically assumed contiguous row-major
//! `f64` input. [`Layout`] makes the assumption explicit and optional:
//! it names the element type ([`ElemType`]), the per-axis strides, and
//! the batch stride of a caller's buffer, so the strided entry points
//! (`Dct2::forward_strided`, `Rfft2Plan::forward_strided`,
//! `Dct2::forward_batch_strided`, …) can run directly over padded or
//! interleaved views instead of forcing a gather copy first — the same
//! "layout is a plan parameter" argument the flexible MD-DFT framework
//! makes for slab/pencil views.
//!
//! Strides are in **elements** (not bytes) and must be positive; the
//! innermost data order inside a block is whatever the strides say, the
//! transform semantics are unchanged (outputs are always the plan's
//! packed row-major order). The strided f64 paths gather exactly the
//! same values a contiguous call would, in the same arithmetic order,
//! so their outputs are bit-identical to the contiguous plan
//! (`tests/prop_layout.rs` pins this).
//!
//! ```
//! use mddct::layout::Layout;
//!
//! // an 8x8 tile inside a 32-column padded image, batches 40 rows apart
//! let l = Layout::contiguous(&[8, 8]).with_strides(&[32, 1]).with_batch_stride(8 * 40);
//! assert_eq!(l.numel(), 64);
//! assert!(!l.is_contiguous());
//! assert!(l.validate().is_ok());
//! ```

/// Element type a buffer holds — the precision half of a [`Layout`].
///
/// `F64` is the crate's native precision; `F32` plans run through the
/// generic element core ([`crate::fft::elem`]) and halve the memory
/// traffic of a memory-bound transform.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum ElemType {
    /// 64-bit IEEE-754 (the default everywhere).
    #[default]
    F64,
    /// 32-bit IEEE-754 (the reduced-precision throughput path).
    F32,
}

impl ElemType {
    /// Size of one element in bytes.
    pub fn size_bytes(self) -> usize {
        match self {
            ElemType::F64 => 8,
            ElemType::F32 => 4,
        }
    }

    /// Stable lowercase label (`"f64"` / `"f32"`) for metrics and
    /// bench JSON rows.
    pub fn name(self) -> &'static str {
        match self {
            ElemType::F64 => "f64",
            ElemType::F32 => "f32",
        }
    }

    /// Parse a label produced by [`ElemType::name`].
    pub fn parse(s: &str) -> Option<ElemType> {
        match s {
            "f64" => Some(ElemType::F64),
            "f32" => Some(ElemType::F32),
            _ => None,
        }
    }
}

/// A strided view description: element type, logical shape, per-axis
/// strides, and the stride between consecutive batch blocks.
///
/// All strides count **elements**. `strides[d]` is the distance between
/// consecutive indices along axis `d`; `batch_stride` is the distance
/// between block `b` and block `b + 1` of a batched buffer. The
/// contiguous row-major layout of shape `[n1, n2]` is
/// `strides = [n2, 1]`, `batch_stride = n1 * n2`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Layout {
    /// Element type of the underlying buffer.
    pub elem: ElemType,
    /// Logical extent per axis (row-major order, outermost first).
    pub shape: Vec<usize>,
    /// Distance in elements between consecutive indices per axis.
    pub strides: Vec<usize>,
    /// Distance in elements between consecutive batch blocks.
    pub batch_stride: usize,
}

impl Layout {
    /// The contiguous row-major `f64` layout of `shape` — the layout
    /// every plan assumed before layouts existed.
    pub fn contiguous(shape: &[usize]) -> Layout {
        let numel: usize = shape.iter().product();
        Layout {
            elem: ElemType::F64,
            shape: shape.to_vec(),
            strides: contiguous_strides(shape),
            batch_stride: numel,
        }
    }

    /// Same layout with a different element type.
    pub fn with_elem(mut self, elem: ElemType) -> Layout {
        self.elem = elem;
        self
    }

    /// Same layout with explicit per-axis strides (must match the rank).
    pub fn with_strides(mut self, strides: &[usize]) -> Layout {
        assert_eq!(
            strides.len(),
            self.shape.len(),
            "stride count must match the rank"
        );
        self.strides = strides.to_vec();
        self
    }

    /// Same layout with an explicit batch stride (padding between
    /// packed blocks).
    pub fn with_batch_stride(mut self, batch_stride: usize) -> Layout {
        self.batch_stride = batch_stride;
        self
    }

    /// Number of axes.
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Logical elements per block (the product of the shape).
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Whether this is the plain packed row-major layout (unit inner
    /// stride, row-major outer strides, blocks exactly `numel` apart).
    pub fn is_contiguous(&self) -> bool {
        self.strides == contiguous_strides(&self.shape) && self.batch_stride == self.numel()
    }

    /// Buffer extent in elements one block touches: one past the
    /// largest reachable offset (0 for an empty shape).
    pub fn block_span(&self) -> usize {
        if self.shape.iter().any(|&d| d == 0) {
            return 0;
        }
        1 + self
            .shape
            .iter()
            .zip(&self.strides)
            .map(|(&d, &s)| (d - 1) * s)
            .sum::<usize>()
    }

    /// Minimum buffer length (in elements) holding `batch` blocks under
    /// this layout. Trailing padding after the last block is not
    /// required.
    pub fn required_len(&self, batch: usize) -> usize {
        if batch == 0 {
            return 0;
        }
        (batch - 1) * self.batch_stride + self.block_span()
    }

    /// Element offset of a multi-index within one block.
    pub fn offset(&self, idx: &[usize]) -> usize {
        assert_eq!(idx.len(), self.rank(), "index rank mismatch");
        idx.iter().zip(&self.strides).map(|(&i, &s)| i * s).sum()
    }

    /// Structural validation: rank ≥ 1, one stride per axis, positive
    /// strides on every non-degenerate axis, and a batch stride large
    /// enough that consecutive blocks cannot overlap.
    pub fn validate(&self) -> Result<(), String> {
        if self.shape.is_empty() {
            return Err("layout rank must be >= 1".into());
        }
        if self.strides.len() != self.shape.len() {
            return Err(format!(
                "{} strides for rank {}",
                self.strides.len(),
                self.shape.len()
            ));
        }
        for (axis, (&d, &s)) in self.shape.iter().zip(&self.strides).enumerate() {
            if d > 1 && s == 0 {
                return Err(format!("axis {axis} has extent {d} but stride 0"));
            }
        }
        if self.batch_stride < self.block_span() {
            return Err(format!(
                "batch stride {} < block span {} (blocks would overlap)",
                self.batch_stride,
                self.block_span()
            ));
        }
        Ok(())
    }

    /// Panic unless the layout is a valid rank-2 f64 view of shape
    /// `(n1, n2)`; returns the two strides. The strided plan entry
    /// points use this as their argument check.
    pub fn expect_2d_f64(&self, n1: usize, n2: usize) -> (usize, usize) {
        assert_eq!(self.elem, ElemType::F64, "f64 entry point given a {} layout", self.elem.name());
        assert_eq!(self.shape, [n1, n2], "layout shape does not match the plan");
        if let Err(e) = self.validate() {
            panic!("invalid layout: {e}");
        }
        (self.strides[0], self.strides[1])
    }
}

/// Row-major strides of `shape` (innermost stride 1).
fn contiguous_strides(shape: &[usize]) -> Vec<usize> {
    let mut strides = vec![1usize; shape.len()];
    for d in (0..shape.len().saturating_sub(1)).rev() {
        strides[d] = strides[d + 1] * shape[d + 1];
    }
    strides
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_layout_roundtrips() {
        let l = Layout::contiguous(&[4, 6]);
        assert_eq!(l.rank(), 2);
        assert_eq!(l.numel(), 24);
        assert_eq!(l.strides, vec![6, 1]);
        assert_eq!(l.batch_stride, 24);
        assert!(l.is_contiguous());
        assert_eq!(l.block_span(), 24);
        assert_eq!(l.required_len(3), 72);
        assert_eq!(l.offset(&[2, 3]), 15);
        assert!(l.validate().is_ok());
    }

    #[test]
    fn padded_and_interleaved_views() {
        // 8x8 tile of a 32-wide image
        let l = Layout::contiguous(&[8, 8]).with_strides(&[32, 1]);
        assert!(!l.is_contiguous());
        assert_eq!(l.block_span(), 7 * 32 + 7 + 1);
        assert_eq!(l.offset(&[1, 2]), 34);
        // interleaved columns
        let i = Layout::contiguous(&[4, 4]).with_strides(&[8, 2]).with_batch_stride(32);
        assert!(i.validate().is_ok());
        assert_eq!(i.block_span(), 3 * 8 + 3 * 2 + 1);
    }

    #[test]
    fn validation_rejects_broken_layouts() {
        assert!(Layout::contiguous(&[]).validate().is_err());
        let zero_stride = Layout::contiguous(&[4, 4]).with_strides(&[0, 1]);
        assert!(zero_stride.validate().is_err());
        let overlapping = Layout::contiguous(&[4, 4]).with_batch_stride(3);
        assert!(overlapping.validate().is_err());
        // a degenerate axis may carry stride 0 (it is never advanced)
        let degenerate = Layout::contiguous(&[1, 4]).with_strides(&[0, 1]);
        assert!(degenerate.validate().is_ok());
    }

    #[test]
    fn elem_type_labels() {
        assert_eq!(ElemType::F64.size_bytes(), 8);
        assert_eq!(ElemType::F32.size_bytes(), 4);
        assert_eq!(ElemType::parse("f32"), Some(ElemType::F32));
        assert_eq!(ElemType::parse(ElemType::F64.name()), Some(ElemType::F64));
        assert_eq!(ElemType::parse("f16"), None);
        assert_eq!(ElemType::default(), ElemType::F64);
    }

    #[test]
    fn required_len_without_trailing_padding() {
        let l = Layout::contiguous(&[2, 2]).with_batch_stride(10);
        assert_eq!(l.required_len(0), 0);
        assert_eq!(l.required_len(1), 4);
        assert_eq!(l.required_len(3), 24);
    }
}
