//! Precomputed DCT twiddle tables w(k) = e^{-j pi k / 2N}.
//!
//! The paper: "the terms of a and b ... are pre-computed and fixed before
//! the call of the DCT procedures" (texture cache on the GPU). Tables are
//! cached per size alongside the FFT plans.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::fft::C64;

/// Twiddle table for one size: w[k] = e^{-j pi k / 2n}, k = 0..n-1.
#[derive(Debug, Clone)]
pub struct Twiddle {
    /// Table size (one entry per k in `0..n`).
    pub n: usize,
    /// The table itself: `w[k] = e^{-j pi k / 2n}`.
    pub w: Vec<C64>,
}

impl Twiddle {
    /// Build the size-`n` table (n cis evaluations, done once per size).
    pub fn new(n: usize) -> Twiddle {
        let step = -std::f64::consts::PI / (2.0 * n as f64);
        Twiddle { n, w: (0..n).map(|k| C64::cis(step * k as f64)).collect() }
    }

    /// w[k] (the paper's `a` / `b` coefficients).
    #[inline(always)]
    pub fn at(&self, k: usize) -> C64 {
        self.w[k]
    }

    /// conj(w[k]) -- the paper stores only `a` and derives `a-bar`.
    #[inline(always)]
    pub fn conj_at(&self, k: usize) -> C64 {
        self.w[k].conj()
    }
}

static TW_CACHE: OnceLock<Mutex<HashMap<usize, Arc<Twiddle>>>> = OnceLock::new();

/// Fetch (or build and cache) the twiddle table for size n.
pub fn twiddle(n: usize) -> Arc<Twiddle> {
    let mut cache = TW_CACHE.get_or_init(|| Mutex::new(HashMap::new())).lock().unwrap();
    cache.entry(n).or_insert_with(|| Arc::new(Twiddle::new(n))).clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn values_on_unit_circle() {
        let t = Twiddle::new(16);
        for k in 0..16 {
            assert!((t.at(k).abs() - 1.0).abs() < 1e-14);
        }
        assert!((t.at(0) - C64::new(1.0, 0.0)).abs() < 1e-14);
    }

    #[test]
    fn angle_is_minus_pi_k_over_2n() {
        let n = 8;
        let t = Twiddle::new(n);
        for k in 0..n {
            let want = C64::cis(-std::f64::consts::PI * k as f64 / (2.0 * n as f64));
            assert!((t.at(k) - want).abs() < 1e-14);
            assert!((t.conj_at(k) - want.conj()).abs() < 1e-14);
        }
    }

    #[test]
    fn cache_shares_instances() {
        let a = twiddle(24);
        let b = twiddle(24);
        assert!(Arc::ptr_eq(&a, &b));
    }
}
