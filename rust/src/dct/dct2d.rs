//! Fused 2D DCT / IDCT — the paper's headline contribution (Algorithm 2 +
//! the §III-B efficient postprocessing).
//!
//! Forward:  Eq. (13) fused reorder -> 2D RFFT -> paired-quadrant combine
//!           (4 outputs per 2 onesided-spectrum reads, Eqs. 17/18).
//! Inverse:  onesided Hermitian spectrum build (corrected Eq. 15, 4 reads
//!           per entry) -> 2D IRFFT -> Eq. (16) unreorder.
//!
//! Only 3 full-matrix memory stages per transform vs. the row-column
//! method's 8 (Fig. 5) — that is the entire speedup story, reproduced by
//! `benches/table5_2d_dct.rs`.

use std::sync::Arc;
use std::time::Instant;

use crate::fft::{onesided_len, C64, Rfft2Plan};
use crate::layout::Layout;
use crate::parallel::{
    global_pool, par_chunks_mut, par_strided_chunks_mut, split_groups, ExecPolicy, ShardPolicy,
};

use super::reorder::{
    reorder_2d_gather_row, reorder_2d_gather_row_strided, reorder_2d_scatter,
    reorder_2d_scatter_strided, unreorder_2d, unreorder_2d_row,
};
use super::twiddle::{twiddle, Twiddle};
use crate::util::scratch;

/// Per-stage wall-clock breakdown (Figure 6).
#[derive(Debug, Clone, Copy, Default)]
pub struct StageTimes {
    /// Seconds in the preprocess (reorder/gather) stage.
    pub pre: f64,
    /// Seconds in the MD RFFT stage.
    pub fft: f64,
    /// Seconds in the postprocess (twiddle-combine) stage.
    pub post: f64,
}

impl StageTimes {
    /// Sum of the three stage times.
    pub fn total(&self) -> f64 {
        self.pre + self.fft + self.post
    }
}

/// Split `out` into the §III-B row pairs (k1, N1-k1): each item owns
/// output row k1 and, when distinct, row m1 = N1-k1. Pairs touch
/// disjoint rows, so they are the unit of postprocess parallelism.
fn claim_row_pairs(
    out: &mut [f64],
    n1: usize,
    n2: usize,
) -> Vec<(usize, &mut [f64], Option<&mut [f64]>)> {
    let mut rows: Vec<Option<&mut [f64]>> = out.chunks_mut(n2).map(Some).collect();
    let mut pairs = Vec::with_capacity(n1 / 2 + 1);
    for k1 in 0..=n1 / 2 {
        let m1 = (n1 - k1) % n1;
        let top = rows[k1].take().expect("each row claimed once");
        let bot = if m1 != k1 { rows[m1].take() } else { None };
        pairs.push((k1, top, bot));
    }
    pairs
}

/// Fused 2D DCT plan.
#[derive(Debug, Clone)]
pub struct Dct2 {
    /// Number of rows.
    pub n1: usize,
    /// Number of columns.
    pub n2: usize,
    h2: usize,
    rfft2: Rfft2Plan,
    tw1: Arc<Twiddle>,
    tw2: Arc<Twiddle>,
    policy: ExecPolicy,
    shards: ShardPolicy,
    ws: scratch::Workspace,
}

impl Dct2 {
    /// Plan an `n1 x n2` fused 2D DCT with the auto execution policy.
    pub fn new(n1: usize, n2: usize) -> Dct2 {
        Self::with_policy(n1, n2, ExecPolicy::Auto)
    }

    /// Plan with an explicit execution policy (threaded through all
    /// three stages and the inner 2D RFFT).
    pub fn with_policy(n1: usize, n2: usize, policy: ExecPolicy) -> Dct2 {
        let h2 = onesided_len(n2);
        let rfft2 = Rfft2Plan::with_policy(n1, n2, policy);
        let mut ws = scratch::Workspace::new();
        ws.add_f64(n1 * n2); // reordered input
        ws.add_c64(n1 * h2); // onesided spectrum
        ws.merge(&rfft2.workspace());
        ws.prewarm();
        Dct2 {
            n1,
            n2,
            h2,
            rfft2,
            tw1: twiddle(n1),
            tw2: twiddle(n2),
            policy,
            shards: ShardPolicy::Auto,
            ws,
        }
    }

    /// Scratch manifest of one `forward` call, pre-sized at plan build
    /// (see [`crate::util::scratch::Workspace`] for the lifetime rules).
    pub fn workspace(&self) -> &scratch::Workspace {
        &self.ws
    }

    /// Prewarm the calling thread's scratch pool so its next `forward`
    /// performs zero heap allocations.
    pub fn prewarm(&self) {
        self.ws.prewarm();
    }

    /// Same plan with an explicit band-shard policy, threaded through
    /// all three stages (pre-reorder rows, the inner 2D RFFT's row and
    /// column stages, postprocess row pairs). Each stage becomes the
    /// work-item count [`ShardPolicy::bands`] dictates for its row
    /// count; `ShardPolicy::MaxShards(1)` forces single-band execution.
    pub fn with_shards(mut self, shards: ShardPolicy) -> Dct2 {
        self.shards = shards;
        self.rfft2 = self.rfft2.with_shards(shards);
        self
    }

    /// Band work items for a stage of `rows` rows under this plan's
    /// exec + shard policies.
    fn bands(&self, rows: usize) -> usize {
        self.shards.bands(rows, self.policy.lanes(self.n1 * self.n2))
    }

    /// Compute the 2D DCT of row-major `x` into `out`.
    pub fn forward(&self, x: &[f64], out: &mut [f64]) {
        self.forward_timed(x, out);
    }

    /// Forward transform returning the per-stage breakdown (Fig. 6).
    pub fn forward_timed(&self, x: &[f64], out: &mut [f64]) -> StageTimes {
        let (n1, n2, h2) = (self.n1, self.n2, self.h2);
        assert_eq!(x.len(), n1 * n2);
        assert_eq!(out.len(), n1 * n2);

        let t0 = Instant::now();
        let mut pre = scratch::take_f64(n1 * n2);
        let lanes = self.bands(n1);
        if lanes > 1 {
            // gather order is row-local on the output, so rows fan out
            par_chunks_mut(&mut pre, n2, lanes, |r, row| {
                reorder_2d_gather_row(x, row, r, n1, n2);
            });
        } else {
            reorder_2d_scatter(x, &mut pre, n1, n2);
        }
        let t1 = Instant::now();
        let mut spec = scratch::take_c64(n1 * h2);
        self.rfft2.forward(&pre, &mut spec);
        let t2 = Instant::now();
        self.postprocess(&spec, out);
        let t3 = Instant::now();
        scratch::give_f64(pre);
        scratch::give_c64(spec);
        // the trace spans reuse the same instants as the returned
        // StageTimes, so both views of the breakdown cannot drift
        crate::obs::stage_span("dct2.pre", t0, t1);
        crate::obs::stage_span("dct2.fft", t1, t2);
        crate::obs::stage_span("dct2.post", t2, t3);
        StageTimes {
            pre: (t1 - t0).as_secs_f64(),
            fft: (t2 - t1).as_secs_f64(),
            post: (t3 - t2).as_secs_f64(),
        }
    }

    /// Efficient postprocess (§III-B): row pairs (k1, N1-k1); each
    /// iteration reads V(k1,k2) and V(m1,k2) once and writes the four
    /// outputs y(k1,k2), y(m1,k2), y(k1,N2-k2), y(m1,N2-k2).
    ///
    /// Derivation (validated against the direct oracle): with
    ///   P = a b V1,  Q = a conj(b) conj(V2),
    ///   R = conj(a b-bar) V2 = conj(a) b V2,  S = conj(a b) conj(V1),
    ///   y(k1,  k2)    =  2 Re(P + Q)
    ///   y(k1,  N2-k2) = -2 Im(P - Q)
    ///   y(m1,  k2)    =  2 Im(R + S)
    ///   y(m1,  N2-k2) =  2 Re(R - S)
    pub fn postprocess(&self, spec: &[C64], out: &mut [f64]) {
        let n1 = self.n1;
        // the §III-B row pair is the postprocess shard unit
        let lanes = self.bands(n1 / 2 + 1);
        if lanes > 1 && n1 / 2 + 1 > 1 {
            let pairs = claim_row_pairs(out, n1, self.n2);
            let groups = split_groups(pairs, lanes);
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = groups
                .into_iter()
                .map(|group| {
                    Box::new(move || {
                        let _band = crate::obs::SpanGuard::begin("dct2.post.band");
                        for (k1, top, bot) in group {
                            self.postprocess_pair(spec, k1, top, bot);
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            global_pool().scope(jobs);
        } else {
            self.postprocess_serial(spec, out);
        }
    }

    /// Single-band postprocess: the same row-pair walk as the parallel
    /// path (identical arithmetic, ascending k1) but carving the two
    /// rows out of `out` with `split_at_mut` instead of materializing a
    /// pair list — this keeps the serial hot path allocation-free.
    fn postprocess_serial(&self, spec: &[C64], out: &mut [f64]) {
        let (n1, n2) = (self.n1, self.n2);
        for k1 in 0..=n1 / 2 {
            let m1 = (n1 - k1) % n1;
            if m1 == k1 {
                let top = &mut out[k1 * n2..(k1 + 1) * n2];
                self.postprocess_pair(spec, k1, top, None);
            } else {
                // k1 <= n1/2 <= m1 and they differ, so k1's row ends
                // before m1's begins
                let (head, tail) = out.split_at_mut(m1 * n2);
                let top = &mut head[k1 * n2..(k1 + 1) * n2];
                let bot = &mut tail[..n2];
                self.postprocess_pair(spec, k1, top, Some(bot));
            }
        }
    }

    /// Batched forward DCT: `batch` packed (n1 x n2) blocks in `xs` ->
    /// `batch` packed blocks in `out`. Each stage runs across the whole
    /// batch — a reorder sweep, the inner [`Rfft2Plan::forward_batch`]
    /// (whose row stage is one batched RFFT over all `batch*n1` rows),
    /// and a postprocess sweep — so one [`ExecPolicy`] dispatch covers
    /// the batch instead of one per transform. Per-block arithmetic is
    /// the serial kernel's, so the output is bit-identical to `batch`
    /// solo [`Dct2::forward`] calls (for a fixed FFT kernel).
    pub fn forward_batch(&self, xs: &[f64], out: &mut [f64], batch: usize) {
        let numel = self.n1 * self.n2;
        assert_eq!(xs.len(), batch * numel);
        self.forward_batch_with(|b| &xs[b * numel..(b + 1) * numel], out, batch);
    }

    /// Batched forward DCT over caller-provided per-block views: block
    /// `b` is read from `xs[b]` (each view exactly `n1*n2` long) — no
    /// pack copy of the inputs is ever made. Same stage fusion and
    /// bit-identical output as [`Dct2::forward_batch`] on the packed
    /// concatenation of the views; this is the coordinator's zero-copy
    /// packed-batch path.
    pub fn forward_batch_views(&self, xs: &[&[f64]], out: &mut [f64]) {
        let numel = self.n1 * self.n2;
        for (b, x) in xs.iter().enumerate() {
            assert_eq!(x.len(), numel, "view {b}: expected {numel} elements");
        }
        self.forward_batch_with(|b| xs[b], out, xs.len());
    }

    /// Batched forward DCT over one strided arena: block `b` starts at
    /// `xs[b * layout.batch_stride]` and is read at the layout's
    /// per-axis strides (no gather pack first); output block `b` is
    /// written row-major contiguous starting at
    /// `out[b * layout.batch_stride]` (the inter-block padding is left
    /// untouched). Per-block arithmetic is the contiguous batch
    /// kernel's, so results are bit-identical to packing the views and
    /// calling [`Dct2::forward_batch`].
    pub fn forward_batch_strided(
        &self,
        xs: &[f64],
        layout: &Layout,
        out: &mut [f64],
        batch: usize,
    ) {
        let (n1, n2, h2) = (self.n1, self.n2, self.h2);
        let (s1, s2) = layout.expect_2d_f64(n1, n2);
        let bstride = layout.batch_stride;
        let numel = n1 * n2;
        if batch == 0 {
            return;
        }
        assert!(
            xs.len() >= layout.required_len(batch),
            "strided input too short: {} < {}",
            xs.len(),
            layout.required_len(batch)
        );
        assert!(
            bstride >= numel,
            "batch stride {bstride} cannot hold a packed {n1}x{n2} output block"
        );
        assert!(
            out.len() >= (batch - 1) * bstride + numel,
            "strided output too short: {} < {}",
            out.len(),
            (batch - 1) * bstride + numel
        );
        let lanes = self.policy.lanes(batch * numel);
        let mut pre = scratch::take_f64(batch * numel);
        {
            let _s = crate::obs::SpanGuard::begin("dct2.batch.pre");
            par_chunks_mut(&mut pre, numel, lanes, |b, block| {
                reorder_2d_scatter_strided(&xs[b * bstride..], s1, s2, block, n1, n2);
            });
        }
        let mut spec = scratch::take_c64(batch * n1 * h2);
        {
            let _s = crate::obs::SpanGuard::begin("dct2.batch.fft");
            self.rfft2.forward_batch(&pre, &mut spec, batch);
        }
        {
            let _s = crate::obs::SpanGuard::begin("dct2.batch.post");
            par_strided_chunks_mut(out, numel, bstride, batch, lanes, |b, block| {
                self.postprocess_serial(&spec[b * n1 * h2..(b + 1) * n1 * h2], block);
            });
        }
        scratch::give_f64(pre);
        scratch::give_c64(spec);
    }

    /// Single-transform forward over a strided view: the (n1 x n2)
    /// block is read at `layout` strides straight from `x` (no gather
    /// copy into a packed staging buffer first); the output is the
    /// plan's usual packed row-major block. Bit-identical to packing
    /// the view and calling [`Dct2::forward`].
    pub fn forward_strided(&self, x: &[f64], layout: &Layout, out: &mut [f64]) {
        let (n1, n2, h2) = (self.n1, self.n2, self.h2);
        let (s1, s2) = layout.expect_2d_f64(n1, n2);
        if s2 == 1 && s1 == n2 {
            self.forward(&x[..n1 * n2], out);
            return;
        }
        assert!(
            x.len() > (n1 - 1) * s1 + (n2 - 1) * s2,
            "strided view out of bounds: len {} for shape ({n1},{n2}) strides ({s1},{s2})",
            x.len()
        );
        assert_eq!(out.len(), n1 * n2);
        let t0 = Instant::now();
        let mut pre = scratch::take_f64(n1 * n2);
        let lanes = self.bands(n1);
        if lanes > 1 {
            par_chunks_mut(&mut pre, n2, lanes, |r, row| {
                reorder_2d_gather_row_strided(x, s1, s2, row, r, n1, n2);
            });
        } else {
            reorder_2d_scatter_strided(x, s1, s2, &mut pre, n1, n2);
        }
        let t1 = Instant::now();
        let mut spec = scratch::take_c64(n1 * h2);
        self.rfft2.forward(&pre, &mut spec);
        let t2 = Instant::now();
        self.postprocess(&spec, out);
        let t3 = Instant::now();
        scratch::give_f64(pre);
        scratch::give_c64(spec);
        crate::obs::stage_span("dct2.pre", t0, t1);
        crate::obs::stage_span("dct2.fft", t1, t2);
        crate::obs::stage_span("dct2.post", t2, t3);
    }

    /// The shared batched-forward core: block `b`'s input is whatever
    /// slice `block(b)` returns (a packed sub-slice, a caller view, …),
    /// the three fused stages run across the whole batch, and per-block
    /// arithmetic is the serial kernel's — every public batch entry
    /// point funnels here, which is what makes them bit-identical to
    /// each other.
    fn forward_batch_with<'x, F>(&self, block: F, out: &mut [f64], batch: usize)
    where
        F: Fn(usize) -> &'x [f64] + Sync,
    {
        let (n1, n2, h2) = (self.n1, self.n2, self.h2);
        assert_eq!(out.len(), batch * n1 * n2);
        if batch == 0 {
            return;
        }
        let lanes = self.policy.lanes(batch * n1 * n2);
        let mut pre = scratch::take_f64(batch * n1 * n2);
        {
            let _s = crate::obs::SpanGuard::begin("dct2.batch.pre");
            par_chunks_mut(&mut pre, n1 * n2, lanes, |b, blk| {
                reorder_2d_scatter(block(b), blk, n1, n2);
            });
        }
        let mut spec = scratch::take_c64(batch * n1 * h2);
        {
            let _s = crate::obs::SpanGuard::begin("dct2.batch.fft");
            self.rfft2.forward_batch(&pre, &mut spec, batch);
        }
        {
            let _s = crate::obs::SpanGuard::begin("dct2.batch.post");
            par_chunks_mut(out, n1 * n2, lanes, |b, blk| {
                self.postprocess_serial(&spec[b * n1 * h2..(b + 1) * n1 * h2], blk);
            });
        }
        scratch::give_f64(pre);
        scratch::give_c64(spec);
    }

    /// Postprocess one row pair (k1, N1-k1): reads spectrum rows k1 and
    /// m1, writes output rows `top` (= k1) and `bot` (= m1 when
    /// distinct). Arithmetic per element is identical across serial and
    /// parallel dispatch, so outputs are bit-equal either way.
    fn postprocess_pair(
        &self,
        spec: &[C64],
        k1: usize,
        top: &mut [f64],
        mut bot: Option<&mut [f64]>,
    ) {
        let (n1, n2, h2) = (self.n1, self.n2, self.h2);
        let m1 = (n1 - k1) % n1;
        let a = self.tw1.at(k1);
        let row1 = k1 * h2;
        let row2 = m1 * h2;
        for k2 in 0..h2 {
            let b = self.tw2.at(k2);
            let ab = a * b;
            let abc = a * b.conj();
            let v1 = spec[row1 + k2];
            let v2 = spec[row2 + k2];
            let p = ab * v1;
            let q = abc * v2.conj();
            top[k2] = 2.0 * (p.re + q.re);
            let k2r = n2 - k2; // right-half partner column
            let has_col = k2 > 0 && k2r != k2;
            if has_col {
                top[k2r] = -2.0 * (p.im - q.im);
            }
            if let Some(bottom) = bot.as_deref_mut() {
                let r = abc.conj() * v2;
                let s = ab.conj() * v1.conj();
                bottom[k2] = 2.0 * (r.im + s.im);
                if has_col {
                    bottom[k2r] = 2.0 * (r.re - s.re);
                }
            }
        }
    }

    /// Naive postprocess (Table III's comparison row): one independent
    /// "thread" per output element, each re-reading its two spectrum
    /// entries and redoing the full twiddle math.
    pub fn postprocess_naive(&self, spec: &[C64], out: &mut [f64]) {
        let (n1, n2, h2) = (self.n1, self.n2, self.h2);
        let read = |k1: usize, k2: usize| -> C64 {
            // onesided accessor with Hermitian reconstruction
            if k2 < h2 {
                spec[k1 * h2 + k2]
            } else {
                spec[((n1 - k1) % n1) * h2 + (n2 - k2)].conj()
            }
        };
        for k1 in 0..n1 {
            let a = self.tw1.at(k1);
            let m1 = (n1 - k1) % n1;
            for k2 in 0..n2 {
                let b = self.tw2.at(k2);
                let v1 = read(k1, k2);
                let v2 = read(m1, k2).conj();
                out[k1 * n2 + k2] = 2.0 * (a * (b * v1 + b.conj() * v2)).re;
            }
        }
    }
}

/// Fused 2D IDCT plan.
#[derive(Debug, Clone)]
pub struct Idct2 {
    /// Number of rows.
    pub n1: usize,
    /// Number of columns.
    pub n2: usize,
    h2: usize,
    rfft2: Rfft2Plan,
    tw1: Arc<Twiddle>,
    tw2: Arc<Twiddle>,
    policy: ExecPolicy,
    shards: ShardPolicy,
    ws: scratch::Workspace,
}

impl Idct2 {
    /// Plan an `n1 x n2` fused 2D IDCT with the auto execution policy.
    pub fn new(n1: usize, n2: usize) -> Idct2 {
        Self::with_policy(n1, n2, ExecPolicy::Auto)
    }

    /// Plan with an explicit execution policy.
    pub fn with_policy(n1: usize, n2: usize, policy: ExecPolicy) -> Idct2 {
        let h2 = onesided_len(n2);
        let rfft2 = Rfft2Plan::with_policy(n1, n2, policy);
        let mut ws = scratch::Workspace::new();
        ws.add_c64(n1 * h2); // onesided spectrum build
        ws.add_f64(n1 * n2); // inverse-RFFT output before the unreorder
        ws.merge(&rfft2.workspace());
        ws.prewarm();
        Idct2 {
            n1,
            n2,
            h2,
            rfft2,
            tw1: twiddle(n1),
            tw2: twiddle(n2),
            policy,
            shards: ShardPolicy::Auto,
            ws,
        }
    }

    /// Scratch manifest of one `forward` call, pre-sized at plan build.
    pub fn workspace(&self) -> &scratch::Workspace {
        &self.ws
    }

    /// Prewarm the calling thread's scratch pool so its next `forward`
    /// performs zero heap allocations.
    pub fn prewarm(&self) {
        self.ws.prewarm();
    }

    /// Same plan with an explicit band-shard policy (see
    /// [`Dct2::with_shards`]); threaded through the spectrum-build rows,
    /// the inner 2D IRFFT, and the unreorder rows.
    pub fn with_shards(mut self, shards: ShardPolicy) -> Idct2 {
        self.shards = shards;
        self.rfft2 = self.rfft2.with_shards(shards);
        self
    }

    /// Band work items for a stage of `rows` rows under this plan's
    /// exec + shard policies.
    fn bands(&self, rows: usize) -> usize {
        self.shards.bands(rows, self.policy.lanes(self.n1 * self.n2))
    }

    /// Inverse-transform `x` into `out` (both `n1 * n2` long).
    pub fn forward(&self, x: &[f64], out: &mut [f64]) {
        self.forward_timed(x, out);
    }

    /// Inverse transform with the per-stage breakdown.
    pub fn forward_timed(&self, x: &[f64], out: &mut [f64]) -> StageTimes {
        let (n1, n2, h2) = (self.n1, self.n2, self.h2);
        assert_eq!(x.len(), n1 * n2);
        assert_eq!(out.len(), n1 * n2);

        let t0 = Instant::now();
        let mut spec = scratch::take_c64(n1 * h2);
        self.preprocess(x, &mut spec);
        let t1 = Instant::now();
        let mut v = scratch::take_f64(n1 * n2);
        self.rfft2.inverse(&spec, &mut v);
        let t2 = Instant::now();
        let lanes = self.bands(n1);
        if lanes > 1 {
            par_chunks_mut(out, n2, lanes, |r, row| {
                unreorder_2d_row(&v, row, r, n1, n2);
            });
        } else {
            unreorder_2d(&v, out, n1, n2);
        }
        let t3 = Instant::now();
        scratch::give_c64(spec);
        scratch::give_f64(v);
        // same instants feed the trace and the returned StageTimes
        crate::obs::stage_span("idct2.pre", t0, t1);
        crate::obs::stage_span("idct2.fft", t1, t2);
        crate::obs::stage_span("idct2.post", t2, t3);
        StageTimes {
            pre: (t1 - t0).as_secs_f64(),
            fft: (t2 - t1).as_secs_f64(),
            post: (t3 - t2).as_secs_f64(),
        }
    }

    /// Batched inverse DCT: the stage-fused mirror of
    /// [`Dct2::forward_batch`] — a spectrum-build sweep over the batch,
    /// one [`Rfft2Plan::inverse_batch`], and an unreorder sweep.
    /// Bit-identical to `batch` solo [`Idct2::forward`] calls for a
    /// fixed FFT kernel.
    pub fn forward_batch(&self, xs: &[f64], out: &mut [f64], batch: usize) {
        let numel = self.n1 * self.n2;
        assert_eq!(xs.len(), batch * numel);
        self.forward_batch_with(|b| &xs[b * numel..(b + 1) * numel], out, batch);
    }

    /// Batched inverse DCT over caller-provided per-block views (the
    /// mirror of [`Dct2::forward_batch_views`]): block `b` is read from
    /// `xs[b]` with no pack copy; bit-identical to
    /// [`Idct2::forward_batch`] on the packed concatenation.
    pub fn forward_batch_views(&self, xs: &[&[f64]], out: &mut [f64]) {
        let numel = self.n1 * self.n2;
        for (b, x) in xs.iter().enumerate() {
            assert_eq!(x.len(), numel, "view {b}: expected {numel} elements");
        }
        self.forward_batch_with(|b| xs[b], out, xs.len());
    }

    /// Batched inverse DCT over one strided arena (the mirror of
    /// [`Dct2::forward_batch_strided`]): input block `b` is read at
    /// `layout` strides from `xs[b * layout.batch_stride]`, output
    /// block `b` is written packed row-major at
    /// `out[b * layout.batch_stride]` with inter-block padding left
    /// untouched.
    pub fn forward_batch_strided(
        &self,
        xs: &[f64],
        layout: &Layout,
        out: &mut [f64],
        batch: usize,
    ) {
        let (n1, n2, h2) = (self.n1, self.n2, self.h2);
        let (s1, s2) = layout.expect_2d_f64(n1, n2);
        let bstride = layout.batch_stride;
        let numel = n1 * n2;
        if batch == 0 {
            return;
        }
        assert!(
            xs.len() >= layout.required_len(batch),
            "strided input too short: {} < {}",
            xs.len(),
            layout.required_len(batch)
        );
        assert!(
            bstride >= numel,
            "batch stride {bstride} cannot hold a packed {n1}x{n2} output block"
        );
        assert!(
            out.len() >= (batch - 1) * bstride + numel,
            "strided output too short: {} < {}",
            out.len(),
            (batch - 1) * bstride + numel
        );
        let lanes = self.policy.lanes(batch * numel);
        let mut spec = scratch::take_c64(batch * n1 * h2);
        {
            let _s = crate::obs::SpanGuard::begin("idct2.batch.pre");
            par_chunks_mut(&mut spec, n1 * h2, lanes, |b, sblock| {
                let xb = &xs[b * bstride..];
                for (k1, srow) in sblock.chunks_mut(h2).enumerate() {
                    self.preprocess_row_strided(xb, s1, s2, k1, srow);
                }
            });
        }
        let mut v = scratch::take_f64(batch * numel);
        {
            let _s = crate::obs::SpanGuard::begin("idct2.batch.fft");
            self.rfft2.inverse_batch(&spec, &mut v, batch);
        }
        {
            let _s = crate::obs::SpanGuard::begin("idct2.batch.post");
            par_strided_chunks_mut(out, numel, bstride, batch, lanes, |b, block| {
                unreorder_2d(&v[b * numel..(b + 1) * numel], block, n1, n2);
            });
        }
        scratch::give_c64(spec);
        scratch::give_f64(v);
    }

    /// Single-transform inverse over a strided view (the mirror of
    /// [`Dct2::forward_strided`]): the spectrum build reads the four
    /// mirrored inputs at `layout` strides, the rest of the pipeline is
    /// the contiguous one. Bit-identical to packing the view and
    /// calling [`Idct2::forward`].
    pub fn forward_strided(&self, x: &[f64], layout: &Layout, out: &mut [f64]) {
        let (n1, n2, h2) = (self.n1, self.n2, self.h2);
        let (s1, s2) = layout.expect_2d_f64(n1, n2);
        if s2 == 1 && s1 == n2 {
            self.forward(&x[..n1 * n2], out);
            return;
        }
        assert!(
            x.len() > (n1 - 1) * s1 + (n2 - 1) * s2,
            "strided view out of bounds: len {} for shape ({n1},{n2}) strides ({s1},{s2})",
            x.len()
        );
        assert_eq!(out.len(), n1 * n2);
        let t0 = Instant::now();
        let mut spec = scratch::take_c64(n1 * h2);
        let lanes = self.bands(n1);
        par_chunks_mut(&mut spec, h2, lanes, |k1, srow| {
            self.preprocess_row_strided(x, s1, s2, k1, srow);
        });
        let t1 = Instant::now();
        let mut v = scratch::take_f64(n1 * n2);
        self.rfft2.inverse(&spec, &mut v);
        let t2 = Instant::now();
        if lanes > 1 {
            par_chunks_mut(out, n2, lanes, |r, row| {
                unreorder_2d_row(&v, row, r, n1, n2);
            });
        } else {
            unreorder_2d(&v, out, n1, n2);
        }
        let t3 = Instant::now();
        scratch::give_c64(spec);
        scratch::give_f64(v);
        crate::obs::stage_span("idct2.pre", t0, t1);
        crate::obs::stage_span("idct2.fft", t1, t2);
        crate::obs::stage_span("idct2.post", t2, t3);
    }

    /// The shared batched-inverse core (see [`Dct2::forward_batch_with`]
    /// for the contract): every public batch entry point funnels here.
    fn forward_batch_with<'x, F>(&self, block: F, out: &mut [f64], batch: usize)
    where
        F: Fn(usize) -> &'x [f64] + Sync,
    {
        let (n1, n2, h2) = (self.n1, self.n2, self.h2);
        assert_eq!(out.len(), batch * n1 * n2);
        if batch == 0 {
            return;
        }
        let lanes = self.policy.lanes(batch * n1 * n2);
        let mut spec = scratch::take_c64(batch * n1 * h2);
        {
            let _s = crate::obs::SpanGuard::begin("idct2.batch.pre");
            par_chunks_mut(&mut spec, n1 * h2, lanes, |b, sblock| {
                let xb = block(b);
                for (k1, srow) in sblock.chunks_mut(h2).enumerate() {
                    self.preprocess_row(xb, k1, srow);
                }
            });
        }
        let mut v = scratch::take_f64(batch * n1 * n2);
        {
            let _s = crate::obs::SpanGuard::begin("idct2.batch.fft");
            self.rfft2.inverse_batch(&spec, &mut v, batch);
        }
        {
            let _s = crate::obs::SpanGuard::begin("idct2.batch.post");
            par_chunks_mut(out, n1 * n2, lanes, |b, blk| {
                unreorder_2d(&v[b * n1 * n2..(b + 1) * n1 * n2], blk, n1, n2);
            });
        }
        scratch::give_c64(spec);
        scratch::give_f64(v);
    }

    /// Onesided spectrum build (corrected Eq. 15): each entry reads the
    /// four mirrored inputs x(k1,k2), x(m1,k2), x(k1,m2), x(m1,m2) with
    /// zero boundaries, and writes one complex value:
    ///   V = conj(a) conj(b) / 4 * ( (x11 - x22) - j (x21 + x12) )
    pub fn preprocess(&self, x: &[f64], spec: &mut [C64]) {
        let lanes = self.bands(self.n1);
        // each spectrum row k1 only *reads* input rows k1 / n1-k1, so
        // rows are independent and fan out directly
        par_chunks_mut(spec, self.h2, lanes, |k1, srow| {
            self.preprocess_row(x, k1, srow);
        });
    }

    /// Build one onesided spectrum row (the per-lane preprocess kernel).
    fn preprocess_row(&self, x: &[f64], k1: usize, srow: &mut [C64]) {
        let (n1, n2, h2) = (self.n1, self.n2, self.h2);
        debug_assert_eq!(srow.len(), h2);
        let ac = self.tw1.conj_at(k1);
        for k2 in 0..h2 {
            let bc = self.tw2.conj_at(k2);
            let x11 = x[k1 * n2 + k2];
            let x21 = if k1 == 0 { 0.0 } else { x[(n1 - k1) * n2 + k2] };
            let x12 = if k2 == 0 { 0.0 } else { x[k1 * n2 + (n2 - k2)] };
            let x22 = if k1 == 0 || k2 == 0 {
                0.0
            } else {
                x[(n1 - k1) * n2 + (n2 - k2)]
            };
            let z = C64::new(x11 - x22, -(x21 + x12));
            srow[k2] = (ac * bc * z).scale(0.25);
        }
    }

    /// [`Idct2::preprocess_row`] over a strided view: identical
    /// arithmetic, with every input read at `x[i1*s1 + i2*s2]` instead
    /// of the packed row-major offset — so the spectrum (and therefore
    /// the transform) is bit-identical to the contiguous path.
    fn preprocess_row_strided(&self, x: &[f64], s1: usize, s2: usize, k1: usize, srow: &mut [C64]) {
        let (n1, n2, h2) = (self.n1, self.n2, self.h2);
        debug_assert_eq!(srow.len(), h2);
        let ac = self.tw1.conj_at(k1);
        for k2 in 0..h2 {
            let bc = self.tw2.conj_at(k2);
            let x11 = x[k1 * s1 + k2 * s2];
            let x21 = if k1 == 0 { 0.0 } else { x[(n1 - k1) * s1 + k2 * s2] };
            let x12 = if k2 == 0 { 0.0 } else { x[k1 * s1 + (n2 - k2) * s2] };
            let x22 = if k1 == 0 || k2 == 0 {
                0.0
            } else {
                x[(n1 - k1) * s1 + (n2 - k2) * s2]
            };
            let z = C64::new(x11 - x22, -(x21 + x12));
            srow[k2] = (ac * bc * z).scale(0.25);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::direct::{dct2d_direct, idct2d_direct};
    use crate::util::prop::{check_close, forall, shapes};

    #[test]
    fn dct2_matches_direct() {
        forall(30, shapes(1, 24), |rng, &(n1, n2)| {
            let x = rng.normal_vec(n1 * n2);
            let plan = Dct2::new(n1, n2);
            let mut out = vec![0.0; n1 * n2];
            plan.forward(&x, &mut out);
            check_close(&out, &dct2d_direct(&x, n1, n2), 1e-9)
        });
    }

    #[test]
    fn idct2_matches_direct() {
        forall(30, shapes(1, 24), |rng, &(n1, n2)| {
            let x = rng.normal_vec(n1 * n2);
            let plan = Idct2::new(n1, n2);
            let mut out = vec![0.0; n1 * n2];
            plan.forward(&x, &mut out);
            check_close(&out, &idct2d_direct(&x, n1, n2), 1e-9)
        });
    }

    #[test]
    fn roundtrip_identity() {
        forall(20, shapes(1, 32), |rng, &(n1, n2)| {
            let x = rng.normal_vec(n1 * n2);
            let mut y = vec![0.0; n1 * n2];
            Dct2::new(n1, n2).forward(&x, &mut y);
            let mut back = vec![0.0; n1 * n2];
            Idct2::new(n1, n2).forward(&y, &mut back);
            check_close(&back, &x, 1e-9)
        });
    }

    #[test]
    fn efficient_equals_naive_postprocess() {
        forall(20, shapes(2, 24), |rng, &(n1, n2)| {
            let x = rng.normal_vec(n1 * n2);
            let plan = Dct2::new(n1, n2);
            let mut pre = vec![0.0; n1 * n2];
            super::super::reorder::reorder_2d_scatter(&x, &mut pre, n1, n2);
            let mut spec = vec![C64::default(); n1 * onesided_len(n2)];
            plan.rfft2.forward(&pre, &mut spec);
            let mut a = vec![0.0; n1 * n2];
            let mut b = vec![0.0; n1 * n2];
            plan.postprocess(&spec, &mut a);
            plan.postprocess_naive(&spec, &mut b);
            check_close(&a, &b, 1e-10)
        });
    }

    #[test]
    fn parallel_policy_is_bit_equal_to_serial() {
        use crate::parallel::ExecPolicy;
        let mut rng = crate::util::rng::Rng::new(40);
        // odd, prime (Bluestein on both axes), and power-of-two shapes
        for &(n1, n2) in &[(9usize, 15usize), (13, 7), (16, 16), (1, 8), (2, 2), (31, 10)] {
            let x = rng.normal_vec(n1 * n2);
            let mut ys = vec![0.0; n1 * n2];
            let mut yp = vec![0.0; n1 * n2];
            Dct2::with_policy(n1, n2, ExecPolicy::Serial).forward(&x, &mut ys);
            Dct2::with_policy(n1, n2, ExecPolicy::Threads(4)).forward(&x, &mut yp);
            assert_eq!(ys, yp, "dct2 ({n1},{n2})");
            let mut bs = vec![0.0; n1 * n2];
            let mut bp = vec![0.0; n1 * n2];
            Idct2::with_policy(n1, n2, ExecPolicy::Serial).forward(&ys, &mut bs);
            Idct2::with_policy(n1, n2, ExecPolicy::Threads(4)).forward(&yp, &mut bp);
            assert_eq!(bs, bp, "idct2 ({n1},{n2})");
        }
    }

    #[test]
    fn sharded_plan_is_bit_equal_to_serial() {
        use crate::parallel::{ExecPolicy, ShardPolicy};
        let mut rng = crate::util::rng::Rng::new(41);
        for &(n1, n2) in &[(9usize, 15usize), (16, 16), (13, 7), (33, 17)] {
            let x = rng.normal_vec(n1 * n2);
            let mut ys = vec![0.0; n1 * n2];
            Dct2::with_policy(n1, n2, ExecPolicy::Serial).forward(&x, &mut ys);
            for shards in [1usize, 2, 3, 7] {
                let mut yp = vec![0.0; n1 * n2];
                Dct2::with_policy(n1, n2, ExecPolicy::Serial)
                    .with_shards(ShardPolicy::MaxShards(shards))
                    .forward(&x, &mut yp);
                assert_eq!(ys, yp, "dct2 ({n1},{n2}) shards={shards}");
                let mut bs = vec![0.0; n1 * n2];
                let mut bp = vec![0.0; n1 * n2];
                Idct2::with_policy(n1, n2, ExecPolicy::Serial).forward(&ys, &mut bs);
                Idct2::with_policy(n1, n2, ExecPolicy::Serial)
                    .with_shards(ShardPolicy::MaxShards(shards))
                    .forward(&yp, &mut bp);
                assert_eq!(bs, bp, "idct2 ({n1},{n2}) shards={shards}");
            }
        }
    }

    #[test]
    fn forward_batch_matches_solo_bitwise() {
        use crate::parallel::ExecPolicy;
        let mut rng = crate::util::rng::Rng::new(42);
        for &(n1, n2, batch) in &[(8usize, 8usize, 7usize), (9, 15, 4), (13, 7, 3), (16, 16, 1)] {
            let xs = rng.normal_vec(n1 * n2 * batch);
            for exec in [ExecPolicy::Serial, ExecPolicy::Threads(4)] {
                let fwd = Dct2::with_policy(n1, n2, exec);
                let inv = Idct2::with_policy(n1, n2, exec);
                let numel = n1 * n2;
                let mut want = vec![0.0; numel * batch];
                for (b, w) in want.chunks_mut(numel).enumerate() {
                    fwd.forward(&xs[b * numel..(b + 1) * numel], w);
                }
                let mut got = vec![0.0; numel * batch];
                fwd.forward_batch(&xs, &mut got, batch);
                assert_eq!(got, want, "dct2 ({n1},{n2}) batch={batch} {exec:?}");
                let mut bwant = vec![0.0; numel * batch];
                for (b, w) in bwant.chunks_mut(numel).enumerate() {
                    inv.forward(&want[b * numel..(b + 1) * numel], w);
                }
                let mut bgot = vec![0.0; numel * batch];
                inv.forward_batch(&got, &mut bgot, batch);
                assert_eq!(bgot, bwant, "idct2 ({n1},{n2}) batch={batch} {exec:?}");
            }
        }
    }

    #[test]
    fn views_and_strided_match_packed_bitwise() {
        use crate::layout::Layout;
        use crate::parallel::ExecPolicy;
        let mut rng = crate::util::rng::Rng::new(43);
        for &(n1, n2, batch) in &[(8usize, 8usize, 3usize), (9, 15, 2), (13, 7, 4)] {
            let numel = n1 * n2;
            let xs = rng.normal_vec(numel * batch);
            for exec in [ExecPolicy::Serial, ExecPolicy::Threads(4)] {
                let fwd = Dct2::with_policy(n1, n2, exec);
                let inv = Idct2::with_policy(n1, n2, exec);
                let mut want = vec![0.0; numel * batch];
                fwd.forward_batch(&xs, &mut want, batch);

                // views path: per-block borrows, no pack copy
                let views: Vec<&[f64]> =
                    (0..batch).map(|b| &xs[b * numel..(b + 1) * numel]).collect();
                let mut got = vec![0.0; numel * batch];
                fwd.forward_batch_views(&views, &mut got);
                assert_eq!(got, want, "dct2 views ({n1},{n2}) batch={batch} {exec:?}");

                // strided path: blocks embedded in a padded arena
                let (s2, s1) = (2usize, n2 * 2 + 3);
                let layout = Layout::contiguous(&[n1, n2])
                    .with_strides(&[s1, s2])
                    .with_batch_stride((n1 - 1) * s1 + (n2 - 1) * s2 + 5);
                let mut arena = vec![f64::NAN; layout.required_len(batch)];
                for b in 0..batch {
                    for i1 in 0..n1 {
                        for i2 in 0..n2 {
                            arena[b * layout.batch_stride + i1 * s1 + i2 * s2] =
                                xs[b * numel + i1 * n2 + i2];
                        }
                    }
                }
                let mut sout = vec![f64::NAN; (batch - 1) * layout.batch_stride + numel];
                fwd.forward_batch_strided(&arena, &layout, &mut sout, batch);
                for b in 0..batch {
                    let blk = &sout[b * layout.batch_stride..b * layout.batch_stride + numel];
                    assert_eq!(blk, &want[b * numel..(b + 1) * numel], "dct2 strided b={b}");
                }
                // single-block strided forward
                let mut one = vec![0.0; numel];
                fwd.forward_strided(&arena, &layout, &mut one);
                assert_eq!(one, &want[..numel], "dct2 forward_strided ({n1},{n2}) {exec:?}");

                // inverse mirrors, fed the forward outputs
                let mut bwant = vec![0.0; numel * batch];
                inv.forward_batch(&want, &mut bwant, batch);
                let wviews: Vec<&[f64]> =
                    (0..batch).map(|b| &want[b * numel..(b + 1) * numel]).collect();
                let mut bgot = vec![0.0; numel * batch];
                inv.forward_batch_views(&wviews, &mut bgot);
                assert_eq!(bgot, bwant, "idct2 views ({n1},{n2}) batch={batch} {exec:?}");
                let mut warena = vec![f64::NAN; layout.required_len(batch)];
                for b in 0..batch {
                    for i1 in 0..n1 {
                        for i2 in 0..n2 {
                            warena[b * layout.batch_stride + i1 * s1 + i2 * s2] =
                                want[b * numel + i1 * n2 + i2];
                        }
                    }
                }
                let mut bsout = vec![f64::NAN; (batch - 1) * layout.batch_stride + numel];
                inv.forward_batch_strided(&warena, &layout, &mut bsout, batch);
                for b in 0..batch {
                    let blk = &bsout[b * layout.batch_stride..b * layout.batch_stride + numel];
                    assert_eq!(blk, &bwant[b * numel..(b + 1) * numel], "idct2 strided b={b}");
                }
                let mut bone = vec![0.0; numel];
                inv.forward_strided(&warena, &layout, &mut bone);
                assert_eq!(bone, &bwant[..numel], "idct2 forward_strided ({n1},{n2}) {exec:?}");
            }
        }
    }

    #[test]
    fn stage_times_are_populated() {
        let (n1, n2) = (64, 64);
        let x = vec![1.0; n1 * n2];
        let mut out = vec![0.0; n1 * n2];
        let t = Dct2::new(n1, n2).forward_timed(&x, &mut out);
        assert!(t.pre >= 0.0 && t.fft > 0.0 && t.post >= 0.0);
        assert!(t.total() > 0.0);
    }

    #[test]
    fn constant_input_concentrates_dc() {
        let (n1, n2) = (8, 8);
        let x = vec![1.0; n1 * n2];
        let mut y = vec![0.0; n1 * n2];
        Dct2::new(n1, n2).forward(&x, &mut y);
        assert!((y[0] - 4.0 * (n1 * n2) as f64).abs() < 1e-9);
        let rest: f64 = y[1..].iter().map(|v| v.abs()).sum();
        assert!(rest < 1e-8, "non-DC energy {rest}");
    }
}
