//! Fused 3D DCT via 3D RFFT — the paper's §III-D extension ("our method
//! in 2D transforms can be naturally extended to 3D transforms").
//!
//! Postprocess derivation (validated against the separable direct
//! oracle): with V the 3D FFT of the per-axis butterfly reorder,
//! m_i = (N_i - k_i) % N_i and twiddles a/b/c for axes 1/2/3,
//!
//!   X(k1,k2,k3) = 2 Re( a [  b c  V(k1,k2,k3)
//!                          + b conj(c) conj(V(m1,m2,k3))
//!                          + conj(b) conj(c) conj(V(m1,k2,k3))
//!                          + conj(b) c  V(k1,m2,k3) ] )
//!
//! i.e. each output reads 4 spectrum entries — matching the paper's "each
//! thread reads 4 elements from the input tensor" description of the 3D
//! postprocess (8 outputs per read-group in the paired form).

use std::sync::Arc;

use crate::fft::nd::rfft3_threads;
use crate::fft::{onesided_len, C64};
use crate::parallel::{par_chunks_mut, ExecPolicy};

use super::reorder::src_index_1d;
use super::twiddle::{twiddle, Twiddle};

/// Fused 3D DCT plan.
#[derive(Debug, Clone)]
pub struct Dct3d {
    pub n1: usize,
    pub n2: usize,
    pub n3: usize,
    tw1: Arc<Twiddle>,
    tw2: Arc<Twiddle>,
    tw3: Arc<Twiddle>,
    policy: ExecPolicy,
}

impl Dct3d {
    pub fn new(n1: usize, n2: usize, n3: usize) -> Dct3d {
        Self::with_policy(n1, n2, n3, ExecPolicy::Auto)
    }

    /// Plan with an explicit execution policy: all three stages
    /// parallelize over (i)-slabs of the tensor.
    pub fn with_policy(n1: usize, n2: usize, n3: usize, policy: ExecPolicy) -> Dct3d {
        Dct3d {
            n1,
            n2,
            n3,
            tw1: twiddle(n1),
            tw2: twiddle(n2),
            tw3: twiddle(n3),
            policy,
        }
    }

    /// Eq. (13) generalized: butterfly reorder along all three axes.
    /// Output slabs (fixed i) are independent, so they fan out.
    pub fn preprocess(&self, x: &[f64], out: &mut [f64]) {
        let (n1, n2, n3) = (self.n1, self.n2, self.n3);
        let lanes = self.policy.lanes(n1 * n2 * n3);
        par_chunks_mut(out, n2 * n3, lanes, |i, slab| {
            let si = src_index_1d(i, n1);
            for j in 0..n2 {
                let sj = src_index_1d(j, n2);
                let src_base = (si * n2 + sj) * n3;
                let dst = &mut slab[j * n3..(j + 1) * n3];
                for (k, d) in dst.iter_mut().enumerate() {
                    *d = x[src_base + src_index_1d(k, n3)];
                }
            }
        });
    }

    /// Full fused 3D DCT.
    pub fn forward(&self, x: &[f64], out: &mut [f64]) {
        let (n1, n2, n3) = (self.n1, self.n2, self.n3);
        assert_eq!(x.len(), n1 * n2 * n3);
        assert_eq!(out.len(), n1 * n2 * n3);
        let lanes = self.policy.lanes(n1 * n2 * n3);
        let mut pre = vec![0.0; n1 * n2 * n3];
        self.preprocess(x, &mut pre);
        let spec = rfft3_threads(&pre, n1, n2, n3, lanes);
        self.postprocess(&spec, out);
    }

    fn postprocess(&self, spec: &[C64], out: &mut [f64]) {
        let (n1, n2, n3) = (self.n1, self.n2, self.n3);
        let lanes = self.policy.lanes(n1 * n2 * n3);
        // each output slab (fixed k1) only reads the spectrum, so slabs
        // fan out directly
        par_chunks_mut(out, n2 * n3, lanes, |k1, slab| {
            self.postprocess_slab(spec, k1, slab);
        });
    }

    /// Postprocess one (k1)-slab: out(k1, k2, k3) for all k2, k3.
    fn postprocess_slab(&self, spec: &[C64], k1: usize, slab: &mut [f64]) {
        let (n1, n2, n3) = (self.n1, self.n2, self.n3);
        let h3 = onesided_len(n3);
        // onesided accessor with Hermitian reconstruction for k3 >= h3
        let read = |i: usize, j: usize, k: usize| -> C64 {
            if k < h3 {
                spec[(i * n2 + j) * h3 + k]
            } else {
                spec[(((n1 - i) % n1) * n2 + ((n2 - j) % n2)) * h3 + (n3 - k)].conj()
            }
        };
        let m1 = (n1 - k1) % n1;
        let a = self.tw1.at(k1);
        for k2 in 0..n2 {
            let m2 = (n2 - k2) % n2;
            let b = self.tw2.at(k2);
            for k3 in 0..n3 {
                let c = self.tw3.at(k3);
                let t = b * c * read(k1, k2, k3)
                    + b * c.conj() * read(m1, m2, k3).conj()
                    + b.conj() * c.conj() * read(m1, k2, k3).conj()
                    + b.conj() * c * read(k1, m2, k3);
                slab[k2 * n3 + k3] = 2.0 * (a * t).re;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::direct::dct3d_direct;
    use crate::util::prop::check_close;
    use crate::util::rng::Rng;

    #[test]
    fn matches_direct_oracle() {
        let mut rng = Rng::new(70);
        for &(n1, n2, n3) in &[
            (1usize, 1usize, 1usize),
            (2, 2, 2),
            (4, 4, 4),
            (3, 4, 5),
            (5, 2, 7),
            (8, 8, 8),
        ] {
            let x = rng.normal_vec(n1 * n2 * n3);
            let plan = Dct3d::new(n1, n2, n3);
            let mut out = vec![0.0; x.len()];
            plan.forward(&x, &mut out);
            check_close(&out, &dct3d_direct(&x, n1, n2, n3), 1e-9)
                .unwrap_or_else(|e| panic!("({n1},{n2},{n3}): {e}"));
        }
    }

    #[test]
    fn parallel_policy_is_bit_equal_to_serial() {
        use crate::parallel::ExecPolicy;
        let mut rng = Rng::new(72);
        for &(n1, n2, n3) in &[(4usize, 6usize, 8usize), (3, 5, 7), (8, 8, 8)] {
            let x = rng.normal_vec(n1 * n2 * n3);
            let mut ys = vec![0.0; x.len()];
            let mut yp = vec![0.0; x.len()];
            Dct3d::with_policy(n1, n2, n3, ExecPolicy::Serial).forward(&x, &mut ys);
            Dct3d::with_policy(n1, n2, n3, ExecPolicy::Threads(3)).forward(&x, &mut yp);
            assert_eq!(ys, yp, "({n1},{n2},{n3})");
        }
    }

    #[test]
    fn dc_term() {
        let mut rng = Rng::new(71);
        let (n1, n2, n3) = (4, 6, 8);
        let x = rng.normal_vec(n1 * n2 * n3);
        let plan = Dct3d::new(n1, n2, n3);
        let mut out = vec![0.0; x.len()];
        plan.forward(&x, &mut out);
        let sum: f64 = x.iter().sum();
        assert!((out[0] - 8.0 * sum).abs() < 1e-8);
    }
}
