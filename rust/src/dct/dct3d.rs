//! Fused 3D DCT / IDCT via 3D RFFT — the paper's §III-D extension ("our
//! method in 2D transforms can be naturally extended to 3D transforms").
//!
//! Forward postprocess derivation (validated against the separable
//! direct oracle): with V the 3D FFT of the per-axis butterfly reorder,
//! m_i = (N_i - k_i) % N_i and twiddles a/b/c for axes 1/2/3,
//!
//!   X(k1,k2,k3) = 2 Re( a [  b c  V(k1,k2,k3)
//!                          + b conj(c) conj(V(m1,m2,k3))
//!                          + conj(b) conj(c) conj(V(m1,k2,k3))
//!                          + conj(b) c  V(k1,m2,k3) ] )
//!
//! i.e. each output reads 4 spectrum entries — matching the paper's "each
//! thread reads 4 elements from the input tensor" description of the 3D
//! postprocess (8 outputs per read-group in the paired form).
//!
//! The inverse ([`Idct3d`]) is the corrected Eq. 15 lifted one dimension
//! up (the tensor product of the 1D spectrum-build operator along all
//! three axes): each onesided spectrum entry reads the 8 mirrored
//! coefficients (zero boundaries) and combines them with one triple
//! twiddle, then a normalized inverse 3D RFFT and the Eq. 16 unreorder
//! finish the pipeline.
//!
//! Both plans carry an [`ExecPolicy`] *and*, via `with_shards`, a
//! [`ShardPolicy`]: the dim-0 **i-slab** is the band-shard unit of every
//! stage (the inner [`Rfft3Plan`] re-bands across its dim-1/dim-2
//! transpose barrier), mirroring what the fused 2D plans do with row
//! bands. See `coordinator::shard` for how the service drives this.

use std::sync::Arc;

use crate::fft::{onesided_len, C64, Rfft3Plan};
use crate::parallel::{par_chunks_mut, ExecPolicy, ShardPolicy};

use super::reorder::{dst_index_1d, src_index_1d};
use super::twiddle::{twiddle, Twiddle};

/// Fused 3D DCT plan.
#[derive(Debug, Clone)]
pub struct Dct3d {
    /// Leading (slab) dimension.
    pub n1: usize,
    /// Middle dimension.
    pub n2: usize,
    /// Innermost dimension.
    pub n3: usize,
    rfft3: Rfft3Plan,
    tw1: Arc<Twiddle>,
    tw2: Arc<Twiddle>,
    tw3: Arc<Twiddle>,
    policy: ExecPolicy,
    shards: ShardPolicy,
    ws: crate::util::scratch::Workspace,
}

impl Dct3d {
    /// Plan with the default (`Auto`) execution policy.
    pub fn new(n1: usize, n2: usize, n3: usize) -> Dct3d {
        Self::with_policy(n1, n2, n3, ExecPolicy::Auto)
    }

    /// Plan with an explicit execution policy: all three stages
    /// parallelize over (i)-slabs of the tensor.
    pub fn with_policy(n1: usize, n2: usize, n3: usize, policy: ExecPolicy) -> Dct3d {
        let rfft3 = Rfft3Plan::with_policy(n1, n2, n3, policy);
        let mut ws = crate::util::scratch::Workspace::new();
        ws.add_f64(n1 * n2 * n3); // reordered input
        ws.add_c64(n1 * n2 * onesided_len(n3)); // onesided spectrum
        ws.merge(&rfft3.workspace());
        ws.prewarm();
        Dct3d {
            n1,
            n2,
            n3,
            rfft3,
            tw1: twiddle(n1),
            tw2: twiddle(n2),
            tw3: twiddle(n3),
            policy,
            shards: ShardPolicy::Auto,
            ws,
        }
    }

    /// Scratch manifest of one `forward` call, pre-sized at plan build.
    pub fn workspace(&self) -> &crate::util::scratch::Workspace {
        &self.ws
    }

    /// Prewarm the calling thread's scratch pool for this plan.
    pub fn prewarm(&self) {
        self.ws.prewarm();
    }

    /// Same plan with an explicit band-shard policy (see
    /// [`crate::dct::Dct2::with_shards`] for the 2D analogue): the
    /// preprocess, the inner 3D RFFT's n2-axis stage, and the
    /// postprocess all split into the dim-0 slab count
    /// [`ShardPolicy::bands`] dictates, while the RFFT's row batch
    /// bands over all `n1*n2` rows and its n1-axis stage re-bands
    /// across the transpose barrier.
    pub fn with_shards(mut self, shards: ShardPolicy) -> Dct3d {
        self.shards = shards;
        self.rfft3 = self.rfft3.with_shards(shards);
        self
    }

    /// Slab work items for a stage of `rows` dim-0 slabs under this
    /// plan's exec + shard policies.
    fn bands(&self, rows: usize) -> usize {
        self.shards.bands(rows, self.policy.lanes(self.n1 * self.n2 * self.n3))
    }

    /// Eq. (13) generalized: butterfly reorder along all three axes.
    /// Output slabs (fixed i) are independent, so they fan out.
    pub fn preprocess(&self, x: &[f64], out: &mut [f64]) {
        let (n1, n2, n3) = (self.n1, self.n2, self.n3);
        let slabs = self.bands(n1);
        par_chunks_mut(out, n2 * n3, slabs, |i, slab| {
            let si = src_index_1d(i, n1);
            for j in 0..n2 {
                let sj = src_index_1d(j, n2);
                let src_base = (si * n2 + sj) * n3;
                let dst = &mut slab[j * n3..(j + 1) * n3];
                for (k, d) in dst.iter_mut().enumerate() {
                    *d = x[src_base + src_index_1d(k, n3)];
                }
            }
        });
    }

    /// Full fused 3D DCT.
    pub fn forward(&self, x: &[f64], out: &mut [f64]) {
        let (n1, n2, n3) = (self.n1, self.n2, self.n3);
        assert_eq!(x.len(), n1 * n2 * n3);
        assert_eq!(out.len(), n1 * n2 * n3);
        let mut pre = crate::util::scratch::take_f64(n1 * n2 * n3);
        self.preprocess(x, &mut pre);
        let mut spec = crate::util::scratch::take_c64(n1 * n2 * onesided_len(n3));
        self.rfft3.forward(&pre, &mut spec);
        self.postprocess(&spec, out);
        crate::util::scratch::give_f64(pre);
        crate::util::scratch::give_c64(spec);
    }

    fn postprocess(&self, spec: &[C64], out: &mut [f64]) {
        let (n1, n2, n3) = (self.n1, self.n2, self.n3);
        let slabs = self.bands(n1);
        // each output slab (fixed k1) only reads the spectrum, so slabs
        // fan out directly
        par_chunks_mut(out, n2 * n3, slabs, |k1, slab| {
            self.postprocess_slab(spec, k1, slab);
        });
    }

    /// Postprocess one (k1)-slab: out(k1, k2, k3) for all k2, k3.
    fn postprocess_slab(&self, spec: &[C64], k1: usize, slab: &mut [f64]) {
        let (n1, n2, n3) = (self.n1, self.n2, self.n3);
        let h3 = onesided_len(n3);
        // onesided accessor with Hermitian reconstruction for k3 >= h3
        let read = |i: usize, j: usize, k: usize| -> C64 {
            if k < h3 {
                spec[(i * n2 + j) * h3 + k]
            } else {
                spec[(((n1 - i) % n1) * n2 + ((n2 - j) % n2)) * h3 + (n3 - k)].conj()
            }
        };
        let m1 = (n1 - k1) % n1;
        let a = self.tw1.at(k1);
        for k2 in 0..n2 {
            let m2 = (n2 - k2) % n2;
            let b = self.tw2.at(k2);
            for k3 in 0..n3 {
                let c = self.tw3.at(k3);
                let t = b * c * read(k1, k2, k3)
                    + b * c.conj() * read(m1, m2, k3).conj()
                    + b.conj() * c.conj() * read(m1, k2, k3).conj()
                    + b.conj() * c * read(k1, m2, k3);
                slab[k2 * n3 + k3] = 2.0 * (a * t).re;
            }
        }
    }
}

/// Fused 3D IDCT plan — exact inverse of [`Dct3d`] (the separable
/// `idct3d_direct` oracle), computed as onesided spectrum build ->
/// normalized inverse 3D RFFT -> per-axis unreorder.
#[derive(Debug, Clone)]
pub struct Idct3d {
    /// Leading (slab) dimension.
    pub n1: usize,
    /// Middle dimension.
    pub n2: usize,
    /// Innermost dimension.
    pub n3: usize,
    h3: usize,
    rfft3: Rfft3Plan,
    tw1: Arc<Twiddle>,
    tw2: Arc<Twiddle>,
    tw3: Arc<Twiddle>,
    policy: ExecPolicy,
    shards: ShardPolicy,
    ws: crate::util::scratch::Workspace,
}

impl Idct3d {
    /// Plan with the default (`Auto`) execution policy.
    pub fn new(n1: usize, n2: usize, n3: usize) -> Idct3d {
        Self::with_policy(n1, n2, n3, ExecPolicy::Auto)
    }

    /// Plan with an explicit execution policy.
    pub fn with_policy(n1: usize, n2: usize, n3: usize, policy: ExecPolicy) -> Idct3d {
        let h3 = onesided_len(n3);
        let rfft3 = Rfft3Plan::with_policy(n1, n2, n3, policy);
        let mut ws = crate::util::scratch::Workspace::new();
        ws.add_c64(n1 * n2 * h3); // onesided spectrum build
        ws.add_f64(n1 * n2 * n3); // inverse-RFFT output before unreorder
        ws.merge(&rfft3.workspace());
        ws.prewarm();
        Idct3d {
            n1,
            n2,
            n3,
            h3,
            rfft3,
            tw1: twiddle(n1),
            tw2: twiddle(n2),
            tw3: twiddle(n3),
            policy,
            shards: ShardPolicy::Auto,
            ws,
        }
    }

    /// Scratch manifest of one `forward` call, pre-sized at plan build.
    pub fn workspace(&self) -> &crate::util::scratch::Workspace {
        &self.ws
    }

    /// Prewarm the calling thread's scratch pool for this plan.
    pub fn prewarm(&self) {
        self.ws.prewarm();
    }

    /// Same plan with an explicit band-shard policy (see
    /// [`Dct3d::with_shards`]): spectrum-build slabs, the inner inverse
    /// 3D RFFT's banded stages, and the unreorder slabs all follow it.
    pub fn with_shards(mut self, shards: ShardPolicy) -> Idct3d {
        self.shards = shards;
        self.rfft3 = self.rfft3.with_shards(shards);
        self
    }

    /// Slab work items for a stage of `rows` dim-0 slabs under this
    /// plan's exec + shard policies.
    fn bands(&self, rows: usize) -> usize {
        self.shards.bands(rows, self.policy.lanes(self.n1 * self.n2 * self.n3))
    }

    /// Full fused 3D IDCT.
    pub fn forward(&self, x: &[f64], out: &mut [f64]) {
        let (n1, n2, n3) = (self.n1, self.n2, self.n3);
        assert_eq!(x.len(), n1 * n2 * n3);
        assert_eq!(out.len(), n1 * n2 * n3);
        let mut spec = crate::util::scratch::take_c64(n1 * n2 * self.h3);
        self.preprocess(x, &mut spec);
        let mut v = crate::util::scratch::take_f64(n1 * n2 * n3);
        self.rfft3.inverse(&spec, &mut v);
        // Eq. 16 unreorder along all three axes, banded over dim-0 slabs
        let slabs = self.bands(n1);
        par_chunks_mut(out, n2 * n3, slabs, |i, slab| {
            let si = dst_index_1d(i, n1);
            for j in 0..n2 {
                let sj = dst_index_1d(j, n2);
                let src = &v[(si * n2 + sj) * n3..(si * n2 + sj + 1) * n3];
                let dst = &mut slab[j * n3..(j + 1) * n3];
                for (k, d) in dst.iter_mut().enumerate() {
                    *d = src[dst_index_1d(k, n3)];
                }
            }
        });
        crate::util::scratch::give_c64(spec);
        crate::util::scratch::give_f64(v);
    }

    /// Onesided spectrum build (corrected Eq. 15 along all three axes):
    /// each entry reads the 8 mirrored coefficients with zero boundaries
    /// and writes one complex value
    ///
    ///   V = conj(a) conj(b) conj(c) / 8 *
    ///       ( (x000 - x110 - x101 - x011) + j (x111 - x100 - x010 - x001) )
    ///
    /// where the subscript marks which axes are mirrored (k_i -> N_i-k_i)
    /// and any term whose mirrored axis sits at k_i = 0 is zero.
    /// Spectrum slabs (fixed k1) only read input slabs k1 and N1-k1, so
    /// they are independent and fan out.
    pub fn preprocess(&self, x: &[f64], spec: &mut [C64]) {
        let slabs = self.bands(self.n1);
        par_chunks_mut(spec, self.n2 * self.h3, slabs, |k1, slab| {
            self.preprocess_slab(x, k1, slab);
        });
    }

    /// Build one onesided spectrum slab (the per-work-item kernel).
    fn preprocess_slab(&self, x: &[f64], k1: usize, slab: &mut [C64]) {
        let (n1, n2, n3, h3) = (self.n1, self.n2, self.n3, self.h3);
        debug_assert_eq!(slab.len(), n2 * h3);
        let xat = |i: usize, j: usize, k: usize| x[(i * n2 + j) * n3 + k];
        let ac = self.tw1.conj_at(k1);
        for k2 in 0..n2 {
            let bc = self.tw2.conj_at(k2);
            for k3 in 0..h3 {
                let cc = self.tw3.conj_at(k3);
                let x000 = xat(k1, k2, k3);
                let x100 = if k1 > 0 { xat(n1 - k1, k2, k3) } else { 0.0 };
                let x010 = if k2 > 0 { xat(k1, n2 - k2, k3) } else { 0.0 };
                let x001 = if k3 > 0 { xat(k1, k2, n3 - k3) } else { 0.0 };
                let x110 = if k1 > 0 && k2 > 0 {
                    xat(n1 - k1, n2 - k2, k3)
                } else {
                    0.0
                };
                let x101 = if k1 > 0 && k3 > 0 {
                    xat(n1 - k1, k2, n3 - k3)
                } else {
                    0.0
                };
                let x011 = if k2 > 0 && k3 > 0 {
                    xat(k1, n2 - k2, n3 - k3)
                } else {
                    0.0
                };
                let x111 = if k1 > 0 && k2 > 0 && k3 > 0 {
                    xat(n1 - k1, n2 - k2, n3 - k3)
                } else {
                    0.0
                };
                let t =
                    C64::new(x000 - x110 - x101 - x011, x111 - (x100 + x010 + x001));
                slab[k2 * h3 + k3] = (ac * bc * cc * t).scale(0.125);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::direct::{dct3d_direct, idct3d_direct};
    use crate::util::prop::check_close;
    use crate::util::rng::Rng;

    #[test]
    fn matches_direct_oracle() {
        let mut rng = Rng::new(70);
        for &(n1, n2, n3) in &[
            (1usize, 1usize, 1usize),
            (2, 2, 2),
            (4, 4, 4),
            (3, 4, 5),
            (5, 2, 7),
            (8, 8, 8),
        ] {
            let x = rng.normal_vec(n1 * n2 * n3);
            let plan = Dct3d::new(n1, n2, n3);
            let mut out = vec![0.0; x.len()];
            plan.forward(&x, &mut out);
            check_close(&out, &dct3d_direct(&x, n1, n2, n3), 1e-9)
                .unwrap_or_else(|e| panic!("({n1},{n2},{n3}): {e}"));
        }
    }

    #[test]
    fn idct3d_matches_direct_oracle() {
        let mut rng = Rng::new(73);
        for &(n1, n2, n3) in &[
            (1usize, 1usize, 1usize),
            (2, 2, 2),
            (3, 4, 5),
            (5, 2, 7),
            (8, 8, 8),
            (2, 3, 1),
        ] {
            let x = rng.normal_vec(n1 * n2 * n3);
            let plan = Idct3d::new(n1, n2, n3);
            let mut out = vec![0.0; x.len()];
            plan.forward(&x, &mut out);
            check_close(&out, &idct3d_direct(&x, n1, n2, n3), 1e-9)
                .unwrap_or_else(|e| panic!("({n1},{n2},{n3}): {e}"));
        }
    }

    #[test]
    fn idct3d_inverts_dct3d() {
        let mut rng = Rng::new(74);
        for &(n1, n2, n3) in &[(4usize, 6usize, 8usize), (3, 5, 7), (8, 8, 8), (1, 9, 4)] {
            let x = rng.normal_vec(n1 * n2 * n3);
            let mut y = vec![0.0; x.len()];
            Dct3d::new(n1, n2, n3).forward(&x, &mut y);
            let mut back = vec![0.0; x.len()];
            Idct3d::new(n1, n2, n3).forward(&y, &mut back);
            check_close(&back, &x, 1e-9).unwrap_or_else(|e| panic!("({n1},{n2},{n3}): {e}"));
        }
    }

    #[test]
    fn parallel_policy_is_bit_equal_to_serial() {
        use crate::parallel::ExecPolicy;
        let mut rng = Rng::new(72);
        for &(n1, n2, n3) in &[(4usize, 6usize, 8usize), (3, 5, 7), (8, 8, 8)] {
            let x = rng.normal_vec(n1 * n2 * n3);
            let mut ys = vec![0.0; x.len()];
            let mut yp = vec![0.0; x.len()];
            Dct3d::with_policy(n1, n2, n3, ExecPolicy::Serial).forward(&x, &mut ys);
            Dct3d::with_policy(n1, n2, n3, ExecPolicy::Threads(3)).forward(&x, &mut yp);
            assert_eq!(ys, yp, "dct3d ({n1},{n2},{n3})");
            let mut bs = vec![0.0; x.len()];
            let mut bp = vec![0.0; x.len()];
            Idct3d::with_policy(n1, n2, n3, ExecPolicy::Serial).forward(&ys, &mut bs);
            Idct3d::with_policy(n1, n2, n3, ExecPolicy::Threads(3)).forward(&yp, &mut bp);
            assert_eq!(bs, bp, "idct3d ({n1},{n2},{n3})");
        }
    }

    #[test]
    fn sharded_plan_is_bit_equal_to_serial() {
        let mut rng = Rng::new(75);
        for &(n1, n2, n3) in &[(9usize, 6usize, 10usize), (5, 3, 7), (8, 8, 8)] {
            let x = rng.normal_vec(n1 * n2 * n3);
            let mut ys = vec![0.0; x.len()];
            Dct3d::with_policy(n1, n2, n3, ExecPolicy::Serial).forward(&x, &mut ys);
            for shards in [1usize, 2, 3, 7] {
                let mut yp = vec![0.0; x.len()];
                Dct3d::with_policy(n1, n2, n3, ExecPolicy::Serial)
                    .with_shards(ShardPolicy::MaxShards(shards))
                    .forward(&x, &mut yp);
                assert_eq!(ys, yp, "dct3d ({n1},{n2},{n3}) shards={shards}");
            }
        }
    }

    #[test]
    fn dc_term() {
        let mut rng = Rng::new(71);
        let (n1, n2, n3) = (4, 6, 8);
        let x = rng.normal_vec(n1 * n2 * n3);
        let plan = Dct3d::new(n1, n2, n3);
        let mut out = vec![0.0; x.len()];
        plan.forward(&x, &mut out);
        let sum: f64 = x.iter().sum();
        assert!((out[0] - 8.0 * sum).abs() < 1e-8);
    }
}
