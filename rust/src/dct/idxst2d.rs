//! Fused 2D DREAMPlace transforms IDCT_IDXST / IDXST_IDCT (paper §V-B).
//!
//! Both fold into the SAME fused three-stage 2D IDCT (see DESIGN.md):
//!   IDCT_IDXST(x) = diag((-1)^{k1}) . IDCT2D(S_rows x)
//!   IDXST_IDCT(x) = IDCT2D(S_cols x) . diag((-1)^{k2})
//! where S is the zero-boundary reverse shift. The shift and sign flips
//! are fused into the preprocess read / postprocess write loops, so the
//! memory-stage count stays at 3 — this is why the paper's IDCT_IDXST
//! times match its plain IDCT times.

use super::dct2d::{Idct2, StageTimes};

/// Which DREAMPlace combination a plan computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Combo {
    /// 1D IDCT along rows, then 1D IDXST along columns
    IdctIdxst,
    /// 1D IDXST along rows, then 1D IDCT along columns
    IdxstIdct,
}

/// Fused IDCT_IDXST / IDXST_IDCT plan.
#[derive(Debug, Clone)]
pub struct IdxstCombo {
    /// Number of rows.
    pub n1: usize,
    /// Number of columns.
    pub n2: usize,
    /// Which of the two DREAMPlace combinations this plan computes.
    pub combo: Combo,
    idct: Idct2,
}

impl IdxstCombo {
    /// Plan an `n1 x n2` fused combo transform with the auto policy.
    pub fn new(n1: usize, n2: usize, combo: Combo) -> IdxstCombo {
        IdxstCombo { n1, n2, combo, idct: Idct2::new(n1, n2) }
    }

    /// Plan whose inner fused IDCT carries an explicit execution policy.
    pub fn with_policy(
        n1: usize,
        n2: usize,
        combo: Combo,
        policy: crate::parallel::ExecPolicy,
    ) -> IdxstCombo {
        IdxstCombo { n1, n2, combo, idct: Idct2::with_policy(n1, n2, policy) }
    }

    /// Same plan with an explicit band-shard policy on the inner fused
    /// IDCT (see [`Idct2::with_shards`]); the shift/sign folds are cheap
    /// per-row loops and stay inline.
    pub fn with_shards(mut self, shards: crate::parallel::ShardPolicy) -> IdxstCombo {
        self.idct = self.idct.with_shards(shards);
        self
    }

    /// Transform `x` into `out` (both `n1 * n2` long).
    pub fn forward(&self, x: &[f64], out: &mut [f64]) {
        self.forward_timed(x, out);
    }

    /// Transform with the per-stage wall-clock breakdown.
    pub fn forward_timed(&self, x: &[f64], out: &mut [f64]) -> StageTimes {
        let (n1, n2) = (self.n1, self.n2);
        assert_eq!(x.len(), n1 * n2);
        assert_eq!(out.len(), n1 * n2);
        // shift fold (reads are remapped; one extra buffer keeps the
        // Idct2 API unchanged -- the artifact path truly fuses it)
        let mut shifted = vec![0.0; n1 * n2];
        match self.combo {
            Combo::IdctIdxst => {
                // S_rows: row 0 -> zeros, row k -> x[n1-k]
                for k in 1..n1 {
                    shifted[k * n2..(k + 1) * n2]
                        .copy_from_slice(&x[(n1 - k) * n2..(n1 - k + 1) * n2]);
                }
            }
            Combo::IdxstIdct => {
                // S_cols: col 0 -> zeros, col k -> x[:, n2-k]
                for r in 0..n1 {
                    for k in 1..n2 {
                        shifted[r * n2 + k] = x[r * n2 + (n2 - k)];
                    }
                }
            }
        }
        let times = self.idct.forward_timed(&shifted, out);
        // sign fold
        match self.combo {
            Combo::IdctIdxst => {
                for k1 in (1..n1).step_by(2) {
                    for v in &mut out[k1 * n2..(k1 + 1) * n2] {
                        *v = -*v;
                    }
                }
            }
            Combo::IdxstIdct => {
                for r in 0..n1 {
                    for k2 in (1..n2).step_by(2) {
                        out[r * n2 + k2] = -out[r * n2 + k2];
                    }
                }
            }
        }
        times
    }

    /// Batched forward: `batch` row-major `n1 x n2` inputs packed
    /// contiguously in `xs`, outputs packed the same way. The
    /// zero-boundary shift and sign folds sweep each block around one
    /// inner [`Idct2::forward_batch`] call, so the whole batch shares
    /// the stage-fused path; bit-identical to per-item
    /// [`IdxstCombo::forward`]. The zero row/column each shifted block
    /// carries is written explicitly — pooled scratch buffers are not
    /// re-zeroed.
    pub fn forward_batch(&self, xs: &[f64], out: &mut [f64], batch: usize) {
        let (n1, n2) = (self.n1, self.n2);
        let numel = n1 * n2;
        assert_eq!(xs.len(), numel * batch);
        assert_eq!(out.len(), numel * batch);
        if batch == 0 {
            return;
        }
        let mut shifted = crate::util::scratch::take_f64(numel * batch);
        for (xb, sb) in xs.chunks_exact(numel).zip(shifted.chunks_exact_mut(numel)) {
            match self.combo {
                Combo::IdctIdxst => {
                    // S_rows: row 0 -> zeros, row k -> x[n1-k]
                    sb[..n2].fill(0.0);
                    for k in 1..n1 {
                        sb[k * n2..(k + 1) * n2]
                            .copy_from_slice(&xb[(n1 - k) * n2..(n1 - k + 1) * n2]);
                    }
                }
                Combo::IdxstIdct => {
                    // S_cols: col 0 -> zeros, col k -> x[:, n2-k]
                    for r in 0..n1 {
                        sb[r * n2] = 0.0;
                        for k in 1..n2 {
                            sb[r * n2 + k] = xb[r * n2 + (n2 - k)];
                        }
                    }
                }
            }
        }
        self.idct.forward_batch(&shifted, out, batch);
        for ob in out.chunks_exact_mut(numel) {
            match self.combo {
                Combo::IdctIdxst => {
                    for k1 in (1..n1).step_by(2) {
                        for v in &mut ob[k1 * n2..(k1 + 1) * n2] {
                            *v = -*v;
                        }
                    }
                }
                Combo::IdxstIdct => {
                    for r in 0..n1 {
                        for k2 in (1..n2).step_by(2) {
                            ob[r * n2 + k2] = -ob[r * n2 + k2];
                        }
                    }
                }
            }
        }
        crate::util::scratch::give_f64(shifted);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::direct::{idct_idxst_direct, idxst_idct_direct};
    use crate::util::prop::{check_close, forall, shapes};

    #[test]
    fn idct_idxst_matches_direct() {
        forall(25, shapes(1, 20), |rng, &(n1, n2)| {
            let x = rng.normal_vec(n1 * n2);
            let plan = IdxstCombo::new(n1, n2, Combo::IdctIdxst);
            let mut out = vec![0.0; n1 * n2];
            plan.forward(&x, &mut out);
            check_close(&out, &idct_idxst_direct(&x, n1, n2), 1e-9)
        });
    }

    #[test]
    fn idxst_idct_matches_direct() {
        forall(25, shapes(1, 20), |rng, &(n1, n2)| {
            let x = rng.normal_vec(n1 * n2);
            let plan = IdxstCombo::new(n1, n2, Combo::IdxstIdct);
            let mut out = vec![0.0; n1 * n2];
            plan.forward(&x, &mut out);
            check_close(&out, &idxst_idct_direct(&x, n1, n2), 1e-9)
        });
    }

    #[test]
    fn forward_batch_matches_solo_bitwise() {
        let mut rng = crate::util::rng::Rng::new(56);
        for combo in [Combo::IdctIdxst, Combo::IdxstIdct] {
            for &(n1, n2) in &[(5usize, 7usize), (8, 8), (1, 6)] {
                let numel = n1 * n2;
                let batch = 3;
                let xs = rng.normal_vec(numel * batch);
                let plan = IdxstCombo::new(n1, n2, combo);
                let mut want = vec![0.0; numel * batch];
                for (b, w) in want.chunks_mut(numel).enumerate() {
                    plan.forward(&xs[b * numel..(b + 1) * numel], w);
                }
                let mut got = vec![0.0; numel * batch];
                plan.forward_batch(&xs, &mut got, batch);
                assert_eq!(got, want, "{combo:?} ({n1},{n2})");
            }
        }
    }

    #[test]
    fn transpose_relation() {
        // IDCT_IDXST(x) == IDXST_IDCT(x^T)^T
        let mut rng = crate::util::rng::Rng::new(55);
        let (n1, n2) = (6, 9);
        let x = rng.normal_vec(n1 * n2);
        let mut xt = vec![0.0; n1 * n2];
        for r in 0..n1 {
            for c in 0..n2 {
                xt[c * n1 + r] = x[r * n2 + c];
            }
        }
        let mut a = vec![0.0; n1 * n2];
        IdxstCombo::new(n1, n2, Combo::IdctIdxst).forward(&x, &mut a);
        let mut bt = vec![0.0; n1 * n2];
        IdxstCombo::new(n2, n1, Combo::IdxstIdct).forward(&xt, &mut bt);
        let mut b = vec![0.0; n1 * n2];
        for r in 0..n1 {
            for c in 0..n2 {
                b[r * n2 + c] = bt[c * n1 + r];
            }
        }
        check_close(&a, &b, 1e-10).unwrap();
    }
}
