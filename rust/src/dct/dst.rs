//! Discrete sine transforms via the same fused paradigm — the paper's
//! §III-D extensibility claim ("as long as the Fourier-related transforms
//! can be computed via FFT with preprocessing and postprocessing, they
//! can be accelerated using our paradigm").
//!
//! DST-II folds onto the fused DCT-II core with O(N) pre/post work
//! (validated against the direct sine oracle):
//!
//!   DST2(x)_k  = DCT2( (-1)^n x_n )_{N-1-k}
//!   IDST(y)    = (-1)^n ⊙ IDCT( reverse(y) )        (exact inverse)
//!
//! and the 2D versions apply the folds on both axes around `Dct2`/`Idct2`,
//! keeping the 3-stage memory profile (the folds fuse into the butterfly
//! reorder's index maps; here they are separate O(N^2) passes for
//! clarity, still a small constant against the FFT).

use super::dct2d::{Dct2, Idct2};
use super::dct1d::{Algo1d, Dct1d, Idct1d};

/// Direct O(N^2) DST-II oracle: y_k = 2 sum_n x_n sin(pi(k+1)(2n+1)/2N).
pub fn dst1d_direct(x: &[f64]) -> Vec<f64> {
    let n = x.len();
    let mut out = vec![0.0; n];
    for (k, o) in out.iter_mut().enumerate() {
        let mut acc = 0.0;
        for (m, &v) in x.iter().enumerate() {
            acc += v
                * (std::f64::consts::PI * (k + 1) as f64 * (2 * m + 1) as f64
                    / (2.0 * n as f64))
                    .sin();
        }
        *o = 2.0 * acc;
    }
    out
}

/// Direct separable 2D DST-II oracle.
pub fn dst2d_direct(x: &[f64], n1: usize, n2: usize) -> Vec<f64> {
    let mut rows = vec![0.0; n1 * n2];
    for r in 0..n1 {
        rows[r * n2..(r + 1) * n2].copy_from_slice(&dst1d_direct(&x[r * n2..(r + 1) * n2]));
    }
    let mut out = vec![0.0; n1 * n2];
    let mut col = vec![0.0; n1];
    for c in 0..n2 {
        for r in 0..n1 {
            col[r] = rows[r * n2 + c];
        }
        let y = dst1d_direct(&col);
        for r in 0..n1 {
            out[r * n2 + c] = y[r];
        }
    }
    out
}

/// Fused 1D DST-II plan (folds around the N-point DCT).
#[derive(Debug, Clone)]
pub struct Dst1d {
    dct: Dct1d,
}

impl Dst1d {
    /// Plan a length-`n` DST-II.
    pub fn new(n: usize) -> Dst1d {
        Dst1d { dct: Dct1d::new(n, Algo1d::NPoint) }
    }

    /// Transform `x` into `out` (both length `n`).
    pub fn forward(&self, x: &[f64], out: &mut [f64]) {
        let n = self.dct.n;
        let mut folded = crate::util::scratch::take_f64(n);
        for (i, (f, &v)) in folded.iter_mut().zip(x).enumerate() {
            *f = if i % 2 == 0 { v } else { -v };
        }
        let mut y = crate::util::scratch::take_f64(n);
        self.dct.forward(&folded, &mut y);
        for k in 0..n {
            out[k] = y[n - 1 - k];
        }
        crate::util::scratch::give_f64(folded);
        crate::util::scratch::give_f64(y);
    }
}

/// Fused 1D inverse DST plan (exact inverse of [`Dst1d`]).
#[derive(Debug, Clone)]
pub struct Idst1d {
    idct: Idct1d,
}

impl Idst1d {
    /// Plan a length-`n` inverse DST.
    pub fn new(n: usize) -> Idst1d {
        Idst1d { idct: Idct1d::new(n) }
    }

    /// Inverse-transform `x` into `out` (both length `n`).
    pub fn forward(&self, x: &[f64], out: &mut [f64]) {
        let n = x.len();
        let mut rev = crate::util::scratch::take_f64(n);
        for k in 0..n {
            rev[k] = x[n - 1 - k];
        }
        self.idct.forward(&rev, out);
        for (i, o) in out.iter_mut().enumerate() {
            if i % 2 == 1 {
                *o = -*o;
            }
        }
        crate::util::scratch::give_f64(rev);
    }
}

/// Fused 2D DST-II plan (folds on both axes around the fused 2D DCT).
#[derive(Debug, Clone)]
pub struct Dst2 {
    /// Number of rows.
    pub n1: usize,
    /// Number of columns.
    pub n2: usize,
    dct: Dct2,
}

impl Dst2 {
    /// Plan an `n1 x n2` 2D DST-II with the auto execution policy.
    pub fn new(n1: usize, n2: usize) -> Dst2 {
        Dst2 { n1, n2, dct: Dct2::new(n1, n2) }
    }

    /// Plan whose inner fused DCT carries an explicit execution policy.
    pub fn with_policy(n1: usize, n2: usize, policy: crate::parallel::ExecPolicy) -> Dst2 {
        Dst2 { n1, n2, dct: Dct2::with_policy(n1, n2, policy) }
    }

    /// Same plan with an explicit band-shard policy on the inner fused
    /// DCT (see [`Dct2::with_shards`]).
    pub fn with_shards(mut self, shards: crate::parallel::ShardPolicy) -> Dst2 {
        self.dct = self.dct.with_shards(shards);
        self
    }

    /// Transform `x` into `out` (both `n1 * n2` long).
    pub fn forward(&self, x: &[f64], out: &mut [f64]) {
        let (n1, n2) = (self.n1, self.n2);
        assert_eq!(x.len(), n1 * n2);
        assert_eq!(out.len(), n1 * n2);
        // input fold: checkerboard sign (-1)^{n1+n2}
        let mut folded = crate::util::scratch::take_f64(n1 * n2);
        for r in 0..n1 {
            for c in 0..n2 {
                let v = x[r * n2 + c];
                folded[r * n2 + c] = if (r + c) % 2 == 0 { v } else { -v };
            }
        }
        let mut y = crate::util::scratch::take_f64(n1 * n2);
        self.dct.forward(&folded, &mut y);
        // output fold: reverse both axes
        for r in 0..n1 {
            for c in 0..n2 {
                out[r * n2 + c] = y[(n1 - 1 - r) * n2 + (n2 - 1 - c)];
            }
        }
        crate::util::scratch::give_f64(folded);
        crate::util::scratch::give_f64(y);
    }

    /// Batched forward: `batch` row-major `n1 x n2` inputs packed
    /// contiguously in `xs`, outputs packed the same way. The sign and
    /// reverse folds sweep each block around one inner
    /// [`Dct2::forward_batch`] call, so the whole batch shares the
    /// stage-fused path; bit-identical to per-item [`Dst2::forward`].
    pub fn forward_batch(&self, xs: &[f64], out: &mut [f64], batch: usize) {
        let (n1, n2) = (self.n1, self.n2);
        let numel = n1 * n2;
        assert_eq!(xs.len(), numel * batch);
        assert_eq!(out.len(), numel * batch);
        if batch == 0 {
            return;
        }
        let mut folded = crate::util::scratch::take_f64(numel * batch);
        for (xb, fb) in xs.chunks_exact(numel).zip(folded.chunks_exact_mut(numel)) {
            for r in 0..n1 {
                for c in 0..n2 {
                    let v = xb[r * n2 + c];
                    fb[r * n2 + c] = if (r + c) % 2 == 0 { v } else { -v };
                }
            }
        }
        let mut y = crate::util::scratch::take_f64(numel * batch);
        self.dct.forward_batch(&folded, &mut y, batch);
        for (yb, ob) in y.chunks_exact(numel).zip(out.chunks_exact_mut(numel)) {
            for r in 0..n1 {
                for c in 0..n2 {
                    ob[r * n2 + c] = yb[(n1 - 1 - r) * n2 + (n2 - 1 - c)];
                }
            }
        }
        crate::util::scratch::give_f64(folded);
        crate::util::scratch::give_f64(y);
    }
}

/// Fused 2D inverse DST plan.
#[derive(Debug, Clone)]
pub struct Idst2 {
    /// Number of rows.
    pub n1: usize,
    /// Number of columns.
    pub n2: usize,
    idct: Idct2,
}

impl Idst2 {
    /// Plan an `n1 x n2` 2D inverse DST with the auto execution policy.
    pub fn new(n1: usize, n2: usize) -> Idst2 {
        Idst2 { n1, n2, idct: Idct2::new(n1, n2) }
    }

    /// Plan whose inner fused IDCT carries an explicit execution policy.
    pub fn with_policy(n1: usize, n2: usize, policy: crate::parallel::ExecPolicy) -> Idst2 {
        Idst2 { n1, n2, idct: Idct2::with_policy(n1, n2, policy) }
    }

    /// Same plan with an explicit band-shard policy on the inner fused
    /// IDCT (see [`Idct2::with_shards`]).
    pub fn with_shards(mut self, shards: crate::parallel::ShardPolicy) -> Idst2 {
        self.idct = self.idct.with_shards(shards);
        self
    }

    /// Inverse-transform `x` into `out` (both `n1 * n2` long).
    pub fn forward(&self, x: &[f64], out: &mut [f64]) {
        let (n1, n2) = (self.n1, self.n2);
        assert_eq!(x.len(), n1 * n2);
        assert_eq!(out.len(), n1 * n2);
        let mut rev = crate::util::scratch::take_f64(n1 * n2);
        for r in 0..n1 {
            for c in 0..n2 {
                rev[r * n2 + c] = x[(n1 - 1 - r) * n2 + (n2 - 1 - c)];
            }
        }
        self.idct.forward(&rev, out);
        for r in 0..n1 {
            for c in 0..n2 {
                if (r + c) % 2 == 1 {
                    out[r * n2 + c] = -out[r * n2 + c];
                }
            }
        }
        crate::util::scratch::give_f64(rev);
    }

    /// Batched forward: the reverse fold and checkerboard negation sweep
    /// each packed block around one inner [`Idct2::forward_batch`] call;
    /// bit-identical to per-item [`Idst2::forward`].
    pub fn forward_batch(&self, xs: &[f64], out: &mut [f64], batch: usize) {
        let (n1, n2) = (self.n1, self.n2);
        let numel = n1 * n2;
        assert_eq!(xs.len(), numel * batch);
        assert_eq!(out.len(), numel * batch);
        if batch == 0 {
            return;
        }
        let mut rev = crate::util::scratch::take_f64(numel * batch);
        for (xb, rb) in xs.chunks_exact(numel).zip(rev.chunks_exact_mut(numel)) {
            for r in 0..n1 {
                for c in 0..n2 {
                    rb[r * n2 + c] = xb[(n1 - 1 - r) * n2 + (n2 - 1 - c)];
                }
            }
        }
        self.idct.forward_batch(&rev, out, batch);
        for ob in out.chunks_exact_mut(numel) {
            for r in 0..n1 {
                for c in 0..n2 {
                    if (r + c) % 2 == 1 {
                        ob[r * n2 + c] = -ob[r * n2 + c];
                    }
                }
            }
        }
        crate::util::scratch::give_f64(rev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check_close, forall, shapes, sizes};

    #[test]
    fn dst1d_matches_direct() {
        forall(40, sizes(1, 80), |rng, &n| {
            let x = rng.normal_vec(n);
            let mut out = vec![0.0; n];
            Dst1d::new(n).forward(&x, &mut out);
            check_close(&out, &dst1d_direct(&x), 1e-9)
        });
    }

    #[test]
    fn idst1d_inverts() {
        forall(40, sizes(1, 80), |rng, &n| {
            let x = rng.normal_vec(n);
            let mut y = vec![0.0; n];
            Dst1d::new(n).forward(&x, &mut y);
            let mut back = vec![0.0; n];
            Idst1d::new(n).forward(&y, &mut back);
            check_close(&back, &x, 1e-9)
        });
    }

    #[test]
    fn dst2d_matches_direct() {
        forall(25, shapes(1, 20), |rng, &(n1, n2)| {
            let x = rng.normal_vec(n1 * n2);
            let mut out = vec![0.0; n1 * n2];
            Dst2::new(n1, n2).forward(&x, &mut out);
            check_close(&out, &dst2d_direct(&x, n1, n2), 1e-9)
        });
    }

    #[test]
    fn idst2d_inverts() {
        forall(25, shapes(1, 24), |rng, &(n1, n2)| {
            let x = rng.normal_vec(n1 * n2);
            let mut y = vec![0.0; n1 * n2];
            Dst2::new(n1, n2).forward(&x, &mut y);
            let mut back = vec![0.0; n1 * n2];
            Idst2::new(n1, n2).forward(&y, &mut back);
            check_close(&back, &x, 1e-9)
        });
    }

    #[test]
    fn dst2_forward_batch_is_bit_identical_to_solo() {
        forall(10, shapes(1, 16), |rng, &(n1, n2)| {
            let numel = n1 * n2;
            for batch in [1usize, 2, 5] {
                let xs = rng.normal_vec(numel * batch);
                let plan = Dst2::new(n1, n2);
                let mut got = vec![0.0; numel * batch];
                plan.forward_batch(&xs, &mut got, batch);
                for b in 0..batch {
                    let mut want = vec![0.0; numel];
                    plan.forward(&xs[b * numel..(b + 1) * numel], &mut want);
                    assert_eq!(got[b * numel..(b + 1) * numel], want[..], "{n1}x{n2} item {b}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn idst2_forward_batch_is_bit_identical_to_solo() {
        forall(10, shapes(1, 16), |rng, &(n1, n2)| {
            let numel = n1 * n2;
            for batch in [1usize, 3, 4] {
                let xs = rng.normal_vec(numel * batch);
                let plan = Idst2::new(n1, n2);
                let mut got = vec![0.0; numel * batch];
                plan.forward_batch(&xs, &mut got, batch);
                for b in 0..batch {
                    let mut want = vec![0.0; numel];
                    plan.forward(&xs[b * numel..(b + 1) * numel], &mut want);
                    assert_eq!(got[b * numel..(b + 1) * numel], want[..], "{n1}x{n2} item {b}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn dst_dc_free_for_constant_input() {
        // a constant signal has no energy in the *even* sine modes only;
        // check the known closed form for k = N-1 (the highest mode)
        let n = 8;
        let x = vec![1.0; n];
        let mut y = vec![0.0; n];
        Dst1d::new(n).forward(&x, &mut y);
        let direct = dst1d_direct(&x);
        check_close(&y, &direct, 1e-10).unwrap();
    }
}
