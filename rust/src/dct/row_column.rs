//! Row-column 2D transforms — the paper's strengthened baseline.
//!
//! "We implement and optimize the row-column method based on our 1D
//! DCT/IDCT implementation, which is better than the public
//! implementations we can find." Each 1D pass is the best (N-point)
//! algorithm; the method still pays the 8 full-matrix memory stages of
//! Fig. 5 (2 x (pre + FFT + post) + 2 transposes), which is what the
//! fused path eliminates.

use super::dct1d::{Algo1d, Dct1d, Idct1d, Idxst1d};
use crate::parallel::{par_chunks_mut, transpose_into, ExecPolicy};
use crate::util::scratch;

/// Transpose a row-major (n1 x n2) matrix into `out` (n2 x n1).
/// (Serial entry point; the plan's policy drives the parallel one.)
pub fn transpose(x: &[f64], out: &mut [f64], n1: usize, n2: usize) {
    transpose_into(x, out, n1, n2, 1);
}

/// One of the supported per-axis 1D transforms.
#[derive(Debug, Clone)]
enum Axis1d {
    Dct(Dct1d),
    Idct(Idct1d),
    Idxst(Idxst1d),
}

impl Axis1d {
    fn n(&self) -> usize {
        match self {
            Axis1d::Dct(p) => p.n,
            Axis1d::Idct(p) => p.n,
            Axis1d::Idxst(p) => p.len(),
        }
    }

    fn forward(&self, x: &[f64], out: &mut [f64]) {
        match self {
            Axis1d::Dct(p) => p.forward(x, out),
            Axis1d::Idct(p) => p.forward(x, out),
            Axis1d::Idxst(p) => p.forward(x, out),
        }
    }
}

/// Generic row-column plan: apply `row` along rows, transpose, apply
/// `col` along (what are now) rows, transpose back.
#[derive(Debug, Clone)]
pub struct RowColumn {
    /// Number of rows.
    pub n1: usize,
    /// Number of columns.
    pub n2: usize,
    row: Axis1d,
    col: Axis1d,
    policy: ExecPolicy,
}

impl RowColumn {
    /// Row-column 2D DCT.
    pub fn dct2(n1: usize, n2: usize) -> RowColumn {
        RowColumn {
            n1,
            n2,
            row: Axis1d::Dct(Dct1d::new(n2, Algo1d::NPoint)),
            col: Axis1d::Dct(Dct1d::new(n1, Algo1d::NPoint)),
            policy: ExecPolicy::Auto,
        }
    }

    /// Row-column 2D IDCT.
    pub fn idct2(n1: usize, n2: usize) -> RowColumn {
        RowColumn {
            n1,
            n2,
            row: Axis1d::Idct(Idct1d::new(n2)),
            col: Axis1d::Idct(Idct1d::new(n1)),
            policy: ExecPolicy::Auto,
        }
    }

    /// Row-column IDCT_IDXST (1D IDCT rows, 1D IDXST cols).
    pub fn idct_idxst(n1: usize, n2: usize) -> RowColumn {
        RowColumn {
            n1,
            n2,
            row: Axis1d::Idct(Idct1d::new(n2)),
            col: Axis1d::Idxst(Idxst1d::new(n1)),
            policy: ExecPolicy::Auto,
        }
    }

    /// Row-column IDXST_IDCT (1D IDXST rows, 1D IDCT cols).
    pub fn idxst_idct(n1: usize, n2: usize) -> RowColumn {
        RowColumn {
            n1,
            n2,
            row: Axis1d::Idxst(Idxst1d::new(n2)),
            col: Axis1d::Idct(Idct1d::new(n1)),
            policy: ExecPolicy::Auto,
        }
    }

    /// Override the execution policy (builder style). The baseline gets
    /// the same parallel substrate as the fused path so the paper's
    /// comparison stays apples-to-apples at every thread count.
    pub fn with_policy(mut self, policy: ExecPolicy) -> RowColumn {
        self.policy = policy;
        self
    }

    /// Execute the row-column pipeline (8 full-matrix memory stages).
    pub fn forward(&self, x: &[f64], out: &mut [f64]) {
        let (n1, n2) = (self.n1, self.n2);
        assert_eq!(x.len(), n1 * n2);
        assert_eq!(out.len(), n1 * n2);
        debug_assert_eq!(self.row.n(), n2);
        debug_assert_eq!(self.col.n(), n1);
        let lanes = self.policy.lanes(n1 * n2);
        // pass 1: 1D transform along each row (rows fan out)
        let mut a = scratch::take_f64(n1 * n2);
        let row = &self.row;
        par_chunks_mut(&mut a, n2, lanes, |r, arow| {
            row.forward(&x[r * n2..(r + 1) * n2], arow);
        });
        // transpose (parallel tiled)
        let mut at = scratch::take_f64(n1 * n2);
        transpose_into(&a, &mut at, n1, n2, lanes);
        // pass 2: 1D transform along each (former) column
        let mut b = scratch::take_f64(n1 * n2);
        let col = &self.col;
        par_chunks_mut(&mut b, n1, lanes, |r, brow| {
            col.forward(&at[r * n1..(r + 1) * n1], brow);
        });
        // transpose back
        transpose_into(&b, out, n2, n1, lanes);
        scratch::give_f64(a);
        scratch::give_f64(at);
        scratch::give_f64(b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::dct2d::{Dct2, Idct2};
    use crate::dct::direct::{
        dct2d_direct, idct2d_direct, idct_idxst_direct, idxst_idct_direct,
    };
    use crate::util::prop::{check_close, forall, shapes};

    #[test]
    fn transpose_involution() {
        let mut rng = crate::util::rng::Rng::new(60);
        let (n1, n2) = (13, 37);
        let x = rng.normal_vec(n1 * n2);
        let mut t = vec![0.0; n1 * n2];
        let mut back = vec![0.0; n1 * n2];
        transpose(&x, &mut t, n1, n2);
        transpose(&t, &mut back, n2, n1);
        assert_eq!(back, x);
    }

    #[test]
    fn rc_dct_matches_direct_and_fused() {
        forall(25, shapes(1, 20), |rng, &(n1, n2)| {
            let x = rng.normal_vec(n1 * n2);
            let mut rc = vec![0.0; n1 * n2];
            RowColumn::dct2(n1, n2).forward(&x, &mut rc);
            check_close(&rc, &dct2d_direct(&x, n1, n2), 1e-9)?;
            let mut fused = vec![0.0; n1 * n2];
            Dct2::new(n1, n2).forward(&x, &mut fused);
            check_close(&rc, &fused, 1e-9)
        });
    }

    #[test]
    fn rc_idct_matches_direct_and_fused() {
        forall(25, shapes(1, 20), |rng, &(n1, n2)| {
            let x = rng.normal_vec(n1 * n2);
            let mut rc = vec![0.0; n1 * n2];
            RowColumn::idct2(n1, n2).forward(&x, &mut rc);
            check_close(&rc, &idct2d_direct(&x, n1, n2), 1e-9)?;
            let mut fused = vec![0.0; n1 * n2];
            Idct2::new(n1, n2).forward(&x, &mut fused);
            check_close(&rc, &fused, 1e-9)
        });
    }

    #[test]
    fn parallel_policy_is_bit_equal_to_serial() {
        use crate::parallel::ExecPolicy;
        let mut rng = crate::util::rng::Rng::new(61);
        for &(n1, n2) in &[(9usize, 15usize), (13, 7), (16, 16), (32, 8)] {
            let x = rng.normal_vec(n1 * n2);
            let mut ys = vec![0.0; n1 * n2];
            let mut yp = vec![0.0; n1 * n2];
            RowColumn::dct2(n1, n2).with_policy(ExecPolicy::Serial).forward(&x, &mut ys);
            RowColumn::dct2(n1, n2).with_policy(ExecPolicy::Threads(4)).forward(&x, &mut yp);
            assert_eq!(ys, yp, "({n1},{n2})");
        }
    }

    #[test]
    fn rc_combos_match_direct() {
        forall(20, shapes(1, 16), |rng, &(n1, n2)| {
            let x = rng.normal_vec(n1 * n2);
            let mut a = vec![0.0; n1 * n2];
            RowColumn::idct_idxst(n1, n2).forward(&x, &mut a);
            check_close(&a, &idct_idxst_direct(&x, n1, n2), 1e-9)?;
            let mut b = vec![0.0; n1 * n2];
            RowColumn::idxst_idct(n1, n2).forward(&x, &mut b);
            check_close(&b, &idxst_idct_direct(&x, n1, n2), 1e-9)
        });
    }
}
