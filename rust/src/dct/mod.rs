//! The paper's transform library: fused three-stage MD DCT/IDCT/IDXST,
//! the four 1D algorithms, the row-column baseline, and the direct
//! O(N^2) oracle.
//!
//! | paper concept | module |
//! |---|---|
//! | Algorithm 1 (four 1D DCTs via FFT) | [`dct1d`] |
//! | Algorithm 2 (fused 2D DCT/IDCT) + §III-B postprocess | [`dct2d`] |
//! | Eq. 13/16 butterfly reorders, gather/scatter (§III-A) | [`reorder`] |
//! | IDXST / IDCT_IDXST / IDXST_IDCT (§V-B) | [`idxst2d`] |
//! | Row-column baseline (Fig. 5 left) | [`row_column`] |
//! | 3D extension, forward + inverse (§III-D) | [`dct3d`] |
//! | 4D via two rounds of 2D (§III-D) | [`dct4d`] |
//! | DST family via folds (§III-D extensibility) | [`dst`] |
//! | Direct O(N^2) oracle / MATLAB stand-in | [`direct`] |
//! | Precomputed twiddles (texture-cache analogue) | [`twiddle`] |
//!
//! Every fused 2D plan carries a [`crate::parallel::ExecPolicy`]
//! (lane fan-out) and, via `with_shards`, a
//! [`crate::parallel::ShardPolicy`] (band-shard decomposition) — see
//! [`Dct2::with_shards`]. The fused 3D plans ([`Dct3d`], [`Idct3d`])
//! carry the same two policies with the dim-0 i-slab as their shard
//! unit ([`Dct3d::with_shards`]).
//!
//! ```
//! use mddct::dct::{Dct2, Idct2};
//! use mddct::parallel::{ExecPolicy, ShardPolicy};
//!
//! // a sharded plan splits its stages into 3 band work items but
//! // computes the exact same transform
//! let (n1, n2) = (16, 16);
//! let x: Vec<f64> = (0..n1 * n2).map(|i| (i as f64).sin()).collect();
//! let mut serial = vec![0.0; n1 * n2];
//! Dct2::with_policy(n1, n2, ExecPolicy::Serial).forward(&x, &mut serial);
//! let mut sharded = vec![0.0; n1 * n2];
//! Dct2::with_policy(n1, n2, ExecPolicy::Serial)
//!     .with_shards(ShardPolicy::MaxShards(3))
//!     .forward(&x, &mut sharded);
//! assert_eq!(serial, sharded);
//!
//! // and the inverse plan undoes it
//! let mut back = vec![0.0; n1 * n2];
//! Idct2::new(n1, n2).forward(&sharded, &mut back);
//! assert!(x.iter().zip(&back).all(|(a, b)| (a - b).abs() < 1e-9));
//! ```
#![warn(missing_docs)]

pub mod dct1d;
pub mod dct2d;
pub mod dct3d;
pub mod dct4d;
pub mod direct;
pub mod dst;
pub mod generic;
pub mod idxst2d;
pub mod reorder;
pub mod row_column;
pub mod twiddle;

pub use dct1d::{Algo1d, Dct1d, Idct1d, Idxst1d};
pub use dct2d::{Dct2, Idct2, StageTimes};
pub use generic::{Dct2F32, GenDct2, GenIdct2, Idct2F32};
pub use dct3d::{Dct3d, Idct3d};
pub use dct4d::Dct4d;
pub use dst::{Dst1d, Dst2, Idst1d, Idst2};
pub use idxst2d::{Combo, IdxstCombo};
pub use row_column::RowColumn;
