//! 4D DCT via two rounds of fused 2D DCTs — the paper's §III-D recipe
//! for higher dimensions: "a 4D DCT can be factorized into two rounds of
//! 2D DCTs. We can compute the DCT along any two dimensions at first and
//! then perform DCT along the other two dimensions."
//!
//! Execution: the plan carries an [`ExecPolicy`] and fans each round out
//! over its *slice* dimension (every (n3, n4) slice in round 1, every
//! (n1, n2) fibre in round 2 — mirroring [`super::dct3d::Dct3d`]'s slab
//! fan-out), with the block transposes between rounds running the
//! parallel tiled transpose. The inner 2D plans are deliberately serial:
//! a 4D tensor has `n1*n2` round-1 slices, so the outer loop is the wide
//! axis and keeping the inner kernels serial makes the output identical
//! across lane counts.

use super::dct2d::Dct2;
use crate::parallel::{par_chunks_mut, transpose_into, ExecPolicy};
use crate::util::scratch;

/// 4D DCT plan over a row-major (n1, n2, n3, n4) tensor.
#[derive(Debug, Clone)]
pub struct Dct4d {
    /// Extent of the first (slowest) axis.
    pub n1: usize,
    /// Extent of the second axis.
    pub n2: usize,
    /// Extent of the third axis.
    pub n3: usize,
    /// Extent of the fourth (contiguous) axis.
    pub n4: usize,
    /// fused 2D plan for the trailing axis pair (n3, n4)
    tail: Dct2,
    /// fused 2D plan for the leading axis pair (n1, n2)
    head: Dct2,
    policy: ExecPolicy,
    ws: scratch::Workspace,
}

impl Dct4d {
    /// Plan an `(n1, n2, n3, n4)` 4D DCT with the auto execution policy.
    pub fn new(n1: usize, n2: usize, n3: usize, n4: usize) -> Dct4d {
        Self::with_policy(n1, n2, n3, n4, ExecPolicy::Auto)
    }

    /// Plan with an explicit execution policy: both 2D rounds run
    /// through `parallel_for`-style chunking over their slice dimension,
    /// and the inter-round transposes band over the same lane count.
    pub fn with_policy(n1: usize, n2: usize, n3: usize, n4: usize, policy: ExecPolicy) -> Dct4d {
        let tail = Dct2::with_policy(n3, n4, ExecPolicy::Serial);
        let head = Dct2::with_policy(n1, n2, ExecPolicy::Serial);
        let mut ws = scratch::Workspace::new();
        for _ in 0..3 {
            // the three full-tensor round buffers (a, at, b) coexist
            ws.add_f64(n1 * n2 * n3 * n4);
        }
        ws.merge(tail.workspace());
        ws.merge(head.workspace());
        ws.prewarm();
        Dct4d { n1, n2, n3, n4, tail, head, policy, ws }
    }

    /// Scratch manifest of one `forward` call (three full-tensor round
    /// buffers plus the inner 2D plans' classes).
    pub fn workspace(&self) -> &scratch::Workspace {
        &self.ws
    }

    /// Prewarm the calling thread's scratch pool for this plan.
    pub fn prewarm(&self) {
        self.ws.prewarm();
    }

    /// Lane count for a stage touching the whole tensor.
    fn lanes(&self) -> usize {
        self.policy.lanes(self.n1 * self.n2 * self.n3 * self.n4)
    }

    /// Full 4D DCT: round 1 transforms every (n3, n4) slice; round 2
    /// transforms every (n1, n2) fibre (via a block transpose so each
    /// round runs the fused 2D kernel on contiguous data). Both rounds
    /// fan their independent slices over the shared pool.
    pub fn forward(&self, x: &[f64], out: &mut [f64]) {
        let lead = self.n1 * self.n2;
        let tail = self.n3 * self.n4;
        assert_eq!(x.len(), lead * tail);
        assert_eq!(out.len(), lead * tail);
        let lanes = self.lanes();

        // round 1: 2D DCT over (n3, n4) for each leading index
        let mut a = scratch::take_f64(lead * tail);
        par_chunks_mut(&mut a, tail, lanes, |s, slice| {
            self.tail.forward(&x[s * tail..(s + 1) * tail], slice);
        });
        // transpose to (n3*n4, n1*n2) so the leading pair is contiguous
        let mut at = scratch::take_f64(lead * tail);
        transpose_into(&a, &mut at, lead, tail, lanes);
        // round 2: 2D DCT over (n1, n2) for each trailing index
        let mut b = scratch::take_f64(lead * tail);
        par_chunks_mut(&mut b, lead, lanes, |s, slice| {
            self.head.forward(&at[s * lead..(s + 1) * lead], slice);
        });
        // transpose back to (n1, n2, n3, n4)
        transpose_into(&b, out, tail, lead, lanes);
        scratch::give_f64(a);
        scratch::give_f64(at);
        scratch::give_f64(b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::direct::dct1d_direct;
    use crate::util::prop::check_close;
    use crate::util::rng::Rng;

    /// Separable oracle: 1D direct DCT along each of the four axes.
    fn dct4d_direct(x: &[f64], dims: [usize; 4]) -> Vec<f64> {
        let mut data = x.to_vec();
        let total: usize = dims.iter().product();
        for axis in 0..4 {
            let n = dims[axis];
            let stride: usize = dims[axis + 1..].iter().product();
            let outer = total / (n * stride);
            let mut next = vec![0.0; total];
            let mut fibre = vec![0.0; n];
            for o in 0..outer {
                for s in 0..stride {
                    for i in 0..n {
                        fibre[i] = data[(o * n + i) * stride + s];
                    }
                    let y = dct1d_direct(&fibre);
                    for i in 0..n {
                        next[(o * n + i) * stride + s] = y[i];
                    }
                }
            }
            data = next;
        }
        data
    }

    #[test]
    fn matches_separable_oracle() {
        let mut rng = Rng::new(900);
        for dims in [[2usize, 3, 4, 5], [4, 4, 4, 4], [1, 6, 2, 7], [3, 1, 5, 2]] {
            let total: usize = dims.iter().product();
            let x = rng.normal_vec(total);
            let plan = Dct4d::new(dims[0], dims[1], dims[2], dims[3]);
            let mut out = vec![0.0; total];
            plan.forward(&x, &mut out);
            check_close(&out, &dct4d_direct(&x, dims), 1e-9)
                .unwrap_or_else(|e| panic!("{dims:?}: {e}"));
        }
    }

    #[test]
    fn parallel_policy_is_bit_equal_to_serial() {
        let mut rng = Rng::new(902);
        for dims in [[2usize, 3, 4, 5], [4, 4, 4, 4], [3, 1, 5, 2], [2, 7, 3, 3]] {
            let total: usize = dims.iter().product();
            let x = rng.normal_vec(total);
            let mut ys = vec![0.0; total];
            Dct4d::with_policy(dims[0], dims[1], dims[2], dims[3], ExecPolicy::Serial)
                .forward(&x, &mut ys);
            let mut yp = vec![0.0; total];
            Dct4d::with_policy(dims[0], dims[1], dims[2], dims[3], ExecPolicy::Threads(4))
                .forward(&x, &mut yp);
            assert_eq!(ys, yp, "dct4d {dims:?}");
        }
    }

    #[test]
    fn dc_term_is_16x_sum() {
        let mut rng = Rng::new(901);
        let dims = [3usize, 4, 2, 5];
        let total: usize = dims.iter().product();
        let x = rng.normal_vec(total);
        let mut out = vec![0.0; total];
        Dct4d::new(dims[0], dims[1], dims[2], dims[3]).forward(&x, &mut out);
        let sum: f64 = x.iter().sum();
        assert!((out[0] - 16.0 * sum).abs() < 1e-8); // 2^4 per the convention
    }
}
