//! 4D DCT via two rounds of fused 2D DCTs — the paper's §III-D recipe
//! for higher dimensions: "a 4D DCT can be factorized into two rounds of
//! 2D DCTs. We can compute the DCT along any two dimensions at first and
//! then perform DCT along the other two dimensions."

use super::dct2d::Dct2;

/// 4D DCT plan over a row-major (n1, n2, n3, n4) tensor.
#[derive(Debug, Clone)]
pub struct Dct4d {
    pub n1: usize,
    pub n2: usize,
    pub n3: usize,
    pub n4: usize,
    /// fused 2D plan for the trailing axis pair (n3, n4)
    tail: Dct2,
    /// fused 2D plan for the leading axis pair (n1, n2)
    head: Dct2,
}

impl Dct4d {
    pub fn new(n1: usize, n2: usize, n3: usize, n4: usize) -> Dct4d {
        Dct4d { n1, n2, n3, n4, tail: Dct2::new(n3, n4), head: Dct2::new(n1, n2) }
    }

    /// Full 4D DCT: round 1 transforms every (n3, n4) slice; round 2
    /// transforms every (n1, n2) fibre (via a block transpose so each
    /// round runs the fused 2D kernel on contiguous data).
    pub fn forward(&self, x: &[f64], out: &mut [f64]) {
        let (n1, n2, n3, n4) = (self.n1, self.n2, self.n3, self.n4);
        let lead = n1 * n2;
        let tail = n3 * n4;
        assert_eq!(x.len(), lead * tail);
        assert_eq!(out.len(), lead * tail);

        // round 1: 2D DCT over (n3, n4) for each leading index
        let mut a = crate::util::scratch::take_f64(lead * tail);
        for s in 0..lead {
            self.tail.forward(&x[s * tail..(s + 1) * tail], &mut a[s * tail..(s + 1) * tail]);
        }
        // transpose to (n3*n4, n1*n2) so the leading pair is contiguous
        let mut at = crate::util::scratch::take_f64(lead * tail);
        super::row_column::transpose(&a, &mut at, lead, tail);
        // round 2: 2D DCT over (n1, n2) for each trailing index
        let mut b = crate::util::scratch::take_f64(lead * tail);
        for s in 0..tail {
            self.head.forward(&at[s * lead..(s + 1) * lead], &mut b[s * lead..(s + 1) * lead]);
        }
        // transpose back to (n1, n2, n3, n4)
        super::row_column::transpose(&b, out, tail, lead);
        crate::util::scratch::give_f64(a);
        crate::util::scratch::give_f64(at);
        crate::util::scratch::give_f64(b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::direct::dct1d_direct;
    use crate::util::prop::check_close;
    use crate::util::rng::Rng;

    /// Separable oracle: 1D direct DCT along each of the four axes.
    fn dct4d_direct(x: &[f64], dims: [usize; 4]) -> Vec<f64> {
        let mut data = x.to_vec();
        let total: usize = dims.iter().product();
        for axis in 0..4 {
            let n = dims[axis];
            let stride: usize = dims[axis + 1..].iter().product();
            let outer = total / (n * stride);
            let mut next = vec![0.0; total];
            let mut fibre = vec![0.0; n];
            for o in 0..outer {
                for s in 0..stride {
                    for i in 0..n {
                        fibre[i] = data[(o * n + i) * stride + s];
                    }
                    let y = dct1d_direct(&fibre);
                    for i in 0..n {
                        next[(o * n + i) * stride + s] = y[i];
                    }
                }
            }
            data = next;
        }
        data
    }

    #[test]
    fn matches_separable_oracle() {
        let mut rng = Rng::new(900);
        for dims in [[2usize, 3, 4, 5], [4, 4, 4, 4], [1, 6, 2, 7], [3, 1, 5, 2]] {
            let total: usize = dims.iter().product();
            let x = rng.normal_vec(total);
            let plan = Dct4d::new(dims[0], dims[1], dims[2], dims[3]);
            let mut out = vec![0.0; total];
            plan.forward(&x, &mut out);
            check_close(&out, &dct4d_direct(&x, dims), 1e-9)
                .unwrap_or_else(|e| panic!("{dims:?}: {e}"));
        }
    }

    #[test]
    fn dc_term_is_16x_sum() {
        let mut rng = Rng::new(901);
        let dims = [3usize, 4, 2, 5];
        let total: usize = dims.iter().product();
        let x = rng.normal_vec(total);
        let mut out = vec![0.0; total];
        Dct4d::new(dims[0], dims[1], dims[2], dims[3]).forward(&x, &mut out);
        let sum: f64 = x.iter().sum();
        assert!((out[0] - 16.0 * sum).abs() < 1e-8); // 2^4 per the convention
    }
}
