//! The four 1D DCT-via-FFT algorithms (paper Algorithm 1) plus the
//! inverse and IDXST — native backend.
//!
//! Each plan owns its RFFT plan + twiddle table, so repeated calls do no
//! trig. The N-point variant is the library default (the paper shows it
//! dominates in Table IV); the 4N/2N variants exist as first-class
//! citizens because Table IV benchmarks all four.

use std::sync::Arc;

use crate::fft::{onesided_len, C64, RfftPlan};
use crate::parallel::{par_chunks_mut, ExecPolicy};
use crate::util::scratch::{self, Workspace};

use super::twiddle::{twiddle, Twiddle};

/// Which Algorithm-1 variant a [`Dct1d`] plan uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algo1d {
    /// 4N-point FFT of the zero-interleaved extension (Eq. 3/4)
    FourN,
    /// 2N-point FFT of the mirrored extension (Eq. 5/6)
    Mirror2N,
    /// 2N-point FFT of the zero-padded extension (Eq. 7/8)
    Pad2N,
    /// N-point FFT of the butterfly reorder (Eq. 9/11) — the fast one
    NPoint,
}

impl Algo1d {
    /// All four variants, in paper (Table IV) order.
    pub const ALL: [Algo1d; 4] = [Algo1d::FourN, Algo1d::Mirror2N, Algo1d::Pad2N, Algo1d::NPoint];

    /// Human-readable variant name (bench tables / logs).
    pub fn name(self) -> &'static str {
        match self {
            Algo1d::FourN => "4N",
            Algo1d::Mirror2N => "Mirrored 2N",
            Algo1d::Pad2N => "Padded 2N",
            Algo1d::NPoint => "N",
        }
    }

    /// FFT length this variant transforms for input length n.
    pub fn fft_len(self, n: usize) -> usize {
        match self {
            Algo1d::FourN => 4 * n,
            Algo1d::Mirror2N | Algo1d::Pad2N => 2 * n,
            Algo1d::NPoint => n,
        }
    }
}

/// Forward 1D DCT plan.
#[derive(Debug, Clone)]
pub struct Dct1d {
    /// Transform length.
    pub n: usize,
    /// Which Algorithm-1 variant this plan executes.
    pub algo: Algo1d,
    rfft: RfftPlan,
    tw: Arc<Twiddle>,
    exec: ExecPolicy,
    ws: Workspace,
}

impl Dct1d {
    /// Plan a length-`n` forward DCT-II with the given variant.
    pub fn new(n: usize, algo: Algo1d) -> Dct1d {
        Self::with_exec(n, algo, ExecPolicy::Auto)
    }

    /// Plan with an explicit execution policy: a solo `forward` is
    /// always serial (a single 1D transform is below any useful
    /// fan-out), but [`Dct1d::forward_batch`] chunks the batch over the
    /// policy's lanes.
    pub fn with_exec(n: usize, algo: Algo1d, exec: ExecPolicy) -> Dct1d {
        let m = algo.fft_len(n);
        let rfft = RfftPlan::new(m);
        let mut ws = Workspace::new();
        ws.add_f64(m);
        ws.add_c64(onesided_len(m));
        rfft.register_scratch(&mut ws);
        ws.prewarm();
        Dct1d { n, algo, rfft, tw: twiddle(n), exec, ws }
    }

    /// Scratch manifest of one `forward` call; [`Dct1d::prewarm`] makes
    /// the calling thread allocation-free before its first transform.
    pub fn workspace(&self) -> &Workspace {
        &self.ws
    }

    /// Prewarm the calling thread's scratch pool for this plan.
    pub fn prewarm(&self) {
        self.ws.prewarm();
    }

    /// Compute the DCT of `x` into `out` (both length n).
    pub fn forward(&self, x: &[f64], out: &mut [f64]) {
        let n = self.n;
        assert_eq!(x.len(), n);
        assert_eq!(out.len(), n);
        let m = self.algo.fft_len(n);
        let mut pre = scratch::take_f64(m);
        self.preprocess(x, &mut pre);
        let mut spec = scratch::take_c64(onesided_len(m));
        self.rfft.forward(&pre, &mut spec);
        self.postprocess(&spec, out);
        scratch::give_f64(pre);
        scratch::give_c64(spec);
    }

    /// Batched forward DCT: `batch` packed length-n signals in `xs` ->
    /// `batch` packed outputs in `out`. Each of the three stages runs
    /// across the whole batch — one preprocess sweep, one batched RFFT
    /// (twiddle tables, bit-reversal schedules, and the pool dispatch
    /// paid once per batch), one postprocess sweep — chunked over the
    /// plan's [`ExecPolicy`] lanes. Per-element arithmetic is identical
    /// to `batch` solo [`Dct1d::forward`] calls, so outputs match
    /// bit-for-bit (for a fixed FFT kernel).
    pub fn forward_batch(&self, xs: &[f64], out: &mut [f64], batch: usize) {
        let n = self.n;
        assert_eq!(xs.len(), batch * n);
        assert_eq!(out.len(), batch * n);
        if batch == 0 {
            return;
        }
        let m = self.algo.fft_len(n);
        let h = onesided_len(m);
        let lanes = self.exec.lanes(batch * m);
        let mut pre = scratch::take_f64(batch * m);
        par_chunks_mut(&mut pre, m, lanes, |b, row| {
            self.preprocess(&xs[b * n..(b + 1) * n], row);
        });
        let mut spec = scratch::take_c64(batch * h);
        self.rfft.forward_batch(&pre, &mut spec, lanes);
        par_chunks_mut(out, n, lanes, |b, orow| {
            self.postprocess(&spec[b * h..(b + 1) * h], orow);
        });
        scratch::give_f64(pre);
        scratch::give_c64(spec);
    }

    /// Preprocessing stage only (exposed for stage-level benches).
    pub fn preprocess(&self, x: &[f64], pre: &mut [f64]) {
        let n = self.n;
        match self.algo {
            Algo1d::FourN => {
                pre.fill(0.0);
                for i in 0..n {
                    pre[2 * i + 1] = x[i];
                    pre[2 * n + 2 * i + 1] = x[n - 1 - i];
                }
            }
            Algo1d::Mirror2N => {
                pre[..n].copy_from_slice(x);
                for i in 0..n {
                    pre[n + i] = x[n - 1 - i];
                }
            }
            Algo1d::Pad2N => {
                pre[..n].copy_from_slice(x);
                pre[n..].fill(0.0);
            }
            Algo1d::NPoint => super::reorder::reorder_1d_scatter(x, pre),
        }
    }

    /// Postprocessing stage only (exposed for stage-level benches).
    pub fn postprocess(&self, spec: &[C64], out: &mut [f64]) {
        let n = self.n;
        match self.algo {
            Algo1d::FourN => {
                for (k, o) in out.iter_mut().enumerate() {
                    *o = spec[k].re; // Eq. (4)
                }
            }
            Algo1d::Mirror2N => {
                for (k, o) in out.iter_mut().enumerate() {
                    let w = self.tw.at(k);
                    *o = (w * spec[k]).re; // Eq. (6)
                }
            }
            Algo1d::Pad2N => {
                for (k, o) in out.iter_mut().enumerate() {
                    let w = self.tw.at(k);
                    *o = 2.0 * (w * spec[k]).re; // Eq. (8)
                }
            }
            Algo1d::NPoint => {
                // Eq. (11): onesided spectrum + Hermitian right half
                let h = onesided_len(n);
                for k in 0..h.min(n) {
                    out[k] = 2.0 * (self.tw.at(k) * spec[k]).re;
                }
                for k in h..n {
                    out[k] = 2.0 * (self.tw.at(k) * spec[n - k].conj()).re;
                }
            }
        }
    }
}

/// Inverse 1D DCT plan (N-point IRFFT; the 1D restriction of Eq. 15/16).
#[derive(Debug, Clone)]
pub struct Idct1d {
    /// Transform length.
    pub n: usize,
    rfft: RfftPlan,
    tw: Arc<Twiddle>,
    exec: ExecPolicy,
    ws: Workspace,
}

impl Idct1d {
    /// Plan a length-`n` inverse DCT (DCT-III, paper normalization).
    pub fn new(n: usize) -> Idct1d {
        Self::with_exec(n, ExecPolicy::Auto)
    }

    /// Plan with an explicit execution policy (drives
    /// [`Idct1d::forward_batch`]'s lane fan-out, like
    /// [`Dct1d::with_exec`]).
    pub fn with_exec(n: usize, exec: ExecPolicy) -> Idct1d {
        let rfft = RfftPlan::new(n);
        let mut ws = Workspace::new();
        ws.add_c64(onesided_len(n));
        ws.add_f64(n);
        rfft.register_scratch(&mut ws);
        ws.prewarm();
        Idct1d { n, rfft, tw: twiddle(n), exec, ws }
    }

    /// Scratch manifest of one `forward` call.
    pub fn workspace(&self) -> &Workspace {
        &self.ws
    }

    /// Prewarm the calling thread's scratch pool for this plan.
    pub fn prewarm(&self) {
        self.ws.prewarm();
    }

    /// Inverse-transform `x` into `out` (both length `n`).
    pub fn forward(&self, x: &[f64], out: &mut [f64]) {
        let n = self.n;
        assert_eq!(x.len(), n);
        assert_eq!(out.len(), n);
        let h = onesided_len(n);
        let mut spec = scratch::take_c64(h);
        self.preprocess(x, &mut spec);
        let mut v = scratch::take_f64(n);
        self.rfft.inverse(&spec, &mut v);
        super::reorder::unreorder_1d(&v, out);
        scratch::give_c64(spec);
        scratch::give_f64(v);
    }

    /// Batched inverse DCT: the stage-fused mirror of
    /// [`Dct1d::forward_batch`] (spectrum build sweep, one batched
    /// inverse RFFT, unreorder sweep). Bit-identical to `batch` solo
    /// [`Idct1d::forward`] calls for a fixed FFT kernel.
    pub fn forward_batch(&self, xs: &[f64], out: &mut [f64], batch: usize) {
        let n = self.n;
        assert_eq!(xs.len(), batch * n);
        assert_eq!(out.len(), batch * n);
        if batch == 0 {
            return;
        }
        let h = onesided_len(n);
        let lanes = self.exec.lanes(batch * n);
        let mut spec = scratch::take_c64(batch * h);
        par_chunks_mut(&mut spec, h, lanes, |b, srow| {
            self.preprocess(&xs[b * n..(b + 1) * n], srow);
        });
        let mut v = scratch::take_f64(batch * n);
        self.rfft.inverse_batch(&spec, &mut v, lanes);
        par_chunks_mut(out, n, lanes, |b, orow| {
            super::reorder::unreorder_1d(&v[b * n..(b + 1) * n], orow);
        });
        scratch::give_c64(spec);
        scratch::give_f64(v);
    }

    /// Build the onesided spectrum: V(k) = conj(w_k)/2 (x_k - j x~_k).
    pub fn preprocess(&self, x: &[f64], spec: &mut [C64]) {
        let n = self.n;
        for (k, s) in spec.iter_mut().enumerate() {
            let xt = if k == 0 { 0.0 } else { x[n - k] };
            let wc = self.tw.conj_at(k);
            // wc/2 * (x[k] - j*xt)
            *s = (wc * C64::new(x[k], -xt)).scale(0.5);
        }
    }
}

/// 1D IDXST plan (paper Eq. 21): sign-flipped IDCT of the reverse-shift.
#[derive(Debug, Clone)]
pub struct Idxst1d {
    idct: Idct1d,
    ws: Workspace,
}

impl Idxst1d {
    /// Plan a length-`n` IDXST.
    pub fn new(n: usize) -> Idxst1d {
        let idct = Idct1d::new(n);
        // the shift buffer is held across the whole inner IDCT, so it
        // must be registered *alongside* the inner plan's classes (a
        // second simultaneous f64(n) on top of the IDCT's own)
        let mut ws = Workspace::new();
        ws.add_f64(n);
        ws.merge(idct.workspace());
        ws.prewarm();
        Idxst1d { idct, ws }
    }

    /// Scratch manifest of one `forward` call (shift buffer + the inner
    /// IDCT's own classes).
    pub fn workspace(&self) -> &Workspace {
        &self.ws
    }

    /// Prewarm the calling thread's scratch pool for this plan.
    pub fn prewarm(&self) {
        self.ws.prewarm();
    }

    /// Transform length.
    pub fn len(&self) -> usize {
        self.idct.n
    }

    /// True iff the planned length is zero.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Transform `x` into `out` (both length `n`): reverse-shift, inner
    /// IDCT, then sign-flip of the odd outputs.
    pub fn forward(&self, x: &[f64], out: &mut [f64]) {
        let n = self.idct.n;
        // pooled scratch, not a fresh vec: this buffer was the last
        // per-call allocation left on the 1D hot path
        let mut shifted = scratch::take_f64(n);
        shifted[0] = 0.0;
        for i in 1..n {
            shifted[i] = x[n - i];
        }
        self.idct.forward(&shifted, out);
        scratch::give_f64(shifted);
        for (k, o) in out.iter_mut().enumerate() {
            if k % 2 == 1 {
                *o = -*o;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::direct::{dct1d_direct, idct1d_direct, idxst1d_direct};
    use crate::util::prop::{check_close, forall, sizes};

    #[test]
    fn all_algorithms_match_direct() {
        forall(40, sizes(1, 100), |rng, &n| {
            let x = rng.normal_vec(n);
            let want = dct1d_direct(&x);
            for algo in Algo1d::ALL {
                let plan = Dct1d::new(n, algo);
                let mut out = vec![0.0; n];
                plan.forward(&x, &mut out);
                check_close(&out, &want, 1e-9)
                    .map_err(|e| format!("{} failed: {e}", algo.name()))?;
            }
            Ok(())
        });
    }

    #[test]
    fn idct_matches_direct_and_inverts() {
        forall(40, sizes(1, 100), |rng, &n| {
            let x = rng.normal_vec(n);
            let plan = Idct1d::new(n);
            let mut out = vec![0.0; n];
            plan.forward(&x, &mut out);
            check_close(&out, &idct1d_direct(&x), 1e-9)?;
            // roundtrip
            let fwd = Dct1d::new(n, Algo1d::NPoint);
            let mut y = vec![0.0; n];
            fwd.forward(&x, &mut y);
            let mut back = vec![0.0; n];
            plan.forward(&y, &mut back);
            check_close(&back, &x, 1e-9)
        });
    }

    #[test]
    fn idxst_matches_direct() {
        forall(30, sizes(1, 64), |rng, &n| {
            let x = rng.normal_vec(n);
            let plan = Idxst1d::new(n);
            let mut out = vec![0.0; n];
            plan.forward(&x, &mut out);
            check_close(&out, &idxst1d_direct(&x), 1e-9)
        });
    }

    #[test]
    fn forward_batch_matches_solo_bitwise() {
        use crate::parallel::ExecPolicy;
        let mut rng = crate::util::rng::Rng::new(46);
        for &(n, batch) in &[(16usize, 5usize), (15, 4), (7, 3), (8, 1)] {
            let xs = rng.normal_vec(n * batch);
            for algo in [Algo1d::NPoint, Algo1d::Pad2N] {
                for exec in [ExecPolicy::Serial, ExecPolicy::Threads(4)] {
                    let plan = Dct1d::with_exec(n, algo, exec);
                    let mut want = vec![0.0; n * batch];
                    for b in 0..batch {
                        plan.forward(&xs[b * n..(b + 1) * n], &mut want[b * n..(b + 1) * n]);
                    }
                    let mut got = vec![0.0; n * batch];
                    plan.forward_batch(&xs, &mut got, batch);
                    assert_eq!(got, want, "dct1d {} n={n} batch={batch}", algo.name());
                }
            }
            // inverse side
            let plan = Idct1d::with_exec(n, ExecPolicy::Threads(3));
            let mut want = vec![0.0; n * batch];
            for b in 0..batch {
                plan.forward(&xs[b * n..(b + 1) * n], &mut want[b * n..(b + 1) * n]);
            }
            let mut got = vec![0.0; n * batch];
            plan.forward_batch(&xs, &mut got, batch);
            assert_eq!(got, want, "idct1d n={n} batch={batch}");
        }
    }

    #[test]
    fn fft_lengths_per_algo() {
        assert_eq!(Algo1d::FourN.fft_len(100), 400);
        assert_eq!(Algo1d::Mirror2N.fft_len(100), 200);
        assert_eq!(Algo1d::Pad2N.fft_len(100), 200);
        assert_eq!(Algo1d::NPoint.fft_len(100), 100);
    }

    #[test]
    fn linearity() {
        forall(20, sizes(2, 64), |rng, &n| {
            let x = rng.normal_vec(n);
            let y = rng.normal_vec(n);
            let plan = Dct1d::new(n, Algo1d::NPoint);
            let combo: Vec<f64> =
                x.iter().zip(&y).map(|(a, b)| 3.0 * a - 0.5 * b).collect();
            let mut fc = vec![0.0; n];
            plan.forward(&combo, &mut fc);
            let mut fx = vec![0.0; n];
            plan.forward(&x, &mut fx);
            let mut fy = vec![0.0; n];
            plan.forward(&y, &mut fy);
            let want: Vec<f64> =
                fx.iter().zip(&fy).map(|(a, b)| 3.0 * a - 0.5 * b).collect();
            check_close(&fc, &want, 1e-9)
        });
    }
}
