//! The four 1D DCT-via-FFT algorithms (paper Algorithm 1) plus the
//! inverse and IDXST — native backend.
//!
//! Each plan owns its RFFT plan + twiddle table, so repeated calls do no
//! trig. The N-point variant is the library default (the paper shows it
//! dominates in Table IV); the 4N/2N variants exist as first-class
//! citizens because Table IV benchmarks all four.

use std::sync::Arc;

use crate::fft::{onesided_len, C64, RfftPlan};

use super::twiddle::{twiddle, Twiddle};

/// Which Algorithm-1 variant a [`Dct1d`] plan uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algo1d {
    /// 4N-point FFT of the zero-interleaved extension (Eq. 3/4)
    FourN,
    /// 2N-point FFT of the mirrored extension (Eq. 5/6)
    Mirror2N,
    /// 2N-point FFT of the zero-padded extension (Eq. 7/8)
    Pad2N,
    /// N-point FFT of the butterfly reorder (Eq. 9/11) — the fast one
    NPoint,
}

impl Algo1d {
    pub const ALL: [Algo1d; 4] = [Algo1d::FourN, Algo1d::Mirror2N, Algo1d::Pad2N, Algo1d::NPoint];

    pub fn name(self) -> &'static str {
        match self {
            Algo1d::FourN => "4N",
            Algo1d::Mirror2N => "Mirrored 2N",
            Algo1d::Pad2N => "Padded 2N",
            Algo1d::NPoint => "N",
        }
    }

    /// FFT length this variant transforms for input length n.
    pub fn fft_len(self, n: usize) -> usize {
        match self {
            Algo1d::FourN => 4 * n,
            Algo1d::Mirror2N | Algo1d::Pad2N => 2 * n,
            Algo1d::NPoint => n,
        }
    }
}

/// Forward 1D DCT plan.
#[derive(Debug, Clone)]
pub struct Dct1d {
    pub n: usize,
    pub algo: Algo1d,
    rfft: RfftPlan,
    tw: Arc<Twiddle>,
}

impl Dct1d {
    pub fn new(n: usize, algo: Algo1d) -> Dct1d {
        Dct1d { n, algo, rfft: RfftPlan::new(algo.fft_len(n)), tw: twiddle(n) }
    }

    /// Compute the DCT of `x` into `out` (both length n).
    pub fn forward(&self, x: &[f64], out: &mut [f64]) {
        let n = self.n;
        assert_eq!(x.len(), n);
        assert_eq!(out.len(), n);
        let m = self.algo.fft_len(n);
        let mut pre = crate::util::scratch::take_f64(m);
        self.preprocess(x, &mut pre);
        let mut spec = crate::util::scratch::take_c64(onesided_len(m));
        self.rfft.forward(&pre, &mut spec);
        self.postprocess(&spec, out);
        crate::util::scratch::give_f64(pre);
        crate::util::scratch::give_c64(spec);
    }

    /// Preprocessing stage only (exposed for stage-level benches).
    pub fn preprocess(&self, x: &[f64], pre: &mut [f64]) {
        let n = self.n;
        match self.algo {
            Algo1d::FourN => {
                pre.fill(0.0);
                for i in 0..n {
                    pre[2 * i + 1] = x[i];
                    pre[2 * n + 2 * i + 1] = x[n - 1 - i];
                }
            }
            Algo1d::Mirror2N => {
                pre[..n].copy_from_slice(x);
                for i in 0..n {
                    pre[n + i] = x[n - 1 - i];
                }
            }
            Algo1d::Pad2N => {
                pre[..n].copy_from_slice(x);
                pre[n..].fill(0.0);
            }
            Algo1d::NPoint => super::reorder::reorder_1d_scatter(x, pre),
        }
    }

    /// Postprocessing stage only (exposed for stage-level benches).
    pub fn postprocess(&self, spec: &[C64], out: &mut [f64]) {
        let n = self.n;
        match self.algo {
            Algo1d::FourN => {
                for (k, o) in out.iter_mut().enumerate() {
                    *o = spec[k].re; // Eq. (4)
                }
            }
            Algo1d::Mirror2N => {
                for (k, o) in out.iter_mut().enumerate() {
                    let w = self.tw.at(k);
                    *o = (w * spec[k]).re; // Eq. (6)
                }
            }
            Algo1d::Pad2N => {
                for (k, o) in out.iter_mut().enumerate() {
                    let w = self.tw.at(k);
                    *o = 2.0 * (w * spec[k]).re; // Eq. (8)
                }
            }
            Algo1d::NPoint => {
                // Eq. (11): onesided spectrum + Hermitian right half
                let h = onesided_len(n);
                for k in 0..h.min(n) {
                    out[k] = 2.0 * (self.tw.at(k) * spec[k]).re;
                }
                for k in h..n {
                    out[k] = 2.0 * (self.tw.at(k) * spec[n - k].conj()).re;
                }
            }
        }
    }
}

/// Inverse 1D DCT plan (N-point IRFFT; the 1D restriction of Eq. 15/16).
#[derive(Debug, Clone)]
pub struct Idct1d {
    pub n: usize,
    rfft: RfftPlan,
    tw: Arc<Twiddle>,
}

impl Idct1d {
    pub fn new(n: usize) -> Idct1d {
        Idct1d { n, rfft: RfftPlan::new(n), tw: twiddle(n) }
    }

    pub fn forward(&self, x: &[f64], out: &mut [f64]) {
        let n = self.n;
        assert_eq!(x.len(), n);
        assert_eq!(out.len(), n);
        let h = onesided_len(n);
        let mut spec = crate::util::scratch::take_c64(h);
        self.preprocess(x, &mut spec);
        let mut v = crate::util::scratch::take_f64(n);
        self.rfft.inverse(&spec, &mut v);
        super::reorder::unreorder_1d(&v, out);
        crate::util::scratch::give_c64(spec);
        crate::util::scratch::give_f64(v);
    }

    /// Build the onesided spectrum: V(k) = conj(w_k)/2 (x_k - j x~_k).
    pub fn preprocess(&self, x: &[f64], spec: &mut [C64]) {
        let n = self.n;
        for (k, s) in spec.iter_mut().enumerate() {
            let xt = if k == 0 { 0.0 } else { x[n - k] };
            let wc = self.tw.conj_at(k);
            // wc/2 * (x[k] - j*xt)
            *s = (wc * C64::new(x[k], -xt)).scale(0.5);
        }
    }
}

/// 1D IDXST plan (paper Eq. 21): sign-flipped IDCT of the reverse-shift.
#[derive(Debug, Clone)]
pub struct Idxst1d {
    idct: Idct1d,
}

impl Idxst1d {
    pub fn new(n: usize) -> Idxst1d {
        Idxst1d { idct: Idct1d::new(n) }
    }

    /// Transform length.
    pub fn len(&self) -> usize {
        self.idct.n
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn forward(&self, x: &[f64], out: &mut [f64]) {
        let n = self.idct.n;
        let mut shifted = vec![0.0; n];
        for i in 1..n {
            shifted[i] = x[n - i];
        }
        self.idct.forward(&shifted, out);
        for (k, o) in out.iter_mut().enumerate() {
            if k % 2 == 1 {
                *o = -*o;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::direct::{dct1d_direct, idct1d_direct, idxst1d_direct};
    use crate::util::prop::{check_close, forall, sizes};

    #[test]
    fn all_algorithms_match_direct() {
        forall(40, sizes(1, 100), |rng, &n| {
            let x = rng.normal_vec(n);
            let want = dct1d_direct(&x);
            for algo in Algo1d::ALL {
                let plan = Dct1d::new(n, algo);
                let mut out = vec![0.0; n];
                plan.forward(&x, &mut out);
                check_close(&out, &want, 1e-9)
                    .map_err(|e| format!("{} failed: {e}", algo.name()))?;
            }
            Ok(())
        });
    }

    #[test]
    fn idct_matches_direct_and_inverts() {
        forall(40, sizes(1, 100), |rng, &n| {
            let x = rng.normal_vec(n);
            let plan = Idct1d::new(n);
            let mut out = vec![0.0; n];
            plan.forward(&x, &mut out);
            check_close(&out, &idct1d_direct(&x), 1e-9)?;
            // roundtrip
            let fwd = Dct1d::new(n, Algo1d::NPoint);
            let mut y = vec![0.0; n];
            fwd.forward(&x, &mut y);
            let mut back = vec![0.0; n];
            plan.forward(&y, &mut back);
            check_close(&back, &x, 1e-9)
        });
    }

    #[test]
    fn idxst_matches_direct() {
        forall(30, sizes(1, 64), |rng, &n| {
            let x = rng.normal_vec(n);
            let plan = Idxst1d::new(n);
            let mut out = vec![0.0; n];
            plan.forward(&x, &mut out);
            check_close(&out, &idxst1d_direct(&x), 1e-9)
        });
    }

    #[test]
    fn fft_lengths_per_algo() {
        assert_eq!(Algo1d::FourN.fft_len(100), 400);
        assert_eq!(Algo1d::Mirror2N.fft_len(100), 200);
        assert_eq!(Algo1d::Pad2N.fft_len(100), 200);
        assert_eq!(Algo1d::NPoint.fft_len(100), 100);
    }

    #[test]
    fn linearity() {
        forall(20, sizes(2, 64), |rng, &n| {
            let x = rng.normal_vec(n);
            let y = rng.normal_vec(n);
            let plan = Dct1d::new(n, Algo1d::NPoint);
            let combo: Vec<f64> =
                x.iter().zip(&y).map(|(a, b)| 3.0 * a - 0.5 * b).collect();
            let mut fc = vec![0.0; n];
            plan.forward(&combo, &mut fc);
            let mut fx = vec![0.0; n];
            plan.forward(&x, &mut fx);
            let mut fy = vec![0.0; n];
            plan.forward(&y, &mut fy);
            let want: Vec<f64> =
                fx.iter().zip(&fy).map(|(a, b)| 3.0 * a - 0.5 * b).collect();
            check_close(&fc, &want, 1e-9)
        });
    }
}
