//! Generic-over-element fused 2D DCT / IDCT — the `f32` plan path.
//!
//! [`GenDct2`] / [`GenIdct2`] reproduce the three-stage factorization of
//! [`super::dct2d::Dct2`] / [`super::dct2d::Idct2`] (Eq. (13) reorder →
//! 2D RFFT → §III-B paired combine, and the corrected Eq. (15) spectrum
//! build → 2D IRFFT → Eq. (16) unreorder) over any
//! [`Element`](crate::fft::elem::Element), on the split-plane generic
//! FFT core of [`crate::fft::generic`].
//!
//! The per-block kernel is deliberately serial — the dedicated `f64`
//! plans keep the tuned band-sharded stages — and batching fans whole
//! blocks out across pool lanes ([`GenDct2::forward_batch`]), which is
//! the shape the coordinator's packed path wants anyway. The headline
//! instantiations are the `f32` aliases [`Dct2F32`] / [`Idct2F32`]:
//! half the memory traffic of the `f64` plans on a memory-bound
//! transform (measured by `benches/layout.rs`), at ~1e-6 relative
//! accuracy (pinned within 1e-4 by `tests/prop_layout.rs`).
//!
//! ```
//! use mddct::dct::generic::Dct2F32;
//!
//! let plan = Dct2F32::new(4, 4);
//! let x = vec![1.0f32; 16];
//! let mut y = vec![0.0f32; 16];
//! plan.forward(&x, &mut y);
//! // constant input concentrates in DC: y[0] = 4 * N1 * N2
//! assert!((y[0] - 64.0).abs() < 1e-3);
//! assert!(y[1].abs() < 1e-3);
//! ```

use std::f64::consts::PI;

use crate::fft::elem::{Cx, Element};
use crate::fft::generic::GenRfft2;
use crate::parallel::{par_chunks_mut, ExecPolicy};
use crate::util::scratch::Workspace;

use super::reorder::{reorder_2d_scatter, unreorder_2d};

/// DCT twiddle planes w[k] = e^{-j π k / 2n} for one axis (the generic
/// counterpart of [`super::twiddle::Twiddle`], split re/im, rounded
/// once from `f64`).
#[derive(Debug, Clone)]
struct GenTwiddle<E> {
    re: Vec<E>,
    im: Vec<E>,
}

impl<E: Element> GenTwiddle<E> {
    fn new(n: usize) -> GenTwiddle<E> {
        let step = -PI / (2.0 * n as f64);
        let mut re = Vec::with_capacity(n);
        let mut im = Vec::with_capacity(n);
        for k in 0..n {
            let w: Cx<E> = Cx::cis(step * k as f64);
            re.push(w.re);
            im.push(w.im);
        }
        GenTwiddle { re, im }
    }

    #[inline(always)]
    fn at(&self, k: usize) -> Cx<E> {
        Cx::new(self.re[k], self.im[k])
    }
}

/// Fused 2D DCT plan over a generic element (see the module docs; the
/// `f32` alias is [`Dct2F32`]).
#[derive(Debug, Clone)]
pub struct GenDct2<E> {
    /// Rows.
    pub n1: usize,
    /// Columns.
    pub n2: usize,
    h2: usize,
    rfft2: GenRfft2<E>,
    tw1: GenTwiddle<E>,
    tw2: GenTwiddle<E>,
    policy: ExecPolicy,
    ws: Workspace,
}

impl<E: Element> GenDct2<E> {
    /// Plan for `n1 x n2` inputs with the default (auto) batch policy.
    pub fn new(n1: usize, n2: usize) -> GenDct2<E> {
        Self::with_policy(n1, n2, ExecPolicy::Auto)
    }

    /// Plan with an explicit execution policy (used by
    /// [`GenDct2::forward_batch`] to pick its lane count; the per-block
    /// kernel itself is serial).
    pub fn with_policy(n1: usize, n2: usize, policy: ExecPolicy) -> GenDct2<E> {
        assert!(n1 >= 1 && n2 >= 1);
        let rfft2 = GenRfft2::new(n1, n2);
        let h2 = rfft2.h2;
        let mut ws = Workspace::new();
        E::register_scratch(&mut ws, n1 * n2); // reordered input
        E::register_scratch(&mut ws, n1 * h2); // spectrum re plane
        E::register_scratch(&mut ws, n1 * h2); // spectrum im plane
        rfft2.register_scratch(&mut ws);
        ws.prewarm();
        GenDct2 {
            n1,
            n2,
            h2,
            rfft2,
            tw1: GenTwiddle::new(n1),
            tw2: GenTwiddle::new(n2),
            policy,
            ws,
        }
    }

    /// Scratch manifest of one `forward` call (for prewarming worker
    /// threads).
    pub fn workspace(&self) -> &Workspace {
        &self.ws
    }

    /// Compute the 2D DCT of row-major `x` into `out` (serial kernel).
    pub fn forward(&self, x: &[E], out: &mut [E]) {
        let (n1, n2, h2) = (self.n1, self.n2, self.h2);
        assert_eq!(x.len(), n1 * n2);
        assert_eq!(out.len(), n1 * n2);
        let mut pre = E::take_scratch(n1 * n2);
        reorder_2d_scatter(x, &mut pre, n1, n2);
        let mut sre = E::take_scratch(n1 * h2);
        let mut sim = E::take_scratch(n1 * h2);
        self.rfft2.forward(&pre, &mut sre, &mut sim);
        self.postprocess(&sre, &sim, out);
        E::give_scratch(pre);
        E::give_scratch(sre);
        E::give_scratch(sim);
    }

    /// Batched forward: `batch` packed blocks in, `batch` packed blocks
    /// out, whole blocks fanned out across pool lanes.
    pub fn forward_batch(&self, xs: &[E], out: &mut [E], batch: usize) {
        let numel = self.n1 * self.n2;
        assert_eq!(xs.len(), batch * numel);
        assert_eq!(out.len(), batch * numel);
        if batch == 0 {
            return;
        }
        let lanes = self.policy.lanes(batch * numel).min(batch);
        par_chunks_mut(out, numel, lanes, |b, block| {
            self.forward(&xs[b * numel..(b + 1) * numel], block);
        });
    }

    /// §III-B paired-quadrant combine over split spectrum planes — the
    /// same row-pair walk and arithmetic as `Dct2::postprocess_serial`.
    fn postprocess(&self, sre: &[E], sim: &[E], out: &mut [E]) {
        let (n1, n2, h2) = (self.n1, self.n2, self.h2);
        let two = E::from_f64(2.0);
        for k1 in 0..=n1 / 2 {
            let m1 = (n1 - k1) % n1;
            let (top, mut bot): (&mut [E], Option<&mut [E]>) = if m1 == k1 {
                (&mut out[k1 * n2..(k1 + 1) * n2], None)
            } else {
                // k1 <= n1/2 <= m1 and they differ
                let (head, tail) = out.split_at_mut(m1 * n2);
                (&mut head[k1 * n2..(k1 + 1) * n2], Some(&mut tail[..n2]))
            };
            let a = self.tw1.at(k1);
            let row1 = k1 * h2;
            let row2 = m1 * h2;
            for k2 in 0..h2 {
                let b = self.tw2.at(k2);
                let ab = a * b;
                let abc = a * b.conj();
                let v1 = Cx::new(sre[row1 + k2], sim[row1 + k2]);
                let v2 = Cx::new(sre[row2 + k2], sim[row2 + k2]);
                let p = ab * v1;
                let q = abc * v2.conj();
                top[k2] = two * (p.re + q.re);
                let k2r = n2 - k2; // right-half partner column
                let has_col = k2 > 0 && k2r != k2;
                if has_col {
                    top[k2r] = -(two * (p.im - q.im));
                }
                if let Some(bottom) = bot.as_deref_mut() {
                    let r = abc.conj() * v2;
                    let s = ab.conj() * v1.conj();
                    bottom[k2] = two * (r.im + s.im);
                    if has_col {
                        bottom[k2r] = two * (r.re - s.re);
                    }
                }
            }
        }
    }
}

/// Fused 2D IDCT plan over a generic element (the `f32` alias is
/// [`Idct2F32`]).
#[derive(Debug, Clone)]
pub struct GenIdct2<E> {
    /// Rows.
    pub n1: usize,
    /// Columns.
    pub n2: usize,
    h2: usize,
    rfft2: GenRfft2<E>,
    tw1: GenTwiddle<E>,
    tw2: GenTwiddle<E>,
    policy: ExecPolicy,
    ws: Workspace,
}

impl<E: Element> GenIdct2<E> {
    /// Plan for `n1 x n2` inputs with the default (auto) batch policy.
    pub fn new(n1: usize, n2: usize) -> GenIdct2<E> {
        Self::with_policy(n1, n2, ExecPolicy::Auto)
    }

    /// Plan with an explicit execution policy (batch lane count).
    pub fn with_policy(n1: usize, n2: usize, policy: ExecPolicy) -> GenIdct2<E> {
        assert!(n1 >= 1 && n2 >= 1);
        let rfft2 = GenRfft2::new(n1, n2);
        let h2 = rfft2.h2;
        let mut ws = Workspace::new();
        E::register_scratch(&mut ws, n1 * h2); // spectrum re plane
        E::register_scratch(&mut ws, n1 * h2); // spectrum im plane
        E::register_scratch(&mut ws, n1 * n2); // IRFFT output pre-unreorder
        rfft2.register_scratch(&mut ws);
        ws.prewarm();
        GenIdct2 {
            n1,
            n2,
            h2,
            rfft2,
            tw1: GenTwiddle::new(n1),
            tw2: GenTwiddle::new(n2),
            policy,
            ws,
        }
    }

    /// Scratch manifest of one `forward` call.
    pub fn workspace(&self) -> &Workspace {
        &self.ws
    }

    /// Compute the 2D IDCT of row-major `x` into `out` (serial kernel).
    pub fn forward(&self, x: &[E], out: &mut [E]) {
        let (n1, n2, h2) = (self.n1, self.n2, self.h2);
        assert_eq!(x.len(), n1 * n2);
        assert_eq!(out.len(), n1 * n2);
        let mut sre = E::take_scratch(n1 * h2);
        let mut sim = E::take_scratch(n1 * h2);
        for k1 in 0..n1 {
            self.preprocess_row(x, k1, &mut sre[k1 * h2..(k1 + 1) * h2], &mut sim[k1 * h2..(k1 + 1) * h2]);
        }
        let mut v = E::take_scratch(n1 * n2);
        self.rfft2.inverse(&mut sre, &mut sim, &mut v);
        unreorder_2d(&v, out, n1, n2);
        E::give_scratch(sre);
        E::give_scratch(sim);
        E::give_scratch(v);
    }

    /// Batched inverse: whole blocks fanned out across pool lanes.
    pub fn forward_batch(&self, xs: &[E], out: &mut [E], batch: usize) {
        let numel = self.n1 * self.n2;
        assert_eq!(xs.len(), batch * numel);
        assert_eq!(out.len(), batch * numel);
        if batch == 0 {
            return;
        }
        let lanes = self.policy.lanes(batch * numel).min(batch);
        par_chunks_mut(out, numel, lanes, |b, block| {
            self.forward(&xs[b * numel..(b + 1) * numel], block);
        });
    }

    /// Build one onesided spectrum row (corrected Eq. 15), split-plane
    /// version of `Idct2::preprocess_row`.
    fn preprocess_row(&self, x: &[E], k1: usize, srow_re: &mut [E], srow_im: &mut [E]) {
        let (n1, n2, h2) = (self.n1, self.n2, self.h2);
        debug_assert_eq!(srow_re.len(), h2);
        debug_assert_eq!(srow_im.len(), h2);
        let quarter = E::from_f64(0.25);
        let ac = self.tw1.at(k1).conj();
        for k2 in 0..h2 {
            let bc = self.tw2.at(k2).conj();
            let x11 = x[k1 * n2 + k2];
            let x21 = if k1 == 0 { E::ZERO } else { x[(n1 - k1) * n2 + k2] };
            let x12 = if k2 == 0 { E::ZERO } else { x[k1 * n2 + (n2 - k2)] };
            let x22 = if k1 == 0 || k2 == 0 {
                E::ZERO
            } else {
                x[(n1 - k1) * n2 + (n2 - k2)]
            };
            let z = Cx::new(x11 - x22, -(x21 + x12));
            let v = (ac * bc * z).scale(quarter);
            srow_re[k2] = v.re;
            srow_im[k2] = v.im;
        }
    }
}

/// Single-precision fused 2D DCT (the `ElemType::F32` plan).
pub type Dct2F32 = GenDct2<f32>;
/// Single-precision fused 2D IDCT (the `ElemType::F32` plan).
pub type Idct2F32 = GenIdct2<f32>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::dct2d::{Dct2, Idct2};

    fn rel_close(got: &[f32], want: &[f64], tol: f64) -> Result<(), String> {
        let scale = want.iter().fold(1.0f64, |m, v| m.max(v.abs()));
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            let rel = (*g as f64 - w).abs() / scale;
            if rel > tol {
                return Err(format!("idx {i}: {g} vs {w} (rel {rel:.2e})"));
            }
        }
        Ok(())
    }

    #[test]
    fn gen_f64_matches_dedicated_plan() {
        let mut rng = crate::util::rng::Rng::new(50);
        for &(n1, n2) in &[(1usize, 8usize), (4, 4), (8, 8), (9, 15), (13, 7), (16, 16)] {
            let x = rng.normal_vec(n1 * n2);
            let mut want = vec![0.0; n1 * n2];
            Dct2::new(n1, n2).forward(&x, &mut want);
            let plan: GenDct2<f64> = GenDct2::new(n1, n2);
            let mut got = vec![0.0; n1 * n2];
            plan.forward(&x, &mut got);
            let scale = (n1 * n2) as f64;
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-8 * scale, "dct2 {n1}x{n2}");
            }
            let mut iwant = vec![0.0; n1 * n2];
            Idct2::new(n1, n2).forward(&want, &mut iwant);
            let iplan: GenIdct2<f64> = GenIdct2::new(n1, n2);
            let mut igot = vec![0.0; n1 * n2];
            iplan.forward(&got, &mut igot);
            for (g, w) in igot.iter().zip(&iwant) {
                assert!((g - w).abs() < 1e-7 * scale, "idct2 {n1}x{n2}");
            }
        }
    }

    #[test]
    fn f32_tracks_f64_oracle() {
        let mut rng = crate::util::rng::Rng::new(51);
        for &(n1, n2) in &[(8usize, 8usize), (9, 15), (16, 16), (13, 7)] {
            let x = rng.normal_vec(n1 * n2);
            let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
            let mut want = vec![0.0; n1 * n2];
            Dct2::new(n1, n2).forward(&x, &mut want);
            let plan = Dct2F32::new(n1, n2);
            let mut got = vec![0.0f32; n1 * n2];
            plan.forward(&x32, &mut got);
            rel_close(&got, &want, 1e-4).unwrap();
            // inverse roundtrips back to the input at f32 accuracy
            let iplan = Idct2F32::new(n1, n2);
            let mut back = vec![0.0f32; n1 * n2];
            iplan.forward(&got, &mut back);
            rel_close(&back, &x, 1e-3).unwrap();
        }
    }

    #[test]
    fn batch_matches_solo_bitwise() {
        use crate::parallel::ExecPolicy;
        let mut rng = crate::util::rng::Rng::new(52);
        let (n1, n2, batch) = (8usize, 12usize, 5usize);
        let numel = n1 * n2;
        let xs: Vec<f32> = rng.normal_vec(numel * batch).iter().map(|&v| v as f32).collect();
        for exec in [ExecPolicy::Serial, ExecPolicy::Threads(4)] {
            let plan = Dct2F32::with_policy(n1, n2, exec);
            let mut want = vec![0.0f32; numel * batch];
            for (b, w) in want.chunks_mut(numel).enumerate() {
                plan.forward(&xs[b * numel..(b + 1) * numel], w);
            }
            let mut got = vec![0.0f32; numel * batch];
            plan.forward_batch(&xs, &mut got, batch);
            assert_eq!(got, want, "{exec:?}");
            let iplan = Idct2F32::with_policy(n1, n2, exec);
            let mut iwant = vec![0.0f32; numel * batch];
            for (b, w) in iwant.chunks_mut(numel).enumerate() {
                iplan.forward(&want[b * numel..(b + 1) * numel], w);
            }
            let mut igot = vec![0.0f32; numel * batch];
            iplan.forward_batch(&got, &mut igot, batch);
            assert_eq!(igot, iwant, "{exec:?}");
        }
    }
}
