//! Butterfly (even/odd) reorderings — the paper's preprocessing stage
//! (Eq. 9 / Eq. 13) in both *gather* and *scatter* traversal orders
//! (paper §III-A, Fig. 3, Table II).
//!
//! On a GPU the two orders trade coalesced reads for coalesced writes; on
//! a CPU they trade sequential reads for sequential writes. Both are
//! exposed so `benches/table2_gather_scatter.rs` can reproduce Table II's
//! observation that they perform the same; the library default is scatter
//! (sequential reads), matching the paper's choice.

/// 1D butterfly reorder source index: v[i] = x[src_index_1d(i, n)].
#[inline(always)]
pub fn src_index_1d(i: usize, n: usize) -> usize {
    let half = (n + 1) / 2; // ceil(n/2) entries come from even positions
    if i < half {
        2 * i
    } else {
        2 * (n - i) - 1
    }
}

/// 1D butterfly destination index: v[dst_index_1d(i, n)] = x[i].
#[inline(always)]
pub fn dst_index_1d(i: usize, n: usize) -> usize {
    if i % 2 == 0 {
        i / 2
    } else {
        n - (i + 1) / 2
    }
}

/// 1D reorder, gather order (loop over outputs; sequential writes).
pub fn reorder_1d_gather(x: &[f64], out: &mut [f64]) {
    let n = x.len();
    debug_assert_eq!(out.len(), n);
    for (i, o) in out.iter_mut().enumerate() {
        *o = x[src_index_1d(i, n)];
    }
}

/// 1D reorder, scatter order (loop over inputs; sequential reads).
pub fn reorder_1d_scatter(x: &[f64], out: &mut [f64]) {
    let n = x.len();
    debug_assert_eq!(out.len(), n);
    for (i, &v) in x.iter().enumerate() {
        out[dst_index_1d(i, n)] = v;
    }
}

/// Inverse 1D reorder (Eq. 16 restricted to one axis).
pub fn unreorder_1d(v: &[f64], out: &mut [f64]) {
    let n = v.len();
    debug_assert_eq!(out.len(), n);
    for (i, o) in out.iter_mut().enumerate() {
        *o = v[dst_index_1d(i, n)];
    }
}

/// One output row of the 2D gather reorder: fills `out_row` with
/// reordered row `r`. Row-local writes make this the parallel kernel
/// behind the fused preprocess (each pool lane owns a band of rows).
#[inline]
pub fn reorder_2d_gather_row(x: &[f64], out_row: &mut [f64], r: usize, n1: usize, n2: usize) {
    debug_assert_eq!(x.len(), n1 * n2);
    debug_assert_eq!(out_row.len(), n2);
    let sr = src_index_1d(r, n1);
    let src = &x[sr * n2..(sr + 1) * n2];
    for (c, d) in out_row.iter_mut().enumerate() {
        *d = src[src_index_1d(c, n2)];
    }
}

/// 2D fused butterfly reorder (Eq. 13), gather order: one pass over the
/// output matrix, reading x[src1][src2].
pub fn reorder_2d_gather(x: &[f64], out: &mut [f64], n1: usize, n2: usize) {
    debug_assert_eq!(x.len(), n1 * n2);
    debug_assert_eq!(out.len(), n1 * n2);
    for (r, row) in out.chunks_mut(n2).enumerate() {
        reorder_2d_gather_row(x, row, r, n1, n2);
    }
}

/// 2D fused butterfly reorder (Eq. 13), scatter order: one pass over the
/// input matrix, writing out[dst1][dst2]. Sequential reads, strided
/// writes — the order the paper adopts.
pub fn reorder_2d_scatter(x: &[f64], out: &mut [f64], n1: usize, n2: usize) {
    debug_assert_eq!(x.len(), n1 * n2);
    debug_assert_eq!(out.len(), n1 * n2);
    for r in 0..n1 {
        let dr = dst_index_1d(r, n1);
        let src = &x[r * n2..(r + 1) * n2];
        let dst = &mut out[dr * n2..(dr + 1) * n2];
        for (c, &v) in src.iter().enumerate() {
            dst[dst_index_1d(c, n2)] = v;
        }
    }
}

/// One output row of the 2D un-reorder (parallel kernel of the fused
/// IDCT postprocess): y[r][c] = v[dst1(r)][dst2(c)].
#[inline]
pub fn unreorder_2d_row(v: &[f64], out_row: &mut [f64], r: usize, n1: usize, n2: usize) {
    debug_assert_eq!(v.len(), n1 * n2);
    debug_assert_eq!(out_row.len(), n2);
    let sr = dst_index_1d(r, n1);
    let src = &v[sr * n2..(sr + 1) * n2];
    for (c, d) in out_row.iter_mut().enumerate() {
        *d = src[dst_index_1d(c, n2)];
    }
}

/// Inverse of the 2D reorder (Eq. 16): y[r][c] = v[dst1(r)][dst2(c)].
pub fn unreorder_2d(v: &[f64], out: &mut [f64], n1: usize, n2: usize) {
    debug_assert_eq!(v.len(), n1 * n2);
    debug_assert_eq!(out.len(), n1 * n2);
    for (r, row) in out.chunks_mut(n2).enumerate() {
        unreorder_2d_row(v, row, r, n1, n2);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, shapes, sizes};

    #[test]
    fn index_maps_are_inverse() {
        for n in 1..64 {
            for i in 0..n {
                assert_eq!(dst_index_1d(src_index_1d(i, n), n), i, "n={n} i={i}");
                assert_eq!(src_index_1d(dst_index_1d(i, n), n), i, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn gather_equals_scatter_1d() {
        forall(50, sizes(1, 97), |rng, &n| {
            let x = rng.normal_vec(n);
            let mut a = vec![0.0; n];
            let mut b = vec![0.0; n];
            reorder_1d_gather(&x, &mut a);
            reorder_1d_scatter(&x, &mut b);
            if a == b {
                Ok(())
            } else {
                Err(format!("gather != scatter at n={n}"))
            }
        });
    }

    #[test]
    fn matches_paper_eq9_example() {
        // N = 8: v = [x0, x2, x4, x6, x7, x5, x3, x1]
        let x: Vec<f64> = (0..8).map(|i| i as f64).collect();
        let mut v = vec![0.0; 8];
        reorder_1d_gather(&x, &mut v);
        assert_eq!(v, vec![0.0, 2.0, 4.0, 6.0, 7.0, 5.0, 3.0, 1.0]);
    }

    #[test]
    fn reorder_2d_is_bijective_and_orders_agree() {
        forall(30, shapes(1, 24), |rng, &(n1, n2)| {
            let x = rng.normal_vec(n1 * n2);
            let mut g = vec![0.0; n1 * n2];
            let mut s = vec![0.0; n1 * n2];
            reorder_2d_gather(&x, &mut g, n1, n2);
            reorder_2d_scatter(&x, &mut s, n1, n2);
            if g != s {
                return Err("gather != scatter".into());
            }
            let mut back = vec![0.0; n1 * n2];
            unreorder_2d(&g, &mut back, n1, n2);
            crate::util::prop::check_close(&back, &x, 0.0)
        });
    }

    #[test]
    fn unreorder_1d_inverts() {
        let x: Vec<f64> = (0..13).map(|i| i as f64).collect();
        let mut v = vec![0.0; 13];
        let mut back = vec![0.0; 13];
        reorder_1d_scatter(&x, &mut v);
        unreorder_1d(&v, &mut back);
        assert_eq!(back, x);
    }
}
