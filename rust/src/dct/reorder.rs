//! Butterfly (even/odd) reorderings — the paper's preprocessing stage
//! (Eq. 9 / Eq. 13) in both *gather* and *scatter* traversal orders
//! (paper §III-A, Fig. 3, Table II).
//!
//! On a GPU the two orders trade coalesced reads for coalesced writes; on
//! a CPU they trade sequential reads for sequential writes. Both are
//! exposed so `benches/table2_gather_scatter.rs` can reproduce Table II's
//! observation that they perform the same; the library default is scatter
//! (sequential reads), matching the paper's choice.

/// 1D butterfly reorder source index: v[i] = x[src_index_1d(i, n)].
#[inline(always)]
pub fn src_index_1d(i: usize, n: usize) -> usize {
    let half = (n + 1) / 2; // ceil(n/2) entries come from even positions
    if i < half {
        2 * i
    } else {
        2 * (n - i) - 1
    }
}

/// 1D butterfly destination index: v[dst_index_1d(i, n)] = x[i].
#[inline(always)]
pub fn dst_index_1d(i: usize, n: usize) -> usize {
    if i % 2 == 0 {
        i / 2
    } else {
        n - (i + 1) / 2
    }
}

/// 1D reorder, gather order (loop over outputs; sequential writes).
///
/// Generic over the element (`f64` plans and the generic `f32` core
/// share one reorder implementation; the index math is type-free).
pub fn reorder_1d_gather<T: Copy>(x: &[T], out: &mut [T]) {
    let n = x.len();
    debug_assert_eq!(out.len(), n);
    for (i, o) in out.iter_mut().enumerate() {
        *o = x[src_index_1d(i, n)];
    }
}

/// 1D reorder, scatter order (loop over inputs; sequential reads).
pub fn reorder_1d_scatter<T: Copy>(x: &[T], out: &mut [T]) {
    let n = x.len();
    debug_assert_eq!(out.len(), n);
    for (i, &v) in x.iter().enumerate() {
        out[dst_index_1d(i, n)] = v;
    }
}

/// Inverse 1D reorder (Eq. 16 restricted to one axis).
pub fn unreorder_1d<T: Copy>(v: &[T], out: &mut [T]) {
    let n = v.len();
    debug_assert_eq!(out.len(), n);
    for (i, o) in out.iter_mut().enumerate() {
        *o = v[dst_index_1d(i, n)];
    }
}

/// One output row of the 2D gather reorder: fills `out_row` with
/// reordered row `r`. Row-local writes make this the parallel kernel
/// behind the fused preprocess (each pool lane owns a band of rows).
#[inline]
pub fn reorder_2d_gather_row<T: Copy>(x: &[T], out_row: &mut [T], r: usize, n1: usize, n2: usize) {
    debug_assert_eq!(x.len(), n1 * n2);
    debug_assert_eq!(out_row.len(), n2);
    let sr = src_index_1d(r, n1);
    let src = &x[sr * n2..(sr + 1) * n2];
    for (c, d) in out_row.iter_mut().enumerate() {
        *d = src[src_index_1d(c, n2)];
    }
}

/// 2D fused butterfly reorder (Eq. 13), gather order: one pass over the
/// output matrix, reading x[src1][src2].
pub fn reorder_2d_gather<T: Copy>(x: &[T], out: &mut [T], n1: usize, n2: usize) {
    debug_assert_eq!(x.len(), n1 * n2);
    debug_assert_eq!(out.len(), n1 * n2);
    for (r, row) in out.chunks_mut(n2).enumerate() {
        reorder_2d_gather_row(x, row, r, n1, n2);
    }
}

/// 2D fused butterfly reorder (Eq. 13), scatter order: one pass over the
/// input matrix, writing out[dst1][dst2]. Sequential reads, strided
/// writes — the order the paper adopts.
pub fn reorder_2d_scatter<T: Copy>(x: &[T], out: &mut [T], n1: usize, n2: usize) {
    debug_assert_eq!(x.len(), n1 * n2);
    debug_assert_eq!(out.len(), n1 * n2);
    for r in 0..n1 {
        let dr = dst_index_1d(r, n1);
        let src = &x[r * n2..(r + 1) * n2];
        let dst = &mut out[dr * n2..(dr + 1) * n2];
        for (c, &v) in src.iter().enumerate() {
            dst[dst_index_1d(c, n2)] = v;
        }
    }
}

/// Strided-view variant of [`reorder_2d_scatter`]: the logical
/// `n1 x n2` input lives in `x` at per-axis element strides
/// `(s1, s2)` — `x[r * s1 + c * s2]` is element `(r, c)`. The output
/// is the same packed reordered matrix as the contiguous scatter: for
/// `(s1, s2) = (n2, 1)` this reads exactly the same values in the same
/// order, so the result is identical.
pub fn reorder_2d_scatter_strided<T: Copy>(
    x: &[T],
    s1: usize,
    s2: usize,
    out: &mut [T],
    n1: usize,
    n2: usize,
) {
    debug_assert_eq!(out.len(), n1 * n2);
    debug_assert!(x.len() > (n1 - 1) * s1 + (n2 - 1) * s2, "strided input too short");
    for r in 0..n1 {
        let dr = dst_index_1d(r, n1);
        let dst = &mut out[dr * n2..(dr + 1) * n2];
        let base = r * s1;
        if s2 == 1 {
            // unit inner stride: row is a contiguous slice
            let src = &x[base..base + n2];
            for (c, &v) in src.iter().enumerate() {
                dst[dst_index_1d(c, n2)] = v;
            }
        } else {
            for c in 0..n2 {
                dst[dst_index_1d(c, n2)] = x[base + c * s2];
            }
        }
    }
}

/// Strided-view variant of [`reorder_2d_gather_row`] (the parallel
/// per-row kernel): fills packed output row `r` from the strided
/// `(s1, s2)` view of the logical input.
#[inline]
pub fn reorder_2d_gather_row_strided<T: Copy>(
    x: &[T],
    s1: usize,
    s2: usize,
    out_row: &mut [T],
    r: usize,
    n1: usize,
    n2: usize,
) {
    debug_assert_eq!(out_row.len(), n2);
    let base = src_index_1d(r, n1) * s1;
    if s2 == 1 {
        let src = &x[base..base + n2];
        for (c, d) in out_row.iter_mut().enumerate() {
            *d = src[src_index_1d(c, n2)];
        }
    } else {
        for (c, d) in out_row.iter_mut().enumerate() {
            *d = x[base + src_index_1d(c, n2) * s2];
        }
    }
}

/// One output row of the 2D un-reorder (parallel kernel of the fused
/// IDCT postprocess): y[r][c] = v[dst1(r)][dst2(c)].
#[inline]
pub fn unreorder_2d_row<T: Copy>(v: &[T], out_row: &mut [T], r: usize, n1: usize, n2: usize) {
    debug_assert_eq!(v.len(), n1 * n2);
    debug_assert_eq!(out_row.len(), n2);
    let sr = dst_index_1d(r, n1);
    let src = &v[sr * n2..(sr + 1) * n2];
    for (c, d) in out_row.iter_mut().enumerate() {
        *d = src[dst_index_1d(c, n2)];
    }
}

/// Inverse of the 2D reorder (Eq. 16): y[r][c] = v[dst1(r)][dst2(c)].
pub fn unreorder_2d<T: Copy>(v: &[T], out: &mut [T], n1: usize, n2: usize) {
    debug_assert_eq!(v.len(), n1 * n2);
    debug_assert_eq!(out.len(), n1 * n2);
    for (r, row) in out.chunks_mut(n2).enumerate() {
        unreorder_2d_row(v, row, r, n1, n2);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, shapes, sizes};

    #[test]
    fn index_maps_are_inverse() {
        for n in 1..64 {
            for i in 0..n {
                assert_eq!(dst_index_1d(src_index_1d(i, n), n), i, "n={n} i={i}");
                assert_eq!(src_index_1d(dst_index_1d(i, n), n), i, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn gather_equals_scatter_1d() {
        forall(50, sizes(1, 97), |rng, &n| {
            let x = rng.normal_vec(n);
            let mut a = vec![0.0; n];
            let mut b = vec![0.0; n];
            reorder_1d_gather(&x, &mut a);
            reorder_1d_scatter(&x, &mut b);
            if a == b {
                Ok(())
            } else {
                Err(format!("gather != scatter at n={n}"))
            }
        });
    }

    #[test]
    fn matches_paper_eq9_example() {
        // N = 8: v = [x0, x2, x4, x6, x7, x5, x3, x1]
        let x: Vec<f64> = (0..8).map(|i| i as f64).collect();
        let mut v = vec![0.0; 8];
        reorder_1d_gather(&x, &mut v);
        assert_eq!(v, vec![0.0, 2.0, 4.0, 6.0, 7.0, 5.0, 3.0, 1.0]);
    }

    #[test]
    fn reorder_2d_is_bijective_and_orders_agree() {
        forall(30, shapes(1, 24), |rng, &(n1, n2)| {
            let x = rng.normal_vec(n1 * n2);
            let mut g = vec![0.0; n1 * n2];
            let mut s = vec![0.0; n1 * n2];
            reorder_2d_gather(&x, &mut g, n1, n2);
            reorder_2d_scatter(&x, &mut s, n1, n2);
            if g != s {
                return Err("gather != scatter".into());
            }
            let mut back = vec![0.0; n1 * n2];
            unreorder_2d(&g, &mut back, n1, n2);
            crate::util::prop::check_close(&back, &x, 0.0)
        });
    }

    #[test]
    fn strided_scatter_matches_contiguous() {
        forall(30, shapes(1, 16), |rng, &(n1, n2)| {
            let x = rng.normal_vec(n1 * n2);
            // embed in a padded arena with strides (s1, s2)
            let (s1, s2) = (n2 * 3 + 1, 3);
            let mut arena = vec![0.0f64; (n1 - 1) * s1 + (n2 - 1) * s2 + 1];
            for r in 0..n1 {
                for c in 0..n2 {
                    arena[r * s1 + c * s2] = x[r * n2 + c];
                }
            }
            let mut want = vec![0.0; n1 * n2];
            reorder_2d_scatter(&x, &mut want, n1, n2);
            let mut got = vec![0.0; n1 * n2];
            reorder_2d_scatter_strided(&arena, s1, s2, &mut got, n1, n2);
            if got != want {
                return Err("strided scatter diverged".into());
            }
            // unit inner stride fast path
            let mut padded = vec![0.0f64; n1 * (n2 + 5)];
            for r in 0..n1 {
                padded[r * (n2 + 5)..r * (n2 + 5) + n2].copy_from_slice(&x[r * n2..(r + 1) * n2]);
            }
            let mut got_unit = vec![0.0; n1 * n2];
            reorder_2d_scatter_strided(&padded, n2 + 5, 1, &mut got_unit, n1, n2);
            if got_unit != want {
                return Err("unit-stride scatter diverged".into());
            }
            let mut grow = vec![0.0; n1 * n2];
            for r in 0..n1 {
                reorder_2d_gather_row_strided(
                    &arena,
                    s1,
                    s2,
                    &mut grow[r * n2..(r + 1) * n2],
                    r,
                    n1,
                    n2,
                );
            }
            if grow != want {
                return Err("strided gather row diverged".into());
            }
            Ok(())
        });
    }

    #[test]
    fn unreorder_1d_inverts() {
        let x: Vec<f64> = (0..13).map(|i| i as f64).collect();
        let mut v = vec![0.0; 13];
        let mut back = vec![0.0; 13];
        reorder_1d_scatter(&x, &mut v);
        unreorder_1d(&v, &mut back);
        assert_eq!(back, x);
    }
}
