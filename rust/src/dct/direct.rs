//! Direct O(N^2) transforms — the in-Rust oracle (mirrors python ref.py)
//! and the "unoptimized library baseline" stand-in for Table V's MATLAB
//! column.
//!
//! Conventions match DESIGN.md:
//!   dct(x)[k]  = 2 sum_n x[n] cos(pi k (2n+1) / 2N)
//!   idct       = exact inverse of dct
//!   idxst(x)_k = (-1)^k idct({x[N-n]})_k, x[N] := 0

/// Direct 1D DCT-II along a slice.
pub fn dct1d_direct(x: &[f64]) -> Vec<f64> {
    let n = x.len();
    let mut out = vec![0.0; n];
    for (k, o) in out.iter_mut().enumerate() {
        let mut acc = 0.0;
        for (m, &v) in x.iter().enumerate() {
            acc += v
                * (std::f64::consts::PI * k as f64 * (2 * m + 1) as f64
                    / (2.0 * n as f64))
                    .cos();
        }
        *o = 2.0 * acc;
    }
    out
}

/// Direct 1D inverse DCT.
pub fn idct1d_direct(x: &[f64]) -> Vec<f64> {
    let n = x.len();
    let mut out = vec![0.0; n];
    for (k, o) in out.iter_mut().enumerate() {
        let mut acc = x[0];
        for (m, &v) in x.iter().enumerate().skip(1) {
            acc += 2.0
                * v
                * (std::f64::consts::PI * m as f64 * (2 * k + 1) as f64
                    / (2.0 * n as f64))
                    .cos();
        }
        *o = acc / (2.0 * n as f64);
    }
    out
}

/// Direct 1D IDXST (paper Eq. 21).
pub fn idxst1d_direct(x: &[f64]) -> Vec<f64> {
    let n = x.len();
    let mut shifted = vec![0.0; n];
    for i in 1..n {
        shifted[i] = x[n - i];
    }
    let mut y = idct1d_direct(&shifted);
    for (k, v) in y.iter_mut().enumerate() {
        if k % 2 == 1 {
            *v = -*v;
        }
    }
    y
}

fn apply_rows(f: impl Fn(&[f64]) -> Vec<f64>, x: &[f64], n1: usize, n2: usize) -> Vec<f64> {
    let mut out = vec![0.0; n1 * n2];
    for r in 0..n1 {
        out[r * n2..(r + 1) * n2].copy_from_slice(&f(&x[r * n2..(r + 1) * n2]));
    }
    out
}

fn apply_cols(f: impl Fn(&[f64]) -> Vec<f64>, x: &[f64], n1: usize, n2: usize) -> Vec<f64> {
    let mut out = vec![0.0; n1 * n2];
    let mut col = vec![0.0; n1];
    for c in 0..n2 {
        for r in 0..n1 {
            col[r] = x[r * n2 + c];
        }
        let y = f(&col);
        for r in 0..n1 {
            out[r * n2 + c] = y[r];
        }
    }
    out
}

/// Direct separable 2D DCT (rows then columns).
pub fn dct2d_direct(x: &[f64], n1: usize, n2: usize) -> Vec<f64> {
    apply_cols(dct1d_direct, &apply_rows(dct1d_direct, x, n1, n2), n1, n2)
}

/// Direct separable 2D IDCT.
pub fn idct2d_direct(x: &[f64], n1: usize, n2: usize) -> Vec<f64> {
    apply_cols(idct1d_direct, &apply_rows(idct1d_direct, x, n1, n2), n1, n2)
}

/// Direct IDCT_IDXST (IDCT along rows, IDXST along columns; Eq. 22).
pub fn idct_idxst_direct(x: &[f64], n1: usize, n2: usize) -> Vec<f64> {
    apply_cols(idxst1d_direct, &apply_rows(idct1d_direct, x, n1, n2), n1, n2)
}

/// Direct IDXST_IDCT (IDXST along rows, IDCT along columns; Eq. 22).
pub fn idxst_idct_direct(x: &[f64], n1: usize, n2: usize) -> Vec<f64> {
    apply_cols(idct1d_direct, &apply_rows(idxst1d_direct, x, n1, n2), n1, n2)
}

/// Direct separable 3D DCT (oracle for the 3D extension).
pub fn dct3d_direct(x: &[f64], n1: usize, n2: usize, n3: usize) -> Vec<f64> {
    // along dim 3
    let mut a = vec![0.0; n1 * n2 * n3];
    for s in 0..n1 * n2 {
        a[s * n3..(s + 1) * n3].copy_from_slice(&dct1d_direct(&x[s * n3..(s + 1) * n3]));
    }
    // along dim 2
    let mut b = vec![0.0; n1 * n2 * n3];
    let mut buf = vec![0.0; n2];
    for i in 0..n1 {
        for c in 0..n3 {
            for j in 0..n2 {
                buf[j] = a[(i * n2 + j) * n3 + c];
            }
            let y = dct1d_direct(&buf);
            for j in 0..n2 {
                b[(i * n2 + j) * n3 + c] = y[j];
            }
        }
    }
    // along dim 1
    let mut out = vec![0.0; n1 * n2 * n3];
    let mut buf1 = vec![0.0; n1];
    for j in 0..n2 {
        for c in 0..n3 {
            for i in 0..n1 {
                buf1[i] = b[(i * n2 + j) * n3 + c];
            }
            let y = dct1d_direct(&buf1);
            for i in 0..n1 {
                out[(i * n2 + j) * n3 + c] = y[i];
            }
        }
    }
    out
}

/// Direct separable 3D IDCT (oracle for the fused 3D inverse).
pub fn idct3d_direct(x: &[f64], n1: usize, n2: usize, n3: usize) -> Vec<f64> {
    // along dim 3
    let mut a = vec![0.0; n1 * n2 * n3];
    for s in 0..n1 * n2 {
        a[s * n3..(s + 1) * n3].copy_from_slice(&idct1d_direct(&x[s * n3..(s + 1) * n3]));
    }
    // along dim 2
    let mut b = vec![0.0; n1 * n2 * n3];
    let mut buf = vec![0.0; n2];
    for i in 0..n1 {
        for c in 0..n3 {
            for j in 0..n2 {
                buf[j] = a[(i * n2 + j) * n3 + c];
            }
            let y = idct1d_direct(&buf);
            for j in 0..n2 {
                b[(i * n2 + j) * n3 + c] = y[j];
            }
        }
    }
    // along dim 1
    let mut out = vec![0.0; n1 * n2 * n3];
    let mut buf1 = vec![0.0; n1];
    for j in 0..n2 {
        for c in 0..n3 {
            for i in 0..n1 {
                buf1[i] = b[(i * n2 + j) * n3 + c];
            }
            let y = idct1d_direct(&buf1);
            for i in 0..n1 {
                out[(i * n2 + j) * n3 + c] = y[i];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check_close;
    use crate::util::rng::Rng;

    #[test]
    fn idct_inverts_dct() {
        let mut rng = Rng::new(40);
        for &n in &[1usize, 2, 5, 8, 13] {
            let x = rng.normal_vec(n);
            check_close(&idct1d_direct(&dct1d_direct(&x)), &x, 1e-10).unwrap();
        }
    }

    #[test]
    fn dct_dc_term_is_double_sum() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = dct1d_direct(&x);
        assert!((y[0] - 20.0).abs() < 1e-12); // 2 * sum
    }

    #[test]
    fn dct2d_separable_order_invariant() {
        let mut rng = Rng::new(41);
        let (n1, n2) = (6, 9);
        let x = rng.normal_vec(n1 * n2);
        let a = dct2d_direct(&x, n1, n2);
        let b = apply_rows(dct1d_direct, &apply_cols(dct1d_direct, &x, n1, n2), n1, n2);
        check_close(&a, &b, 1e-10).unwrap();
    }

    #[test]
    fn idct2d_inverts_dct2d() {
        let mut rng = Rng::new(42);
        let (n1, n2) = (7, 5);
        let x = rng.normal_vec(n1 * n2);
        check_close(&idct2d_direct(&dct2d_direct(&x, n1, n2), n1, n2), &x, 1e-10).unwrap();
    }

    #[test]
    fn idxst_ignores_dc_input() {
        let mut rng = Rng::new(43);
        let mut x = rng.normal_vec(9);
        let a = idxst1d_direct(&x);
        x[0] = 1e6;
        let b = idxst1d_direct(&x);
        check_close(&a, &b, 1e-12).unwrap();
    }

    #[test]
    fn idct3d_inverts_dct3d() {
        let mut rng = Rng::new(45);
        for &(n1, n2, n3) in &[(1usize, 1usize, 1usize), (2, 3, 4), (3, 4, 5)] {
            let x = rng.normal_vec(n1 * n2 * n3);
            let y = dct3d_direct(&x, n1, n2, n3);
            check_close(&idct3d_direct(&y, n1, n2, n3), &x, 1e-10).unwrap();
        }
    }

    #[test]
    fn dct3d_dc_is_8x_sum() {
        // X[0,0,0] = 2^3 * sum(x)
        let mut rng = Rng::new(44);
        let (n1, n2, n3) = (3, 4, 5);
        let x = rng.normal_vec(n1 * n2 * n3);
        let y = dct3d_direct(&x, n1, n2, n3);
        let sum: f64 = x.iter().sum();
        assert!((y[0] - 8.0 * sum).abs() < 1e-9);
    }
}
