//! General-purpose substrates built in-repo because the offline crate set
//! lacks serde_json / rand / proptest / criterion-statistics equivalents.

pub mod error;
pub mod json;
pub mod scratch;
pub mod prop;
pub mod rng;
pub mod stats;
