//! General-purpose substrates built in-repo because the offline crate set
//! lacks serde_json / rand / proptest / criterion-statistics equivalents.
#![warn(missing_docs)]

pub mod error;
pub mod json;
pub mod scratch;
pub mod prop;
pub mod rng;
pub mod stats;

/// Parse a positive usize from an env var; `None` for unset, empty,
/// zero, or garbage. Shared by the thread-count, worker-count, and
/// panel-width knobs so the parsing rules cannot drift.
pub fn env_usize(name: &str) -> Option<usize> {
    std::env::var(name).ok()?.trim().parse::<usize>().ok().filter(|&n| n >= 1)
}
