//! Property-based testing mini-framework (proptest substitute).
//!
//! Provides generators over a seeded [`Rng`](crate::util::rng::Rng), a
//! `forall` runner with failure-case reporting and simple input shrinking
//! for sized inputs (halving dimensions), and convenience generators for
//! the transform domain (sizes, matrices, vectors).
//!
//! ```ignore
//! forall(100, sizes(1, 64), |rng, n| {
//!     let x = vec_normal(rng, n);
//!     check_close(&idct(&dct(&x)), &x, 1e-9)
//! });
//! ```

use crate::util::rng::Rng;

/// Outcome of a single property check.
pub type PropResult = Result<(), String>;

/// Assert two slices are elementwise close; returns a readable error.
pub fn check_close(got: &[f64], want: &[f64], tol: f64) -> PropResult {
    if got.len() != want.len() {
        return Err(format!("length mismatch: {} vs {}", got.len(), want.len()));
    }
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let scale = 1.0f64.max(w.abs());
        if (g - w).abs() > tol * scale {
            return Err(format!(
                "mismatch at {i}: got {g}, want {w} (|diff|={}, tol={tol})",
                (g - w).abs()
            ));
        }
    }
    Ok(())
}

/// Run `prop` on `cases` random inputs drawn by `gen`; panic with the
/// seed + a shrunk counterexample description on failure.
pub fn forall<T: Clone + std::fmt::Debug>(
    cases: usize,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&mut Rng, &T) -> PropResult,
) {
    let base_seed = 0xC0FFEE_u64;
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64 * 0x9E3779B9);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        let mut prng = Rng::new(seed ^ 0xABCD);
        if let Err(msg) = prop(&mut prng, &input) {
            panic!(
                "property failed on case {case} (seed {seed:#x})\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Generator: integer size in [lo, hi].
pub fn sizes(lo: usize, hi: usize) -> impl Fn(&mut Rng) -> usize {
    move |rng| rng.range(lo, hi)
}

/// Generator: (n1, n2) pair, each in [lo, hi].
pub fn shapes(lo: usize, hi: usize) -> impl Fn(&mut Rng) -> (usize, usize) {
    move |rng| (rng.range(lo, hi), rng.range(lo, hi))
}

/// Generator: power-of-two size with exponent in [lo_exp, hi_exp].
pub fn pow2_sizes(lo_exp: u32, hi_exp: u32) -> impl Fn(&mut Rng) -> usize {
    move |rng| 1usize << rng.range(lo_exp as usize, hi_exp as usize)
}

/// Normal random vector of length n.
pub fn vec_normal(rng: &mut Rng, n: usize) -> Vec<f64> {
    rng.normal_vec(n)
}

/// Normal random row-major matrix.
pub fn mat_normal(rng: &mut Rng, n1: usize, n2: usize) -> Vec<f64> {
    rng.normal_vec(n1 * n2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(50, sizes(1, 100), |rng, &n| {
            let v = vec_normal(rng, n);
            if v.len() == n {
                Ok(())
            } else {
                Err("len".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failures() {
        forall(10, sizes(1, 4), |_rng, &n| {
            if n < 3 {
                Ok(())
            } else {
                Err("n too big".into())
            }
        });
    }

    #[test]
    fn check_close_catches_mismatch() {
        assert!(check_close(&[1.0, 2.0], &[1.0, 2.1], 1e-3).is_err());
        assert!(check_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-12], 1e-9).is_ok());
        assert!(check_close(&[1.0], &[1.0, 2.0], 1e-9).is_err());
    }

    #[test]
    fn generators_in_bounds() {
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            let n = sizes(3, 9)(&mut rng);
            assert!((3..=9).contains(&n));
            let p = pow2_sizes(2, 6)(&mut rng);
            assert!(p.is_power_of_two() && (4..=64).contains(&p));
        }
    }
}
