//! Thread-local scratch-buffer pool.
//!
//! §Perf iteration 1: the transform hot paths allocated (and page-faulted)
//! multi-megabyte buffers per call; recycling them per thread removed
//! ~25-40% of fused-transform wall time (see EXPERIMENTS.md §Perf).
//! take_* pops a buffer of exactly the requested length (the pool is
//! keyed per length; buffers are never resized), give_* returns it for
//! reuse. No cross-thread sharing: each worker keeps its own pool, so
//! there is no locking on the hot path.
//!
//! Retention is bounded: each (thread, length) size class keeps at most
//! [`MAX_RETAINED_PER_CLASS`] buffers and drops the rest on `give_*`,
//! so a long-running coordinator that sees many transform sizes cannot
//! leak-by-retention (the hot paths hold at most a couple of buffers of
//! any one class at a time, so the cap never costs a reallocation
//! there).

use std::cell::RefCell;
use std::collections::HashMap;

use crate::fft::C64;

/// Max buffers retained per (thread, length) size class; extras given
/// back beyond this are dropped immediately.
pub const MAX_RETAINED_PER_CLASS: usize = 4;

#[derive(Default)]
struct Pool {
    f64s: HashMap<usize, Vec<Vec<f64>>>,
    c64s: HashMap<usize, Vec<Vec<C64>>>,
}

thread_local! {
    static POOL: RefCell<Pool> = RefCell::new(Pool::default());
}

/// Take an f64 buffer of exactly `len` (contents unspecified).
pub fn take_f64(len: usize) -> Vec<f64> {
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        match p.f64s.get_mut(&len).and_then(Vec::pop) {
            Some(v) => v,
            None => vec![0.0; len],
        }
    })
}

/// Return an f64 buffer to the pool (dropped if the class is full).
pub fn give_f64(v: Vec<f64>) {
    let len = v.len();
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        let bucket = p.f64s.entry(len).or_default();
        if bucket.len() < MAX_RETAINED_PER_CLASS {
            bucket.push(v);
        }
    });
}

/// Take a C64 buffer of exactly `len` (contents unspecified).
pub fn take_c64(len: usize) -> Vec<C64> {
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        match p.c64s.get_mut(&len).and_then(Vec::pop) {
            Some(v) => v,
            None => vec![C64::default(); len],
        }
    })
}

/// Return a C64 buffer to the pool (dropped if the class is full).
pub fn give_c64(v: Vec<C64>) {
    let len = v.len();
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        let bucket = p.c64s.entry(len).or_default();
        if bucket.len() < MAX_RETAINED_PER_CLASS {
            bucket.push(v);
        }
    });
}

/// Buffers currently retained for this thread's f64 class of `len`
/// (tests / metrics).
pub fn retained_f64(len: usize) -> usize {
    POOL.with(|p| p.borrow().f64s.get(&len).map_or(0, Vec::len))
}

/// Buffers currently retained for this thread's C64 class of `len`
/// (tests / metrics).
pub fn retained_c64(len: usize) -> usize {
    POOL.with(|p| p.borrow().c64s.get(&len).map_or(0, Vec::len))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuses_buffers() {
        let mut a = take_f64(1024);
        a[0] = 42.0;
        let ptr = a.as_ptr();
        give_f64(a);
        let b = take_f64(1024);
        assert_eq!(b.as_ptr(), ptr, "same buffer should come back");
        give_f64(b);
    }

    #[test]
    fn distinct_sizes_distinct_buffers() {
        let a = take_f64(64);
        let b = take_f64(128);
        assert_eq!(a.len(), 64);
        assert_eq!(b.len(), 128);
        give_f64(a);
        give_f64(b);
    }

    #[test]
    fn c64_pool_roundtrip() {
        let v = take_c64(33);
        assert_eq!(v.len(), 33);
        give_c64(v);
        let w = take_c64(33);
        assert_eq!(w.len(), 33);
        give_c64(w);
    }

    #[test]
    fn retention_is_capped_per_class() {
        // distinctive length so parallel tests on other threads (own
        // pools) and earlier takes in this thread cannot interfere
        let len = 12347;
        let held: Vec<Vec<f64>> = (0..MAX_RETAINED_PER_CLASS + 3).map(|_| take_f64(len)).collect();
        assert_eq!(retained_f64(len), 0);
        for v in held {
            give_f64(v);
        }
        assert_eq!(retained_f64(len), MAX_RETAINED_PER_CLASS);

        let heldc: Vec<Vec<C64>> = (0..MAX_RETAINED_PER_CLASS + 2).map(|_| take_c64(len)).collect();
        assert_eq!(retained_c64(len), 0);
        for v in heldc {
            give_c64(v);
        }
        assert_eq!(retained_c64(len), MAX_RETAINED_PER_CLASS);
    }
}
