//! Thread-local scratch-buffer pool and the plan-owned [`Workspace`]
//! manifest built on top of it.
//!
//! §Perf iteration 1: the transform hot paths allocated (and page-faulted)
//! multi-megabyte buffers per call; recycling them per thread removed
//! ~25-40% of fused-transform wall time (see EXPERIMENTS.md §Perf).
//! take_* pops a buffer of exactly the requested length (the pool is
//! keyed per length; buffers are never resized), give_* returns it for
//! reuse. No cross-thread sharing: each worker keeps its own pool, so
//! there is no locking on the hot path.
//!
//! Retention is bounded: each (thread, length) size class keeps at most
//! [`MAX_RETAINED_PER_CLASS`] buffers and drops the rest on `give_*`,
//! so a long-running coordinator that sees many transform sizes cannot
//! leak-by-retention (the hot paths hold at most a couple of buffers of
//! any one class at a time, so the cap never costs a reallocation
//! there).
//!
//! §Perf iteration 5 (the batched-engine PR): every fused plan now owns
//! a [`Workspace`] — the manifest of scratch size classes its hot path
//! takes — assembled at plan-build time by each layer registering its
//! own classes (`register_scratch` on the FFT plans). The constructor
//! prewarms the building thread's pool from that manifest, so
//! `forward`/`inverse` perform **zero heap allocations** from the very
//! first call on that thread; any other thread is warm after its first
//! call (the pool is thread-local by design). [`pool_misses`] is the
//! debug allocation guard: it counts, per thread, every `take_*` that
//! had to heap-allocate, so a test can assert a warmed hot path never
//! advances it (see `tests/alloc_free.rs` for the stronger
//! counting-global-allocator assertion).

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::fft::C64;
use crate::util::json::Json;

/// Process-wide mirrors of the per-thread pool activity, folded into
/// `Metrics::snapshot()` (the `_scratch` section) so allocation
/// regressions are visible on any running service, not just in the
/// dedicated alloc test. Relaxed ordering: these are statistics, and
/// every update is a single counter bump.
static TOTAL_MISSES: AtomicU64 = AtomicU64::new(0);
static RETAINED_BUFS: AtomicU64 = AtomicU64::new(0);
static RETAINED_BYTES: AtomicU64 = AtomicU64::new(0);
static PREWARM_CALLS: AtomicU64 = AtomicU64::new(0);
static PREWARM_BYTES: AtomicU64 = AtomicU64::new(0);

const F64_BYTES: u64 = std::mem::size_of::<f64>() as u64;
const F32_BYTES: u64 = std::mem::size_of::<f32>() as u64;
const C64_BYTES: u64 = std::mem::size_of::<C64>() as u64;

/// Max buffers retained per (thread, length) size class; extras given
/// back beyond this are dropped immediately.
pub const MAX_RETAINED_PER_CLASS: usize = 4;

#[derive(Default)]
struct Pool {
    f64s: HashMap<usize, Vec<Vec<f64>>>,
    f32s: HashMap<usize, Vec<Vec<f32>>>,
    c64s: HashMap<usize, Vec<Vec<C64>>>,
}

thread_local! {
    static POOL: RefCell<Pool> = RefCell::new(Pool::default());
    static MISSES: Cell<u64> = const { Cell::new(0) };
}

/// How many times this thread's pool missed (a `take_*` that had to
/// heap-allocate) since the thread started. The counter is monotonic;
/// callers snapshot it around a hot section and assert it did not move.
/// This is the debug allocation guard the zero-allocation contract is
/// asserted with.
pub fn pool_misses() -> u64 {
    MISSES.with(Cell::get)
}

fn note_miss() {
    MISSES.with(|m| m.set(m.get() + 1));
    TOTAL_MISSES.fetch_add(1, Ordering::Relaxed);
}

/// Process-wide pool misses across every thread since process start
/// (the cross-thread companion of the per-thread [`pool_misses`]).
pub fn total_pool_misses() -> u64 {
    TOTAL_MISSES.load(Ordering::Relaxed)
}

/// Pool statistics as a JSON object (the metrics snapshot's `_scratch`
/// section): process-wide miss count, currently retained buffer
/// count/bytes across all thread pools, and prewarm activity.
pub fn stats_json() -> Json {
    let mut o = std::collections::BTreeMap::new();
    o.insert("pool_misses".to_string(), Json::Num(TOTAL_MISSES.load(Ordering::Relaxed) as f64));
    o.insert(
        "retained_buffers".to_string(),
        Json::Num(RETAINED_BUFS.load(Ordering::Relaxed) as f64),
    );
    o.insert(
        "retained_bytes".to_string(),
        Json::Num(RETAINED_BYTES.load(Ordering::Relaxed) as f64),
    );
    o.insert(
        "prewarm_calls".to_string(),
        Json::Num(PREWARM_CALLS.load(Ordering::Relaxed) as f64),
    );
    o.insert(
        "prewarm_bytes".to_string(),
        Json::Num(PREWARM_BYTES.load(Ordering::Relaxed) as f64),
    );
    o.insert(
        "max_retained_per_class".to_string(),
        Json::Num(MAX_RETAINED_PER_CLASS as f64),
    );
    Json::Obj(o)
}

/// Drop every buffer retained by this thread's pool. Benches use this to
/// measure the allocate-per-call behaviour the pool (and the plan-owned
/// [`Workspace`] prewarm) replaced.
pub fn clear_thread_pool() {
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        let (mut bufs, mut bytes) = (0u64, 0u64);
        for (len, b) in p.f64s.iter() {
            bufs += b.len() as u64;
            bytes += b.len() as u64 * *len as u64 * F64_BYTES;
        }
        for (len, b) in p.f32s.iter() {
            bufs += b.len() as u64;
            bytes += b.len() as u64 * *len as u64 * F32_BYTES;
        }
        for (len, b) in p.c64s.iter() {
            bufs += b.len() as u64;
            bytes += b.len() as u64 * *len as u64 * C64_BYTES;
        }
        RETAINED_BUFS.fetch_sub(bufs, Ordering::Relaxed);
        RETAINED_BYTES.fetch_sub(bytes, Ordering::Relaxed);
        p.f64s.clear();
        p.f32s.clear();
        p.c64s.clear();
    });
}

/// Take an f64 buffer of exactly `len` (contents unspecified).
pub fn take_f64(len: usize) -> Vec<f64> {
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        match p.f64s.get_mut(&len).and_then(Vec::pop) {
            Some(v) => {
                RETAINED_BUFS.fetch_sub(1, Ordering::Relaxed);
                RETAINED_BYTES.fetch_sub(len as u64 * F64_BYTES, Ordering::Relaxed);
                v
            }
            None => {
                note_miss();
                vec![0.0; len]
            }
        }
    })
}

/// Return an f64 buffer to the pool (dropped if the class is full).
pub fn give_f64(v: Vec<f64>) {
    let len = v.len();
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        let bucket = p.f64s.entry(len).or_default();
        if bucket.len() < MAX_RETAINED_PER_CLASS {
            bucket.push(v);
            RETAINED_BUFS.fetch_add(1, Ordering::Relaxed);
            RETAINED_BYTES.fetch_add(len as u64 * F64_BYTES, Ordering::Relaxed);
        }
    });
}

/// Take an f32 buffer of exactly `len` (contents unspecified). The f32
/// size classes back the generic-element (`ElemType::F32`) plans; they
/// share the retention cap and miss accounting with the f64/C64 classes.
pub fn take_f32(len: usize) -> Vec<f32> {
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        match p.f32s.get_mut(&len).and_then(Vec::pop) {
            Some(v) => {
                RETAINED_BUFS.fetch_sub(1, Ordering::Relaxed);
                RETAINED_BYTES.fetch_sub(len as u64 * F32_BYTES, Ordering::Relaxed);
                v
            }
            None => {
                note_miss();
                vec![0.0f32; len]
            }
        }
    })
}

/// Return an f32 buffer to the pool (dropped if the class is full).
pub fn give_f32(v: Vec<f32>) {
    let len = v.len();
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        let bucket = p.f32s.entry(len).or_default();
        if bucket.len() < MAX_RETAINED_PER_CLASS {
            bucket.push(v);
            RETAINED_BUFS.fetch_add(1, Ordering::Relaxed);
            RETAINED_BYTES.fetch_add(len as u64 * F32_BYTES, Ordering::Relaxed);
        }
    });
}

/// Take a C64 buffer of exactly `len` (contents unspecified).
pub fn take_c64(len: usize) -> Vec<C64> {
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        match p.c64s.get_mut(&len).and_then(Vec::pop) {
            Some(v) => {
                RETAINED_BUFS.fetch_sub(1, Ordering::Relaxed);
                RETAINED_BYTES.fetch_sub(len as u64 * C64_BYTES, Ordering::Relaxed);
                v
            }
            None => {
                note_miss();
                vec![C64::default(); len]
            }
        }
    })
}

/// Return a C64 buffer to the pool (dropped if the class is full).
pub fn give_c64(v: Vec<C64>) {
    let len = v.len();
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        let bucket = p.c64s.entry(len).or_default();
        if bucket.len() < MAX_RETAINED_PER_CLASS {
            bucket.push(v);
            RETAINED_BUFS.fetch_add(1, Ordering::Relaxed);
            RETAINED_BYTES.fetch_add(len as u64 * C64_BYTES, Ordering::Relaxed);
        }
    });
}

/// Buffers currently retained for this thread's f64 class of `len`
/// (tests / metrics).
pub fn retained_f64(len: usize) -> usize {
    POOL.with(|p| p.borrow().f64s.get(&len).map_or(0, Vec::len))
}

/// Buffers currently retained for this thread's C64 class of `len`
/// (tests / metrics).
pub fn retained_c64(len: usize) -> usize {
    POOL.with(|p| p.borrow().c64s.get(&len).map_or(0, Vec::len))
}

/// Buffers currently retained for this thread's f32 class of `len`
/// (tests / metrics).
pub fn retained_f32(len: usize) -> usize {
    POOL.with(|p| p.borrow().f32s.get(&len).map_or(0, Vec::len))
}

/// Plan-owned scratch manifest: the size classes (with multiplicity) a
/// plan's hot path takes from the thread-local pool.
///
/// Built once at plan-build time — each layer registers its own classes
/// (the fused DCT plans register their pre/spectrum buffers, the FFT
/// plans beneath them register packed-complex, convolution, and planar
/// kernel scratch) — then [`Workspace::prewarm`] populates the current
/// thread's pool so every registered `take_*` is a hit.
///
/// Lifetime rules: buffers live in the *thread-local* pool, not in the
/// plan, so a plan stays `Sync` and concurrent `forward` calls never
/// contend. The constructor prewarms the building thread; any other
/// thread that executes the plan is warm after its first call, and a
/// caller that needs first-call-allocation-free execution on a worker
/// thread calls `prewarm` there itself. Multiplicity above
/// [`MAX_RETAINED_PER_CLASS`] cannot be retained and is clamped by the
/// pool's cap.
#[derive(Debug, Clone, Default)]
pub struct Workspace {
    f64_lens: Vec<usize>,
    f32_lens: Vec<usize>,
    c64_lens: Vec<usize>,
}

impl Workspace {
    /// Empty manifest.
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Register one f64 scratch buffer of `len` elements (call twice for
    /// two simultaneously-held buffers of the same class).
    pub fn add_f64(&mut self, len: usize) {
        if len > 0 {
            self.f64_lens.push(len);
        }
    }

    /// Register one f32 scratch buffer of `len` elements (the generic
    /// element core registers its planar scratch through this).
    pub fn add_f32(&mut self, len: usize) {
        if len > 0 {
            self.f32_lens.push(len);
        }
    }

    /// Register one C64 scratch buffer of `len` elements.
    pub fn add_c64(&mut self, len: usize) {
        if len > 0 {
            self.c64_lens.push(len);
        }
    }

    /// Absorb every class another manifest registered (plans compose
    /// their own classes with their sub-plans' this way).
    pub fn merge(&mut self, other: &Workspace) {
        self.f64_lens.extend_from_slice(&other.f64_lens);
        self.f32_lens.extend_from_slice(&other.f32_lens);
        self.c64_lens.extend_from_slice(&other.c64_lens);
    }

    /// Total registered f64 elements (introspection / capacity planning).
    pub fn f64_elems(&self) -> usize {
        self.f64_lens.iter().sum()
    }

    /// Total registered f32 elements.
    pub fn f32_elems(&self) -> usize {
        self.f32_lens.iter().sum()
    }

    /// Total registered C64 elements.
    pub fn c64_elems(&self) -> usize {
        self.c64_lens.iter().sum()
    }

    /// Whether nothing has been registered.
    pub fn is_empty(&self) -> bool {
        self.f64_lens.is_empty() && self.f32_lens.is_empty() && self.c64_lens.is_empty()
    }

    /// Populate the **current thread's** pool so that every registered
    /// class holds at least its registered multiplicity: all buffers are
    /// taken first (forcing the pool to materialize the full working
    /// set) and then returned. Idempotent and cheap when already warm.
    pub fn prewarm(&self) {
        PREWARM_CALLS.fetch_add(1, Ordering::Relaxed);
        PREWARM_BYTES.fetch_add(
            self.f64_elems() as u64 * F64_BYTES
                + self.f32_elems() as u64 * F32_BYTES
                + self.c64_elems() as u64 * C64_BYTES,
            Ordering::Relaxed,
        );
        let held_f: Vec<Vec<f64>> = self.f64_lens.iter().map(|&l| take_f64(l)).collect();
        let held_s: Vec<Vec<f32>> = self.f32_lens.iter().map(|&l| take_f32(l)).collect();
        let held_c: Vec<Vec<C64>> = self.c64_lens.iter().map(|&l| take_c64(l)).collect();
        for v in held_f {
            give_f64(v);
        }
        for v in held_s {
            give_f32(v);
        }
        for v in held_c {
            give_c64(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuses_buffers() {
        let mut a = take_f64(1024);
        a[0] = 42.0;
        let ptr = a.as_ptr();
        give_f64(a);
        let b = take_f64(1024);
        assert_eq!(b.as_ptr(), ptr, "same buffer should come back");
        give_f64(b);
    }

    #[test]
    fn distinct_sizes_distinct_buffers() {
        let a = take_f64(64);
        let b = take_f64(128);
        assert_eq!(a.len(), 64);
        assert_eq!(b.len(), 128);
        give_f64(a);
        give_f64(b);
    }

    #[test]
    fn f32_pool_roundtrip_and_workspace_prewarm() {
        let len = 76543; // unique length: guaranteed cold class
        let before = pool_misses();
        let mut a = take_f32(len);
        assert_eq!(pool_misses(), before + 1);
        a[0] = 1.5;
        let ptr = a.as_ptr();
        give_f32(a);
        assert_eq!(retained_f32(len), 1);
        let b = take_f32(len);
        assert_eq!(b.as_ptr(), ptr, "same buffer should come back");
        assert_eq!(pool_misses(), before + 1, "warm take must not miss");
        give_f32(b);

        let wlen = 76547;
        let mut ws = Workspace::new();
        ws.add_f32(wlen);
        assert_eq!(ws.f32_elems(), wlen);
        assert!(!ws.is_empty());
        ws.prewarm();
        assert_eq!(retained_f32(wlen), 1);
        clear_thread_pool();
        assert_eq!(retained_f32(wlen), 0);
    }

    #[test]
    fn c64_pool_roundtrip() {
        let v = take_c64(33);
        assert_eq!(v.len(), 33);
        give_c64(v);
        let w = take_c64(33);
        assert_eq!(w.len(), 33);
        give_c64(w);
    }

    #[test]
    fn retention_is_capped_per_class() {
        // distinctive length so parallel tests on other threads (own
        // pools) and earlier takes in this thread cannot interfere
        let len = 12347;
        let held: Vec<Vec<f64>> = (0..MAX_RETAINED_PER_CLASS + 3).map(|_| take_f64(len)).collect();
        assert_eq!(retained_f64(len), 0);
        for v in held {
            give_f64(v);
        }
        assert_eq!(retained_f64(len), MAX_RETAINED_PER_CLASS);

        let heldc: Vec<Vec<C64>> = (0..MAX_RETAINED_PER_CLASS + 2).map(|_| take_c64(len)).collect();
        assert_eq!(retained_c64(len), 0);
        for v in heldc {
            give_c64(v);
        }
        assert_eq!(retained_c64(len), MAX_RETAINED_PER_CLASS);
    }

    #[test]
    fn workspace_prewarm_makes_takes_hit() {
        // distinctive lengths so other tests in this thread cannot
        // have warmed the classes already
        let (a, b) = (54321, 54323);
        let mut ws = Workspace::new();
        ws.add_f64(a);
        ws.add_f64(a); // multiplicity 2: both held at once in the hot path
        ws.add_c64(b);
        assert_eq!(ws.f64_elems(), 2 * a);
        assert_eq!(ws.c64_elems(), b);
        assert!(!ws.is_empty());
        ws.prewarm();
        assert_eq!(retained_f64(a), 2);
        assert_eq!(retained_c64(b), 1);
        // a warmed take/give cycle is a pool hit: the miss guard stays put
        let before = pool_misses();
        let x = take_f64(a);
        let y = take_f64(a);
        let z = take_c64(b);
        give_f64(x);
        give_f64(y);
        give_c64(z);
        assert_eq!(pool_misses(), before, "warmed takes must not miss");
    }

    #[test]
    fn stats_json_reports_activity() {
        // counters are process-wide and other tests run concurrently, so
        // assert monotonicity and schema, not exact values
        let before = total_pool_misses();
        give_f64(take_f64(98765)); // unique length: guaranteed cold
        assert!(total_pool_misses() > before);
        let mut ws = Workspace::new();
        ws.add_f64(16);
        ws.prewarm();
        match stats_json() {
            Json::Obj(o) => {
                for key in [
                    "pool_misses",
                    "retained_buffers",
                    "retained_bytes",
                    "prewarm_calls",
                    "prewarm_bytes",
                    "max_retained_per_class",
                ] {
                    match o.get(key) {
                        Some(Json::Num(n)) => assert!(*n >= 0.0, "{key} must be non-negative"),
                        other => panic!("missing numeric key {key}: {other:?}"),
                    }
                }
            }
            other => panic!("stats_json must be an object, got {other:?}"),
        }
    }

    #[test]
    fn miss_guard_counts_cold_takes_and_clear_resets_retention() {
        let len = 54329; // unique to this test
        let before = pool_misses();
        give_f64(take_f64(len)); // cold: one miss
        assert_eq!(pool_misses(), before + 1);
        give_f64(take_f64(len)); // warm: no further miss
        assert_eq!(pool_misses(), before + 1);
        assert_eq!(retained_f64(len), 1);
        clear_thread_pool();
        assert_eq!(retained_f64(len), 0);
        // zero-length registrations are ignored
        let mut ws = Workspace::new();
        ws.add_f64(0);
        ws.add_c64(0);
        assert!(ws.is_empty());
    }
}
