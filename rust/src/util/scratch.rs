//! Thread-local scratch-buffer pool.
//!
//! §Perf iteration 1: the transform hot paths allocated (and page-faulted)
//! multi-megabyte buffers per call; recycling them per thread removed
//! ~25-40% of fused-transform wall time (see EXPERIMENTS.md §Perf).
//! take_* pops a buffer of at least the requested length (resized to it),
//! give_* returns it for reuse. No cross-thread sharing: each worker
//! keeps its own pool, so there is no locking on the hot path.

use std::cell::RefCell;
use std::collections::HashMap;

use crate::fft::C64;

#[derive(Default)]
struct Pool {
    f64s: HashMap<usize, Vec<Vec<f64>>>,
    c64s: HashMap<usize, Vec<Vec<C64>>>,
}

thread_local! {
    static POOL: RefCell<Pool> = RefCell::new(Pool::default());
}

/// Take an f64 buffer of exactly `len` (contents unspecified).
pub fn take_f64(len: usize) -> Vec<f64> {
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        match p.f64s.get_mut(&len).and_then(Vec::pop) {
            Some(v) => v,
            None => vec![0.0; len],
        }
    })
}

/// Return an f64 buffer to the pool.
pub fn give_f64(v: Vec<f64>) {
    let len = v.len();
    POOL.with(|p| p.borrow_mut().f64s.entry(len).or_default().push(v));
}

/// Take a C64 buffer of exactly `len` (contents unspecified).
pub fn take_c64(len: usize) -> Vec<C64> {
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        match p.c64s.get_mut(&len).and_then(Vec::pop) {
            Some(v) => v,
            None => vec![C64::default(); len],
        }
    })
}

/// Return a C64 buffer to the pool.
pub fn give_c64(v: Vec<C64>) {
    let len = v.len();
    POOL.with(|p| p.borrow_mut().c64s.entry(len).or_default().push(v));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuses_buffers() {
        let mut a = take_f64(1024);
        a[0] = 42.0;
        let ptr = a.as_ptr();
        give_f64(a);
        let b = take_f64(1024);
        assert_eq!(b.as_ptr(), ptr, "same buffer should come back");
        give_f64(b);
    }

    #[test]
    fn distinct_sizes_distinct_buffers() {
        let a = take_f64(64);
        let b = take_f64(128);
        assert_eq!(a.len(), 64);
        assert_eq!(b.len(), 128);
        give_f64(a);
        give_f64(b);
    }

    #[test]
    fn c64_pool_roundtrip() {
        let v = take_c64(33);
        assert_eq!(v.len(), 33);
        give_c64(v);
        let w = take_c64(33);
        assert_eq!(w.len(), 33);
        give_c64(w);
    }
}
