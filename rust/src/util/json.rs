//! Minimal JSON substrate (serde_json substitute): a DOM parser/writer
//! for small machine-generated documents (the artifact manifest, metrics
//! dumps) plus a pull-based streaming reader/writer for the wire
//! protocol (`crate::server`), where request payloads parse directly
//! into the transform buffer and replies serialize straight from the
//! output slice — no DOM is ever materialized on the hot path.
//!
//! Both parsers share the same byte-level scanner ([`JsonReader`]), so
//! they accept the same grammar and enforce the same hardening rules:
//! containers nest at most [`MAX_DEPTH`] levels (a hostile frame cannot
//! overflow the stack) and numbers must be finite (`1e999` is a typed
//! error, never `inf`).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// The `null` literal.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (always held as f64; must be finite).
    Num(f64),
    /// A string.
    Str(String),
    /// An array of values.
    Arr(Vec<Json>),
    /// An object, key-sorted (BTreeMap) for deterministic serialization.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object field access; `None` for non-objects / missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array view; `None` for non-arrays.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// String view; `None` for non-strings.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value; `None` for non-numbers.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value truncated to usize; `None` for non-numbers.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// Parse a JSON document from text.
    ///
    /// ```
    /// use mddct::util::json::Json;
    ///
    /// let doc = Json::parse(r#"{"op": "dct2d", "shape": [8, 8]}"#).unwrap();
    /// assert_eq!(doc.get("op").and_then(Json::as_str), Some("dct2d"));
    /// assert_eq!(doc.get("shape").and_then(Json::as_arr).map(|a| a.len()), Some(2));
    /// // numbers must be finite: 1e999 is a typed error, never `inf`
    /// assert!(Json::parse("[1e999]").is_err());
    /// ```
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut r = JsonReader::new(text.as_bytes());
        let v = dom_value(&mut r, 0)?;
        r.end()?;
        Ok(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset into the input where the error was detected.
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Any JSON error arriving from the wire is a malformed request: the
/// client sent bytes the protocol cannot accept, and retrying the
/// identical frame can never succeed.
impl From<JsonError> for crate::util::error::TransformError {
    fn from(e: JsonError) -> Self {
        crate::util::error::TransformError::InvalidRequest(format!("wire json: {e}"))
    }
}

/// Maximum container nesting depth both parsers accept. Deep enough for
/// any real document this crate produces (snapshots nest 3–4 levels),
/// shallow enough that a hostile `[[[[...` frame errors out long before
/// the recursion threatens the stack.
pub const MAX_DEPTH: usize = 64;

/// Pull-based streaming JSON reader over a byte slice.
///
/// The caller drives the grammar: `obj_begin`/`obj_key` and
/// `arr_begin`/`arr_next` step through containers, scalar methods
/// consume one value, [`JsonReader::skip_value`] discards an
/// unrecognized field (depth-capped), and
/// [`JsonReader::read_f64_array`] parses a numeric array *directly into
/// a caller-owned buffer* — the wire decoder hands it the transform
/// input vector, so payload bytes become `f64`s with no intermediate
/// DOM or per-element allocation.
///
/// Every method fails with a typed [`JsonError`] carrying the byte
/// offset; nothing panics on malformed input (the fuzz harness in
/// `tests/fuzz_wire.rs` holds it to that).
pub struct JsonReader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> JsonReader<'a> {
    /// Reader over a byte slice (usually one wire frame body).
    pub fn new(bytes: &'a [u8]) -> JsonReader<'a> {
        JsonReader { b: bytes, i: 0 }
    }

    /// Current byte offset (for error context).
    pub fn offset(&self) -> usize {
        self.i
    }

    fn fail(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.fail(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str) -> Result<(), JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(())
        } else {
            Err(self.fail(&format!("expected literal {s}")))
        }
    }

    /// Consume `{`.
    pub fn obj_begin(&mut self) -> Result<(), JsonError> {
        self.skip_ws();
        self.expect(b'{')
    }

    /// Step to the next object entry: returns its key with the `:`
    /// consumed (the next read is the value), or `None` after consuming
    /// the closing `}`. Pass `first = true` for the entry right after
    /// `obj_begin`, `false` once a value has been read.
    pub fn obj_key(&mut self, first: bool) -> Result<Option<String>, JsonError> {
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(None);
        }
        if !first {
            self.expect(b',')?;
            self.skip_ws();
        }
        let k = self.string_value()?;
        self.skip_ws();
        self.expect(b':')?;
        Ok(Some(k))
    }

    /// Consume `[`.
    pub fn arr_begin(&mut self) -> Result<(), JsonError> {
        self.skip_ws();
        self.expect(b'[')
    }

    /// Step to the next array element: `true` = an element follows (read
    /// it next), `false` = the closing `]` was consumed. Pass
    /// `first = true` right after `arr_begin`.
    pub fn arr_next(&mut self, first: bool) -> Result<bool, JsonError> {
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(false);
        }
        if !first {
            self.expect(b',')?;
        }
        Ok(true)
    }

    /// Consume one string value (full escape handling, UTF-8 validated).
    pub fn string_value(&mut self) -> Result<String, JsonError> {
        self.skip_ws();
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.fail("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.fail("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.fail("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.fail("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.fail("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar (multi-byte safe)
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.fail("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    /// Consume one finite number. `NaN`/`Infinity` tokens never match
    /// the grammar, and an overflowing literal (`1e999`) is rejected
    /// rather than parsed to `inf` — the transform pipeline only ever
    /// sees finite payloads.
    pub fn f64_value(&mut self) -> Result<f64, JsonError> {
        self.skip_ws();
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        let v = txt.parse::<f64>().map_err(|_| self.fail("bad number"))?;
        if !v.is_finite() {
            return Err(self.fail("number overflows f64"));
        }
        Ok(v)
    }

    /// Consume one non-negative integer (an exactly-representable
    /// integral number; `2.5`, negatives, and values past 2^53 fail).
    pub fn u64_value(&mut self) -> Result<u64, JsonError> {
        let at = self.i;
        let v = self.f64_value()?;
        if v < 0.0 || v.fract() != 0.0 || v > 9007199254740992.0 {
            return Err(JsonError {
                msg: format!("expected unsigned integer, got {v}"),
                offset: at,
            });
        }
        Ok(v as u64)
    }

    /// Consume `true` or `false`.
    pub fn bool_value(&mut self) -> Result<bool, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b't') => self.lit("true").map(|_| true),
            Some(b'f') => self.lit("false").map(|_| false),
            _ => Err(self.fail("expected bool")),
        }
    }

    /// Consume `null`.
    pub fn null_value(&mut self) -> Result<(), JsonError> {
        self.skip_ws();
        self.lit("null")
    }

    /// Consume a numeric array, appending each element to `out` — the
    /// wire decoder's zero-DOM payload path. Returns the element count.
    pub fn read_f64_array(&mut self, out: &mut Vec<f64>) -> Result<usize, JsonError> {
        self.arr_begin()?;
        let mut first = true;
        let mut n = 0usize;
        while self.arr_next(first)? {
            first = false;
            out.push(self.f64_value()?);
            n += 1;
        }
        Ok(n)
    }

    /// Discard one value of any type (unknown fields stay
    /// forward-compatible). Depth-capped at [`MAX_DEPTH`].
    pub fn skip_value(&mut self) -> Result<(), JsonError> {
        self.skip_value_depth(0)
    }

    fn skip_value_depth(&mut self, depth: usize) -> Result<(), JsonError> {
        if depth > MAX_DEPTH {
            return Err(self.fail("nesting too deep"));
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => {
                self.obj_begin()?;
                let mut first = true;
                while self.obj_key(first)?.is_some() {
                    first = false;
                    self.skip_value_depth(depth + 1)?;
                }
                Ok(())
            }
            Some(b'[') => {
                self.arr_begin()?;
                let mut first = true;
                while self.arr_next(first)? {
                    first = false;
                    self.skip_value_depth(depth + 1)?;
                }
                Ok(())
            }
            Some(b'"') => self.string_value().map(|_| ()),
            Some(b't' | b'f') => self.bool_value().map(|_| ()),
            Some(b'n') => self.null_value(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.f64_value().map(|_| ()),
            _ => Err(self.fail("unexpected character")),
        }
    }

    /// Consume one value of any type as a DOM [`Json`] — for cold paths
    /// like a metrics snapshot embedded in a reply frame. Depth-capped
    /// at [`MAX_DEPTH`].
    pub fn value(&mut self) -> Result<Json, JsonError> {
        dom_value(self, 0)
    }

    /// Assert the input is exhausted (only trailing whitespace left).
    pub fn end(&mut self) -> Result<(), JsonError> {
        self.skip_ws();
        if self.i != self.b.len() {
            return Err(self.fail("trailing characters"));
        }
        Ok(())
    }
}

/// DOM construction on top of the streaming reader (shared grammar,
/// shared depth cap).
fn dom_value(r: &mut JsonReader, depth: usize) -> Result<Json, JsonError> {
    if depth > MAX_DEPTH {
        return Err(r.fail("nesting too deep"));
    }
    r.skip_ws();
    match r.peek() {
        Some(b'{') => {
            r.obj_begin()?;
            let mut m = BTreeMap::new();
            let mut first = true;
            while let Some(k) = r.obj_key(first)? {
                first = false;
                m.insert(k, dom_value(r, depth + 1)?);
            }
            Ok(Json::Obj(m))
        }
        Some(b'[') => {
            r.arr_begin()?;
            let mut v = Vec::new();
            let mut first = true;
            while r.arr_next(first)? {
                first = false;
                v.push(dom_value(r, depth + 1)?);
            }
            Ok(Json::Arr(v))
        }
        Some(b'"') => Ok(Json::Str(r.string_value()?)),
        Some(b't' | b'f') => Ok(Json::Bool(r.bool_value()?)),
        Some(b'n') => {
            r.null_value()?;
            Ok(Json::Null)
        }
        Some(c) if c == b'-' || c.is_ascii_digit() => Ok(Json::Num(r.f64_value()?)),
        _ => Err(r.fail("unexpected character")),
    }
}

/// Escape a string into `out` per the JSON grammar (the writer-side
/// twin of [`JsonReader::string_value`]).
fn escape_into(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                use fmt::Write as _;
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Streaming JSON writer: appends tokens to one growing `String` with a
/// comma-state stack, so a reply frame is serialized in a single pass
/// with no intermediate values. [`JsonWriter::f64_slice`] streams a
/// numeric array straight from a borrowed slice — the wire encoder
/// hands it the transform output buffer directly.
///
/// `f64` values print via Rust's shortest-round-trip formatting, so
/// `decode(encode(x))` is bit-identical for every finite value
/// (including `-0.0` and subnormals); non-finite values are written as
/// `null` (the reader side rejects non-finite numbers, so a round trip
/// through the wire never manufactures them).
pub struct JsonWriter {
    s: String,
    stack: Vec<bool>,
    suppress: bool,
}

impl Default for JsonWriter {
    fn default() -> Self {
        Self::new()
    }
}

impl JsonWriter {
    /// Empty writer.
    pub fn new() -> JsonWriter {
        Self::with_capacity(64)
    }

    /// Writer with a pre-sized output buffer (one allocation for a
    /// reply whose payload size is known).
    pub fn with_capacity(cap: usize) -> JsonWriter {
        JsonWriter { s: String::with_capacity(cap), stack: Vec::new(), suppress: false }
    }

    /// Comma bookkeeping: emit a separator unless this is the first
    /// element at the current level or the value right after a key.
    fn sep(&mut self) {
        if self.suppress {
            self.suppress = false;
            return;
        }
        if let Some(first_done) = self.stack.last_mut() {
            if *first_done {
                self.s.push(',');
            } else {
                *first_done = true;
            }
        }
    }

    /// Open an object.
    pub fn obj_begin(&mut self) -> &mut Self {
        self.sep();
        self.s.push('{');
        self.stack.push(false);
        self
    }

    /// Close the current object.
    pub fn obj_end(&mut self) -> &mut Self {
        self.stack.pop();
        self.s.push('}');
        self
    }

    /// Open an array.
    pub fn arr_begin(&mut self) -> &mut Self {
        self.sep();
        self.s.push('[');
        self.stack.push(false);
        self
    }

    /// Close the current array.
    pub fn arr_end(&mut self) -> &mut Self {
        self.stack.pop();
        self.s.push(']');
        self
    }

    /// Object key (escaped); the next value call attaches to it.
    pub fn key(&mut self, k: &str) -> &mut Self {
        self.sep();
        escape_into(&mut self.s, k);
        self.s.push(':');
        self.suppress = true;
        self
    }

    /// String value (escaped).
    pub fn str_value(&mut self, v: &str) -> &mut Self {
        self.sep();
        escape_into(&mut self.s, v);
        self
    }

    /// Finite `f64` value in shortest-round-trip form (`null` when
    /// non-finite).
    pub fn f64_value(&mut self, v: f64) -> &mut Self {
        self.sep();
        if v.is_finite() {
            use fmt::Write as _;
            let _ = write!(self.s, "{v}");
        } else {
            self.s.push_str("null");
        }
        self
    }

    /// Unsigned integer value.
    pub fn u64_value(&mut self, v: u64) -> &mut Self {
        self.sep();
        use fmt::Write as _;
        let _ = write!(self.s, "{v}");
        self
    }

    /// Boolean value.
    pub fn bool_value(&mut self, v: bool) -> &mut Self {
        self.sep();
        self.s.push_str(if v { "true" } else { "false" });
        self
    }

    /// `null`.
    pub fn null_value(&mut self) -> &mut Self {
        self.sep();
        self.s.push_str("null");
        self
    }

    /// Embed pre-rendered JSON verbatim (e.g. a metrics snapshot's
    /// `Display` output) as one value.
    pub fn raw(&mut self, json: &str) -> &mut Self {
        self.sep();
        self.s.push_str(json);
        self
    }

    /// Stream a borrowed slice as a numeric array — the zero-copy reply
    /// path: output elements go from the transform buffer to wire bytes
    /// without an intermediate collection.
    pub fn f64_slice(&mut self, xs: &[f64]) -> &mut Self {
        self.arr_begin();
        for &x in xs {
            self.f64_value(x);
        }
        self.arr_end()
    }

    /// The serialized document so far.
    pub fn as_str(&self) -> &str {
        &self.s
    }

    /// Finish and take the serialized document.
    pub fn finish(self) -> String {
        self.s
    }
}

/// Serialize with escaping; deterministic key order (BTreeMap).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64().unwrap(), 2.0);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""tab\t quote\" uA π""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "tab\t quote\" uA π");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"entries":[{"name":"dct2d_64x64","shape":[64,64]}],"version":1}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn rejects_nonfinite_numbers_as_typed_errors() {
        // overflow must not become inf — the transform pipeline is
        // guaranteed finite inputs by the decoder
        assert!(Json::parse("1e999").is_err());
        assert!(Json::parse("-1e999").is_err());
        let mut r = JsonReader::new(b"1e999");
        assert!(r.f64_value().is_err());
        // NaN / Infinity tokens never match the grammar
        assert!(Json::parse("NaN").is_err());
        assert!(Json::parse("Infinity").is_err());
        // underflow is fine (rounds to zero)
        assert_eq!(Json::parse("1e-999").unwrap(), Json::Num(0.0));
    }

    #[test]
    fn depth_cap_rejects_hostile_nesting_without_overflow() {
        // far past MAX_DEPTH: must return a typed error, not blow the
        // stack (this is the fuzz harness's deep-nesting class)
        let hostile = "[".repeat(100_000);
        assert!(Json::parse(&hostile).is_err());
        let mut r = JsonReader::new(hostile.as_bytes());
        assert!(r.skip_value().is_err());
        // exactly at the cap still parses
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn streaming_reader_pulls_objects_and_arrays() {
        let doc = br#" {"op":"dct2d", "shape":[8, 8], "extra":{"deep":[1,2]}, "data":[1.5,-2.0]} "#;
        let mut r = JsonReader::new(doc);
        r.obj_begin().unwrap();
        let mut first = true;
        let mut data: Vec<f64> = Vec::new();
        let mut shape: Vec<u64> = Vec::new();
        let mut op = String::new();
        while let Some(k) = r.obj_key(first).unwrap() {
            first = false;
            match k.as_str() {
                "op" => op = r.string_value().unwrap(),
                "shape" => {
                    r.arr_begin().unwrap();
                    let mut f = true;
                    while r.arr_next(f).unwrap() {
                        f = false;
                        shape.push(r.u64_value().unwrap());
                    }
                }
                "data" => {
                    assert_eq!(r.read_f64_array(&mut data).unwrap(), 2);
                }
                _ => r.skip_value().unwrap(),
            }
        }
        r.end().unwrap();
        assert_eq!(op, "dct2d");
        assert_eq!(shape, [8, 8]);
        assert_eq!(data, [1.5, -2.0]);
    }

    #[test]
    fn u64_value_rejects_fractions_and_negatives() {
        assert_eq!(JsonReader::new(b"42").u64_value().unwrap(), 42);
        assert!(JsonReader::new(b"2.5").u64_value().is_err());
        assert!(JsonReader::new(b"-1").u64_value().is_err());
        assert!(JsonReader::new(b"1e20").u64_value().is_err());
        // scientific notation for an exact integer is accepted
        assert_eq!(JsonReader::new(b"1e3").u64_value().unwrap(), 1000);
    }

    #[test]
    fn writer_builds_documents_the_reader_accepts() {
        let mut w = JsonWriter::new();
        w.obj_begin();
        w.key("ok").bool_value(true);
        w.key("id").u64_value(7);
        w.key("msg").str_value("a \"quoted\"\nline");
        w.key("data").f64_slice(&[1.5, -0.0, 3e-300]);
        w.key("nested").obj_begin();
        w.key("empty").arr_begin();
        w.arr_end();
        w.obj_end();
        w.obj_end();
        let doc = w.finish();
        let v = Json::parse(&doc).unwrap();
        assert_eq!(v.get("ok").unwrap(), &Json::Bool(true));
        assert_eq!(v.get("id").unwrap().as_f64().unwrap(), 7.0);
        assert_eq!(v.get("msg").unwrap().as_str().unwrap(), "a \"quoted\"\nline");
        assert_eq!(v.get("nested").unwrap().get("empty").unwrap(), &Json::Arr(vec![]));
    }

    #[test]
    fn writer_f64_round_trips_bit_identically() {
        let edge = [
            0.0,
            -0.0,
            1.0,
            -1.5,
            1e300,
            -1e300,
            f64::MIN_POSITIVE,
            5e-324, // smallest subnormal
            std::f64::consts::PI,
            123456789.123456789,
        ];
        let mut w = JsonWriter::new();
        w.f64_slice(&edge);
        let doc = w.finish();
        let mut back = Vec::new();
        JsonReader::new(doc.as_bytes()).read_f64_array(&mut back).unwrap();
        assert_eq!(back.len(), edge.len());
        for (a, b) in edge.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
        // non-finite writes null (readable, but typed-rejected as f64)
        let mut w = JsonWriter::new();
        w.f64_value(f64::NAN);
        assert_eq!(w.finish(), "null");
    }
}
