//! Deterministic PRNGs (rand-crate substitute): SplitMix64 for seeding and
//! xoshiro256++ for the main stream, plus uniform/normal helpers used by
//! the workload generators, property tests, and benches.

/// SplitMix64 — used to expand a single u64 seed into xoshiro state.
#[derive(Debug, Clone)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    /// Next value in the stream (advances the state).
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality 64-bit generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal deviate (Box-Muller produces pairs)
    spare: Option<f64>,
}

impl Rng {
    /// Seed deterministically from a u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64(seed);
        Rng { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()], spare: None }
    }

    /// Next raw 64-bit value (advances the state).
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi].
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal deviate (Box-Muller with caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u = self.f64();
            if u <= f64::EPSILON {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Vector of standard normals (the benches' standard workload).
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of uniforms in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.range_f64(lo, hi)).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the public SplitMix64 spec.
        let mut sm = SplitMix64(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // determinism
        let mut sm2 = SplitMix64(1234567);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(99);
        let n = 200_000;
        let v: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
            let x = r.range(5, 9);
            assert!((5..=9).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..64).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..64).collect::<Vec<_>>());
    }
}
